//! Operator-representation parity — the contracts of the `MeasureOp`
//! refactor:
//!
//! 1. **DenseOp is bit-exact vs the pre-refactor arithmetic.** An in-test
//!    verbatim copy of the old raw-`Mat` StoIHT step (fused proxy on a
//!    `RowBlock`, top-s, estimate-onto-union) must reproduce the
//!    operator-driven kernel bit for bit, and the sparse halting statistic
//!    must equal the old transposed-copy axpy loop bit for bit.
//! 2. **SubsampledDctOp matches the dense `partial_dct` ensemble.** The
//!    same seed draws the same ensemble under both representations
//!    (entrywise bit-identical); full StoIHT and StoGradMP trajectories
//!    through the matrix-free operator track the dense ones to ≤ 1e-12 per
//!    iterate — sequentially, through the discrete-time simulator, and
//!    through a single-worker `run_async` replay.

use astir::algorithms::{StoGradMpKernel, StoihtKernel, SupportKernel};
use astir::async_runtime::{run_async, run_async_with, AsyncOpts};
use astir::linalg::SparseIterate;
use astir::problem::{Ensemble, Problem, ProblemSpec};
use astir::rng::Rng;
use astir::sim::{simulate, simulate_with, SimOpts, SpeedSchedule};
use astir::support::top_s;

fn dct_spec() -> ProblemSpec {
    ProblemSpec {
        n: 64,
        m: 32,
        b: 8,
        s: 4,
        ensemble: Ensemble::PartialDct,
        ..ProblemSpec::tiny()
    }
}

/// The dense and matrix-free draws of one `partial_dct` ensemble.
fn twin_problems(seed: u64) -> (Problem, Problem) {
    let dense = dct_spec().generate(&mut Rng::seed_from(seed));
    let free =
        ProblemSpec { dense_a: false, ..dct_spec() }.generate(&mut Rng::seed_from(seed));
    (dense, free)
}

// ------------------------------------------------------- 1. dense bitwise

/// Verbatim pre-refactor StoIHT dense step: raw `RowBlock` fused proxy,
/// `top_s`, estimate onto `Γ ∪ extra` — including the exact `alpha`
/// expression `gamma / (M · p)` with uniform `p = 1/M`.
fn reference_stoiht_step(
    p: &Problem,
    x: &mut [f64],
    block: usize,
    gamma: f64,
    extra: Option<&[usize]>,
) -> Vec<usize> {
    let spec = &p.spec;
    let mb = spec.num_blocks() as f64;
    let alpha = gamma / (mb * (1.0 / mb));
    let (blk, yb) = p.block(block);
    let mut resid = vec![0.0; spec.b];
    let mut proxy = vec![0.0; spec.n];
    blk.proxy_step_into(yb, x, alpha, &mut resid, &mut proxy);
    let gamma_set = top_s(&proxy, spec.s);
    x.fill(0.0);
    for &i in &gamma_set {
        x[i] = proxy[i];
    }
    if let Some(extra) = extra {
        for &i in extra {
            x[i] = proxy[i];
        }
    }
    gamma_set
}

#[test]
fn dense_op_stoiht_step_is_bit_exact_vs_raw_mat_arithmetic() {
    for ensemble in [Ensemble::Gaussian, Ensemble::Bernoulli, Ensemble::PartialDct] {
        let spec = ProblemSpec { ensemble, ..dct_spec() };
        let p = spec.generate(&mut Rng::seed_from(3));
        let mut rng = Rng::seed_from(4);
        let mut oracle = rng.subset(p.spec.n, p.spec.s);
        oracle.sort_unstable();
        let mut kernel = StoihtKernel::new(&p, 1.0);
        let mut xk = vec![0.0f64; p.spec.n];
        let mut xr = vec![0.0f64; p.spec.n];
        for it in 0..40 {
            let block = rng.below(p.spec.num_blocks());
            let extra = if it % 2 == 1 { Some(oracle.as_slice()) } else { None };
            let gk = kernel.step(&mut xk, block, extra).to_vec();
            let gr = reference_stoiht_step(&p, &mut xr, block, 1.0, extra);
            assert_eq!(gk, gr, "{ensemble:?} iter {it}: gamma sets differ");
            for i in 0..p.spec.n {
                assert_eq!(
                    xk[i].to_bits(),
                    xr[i].to_bits(),
                    "{ensemble:?} iter {it} coord {i}: {} vs {}",
                    xk[i],
                    xr[i]
                );
            }
        }
    }
}

#[test]
fn dense_op_sparse_residual_is_bit_exact_vs_transposed_axpy_loop() {
    let p = dct_spec().generate(&mut Rng::seed_from(5));
    let mut rng = Rng::seed_from(6);
    let mut supp = rng.subset(p.spec.n, 7);
    supp.sort_unstable();
    let mut x = vec![0.0; p.spec.n];
    for &j in &supp {
        x[j] = rng.gauss();
    }
    // Pre-refactor loop: r = y; axpy(-x_j, a_t.row(j), r); ||r|| — using
    // the crate's own axpy so the operation order is identical.
    let m = p.spec.m;
    let mut r = p.y.clone();
    for &j in &supp {
        let xj = x[j];
        if xj != 0.0 {
            let a_t = p.try_dense_t().expect("dense fixture");
            astir::linalg::axpy(-xj, &a_t.row(j)[..m], &mut r);
        }
    }
    let want = astir::linalg::nrm2(&r);
    let got = p.residual_norm_sparse(&x, &supp);
    assert_eq!(got.to_bits(), want.to_bits());
}

// ------------------------------------------- 2. matrix-free vs dense DCT

#[test]
fn twin_draws_are_entrywise_bit_identical() {
    let (pd, pf) = twin_problems(11);
    assert_eq!(pd.x_true, pf.x_true);
    assert_eq!(pd.support, pf.support);
    let astir::linalg::Operator::SubsampledDct(op) = &*pf.op else {
        panic!("expected the matrix-free operator");
    };
    for i in 0..pd.spec.m {
        for j in 0..pd.spec.n {
            let a = pd.try_dense().expect("dense twin");
            assert_eq!(a.get(i, j).to_bits(), op.entry(i, j).to_bits(), "({i}, {j})");
        }
    }
}

/// `max_i |a_i − b_i|` with the ≤ 1e-12 per-iterate contract.
fn assert_iterates_close(a: &[f64], b: &[f64], what: &str) {
    for i in 0..a.len() {
        let d = (a[i] - b[i]).abs();
        assert!(d <= 1e-12, "{what} coord {i}: {} vs {} (|Δ| = {d:.3e})", a[i], b[i]);
    }
}

#[test]
fn stoiht_trajectories_match_across_representations() {
    let (pd, pf) = twin_problems(21);
    let mut kd = StoihtKernel::new(&pd, 1.0);
    let mut kf = StoihtKernel::new(&pf, 1.0);
    let mut xd = SparseIterate::zeros(pd.spec.n);
    let mut xf = SparseIterate::zeros(pf.spec.n);
    let mut rng = Rng::seed_from(22);
    let oracle = pd.support.clone(); // == pf.support
    let (mut gd, mut gf) = (Vec::new(), Vec::new());
    for it in 0..30 {
        let block = rng.below(pd.spec.num_blocks());
        let est: &[usize] = if it % 3 == 1 { &oracle } else { &[] };
        kd.tally_step(&mut xd, block, est, &mut gd);
        kf.tally_step(&mut xf, block, est, &mut gf);
        assert_eq!(gd, gf, "iter {it}: voted supports diverged");
        assert_iterates_close(xd.values(), xf.values(), &format!("stoiht iter {it}"));
    }
    // The halting statistic agrees across representations too.
    let (mut ra, mut rb) = (Vec::new(), Vec::new());
    let rd = kd.residual(&xd, &mut ra);
    let rf = kf.residual(&xf, &mut rb);
    assert!((rd - rf).abs() <= 1e-12 * (1.0 + rd.abs()), "{rd} vs {rf}");
}

#[test]
fn stogradmp_trajectories_match_across_representations() {
    let (pd, pf) = twin_problems(31);
    let mut kd = StoGradMpKernel::new(&pd);
    let mut kf = StoGradMpKernel::new(&pf);
    let mut xd = SparseIterate::zeros(pd.spec.n);
    let mut xf = SparseIterate::zeros(pf.spec.n);
    let mut rng = Rng::seed_from(32);
    let (mut gd, mut gf) = (Vec::new(), Vec::new());
    for it in 0..12 {
        let block = rng.below(pd.spec.num_blocks());
        let est: &[usize] = if it % 4 == 2 { &pd.support } else { &[] };
        kd.tally_step(&mut xd, block, est, &mut gd);
        kf.tally_step(&mut xf, block, est, &mut gf);
        assert_eq!(gd, gf, "iter {it}: pruned supports diverged");
        assert_iterates_close(xd.values(), xf.values(), &format!("stogradmp iter {it}"));
    }
}

#[test]
fn simulated_async_agrees_across_representations() {
    let (pd, pf) = twin_problems(41);
    let opts = SimOpts::default();
    let sched = SpeedSchedule::AllFast;
    let od = simulate(&pd, 4, &sched, &opts, &mut Rng::seed_from(42));
    let of = simulate(&pf, 4, &sched, &opts, &mut Rng::seed_from(42));
    assert!(od.converged && of.converged, "{} / {}", od.steps, of.steps);
    assert_eq!(od.steps, of.steps, "exit step diverged");
    assert_eq!(od.exit_core, of.exit_core);
    assert_eq!(od.local_iters, of.local_iters);
    assert!((od.final_error - of.final_error).abs() <= 1e-10, "final error diverged");
    // StoGradMP through the generic simulator.
    let og =
        simulate_with(&pd, 2, &sched, &opts, &mut Rng::seed_from(43), StoGradMpKernel::new);
    let oh =
        simulate_with(&pf, 2, &sched, &opts, &mut Rng::seed_from(43), StoGradMpKernel::new);
    assert!(og.converged && oh.converged);
    assert_eq!(og.steps, oh.steps);
    assert_eq!(og.exit_core, oh.exit_core);
}

#[test]
fn single_worker_run_async_agrees_across_representations() {
    let (pd, pf) = twin_problems(51);
    let opts = AsyncOpts::default();
    // One worker: the real-thread runtime is deterministic given the seed.
    let od = run_async(&pd, 1, &opts, 99);
    let of = run_async(&pf, 1, &opts, 99);
    assert!(od.converged && of.converged);
    assert_eq!(od.local_iters, of.local_iters, "local iteration counts diverged");
    assert_eq!(od.exit_core, of.exit_core);
    assert_iterates_close(&od.x, &of.x, "winner iterate");
    // ... and for StoGradMP.
    let og = run_async_with(&pd, 1, &opts, 100, StoGradMpKernel::new);
    let oh = run_async_with(&pf, 1, &opts, 100, StoGradMpKernel::new);
    assert!(og.converged && oh.converged);
    assert_eq!(og.local_iters, oh.local_iters);
    assert_iterates_close(&og.x, &oh.x, "stogradmp winner iterate");
}
