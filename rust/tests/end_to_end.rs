//! End-to-end integration: config file → leader → simulator / threads →
//! metrics, at reduced trial counts. These are the cross-module journeys a
//! user takes; shape-level assertions mirror the paper's claims.

use astir::algorithms::{stoiht, GreedyOpts};
use astir::async_runtime::{run_async, AsyncOpts};
use astir::config::ExperimentConfig;
use astir::coordinator::Leader;
use astir::experiments::{self, Fig2Variant};
use astir::problem::ProblemSpec;
use astir::rng::Rng;
use astir::sim::{simulate, SimOpts, SpeedSchedule};

fn small_cfg() -> ExperimentConfig {
    ExperimentConfig {
        problem: ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() },
        trials: 6,
        cores: vec![1, 4],
        trial_threads: 4,
        ..Default::default()
    }
}

#[test]
fn config_file_to_experiment() {
    let dir = std::env::temp_dir().join("astir_e2e_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
trials = 4
max_iters = 1200
cores = [1, 2]
trial_threads = 2
seed = 11

[problem]
n = 96
m = 48
b = 8
s = 4
"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_file(&path).unwrap();
    assert_eq!(cfg.trials, 4);
    let leader = Leader::new(cfg);
    let pts = leader.sweep_cores(&SpeedSchedule::AllFast, &SimOpts::default());
    assert_eq!(pts.len(), 2);
    assert!(pts.iter().all(|p| p.convergence_rate > 0.5));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn simulated_and_real_async_agree_qualitatively() {
    // Same problem: the discrete-time sim and the real-thread runtime must
    // both converge and produce solutions of the same quality.
    let p = small_cfg().problem.generate(&mut Rng::seed_from(21));
    let sim_out =
        simulate(&p, 4, &SpeedSchedule::AllFast, &SimOpts::default(), &mut Rng::seed_from(1));
    let thr_out = run_async(&p, 4, &AsyncOpts::default(), 2);
    assert!(sim_out.converged, "sim steps {}", sim_out.steps);
    assert!(thr_out.converged);
    assert!(sim_out.final_error < 1e-5);
    assert!(thr_out.final_error < 1e-5);
}

#[test]
fn fig1_and_fig2_tables_have_consistent_shapes() {
    let mut cfg = small_cfg();
    cfg.trials = 5;
    let t1 = experiments::fig1(&cfg);
    assert_eq!(t1.series.columns[0], "iteration");
    assert!(t1.series.rows.len() > 20);
    // error columns start positive
    assert!(t1.series.rows[0][1] > 0.0);
    assert_eq!(t1.summary.rows.len(), 6);

    let t2 = experiments::fig2(&cfg, Fig2Variant::Upper);
    assert_eq!(t2.rows.len(), cfg.cores.len());
    // stoiht columns constant across rows
    assert_eq!(t2.rows[0][4], t2.rows[1][4]);
}

#[test]
fn paper_scale_single_trial_smoke() {
    // One full paper-scale trial through each major path (kept single-trial
    // so the suite stays fast).
    let p = ProblemSpec::paper().generate(&mut Rng::seed_from(5));
    let r = stoiht(&p, &GreedyOpts::default(), &mut Rng::seed_from(6));
    assert!(r.converged, "stoiht residual {}", r.residual);
    // Generous cap for the single-trial smoke: individual trials have a
    // long upper tail (the Fig.-2 sweep caps at 1500 like the paper, which
    // censors that tail in the aggregate statistics).
    let sim_opts = SimOpts { max_steps: 5000, ..Default::default() };
    let o = simulate(&p, 8, &SpeedSchedule::AllFast, &sim_opts, &mut Rng::seed_from(7));
    assert!(o.converged, "sim steps {}", o.steps);
    assert!(o.final_error < 1e-4);
}

#[test]
fn slow_schedule_real_threads() {
    let p = small_cfg().problem.generate(&mut Rng::seed_from(30));
    let opts = AsyncOpts { schedule: SpeedSchedule::HalfSlow { period: 3 }, ..Default::default() };
    let out = run_async(&p, 4, &opts, 31);
    assert!(out.converged);
    assert!(p.residual_norm(&out.x) < 1e-6);
}

#[test]
fn noisy_recovery_support_rate_is_pinned() {
    // Regression pin for the `noise_std` knob, which no test exercised
    // end-to-end: with ±1 spikes well above a 0.02 noise floor, both
    // algorithms must keep identifying the planted support. The exit
    // tolerance sits just above the expected noise energy
    // ‖z‖ ≈ 0.02·√m ≈ 0.23, so runs terminate at the noise floor instead
    // of the (unreachable) noiseless 1e-7.
    use astir::algorithms::stogradmp;
    use astir::problem::SignalModel;
    use astir::support::intersection_size;
    let spec = ProblemSpec {
        n: 256,
        m: 128,
        b: 8,
        s: 8,
        signal: SignalModel::FlatSpikes,
        noise_std: 0.02,
        ..ProblemSpec::tiny()
    };
    let trials = 12usize;
    let mut rate = [0.0f64; 2]; // [stoiht, stogradmp]
    for t in 0..trials {
        let p = spec.generate(&mut Rng::seed_from(700 + t as u64));
        let noise_floor_opts = GreedyOpts { tolerance: 0.3, ..Default::default() };
        let r1 = stoiht(&p, &noise_floor_opts, &mut Rng::seed_from(800 + t as u64));
        let opts2 = GreedyOpts { tolerance: 0.3, max_iters: 100, ..Default::default() };
        let r2 = stogradmp(&p, &opts2, &mut Rng::seed_from(900 + t as u64));
        for (k, r) in [r1, r2].into_iter().enumerate() {
            let supp = astir::support::support_of(&r.x);
            rate[k] += intersection_size(&supp, &p.support) as f64 / p.spec.s as f64;
            // Noise keeps the residual off zero: the halting statistic
            // can't do better than ‖z‖.
            assert!(p.residual_norm(&r.x) > 0.05, "trial {t} alg {k} implausibly clean");
            // ... but the estimate still tracks the signal (the ±1 spikes
            // dominate the ≈0.3-residual stopping point comfortably).
            let rel = p.relative_error(&r.x);
            assert!(rel < 0.2, "trial {t} alg {k}: relative error {rel}");
        }
    }
    for (k, name) in ["stoiht", "stogradmp"].iter().enumerate() {
        let mean = rate[k] / trials as f64;
        assert!(mean >= 0.95, "{name}: mean support-recovery rate {mean} under noise");
    }
}
