//! Kernel-parity guarantees for the `SupportKernel` refactor.
//!
//! 1. **Golden StoIHT parity** — `reference_simulate` below is a faithful
//!    copy of the PRE-refactor `sim::simulate` loop (hardwired to
//!    `StoihtKernel::step_sparse` / `::step`, with the read/commit helpers
//!    inlined). The post-refactor generic `simulate` must produce
//!    bit-identical outcomes across seeds, core counts, sharing modes,
//!    fault-injection knobs, weightings, and schedules.
//! 2. **Real-thread parity** — a single-worker `run_async` is
//!    deterministic (no races), so its published iterate must replay
//!    bit-for-bit from a hand-rolled worker loop over the same RNG stream.
//! 3. **Async StoGradMP cross-check** — at `c = 1` with `self_exclude`
//!    the tally estimate is always empty, so the simulated asynchronous
//!    StoGradMP must match sequential `stogradmp` *exactly*
//!    (stream-for-stream, bit-for-bit).

use astir::algorithms::{stogradmp, GreedyOpts, StoGradMpKernel, StoihtKernel, SupportKernel};
use astir::async_runtime::{run_async, AsyncOpts};
use astir::linalg::SparseIterate;
use astir::problem::{Problem, ProblemSpec};
use astir::rng::Rng;
use astir::sim::{simulate, simulate_with, SharingMode, SimOpts, SimOutcome, SpeedSchedule};
use astir::support::{support_of, union};
use astir::tally::{positive_top_s, AtomicTally, LocalTally};

fn easy(seed: u64) -> Problem {
    ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() }
        .generate(&mut Rng::seed_from(seed))
}

// ---------------------------------------------------------------------
// A faithful reimplementation of the pre-refactor simulate() loop.
// ---------------------------------------------------------------------

enum RefPendingX {
    Sparse(SparseIterate<f64>),
    Dense(Vec<f64>),
}

struct RefPending {
    commit_at: usize,
    new_x: RefPendingX,
    gamma: Vec<usize>,
    support: Vec<usize>,
}

fn ref_read_estimate(
    tally: &LocalTally,
    prev_votes: &[i64],
    s: usize,
    stale_prob: f64,
    fault_rng: &mut Rng,
) -> Vec<usize> {
    if stale_prob <= 0.0 {
        return tally.estimate(s);
    }
    let cur = tally.votes();
    let mixed: Vec<i64> = (0..cur.len())
        .map(|i| if fault_rng.bernoulli(stale_prob) { prev_votes[i] } else { cur[i] })
        .collect();
    positive_top_s(&mixed, s)
}

#[allow(clippy::too_many_arguments)]
fn ref_read_estimate_excluding(
    tally: &LocalTally,
    prev_votes: &[i64],
    s: usize,
    stale_prob: f64,
    fault_rng: &mut Rng,
    own_gamma: &[usize],
    own_weight: i64,
) -> Vec<usize> {
    let cur = tally.votes();
    let mut mixed: Vec<i64> = if stale_prob <= 0.0 {
        cur.to_vec()
    } else {
        (0..cur.len())
            .map(|i| if fault_rng.bernoulli(stale_prob) { prev_votes[i] } else { cur[i] })
            .collect()
    };
    for &i in own_gamma {
        mixed[i] -= own_weight;
    }
    positive_top_s(&mixed, s)
}

fn ref_shuffle<T>(items: &mut [T], rng: &mut Rng) {
    for i in (1..items.len()).rev() {
        let j = rng.below(i + 1);
        items.swap(i, j);
    }
}

/// The pre-refactor `sim::simulate`, verbatim modulo private-helper
/// inlining: hardwired StoIHT kernels, `step_sparse` in Tally mode, dense
/// `step` in SharedX mode.
fn reference_simulate(
    problem: &Problem,
    cores: usize,
    schedule: &SpeedSchedule,
    opts: &SimOpts,
    rng: &mut Rng,
) -> SimOutcome {
    assert!(cores >= 1);
    let spec = &problem.spec;
    let periods = schedule.periods(cores);
    let n = spec.n;
    let s = spec.s;

    let mut kernels: Vec<StoihtKernel> =
        (0..cores).map(|_| StoihtKernel::new(problem, opts.gamma)).collect();
    let mut rngs: Vec<Rng> = (0..cores).map(|i| rng.split(i as u64 + 1)).collect();
    let mut xs: Vec<SparseIterate<f64>> = (0..cores).map(|_| SparseIterate::zeros(n)).collect();
    let mut t_local: Vec<u64> = vec![1; cores];
    let mut prev_gamma: Vec<Vec<usize>> = vec![Vec::new(); cores];
    let mut pending: Vec<Option<RefPending>> = (0..cores).map(|_| None).collect();

    let mut tally = LocalTally::new(n, opts.weighting);
    let mut prev_votes: Vec<i64> = vec![0; n];
    let mut shared_x: Vec<f64> = vec![0.0; n];
    let mut commit_order_rng = rng.split(0x5EED);
    let mut fault_rng = rng.split(0xFA17);

    let mut error_trace = Vec::new();

    for step in 1..=opts.max_steps {
        let shared_estimate: Vec<usize> = if opts.mode == SharingMode::Tally && !opts.self_exclude
        {
            ref_read_estimate(&tally, &prev_votes, s, opts.stale_read_prob, &mut fault_rng)
        } else {
            Vec::new()
        };
        for c in 0..cores {
            if pending[c].is_some() {
                continue;
            }
            if (step - 1) % periods[c] != 0 {
                continue;
            }
            let commit_at = step + periods[c] - 1;
            let block = kernels[c].sample_block(&mut rngs[c]);
            let p = match opts.mode {
                SharingMode::Tally => {
                    let estimate: Vec<usize> = if opts.self_exclude {
                        ref_read_estimate_excluding(
                            &tally,
                            &prev_votes,
                            s,
                            opts.stale_read_prob,
                            &mut fault_rng,
                            &prev_gamma[c],
                            opts.weighting.add_weight(t_local[c].saturating_sub(1)),
                        )
                    } else {
                        shared_estimate.clone()
                    };
                    let extra = if estimate.is_empty() { None } else { Some(estimate.as_slice()) };
                    let mut new_x = xs[c].clone();
                    let gamma = kernels[c].step_sparse(&mut new_x, block, extra).to_vec();
                    let support = union(&gamma, &estimate);
                    RefPending { commit_at, new_x: RefPendingX::Sparse(new_x), gamma, support }
                }
                SharingMode::SharedX => {
                    let mut new_x = shared_x.clone();
                    let gamma = kernels[c].step(&mut new_x, block, None).to_vec();
                    let support = gamma.clone();
                    RefPending { commit_at, new_x: RefPendingX::Dense(new_x), gamma, support }
                }
            };
            pending[c] = Some(p);
        }

        prev_votes.copy_from_slice(tally.votes());
        let mut committers: Vec<usize> = (0..cores)
            .filter(|&c| pending[c].as_ref().is_some_and(|p| p.commit_at == step))
            .collect();
        ref_shuffle(&mut committers, &mut commit_order_rng);

        let mut exited: Option<(usize, f64)> = None;
        for &c in &committers {
            let p = pending[c].take().unwrap();
            match p.new_x {
                RefPendingX::Sparse(nx) => {
                    xs[c] = nx;
                    tally.commit(&p.gamma, &prev_gamma[c], t_local[c]);
                    prev_gamma[c] = p.gamma;
                    t_local[c] += 1;
                    if exited.is_none() {
                        let r = problem.residual_norm_sparse(xs[c].values(), &p.support);
                        if r < opts.tolerance {
                            exited = Some((c, problem.recovery_error(xs[c].values())));
                        }
                    }
                }
                RefPendingX::Dense(nx) => {
                    for &i in &prev_gamma[c] {
                        shared_x[i] = 0.0;
                    }
                    for &i in &p.gamma {
                        shared_x[i] = nx[i];
                    }
                    prev_gamma[c] = p.gamma;
                    t_local[c] += 1;
                }
            }
        }
        if opts.mode == SharingMode::SharedX && !committers.is_empty() && exited.is_none() {
            let supp = support_of(&shared_x);
            let r = problem.residual_norm_sparse(&shared_x, &supp);
            if r < opts.tolerance {
                exited = Some((usize::MAX, problem.recovery_error(&shared_x)));
            }
        }

        if opts.record_error {
            let err = match opts.mode {
                SharingMode::Tally => xs
                    .iter()
                    .map(|x| problem.recovery_error(x.values()))
                    .fold(f64::INFINITY, f64::min),
                SharingMode::SharedX => problem.recovery_error(&shared_x),
            };
            error_trace.push(err);
        }

        if let Some((core, err)) = exited {
            return SimOutcome {
                steps: step,
                converged: true,
                exit_core: if core == usize::MAX { None } else { Some(core) },
                local_iters: t_local.iter().map(|&t| t - 1).collect(),
                final_error: err,
                error_trace,
            };
        }
    }

    let final_error = match opts.mode {
        SharingMode::Tally => xs
            .iter()
            .map(|x| problem.recovery_error(x.values()))
            .fold(f64::INFINITY, f64::min),
        SharingMode::SharedX => problem.recovery_error(&shared_x),
    };
    SimOutcome {
        steps: opts.max_steps,
        converged: false,
        exit_core: None,
        local_iters: t_local.iter().map(|&t| t - 1).collect(),
        final_error,
        error_trace,
    }
}

fn assert_outcomes_identical(a: &SimOutcome, b: &SimOutcome, ctx: &str) {
    assert_eq!(a.steps, b.steps, "{ctx}: steps");
    assert_eq!(a.converged, b.converged, "{ctx}: converged");
    assert_eq!(a.exit_core, b.exit_core, "{ctx}: exit core");
    assert_eq!(a.local_iters, b.local_iters, "{ctx}: local iterations");
    assert_eq!(
        a.final_error.to_bits(),
        b.final_error.to_bits(),
        "{ctx}: final error {} vs {}",
        a.final_error,
        b.final_error
    );
    assert_eq!(a.error_trace.len(), b.error_trace.len(), "{ctx}: trace length");
    for (i, (ea, eb)) in a.error_trace.iter().zip(&b.error_trace).enumerate() {
        assert_eq!(ea.to_bits(), eb.to_bits(), "{ctx}: trace[{i}]");
    }
}

#[test]
fn generic_simulate_is_bit_identical_to_prerefactor_stoiht() {
    use astir::tally::TallyWeighting;
    let variants: [(SimOpts, &str); 8] = [
        (SimOpts { max_steps: 400, ..Default::default() }, "default"),
        (SimOpts { max_steps: 400, self_exclude: true, ..Default::default() }, "self_exclude"),
        (SimOpts { max_steps: 400, stale_read_prob: 0.25, ..Default::default() }, "stale_reads"),
        (
            SimOpts { max_steps: 400, mode: SharingMode::SharedX, ..Default::default() },
            "shared_x",
        ),
        (
            SimOpts { max_steps: 400, weighting: TallyWeighting::Unit, ..Default::default() },
            "unit_weighting",
        ),
        (
            SimOpts {
                max_steps: 400,
                weighting: TallyWeighting::NoDecrement,
                ..Default::default()
            },
            "no_decrement",
        ),
        (SimOpts { max_steps: 50, record_error: true, ..Default::default() }, "error_trace"),
        (SimOpts { max_steps: 400, gamma: 0.8, ..Default::default() }, "gamma_0_8"),
    ];
    for seed in 0..3u64 {
        let p = easy(200 + seed);
        for (opts, label) in &variants {
            for (cores, schedule) in [
                (1usize, SpeedSchedule::AllFast),
                (4, SpeedSchedule::AllFast),
                (4, SpeedSchedule::HalfSlow { period: 3 }),
            ] {
                let ctx = format!("seed {seed} {label} c={cores} {schedule:?}");
                let mut rng_new = Rng::seed_from(900 + seed);
                let mut rng_ref = Rng::seed_from(900 + seed);
                let new = simulate(&p, cores, &schedule, opts, &mut rng_new);
                let reference = reference_simulate(&p, cores, &schedule, opts, &mut rng_ref);
                assert_outcomes_identical(&new, &reference, &ctx);
            }
        }
    }
}

#[test]
fn single_worker_run_async_replays_bit_for_bit() {
    // c = 1 has no races: the worker's iterate sequence is a deterministic
    // function of its RNG stream, so the published winner must replay from
    // a hand-rolled copy of the worker loop.
    for seed in [7u64, 41, 2024] {
        let p = easy(300 + seed);
        let opts = AsyncOpts::default();
        let out = run_async(&p, 1, &opts, seed);
        assert!(out.converged, "seed {seed}");

        let mut root = Rng::seed_from(seed);
        let mut rng = root.split(0); // worker 0's stream
        let tally = AtomicTally::new(p.spec.n, opts.weighting);
        let mut kernel = StoihtKernel::new(&p, opts.gamma);
        let mut x = SparseIterate::zeros(p.spec.n);
        let mut gamma: Vec<usize> = Vec::new();
        let mut prev_gamma: Vec<usize> = Vec::new();
        let mut estimate: Vec<usize> = Vec::new();
        let mut tally_scratch: Vec<i64> = Vec::new();
        let mut resid_scratch: Vec<f64> = Vec::new();
        let mut iters = 0u64;
        let mut residual = f64::NAN;
        for t in 1..=opts.max_local_iters as u64 {
            tally.estimate_into(p.spec.s, &mut tally_scratch, &mut estimate);
            let block = kernel.sample_block(&mut rng);
            kernel.tally_step(&mut x, block, &estimate, &mut gamma);
            tally.commit(&gamma, &prev_gamma, t);
            std::mem::swap(&mut prev_gamma, &mut gamma);
            iters = t;
            let r = kernel.residual(&x, &mut resid_scratch);
            if r < opts.tolerance {
                residual = r;
                break;
            }
        }
        assert_eq!(out.local_iters[0], iters, "seed {seed}: iteration count");
        assert_eq!(out.residual.to_bits(), residual.to_bits(), "seed {seed}: residual");
        for i in 0..p.spec.n {
            assert_eq!(
                out.x[i].to_bits(),
                x.values()[i].to_bits(),
                "seed {seed} coord {i}: {} vs {}",
                out.x[i],
                x.values()[i]
            );
        }
    }
}

#[test]
fn async_stogradmp_c1_self_exclude_matches_sequential_exactly() {
    // The acceptance cross-check: with one core and self-excluding tally
    // reads the estimate is always empty, so the asynchronous loop is
    // sequential StoGradMP on the sim's core-0 RNG stream. `simulate`
    // derives that stream as `rng.split(1)`, and the sequential solver
    // rides the identical kernel + sparse exit check, so the match is
    // exact: same step count, bit-identical final error.
    for seed in [11u64, 99, 1234] {
        let p = easy(400 + seed);
        let sim_opts = SimOpts { max_steps: 200, self_exclude: true, ..Default::default() };
        let mut sim_rng = Rng::seed_from(seed);
        let sched = SpeedSchedule::AllFast;
        let out = simulate_with(&p, 1, &sched, &sim_opts, &mut sim_rng, StoGradMpKernel::new);
        assert!(out.converged, "seed {seed}: sim did not converge");

        let mut seq_rng = Rng::seed_from(seed).split(1); // sim core 0's stream
        let opts = GreedyOpts { max_iters: 200, ..Default::default() };
        let r = stogradmp(&p, &opts, &mut seq_rng);
        assert!(r.converged, "seed {seed}: sequential did not converge");
        assert_eq!(out.steps, r.iters, "seed {seed}: step count");
        assert_eq!(out.exit_core, Some(0));
        let seq_err = p.recovery_error(&r.x);
        assert_eq!(
            out.final_error.to_bits(),
            seq_err.to_bits(),
            "seed {seed}: final error {} vs {}",
            out.final_error,
            seq_err
        );
    }
}

#[test]
fn async_stoiht_c1_self_exclude_degenerates_to_algorithm_1() {
    // The README's A6 claim, pinned through the generic path: c = 1 with
    // self-exclusion is exactly Algorithm 1 on the core-0 stream.
    for seed in [3u64, 17] {
        let p = easy(500 + seed);
        let sim_opts = SimOpts { max_steps: 1500, self_exclude: true, ..Default::default() };
        let out =
            simulate(&p, 1, &SpeedSchedule::AllFast, &sim_opts, &mut Rng::seed_from(seed));
        assert!(out.converged, "seed {seed}");

        let mut seq_rng = Rng::seed_from(seed).split(1);
        let mut kernel = StoihtKernel::new(&p, 1.0);
        let mut x = SparseIterate::zeros(p.spec.n);
        for _ in 0..out.steps {
            let block = kernel.sample_block(&mut seq_rng);
            kernel.step_sparse(&mut x, block, None);
        }
        let err = p.recovery_error(x.values());
        assert_eq!(out.final_error.to_bits(), err.to_bits(), "seed {seed}");
    }
}
