//! SIMD & transform parity tier — the bit-level contracts of the
//! [`astir::linalg::simd`] doorway and the pair-fused FFT, enforced at the
//! integration surface:
//!
//! 1. **Dispatched kernels are bit-identical to the scalar references.**
//!    Whatever level the host probe picks (CI additionally forces
//!    `ASTIR_SIMD=scalar` in one job to pin the reference path itself),
//!    `dot`/`axpy`/`nrm2`/`dot4` must reproduce the canonical 4-lane
//!    accumulation exactly — no FMA, no reassociation.
//! 2. **The kernels that *consume* the doorway inherit the guarantee.**
//!    The fused dense proxy step and the multi-RHS panel apply must match
//!    scalar-kernel chains / per-column applies bit for bit.
//! 3. **The fused, cache-blocked FFT is bit-identical to the retained
//!    radix-2 reference**, and both match the direct cosine sums to the
//!    crate tolerance — at a small size and at the `large_n` bench size
//!    `n = 2^17`, where the cache-blocked schedule actually engages.

use astir::linalg::simd::{self, Level};
use astir::linalg::{plan_for, DenseOp, Mat, MeasureOp, SubsampledDctOp};
use astir::rng::Rng;

fn wave(n: usize, seed: u64) -> Vec<f64> {
    (0..n).map(|i| ((i as f64 + 1.7 * seed as f64) * 0.6143).sin() * 1.3).collect()
}

#[test]
fn forced_scalar_override_pins_the_level() {
    // CI's `ASTIR_SIMD: scalar` job makes this a hard pin; elsewhere the
    // probe may legitimately pick any level.
    if std::env::var("ASTIR_SIMD").as_deref() == Ok("scalar") {
        assert_eq!(simd::level(), Level::Scalar);
    }
    assert_eq!(simd::level(), simd::level(), "probe must be cached");
}

#[test]
fn dispatched_kernels_match_scalar_references_bitwise() {
    for n in [0usize, 1, 2, 3, 4, 7, 8, 31, 100, 1000, 4093, 10000] {
        let a = wave(n, 1);
        let b = wave(n, 2);
        assert_eq!(simd::dot(&a, &b).to_bits(), simd::dot_scalar(&a, &b).to_bits(), "dot n={n}");
        assert_eq!(simd::nrm2(&a).to_bits(), simd::nrm2_scalar(&a).to_bits(), "nrm2 n={n}");
        // The generic dense kernel routes f64 through the doorway — same bits.
        assert_eq!(
            astir::linalg::dot(&a, &b).to_bits(),
            simd::dot_scalar(&a, &b).to_bits(),
            "dense::dot n={n}"
        );
        let mut y_d = wave(n, 3);
        let mut y_s = y_d.clone();
        simd::axpy(-0.83, &a, &mut y_d);
        simd::axpy_scalar(-0.83, &a, &mut y_s);
        for i in 0..n {
            assert_eq!(y_d[i].to_bits(), y_s[i].to_bits(), "axpy n={n} i={i}");
        }
        let (c0, c1, c2, c3) = (wave(n, 4), wave(n, 5), wave(n, 6), wave(n, 7));
        let cols = [&c0[..], &c1[..], &c2[..], &c3[..]];
        let got = simd::dot4(&a, cols);
        let want = simd::dot4_scalar(&a, cols);
        for c in 0..4 {
            assert_eq!(got[c].to_bits(), want[c].to_bits(), "dot4 n={n} col {c}");
        }
    }
}

/// The fused dense proxy (`RowBlock::proxy_step_into` behind
/// `DenseOp::block_proxy_step`) restated on the *scalar* kernels: same
/// two-pass structure, same skip-zero-weight rule, `dot_scalar`/`axpy_scalar`
/// in place of the dispatched kernels.
fn proxy_reference(
    a: &Mat<f64>,
    row0: usize,
    y_b: &[f64],
    x: &[f64],
    alpha: f64,
) -> (Vec<f64>, Vec<f64>) {
    let b = y_b.len();
    let mut resid = vec![0.0; b];
    for i in 0..b {
        resid[i] = y_b[i] - simd::dot_scalar(a.row(row0 + i), x);
    }
    let mut out = x.to_vec();
    for i in 0..b {
        let w = alpha * resid[i];
        if w != 0.0 {
            simd::axpy_scalar(w, a.row(row0 + i), &mut out);
        }
    }
    (resid, out)
}

#[test]
fn fused_proxy_step_matches_scalar_kernel_chain_bitwise() {
    let (m, n, b) = (48usize, 200usize, 12usize);
    let mut rng = Rng::seed_from(8);
    let mat = Mat::from_fn(m, n, |_, _| rng.gauss());
    let op = DenseOp::new(mat.clone());
    let y = wave(m, 9);
    let x = wave(n, 10);
    let mut scratch = op.make_scratch();
    for block in 0..m / b {
        let row0 = block * b;
        let y_b = &y[row0..row0 + b];
        let mut resid = vec![0.0; b];
        let mut out = vec![0.0; n];
        op.block_proxy_step(row0, y_b, &x, 0.67, &mut resid, &mut scratch, &mut out);
        let (want_resid, want_out) = proxy_reference(&mat, row0, y_b, &x, 0.67);
        for i in 0..b {
            assert_eq!(resid[i].to_bits(), want_resid[i].to_bits(), "block {block} resid {i}");
        }
        for j in 0..n {
            assert_eq!(out[j].to_bits(), want_out[j].to_bits(), "block {block} out {j}");
        }
    }
}

#[test]
fn panel_apply_matches_per_column_apply_bitwise() {
    // B = 1 and 3 exercise the remainder path alone, 4 one dot4 group,
    // 8 two groups — on both operator implementations.
    let (m, n) = (40usize, 128usize);
    let mut rng = Rng::seed_from(11);
    let dense = DenseOp::new(Mat::from_fn(m, n, |_, _| rng.gauss()));
    let dct = SubsampledDctOp::new(n, Rng::seed_from(12).subset(n, m));
    fn check<O: MeasureOp>(op: &O, name: &str) {
        let (n, m) = (op.cols(), op.rows());
        for ncols in [1usize, 3, 4, 8] {
            let x_panel: Vec<f64> =
                (0..ncols * n).map(|i| ((i as f64) * 0.271).sin() * 0.9).collect();
            let mut scratch = op.make_scratch();
            let mut out_panel = vec![0.0; ncols * m];
            op.apply_multi_into(&x_panel, &mut scratch, &mut out_panel);
            for c in 0..ncols {
                let mut want = vec![0.0; m];
                op.apply_into(&x_panel[c * n..(c + 1) * n], &mut scratch, &mut want);
                for i in 0..m {
                    assert_eq!(
                        out_panel[c * m + i].to_bits(),
                        want[i].to_bits(),
                        "{name} B={ncols} col {c} row {i}"
                    );
                }
            }
        }
    }
    check(&dense, "dense");
    check(&dct, "subsampled_dct");
}

#[test]
fn fused_dct_matches_reference_pipeline_bitwise() {
    // 2^10 runs unchunked; 2^17 engages the depth-first cache-blocked
    // schedule (odd lg n → the 2^13 block) — both must reproduce the
    // retained radix-2 pipeline exactly, forward and transpose.
    for n in [1usize << 10, 1 << 17] {
        let plan = plan_for(n);
        let mut s_new = plan.scratch();
        let mut s_ref = plan.scratch();
        let x = wave(n, 13);
        let (mut out_new, mut out_ref) = (vec![0.0; n], vec![0.0; n]);
        plan.dct2_into(&x, &mut s_new, &mut out_new);
        plan.dct2_reference_into(&x, &mut s_ref, &mut out_ref);
        for k in 0..n {
            assert_eq!(out_new[k].to_bits(), out_ref[k].to_bits(), "dct2 n={n} k={k}");
        }
        plan.dct3_into(&x, &mut s_new, &mut out_new);
        plan.dct3_reference_into(&x, &mut s_ref, &mut out_ref);
        for j in 0..n {
            assert_eq!(out_new[j].to_bits(), out_ref[j].to_bits(), "dct3 n={n} j={j}");
        }
    }
}

/// Direct DCT-II coefficient `X_k = Σ_j x_j cos(π k (2j+1) / (2n))`,
/// summed in index order — the O(n) ground truth per coefficient.
fn direct_dct2_coeff(x: &[f64], k: usize) -> f64 {
    let nf = x.len() as f64;
    x.iter()
        .enumerate()
        .map(|(j, &xj)| xj * (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / nf).cos())
        .sum()
}

#[test]
fn fft_dct_matches_direct_cosine_sum() {
    // Full cross-check at 2^10; spot-checked coefficients at 2^17 (the
    // full direct sum would be O(n²) ≈ 1.7e10 flops there).
    let n = 1usize << 10;
    let plan = plan_for(n);
    let mut scratch = plan.scratch();
    let x = wave(n, 14);
    let mut out = vec![0.0; n];
    plan.dct2_into(&x, &mut scratch, &mut out);
    for k in 0..n {
        let want = direct_dct2_coeff(&x, k);
        assert!(
            (out[k] - want).abs() <= 1e-10 * (1.0 + want.abs()),
            "n={n} k={k}: {} vs {want}",
            out[k]
        );
    }
    let n = 1usize << 17;
    let plan = plan_for(n);
    let mut scratch = plan.scratch();
    let x = wave(n, 15);
    let mut out = vec![0.0; n];
    plan.dct2_into(&x, &mut scratch, &mut out);
    for k in [0usize, 1, 2, 255, 4096, 65535, 65536, 131071] {
        let want = direct_dct2_coeff(&x, k);
        // Tolerance scaled by ‖x‖₁-ish magnitude: the direct sum itself
        // carries O(n·eps) rounding at this length.
        assert!(
            (out[k] - want).abs() <= 1e-8 * (1.0 + want.abs()),
            "n={n} k={k}: {} vs {want}",
            out[k]
        );
    }
}

#[test]
fn adjoint_identity_holds_at_large_n() {
    // ⟨A x, r⟩ == ⟨x, Aᵀ r⟩ through the full fast-transform pipeline at
    // the bench sizes the async runtimes actually use.
    for (n, m) in [(1usize << 10, 256usize), (1 << 17, 2048)] {
        let rows = Rng::seed_from(16).subset(n, m);
        let op = SubsampledDctOp::new(n, rows);
        let x = wave(n, 17);
        let r = wave(m, 18);
        let mut scratch = op.make_scratch();
        let mut ax = vec![0.0; m];
        op.apply_into(&x, &mut scratch, &mut ax);
        let mut atr = vec![0.0; n];
        op.apply_t_into(&r, &mut scratch, &mut atr);
        let lhs = simd::dot(&ax, &r);
        let rhs = simd::dot(&x, &atr);
        assert!(
            (lhs - rhs).abs() <= 1e-10 * (1.0 + lhs.abs()),
            "n={n}: ⟨Ax,r⟩={lhs} vs ⟨x,Aᵀr⟩={rhs}"
        );
    }
}

#[test]
fn plan_cache_shares_plans_across_lookups() {
    let p1 = plan_for(1 << 10);
    let p2 = plan_for(1 << 10);
    assert!(
        astir::sync::Arc::ptr_eq(&p1, &p2),
        "repeated plan_for lookups must share one table build"
    );
    assert_eq!(p1.n(), 1 << 10);
}
