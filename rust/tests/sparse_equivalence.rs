//! Equivalence suite for the sparse fast path: `proxy_step_sparse_into`
//! must match `proxy_step_into` **bit-for-bit** across randomized supports
//! (including empty, full, and `s > n` clamps), the sparse kernel step
//! must track the dense kernel step bit-for-bit across whole trajectories,
//! and `residual_norm_sparse` must agree with the dense `residual_norm`
//! on every winner iterate published by the real-thread runtime.

use astir::algorithms::StoihtKernel;
use astir::async_runtime::{run_async, AsyncOpts};
use astir::linalg::{Mat, SparseIterate};
use astir::problem::{Problem, ProblemSpec};
use astir::rng::Rng;
use astir::sim::SpeedSchedule;
use astir::support::support_of;

fn assert_bits_eq(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{ctx}: coordinate {i}: {} vs {}",
            a[i],
            b[i]
        );
    }
}

#[test]
fn proxy_kernels_bitwise_equal_on_random_supports() {
    let mut rng = Rng::seed_from(2024);
    for trial in 0..200 {
        let b = 1 + rng.below(12);
        let blocks = 1 + rng.below(5);
        let m = b * blocks;
        let n = 1 + rng.below(300);
        let a = Mat::<f64>::from_fn(m, n, |_, _| rng.gauss());
        let a_t = Mat::<f64>::from_fn(n, m, |i, j| a.get(j, i));
        // Random support size over the full range [0, n] — empty and full
        // supports both land here with positive probability; force them on
        // the first trials to make sure.
        let k = match trial {
            0 => 0,
            1 => n,
            _ => rng.below(n + 1),
        };
        let mut supp = rng.subset(n, k);
        supp.sort_unstable();
        let mut x = vec![0.0f64; n];
        for &j in &supp {
            x[j] = rng.gauss();
        }
        let alpha = if trial % 3 == 0 { 0.0 } else { rng.gauss() };
        let block = rng.below(blocks);
        let row0 = block * b;
        let blk = a.row_block(row0, row0 + b);
        let y: Vec<f64> = (0..b).map(|_| rng.gauss()).collect();

        let (mut scr_d, mut out_d) = (vec![0.0; b], vec![0.0; n]);
        blk.proxy_step_into(&y, &x, alpha, &mut scr_d, &mut out_d);
        let (mut scr_s, mut out_s) = (vec![0.0; b], vec![0.0; n]);
        blk.proxy_step_sparse_into(&a_t, row0, &y, &x, &supp, alpha, &mut scr_s, &mut out_s);

        assert_bits_eq(&scr_d, &scr_s, &format!("trial {trial} residual (n={n} b={b} k={k})"));
        assert_bits_eq(&out_d, &out_s, &format!("trial {trial} proxy (n={n} b={b} k={k})"));
    }
}

#[test]
fn kernel_trajectories_bitwise_equal() {
    // Whole StoIHT trajectories: dense step vs sparse step, with and
    // without an extra (tally-style) support, must agree on every bit of
    // every iterate — so the runtimes' switch to the sparse path cannot
    // change any experiment by even an ulp.
    for seed in 0..4u64 {
        let spec = ProblemSpec { n: 160, m: 80, b: 8, s: 5, ..ProblemSpec::tiny() };
        let p = spec.generate(&mut Rng::seed_from(100 + seed));
        let mut rng = Rng::seed_from(500 + seed);
        let mut extra = rng.subset(spec.n, spec.s);
        extra.sort_unstable();
        let mut kd = StoihtKernel::new(&p, 1.0);
        let mut ks = StoihtKernel::new(&p, 1.0);
        let mut xd = vec![0.0f64; spec.n];
        let mut xs = SparseIterate::zeros(spec.n);
        for it in 0..80 {
            let block = kd.sample_block(&mut rng);
            let use_extra = it % 3 != 0;
            let e = if use_extra { Some(extra.as_slice()) } else { None };
            let gd = kd.step(&mut xd, block, e).to_vec();
            let gs = ks.step_sparse(&mut xs, block, e).to_vec();
            assert_eq!(gd, gs, "seed {seed} iter {it}: gamma");
            assert_bits_eq(&xd, xs.values(), &format!("seed {seed} iter {it}"));
        }
    }
}

#[test]
fn sparse_step_handles_s_equal_n_clamp() {
    // s == n: top_s clamps to the full index set; the sparse support is
    // everything and both paths must still agree bit-for-bit.
    let spec = ProblemSpec { n: 24, m: 12, b: 4, s: 24, ..ProblemSpec::tiny() };
    let p = spec.generate(&mut Rng::seed_from(9));
    let mut kd = StoihtKernel::new(&p, 1.0);
    let mut ks = StoihtKernel::new(&p, 1.0);
    let mut xd = vec![0.0f64; spec.n];
    let mut xs = SparseIterate::zeros(spec.n);
    for it in 0..20 {
        let block = it % spec.num_blocks();
        kd.step(&mut xd, block, None);
        ks.step_sparse(&mut xs, block, None);
        assert_bits_eq(&xd, xs.values(), &format!("iter {it}"));
        assert_eq!(xs.support().len(), spec.n);
    }
}

#[test]
fn sequential_solver_unchanged_by_sparse_path() {
    // stoiht() now runs step_sparse internally; a hand-rolled dense-step
    // replay with the same RNG stream must reproduce its iterate exactly.
    let spec = ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() };
    let p = spec.generate(&mut Rng::seed_from(77));
    let opts = astir::algorithms::GreedyOpts::default();
    let r = astir::algorithms::stoiht(&p, &opts, &mut Rng::seed_from(31));
    assert!(r.converged);

    let mut kernel = StoihtKernel::new(&p, opts.gamma);
    let mut rng = Rng::seed_from(31);
    let mut x = vec![0.0f64; spec.n];
    for _ in 0..r.iters {
        let block = kernel.sample_block(&mut rng);
        kernel.step(&mut x, block, None);
    }
    assert_bits_eq(&r.x, &x, "sequential replay");
}

#[test]
fn async_winner_iterates_pass_dense_residual_cross_check() {
    // Multi-thread stress: across seeds, schedules, and core counts, every
    // winner iterate published by run_async must satisfy
    // residual_norm_sparse == residual_norm (the exit check is honest).
    let spec = ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() };
    let mut checked = 0usize;
    for seed in 0..6u64 {
        let p: Problem = spec.generate(&mut Rng::seed_from(1000 + seed));
        for (cores, schedule) in [
            (2usize, SpeedSchedule::AllFast),
            (4, SpeedSchedule::AllFast),
            (4, SpeedSchedule::HalfSlow { period: 3 }),
        ] {
            let opts = AsyncOpts { schedule: schedule.clone(), ..Default::default() };
            let out = run_async(&p, cores, &opts, 7000 + seed);
            if !out.converged {
                continue;
            }
            checked += 1;
            let supp = support_of(&out.x);
            assert!(supp.len() <= 2 * spec.s, "winner support too large: {}", supp.len());
            let sparse = p.residual_norm_sparse(&out.x, &supp);
            let dense = p.residual_norm(&out.x);
            assert!(
                (sparse - dense).abs() <= 1e-12 * (1.0 + dense),
                "seed {seed} cores {cores}: sparse {sparse} vs dense {dense}"
            );
            assert!(dense < opts.tolerance * 1.0000001, "published residual not under tol");
        }
    }
    assert!(checked >= 10, "too few converged runs to be meaningful: {checked}");
}
