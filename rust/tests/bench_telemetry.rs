//! Perf-telemetry contract tests: the `astir-bench-v1` JSON schema
//! round-trips and stays byte-stable, the suite registry is deterministic
//! across runs, and `astir bench --compare` exits nonzero on an injected
//! regression.

use astir::bench_harness::json::{parse_report, report_to_json, write_report};
use astir::bench_harness::{
    compare_reports, suites, BenchDims, BenchRecord, Mode, RunOpts, RunReport, Scale, SuiteReport,
    SCHEMA,
};
use astir::metrics::Stats;

fn sample_report() -> RunReport {
    RunReport {
        schema: SCHEMA.to_string(),
        git_rev: Some("abc123def456".to_string()),
        mode: Mode::Smoke,
        suites: vec![SuiteReport {
            name: "demo".to_string(),
            benches: vec![
                BenchRecord {
                    name: "proxy".to_string(),
                    scale: Scale::Standard,
                    dims: Some(BenchDims { n: 1000, m: 300, b: 15, s: 20 }),
                    seed: 11,
                    iters: 4,
                    time: Stats { n: 2, mean: 0.5, std: 0.25, min: 0.25, max: 0.75, median: 0.5 },
                },
                BenchRecord {
                    name: "dimless".to_string(),
                    scale: Scale::Jumbo,
                    dims: None,
                    seed: 0,
                    iters: 0,
                    time: Stats {
                        n: 0,
                        mean: f64::NAN,
                        std: f64::NAN,
                        min: f64::INFINITY,
                        max: f64::NEG_INFINITY,
                        median: f64::NAN,
                    },
                },
            ],
            skipped: vec!["jumbo_step".to_string()],
        }],
    }
}

#[test]
fn json_snapshot_is_schema_stable() {
    // Byte-for-byte pin of the v1 schema: if this test needs editing, the
    // schema changed — bump SCHEMA and say so in the README.
    let expected = concat!(
        "{\"schema\":\"astir-bench-v1\",\"git_rev\":\"abc123def456\",\"mode\":\"smoke\",",
        "\"suites\":[{\"name\":\"demo\",\"skipped\":[\"jumbo_step\"],\"benches\":[",
        "{\"name\":\"proxy\",\"scale\":\"standard\",\"seed\":11,",
        "\"dims\":{\"n\":1000,\"m\":300,\"b\":15,\"s\":20},\"iters\":4,\"samples\":2,",
        "\"mean_s\":0.5,\"std_s\":0.25,\"min_s\":0.25,\"throughput_iters_per_s\":2.0},",
        "{\"name\":\"dimless\",\"scale\":\"jumbo\",\"seed\":0,\"dims\":null,",
        "\"iters\":0,\"samples\":0,\"mean_s\":null,\"std_s\":null,\"min_s\":null,",
        "\"throughput_iters_per_s\":null}]}]}"
    );
    assert_eq!(report_to_json(&sample_report()), expected);
}

#[test]
fn json_roundtrip_preserves_schema_fields() {
    let original = sample_report();
    let parsed = parse_report(&report_to_json(&original)).expect("round-trip parse");
    assert_eq!(parsed.schema, original.schema);
    assert_eq!(parsed.git_rev, original.git_rev);
    assert_eq!(parsed.mode, original.mode);
    assert_eq!(parsed.suites.len(), 1);
    let (ps, os) = (&parsed.suites[0], &original.suites[0]);
    assert_eq!(ps.name, os.name);
    assert_eq!(ps.skipped, os.skipped);
    assert_eq!(ps.benches.len(), os.benches.len());
    for (pb, ob) in ps.benches.iter().zip(&os.benches) {
        assert_eq!(pb.name, ob.name);
        assert_eq!(pb.scale, ob.scale);
        assert_eq!(pb.dims, ob.dims);
        assert_eq!(pb.seed, ob.seed);
        assert_eq!(pb.iters, ob.iters);
        assert_eq!(pb.time.n, ob.time.n);
        // numeric fields: NaN-aware equality on what the schema carries
        for (p, o) in [
            (pb.time.mean, ob.time.mean),
            (pb.time.std, ob.time.std),
            (pb.time.min, ob.time.min),
        ] {
            assert!(p == o || (p.is_nan() && !o.is_finite()), "{p} vs {o}");
        }
    }
    // serializing the parsed report again is byte-identical except for
    // fields the schema does not carry (none at the top level)
    assert_eq!(report_to_json(&parsed), report_to_json(&original));
}

#[test]
fn parse_rejects_foreign_schema() {
    let doc = report_to_json(&sample_report()).replace("astir-bench-v1", "someone-elses-v9");
    let err = parse_report(&doc).unwrap_err();
    assert!(err.contains("someone-elses-v9"), "{err}");
    assert!(parse_report("{}").is_err());
    assert!(parse_report("not json at all").is_err());
}

#[test]
fn write_report_creates_parents_and_roundtrips() {
    let dir = std::env::temp_dir().join("astir_bench_telemetry_test").join("nested");
    let path = dir.join("BENCH_demo.json");
    write_report(&sample_report(), &path).expect("write");
    let parsed = parse_report(&std::fs::read_to_string(&path).unwrap()).expect("parse");
    assert_eq!(parsed.suites[0].benches[0].name, "proxy");
    let _ = std::fs::remove_dir_all(dir.parent().unwrap());
}

#[test]
fn two_seeded_smoke_runs_register_identically() {
    // Dry runs register every spec (names, dims, seeds, scales) without
    // timing anything: two passes over the registry must agree exactly,
    // and the smoke problem dims must be the deterministic paper shapes.
    let opts = RunOpts { mode: Mode::Smoke, filter: None, skip_jumbo: true, dry_run: true };
    let a = suites::run_all(&opts);
    let b = suites::run_all(&opts);
    assert_eq!(a.suites.len(), 10);
    assert_eq!(a.suites.len(), b.suites.len());
    for (sa, sb) in a.suites.iter().zip(&b.suites) {
        assert_eq!(sa.name, sb.name);
        assert_eq!(sa.skipped, sb.skipped);
        assert_eq!(sa.benches.len(), sb.benches.len());
        assert!(!sa.benches.is_empty(), "suite {} registered no benches", sa.name);
        for (ba, bb) in sa.benches.iter().zip(&sb.benches) {
            assert_eq!(ba.name, bb.name);
            assert_eq!(ba.dims, bb.dims);
            assert_eq!(ba.seed, bb.seed);
            assert_eq!(ba.scale, bb.scale);
        }
    }
    // experiment suites carry the paper problem shape
    let fig1 = a.suites.iter().find(|s| s.name == "fig1").unwrap();
    assert_eq!(fig1.benches[0].dims, Some(BenchDims { n: 1000, m: 300, b: 15, s: 20 }));
}

#[test]
fn compare_exits_nonzero_on_injected_regression() {
    // End-to-end through the CLI: run one real (tiny) smoke bench with
    // --json, then doctor the baseline to be far faster and assert the
    // --compare run fails while the honest compare passes.
    let astir = env!("CARGO_BIN_EXE_astir");
    let dir = std::env::temp_dir().join("astir_bench_compare_test");
    std::fs::create_dir_all(&dir).unwrap();
    let current = dir.join("current.json");

    let out = std::process::Command::new(astir)
        .args(["bench", "--smoke", "--filter", "hot_path/tally_estimate", "--json"])
        .arg(&current)
        .output()
        .expect("run astir bench");
    assert!(out.status.success(), "bench run failed: {}", String::from_utf8_lossy(&out.stderr));
    let mut report = parse_report(&std::fs::read_to_string(&current).unwrap()).unwrap();
    let bench = &report.suites[0].benches[0];
    assert_eq!(bench.name, "tally_estimate");
    assert!(bench.time.mean > 0.0);

    // Self-compare with a generous threshold (re-measurement noise on a
    // loaded test machine must not fail the honest case), must pass.
    let ok = std::process::Command::new(astir)
        .args(["bench", "--smoke", "--filter", "hot_path/tally_estimate", "--threshold", "3.0"])
        .arg("--compare")
        .arg(&current)
        .output()
        .expect("run astir bench --compare");
    assert!(
        ok.status.success(),
        "self-compare should pass: {}",
        String::from_utf8_lossy(&ok.stderr)
    );

    // Injected regression: pretend the baseline was 1000x faster.
    for b in &mut report.suites[0].benches {
        b.time.mean /= 1000.0;
        b.time.std /= 1000.0;
        b.time.min /= 1000.0;
    }
    let doctored = dir.join("doctored.json");
    write_report(&report, &doctored).unwrap();
    let bad = std::process::Command::new(astir)
        .args(["bench", "--smoke", "--filter", "hot_path/tally_estimate", "--threshold", "3.0"])
        .arg("--compare")
        .arg(&doctored)
        .output()
        .expect("run astir bench --compare (doctored)");
    assert!(!bad.status.success(), "doctored compare must exit nonzero");
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("regressed"), "stderr: {stderr}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compare_reports_threshold_boundaries() {
    let mk = |mean: f64| RunReport {
        schema: SCHEMA.to_string(),
        git_rev: None,
        mode: Mode::Full,
        suites: vec![SuiteReport {
            name: "s".to_string(),
            benches: vec![BenchRecord {
                name: "k".to_string(),
                scale: Scale::Standard,
                dims: None,
                seed: 0,
                iters: 1,
                time: Stats { n: 1, mean, std: 0.0, min: mean, max: mean, median: mean },
            }],
            skipped: Vec::new(),
        }],
    };
    let base = mk(1.0);
    assert!(compare_reports(&base, &mk(1.49), 0.5).regressions().is_empty());
    assert_eq!(compare_reports(&base, &mk(1.51), 0.5).regressions().len(), 1);
    // improvements never regress
    assert!(compare_reports(&base, &mk(0.1), 0.0).regressions().is_empty());
}
