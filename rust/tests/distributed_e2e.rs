//! End-to-end multi-process sharded recovery: spawn the real `astir`
//! binary (`CARGO_BIN_EXE_astir`) as one `exchange-hub` plus `S`
//! `shard-worker` processes on loopback, and pin the two distributed
//! contracts:
//!
//! * **Bit-identity** — the fleet's per-shard results (iteration counts,
//!   residual/error bit patterns, an FNV digest of each iterate) are
//!   bit-for-bit the in-process [`ShardedPool`] run at the same
//!   `(S, E, seed)`: the socket transport adds processes, not
//!   arithmetic.
//! * **Degradation over deadlock** — killing one worker mid-round
//!   retires it at the hub; the survivors keep exchanging against its
//!   stale snapshot, finish, and exit cleanly, and the hub reports the
//!   dead shard as degraded. Nothing hangs.
//!
//! Every child is killed on drop, and scrape loops are bounded, so a
//! regression fails fast instead of wedging CI (the workflow adds a hard
//! `timeout-minutes` on top).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Lines};
use std::process::{Child, ChildStdout, Command, Stdio};

use astir::algorithms::Alg;
use astir::async_runtime::AsyncOpts;
use astir::problem::ProblemSpec;
use astir::service::transport::x_digest;
use astir::service::ShardedPool;
use astir::sim::ShardOpts;

const N: usize = 1000;
const M: usize = 300;
const B: usize = 15;
const S_SPARSE: usize = 20;
const SEED: u64 = 20170301;
const SHARDS: usize = 4;
const PERIOD: usize = 16;

/// A spawned `astir` child with piped stdout, killed on drop.
struct Proc {
    child: Child,
    lines: Lines<BufReader<ChildStdout>>,
}

impl Proc {
    fn spawn(args: &[&str]) -> Proc {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_astir"));
        cmd.args(args);
        cmd.stdout(Stdio::piped()).stderr(Stdio::inherit()).stdin(Stdio::null());
        let mut child = cmd.spawn().expect("spawn astir");
        let stdout = child.stdout.take().expect("piped stdout");
        Proc { child, lines: BufReader::new(stdout).lines() }
    }

    /// Read stdout until a line starts with `prefix`; returns the rest
    /// of that line. Panics if the child exits first — the pipe EOF
    /// bounds the wait.
    fn scrape(&mut self, prefix: &str) -> String {
        loop {
            match self.lines.next() {
                Some(Ok(line)) => {
                    if let Some(rest) = line.strip_prefix(prefix) {
                        return rest.trim().to_string();
                    }
                }
                _ => panic!("child exited before printing `{prefix}`"),
            }
        }
    }

    /// Drain stdout to EOF (child exit), returning every line.
    fn drain(&mut self) -> Vec<String> {
        let lines: Vec<String> = (&mut self.lines).map_while(Result::ok).collect();
        let status = self.child.wait().expect("wait astir child");
        assert!(status.success(), "astir child failed: {status}");
        lines
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_hub(shards: usize, extra: &[&str]) -> (Proc, String) {
    let shards = shards.to_string();
    let mut args = vec!["exchange-hub", "--addr", "127.0.0.1:0", "--shards", &shards];
    args.extend_from_slice(extra);
    let mut hub = Proc::spawn(&args);
    let addr = hub.scrape("listening on ");
    (hub, addr)
}

fn spawn_worker(addr: &str, shard: usize, shards: usize, period: usize) -> Proc {
    Proc::spawn(&[
        "shard-worker",
        "--hub",
        addr,
        "--shard",
        &shard.to_string(),
        "--shards",
        &shards.to_string(),
        "--exchange-period",
        &period.to_string(),
        "--n",
        &N.to_string(),
        "--m",
        &M.to_string(),
        "--b",
        &B.to_string(),
        "--s",
        &S_SPARSE.to_string(),
        "--seed",
        &SEED.to_string(),
    ])
}

/// `key=value` tokens of a worker's `shard-result` line.
fn parse_result(line: &str) -> HashMap<String, String> {
    line.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// The in-process reference at the fleet's exact axes: the same problem
/// generation (`Rng::seed_from(seed)` feeding `ProblemSpec::generate`)
/// and run-seed derivation (`seed ^ 0xA5`) the CLI uses.
fn reference_pool() -> astir::service::ShardedOutcome {
    // `ProblemSpec::paper()` IS the CLI default; the explicit dims the
    // workers are launched with restate it so a default drift fails
    // loudly here instead of silently changing the fleet's problem.
    let spec = ProblemSpec { n: N, m: M, b: B, s: S_SPARSE, ..ProblemSpec::paper() };
    let mut rng = astir::rng::Rng::seed_from(SEED);
    let problem = spec.generate(&mut rng);
    let sh = ShardOpts { shards: SHARDS, exchange_period: PERIOD, ..Default::default() };
    ShardedPool::new(sh).run(&problem, Alg::Stoiht, &AsyncOpts::default(), SEED ^ 0xA5)
}

#[test]
fn process_fleet_is_bit_identical_to_the_in_process_pool() {
    let (mut hub, addr) = spawn_hub(SHARDS, &[]);
    let mut workers: Vec<Proc> =
        (0..SHARDS).map(|k| spawn_worker(&addr, k, SHARDS, PERIOD)).collect();
    let pool = reference_pool();
    for (k, w) in workers.iter_mut().enumerate() {
        let lines = w.drain();
        let result = lines
            .iter()
            .find_map(|l| l.strip_prefix("shard-result "))
            .unwrap_or_else(|| panic!("worker {k} printed no shard-result: {lines:?}"));
        let kv = parse_result(result);
        let expect = &pool.shards[k];
        assert_eq!(kv["shard"], k.to_string());
        assert_eq!(kv["converged"], expect.converged.to_string(), "shard {k} convergence");
        assert_eq!(kv["iters"], expect.iters.to_string(), "shard {k} iteration count");
        assert_eq!(kv["rounds"], pool.rounds.to_string(), "shard {k} exchange rounds");
        assert_eq!(kv["stale_rounds"], "0", "clean fleet must never observe staleness");
        assert_eq!(
            kv["residual_bits"],
            format!("{:016x}", expect.residual.to_bits()),
            "shard {k} residual drifted over the wire"
        );
        assert_eq!(
            kv["error_bits"],
            format!("{:016x}", expect.final_error.to_bits()),
            "shard {k} recovery error drifted over the wire"
        );
        assert_eq!(
            kv["x_fnv"],
            format!("{:016x}", x_digest(&expect.x)),
            "shard {k} iterate drifted over the wire"
        );
    }
    let report = hub.drain().into_iter().find(|l| l.starts_with("hub-report ")).expect("report");
    let kv = parse_result(&report);
    assert_eq!(kv["degraded"], "[]", "clean fleet must not degrade");
    assert_eq!(kv["rounds"], (pool.rounds + 1).to_string(), "hub counts the final drain round");
}

#[test]
fn killing_a_worker_mid_round_degrades_the_fleet_instead_of_deadlocking() {
    // Tight round deadline so the hub retires the killed worker quickly
    // even if the socket EOF is swallowed.
    let (mut hub, addr) = spawn_hub(SHARDS, &["--round-timeout-ms", "1000"]);
    let mut workers: Vec<Proc> =
        (0..SHARDS).map(|k| spawn_worker(&addr, k, SHARDS, PERIOD)).collect();
    // The victim confirms fleet assembly (its join reply arrived), so the
    // kill lands mid-session — after round 1 started, before the fleet
    // drained.
    let victim = workers.last_mut().expect("victim worker");
    victim.scrape("joined hub as shard ");
    victim.child.kill().expect("kill victim worker");
    let _ = victim.child.wait();
    workers.pop();
    // Survivors must finish — with stale rounds observed, since the dead
    // peer's snapshot goes stale the moment the hub retires it.
    for (k, w) in workers.iter_mut().enumerate() {
        let lines = w.drain();
        let result = lines
            .iter()
            .find_map(|l| l.strip_prefix("shard-result "))
            .unwrap_or_else(|| panic!("survivor {k} printed no shard-result: {lines:?}"));
        let kv = parse_result(result);
        assert_ne!(kv["rounds"], "0", "survivor {k} must have exchanged");
        assert_ne!(kv["stale_rounds"], "0", "survivor {k} must observe the degraded rounds");
    }
    let report = hub.drain().into_iter().find(|l| l.starts_with("hub-report ")).expect("report");
    let kv = parse_result(&report);
    assert_eq!(
        kv["degraded"],
        format!("[{}]", SHARDS - 1),
        "the hub must report exactly the killed shard as degraded"
    );
}

/// The fleet barrier is load-bearing: a worker whose peers never arrive
/// must not hang past the hub's join window, and the hub must report the
/// absent shards. Keeps the timeout path honest without waiting the
/// default 30 s.
#[test]
fn a_partial_fleet_starts_degraded_after_the_join_window() {
    let (mut hub, addr) = spawn_hub(2, &["--join-timeout-ms", "1500", "--round-timeout-ms", "800"]);
    let mut worker = spawn_worker(&addr, 0, 2, 4);
    let lines = worker.drain();
    let result = lines
        .iter()
        .find_map(|l| l.strip_prefix("shard-result "))
        .unwrap_or_else(|| panic!("solo worker printed no shard-result: {lines:?}"));
    let kv = parse_result(result);
    assert_ne!(kv["stale_rounds"], "0", "the absent peer must read as stale");
    let report = hub.drain().into_iter().find(|l| l.starts_with("hub-report ")).expect("report");
    assert_eq!(parse_result(&report)["degraded"], "[1]");
}
