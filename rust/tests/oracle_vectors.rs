//! Cross-language correctness: the Rust native kernels against test
//! vectors exported from the JAX/Pallas oracle
//! (`python/compile/export_testvectors.py`, run by `make artifacts`).
//!
//! These vectors were computed in f32 by `kernels/ref.py`; the Rust side
//! recomputes in f64 from the same inputs and must agree to f32 precision.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use astir::backend::{Backend, NativeBackend};
use astir::linalg::Mat;
use astir::problem::{Problem, ProblemSpec};

struct TestVectors {
    n: usize,
    m: usize,
    b: usize,
    s: usize,
    block: usize,
    gamma_iht: f64,
    residual_norm: f64,
    tensors: HashMap<String, Vec<f64>>,
}

fn parse_vectors(path: &Path) -> TestVectors {
    let text = std::fs::read_to_string(path).unwrap();
    let mut headers: HashMap<String, String> = HashMap::new();
    let mut tensors: HashMap<String, Vec<f64>> = HashMap::new();
    let mut lines = text.lines().peekable();
    while let Some(line) = lines.next() {
        if let Some(rest) = line.strip_prefix('#') {
            if let Some((k, v)) = rest.split_once('=') {
                headers.insert(k.trim().to_string(), v.trim().to_string());
            }
        } else if let Some(rest) = line.strip_prefix("tensor ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().unwrap().to_string();
            let len: usize = parts.next().unwrap().parse().unwrap();
            let mut data = Vec::with_capacity(len);
            for _ in 0..len {
                data.push(lines.next().unwrap().parse::<f64>().unwrap());
            }
            tensors.insert(name, data);
        }
    }
    TestVectors {
        n: headers["n"].parse().unwrap(),
        m: headers["m"].parse().unwrap(),
        b: headers["b"].parse().unwrap(),
        s: headers["s"].parse().unwrap(),
        block: headers["block"].parse().unwrap(),
        gamma_iht: headers["gamma_iht"].parse().unwrap(),
        residual_norm: headers["residual_norm"].parse().unwrap(),
        tensors,
    }
}

fn vectors_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(
        std::env::var_os("ASTIR_ARTIFACTS").unwrap_or_else(|| "artifacts".into()),
    )
    .join("testvectors");
    if dir.join("case_small.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping oracle-vector tests: run `make artifacts` first");
        None
    }
}

/// Rebuild a `Problem` from the exported tensors.
fn problem_from(tv: &TestVectors) -> Problem {
    let spec = ProblemSpec { n: tv.n, m: tv.m, b: tv.b, s: tv.s, ..ProblemSpec::tiny() };
    let a = Mat::from_vec(tv.m, tv.n, tv.tensors["a"].clone());
    let x_true = tv.tensors["x_true"].clone();
    let y = tv.tensors["y"].clone();
    Problem::from_parts(spec, a, x_true, y)
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn for_each_case(f: impl Fn(&str, &TestVectors, &Problem)) {
    let Some(dir) = vectors_dir() else { return };
    for case in ["case_small", "case_mid", "case_paper"] {
        let tv = parse_vectors(&dir.join(format!("{case}.txt")));
        let p = problem_from(&tv);
        f(case, &tv, &p);
    }
}

#[test]
fn proxy_step_matches_jax_oracle() {
    for_each_case(|case, tv, p| {
        let mut be = NativeBackend::new();
        let x = &tv.tensors["x"];
        let got = be.proxy_step(p, tv.block, x, 1.0).unwrap();
        let want = &tv.tensors["proxy"];
        let d = max_abs_diff(&got, want);
        assert!(d < 5e-4, "{case}: proxy max diff {d}");
    });
}

#[test]
fn stoiht_step_matches_jax_oracle() {
    for_each_case(|case, tv, p| {
        let mut be = NativeBackend::new();
        let x = &tv.tensors["x"];
        let tally_mask = &tv.tensors["tally_mask"];
        let (x_next, gamma) = be.stoiht_step(p, tv.block, x, 1.0, tally_mask).unwrap();
        let want_x = &tv.tensors["x_next"];
        let d = max_abs_diff(&x_next, want_x);
        assert!(d < 5e-4, "{case}: x_next max diff {d}");
        // gamma mask must agree exactly (f32 vs f64 top-s can only differ
        // on near-ties; the exported cases were chosen tie-free).
        let want_gamma: Vec<usize> = tv.tensors["gamma_mask"]
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(gamma, want_gamma, "{case}: gamma sets differ");
    });
}

#[test]
fn residual_norm_matches_jax_oracle() {
    for_each_case(|case, tv, p| {
        let got = p.residual_norm(&tv.tensors["x"]);
        let rel = (got - tv.residual_norm).abs() / tv.residual_norm.max(1e-12);
        assert!(rel < 1e-4, "{case}: residual {got} vs {}", tv.residual_norm);
    });
}

#[test]
fn iht_step_matches_jax_oracle() {
    for_each_case(|case, tv, p| {
        let got = astir::algorithms::iht::iht_step(p, &tv.tensors["x"], tv.gamma_iht);
        let want = &tv.tensors["iht_next"];
        let d = max_abs_diff(&got, want);
        assert!(d < 5e-4, "{case}: iht max diff {d}");
    });
}
