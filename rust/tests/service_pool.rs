//! Recovery-service contract tests: the persistent pool is deterministic
//! at any worker count and saturates cleanly; a pool job is bit-identical
//! to the spawn-per-call runtime's single-worker run; the batched
//! multi-RHS operator entry points are bitwise per-column equal to the
//! single-signal kernels on both operator implementations; and the
//! lockstep batched recovery degenerates to the solo algorithm exactly at
//! batch size one.

use astir::sync::Arc;

use astir::algorithms::Alg;
use astir::async_runtime::{run_async, run_async_with, AsyncOpts};
use astir::linalg::{MeasureOp, Operator, ProxyCol};
use astir::problem::{Ensemble, Problem, ProblemSpec};
use astir::rng::Rng;
use astir::service::{recover_batch_stoiht, solve_job, solve_job_with, RecoveryPool};

fn easy_spec() -> ProblemSpec {
    ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
}

/// One operator, `count` signals sharing it.
fn shared_problems(spec: &ProblemSpec, count: usize, seed: u64) -> Arc<Vec<Problem>> {
    let mut rng = Rng::seed_from(seed);
    let op = spec.draw_operator(&mut rng);
    Arc::new((0..count).map(|_| spec.generate_with_op(&op, &mut rng)).collect())
}

#[test]
#[cfg_attr(miri, ignore = "full solve loops are too slow under Miri; see the cheap-jobs variant")]
fn pool_results_bit_identical_across_worker_counts() {
    // The satellite guarantee: same jobs, same seeds, ANY worker count —
    // identical bits. 24 jobs over 1/4/8 workers (jobs >> workers for the
    // larger counts, workers partially idle for the smaller).
    let problems = shared_problems(&easy_spec(), 24, 11);
    let opts = AsyncOpts::default();
    let run = |workers: usize| {
        let pool = RecoveryPool::new(workers);
        let ps = Arc::clone(&problems);
        let opts = opts.clone();
        pool.run_jobs(24, 77, move |i, rng| {
            let seed = rng.next_u64();
            solve_job(&ps[i], Alg::Stoiht, &opts, seed)
        })
    };
    let base = run(1);
    assert!(base.iter().all(|o| o.converged), "baseline jobs must converge");
    for workers in [4usize, 8] {
        let out = run(workers);
        assert_eq!(out.len(), base.len());
        for (i, (a, b)) in base.iter().zip(&out).enumerate() {
            assert_eq!(a.iters, b.iters, "workers {workers} job {i}: iters");
            assert_eq!(
                a.residual.to_bits(),
                b.residual.to_bits(),
                "workers {workers} job {i}: residual"
            );
            assert_eq!(a.x.len(), b.x.len());
            for (j, (&va, &vb)) in a.x.iter().zip(&b.x).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "workers {workers} job {i} coord {j}"
                );
            }
        }
    }
}

#[test]
fn pool_saturates_with_many_more_jobs_than_workers() {
    // Jobs >> workers: every job runs exactly once, results land in job
    // order, and the pool survives repeated saturated batches. Miri runs
    // a shrunk instance (same protocol, fewer interpreter steps).
    let jobs = if cfg!(miri) { 12 } else { 64 };
    let spins = if cfg!(miri) { 8 } else { 100 };
    let rounds = if cfg!(miri) { 2 } else { 3 };
    let pool = RecoveryPool::new(4);
    for round in 0..rounds {
        let out: Vec<u64> = pool.run_jobs(jobs, round, move |i, rng| {
            // A nontrivial body so claims interleave across workers.
            let mut acc = rng.next_u64();
            for _ in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i as u64);
            }
            acc
        });
        assert_eq!(out.len(), jobs);
        let again: Vec<u64> = pool.run_jobs(jobs, round, move |i, rng| {
            let mut acc = rng.next_u64();
            for _ in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i as u64);
            }
            acc
        });
        assert_eq!(out, again, "round {round} must be reproducible");
    }
}

#[test]
fn pool_results_bit_identical_across_worker_counts_with_cheap_jobs() {
    // The Miri-sized face of the bit-identity guarantee: any worker count,
    // identical bits — with arithmetic jobs, so the run is all protocol
    // (claim ticket, slot writes, batch retire) and no solver time.
    let jobs = if cfg!(miri) { 8 } else { 24 };
    let run = |workers: usize| {
        let pool = RecoveryPool::new(workers);
        pool.run_jobs(jobs, 77, |i, rng| rng.next_u64().wrapping_add(i as u64))
    };
    let base = run(1);
    for workers in [2usize, 3] {
        assert_eq!(run(workers), base, "worker count {workers} changed the bits");
    }
}

#[test]
fn pool_zero_and_one_job_edge_cases() {
    let pool = RecoveryPool::new(3);
    let none: Vec<u8> = pool.run_jobs(0, 9, |_, _| 1);
    assert!(none.is_empty());
    if cfg!(miri) {
        // Same one-job hand-off, interpreter-sized body.
        let one = pool.run_jobs(1, 13, |i, rng| rng.next_u64() ^ i as u64);
        assert_eq!(one.len(), 1);
        return;
    }
    let problems = shared_problems(&easy_spec(), 1, 12);
    let ps = Arc::clone(&problems);
    let one = pool.run_jobs(1, 13, move |i, rng| {
        let seed = rng.next_u64();
        solve_job(&ps[i], Alg::Stoiht, &AsyncOpts::default(), seed)
    });
    assert_eq!(one.len(), 1);
    assert!(one[0].converged);
}

#[test]
#[cfg_attr(miri, ignore = "full solve loops are too slow under Miri")]
fn pool_single_job_bitwise_matches_spawn_per_call_runtime() {
    // The tentpole identity: solve_job (the pool's inline per-job solve)
    // is bit-for-bit run_async_with(problem, 1, ...) — same drive_worker
    // body, same RNG derivation, same tally protocol — for both kernels.
    let spec = easy_spec();
    let problems = shared_problems(&spec, 2, 21);
    let opts = AsyncOpts { max_local_iters: 400, ..Default::default() };
    for (p, alg, seed) in
        [(&problems[0], Alg::Stoiht, 42u64), (&problems[1], Alg::StoGradMp, 43u64)]
    {
        let pooled = solve_job(p, alg, &opts, seed);
        let spawned = match alg {
            Alg::Stoiht => run_async(p, 1, &opts, seed),
            Alg::StoGradMp => {
                run_async_with(p, 1, &opts, seed, astir::algorithms::StoGradMpKernel::new)
            }
        };
        assert!(pooled.converged && spawned.converged, "{alg:?} must converge");
        assert_eq!(pooled.iters, spawned.local_iters[0], "{alg:?}: iteration count");
        assert_eq!(
            pooled.residual.to_bits(),
            spawned.residual.to_bits(),
            "{alg:?}: residual bits"
        );
        for (j, (&a, &b)) in pooled.x.iter().zip(&spawned.x).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{alg:?}: coord {j}");
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "full solve loops are too slow under Miri")]
fn batch_of_one_degenerates_to_the_single_job_exactly() {
    // The lockstep batched step must be the solo Algorithm 2 verbatim
    // when the batch holds one signal: same RNG stream, same estimate,
    // same proxy/identify arithmetic, same exit check.
    let problems = shared_problems(&easy_spec(), 1, 31);
    let opts = AsyncOpts::default();
    let solo = solve_job(&problems[0], Alg::Stoiht, &opts, 99);
    let batched = recover_batch_stoiht(&problems[..1], &opts, 99);
    assert!(solo.converged && batched.all_converged());
    let b0 = &batched.signals[0];
    assert_eq!(solo.iters, b0.iters, "iteration counts");
    assert_eq!(solo.residual.to_bits(), b0.residual.to_bits(), "residual bits");
    for (j, (&a, &b)) in solo.x.iter().zip(&b0.x).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "coord {j}");
    }
}

#[test]
#[cfg_attr(miri, ignore = "full solve loops are too slow under Miri")]
fn batched_mmv_recovery_converges_and_is_no_slower_than_sequential() {
    // 6 MMV signals sharing one operator and one support: the shared
    // tally must not hurt — per-signal lockstep iterations stay within a
    // whisker of the independent solves (and in practice drop well below,
    // which is the throughput suite's jobs/sec win).
    let spec = ProblemSpec { n: 256, m: 96, b: 8, s: 10, ..ProblemSpec::tiny() };
    let mut rng = Rng::seed_from(41);
    let op = spec.draw_operator(&mut rng);
    let batch = spec.generate_mmv_with_op(&op, &mut rng, 6);
    let opts = AsyncOpts::default();
    let out = recover_batch_stoiht(&batch, &opts, 71);
    assert!(out.all_converged(), "batched MMV signals must converge");
    let seq: Vec<_> = (0..batch.len())
        .map(|c| solve_job(&batch[c], Alg::Stoiht, &opts, 500 + c as u64))
        .collect();
    assert!(seq.iter().all(|s| s.converged), "sequential signals must converge");
    let mean = |iters: &[u64]| iters.iter().sum::<u64>() as f64 / iters.len() as f64;
    let batched_iters: Vec<u64> = out.signals.iter().map(|s| s.iters).collect();
    let seq_iters: Vec<u64> = seq.iter().map(|s| s.iters).collect();
    assert!(
        mean(&batched_iters) <= 1.1 * mean(&seq_iters),
        "batched {batched_iters:?} vs sequential {seq_iters:?}"
    );
    for (p, s) in batch.iter().zip(&out.signals) {
        assert!(p.residual_norm(&s.x) < 1e-6);
        assert!(p.recovery_error(&s.x) < 1e-5);
    }
}

/// Batched-vs-single per-column bitwise parity of the multi-RHS operator
/// entry points, exercised through the public API on both operator
/// implementations (the satellite's coverage requirement; the in-crate
/// unit tests cover more support shapes).
#[test]
#[cfg_attr(miri, ignore = "pure f64 kernels with no sync code under test; slow under Miri")]
fn multi_rhs_operator_entry_points_are_bitwise_per_column() {
    let dense_spec = ProblemSpec {
        n: 64,
        m: 32,
        b: 8,
        s: 4,
        ensemble: Ensemble::PartialDct,
        ..ProblemSpec::tiny()
    };
    let free_spec = ProblemSpec { dense_a: false, ..dense_spec.clone() };
    for (spec, label) in [(dense_spec, "dense"), (free_spec, "subsampled_dct")] {
        let mut rng = Rng::seed_from(51);
        let op = spec.draw_operator(&mut rng);
        let batch = spec.generate_mmv_with_op(&op, &mut rng, 3);
        let op: &Operator = &batch[0].op;
        let n = spec.n;
        let b = spec.b;
        let row0 = b * 2;
        // Per-signal iterate-like inputs on distinct supports.
        let supports: Vec<Vec<usize>> = (0..3)
            .map(|k| {
                let mut s = Rng::seed_from(60 + k).subset(n, 4 + k as usize);
                s.sort_unstable();
                s
            })
            .collect();
        let xs: Vec<Vec<f64>> = supports
            .iter()
            .map(|supp| {
                let mut x = vec![0.0; n];
                for (q, &j) in supp.iter().enumerate() {
                    x[j] = 0.4 + 0.2 * q as f64;
                }
                x
            })
            .collect();
        let mut scratch = op.make_scratch();
        // Singles.
        let mut want_out = vec![vec![0.0; n]; 3];
        let mut want_resid = vec![vec![0.0; b]; 3];
        for k in 0..3 {
            op.block_proxy_step_sparse(
                row0,
                batch[k].y_block(2),
                &xs[k],
                &supports[k],
                1.0,
                &mut want_resid[k],
                &mut scratch,
                &mut want_out[k],
            );
        }
        // Batched.
        let mut got_out = vec![vec![0.0; n]; 3];
        let mut got_resid = vec![vec![0.0; b]; 3];
        {
            let mut cols: Vec<ProxyCol<'_>> = Vec::new();
            for (((k, out), resid), x) in
                got_out.iter_mut().enumerate().zip(got_resid.iter_mut()).zip(xs.iter())
            {
                cols.push(ProxyCol {
                    y_b: batch[k].y_block(2),
                    x,
                    support: &supports[k],
                    resid: &mut resid[..],
                    out: &mut out[..],
                });
            }
            op.block_proxy_step_sparse_multi(row0, &mut cols, 1.0, &mut scratch);
        }
        for k in 0..3 {
            for i in 0..b {
                assert_eq!(
                    got_resid[k][i].to_bits(),
                    want_resid[k][i].to_bits(),
                    "{label}: col {k} resid row {i}"
                );
            }
            for j in 0..n {
                assert_eq!(
                    got_out[k][j].to_bits(),
                    want_out[k][j].to_bits(),
                    "{label}: col {k} out coord {j}"
                );
            }
        }
        // Multi-apply parity on the same operator.
        let x_panel: Vec<f64> = xs.concat();
        let mut out_panel = vec![0.0; 3 * spec.m];
        op.apply_multi_into(&x_panel, &mut scratch, &mut out_panel);
        for k in 0..3 {
            let mut want = vec![0.0; spec.m];
            op.apply_into(&xs[k], &mut scratch, &mut want);
            for i in 0..spec.m {
                assert_eq!(
                    out_panel[k * spec.m + i].to_bits(),
                    want[i].to_bits(),
                    "{label}: apply col {k} row {i}"
                );
            }
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "full solve loop on the calling thread; slow under Miri")]
fn custom_kernel_jobs_ride_the_pool() {
    // solve_job_with accepts any SupportKernel factory, so service users
    // can pool custom kernels exactly like the built-ins.
    let problems = shared_problems(&easy_spec(), 1, 61);
    let opts = AsyncOpts::default();
    let out = solve_job_with(&problems[0], &opts, 5, |p| {
        astir::algorithms::StoihtKernel::new(p, opts.gamma)
    });
    assert!(out.converged);
    assert!(problems[0].residual_norm(&out.x) < 1e-6);
}
