//! The `astir lint` rules, enforced on this very source tree as an
//! ordinary test: `cargo test` fails the moment an atomic loses its
//! ordering justification, a module bypasses the `crate::sync` doorway,
//! an `unsafe` block sheds its SAFETY comment, or an arch intrinsic
//! escapes the `src/linalg/simd/` doorway. CI additionally runs the
//! `astir lint` subcommand, which prints per-finding locations.

use std::path::Path;

#[test]
#[cfg_attr(miri, ignore = "reads the source tree from disk; no UB to find")]
fn source_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = astir::lint::lint_tree(root).expect("lint walk failed");
    let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(rendered.is_empty(), "lint findings:\n{}", rendered.join("\n"));
}

/// L6 guards the SIMD doorway from *outside* the doorway: an intrinsic or
/// `std::arch` path in an ordinary module is a finding even when it carries
/// a SAFETY comment. (The in-crate unit tests cover the inside-the-doorway
/// cases; this pins the rule at the integration surface the gate runs.)
#[test]
#[cfg_attr(miri, ignore = "string-level analysis; no UB to find")]
fn l6_rejects_intrinsics_outside_the_simd_doorway() {
    let src = "// SAFETY (AVX2): irrelevant — wrong module.\n\
               let v = _mm256_setzero_pd();\nuse std::arch::x86_64::_mm256_add_pd;";
    let findings = astir::lint::lint_source("src/algorithms/stoiht.rs", src);
    assert!(
        findings.iter().filter(|f| f.rule == "L6").count() >= 3,
        "expected L6 findings, got: {findings:?}"
    );
    assert!(astir::lint::lint_source("src/linalg/simd/avx2.rs", src).is_empty());
}
