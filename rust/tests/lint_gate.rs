//! The `astir lint` rules, enforced on this very source tree as an
//! ordinary test: `cargo test` fails the moment an atomic loses its
//! ordering justification, a module bypasses the `crate::sync` doorway,
//! or an `unsafe` block sheds its SAFETY comment. CI additionally runs
//! the `astir lint` subcommand, which prints per-finding locations.

use std::path::Path;

#[test]
#[cfg_attr(miri, ignore = "reads the source tree from disk; no UB to find")]
fn source_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = astir::lint::lint_tree(root).expect("lint walk failed");
    let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(rendered.is_empty(), "lint findings:\n{}", rendered.join("\n"));
}
