//! Model-check tier: the in-crate deterministic scheduler explores every
//! interleaving (up to a preemption bound) of small concurrent programs.
//!
//! Two families live here:
//!
//! * **Checker self-tests** — seeded bugs (races, deadlocks, lost
//!   wakeups, schedule-dependent asserts) the checker must *find*, and
//!   correct protocols it must *pass*. These pin down the checker's
//!   vocabulary of violations.
//! * **Crate-protocol tests** — the real [`RecoveryPool`] and
//!   [`AtomicTally`] run under the model at small configurations,
//!   including the mutation witness: weakening the pool's `pending`
//!   countdown from `AcqRel` to `Relaxed` must produce a `DataRace`.
//!
//! Run with: `cargo test --features model --test model_check`. Knobs:
//! `ASTIR_MODEL_PREEMPTIONS`, `ASTIR_MODEL_MAX_SCHEDULES`,
//! `ASTIR_MODEL_MAX_STEPS`.
#![cfg(feature = "model")]

use astir::service::RecoveryPool;
use astir::sync::atomic::{AtomicBool, Ordering};
use astir::sync::model::{check, check_with, set_weaken_pool_pending, ModelOpts, ViolationKind};
use astir::sync::{thread, Arc, Condvar, Mutex, RaceCell};
use astir::tally::{AtomicTally, ExchangeBoard, TallyWeighting};

/// Pool programs have long op sequences; one involuntary switch already
/// covers the witness race and keeps the schedule count CI-sized.
fn bound1() -> ModelOpts {
    ModelOpts { preemption_bound: 1, ..ModelOpts::default() }
}

// The mutation knob is process-global (pool worker threads must see it),
// so every test that runs the pool under the model serializes on this
// lock to keep the knob's value from leaking across tests.
static POOL_LOCK: Mutex<()> = Mutex::new(());

// ---------------------------------------------------------------------------
// Checker self-tests: seeded bugs it must find, clean protocols it must pass
// ---------------------------------------------------------------------------

#[test]
fn mutex_protected_counter_is_clean() {
    let report = check(|| {
        let total = Arc::new(Mutex::new(0u64));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let total = Arc::clone(&total);
            handles.push(thread::spawn(move || *total.lock().unwrap() += 1));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*total.lock().unwrap(), 2);
    });
    assert!(report.schedules > 1, "two racing lockers must branch the schedule space");
}

#[test]
fn unsynchronized_writes_are_reported_as_a_race() {
    struct Shared(RaceCell<u64>);
    // SAFETY: deliberately unsound — two threads get at the cell with no
    // synchronization at all, which is exactly what the checker must flag.
    unsafe impl Sync for Shared {}
    let v = check_with(&ModelOpts::default(), || {
        let cell = Arc::new(Shared(RaceCell::new(0u64)));
        let mut handles = Vec::new();
        for val in 1..=2u64 {
            let cell = Arc::clone(&cell);
            handles.push(thread::spawn(move || {
                // SAFETY: the pointer is valid; the *race* is the bug
                // under test, and the model reports it rather than
                // letting the accesses overlap.
                cell.0.with_mut(|p| unsafe { *p = val });
            }));
        }
        for h in handles {
            let _ = h.join();
        }
    })
    .expect_err("two unsynchronized writers must race");
    assert_eq!(v.kind, ViolationKind::DataRace, "expected a data race, got: {v}");
}

#[test]
fn stop_flag_release_acquire_protocol_is_clean() {
    struct Exit {
        stop: AtomicBool,
        data: RaceCell<u64>,
    }
    // SAFETY: `data` is written only before the Release store of `stop`
    // and read only after an Acquire load observes it; the checker
    // verifies that edge in every schedule.
    unsafe impl Sync for Exit {}
    check(|| {
        let st = Arc::new(Exit { stop: AtomicBool::new(false), data: RaceCell::new(0) });
        let s = Arc::clone(&st);
        let winner = thread::spawn(move || {
            // SAFETY: single writer; readers are gated on the store below.
            s.data.with_mut(|p| unsafe { *p = 99 });
            // Release: publish the `data` write before raising the stop
            // flag (the async_runtime ExitInfo protocol in miniature).
            s.stop.store(true, Ordering::Release);
        });
        let s = Arc::clone(&st);
        let watcher = thread::spawn(move || {
            // Bounded poll — the model forbids unbounded spins.
            for _ in 0..2 {
                // Acquire: pairs with the winner's Release store.
                if s.stop.load(Ordering::Acquire) {
                    // SAFETY: the Acquire load ordered us after the
                    // winner's write to `data`.
                    let seen = s.data.with(|p| unsafe { *p });
                    assert_eq!(seen, 99);
                    return;
                }
            }
        });
        winner.join().unwrap();
        watcher.join().unwrap();
    });
}

#[test]
fn stop_flag_with_relaxed_ordering_is_reported() {
    struct Exit {
        stop: AtomicBool,
        data: RaceCell<u64>,
    }
    // SAFETY: same shape as the clean test — but the orderings below are
    // too weak, and the checker must say so rather than stay silent.
    unsafe impl Sync for Exit {}
    let v = check_with(&ModelOpts::default(), || {
        let st = Arc::new(Exit { stop: AtomicBool::new(false), data: RaceCell::new(0) });
        let s = Arc::clone(&st);
        let winner = thread::spawn(move || {
            // SAFETY: pointer is valid; the missing Release edge is the
            // bug under test.
            s.data.with_mut(|p| unsafe { *p = 99 });
            // Relaxed: the mutation — no release edge carries `data`.
            s.stop.store(true, Ordering::Relaxed);
        });
        let s = Arc::clone(&st);
        let watcher = thread::spawn(move || {
            // Relaxed: no acquire edge either; seeing the flag no longer
            // orders the `data` read after the write.
            if s.stop.load(Ordering::Relaxed) {
                // SAFETY: pointer is valid; the unordered read is the
                // point of the test.
                let _ = s.data.with(|p| unsafe { *p });
            }
        });
        let _ = winner.join();
        let _ = watcher.join();
    })
    .expect_err("a relaxed stop flag must not order the data read");
    assert_eq!(v.kind, ViolationKind::DataRace, "expected a data race, got: {v}");
}

#[test]
fn opposite_lock_orders_deadlock() {
    let v = check_with(&ModelOpts::default(), || {
        let a = Arc::new(Mutex::new(()));
        let b = Arc::new(Mutex::new(()));
        let (a1, b1) = (Arc::clone(&a), Arc::clone(&b));
        let t1 = thread::spawn(move || {
            let _ga = a1.lock().unwrap();
            let _gb = b1.lock().unwrap();
        });
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        let t2 = thread::spawn(move || {
            let _gb = b2.lock().unwrap();
            let _ga = a2.lock().unwrap();
        });
        let _ = t1.join();
        let _ = t2.join();
    })
    .expect_err("AB/BA lock order must deadlock under some schedule");
    assert_eq!(v.kind, ViolationKind::Deadlock, "expected a deadlock, got: {v}");
}

#[test]
fn notify_with_no_waiter_is_lost() {
    let v = check_with(&ModelOpts::default(), || {
        let pair = Arc::new((Mutex::new(()), Condvar::new()));
        let p = Arc::clone(&pair);
        let waiter = thread::spawn(move || {
            // Bug under test: waiting with no predicate — a notify that
            // fires before this wait is lost and the wait blocks forever.
            let g = p.0.lock().unwrap();
            let _g = p.1.wait(g).unwrap();
        });
        let p = Arc::clone(&pair);
        let notifier = thread::spawn(move || p.1.notify_one());
        let _ = waiter.join();
        let _ = notifier.join();
    })
    .expect_err("an un-predicated wait must miss an early notify");
    assert_eq!(v.kind, ViolationKind::Deadlock, "expected a deadlock, got: {v}");
}

#[test]
fn predicate_guarded_wait_is_clean() {
    check(|| {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p = Arc::clone(&pair);
        let waiter = thread::spawn(move || {
            let mut g = p.0.lock().unwrap();
            while !*g {
                g = p.1.wait(g).unwrap();
            }
        });
        let p = Arc::clone(&pair);
        let notifier = thread::spawn(move || {
            let mut g = p.0.lock().unwrap();
            *g = true;
            // Notify under the lock: the waiter is either not yet waiting
            // (and will see the flag) or parked (and gets the wakeup).
            p.1.notify_one();
            drop(g);
        });
        waiter.join().unwrap();
        notifier.join().unwrap();
    });
}

#[test]
fn schedule_dependent_assert_is_surfaced_as_panic() {
    let v = check_with(&ModelOpts::default(), || {
        let flag = Arc::new(AtomicBool::new(false));
        let f = Arc::clone(&flag);
        let setter = thread::spawn(move || {
            // Relaxed: this test is about schedules, not visibility — the
            // model gives atomic values sequential consistency anyway.
            f.store(true, Ordering::Relaxed);
        });
        // Relaxed: see above — the load races the store on purpose.
        let saw = flag.load(Ordering::Relaxed);
        let _ = setter.join();
        assert!(saw, "some schedule runs this load before the store");
    })
    .expect_err("the load-before-store schedule must be found");
    assert_eq!(v.kind, ViolationKind::Panic, "expected a panic, got: {v}");
}

// ---------------------------------------------------------------------------
// Crate protocols under the model
// ---------------------------------------------------------------------------

#[test]
fn tally_concurrent_unit_commits_preserve_the_total() {
    let report = check(|| {
        let tally = Arc::new(AtomicTally::new(3, TallyWeighting::Unit));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let tally = Arc::clone(&tally);
            handles.push(thread::spawn(move || tally.commit(&[0, 1], &[], t + 1)));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Two unit-weight commits over two indices each: votes [2, 2, 0].
        let mut snap = vec![0i64; 3];
        tally.snapshot_into(&mut snap);
        assert_eq!(snap, vec![2, 2, 0]);
        assert_eq!(tally.total(), 4);
    });
    assert!(report.schedules > 1, "interleaved commits must branch the schedule space");
}

#[test]
fn exchange_board_round_is_race_free_and_deterministic() {
    // One full sharded-exchange round (publish -> read -> release) for two
    // shards: in EVERY interleaving the peer sum each shard reads must be
    // exactly the other shard's snapshot, the merged view must be their
    // canonical coordinate-wise sum, and the barrier-latched finished
    // count must agree across shards (shard 1 publishes `finished`).
    let report = check_with(&bound1(), || {
        let board = Arc::new(ExchangeBoard::new(2, 3));
        let mut handles = Vec::new();
        for k in 0..2usize {
            let board = Arc::clone(&board);
            handles.push(thread::spawn(move || {
                let votes: Vec<i64> = (0..3).map(|i| (k as i64 + 1) * 10 + i as i64).collect();
                board.publish_and_wait(k, &votes, k == 1);
                let done = board.finished_count();
                let mut peers = Vec::new();
                board.peer_sum_into(k, &mut peers);
                let other = 1 - k;
                let expect: Vec<i64> = (0..3).map(|i| (other as i64 + 1) * 10 + i as i64).collect();
                assert_eq!(peers, expect, "peer sum must be exactly the other shard's snapshot");
                let mut merged = Vec::new();
                board.merged_into(&mut merged);
                let want: Vec<i64> = (0..3).map(|i| 30 + 2 * i as i64).collect();
                assert_eq!(merged, want, "merged view must be the canonical sum");
                board.wait();
                done
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 1, "both shards must see the latched finished count");
        }
    })
    .unwrap_or_else(|v| panic!("model check failed\n{v}"));
    assert!(report.schedules > 1, "a two-shard exchange must branch the schedule space");
}

#[test]
fn pool_drains_a_small_batch_under_all_schedules() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_weaken_pool_pending(false);
    let report = check_with(&bound1(), || {
        let pool = RecoveryPool::new(2);
        let out = pool.run_jobs(3, 7, |i, _rng| i);
        assert_eq!(out, vec![0, 1, 2]);
    })
    .unwrap_or_else(|v| panic!("model check failed\n{v}"));
    assert!(report.schedules > 1, "a 2-worker drain must branch the schedule space");
}

#[test]
fn weakened_pending_countdown_is_caught() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_weaken_pool_pending(true);
    let result = check_with(&bound1(), || {
        let pool = RecoveryPool::new(2);
        let out = pool.run_jobs(2, 3, |i, _rng| i * 10);
        assert_eq!(out, vec![0, 10]);
    });
    set_weaken_pool_pending(false);
    let v = result.expect_err("a Relaxed pending countdown must lose the publication edge");
    assert_eq!(v.kind, ViolationKind::DataRace, "expected a data race, got: {v}");
}
