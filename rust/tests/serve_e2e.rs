//! End-to-end `astir serve` contract over localhost TCP: spawn the real
//! binary (`CARGO_BIN_EXE_astir`), scrape the ephemeral port from its
//! `listening on <addr>` line, and drive it with [`Client`] — the same
//! wire codec production clients use.
//!
//! Two contracts are pinned:
//!
//! * **Bit-identity** — with `--batch-window-ms 0` every served reply
//!   (iterates, residual, final error) is bit-for-bit the result of
//!   resolving the same [`JobRequest`] and running [`solve_job`] in this
//!   process: the network front-end adds transport, not arithmetic.
//! * **Typed admission** — with `--max-inflight 1` a job parked in an
//!   open batch window holds the only slot, so a concurrent job bounces
//!   with the typed [`ServeError::Busy`] (never a hang or a dropped
//!   connection), while stats frames bypass admission throughout.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use astir::algorithms::Alg;
use astir::async_runtime::AsyncOpts;
use astir::problem::Ensemble;
use astir::service::api::{JobRequest, ServeError};
use astir::service::solve_job;
use astir::service::wire::Client;
use astir::sync::thread;

/// A spawned `astir serve` child, killed on drop (success or panic).
struct Serve {
    child: Child,
    addr: String,
}

impl Serve {
    /// Spawn `astir serve` on an ephemeral loopback port and scrape the
    /// bound address from its `listening on <addr>` stdout line.
    fn spawn(extra: &[&str]) -> Serve {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_astir"));
        cmd.args(["serve", "--addr", "127.0.0.1:0", "--workers", "4"]);
        cmd.args(extra);
        cmd.stdout(Stdio::piped()).stderr(Stdio::null()).stdin(Stdio::null());
        let mut child = cmd.spawn().expect("spawn astir serve");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            match lines.next() {
                Some(Ok(line)) => {
                    if let Some(rest) = line.strip_prefix("listening on ") {
                        break rest.trim().to_string();
                    }
                }
                _ => panic!("server exited before printing its address"),
            }
        };
        Serve { child, addr }
    }

    fn client(&self) -> Client {
        Client::connect(&self.addr).expect("connect to served addr")
    }
}

impl Drop for Serve {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn req(seed: u64) -> JobRequest {
    JobRequest { ensemble: Ensemble::Gaussian, n: 128, m: 64, b: 8, s: 4, seed, y: None }
}

#[test]
fn served_results_are_bit_identical_to_in_process_solves() {
    let server = Serve::spawn(&["--batch-window-ms", "0"]);
    // Six concurrent clients over three operator seeds: the second wave of
    // each seed must hit the warm cache, and every reply must be
    // bit-identical to the same JobRequest resolved and solved here.
    let seeds = [5u64, 6, 7, 5, 6, 7];
    let mut handles = Vec::new();
    for &seed in &seeds {
        let addr = server.addr.clone();
        handles.push(thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let resp = client.job(&req(seed)).expect("transport").expect("typed ok");
            (seed, resp)
        }));
    }
    for h in handles {
        let (seed, resp) = h.join().expect("client thread");
        let r = req(seed);
        let op = r.draw_operator();
        let p = r.problem(&op).expect("resolve problem");
        let local = solve_job(&p, Alg::Stoiht, &AsyncOpts::default(), seed);
        assert!(resp.converged && local.converged, "seed {seed} must converge");
        assert_eq!(resp.iters, local.iters, "seed {seed}: iteration count drifted");
        assert_eq!(resp.x.len(), local.x.len());
        for (a, b) in resp.x.iter().zip(&local.x) {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}: x drifted over the wire");
        }
        assert_eq!(resp.residual.to_bits(), local.residual.to_bits(), "seed {seed}: residual");
        // y was generated server-side from the seed, so the truth is known
        // and final_error comes back populated.
        assert_eq!(resp.final_error.map(f64::to_bits), Some(local.final_error.to_bits()));
    }
    let mut client = server.client();
    let stats = client.stats().expect("stats frame");
    assert_eq!(stats.served, seeds.len() as u64);
    assert_eq!(stats.rejected, 0);
    // Every request is exactly one lookup outcome. Operator draws run
    // outside the cache lock, so concurrent misses on one key may each
    // draw (publication dedups the Arc, not the draw): the exact
    // hit/miss split is racy, but each of the three distinct keys must
    // miss at least once, and results stay bit-identical regardless
    // (problem resolution is cache-stable by construction).
    assert_eq!(stats.cache_hits + stats.cache_misses, seeds.len() as u64);
    assert!(stats.cache_misses >= 3, "three distinct operator keys");
    assert_eq!(stats.inflight, 0);
    assert!(stats.p50_s > 0.0 && stats.p99_s >= stats.p50_s);
}

#[test]
fn admission_rejects_typed_busy_while_a_window_is_parked() {
    let server = Serve::spawn(&["--batch-window-ms", "1500", "--max-inflight", "1"]);
    // Client A's job is admitted and parks as the leader of a 1.5 s batch
    // window; its admission slot is held for the whole window.
    let addr = server.addr.clone();
    let parked = thread::spawn(move || {
        let mut client = Client::connect(&addr).expect("connect");
        client.job(&req(40)).expect("transport").expect("parked job must succeed")
    });
    // Stats frames bypass admission: poll until A's slot is visible.
    let mut stats_client = server.client();
    let mut waited = 0;
    while stats_client.stats().expect("stats frame").inflight == 0 {
        waited += 1;
        assert!(waited < 400, "parked job never became visible in stats");
        thread::sleep(Duration::from_millis(5));
    }
    // Deterministic rejection: the only slot stays held for the rest of
    // the window, so B bounces with the typed Busy error immediately.
    let mut b = server.client();
    let rejected = b.job(&req(41)).expect("transport");
    assert_eq!(rejected, Err(ServeError::Busy));
    // A still completes fine once the window deadline passes.
    let resp = parked.join().expect("client thread");
    assert!(resp.converged);
    let stats = stats_client.stats().expect("stats frame");
    assert_eq!(stats.served, 1);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.inflight, 0);
}
