//! Three-layer composition: the AOT HLO artifacts (Pallas kernel → JAX
//! graph → HLO text) executed through PJRT from Rust, pinned against the
//! native backend on identical inputs.
//!
//! Skipped (with a notice) when `artifacts/` has not been built.

use std::path::PathBuf;

use astir::backend::{reference_step, Backend, NativeBackend, PjrtBackend};
use astir::problem::{Problem, ProblemSpec};
use astir::rng::Rng;
use astir::runtime::{ArtifactStore, PjrtRuntime};

fn artifacts_ready() -> bool {
    let dir: PathBuf = ArtifactStore::default_dir();
    let ok = dir.join("stoiht_step_n32_b4_s3.meta").exists();
    if !ok {
        eprintln!("skipping PJRT integration tests: run `make artifacts` first");
    }
    ok
}

fn tiny_problem(seed: u64) -> Problem {
    ProblemSpec::tiny().generate(&mut Rng::seed_from(seed))
}

#[test]
fn pjrt_step_matches_native_and_reference() {
    if !artifacts_ready() {
        return;
    }
    let p = tiny_problem(11);
    let mut native = NativeBackend::new();
    let mut pjrt = PjrtBackend::from_default_dir().unwrap();
    let mut rng = Rng::seed_from(3);
    for block in 0..p.spec.num_blocks() {
        let x: Vec<f64> = (0..p.spec.n).map(|_| 0.2 * rng.gauss()).collect();
        let mut mask = vec![0.0; p.spec.n];
        for i in rng.subset(p.spec.n, 4) {
            mask[i] = 1.0;
        }
        let (nx, ng) = native.stoiht_step(&p, block, &x, 1.0, &mask).unwrap();
        let (px, pg) = pjrt.stoiht_step(&p, block, &x, 1.0, &mask).unwrap();
        let (rx, rg) = reference_step(&p, block, &x, 1.0, &mask);
        assert_eq!(ng, rg, "native vs reference gamma (block {block})");
        assert_eq!(pg, rg, "pjrt vs reference gamma (block {block})");
        for i in 0..p.spec.n {
            assert!((nx[i] - rx[i]).abs() < 1e-10, "native i={i}");
            assert!((px[i] - rx[i]).abs() < 1e-4, "pjrt i={i}: {} vs {}", px[i], rx[i]);
        }
    }
}

#[test]
fn pjrt_residual_matches_native() {
    if !artifacts_ready() {
        return;
    }
    let p = tiny_problem(12);
    let mut pjrt = PjrtBackend::from_default_dir().unwrap();
    let mut rng = Rng::seed_from(4);
    for _ in 0..5 {
        let x: Vec<f64> = (0..p.spec.n).map(|_| rng.gauss()).collect();
        let want = p.residual_norm(&x);
        let got = pjrt.residual_norm(&p, &x).unwrap();
        assert!(
            (got - want).abs() / want.max(1.0) < 1e-4,
            "pjrt residual {got} vs native {want}"
        );
    }
}

#[test]
fn pjrt_iht_step_matches_native() {
    if !artifacts_ready() {
        return;
    }
    let p = tiny_problem(13);
    let rt = PjrtRuntime::from_default_dir().unwrap();
    let mut rng = Rng::seed_from(5);
    let x: Vec<f64> = (0..p.spec.n).map(|_| 0.3 * rng.gauss()).collect();
    let got = rt
        .iht_step(p.spec.n, p.spec.m, p.spec.s, p.try_dense().unwrap().data(), &p.y, &x, 0.8)
        .unwrap();
    let want = astir::algorithms::iht::iht_step(&p, &x, 0.8);
    for i in 0..p.spec.n {
        assert!((got[i] - want[i]).abs() < 1e-4, "i={i}: {} vs {}", got[i], want[i]);
    }
}

#[test]
fn pjrt_full_recovery_tiny() {
    // Sequential StoIHT through the PJRT backend end-to-end (f32 artifacts
    // => relaxed exit tolerance).
    if !artifacts_ready() {
        return;
    }
    let p = tiny_problem(14);
    let mut pjrt = PjrtBackend::from_default_dir().unwrap();
    let mut rng = Rng::seed_from(6);
    let mb = p.spec.num_blocks();
    let zero_mask = vec![0.0; p.spec.n];
    let mut x = vec![0.0f64; p.spec.n];
    let mut converged = false;
    for _ in 0..800 {
        let block = rng.below(mb);
        let (xn, _) = pjrt.stoiht_step(&p, block, &x, 1.0, &zero_mask).unwrap();
        x = xn;
        if pjrt.residual_norm(&p, &x).unwrap() < 1e-5 {
            converged = true;
            break;
        }
    }
    assert!(converged, "PJRT StoIHT did not reach 1e-5");
    assert!(p.recovery_error(&x) < 1e-3, "error {}", p.recovery_error(&x));
}

#[test]
fn runtime_reports_platform() {
    if !artifacts_ready() {
        return;
    }
    let rt = PjrtRuntime::from_default_dir().unwrap();
    let platform = rt.platform();
    assert!(!platform.is_empty());
    assert!(rt.store().len() >= 6);
}
