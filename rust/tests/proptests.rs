//! Property-based tests over the coordinator-side invariants (routing,
//! batching, state) and the numerical substrate, using the in-repo
//! `testutil` mini-framework. Each property runs across on the order of a
//! hundred randomized cases; failures print an `ASTIR_PROP_SEED` repro.

use std::io::Cursor;

use astir::algorithms::StoihtKernel;
use astir::coordinator::run_trials;
use astir::linalg::{dist2, dot, lstsq, nrm2, Mat, MeasureOp, Operator};
use astir::problem::{Ensemble, Problem, ProblemSpec};
use astir::rng::Rng;
use astir::service::api::{
    ExchangeJoin, ExchangeJoined, ExchangeLeave, ExchangePublish, ExchangeView, ServeError,
};
use astir::service::wire::{read_frame, write_frame, HubReply, HubRequest};
use astir::sim::{simulate, simulate_sharded, ShardOpts, SimOpts, SpeedSchedule};
use astir::support::{accuracy, intersection_size, top_s, union, union_into};
use astir::tally::{
    merge_votes_into, positive_top_s, ExchangeProtocol, LocalTally, TallyWeighting,
};
use astir::testutil::{property, Gen, OrFail};

fn random_problem(g: &mut Gen) -> Problem {
    let b = g.usize_in(2, 8);
    let blocks = g.usize_in(2, 6);
    let m = b * blocks;
    let n = m * 2 + g.usize_in(0, 32);
    let s = g.usize_in(1, (m / 4).max(1).min(8));
    ProblemSpec { n, m, b, s, ..ProblemSpec::tiny() }.generate(g.rng())
}

#[test]
fn prop_top_s_is_a_maximal_magnitude_set() {
    property("top_s maximal", 150, |g| {
        let n = g.usize_in(1, 120);
        let s = g.usize_in(0, n);
        let v = g.vec_gauss(n);
        let sel = top_s(&v, s);
        (sel.len() == s.min(n)).or_fail("cardinality")?;
        // every selected magnitude >= every unselected magnitude
        let min_in = sel.iter().map(|&i| v[i].abs()).fold(f64::INFINITY, f64::min);
        let max_out = (0..n)
            .filter(|i| !sel.contains(i))
            .map(|i| v[i].abs())
            .fold(0.0f64, f64::max);
        (sel.is_empty() || min_in >= max_out)
            .or_fail(format!("min_in {min_in} < max_out {max_out}"))
    });
}

#[test]
fn prop_union_is_sorted_superset() {
    property("union sorted superset", 150, |g| {
        let n = 80;
        let ka = g.usize_in(0, 20);
        let a = g.sorted_subset(n, ka);
        let kb = g.usize_in(0, 20);
        let b = g.sorted_subset(n, kb);
        let u = union(&a, &b);
        u.windows(2).all(|w| w[0] < w[1]).or_fail("not strictly sorted")?;
        (a.iter().all(|x| u.contains(x)) && b.iter().all(|x| u.contains(x)))
            .or_fail("missing member")?;
        (intersection_size(&a, &b) + u.len() == a.len() + b.len())
            .or_fail("inclusion-exclusion violated")
    });
}

#[test]
fn prop_union_into_agrees_with_union() {
    // The allocation-free form and the allocating wrapper must be the same
    // function: identical output, sorted, deduplicated, with stale buffer
    // contents discarded, and the empty set as the identity element.
    property("union_into == union", 150, |g| {
        let n = 100;
        let ka = g.usize_in(0, 25);
        let a = g.sorted_subset(n, ka);
        let kb = g.usize_in(0, 25);
        let b = g.sorted_subset(n, kb);
        let u = union(&a, &b);
        // reuse a dirty buffer: stale contents must not leak through
        let stale = g.usize_in(0, 8);
        let mut buf: Vec<usize> = vec![usize::MAX; stale];
        union_into(&a, &b, &mut buf);
        (buf == u).or_fail("union_into disagrees with union")?;
        u.windows(2).all(|w| w[0] < w[1]).or_fail("not sorted/deduplicated")?;
        // commutativity and the empty identity
        (union(&b, &a) == u).or_fail("union not commutative")?;
        (union(&a, &[]) == a).or_fail("union(a, []) != a")?;
        let mut buf2 = Vec::new();
        union_into(&a, &[], &mut buf2);
        (buf2 == a).or_fail("union_into(a, []) != a")
    });
}

#[test]
fn prop_tally_votes_conserved() {
    // After any interleaving of per-core commit sequences, the tally total
    // equals the sum over cores of s * w(final t) under Progress weighting.
    property("tally conservation", 80, |g| {
        let n = 60;
        let cores = g.usize_in(1, 5);
        let s = g.usize_in(1, 6);
        let iters = g.usize_in(1, 30);
        let mut tally = LocalTally::new(n, TallyWeighting::Progress);
        let mut prev: Vec<Vec<usize>> = vec![Vec::new(); cores];
        // random global interleaving of (core, t) pairs, order preserved per core
        let mut t_next = vec![1u64; cores];
        for _ in 0..(cores * iters) {
            let c = g.usize_in(0, cores - 1);
            let t = t_next[c];
            let gamma = g.sorted_subset(n, s);

            tally.commit(&gamma, &prev[c], t);
            prev[c] = gamma;
            t_next[c] += 1;
        }
        let expected: i64 = t_next.iter().map(|&t| (t as i64 - 1) * s as i64).sum();
        (tally.total() == expected)
            .or_fail(format!("total {} != expected {expected}", tally.total()))
    });
}

#[test]
fn prop_positive_top_s_subset_of_positives() {
    property("positive_top_s positives only", 120, |g| {
        let n = g.usize_in(1, 100);
        let votes: Vec<i64> = (0..n).map(|_| g.usize_in(0, 6) as i64 - 2).collect();
        let s = g.usize_in(0, n);
        let est = positive_top_s(&votes, s);

        (est.len() <= s).or_fail("size")?;
        est.iter().all(|&i| votes[i] > 0).or_fail("non-positive selected")?;
        let positives = votes.iter().filter(|&&v| v > 0).count();
        (est.len() == s.min(positives)).or_fail("not maximal")
    });
}

#[test]
fn prop_stoiht_step_support_invariant() {
    // After one kernel step, supp(x) ⊆ Γ ∪ extra and |supp(x)| ≤ s + |extra|.
    property("stoiht step support", 60, |g| {
        let p = random_problem(g);
        let mut kernel = StoihtKernel::new(&p, 1.0);
        let mut x: Vec<f64> = g.vec_gauss(p.spec.n).iter().map(|v| v * 0.1).collect();
        let k_extra = g.usize_in(0, p.spec.s);
        let extra = g.sorted_subset(p.spec.n, k_extra);
        let block = g.usize_in(0, p.spec.num_blocks() - 1);
        let gamma = kernel
            .step(&mut x, block, if extra.is_empty() { None } else { Some(&extra) })
            .to_vec();
        (gamma.len() == p.spec.s.min(p.spec.n)).or_fail("gamma size")?;
        let allowed = union(&gamma, &extra);
        (0..p.spec.n)
            .all(|i| x[i] == 0.0 || allowed.binary_search(&i).is_ok())
            .or_fail("support escaped the union")
    });
}

#[test]
fn prop_run_trials_thread_invariant() {
    // Monte-Carlo batching must be bit-deterministic in the thread count.
    property("run_trials determinism", 20, |g| {
        let trials = g.usize_in(1, 12);
        let seed = g.rng().next_u64();
        let threads = g.usize_in(2, 6);
        let one: Vec<u64> = run_trials(trials, 1, seed, |_i, r| r.next_u64());
        let many: Vec<u64> = run_trials(trials, threads, seed, |_i, r| r.next_u64());
        (one == many).or_fail("outputs depend on thread count")
    });
}

#[test]
fn prop_sim_exit_implies_tolerance() {
    // Whenever the simulator reports convergence, the winning core's
    // iterate truly satisfies the dense residual tolerance.
    property("sim exit honest", 25, |g| {
        let p = random_problem(g);
        let cores = g.usize_in(1, 6);
        let opts = SimOpts { max_steps: 4000, ..Default::default() };
        let out = simulate(&p, cores, &SpeedSchedule::AllFast, &opts, g.rng());
        if !out.converged {
            return Ok(()); // hard instances are allowed to time out
        }
        (out.final_error.is_finite() && out.steps <= 4000).or_fail("bookkeeping")?;
        // recovery error should be small when the residual is < 1e-7 on a
        // noiseless instance (allowing loose slack for conditioning).
        (out.final_error < 1e-3).or_fail(format!("error {}", out.final_error))
    });
}

#[test]
fn prop_merge_votes_is_permutation_invariant() {
    // The sharded support exchange sums snapshots coordinate-wise; the
    // merged votes (and hence the support estimate cut from them) must not
    // depend on which order the shard snapshots arrived in.
    property("merge_votes_into permutation invariant", 100, |g| {
        let n = g.usize_in(1, 60);
        let shards = g.usize_in(1, 6);
        let snaps: Vec<Vec<i64>> = (0..shards)
            .map(|_| (0..n).map(|_| g.usize_in(0, 12) as i64 - 6).collect())
            .collect();
        let mut base = Vec::new();
        merge_votes_into(&snaps, None, &mut base);
        // Fisher–Yates over the snapshot list
        let mut order: Vec<usize> = (0..shards).collect();
        for i in (1..shards).rev() {
            order.swap(i, g.usize_in(0, i));
        }
        let shuffled: Vec<Vec<i64>> = order.iter().map(|&i| snaps[i].clone()).collect();
        let mut permuted = Vec::new();
        merge_votes_into(&shuffled, None, &mut permuted);
        (permuted == base).or_fail("merged votes depend on arrival order")?;
        let s = g.usize_in(0, n);
        (positive_top_s(&permuted, s) == positive_top_s(&base, s))
            .or_fail("support estimate depends on arrival order")?;
        // excluding shard k must equal merging the list with k removed
        let k = g.usize_in(0, shards - 1);
        let mut without = Vec::new();
        merge_votes_into(&snaps, Some(k), &mut without);
        let rest: Vec<Vec<i64>> =
            (0..shards).filter(|&i| i != k).map(|i| snaps[i].clone()).collect();
        let mut expect = Vec::new();
        merge_votes_into(&rest, None, &mut expect);
        (without == expect).or_fail("self-exclusion disagrees with removal")
    });
}

#[test]
fn prop_sharded_sim_is_deterministic() {
    // Fixed (shards, exchange period, protocol, seed) must reproduce the
    // sharded run bit-for-bit: the merge is canonical, so nothing
    // schedule-shaped can leak into the trajectory.
    property("sharded sim determinism", 15, |g| {
        let p = random_problem(g);
        let so = ShardOpts {
            shards: g.usize_in(1, p.spec.num_blocks().min(3)),
            exchange_period: g.usize_in(1, 8),
            protocol: if g.usize_in(0, 1) == 0 {
                ExchangeProtocol::Gossip
            } else {
                ExchangeProtocol::LeaderMerge
            },
        };
        let opts = SimOpts { max_steps: 600, ..Default::default() };
        let seed = g.rng().next_u64();
        let sched = SpeedSchedule::AllFast;
        let a = simulate_sharded(&p, &so, &sched, &opts, &mut Rng::seed_from(seed));
        let b = simulate_sharded(&p, &so, &sched, &opts, &mut Rng::seed_from(seed));
        (a.steps == b.steps && a.converged == b.converged).or_fail("trajectory diverged")?;
        (a.final_error.to_bits() == b.final_error.to_bits())
            .or_fail("final error not bitwise equal")?;
        (a.local_iters == b.local_iters).or_fail("local iteration counts diverged")
    });
}

#[test]
fn prop_lstsq_normal_equations() {
    property("lstsq optimality", 80, |g| {
        let m = g.usize_in(1, 30);
        let k = g.usize_in(1, 30);
        let a = Mat::from_fn(m, k, |_, _| g.gauss());
        let y = g.vec_gauss(m);
        let z = lstsq(&a, &y);
        let az = a.gemv(&z);
        let r: Vec<f64> = y.iter().zip(&az).map(|(&p, &q)| p - q).collect();
        let atr = a.gemv_t(&r);
        // A^T r ≈ 0 at any least-squares solution (over- or under-determined).
        (nrm2(&atr) <= 1e-6 * (1.0 + nrm2(&y)) * (1.0 + frob(&a)))
            .or_fail(format!("||A^T r|| = {}", nrm2(&atr)))
    });
}

fn frob(a: &Mat<f64>) -> f64 {
    dot(a.data(), a.data()).sqrt()
}

#[test]
fn prop_accuracy_bounds() {
    property("accuracy in [0,1]", 100, |g| {
        let n = 60;
        let ke = g.usize_in(1, 20);
        let est = g.sorted_subset(n, ke);
        let kt = g.usize_in(0, 20);
        let truth = g.sorted_subset(n, kt);
        let acc = accuracy(&est, &truth);
        (0.0..=1.0).contains(&acc).or_fail(format!("acc {acc}"))
    });
}

#[test]
fn prop_measure_op_adjoint_consistency() {
    // ⟨A_b x, r⟩ == ⟨x, A_bᵀ r⟩ within 1e-10, for every ensemble × both
    // MeasureOp implementations, over random blocks and shapes. The
    // matrix-free operator exists only for partial_dct (power-of-two n),
    // so it is exercised on that ensemble; DenseOp covers all four.
    property("measure-op adjoint identity", 40, |g| {
        let n = 1usize << g.usize_in(4, 7); // 16, 32, 64, 128
        let b = [2usize, 4, 8][g.usize_in(0, 2)];
        let blocks = g.usize_in(1, (n / b).min(4));
        let m = b * blocks;
        let s = g.usize_in(1, 4);
        let dense_ensembles = [
            Ensemble::Gaussian,
            Ensemble::GaussianUnnormalized,
            Ensemble::Bernoulli,
            Ensemble::PartialDct,
        ];
        let mut ops: Vec<(astir::sync::Arc<Operator>, String)> = Vec::new();
        for e in dense_ensembles {
            let spec = ProblemSpec { n, m, b, s, ensemble: e, ..ProblemSpec::tiny() };
            ops.push((spec.generate(g.rng()).op, format!("dense/{e:?}")));
        }
        let free = ProblemSpec {
            n,
            m,
            b,
            s,
            ensemble: Ensemble::PartialDct,
            dense_a: false,
            ..ProblemSpec::tiny()
        };
        ops.push((free.generate(g.rng()).op, "subsampled_dct".to_string()));
        for (op, label) in &ops {
            let block = g.usize_in(0, blocks - 1);
            let row0 = block * b;
            let x = g.vec_gauss(n);
            let r = g.vec_gauss(b);
            let mut scratch = op.make_scratch();
            let mut ax = vec![0.0; b];
            op.block_apply_into(row0, &x, &mut scratch, &mut ax);
            let mut atr = vec![0.0; n];
            op.block_apply_t_acc(row0, &r, 0.0, &mut scratch, &mut atr);
            let lhs = dot(&ax, &r);
            let rhs = dot(&x, &atr);
            ((lhs - rhs).abs() <= 1e-10 * (1.0 + lhs.abs() + rhs.abs()))
                .or_fail(format!("{label} block {block}: {lhs} vs {rhs}"))?;
            // Full-operator identity rides the same contract.
            let rm = g.vec_gauss(m);
            let mut axm = vec![0.0; m];
            op.apply_into(&x, &mut scratch, &mut axm);
            let mut atrm = vec![0.0; n];
            op.apply_t_into(&rm, &mut scratch, &mut atrm);
            let l2 = dot(&axm, &rm);
            let r2 = dot(&x, &atrm);
            ((l2 - r2).abs() <= 1e-10 * (1.0 + l2.abs() + r2.abs()))
                .or_fail(format!("{label} full operator: {l2} vs {r2}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_problem_blocks_partition() {
    property("blocks partition measurements", 40, |g| {
        let p = random_problem(g);
        let x = g.vec_gauss(p.spec.n);
        let full = p.try_dense().expect("random_problem draws dense").gemv(&x);
        let mut reassembled = Vec::new();
        for i in 0..p.spec.num_blocks() {
            let (blk, _) = p.block(i);
            reassembled.extend(blk.gemv(&x));
        }
        (dist2(&full, &reassembled) < 1e-10).or_fail("block views disagree with full gemv")
    });
}

// ------------------------------------------------ distributed exchange

/// Full-range `i64` vote vector: uniform random words plus one forced
/// extreme per case, so the decimal-string encoding beyond the f64-exact
/// window (`|v| > 2^53`) is always exercised alongside the plain-number
/// path.
fn vote_vec(g: &mut Gen) -> Vec<i64> {
    const EDGES: [i64; 8] = [
        i64::MIN,
        i64::MAX,
        1 << 53,
        -(1 << 53),
        (1 << 53) + 1,
        -(1 << 53) - 1,
        0,
        -1,
    ];
    let len = g.usize_in(0, 24);
    let mut votes: Vec<i64> = (0..len).map(|_| g.rng().next_u64() as i64).collect();
    votes.push(*g.choose(&EDGES));
    votes
}

#[test]
fn prop_exchange_frames_roundtrip_the_wire() {
    property("exchange frames roundtrip the wire", 120, |g| {
        let req = match g.usize_in(0, 2) {
            0 => HubRequest::Join(ExchangeJoin {
                shard: g.usize_in(0, 63),
                shards: g.usize_in(1, 64),
                n: g.usize_in(0, 1 << 20),
                exchange_period: g.usize_in(1, 1 << 16),
            }),
            1 => HubRequest::Publish(ExchangePublish {
                shard: g.usize_in(0, 63),
                // `u64` protocol counters ride plain JSON numbers and are
                // rejected past 2^53 by design; stay in the exact window.
                round: g.rng().next_u64() >> 11,
                finished: g.bool(),
                votes: vote_vec(g),
            }),
            _ => HubRequest::Leave(ExchangeLeave { shard: g.usize_in(0, 63) }),
        };
        let reply = match g.usize_in(0, 2) {
            0 => HubReply::Joined(ExchangeJoined {
                shards: g.usize_in(1, 64),
                round_timeout_ms: g.rng().next_u64() >> 11,
            }),
            1 => HubReply::View(ExchangeView {
                round: g.rng().next_u64() >> 11,
                finished_shards: g.usize_in(0, 64),
                stale_peers: g.usize_in(0, 64),
                merged: vote_vec(g),
            }),
            _ => HubReply::Error(ServeError::Incompatible("shape mismatch".to_string())),
        };
        // Through the framed byte layer, not just the JSON text: what one
        // side writes must read back identically on the other.
        let mut buf = Vec::new();
        write_frame(&mut buf, &req.to_json()).expect("write to a Vec cannot fail");
        write_frame(&mut buf, &reply.to_json()).expect("write to a Vec cannot fail");
        let mut cur = Cursor::new(buf);
        let req_text = read_frame(&mut cur).expect("framed read").expect("frame present");
        let reply_text = read_frame(&mut cur).expect("framed read").expect("frame present");
        let req_back = HubRequest::parse(&req_text).map_err(|e| format!("request: {e:?}"))?;
        (req_back == req).or_fail(format!("request drifted over the wire: {req:?}"))?;
        let reply_back = HubReply::parse(&reply_text).map_err(|e| format!("reply: {e:?}"))?;
        (reply_back == reply).or_fail(format!("reply drifted over the wire: {reply:?}"))
    });
}
