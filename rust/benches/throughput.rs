//! PERF — recovery-service throughput benches
//! (`cargo bench --bench throughput`).
//!
//! Thin wrapper over the `throughput` suite in
//! `astir::bench_harness::suites`: jobs/sec at `n = 2^17` (matrix-free
//! subsampled DCT, one operator shared by `Arc` across all jobs) for the
//! persistent `RecoveryPool` vs today's spawn-per-call runtime, and for
//! lockstep batched MMV recovery (shared tally + one multi-RHS fused
//! proxy per time step) vs a sequential per-signal loop. Single-pass
//! experiment budgets; everything runs in CI smoke under the committed
//! `baseline_smoke.json` regression gate.
//!
//! Telemetry: `results/BENCH_throughput.json`.

mod common;

fn main() {
    common::bench_binary_main("throughput");
}
