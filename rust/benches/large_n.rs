//! PERF — matrix-free large-n benches (`cargo bench --bench large_n`).
//!
//! Thin wrapper over the `large_n` suite in
//! `astir::bench_harness::suites`: the matrix-free subsampled-DCT operator
//! (`SubsampledDctOp`) at `n = 2^17` (apply / adjoint / sparse-proxy, one
//! fast transform each) and `n = 2^20, m = 3·10^5` (full-transform apply +
//! a 4-worker asynchronous StoIHT recovery run). The dense matrix pair for
//! the big shape would need ~2.4 TB — these shapes exist **only** through
//! the operator, so nothing here is jumbo-gated and every point runs in
//! smoke mode under the committed `baseline_smoke.json` regression gate.
//!
//! Telemetry: `results/BENCH_large_n.json`.

mod common;

fn main() {
    common::bench_binary_main("large_n");
}
