//! A5 — baseline phase-transition sweep (`cargo bench --bench baselines`),
//! via the `baselines` suite in `astir::bench_harness::suites`.
//!
//! Success rate (relative error < 1e-4) vs number of measurements `m` for
//! IHT, StoIHT, OMP, CoSaMP and StoGradMP at the paper's n = 1000, s = 20.
//! Expected shape: all curves rise from 0 to 1; LS-refitting algorithms
//! (OMP/CoSaMP/StoGradMP) transition earlier than the thresholding family.
//! Telemetry: `results/BENCH_baselines.json`.

mod common;

fn main() {
    common::bench_binary_main("baselines");
}
