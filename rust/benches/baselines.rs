//! A5 — baseline phase-transition sweep (`cargo bench --bench baselines`).
//!
//! Success rate (relative error < 1e-4) vs number of measurements `m` for
//! IHT, StoIHT, OMP, CoSaMP and StoGradMP at the paper's n = 1000, s = 20.
//! Expected shape: all curves rise from 0 to 1; LS-refitting algorithms
//! (OMP/CoSaMP/StoGradMP) transition earlier than the thresholding family.

mod common;

use astir::experiments::phase_transition;
use astir::report;

fn main() {
    let mut cfg = common::paper_cfg(15);
    // Phase transitions are the expensive sweep (5 solvers x trials x m).
    cfg.trials = cfg.trials.min(50);
    common::banner("A5 — success rate vs m (phase transition)", &cfg);

    let ms = [60, 90, 120, 150, 180, 240, 300];
    let t0 = std::time::Instant::now();
    let table = phase_transition(&cfg, &ms);
    println!("[baselines computed in {:.1?}]", t0.elapsed());
    report::emit("baselines_phase_transition", "A5: success rate vs m", &table);
    report::note("success = relative recovery error < 1e-4; n=1000, s=20, Gaussian ensemble");
}
