//! Ablations A1–A4 + A6 (`cargo bench --bench ablations [-- <name>]`):
//!
//! * `tally_vs_shared_x`  — A1: the paper's central design choice
//! * `inconsistent_reads` — A2: stale tally reads (paper §III ¶3)
//! * `tally_weighting`    — A3: +t/−(t−1) vs unit vs no-decrement
//! * `block_size`         — A4: StoIHT iterations vs b
//! * `self_exclusion`     — A6: reading φ minus one's own votes
//!   (reproduction finding, see the notes in README.md)
//!
//! With no filter argument, all ablations run.

mod common;

use astir::coordinator::Leader;
use astir::experiments;
use astir::metrics::{stats, Table};
use astir::report;
use astir::sim::{SimOpts, SpeedSchedule};

fn main() {
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let want = |name: &str| filter.as_deref().map_or(true, |f| f == name);
    let cfg = common::paper_cfg(15);
    common::banner("Ablations A1–A4, A6", &cfg);

    if want("tally_vs_shared_x") {
        let t0 = std::time::Instant::now();
        let t = experiments::tally_vs_shared_x(&cfg);
        println!("[A1 in {:.1?}]", t0.elapsed());
        report::emit("ablation_tally_vs_shared_x", "A1: tally vs HOGWILD!-style shared x (half-slow schedule)", &t);
        report::note("paper §I: with dense cost functions, sharing x lets slow cores undo progress;");
        report::note("sharing the passively-read tally is robust. Compare the *_conv columns.");
    }

    if want("inconsistent_reads") {
        let t0 = std::time::Instant::now();
        let t = experiments::inconsistent_reads(&cfg);
        println!("[A2 in {:.1?}]", t0.elapsed());
        report::emit("ablation_inconsistent_reads", "A2: per-coordinate stale-read probability", &t);
    }

    if want("tally_weighting") {
        let t0 = std::time::Instant::now();
        let t = experiments::tally_weighting(&cfg);
        println!("[A3 in {:.1?}]", t0.elapsed());
        report::emit("ablation_weighting", "A3: tally weighting schemes (half-slow schedule)", &t);
        report::note("paper Alg. 2 weights votes by local iteration (+t/−(t−1)) so fast cores dominate.");
    }

    if want("block_size") {
        let t0 = std::time::Instant::now();
        let t = experiments::block_size_sweep(&cfg, &[5, 10, 15, 25, 50, 75]);
        println!("[A4 in {:.1?}]", t0.elapsed());
        report::emit("ablation_block_size", "A4: StoIHT iterations vs block size b (m = 300)", &t);
    }

    if want("self_exclusion") {
        let t0 = std::time::Instant::now();
        let leader = Leader::new(cfg.clone());
        let mut t = Table::new(&["cores", "literal_mean", "literal_conv", "selfexcl_mean", "selfexcl_conv"]);
        for &c in &cfg.cores {
            let lit = leader.monte_carlo_sim(
                c,
                &SpeedSchedule::AllFast,
                &SimOpts { max_steps: cfg.max_iters, ..Default::default() },
            );
            let sx = leader.monte_carlo_sim(
                c,
                &SpeedSchedule::AllFast,
                &SimOpts { max_steps: cfg.max_iters, self_exclude: true, ..Default::default() },
            );
            let mean = |o: &[astir::sim::SimOutcome]| stats(&o.iter().map(|x| x.steps as f64).collect::<Vec<_>>()).mean;
            let conv = |o: &[astir::sim::SimOutcome]| o.iter().filter(|x| x.converged).count() as f64 / o.len() as f64;
            t.push_row(vec![c as f64, mean(&lit), conv(&lit), mean(&sx), conv(&sx)]);
        }
        println!("[A6 in {:.1?}]", t0.elapsed());
        report::emit("ablation_self_exclusion", "A6: literal Alg. 2 vs self-excluding tally reads", &t);
        report::note("self-exclusion makes c=1 degenerate exactly to Alg. 1, removing the small-c penalty.");
    }
}
