//! Ablations A1–A4 + A6 (`cargo bench --bench ablations [-- <name>]`),
//! via the `ablations` suite in `astir::bench_harness::suites`:
//!
//! * `tally_vs_shared_x`  — A1: the paper's central design choice
//! * `inconsistent_reads` — A2: stale tally reads (paper §III ¶3)
//! * `weighting`          — A3: +t/−(t−1) vs unit vs no-decrement
//! * `block_size`         — A4: StoIHT iterations vs b
//! * `self_exclusion`     — A6: reading φ minus one's own votes
//!   (reproduction finding, see the notes in README.md)
//!
//! With no filter argument, all ablations run.
//! Telemetry: `results/BENCH_ablations.json`.

mod common;

fn main() {
    common::bench_binary_main("ablations");
}
