//! EXPERIMENT — distributed sharded recovery over loopback
//! (`cargo bench --bench distributed`).
//!
//! Thin wrapper over the `distributed` suite in
//! `astir::bench_harness::suites`: each `(S, E)` cell of the staleness
//! grid (shards in {2, 4}, exchange period in {1, 16}) runs as a fleet
//! of `S` `astir shard-worker` processes exchanging vote snapshots
//! through an `astir exchange-hub` on loopback TCP, plus the in-process
//! `ShardedPool` at S = 4, E = 16 — the per-cell delta against that
//! reference is the socket-transport tax. Under this `cargo bench`
//! harness the CLI binary is not reachable, so cells fall back to an
//! in-process fleet over real loopback sockets unless `ASTIR_BIN`
//! points at an `astir` build.
//!
//! Telemetry: `results/BENCH_distributed_fleet.json`.

mod common;

fn main() {
    common::bench_binary_main("distributed");
}
