//! EXPERIMENT — sharded tally under bounded staleness
//! (`cargo bench --bench sharded`).
//!
//! Thin wrapper over the `sharded` suite in
//! `astir::bench_harness::suites`: time steps to converge over the
//! S x E grid (shards in {1, 2, 4, 8}, exchange period in
//! {1, 4, 16, 64}) under the unit-rate simulator, plus one real-thread
//! `ShardedPool` point at S = 4, E = 16. The S = 1 column is
//! bit-identical to the single-tally runtime by construction, so the
//! grid isolates what bounded-staleness exchange costs.
//!
//! Telemetry: `results/BENCH_sharded_staleness.json`.

mod common;

fn main() {
    common::bench_binary_main("sharded");
}
