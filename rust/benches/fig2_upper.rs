//! Regenerates **Fig. 2 (upper)** — time steps to exit vs cores, all cores
//! fast (`cargo bench --bench fig2_upper`), via the `fig2_upper` suite in
//! `astir::bench_harness::suites`.
//!
//! Paper shape to verify: async mean below the standard-StoIHT horizontal
//! line, improving with core count. Our faithful Alg.-2 reproduction finds
//! the crossover at c ≈ 4 (see the reproduction notes in README.md); the
//! self-exclusion variant (`ablations` bench) removes the small-c penalty.
//! Telemetry: `results/BENCH_fig2_upper.json`.

mod common;

fn main() {
    common::bench_binary_main("fig2_upper");
}
