//! Regenerates **Fig. 2 (upper)** — time steps to exit vs cores, all cores
//! fast (`cargo bench --bench fig2_upper`).
//!
//! Paper shape to verify: async mean below the standard-StoIHT horizontal
//! line, improving with core count. Our faithful Alg.-2 reproduction finds
//! the crossover at c ≈ 4 (see the reproduction notes in README.md); the
//! self-exclusion variant (`ablations` bench) removes the small-c penalty.

mod common;

use astir::experiments::{fig2, Fig2Variant};
use astir::report;

fn main() {
    let cfg = common::paper_cfg(30);
    common::banner("Fig. 2 upper — steps to exit vs cores (all fast)", &cfg);

    let t0 = std::time::Instant::now();
    let table = fig2(&cfg, Fig2Variant::Upper);
    println!("[fig2 upper computed in {:.1?}]", t0.elapsed());
    report::emit("fig2_upper", "Fig. 2 upper (async vs standard StoIHT)", &table);

    let std_mean = table.rows[0][4];
    println!("\nstandard StoIHT line: {std_mean:.0} steps");
    for row in &table.rows {
        let gain = std_mean / row[1];
        println!(
            "  c={:<3} async {:6.0} ± {:4.0}  ({:4.2}x vs standard, conv {:.0}%)",
            row[0],
            row[1],
            row[2],
            gain,
            100.0 * row[3]
        );
    }
}
