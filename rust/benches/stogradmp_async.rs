//! Asynchronous StoGradMP (`cargo bench --bench stogradmp_async`), via the
//! `stogradmp_async` suite in `astir::bench_harness::suites`.
//!
//! The paper's §V extension measured end-to-end: sequential StoGradMP
//! iterations-to-exit, a discrete-time steps-vs-cores sweep (the Fig.-2
//! semantics for the new kernel), and real-thread async wallclock per core
//! count at the paper's n = 1000 scale.
//! Telemetry: `results/BENCH_stogradmp_async.json`.

mod common;

fn main() {
    common::bench_binary_main("stogradmp_async");
}
