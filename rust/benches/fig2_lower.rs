//! Regenerates **Fig. 2 (lower)** — time steps to exit vs cores with half
//! the cores slow (one iteration per four steps)
//! (`cargo bench --bench fig2_lower`).
//!
//! Paper shape to verify: no improvement at c = 2 (one fast + one slow),
//! improvement for the larger core counts.

mod common;

use astir::experiments::{fig2, Fig2Variant};
use astir::report;

fn main() {
    let mut cfg = common::paper_cfg(30);
    // The paper's lower panel is about the slow-core regime; include c = 2
    // explicitly since the "no gain at 2" claim is the headline.
    if !cfg.cores.contains(&2) {
        cfg.cores.push(2);
        cfg.cores.sort_unstable();
    }
    common::banner("Fig. 2 lower — half the cores slow (period 4)", &cfg);

    let t0 = std::time::Instant::now();
    let table = fig2(&cfg, Fig2Variant::Lower { period: 4 });
    println!("[fig2 lower computed in {:.1?}]", t0.elapsed());
    report::emit("fig2_lower", "Fig. 2 lower (async vs standard StoIHT)", &table);

    let std_mean = table.rows[0][4];
    println!("\nstandard StoIHT line: {std_mean:.0} steps");
    for row in &table.rows {
        println!(
            "  c={:<3} async {:6.0} ± {:4.0}  ({:4.2}x vs standard, conv {:.0}%)",
            row[0],
            row[1],
            row[2],
            std_mean / row[1],
            100.0 * row[3]
        );
    }
    println!("\npaper claim: c=2 ⇒ no improvement; larger c ⇒ improvement.");
}
