//! Regenerates **Fig. 2 (lower)** — time steps to exit vs cores with half
//! the cores slow (one iteration per four steps)
//! (`cargo bench --bench fig2_lower`), via the `fig2_lower` suite in
//! `astir::bench_harness::suites`.
//!
//! Paper shape to verify: no improvement at c = 2 (one fast + one slow),
//! improvement for the larger core counts.
//! Telemetry: `results/BENCH_fig2_lower.json`.

mod common;

fn main() {
    common::bench_binary_main("fig2_lower");
}
