//! PERF — hot-path microbenches (`cargo bench --bench hot_path`).
//!
//! Measures the per-iteration cost centers of the whole stack and reports
//! achieved memory bandwidth against a STREAM-like roofline measured in
//! the same process:
//!
//! * native proxy step (the Layer-1 twin): b=15, n=1000 fused kernel
//! * gemv / gemv_t primitives
//! * top-s quickselect and tally ops (vote + estimate)
//! * full StoIHT iteration (proxy + identify + estimate + sparse exit check)
//! * PJRT stoiht_step executable (artifact path), when artifacts exist
//! * atomic tally contention: 8 threads hammering commit()

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use astir::backend::{Backend, PjrtBackend};
use astir::bench_harness::{bench_header, human_time, quick_bench};
use astir::linalg::{dot, Mat};
use astir::problem::ProblemSpec;
use astir::rng::Rng;
use astir::support::{top_s_into, union};
use astir::tally::{AtomicTally, TallyWeighting};

fn main() {
    let spec = ProblemSpec::paper();
    let mut rng = Rng::seed_from(1);
    let p = spec.generate(&mut rng);
    let x: Vec<f64> = (0..spec.n).map(|_| rng.gauss() * 0.1).collect();

    bench_header("memory roofline (in-process STREAM-like)");
    // Triad a[i] = b[i] + s*c[i] over 8 MB working set.
    let nn = 1 << 20;
    let bsrc: Vec<f64> = (0..nn).map(|i| i as f64).collect();
    let csrc: Vec<f64> = (0..nn).map(|i| (i * 7) as f64).collect();
    let mut asink = vec![0.0f64; nn];
    let triad = quick_bench("triad 1M f64 (24 MB traffic)", || {
        for i in 0..nn {
            asink[i] = bsrc[i] + 0.5 * csrc[i];
        }
        std::hint::black_box(&asink);
    });
    let bw = 24e6 / triad.time.mean / 1e9; // GB/s (3 streams x 8 B x 1M)
    println!("  => sustainable bandwidth ≈ {bw:.1} GB/s");

    bench_header("linalg primitives (paper shape)");
    let blk_rows = spec.b;
    let a_blk = Mat::<f64>::from_fn(blk_rows, spec.n, |i, j| ((i * spec.n + j) as f64 * 0.37).sin());
    let yv: Vec<f64> = (0..blk_rows).map(|i| i as f64 * 0.1).collect();
    let mut scratch = vec![0.0; blk_rows];
    let mut out = vec![0.0; spec.n];
    quick_bench("dot n=1000", || {
        std::hint::black_box(dot(&x, &out));
    });
    quick_bench("gemv 15x1000", || {
        a_blk.as_block().gemv_into(&x, &mut scratch);
        std::hint::black_box(&scratch);
    });
    let proxy = quick_bench("proxy_step 15x1000 fused", || {
        a_blk.as_block().proxy_step_into(&yv, &x, 1.0, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    // Proxy traffic: A streamed twice (2 * 15k * 8 B) + vectors.
    let traffic = (2 * blk_rows * spec.n + 4 * spec.n + 2 * blk_rows) as f64 * 8.0;
    println!(
        "  => proxy streams {:.0} KB/iter at {:.1} GB/s ({:.0}% of triad roofline)",
        traffic / 1e3,
        traffic / proxy.time.mean / 1e9,
        100.0 * (traffic / proxy.time.mean / 1e9) / bw
    );

    bench_header("support + tally ops");
    let v: Vec<f64> = (0..spec.n).map(|i| ((i * 31 % 97) as f64) - 48.0).collect();
    let mut idx_scratch = Vec::new();
    let mut sel = vec![0usize; spec.s];
    quick_bench("top_s quickselect n=1000 s=20", || {
        top_s_into(&v, spec.s, &mut idx_scratch, &mut sel);
        std::hint::black_box(&sel);
    });
    let tally = AtomicTally::new(spec.n, TallyWeighting::Progress);
    let gamma: Vec<usize> = (0..spec.s).map(|k| k * 37 % spec.n).collect();
    let mut sorted_gamma = gamma.clone();
    sorted_gamma.sort_unstable();
    quick_bench("tally commit (2s atomic RMWs)", || {
        tally.commit(&sorted_gamma, &sorted_gamma, 7);
    });
    let mut tally_scratch = Vec::new();
    quick_bench("tally estimate (snapshot + top-s)", || {
        std::hint::black_box(tally.estimate(spec.s, &mut tally_scratch));
    });

    bench_header("full StoIHT iteration (native)");
    let mut kernel = astir::algorithms::StoihtKernel::new(&p, 1.0);
    let mut xi = vec![0.0f64; spec.n];
    let mut block_rng = Rng::seed_from(3);
    let est: Vec<usize> = (0..spec.s).map(|k| k * 17 % spec.n).collect();
    let mut est_sorted = est.clone();
    est_sorted.sort_unstable();
    est_sorted.dedup();
    quick_bench("kernel.step + sparse exit check", || {
        let b = kernel.sample_block(&mut block_rng);
        let gamma = kernel.step(&mut xi, b, Some(&est_sorted)).to_vec();
        let supp = union(&gamma, &est_sorted);
        std::hint::black_box(p.residual_norm_sparse(&xi, &supp));
    });
    quick_bench("dense residual check (m x n gemv)", || {
        std::hint::black_box(p.residual_norm(&xi));
    });

    bench_header("atomic tally under contention (8 threads)");
    let shared = Arc::new(AtomicTally::new(spec.n, TallyWeighting::Progress));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for w in 0..7 {
        let shared = Arc::clone(&shared);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut r = Rng::seed_from(w);
            let mut prev: Vec<usize> = Vec::new();
            let mut t = 1u64;
            while !stop.load(Ordering::Relaxed) {
                let mut g = r.subset(1000, 20);
                g.sort_unstable();
                shared.commit(&g, &prev, t);
                prev = g;
                t += 1;
            }
        }));
    }
    let res = quick_bench("tally commit w/ 7 writer threads", || {
        shared.commit(&sorted_gamma, &sorted_gamma, 9);
    });
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
    println!("  => contended commit {}", human_time(res.time.mean));

    bench_header("PJRT artifact path (needs `make artifacts`)");
    match PjrtBackend::from_default_dir() {
        Ok(mut be) => {
            let tiny = ProblemSpec::tiny().generate(&mut Rng::seed_from(2));
            let xt = vec![0.0f64; tiny.spec.n];
            let mask = vec![0.0f64; tiny.spec.n];
            // warm the executable cache outside the timer
            let _ = be.stoiht_step(&tiny, 0, &xt, 1.0, &mask).unwrap();
            let r = astir::bench_harness::bench(
                "pjrt stoiht_step n=32 b=4 (marshal+execute)",
                Duration::from_millis(200),
                Duration::from_secs(1),
                || {
                    std::hint::black_box(be.stoiht_step(&tiny, 0, &xt, 1.0, &mask).unwrap());
                },
            );
            println!("{}", r.summary());
            let paper = spec.generate(&mut Rng::seed_from(3));
            let xp = vec![0.0f64; spec.n];
            let maskp = vec![0.0f64; spec.n];
            let _ = be.stoiht_step(&paper, 0, &xp, 1.0, &maskp).unwrap();
            let r = astir::bench_harness::bench(
                "pjrt stoiht_step n=1000 b=15 (marshal+execute)",
                Duration::from_millis(200),
                Duration::from_secs(1),
                || {
                    std::hint::black_box(be.stoiht_step(&paper, 0, &xp, 1.0, &maskp).unwrap());
                },
            );
            println!("{}", r.summary());
        }
        Err(e) => println!("skipped: {e}"),
    }
}
