//! PERF — hot-path microbenches (`cargo bench --bench hot_path`).
//!
//! Measures the per-iteration cost centers of the whole stack and reports
//! achieved memory bandwidth against a STREAM-like roofline measured in
//! the same process:
//!
//! * native proxy step (the Layer-1 twin): b=15, n=1000 fused kernel
//! * gemv / gemv_t primitives
//! * top-s quickselect and tally ops (vote + estimate)
//! * full StoIHT iteration (proxy + identify + estimate + sparse exit check)
//! * **dense vs sparse step** at the paper scale and at stress scales
//!   (n = 10^4 and 10^5 with s = 20–50) — the `s ≪ n` regime the paper
//!   targets; prints the measured speedup of the sparse fast path
//! * PJRT stoiht_step executable (artifact path), when artifacts exist
//! * atomic tally contention: 8 threads hammering commit()
//!
//! Set `ASTIR_BENCH_SKIP_JUMBO=1` to skip the n = 10^5 point (its matrix
//! plus transpose needs ~200 MB).

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use astir::algorithms::StoihtKernel;
use astir::backend::{Backend, PjrtBackend};
use astir::bench_harness::{bench_header, human_time, quick_bench};
use astir::linalg::{dot, Mat, SparseIterate};
use astir::problem::{Problem, ProblemSpec};
use astir::rng::Rng;
use astir::support::{top_s_into, union};
use astir::tally::{AtomicTally, TallyWeighting};

/// Dense-vs-sparse comparison at one problem scale: the fused proxy kernel
/// alone, then the full Alg.-2 step (proxy + identify + estimate).
fn sparse_vs_dense_at(label: &str, spec: &ProblemSpec, seed: u64) {
    bench_header(&format!(
        "sparse fast path — {label} (n={} b={} s={})",
        spec.n, spec.b, spec.s
    ));
    let mut rng = Rng::seed_from(seed);
    let p: Problem = spec.generate(&mut rng);

    // A representative 2s-support iterate (Γ ∪ T̃) and tally estimate.
    let est: Vec<usize> = {
        let mut e = rng.subset(spec.n, spec.s);
        e.sort_unstable();
        e
    };
    let mut warm = StoihtKernel::new(&p, 1.0);
    let mut x_sparse = SparseIterate::zeros(spec.n);
    for _ in 0..5 {
        let b = warm.sample_block(&mut rng);
        warm.step_sparse(&mut x_sparse, b, Some(&est));
    }
    let x_dense: Vec<f64> = x_sparse.to_dense();

    // --- fused proxy kernel alone -----------------------------------
    let (blk, yb) = p.block(0);
    let mut scratch = vec![0.0; spec.b];
    let mut out = vec![0.0; spec.n];
    let dense_proxy = quick_bench("proxy_step_into (dense residual pass)", || {
        blk.proxy_step_into(yb, &x_dense, 1.0, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    let supp = x_sparse.support().to_vec();
    let sparse_proxy = quick_bench("proxy_step_sparse_into (gathered)", || {
        blk.proxy_step_sparse_into(&p.a_t, 0, yb, x_sparse.values(), &supp, 1.0, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    println!(
        "  => proxy kernel speedup: {:.2}x (|supp| = {})",
        dense_proxy.time.mean / sparse_proxy.time.mean,
        supp.len()
    );

    // --- full Alg.-2 step (proxy + identify + estimate) -------------
    let mut kd = StoihtKernel::new(&p, 1.0);
    let mut xd = x_dense.clone();
    let mut rng_d = Rng::seed_from(seed ^ 0xBEEF);
    let dense_step = quick_bench("full step, dense iterate", || {
        let b = kd.sample_block(&mut rng_d);
        std::hint::black_box(kd.step(&mut xd, b, Some(&est)));
    });
    let mut ks = StoihtKernel::new(&p, 1.0);
    let mut xs = x_sparse.clone();
    let mut rng_s = Rng::seed_from(seed ^ 0xBEEF);
    let sparse_step = quick_bench("full step, sparse iterate", || {
        let b = ks.sample_block(&mut rng_s);
        std::hint::black_box(ks.step_sparse(&mut xs, b, Some(&est)));
    });
    println!(
        "  => full-step speedup: {:.2}x ({} vs {} per iter)",
        dense_step.time.mean / sparse_step.time.mean,
        human_time(dense_step.time.mean),
        human_time(sparse_step.time.mean)
    );
}

fn main() {
    let spec = ProblemSpec::paper();
    let mut rng = Rng::seed_from(1);
    let p = spec.generate(&mut rng);
    let x: Vec<f64> = (0..spec.n).map(|_| rng.gauss() * 0.1).collect();

    bench_header("memory roofline (in-process STREAM-like)");
    // Triad a[i] = b[i] + s*c[i] over 8 MB working set.
    let nn = 1 << 20;
    let bsrc: Vec<f64> = (0..nn).map(|i| i as f64).collect();
    let csrc: Vec<f64> = (0..nn).map(|i| (i * 7) as f64).collect();
    let mut asink = vec![0.0f64; nn];
    let triad = quick_bench("triad 1M f64 (24 MB traffic)", || {
        for i in 0..nn {
            asink[i] = bsrc[i] + 0.5 * csrc[i];
        }
        std::hint::black_box(&asink);
    });
    let bw = 24e6 / triad.time.mean / 1e9; // GB/s (3 streams x 8 B x 1M)
    println!("  => sustainable bandwidth ≈ {bw:.1} GB/s");

    bench_header("linalg primitives (paper shape)");
    let blk_rows = spec.b;
    let a_blk = Mat::<f64>::from_fn(blk_rows, spec.n, |i, j| ((i * spec.n + j) as f64 * 0.37).sin());
    let yv: Vec<f64> = (0..blk_rows).map(|i| i as f64 * 0.1).collect();
    let mut scratch = vec![0.0; blk_rows];
    let mut out = vec![0.0; spec.n];
    quick_bench("dot n=1000", || {
        std::hint::black_box(dot(&x, &out));
    });
    quick_bench("gemv 15x1000", || {
        a_blk.as_block().gemv_into(&x, &mut scratch);
        std::hint::black_box(&scratch);
    });
    let proxy = quick_bench("proxy_step 15x1000 fused", || {
        a_blk.as_block().proxy_step_into(&yv, &x, 1.0, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    // Proxy traffic: A streamed twice (2 * 15k * 8 B) + vectors.
    let traffic = (2 * blk_rows * spec.n + 4 * spec.n + 2 * blk_rows) as f64 * 8.0;
    println!(
        "  => proxy streams {:.0} KB/iter at {:.1} GB/s ({:.0}% of triad roofline)",
        traffic / 1e3,
        traffic / proxy.time.mean / 1e9,
        100.0 * (traffic / proxy.time.mean / 1e9) / bw
    );

    bench_header("support + tally ops");
    let v: Vec<f64> = (0..spec.n).map(|i| ((i * 31 % 97) as f64) - 48.0).collect();
    let mut idx_scratch = Vec::new();
    let mut sel = vec![0usize; spec.s];
    quick_bench("top_s quickselect n=1000 s=20", || {
        top_s_into(&v, spec.s, &mut idx_scratch, &mut sel);
        std::hint::black_box(&sel);
    });
    let tally = AtomicTally::new(spec.n, TallyWeighting::Progress);
    let gamma: Vec<usize> = (0..spec.s).map(|k| k * 37 % spec.n).collect();
    let mut sorted_gamma = gamma.clone();
    sorted_gamma.sort_unstable();
    quick_bench("tally commit (2s atomic RMWs)", || {
        tally.commit(&sorted_gamma, &sorted_gamma, 7);
    });
    let mut tally_scratch = Vec::new();
    quick_bench("tally estimate (snapshot + top-s)", || {
        std::hint::black_box(tally.estimate(spec.s, &mut tally_scratch));
    });

    bench_header("full StoIHT iteration (native)");
    let mut kernel = astir::algorithms::StoihtKernel::new(&p, 1.0);
    let mut xi = vec![0.0f64; spec.n];
    let mut block_rng = Rng::seed_from(3);
    let est: Vec<usize> = (0..spec.s).map(|k| k * 17 % spec.n).collect();
    let mut est_sorted = est.clone();
    est_sorted.sort_unstable();
    est_sorted.dedup();
    quick_bench("kernel.step + sparse exit check", || {
        let b = kernel.sample_block(&mut block_rng);
        let gamma = kernel.step(&mut xi, b, Some(&est_sorted)).to_vec();
        let supp = union(&gamma, &est_sorted);
        std::hint::black_box(p.residual_norm_sparse(&xi, &supp));
    });
    quick_bench("dense residual check (m x n gemv)", || {
        std::hint::black_box(p.residual_norm(&xi));
    });

    // Dense-vs-sparse step at the paper scale and in the s ≪ n stress
    // regime the paper targets (and where a production service would
    // live). The equivalence suite (rust/tests/sparse_equivalence.rs)
    // proves the two paths produce bit-identical iterates; this measures
    // what the sparsity buys.
    sparse_vs_dense_at("paper scale", &ProblemSpec::paper(), 11);
    sparse_vs_dense_at(
        "stress scale",
        &ProblemSpec { n: 10_000, m: 300, b: 15, s: 20, ..ProblemSpec::paper() },
        12,
    );
    if std::env::var_os("ASTIR_BENCH_SKIP_JUMBO").is_none() {
        sparse_vs_dense_at(
            "jumbo scale",
            &ProblemSpec { n: 100_000, m: 120, b: 15, s: 50, ..ProblemSpec::paper() },
            13,
        );
    }

    bench_header("atomic tally under contention (8 threads)");
    let shared = Arc::new(AtomicTally::new(spec.n, TallyWeighting::Progress));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for w in 0..7 {
        let shared = Arc::clone(&shared);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut r = Rng::seed_from(w);
            let mut prev: Vec<usize> = Vec::new();
            let mut t = 1u64;
            while !stop.load(Ordering::Relaxed) {
                let mut g = r.subset(1000, 20);
                g.sort_unstable();
                shared.commit(&g, &prev, t);
                prev = g;
                t += 1;
            }
        }));
    }
    let res = quick_bench("tally commit w/ 7 writer threads", || {
        shared.commit(&sorted_gamma, &sorted_gamma, 9);
    });
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
    println!("  => contended commit {}", human_time(res.time.mean));

    bench_header("PJRT artifact path (needs `make artifacts`)");
    match PjrtBackend::from_default_dir() {
        Ok(mut be) => {
            let tiny = ProblemSpec::tiny().generate(&mut Rng::seed_from(2));
            let xt = vec![0.0f64; tiny.spec.n];
            let mask = vec![0.0f64; tiny.spec.n];
            // warm the executable cache outside the timer
            let _ = be.stoiht_step(&tiny, 0, &xt, 1.0, &mask).unwrap();
            let r = astir::bench_harness::bench(
                "pjrt stoiht_step n=32 b=4 (marshal+execute)",
                Duration::from_millis(200),
                Duration::from_secs(1),
                || {
                    std::hint::black_box(be.stoiht_step(&tiny, 0, &xt, 1.0, &mask).unwrap());
                },
            );
            println!("{}", r.summary());
            let paper = spec.generate(&mut Rng::seed_from(3));
            let xp = vec![0.0f64; spec.n];
            let maskp = vec![0.0f64; spec.n];
            let _ = be.stoiht_step(&paper, 0, &xp, 1.0, &maskp).unwrap();
            let r = astir::bench_harness::bench(
                "pjrt stoiht_step n=1000 b=15 (marshal+execute)",
                Duration::from_millis(200),
                Duration::from_secs(1),
                || {
                    std::hint::black_box(be.stoiht_step(&paper, 0, &xp, 1.0, &maskp).unwrap());
                },
            );
            println!("{}", r.summary());
        }
        Err(e) => println!("skipped: {e}"),
    }
}
