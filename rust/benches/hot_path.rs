//! PERF — hot-path microbenches (`cargo bench --bench hot_path`).
//!
//! Thin wrapper over the `hot_path` suite in
//! `astir::bench_harness::suites`: per-iteration cost centers of the whole
//! stack against a STREAM-like roofline measured in the same process —
//! gemv / fused proxy primitives, top-s quickselect, tally ops (incl. an
//! 8-thread contended commit), full Alg.-2 steps, **dense vs sparse** at
//! the paper scale and at stress scales (n = 10^4 and 10^5), and the PJRT
//! stoiht_step executable when artifacts exist.
//!
//! Set `ASTIR_BENCH_SKIP_JUMBO=1` to skip the n = 10^5 point (its matrix
//! plus transpose needs ~200 MB). Telemetry: `results/BENCH_hot_path.json`.

mod common;

fn main() {
    common::bench_binary_main("hot_path");
}
