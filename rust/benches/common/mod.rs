//! Shared plumbing for the `harness = false` bench binaries.
//!
//! Every figure bench accepts its Monte-Carlo budget from the environment
//! so `cargo bench` stays tractable by default while the paper-fidelity
//! run is one env var away:
//!
//! ```text
//! cargo bench                              # quick: ASTIR defaults below
//! ASTIR_BENCH_TRIALS=500 cargo bench       # the paper's 500 trials
//! ```

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use astir::config::ExperimentConfig;

/// Trial budget: `$ASTIR_BENCH_TRIALS` (default `default_trials`).
pub fn bench_trials(default_trials: usize) -> usize {
    std::env::var("ASTIR_BENCH_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_trials)
}

/// The paper's experiment configuration with the bench trial budget.
pub fn paper_cfg(default_trials: usize) -> ExperimentConfig {
    ExperimentConfig { trials: bench_trials(default_trials), ..Default::default() }
}

/// Standard bench banner.
pub fn banner(what: &str, cfg: &ExperimentConfig) {
    println!("\n################################################################");
    println!("# {what}");
    println!(
        "# n={} m={} b={} s={} gamma={} tol={:.0e} trials={} threads={}",
        cfg.problem.n,
        cfg.problem.m,
        cfg.problem.b,
        cfg.problem.s,
        cfg.gamma,
        cfg.tolerance,
        cfg.trials,
        cfg.trial_threads
    );
    println!("# (set ASTIR_BENCH_TRIALS=500 for the paper's full budget)");
    println!("################################################################");
}
