//! Shared entry point for the `harness = false` bench binaries.
//!
//! Every binary is one named suite from the in-crate registry
//! (`astir::bench_harness::suites`) — `astir bench` runs the same
//! definitions, so a perf number means the same thing however produced.
//! Each full run also writes its suite's telemetry (schema
//! `astir-bench-v1`) to `results/BENCH_<suite>.json`; smoke runs write
//! `smoke_BENCH_<suite>.json`, and filtered runs write only with an
//! explicit `--json` — recorded full-budget baselines are never
//! clobbered by partial data.
//!
//! Arguments (after `--` with `cargo bench`):
//!
//! ```text
//! cargo bench --bench hot_path                     # full budgets
//! cargo bench --bench hot_path -- --smoke          # CI-sized budgets
//! cargo bench --bench ablations -- block_size      # bare word = filter
//! cargo bench --bench fig1 -- --json out.json      # telemetry elsewhere
//! ASTIR_BENCH_TRIALS=500 cargo bench --bench fig2_upper   # paper budget
//! ASTIR_BENCH_SKIP_JUMBO=1 cargo bench --bench hot_path   # skip n=10^5
//! ```
//!
//! Unknown `-*` flags are ignored (cargo may pass harness flags through).

use std::path::PathBuf;

use astir::bench_harness::json::write_report;
use astir::bench_harness::{suites, Mode, RunOpts};

pub fn bench_binary_main(suite_name: &str) {
    let mut filter: Option<String> = None;
    let mut mode = Mode::Full;
    let mut json: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    // A flag's value must not itself look like a flag — otherwise
    // `-- --json --smoke` would eat the smoke switch as a path.
    fn value_for(flag: &str, args: &mut dyn Iterator<Item = String>) -> String {
        match args.next() {
            Some(v) if !v.starts_with('-') => v,
            _ => {
                eprintln!("error: {flag} needs a value");
                std::process::exit(2);
            }
        }
    }
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => mode = Mode::Smoke,
            "--json" => json = Some(PathBuf::from(value_for("--json", &mut args))),
            "--filter" => filter = Some(value_for("--filter", &mut args)),
            s if !s.starts_with('-') => filter = Some(s.to_string()),
            _ => {}
        }
    }
    let mut opts = RunOpts::from_env(mode);
    // A bare filter word from `cargo bench -- <word>` is scoped to this
    // suite; an explicit `--filter` with a `/` is taken verbatim.
    opts.filter = filter.map(|f| if f.contains('/') { f } else { format!("{suite_name}/{f}") });

    let def = suites::find(suite_name).expect("bench binary names a registered suite");
    let report = suites::run_one(&def, &opts);

    // Default telemetry paths are mode-distinct (a smoke run must not
    // clobber a recorded full-budget baseline), and a filtered run is
    // partial telemetry — written only when a path is asked for.
    let path = if let Some(p) = json {
        p
    } else if opts.filter.is_some() {
        println!("\n[filtered run: telemetry not written; pass --json <path> to keep it]");
        return;
    } else {
        let stem = match mode {
            Mode::Full => format!("BENCH_{suite_name}.json"),
            Mode::Smoke => format!("smoke_BENCH_{suite_name}.json"),
        };
        astir::report::results_dir().join(stem)
    };
    match write_report(&report, &path) {
        Ok(()) => println!("\n[bench telemetry written {}]", path.display()),
        Err(e) => eprintln!("\n[warn] could not write {}: {e}", path.display()),
    }
}
