//! Regenerates **Fig. 1** — StoIHT vs oracle-support StoIHT
//! (`cargo bench --bench fig1`).
//!
//! Paper shape to verify: α > 0.5 converges in fewer iterations than
//! standard; α = 1 in roughly **half**; α = 0 no faster than standard.

mod common;

use astir::experiments::fig1::{fig1, iters_to_threshold};
use astir::report;

fn main() {
    let cfg = common::paper_cfg(25); // paper budget: ASTIR_BENCH_TRIALS=50
    common::banner("Fig. 1 — mean recovery error vs iteration", &cfg);

    let t0 = std::time::Instant::now();
    let out = fig1(&cfg);
    let table = out.series;
    println!("[fig1 computed in {:.1?}]", t0.elapsed());

    // Thin for the terminal; full series to CSV.
    let mut thin = astir::metrics::Table::new(
        &table.columns.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (i, row) in table.rows.iter().enumerate() {
        if i % 100 == 0 || i + 1 == table.rows.len() {
            thin.push_row(row.clone());
        }
    }
    report::emit("fig1", "Fig. 1 (every 100th iteration)", &thin);
    report::emit("fig1_full", "Fig. 1 full series", &table);
    report::emit(
        "fig1_summary",
        "Fig. 1 per-variant convergence (variant 0=stoiht, 1..=alpha 0,.25,.5,.75,1)",
        &out.summary,
    );

    // Quantified paper claims at the 1e-5 error level.
    let thr = 1e-5;
    let std_it = iters_to_threshold(&table, 1, thr);
    println!("\niterations to mean error < {thr:.0e}:");
    let labels = ["stoiht", "alpha=0", "alpha=.25", "alpha=.5", "alpha=.75", "alpha=1"];
    for (k, label) in labels.iter().enumerate() {
        match iters_to_threshold(&table, k + 1, thr) {
            Some(it) => println!("  {label:>9}: {it}"),
            None => println!("  {label:>9}: (not reached)"),
        }
    }
    if let (Some(s), Some(a1)) = (std_it, iters_to_threshold(&table, 6, thr)) {
        println!(
            "\npaper claim `alpha=1 needs ~half the iterations`: ratio = {:.2}",
            a1 as f64 / s as f64
        );
    }
}
