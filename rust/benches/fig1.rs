//! Regenerates **Fig. 1** — StoIHT vs oracle-support StoIHT
//! (`cargo bench --bench fig1`), via the `fig1` suite in
//! `astir::bench_harness::suites`.
//!
//! Paper shape to verify: α > 0.5 converges in fewer iterations than
//! standard; α = 1 in roughly **half**; α = 0 no faster than standard.
//! Telemetry: `results/BENCH_fig1.json`; tables: `results/fig1*.csv/json`.

mod common;

fn main() {
    common::bench_binary_main("fig1");
}
