//! PERF — `astir serve` load-generator benches
//! (`cargo bench --bench loadgen`).
//!
//! Thin wrapper over the `loadgen` suite in
//! `astir::bench_harness::suites`: an in-process server on a loopback
//! ephemeral port is driven by open-loop Poisson arrivals (precomputed
//! exponential inter-arrivals, so the offered load never adapts to
//! server backpressure) at two rates. Each rate records the window wall
//! time plus the server's own p50/p99 request latency, and asserts the
//! operator cache serves the tail warm (hit ratio >= 0.5). Single-pass
//! experiment budgets; everything runs in CI smoke under the committed
//! `baseline_smoke.json` regression gate.
//!
//! Telemetry: `results/BENCH_loadgen.json`.

mod common;

fn main() {
    common::bench_binary_main("loadgen");
}
