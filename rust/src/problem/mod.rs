//! Compressed-sensing problem generation.
//!
//! Builds the `y = A x + z` instances of the paper's §IV: `A ∈ R^{m x n}`
//! from a configurable ensemble, `x` exactly `s`-sparse from a configurable
//! coefficient model, optional Gaussian noise, and the block partition
//! `M = m / b` that StoIHT samples from.
//!
//! The measurement ensemble is held as a [`crate::linalg::Operator`] — by
//! default a materialized matrix plus its transposed copy ([`DenseOp`]:
//! the sparse proxy kernel and the asynchronous runtimes' exit check walk
//! *columns* of `A`, which the transpose makes contiguous), and for the
//! `partial_dct` ensemble optionally the **matrix-free**
//! [`crate::linalg::SubsampledDctOp`] (`dense_a = false`), which stores
//! only the `m` sampled row indices and evaluates every operator action
//! through an O(n log n) fast transform. That is the `n = 10^6` path: at
//! the `large_n` bench shape the dense pair would need terabytes.
//!
//! The paper does not state its matrix normalization; the default here is
//! i.i.d. `N(0, 1/m)` entries (columns have unit expected norm), the
//! standard choice under which `gamma = 1` StoIHT converges as in Fig. 1.
//! Alternatives are exposed for ablations.

use crate::sync::Arc;

use crate::linalg::{nrm2, DenseOp, Mat, MeasureOp, OpScratch, Operator, RowBlock, SubsampledDctOp};
use crate::rng::Rng;

/// Measurement-matrix ensembles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ensemble {
    /// i.i.d. `N(0, 1/m)` entries (default; unit expected column norm).
    Gaussian,
    /// i.i.d. `N(0, 1)` entries (unnormalized — for ablations).
    GaussianUnnormalized,
    /// i.i.d. `±1/√m` (Rademacher / Bernoulli ensemble).
    Bernoulli,
    /// `m` distinct rows of the `n x n` DCT-II matrix, chosen uniformly,
    /// scaled by `√(n/m)` so columns have unit norm in expectation —
    /// a deterministic-row structured ensemble (subsampled DCT). The only
    /// ensemble with a matrix-free operator form (`dense_a = false`).
    PartialDct,
}

impl Ensemble {
    /// Parse from a config string.
    pub fn parse(s: &str) -> Option<Ensemble> {
        match s {
            "gaussian" => Some(Ensemble::Gaussian),
            "gaussian_unnormalized" => Some(Ensemble::GaussianUnnormalized),
            "bernoulli" => Some(Ensemble::Bernoulli),
            "partial_dct" => Some(Ensemble::PartialDct),
            _ => None,
        }
    }

    /// The config/wire string [`Ensemble::parse`] inverts.
    pub fn as_str(self) -> &'static str {
        match self {
            Ensemble::Gaussian => "gaussian",
            Ensemble::GaussianUnnormalized => "gaussian_unnormalized",
            Ensemble::Bernoulli => "bernoulli",
            Ensemble::PartialDct => "partial_dct",
        }
    }
}

/// Distribution of the `s` nonzero signal coefficients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SignalModel {
    /// i.i.d. standard normal nonzeros (default).
    GaussianSpikes,
    /// `±1` nonzeros (hardest case for support identification).
    FlatSpikes,
    /// Linearly decaying magnitudes `1, (s-1)/s, ..., 1/s` with random signs.
    LinearDecay,
}

impl SignalModel {
    pub fn parse(s: &str) -> Option<SignalModel> {
        match s {
            "gaussian" => Some(SignalModel::GaussianSpikes),
            "flat" => Some(SignalModel::FlatSpikes),
            "linear_decay" => Some(SignalModel::LinearDecay),
            _ => None,
        }
    }
}

/// Full specification of a problem instance distribution.
#[derive(Clone, Debug)]
pub struct ProblemSpec {
    /// Signal dimension `n`.
    pub n: usize,
    /// Number of measurements `m`.
    pub m: usize,
    /// Block size `b` (must divide `m`).
    pub b: usize,
    /// Sparsity level `s`.
    pub s: usize,
    /// Matrix ensemble.
    pub ensemble: Ensemble,
    /// Signal coefficient model.
    pub signal: SignalModel,
    /// Standard deviation of additive measurement noise `z`.
    pub noise_std: f64,
    /// Materialize the `m x n` matrix (and its transpose)? `true` (default)
    /// gives the bit-exact dense path; `false` — `partial_dct` with a
    /// power-of-two `n` only — builds the matrix-free
    /// [`crate::linalg::SubsampledDctOp`] instead, unlocking problem sizes
    /// the dense representation cannot hold.
    pub dense_a: bool,
}

impl ProblemSpec {
    /// The paper's §IV configuration: n=1000, m=300, b=15, s=20, noiseless.
    pub fn paper() -> Self {
        ProblemSpec {
            n: 1000,
            m: 300,
            b: 15,
            s: 20,
            ensemble: Ensemble::Gaussian,
            signal: SignalModel::GaussianSpikes,
            noise_std: 0.0,
            dense_a: true,
        }
    }

    /// A small configuration for fast tests (matches the test artifacts).
    pub fn tiny() -> Self {
        ProblemSpec {
            n: 32,
            m: 16,
            b: 4,
            s: 3,
            ensemble: Ensemble::Gaussian,
            signal: SignalModel::GaussianSpikes,
            noise_std: 0.0,
            dense_a: true,
        }
    }

    /// A small **matrix-free** configuration (subsampled DCT, power-of-two
    /// `n`) — the canonical fixture the operator-path tests share.
    pub fn tiny_matrix_free() -> Self {
        ProblemSpec {
            n: 256,
            m: 128,
            b: 8,
            s: 4,
            ensemble: Ensemble::PartialDct,
            dense_a: false,
            ..ProblemSpec::tiny()
        }
    }

    /// Number of measurement blocks `M = m / b`.
    pub fn num_blocks(&self) -> usize {
        self.m / self.b
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 || self.m == 0 || self.b == 0 || self.s == 0 {
            return Err("n, m, b, s must all be positive".into());
        }
        if self.m % self.b != 0 {
            return Err(format!("block size b={} must divide m={}", self.b, self.m));
        }
        if self.s > self.n {
            return Err(format!("sparsity s={} exceeds dimension n={}", self.s, self.n));
        }
        if self.ensemble == Ensemble::PartialDct && self.m > self.n {
            return Err("partial DCT requires m <= n (distinct rows)".into());
        }
        if self.noise_std < 0.0 {
            return Err("noise_std must be nonnegative".into());
        }
        if !self.dense_a {
            if self.ensemble != Ensemble::PartialDct {
                return Err(
                    "dense_a = false (matrix-free) is only available for the partial_dct ensemble"
                        .into(),
                );
            }
            if !self.n.is_power_of_two() {
                return Err(format!(
                    "dense_a = false requires a power-of-two n (radix-2 fast transform), got n={}",
                    self.n
                ));
            }
        }
        Ok(())
    }

    /// Draw a problem instance.
    pub fn generate(&self, rng: &mut Rng) -> Problem {
        let op = self.draw_operator(rng);
        self.generate_with_op(&op, rng)
    }

    /// Draw only the measurement operator (shared-`Arc` form) — the
    /// expensive, **shareable** part of problem setup. The recovery service
    /// draws one operator and serves many signals against it
    /// ([`ProblemSpec::generate_with_op`] /
    /// [`ProblemSpec::generate_mmv_with_op`]) without re-materializing the
    /// matrix or re-planning the transform per job.
    pub fn draw_operator(&self, rng: &mut Rng) -> Arc<Operator> {
        self.validate().expect("invalid ProblemSpec");
        Arc::new(self.gen_operator(rng))
    }

    /// Draw one signal + measurements against an existing operator (shared
    /// by reference count, never copied). `generate` is exactly
    /// `draw_operator` followed by this, so the combined RNG stream is
    /// unchanged.
    pub fn generate_with_op(&self, op: &Arc<Operator>, rng: &mut Rng) -> Problem {
        self.validate().expect("invalid ProblemSpec");
        assert_eq!(op.rows(), self.m, "operator rows != spec.m");
        assert_eq!(op.cols(), self.n, "operator cols != spec.n");
        let (x_true, supp) = self.gen_signal(rng);
        let y = self.measure(op, &x_true, rng);
        Problem { spec: self.clone(), op: Arc::clone(op), x_true, support: supp, y }
    }

    /// Draw `batch` MMV-style signals sharing one operator **and one
    /// support** (the classic multiple-measurement-vector model): the
    /// support is drawn once, then all per-signal coefficients, then all
    /// per-signal noise. Measurement is ONE multi-RHS panel apply
    /// ([`MeasureOp::apply_multi_into`] — per column bit-identical to the
    /// single apply), so the whole batch shares one operator workspace.
    /// The batched recovery path exploits the shared support through the
    /// shared tally (every signal's votes sharpen every other's estimate).
    pub fn generate_mmv_with_op(
        &self,
        op: &Arc<Operator>,
        rng: &mut Rng,
        batch: usize,
    ) -> Vec<Problem> {
        self.validate().expect("invalid ProblemSpec");
        assert!(batch >= 1, "batch must be positive");
        assert_eq!(op.rows(), self.m, "operator rows != spec.m");
        assert_eq!(op.cols(), self.n, "operator cols != spec.n");
        let mut supp = rng.subset(self.n, self.s);
        supp.sort_unstable();
        let xs: Vec<Vec<f64>> = (0..batch).map(|_| self.gen_coeffs(&supp, rng)).collect();
        let x_panel: Vec<f64> = xs.concat();
        let mut y_panel = vec![0.0; batch * self.m];
        let mut scratch = op.make_scratch();
        op.apply_multi_into(&x_panel, &mut scratch, &mut y_panel);
        xs.into_iter()
            .enumerate()
            .map(|(c, x_true)| {
                let mut y = y_panel[c * self.m..(c + 1) * self.m].to_vec();
                if self.noise_std > 0.0 {
                    for v in y.iter_mut() {
                        *v += self.noise_std * rng.gauss();
                    }
                }
                Problem {
                    spec: self.clone(),
                    op: Arc::clone(op),
                    x_true,
                    support: supp.clone(),
                    y,
                }
            })
            .collect()
    }

    /// `y = A x (+ z)` for a freshly drawn signal.
    fn measure(&self, op: &Operator, x_true: &[f64], rng: &mut Rng) -> Vec<f64> {
        let mut y = op.apply(x_true);
        if self.noise_std > 0.0 {
            for v in y.iter_mut() {
                *v += self.noise_std * rng.gauss();
            }
        }
        y
    }

    /// Draw the measurement operator, consuming the identical RNG stream in
    /// dense and matrix-free form: the `partial_dct` row draw is one
    /// `subset(n, m)` call either way, so the same seed yields the same
    /// ensemble (and the same downstream signal/noise draws) under both
    /// representations.
    fn gen_operator(&self, rng: &mut Rng) -> Operator {
        if !self.dense_a {
            debug_assert_eq!(self.ensemble, Ensemble::PartialDct);
            let rows = rng.subset(self.n, self.m);
            return Operator::SubsampledDct(SubsampledDctOp::new(self.n, rows));
        }
        Operator::Dense(DenseOp::new(self.gen_matrix(rng)))
    }

    fn gen_matrix(&self, rng: &mut Rng) -> Mat<f64> {
        let (m, n) = (self.m, self.n);
        match self.ensemble {
            Ensemble::Gaussian => {
                let sc = 1.0 / (m as f64).sqrt();
                Mat::from_fn(m, n, |_, _| sc * rng.gauss())
            }
            Ensemble::GaussianUnnormalized => Mat::from_fn(m, n, |_, _| rng.gauss()),
            Ensemble::Bernoulli => {
                let sc = 1.0 / (m as f64).sqrt();
                Mat::from_fn(m, n, |_, _| sc * rng.sign())
            }
            Ensemble::PartialDct => {
                let rows = rng.subset(n, m);
                let sc = (n as f64 / m as f64).sqrt();
                let nf = n as f64;
                Mat::from_fn(m, n, |i, j| {
                    let k = rows[i] as f64;
                    // Orthonormal DCT-II row k.
                    let c0 = if rows[i] == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
                    sc * c0 * (std::f64::consts::PI * k * (j as f64 + 0.5) / nf).cos()
                })
            }
        }
    }

    fn gen_signal(&self, rng: &mut Rng) -> (Vec<f64>, Vec<usize>) {
        let mut supp = rng.subset(self.n, self.s);
        supp.sort_unstable();
        let x = self.gen_coeffs(&supp, rng);
        (x, supp)
    }

    /// Coefficients on a fixed (sorted) support — shared by the
    /// single-signal and MMV draws.
    fn gen_coeffs(&self, supp: &[usize], rng: &mut Rng) -> Vec<f64> {
        let mut x = vec![0.0f64; self.n];
        for (k, &i) in supp.iter().enumerate() {
            x[i] = match self.signal {
                SignalModel::GaussianSpikes => rng.gauss(),
                SignalModel::FlatSpikes => rng.sign(),
                SignalModel::LinearDecay => rng.sign() * (self.s - k) as f64 / self.s as f64,
            };
        }
        x
    }
}

/// A concrete compressed-sensing instance.
#[derive(Clone, Debug)]
pub struct Problem {
    pub spec: ProblemSpec,
    /// The measurement operator: materialized matrix + transpose (dense) or
    /// matrix-free subsampled DCT. All solver arithmetic routes through
    /// this; dense-only consumers reach the matrices via
    /// [`Problem::try_dense`] / [`Problem::try_dense_t`]. Held behind an
    /// `Arc` so many problems (a batch of
    /// MMV signals, a queue of service jobs) share **one** operator — the
    /// recovery pool never re-materializes the matrix or re-plans the
    /// transform per job.
    pub op: Arc<Operator>,
    /// Planted `s`-sparse signal.
    pub x_true: Vec<f64>,
    /// Sorted support of `x_true`.
    pub support: Vec<usize>,
    /// Observations `y = A x + z`.
    pub y: Vec<f64>,
}

impl Problem {
    /// Assemble an instance from raw parts (test vectors, custom data).
    /// Derives the support and the transposed copy (dense operator).
    pub fn from_parts(spec: ProblemSpec, a: Mat<f64>, x_true: Vec<f64>, y: Vec<f64>) -> Problem {
        let support = crate::support::support_of(&x_true);
        let op = Arc::new(Operator::Dense(DenseOp::new(a)));
        Problem { spec, op, x_true, support, y }
    }

    /// Assemble an instance from raw **measurements only** against an
    /// existing (possibly cached/shared) operator — the served-API shape,
    /// where `y` comes off the wire and no planted truth exists. The
    /// ground-truth fields are placeholders (`x_true` all-zero, empty
    /// support), so [`Problem::recovery_error`] against them is
    /// meaningless; the serving layer reports `final_error` as unknown
    /// for such problems. Errors (never panics) on dimension mismatches.
    pub fn from_measurements(
        spec: ProblemSpec,
        op: &Arc<Operator>,
        y: Vec<f64>,
    ) -> Result<Problem, String> {
        spec.validate()?;
        if op.rows() != spec.m || op.cols() != spec.n {
            return Err(format!(
                "operator is {}x{}, spec wants {}x{}",
                op.rows(),
                op.cols(),
                spec.m,
                spec.n
            ));
        }
        if y.len() != spec.m {
            return Err(format!("y has {} entries, expected m = {}", y.len(), spec.m));
        }
        let x_true = vec![0.0; spec.n];
        Ok(Problem { spec, op: Arc::clone(op), x_true, support: Vec::new(), y })
    }

    /// Does this problem share its operator with `other` (same allocation,
    /// not merely equal entries)? Batched recovery requires it.
    pub fn shares_operator_with(&self, other: &Problem) -> bool {
        Arc::ptr_eq(&self.op, &other.op)
    }

    /// The dense operator, for code paths that genuinely need materialized
    /// matrices (PJRT artifact protocol, classical full-gradient baselines).
    /// Panics on a matrix-free problem with a pointed message.
    fn dense_op(&self) -> &DenseOp {
        self.op.dense().expect(
            "this code path needs the materialized matrix, but the problem was generated \
             matrix-free (dense_a = false); regenerate with dense_a = true",
        )
    }

    /// Measurement matrix, row-major `m x n`, when this problem holds a
    /// materialized operator — `None` for matrix-free problems. This is
    /// the **public** dense accessor: external callers (and anything fed
    /// by the served job API, where the representation is user input)
    /// must handle the `None` instead of relying on a panic.
    pub fn try_dense(&self) -> Option<&Mat<f64>> {
        self.op.dense().map(DenseOp::a)
    }

    /// Transposed copy `n x m` (row `j` holds column `j` of `A`
    /// contiguously — see README.md, "sparse fast path"), when the
    /// operator is materialized; `None` for matrix-free problems.
    pub fn try_dense_t(&self) -> Option<&Mat<f64>> {
        self.op.dense().map(DenseOp::a_t)
    }

    /// Measurement matrix, row-major `m x n` (dense problems only).
    /// Crate-private panicking form for paths that structurally require
    /// the matrix (PJRT artifact protocol, classical full-gradient
    /// baselines); public callers use [`Problem::try_dense`].
    pub(crate) fn a(&self) -> &Mat<f64> {
        self.dense_op().a()
    }

    /// Transposed copy `n x m` (dense problems only) — crate-private
    /// panicking twin of [`Problem::try_dense_t`].
    pub(crate) fn a_t(&self) -> &Mat<f64> {
        self.dense_op().a_t()
    }

    /// Measurement block `A_{b_i}` as a zero-copy view, with its `y` slice
    /// (dense problems only — matrix-free callers use the operator's block
    /// methods plus [`Problem::y_block`]).
    pub fn block(&self, i: usize) -> (RowBlock<'_, f64>, &[f64]) {
        let b = self.spec.b;
        assert!(i < self.spec.num_blocks(), "block index {i} out of range");
        (self.a().row_block(i * b, (i + 1) * b), &self.y[i * b..(i + 1) * b])
    }

    /// The `y` slice of measurement block `i` (any operator).
    pub fn y_block(&self, i: usize) -> &[f64] {
        let b = self.spec.b;
        assert!(i < self.spec.num_blocks(), "block index {i} out of range");
        &self.y[i * b..(i + 1) * b]
    }

    /// `||y - A x||_2` — the paper's halting statistic (allocating
    /// convenience form of [`Problem::residual_norm_with`]).
    pub fn residual_norm(&self, x: &[f64]) -> f64 {
        let mut ax = Vec::new();
        let mut scratch = self.op.make_scratch();
        self.residual_norm_with(x, &mut ax, &mut scratch)
    }

    /// `||y - A x||_2` in caller-owned scratch: `ax_scratch` holds `A x`
    /// (resized to `m`) and `op_scratch` the operator workspace — the
    /// sequential solvers check this once per `check_every` iterations, so
    /// the matrix-free transform must not pay a fresh allocation each time.
    pub fn residual_norm_with(
        &self,
        x: &[f64],
        ax_scratch: &mut Vec<f64>,
        op_scratch: &mut OpScratch,
    ) -> f64 {
        ax_scratch.clear();
        ax_scratch.resize(self.spec.m, 0.0);
        self.op.apply_into(x, op_scratch, ax_scratch);
        let mut s = 0.0;
        for i in 0..self.spec.m {
            let d = self.y[i] - ax_scratch[i];
            s += d * d;
        }
        s.sqrt()
    }

    /// `||y - A x||_2` exploiting a known (sorted) support of `x`: on the
    /// dense operator `A x` touches only the supported columns
    /// (`O(m |supp|)` via the transposed copy, accumulated in `r_scratch`
    /// so no per-check allocation survives in the hot loop); the
    /// matrix-free operator runs one O(n log n) transform in `op_scratch`.
    /// The asynchronous runtimes call this once per core per time step
    /// through each kernel's scratch.
    pub fn residual_norm_sparse_with(
        &self,
        x: &[f64],
        support: &[usize],
        r_scratch: &mut Vec<f64>,
        op_scratch: &mut OpScratch,
    ) -> f64 {
        self.op.residual_norm_sparse(&self.y, x, support, r_scratch, op_scratch)
    }

    /// Allocating convenience wrapper over
    /// [`Problem::residual_norm_sparse_with`].
    pub fn residual_norm_sparse(&self, x: &[f64], support: &[usize]) -> f64 {
        let mut r = Vec::new();
        let mut scratch = self.op.make_scratch();
        self.residual_norm_sparse_with(x, support, &mut r, &mut scratch)
    }

    /// Recovery error `||x - x_true||_2` (Fig. 1's y-axis).
    pub fn recovery_error(&self, x: &[f64]) -> f64 {
        crate::linalg::dist2(x, &self.x_true)
    }

    /// Relative recovery error `||x - x_true|| / ||x_true||`.
    pub fn relative_error(&self, x: &[f64]) -> f64 {
        let denom = nrm2(&self.x_true);
        if denom == 0.0 {
            self.recovery_error(x)
        } else {
            self.recovery_error(x) / denom
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    fn spec(e: Ensemble) -> ProblemSpec {
        ProblemSpec { ensemble: e, ..ProblemSpec::tiny() }
    }

    #[test]
    fn paper_spec_is_valid() {
        let sp = ProblemSpec::paper();
        sp.validate().unwrap();
        assert_eq!(sp.num_blocks(), 20);
    }

    #[test]
    fn validation_catches_bad_specs() {
        let mut sp = ProblemSpec::tiny();
        sp.b = 5; // doesn't divide m=16
        assert!(sp.validate().is_err());
        let mut sp = ProblemSpec::tiny();
        sp.s = 100; // > n
        assert!(sp.validate().is_err());
        let mut sp = ProblemSpec::tiny();
        sp.noise_std = -1.0;
        assert!(sp.validate().is_err());
        let mut sp = ProblemSpec::tiny();
        sp.m = 64;
        sp.b = 4;
        sp.ensemble = Ensemble::PartialDct; // m > n
        assert!(sp.validate().is_err());
    }

    #[test]
    fn matrix_free_validation() {
        // Matrix-free is partial_dct + power-of-two n only.
        let ok = ProblemSpec { dense_a: false, ..spec(Ensemble::PartialDct) };
        ok.validate().unwrap();
        let wrong_ensemble = ProblemSpec { dense_a: false, ..ProblemSpec::tiny() };
        assert!(wrong_ensemble.validate().unwrap_err().contains("partial_dct"));
        let bad_n = ProblemSpec {
            n: 24,
            m: 16,
            ensemble: Ensemble::PartialDct,
            dense_a: false,
            ..ProblemSpec::tiny()
        };
        assert!(bad_n.validate().unwrap_err().contains("power-of-two"));
    }

    #[test]
    fn generated_signal_is_exactly_s_sparse() {
        let mut rng = Rng::seed_from(1);
        let models =
            [SignalModel::GaussianSpikes, SignalModel::FlatSpikes, SignalModel::LinearDecay];
        for model in models {
            let sp = ProblemSpec { signal: model, ..ProblemSpec::tiny() };
            let p = sp.generate(&mut rng);
            let nnz = p.x_true.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, sp.s);
            assert_eq!(p.support.len(), sp.s);
            for &i in &p.support {
                assert!(p.x_true[i] != 0.0);
            }
        }
    }

    #[test]
    fn noiseless_measurements_are_consistent() {
        let mut rng = Rng::seed_from(2);
        let p = ProblemSpec::tiny().generate(&mut rng);
        assert!(p.residual_norm(&p.x_true) < 1e-12);
        assert_eq!(p.recovery_error(&p.x_true), 0.0);
    }

    #[test]
    fn noise_perturbs_measurements() {
        let mut rng = Rng::seed_from(3);
        let sp = ProblemSpec { noise_std: 0.1, ..ProblemSpec::tiny() };
        let p = sp.generate(&mut rng);
        let r = p.residual_norm(&p.x_true);
        // E[r] ≈ 0.1 * sqrt(m) = 0.4
        assert!(r > 0.05 && r < 1.5, "residual {r}");
    }

    #[test]
    fn gaussian_columns_have_unit_expected_norm() {
        let mut rng = Rng::seed_from(4);
        let sp = ProblemSpec { n: 64, m: 256, b: 16, ..spec(Ensemble::Gaussian) };
        let p = sp.generate(&mut rng);
        let mut mean = 0.0;
        for j in 0..sp.n {
            let c = p.a().col_copy(j);
            mean += dot(&c, &c);
        }
        mean /= sp.n as f64;
        assert!((mean - 1.0).abs() < 0.15, "mean col norm^2 {mean}");
    }

    #[test]
    fn bernoulli_entries_are_pm_inv_sqrt_m() {
        let mut rng = Rng::seed_from(5);
        let p = spec(Ensemble::Bernoulli).generate(&mut rng);
        let v = 1.0 / (p.spec.m as f64).sqrt();
        assert!(p.a().data().iter().all(|&x| (x.abs() - v).abs() < 1e-15));
    }

    #[test]
    fn partial_dct_rows_are_orthonormal_before_scaling() {
        let mut rng = Rng::seed_from(6);
        let sp = ProblemSpec { n: 32, m: 16, b: 4, ..spec(Ensemble::PartialDct) };
        let p = sp.generate(&mut rng);
        let sc2 = sp.n as f64 / sp.m as f64;
        // Rows of the scaled matrix: ||row||^2 = n/m; distinct rows orthogonal.
        for i in 0..sp.m {
            let ri = p.a().row(i);
            assert!((dot(ri, ri) - sc2).abs() < 1e-10, "row norm");
            for j in (i + 1)..sp.m {
                assert!(dot(ri, p.a().row(j)).abs() < 1e-10, "orthogonality");
            }
        }
    }

    #[test]
    fn matrix_free_draw_matches_dense_draw_bitwise() {
        // Same seed, same spec modulo dense_a: identical row indices,
        // entrywise bit-identical operator, identical planted signal, and
        // measurements equal to transform accuracy.
        let dense_spec = ProblemSpec { ensemble: Ensemble::PartialDct, ..ProblemSpec::tiny() };
        let free_spec = ProblemSpec { dense_a: false, ..dense_spec.clone() };
        let pd = dense_spec.generate(&mut Rng::seed_from(42));
        let pf = free_spec.generate(&mut Rng::seed_from(42));
        assert_eq!(pd.x_true, pf.x_true);
        assert_eq!(pd.support, pf.support);
        let Operator::SubsampledDct(op) = &*pf.op else { panic!("expected matrix-free operator") };
        for i in 0..pd.spec.m {
            for j in 0..pd.spec.n {
                assert_eq!(
                    pd.a().get(i, j).to_bits(),
                    op.entry(i, j).to_bits(),
                    "entry ({i}, {j})"
                );
            }
        }
        for i in 0..pd.spec.m {
            assert!((pd.y[i] - pf.y[i]).abs() <= 1e-12 * (1.0 + pd.y[i].abs()), "y[{i}]");
        }
        // Matrix-free instances satisfy their own measurements.
        assert!(pf.residual_norm(&pf.x_true) < 1e-10);
    }

    #[test]
    fn try_dense_reports_the_representation() {
        let dense = ProblemSpec::tiny().generate(&mut Rng::seed_from(70));
        let a = dense.try_dense().expect("dense problem has a matrix");
        assert_eq!(a.data(), dense.a().data());
        let a_t = dense.try_dense_t().expect("dense problem has a transpose");
        assert_eq!(a_t.data(), dense.a_t().data());
        let free = ProblemSpec::tiny_matrix_free().generate(&mut Rng::seed_from(71));
        assert!(free.try_dense().is_none());
        assert!(free.try_dense_t().is_none());
    }

    #[test]
    fn from_measurements_takes_y_verbatim_and_validates() {
        let spec = ProblemSpec::tiny();
        let mut rng = Rng::seed_from(72);
        let op = spec.draw_operator(&mut rng);
        let donor = spec.generate_with_op(&op, &mut rng);
        let p = Problem::from_measurements(spec.clone(), &op, donor.y.clone()).unwrap();
        assert_eq!(p.y, donor.y);
        assert!(p.shares_operator_with(&donor));
        assert!(p.x_true.iter().all(|&v| v == 0.0));
        assert!(p.support.is_empty());
        // Wrong y length errors instead of panicking.
        let short = Problem::from_measurements(spec.clone(), &op, vec![0.0; 3]);
        assert!(short.unwrap_err().contains("expected m"));
        // Operator/spec dimension mismatch errors too.
        let mut other = spec;
        other.n = 64;
        other.m = 32;
        let bad = Problem::from_measurements(other, &op, vec![0.0; 32]);
        assert!(bad.unwrap_err().contains("operator"));
    }

    #[test]
    fn ensemble_as_str_roundtrips() {
        for e in [
            Ensemble::Gaussian,
            Ensemble::GaussianUnnormalized,
            Ensemble::Bernoulli,
            Ensemble::PartialDct,
        ] {
            assert_eq!(Ensemble::parse(e.as_str()), Some(e));
        }
    }

    #[test]
    #[should_panic(expected = "matrix-free")]
    fn dense_accessor_panics_on_matrix_free_problem() {
        let sp = ProblemSpec {
            ensemble: Ensemble::PartialDct,
            dense_a: false,
            ..ProblemSpec::tiny()
        };
        let p = sp.generate(&mut Rng::seed_from(7));
        let _ = p.a();
    }

    #[test]
    fn generate_equals_draw_operator_then_generate_with_op() {
        // `generate` is draw_operator + generate_with_op on one RNG stream.
        let spec = ProblemSpec::tiny();
        let whole = spec.generate(&mut Rng::seed_from(77));
        let mut rng = Rng::seed_from(77);
        let op = spec.draw_operator(&mut rng);
        let split = spec.generate_with_op(&op, &mut rng);
        assert_eq!(whole.x_true, split.x_true);
        assert_eq!(whole.support, split.support);
        assert_eq!(whole.y, split.y);
        assert_eq!(whole.a().data(), split.a().data());
    }

    #[test]
    fn signals_on_one_operator_share_the_allocation() {
        let spec = ProblemSpec::tiny();
        let mut rng = Rng::seed_from(78);
        let op = spec.draw_operator(&mut rng);
        let a = spec.generate_with_op(&op, &mut rng);
        let b = spec.generate_with_op(&op, &mut rng);
        assert!(a.shares_operator_with(&b));
        assert_ne!(a.x_true, b.x_true, "independent signal draws");
        // Each signal satisfies its own measurements.
        assert!(a.residual_norm(&a.x_true) < 1e-10);
        assert!(b.residual_norm(&b.x_true) < 1e-10);
        // Fresh generation does not share.
        let c = spec.generate(&mut rng);
        assert!(!a.shares_operator_with(&c));
    }

    #[test]
    fn mmv_batch_shares_support_and_operator() {
        let spec = ProblemSpec { noise_std: 0.01, ..ProblemSpec::tiny() };
        let mut rng = Rng::seed_from(79);
        let op = spec.draw_operator(&mut rng);
        let batch = spec.generate_mmv_with_op(&op, &mut rng, 4);
        assert_eq!(batch.len(), 4);
        for p in &batch {
            assert!(p.shares_operator_with(&batch[0]));
            assert_eq!(p.support, batch[0].support, "MMV signals share one support");
            let nnz = p.x_true.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, spec.s);
            // Noisy measurements still close to consistent.
            assert!(p.residual_norm(&p.x_true) < 1.0);
        }
        assert_ne!(batch[0].x_true, batch[1].x_true, "coefficients differ per signal");
        assert_ne!(batch[0].y, batch[1].y);
    }

    #[test]
    fn block_views_tile_the_matrix() {
        let mut rng = Rng::seed_from(7);
        let p = ProblemSpec::tiny().generate(&mut rng);
        let x: Vec<f64> = (0..p.spec.n).map(|i| (i as f64 * 0.1).sin()).collect();
        let full = p.a().gemv(&x);
        for i in 0..p.spec.num_blocks() {
            let (blk, yb) = p.block(i);
            assert_eq!(blk.gemv(&x), &full[i * p.spec.b..(i + 1) * p.spec.b]);
            assert_eq!(yb, &p.y[i * p.spec.b..(i + 1) * p.spec.b]);
            assert_eq!(yb, p.y_block(i));
        }
    }

    #[test]
    fn transposed_copy_is_consistent() {
        let mut rng = Rng::seed_from(9);
        let p = ProblemSpec::tiny().generate(&mut rng);
        for i in 0..p.spec.m {
            for j in 0..p.spec.n {
                assert_eq!(p.a().get(i, j), p.a_t().get(j, i));
            }
        }
    }

    #[test]
    fn sparse_residual_matches_dense() {
        let mut rng = Rng::seed_from(8);
        let p = ProblemSpec::tiny().generate(&mut rng);
        let mut x = vec![0.0; p.spec.n];
        let supp = vec![1usize, 7, 20];
        for &i in &supp {
            x[i] = rng.gauss();
        }
        let dense = p.residual_norm(&x);
        let sparse = p.residual_norm_sparse(&x, &supp);
        assert!((dense - sparse).abs() < 1e-12);
        // empty support = ||y||
        let zero = vec![0.0; p.spec.n];
        assert!((p.residual_norm_sparse(&zero, &[]) - crate::linalg::nrm2(&p.y)).abs() < 1e-12);
    }

    #[test]
    fn deterministic_generation() {
        let p1 = ProblemSpec::paper().generate(&mut Rng::seed_from(42));
        let p2 = ProblemSpec::paper().generate(&mut Rng::seed_from(42));
        assert_eq!(p1.a().data(), p2.a().data());
        assert_eq!(p1.x_true, p2.x_true);
        assert_eq!(p1.y, p2.y);
    }

    #[test]
    fn parse_enums() {
        assert_eq!(Ensemble::parse("gaussian"), Some(Ensemble::Gaussian));
        assert_eq!(Ensemble::parse("partial_dct"), Some(Ensemble::PartialDct));
        assert_eq!(Ensemble::parse("nope"), None);
        assert_eq!(SignalModel::parse("flat"), Some(SignalModel::FlatSpikes));
        assert_eq!(SignalModel::parse("nope"), None);
    }
}
