//! Metrics: per-iteration convergence traces, Monte-Carlo trial statistics
//! (the mean ± σ curves of the paper's figures), and CSV/JSON writers used
//! by `report` to persist regenerated figure data under `results/`.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A per-iteration scalar trace (e.g. recovery error vs iteration).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub values: Vec<f64>,
}

impl Trace {
    pub fn new() -> Self {
        Trace { values: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Pad (with the last value) or truncate to exactly `len` — Fig. 1
    /// averages traces of unequal length by holding the final error.
    pub fn resampled(&self, len: usize) -> Trace {
        let mut v = self.values.clone();
        let last = v.last().copied().unwrap_or(f64::NAN);
        v.resize(len, last);
        Trace { values: v }
    }
}

/// Pointwise mean of traces (padded to the longest with their final value).
pub fn mean_trace(traces: &[Trace]) -> Trace {
    if traces.is_empty() {
        return Trace::new();
    }
    let len = traces.iter().map(|t| t.len()).max().unwrap_or(0);
    let mut out = vec![0.0f64; len];
    for t in traces {
        let r = t.resampled(len);
        for (o, v) in out.iter_mut().zip(&r.values) {
            *o += v;
        }
    }
    for o in out.iter_mut() {
        *o /= traces.len() as f64;
    }
    Trace { values: out }
}

/// Streaming mean/variance accumulator (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Summary statistics of a sample (the `mean ± σ` bands of Fig. 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

/// Compute summary statistics of a sample.
pub fn stats(sample: &[f64]) -> Stats {
    let mut acc = Accumulator::new();
    for &v in sample {
        acc.push(v);
    }
    Stats {
        n: sample.len(),
        mean: acc.mean(),
        std: acc.std(),
        min: acc.min(),
        max: acc.max(),
        median: quantile(sample, 0.5),
    }
}

/// Empirical quantile (linear interpolation between order statistics).
///
/// NaN policy: non-finite samples are ignored — a single poisoned latency
/// measurement must not take down a stats endpoint (`partial_cmp().unwrap()`
/// used to panic here). If no finite sample remains, returns NaN, which the
/// JSON layer renders as `null` via [`json_f64`].
pub fn quantile(sample: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = sample.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// A rectangular table of named columns, writable as CSV — the exchange
/// format for every regenerated figure.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Self {
        Table { columns: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push_row(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Render as CSV text.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for r in &self.rows {
            // Non-finite values have no portable CSV spelling (`NaN`/`inf`
            // literals break downstream readers) — emit an empty cell, the
            // CSV analogue of the JSON layer's `null`.
            let cells: Vec<String> = r
                .iter()
                .map(|v| if v.is_finite() { format!("{v}") } else { String::new() })
                .collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV under the given path, creating parent dirs.
    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_csv())
    }

    /// Render as a JSON object `{"columns": [...], "rows": [[...], ...]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\"columns\":[");
        for (i, c) in self.columns.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{}\"", json_escape(c));
        }
        out.push_str("],\"rows\":[");
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('[');
            for (j, v) in r.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&json_f64(*v));
            }
            out.push(']');
        }
        out.push_str("]}");
        out
    }

    /// Write JSON under the given path, creating parent dirs.
    pub fn write_json(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_json())
    }

    /// Every `stride`-th row plus the last — terminal-display thinning
    /// shared by the CLI and the bench suites (long per-iteration series).
    pub fn thinned(&self, stride: usize) -> Table {
        let stride = stride.max(1);
        let mut t = Table { columns: self.columns.clone(), rows: Vec::new() };
        for (i, row) in self.rows.iter().enumerate() {
            if i % stride == 0 || i + 1 == self.rows.len() {
                t.rows.push(row.clone());
            }
        }
        t
    }

    /// Render as an aligned text table (what the benches print).
    pub fn to_aligned(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let cells: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(|v| format_sig(*v, 6)).collect())
            .collect();
        for row in &cells {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        for (i, c) in self.columns.iter().enumerate() {
            let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
        }
        out.push('\n');
        for row in &cells {
            for (i, c) in row.iter().enumerate() {
                let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
            }
            out.push('\n');
        }
        out
    }
}

/// Escape a string for embedding in a JSON document (RFC 8259 §7): quote,
/// backslash, and control characters; everything else passes through.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render a finite `f64` as a JSON number in shortest round-trip form.
/// Non-finite values have no JSON representation and become `null`.
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "null".to_string()
    }
}

/// Format with ~`sig` significant digits, avoiding exponent noise for
/// mid-range values. Non-finite values render as an empty cell, consistent
/// with [`Table::to_csv`] (and with `null` in JSON via [`json_f64`]) — the
/// literal `NaN`/`inf` spellings used to leak into exported tables.
pub fn format_sig(v: f64, sig: usize) -> String {
    if !v.is_finite() {
        return String::new();
    }
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if (1e-4..1e7).contains(&a) {
        let digits = (sig as i32 - 1 - a.log10().floor() as i32).max(0) as usize;
        format!("{v:.digits$}")
    } else {
        format!("{v:.prec$e}", prec = sig - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_resample_pads_with_last() {
        let t = Trace { values: vec![3.0, 2.0, 1.0] };
        assert_eq!(t.resampled(5).values, vec![3.0, 2.0, 1.0, 1.0, 1.0]);
        assert_eq!(t.resampled(2).values, vec![3.0, 2.0]);
    }

    #[test]
    fn mean_trace_averages_pointwise() {
        let a = Trace { values: vec![2.0, 4.0] };
        let b = Trace { values: vec![4.0] }; // pads to [4.0, 4.0]
        let m = mean_trace(&[a, b]);
        assert_eq!(m.values, vec![3.0, 4.0]);
        assert!(mean_trace(&[]).is_empty());
    }

    #[test]
    fn welford_matches_two_pass() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((acc.mean() - mean).abs() < 1e-12);
        assert!((acc.std() - var.sqrt()).abs() < 1e-12);
        assert_eq!(acc.min(), 1.0);
        assert_eq!(acc.max(), 16.0);
        assert_eq!(acc.count(), 5);
    }

    #[test]
    fn stats_and_quantiles() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
        assert_eq!(quantile(&[1.0, 2.0, 3.0, 4.0], 0.0), 1.0);
        assert_eq!(quantile(&[1.0, 2.0, 3.0, 4.0], 1.0), 4.0);
        assert_eq!(quantile(&[3.0, 1.0, 2.0], 0.5), 2.0);
        assert!(quantile(&[], 0.5).is_nan());
    }

    #[test]
    fn single_sample_stats() {
        let s = stats(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn table_csv_and_alignment() {
        let mut t = Table::new(&["cores", "mean", "std"]);
        t.push_row(vec![1.0, 612.25, 55.5]);
        t.push_row(vec![16.0, 403.0, 41.25]);
        let csv = t.to_csv();
        assert!(csv.starts_with("cores,mean,std\n"));
        assert!(csv.contains("16,403,41.25"));
        let txt = t.to_aligned();
        assert!(txt.contains("cores"));
        assert_eq!(txt.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.push_row(vec![1.0]);
    }

    #[test]
    fn format_sig_ranges() {
        assert_eq!(format_sig(0.0, 4), "0");
        assert_eq!(format_sig(1.0, 3), "1.00");
        assert!(format_sig(1e-9, 3).contains('e'));
        // Non-finite values render as empty cells, never literal NaN/inf.
        assert_eq!(format_sig(f64::INFINITY, 3), "");
        assert_eq!(format_sig(f64::NEG_INFINITY, 3), "");
        assert_eq!(format_sig(f64::NAN, 3), "");
    }

    #[test]
    fn quantile_ignores_non_finite_samples() {
        // Regression: a single NaN used to panic the sort's partial_cmp.
        let poisoned = [2.0, f64::NAN, 1.0, 3.0, f64::INFINITY, 4.0];
        assert_eq!(quantile(&poisoned, 0.5), 2.5);
        assert_eq!(quantile(&poisoned, 0.0), 1.0);
        assert_eq!(quantile(&poisoned, 1.0), 4.0);
        // All-NaN (or otherwise non-finite) collapses to NaN, not a panic.
        assert!(quantile(&[f64::NAN, f64::NAN], 0.9).is_nan());
        assert!(quantile(&[f64::NEG_INFINITY], 0.5).is_nan());
        // stats() routes its median through quantile — same resilience.
        let s = stats(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn csv_renders_non_finite_as_empty_cell() {
        let mut t = Table::new(&["a", "b", "c"]);
        t.push_row(vec![1.0, f64::NAN, f64::INFINITY]);
        let csv = t.to_csv();
        assert!(csv.contains("1,,\n"), "expected empty cells, got: {csv}");
        assert!(!csv.contains("NaN") && !csv.contains("inf"));
        // JSON stays `null`, consistent with json_f64.
        assert!(t.to_json().contains("[1.0,null,null]"));
    }

    #[test]
    fn thinned_keeps_stride_and_last_row() {
        let mut t = Table::new(&["i"]);
        for i in 0..7 {
            t.push_row(vec![i as f64]);
        }
        let thin = t.thinned(3);
        let col: Vec<f64> = thin.rows.iter().map(|r| r[0]).collect();
        assert_eq!(col, vec![0.0, 3.0, 6.0]);
        let thin1 = t.thinned(1);
        assert_eq!(thin1.rows.len(), 7);
        assert!(Table::new(&["i"]).thinned(0).rows.is_empty());
    }

    #[test]
    fn json_escape_specials() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\n\t\r"), "x\\n\\t\\r");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        assert_eq!(json_escape("µs"), "µs"); // non-ASCII passes through
    }

    #[test]
    fn json_f64_forms() {
        assert_eq!(json_f64(1.0), "1.0");
        assert_eq!(json_f64(0.5), "0.5");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
        // shortest round-trip form parses back exactly
        let v = 1.2345678912345e-7;
        assert_eq!(json_f64(v).parse::<f64>().unwrap(), v);
    }

    #[test]
    fn table_to_json_shape() {
        let mut t = Table::new(&["a\"q", "b"]);
        t.push_row(vec![1.0, f64::NAN]);
        assert_eq!(t.to_json(), "{\"columns\":[\"a\\\"q\",\"b\"],\"rows\":[[1.0,null]]}");
        let empty = Table::new(&[]);
        assert_eq!(empty.to_json(), "{\"columns\":[],\"rows\":[]}");
    }

    #[test]
    fn table_write_csv_roundtrip() {
        let dir = std::env::temp_dir().join("astir_test_metrics");
        let path = dir.join("t.csv");
        let mut t = Table::new(&["x"]);
        t.push_row(vec![1.5]);
        t.write_csv(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, "x\n1.5\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
