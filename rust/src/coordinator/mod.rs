//! Leader/worker orchestration.
//!
//! The experiments are Monte-Carlo sweeps: hundreds of independent trials
//! (fresh problem instance each) per configuration point. The coordinator
//! owns that outer loop:
//!
//! * [`run_trials`] — a deterministic work-stealing trial pool: trial `i`
//!   always receives the same RNG stream regardless of which OS thread
//!   executes it, so results are bit-identical at any `threads` setting.
//! * [`ResultSlots`] — preallocated one-writer-per-slot output storage, so
//!   the trial loop commits results without touching a lock (the seed took
//!   the results `Mutex` once per trial); the persistent
//!   [`crate::service::RecoveryPool`] reuses the same scheme.
//! * [`Leader`] — the config-driven facade the CLI and benches use:
//!   generate per-trial problems, dispatch to the sequential solvers, the
//!   discrete-time simulator, or the real-thread runtime, and aggregate
//!   [`crate::metrics::Stats`]. Its Monte-Carlo sweeps ride a persistent
//!   [`crate::service::RecoveryPool`] (spawned once per leader) with the
//!   identical per-trial RNG derivation, so results are bit-for-bit what
//!   the spawn-per-call [`run_trials`] produces.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::algorithms::{self, Alg, GreedyOpts, RunResult, StoGradMpKernel};
use crate::config::ExperimentConfig;
use crate::metrics::{stats, Stats};
use crate::problem::Problem;
use crate::rng::Rng;
use crate::service::RecoveryPool;
use crate::sim::{simulate, simulate_with, SimOpts, SimOutcome, SpeedSchedule};

/// Preallocated per-trial output slots written without locks.
///
/// The work-queue protocol (an atomic ticket in [`run_trials`] and in the
/// recovery pool) hands each slot index to exactly one worker, so a slot
/// write needs no synchronization of its own; publication to the reader
/// happens through the queue's existing synchronization (thread join, or
/// the pool's release/acquire completion counter + mutex hand-off).
pub(crate) struct ResultSlots<T> {
    slots: Vec<UnsafeCell<Option<T>>>,
}

// SAFETY: slots are only written through `put` under the one-writer-per-
// index contract below, and only read after a happens-before edge from
// every writer; `T: Send` is all that crossing threads then requires.
unsafe impl<T: Send> Sync for ResultSlots<T> {}

impl<T> ResultSlots<T> {
    pub(crate) fn new(len: usize) -> Self {
        ResultSlots { slots: (0..len).map(|_| UnsafeCell::new(None)).collect() }
    }

    pub(crate) fn len(&self) -> usize {
        self.slots.len()
    }

    /// Write slot `i`.
    ///
    /// SAFETY: the caller must guarantee `i` was claimed exclusively (e.g.
    /// via an atomic `fetch_add` ticket), so no other `put`/`take` touches
    /// slot `i` concurrently.
    pub(crate) unsafe fn put(&self, i: usize, v: T) {
        *self.slots[i].get() = Some(v);
    }

    /// Take slot `i` back out.
    ///
    /// SAFETY: the caller must guarantee all writers are finished and
    /// synchronized-with (happens-before) this call, and that no other
    /// `take` targets slot `i` concurrently.
    pub(crate) unsafe fn take(&self, i: usize) -> Option<T> {
        (*self.slots[i].get()).take()
    }

    /// Consume into the ordered results; panics if any slot was never
    /// written (a worker died before finishing its claim).
    pub(crate) fn into_vec(self) -> Vec<T> {
        self.slots
            .into_iter()
            .map(|c| c.into_inner().expect("every claimed slot must produce a result"))
            .collect()
    }
}

/// Run `trials` independent jobs on `threads` OS threads.
///
/// Job `i` gets an RNG derived from `master_seed` and `i` only — results
/// are independent of the thread count and of scheduling order. Outputs
/// are returned in trial order. The loop body is lock-free: trials are
/// claimed by an atomic ticket and committed into [`ResultSlots`]
/// (one exclusive writer per slot), with the scope join supplying the
/// final happens-before edge.
pub fn run_trials<T, F>(trials: usize, threads: usize, master_seed: u64, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Rng) -> T + Sync,
{
    assert!(threads >= 1);
    // Pre-derive one RNG per trial from the master stream (serially, so
    // the assignment is scheduling-independent).
    let trial_rngs = split_rngs(master_seed, trials);

    let next = AtomicUsize::new(0);
    let slots: ResultSlots<T> = ResultSlots::new(trials);

    std::thread::scope(|scope| {
        for _ in 0..threads.min(trials.max(1)) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= trials {
                    break;
                }
                let mut rng = trial_rngs[i].clone();
                let out = f(i, &mut rng);
                // SAFETY: the ticket above hands index i to this thread
                // alone; reads happen after the scope joins every worker.
                unsafe { slots.put(i, out) };
            });
        }
    });

    slots.into_vec()
}

/// One independent RNG per job, derived from the master seed and the job
/// index only — the scheduling-independent splitting scheme shared by
/// [`run_trials`] and the persistent recovery pool.
pub fn split_rngs(master_seed: u64, jobs: usize) -> Vec<Rng> {
    let mut root = Rng::seed_from(master_seed);
    (0..jobs).map(|i| root.split(i as u64)).collect()
}

/// Aggregated sweep point: a configuration value and the sample statistics
/// of its per-trial outcomes.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// The swept parameter (e.g. number of cores).
    pub param: f64,
    /// Statistics of steps-to-exit across trials.
    pub steps: Stats,
    /// Fraction of trials that converged.
    pub convergence_rate: f64,
}

/// Config-driven experiment facade. Owns a persistent
/// [`RecoveryPool`] (sized by `trial_threads`, spawned lazily on the
/// first sweep so constructing a `Leader` stays free): every Monte-Carlo
/// sweep below is a batch of pool jobs, so repeated sweeps — a core-count
/// sweep, the bench suites, the CLI — reuse the same worker threads
/// instead of re-spawning a scoped team per call.
pub struct Leader {
    pub cfg: ExperimentConfig,
    pool: std::sync::OnceLock<RecoveryPool>,
}

impl Leader {
    pub fn new(cfg: ExperimentConfig) -> Self {
        cfg.validate().expect("invalid experiment config");
        Leader { cfg, pool: std::sync::OnceLock::new() }
    }

    /// The leader's persistent worker pool (spawned on first use).
    pub fn pool(&self) -> &RecoveryPool {
        self.pool.get_or_init(|| RecoveryPool::new(self.cfg.trial_threads))
    }

    /// Fresh problem instance for trial `i` (deterministic in the config
    /// seed; shared by all solvers compared within the trial).
    ///
    /// Contract: this is exactly `cfg.problem.generate(rng)` — the pooled
    /// sweeps below inline that same call (their `'static` closures cannot
    /// borrow `self`), so any change to the per-trial draw must happen in
    /// `ProblemSpec::generate`, never here.
    pub fn problem_for_trial(&self, rng: &mut Rng) -> Problem {
        self.cfg.problem.generate(rng)
    }

    /// Greedy options implied by the config.
    pub fn greedy_opts(&self) -> GreedyOpts {
        GreedyOpts {
            gamma: self.cfg.gamma,
            tolerance: self.cfg.tolerance,
            max_iters: self.cfg.max_iters,
            ..Default::default()
        }
    }

    /// Monte-Carlo over sequential StoIHT (the paper's horizontal line in
    /// Fig. 2): returns per-trial results. Rides the persistent pool with
    /// the [`run_trials`] RNG derivation, so the results are bit-for-bit
    /// what the scoped-thread path produced.
    pub fn monte_carlo_stoiht(&self, opts: &GreedyOpts) -> Vec<RunResult> {
        let problem = self.cfg.problem.clone();
        let opts = opts.clone();
        self.pool().run_jobs(self.cfg.trials, self.cfg.seed, move |_i, rng| {
            let p = problem.generate(rng);
            let mut solver_rng = rng.split(0xA160);
            algorithms::stoiht(&p, &opts, &mut solver_rng)
        })
    }

    /// Monte-Carlo over the configured sequential algorithm
    /// ([`ExperimentConfig::alg`]) — the generalized horizontal line. The
    /// StoIHT arm delegates so the trial body (and its RNG derivation)
    /// exists exactly once.
    pub fn monte_carlo_seq(&self, opts: &GreedyOpts) -> Vec<RunResult> {
        match self.cfg.alg {
            Alg::Stoiht => self.monte_carlo_stoiht(opts),
            Alg::StoGradMp => {
                let problem = self.cfg.problem.clone();
                let opts = opts.clone();
                self.pool().run_jobs(self.cfg.trials, self.cfg.seed, move |_i, rng| {
                    let p = problem.generate(rng);
                    let mut solver_rng = rng.split(0xA160);
                    algorithms::stogradmp(&p, &opts, &mut solver_rng)
                })
            }
        }
    }

    /// Monte-Carlo over the discrete-time simulator at a fixed core count,
    /// driving the configured algorithm's kernel.
    pub fn monte_carlo_sim(
        &self,
        cores: usize,
        schedule: &SpeedSchedule,
        sim_opts: &SimOpts,
    ) -> Vec<SimOutcome> {
        let alg = self.cfg.alg;
        let problem = self.cfg.problem.clone();
        let schedule = schedule.clone();
        let sim_opts = sim_opts.clone();
        self.pool().run_jobs(self.cfg.trials, self.cfg.seed, move |_i, rng| {
            let p = problem.generate(rng);
            let mut sim_rng = rng.split(0x519);
            match alg {
                Alg::Stoiht => simulate(&p, cores, &schedule, &sim_opts, &mut sim_rng),
                Alg::StoGradMp => simulate_with(
                    &p,
                    cores,
                    &schedule,
                    &sim_opts,
                    &mut sim_rng,
                    StoGradMpKernel::new,
                ),
            }
        })
    }

    /// Sweep the configured core counts; aggregate steps-to-exit stats.
    pub fn sweep_cores(&self, schedule: &SpeedSchedule, sim_opts: &SimOpts) -> Vec<SweepPoint> {
        self.cfg
            .cores
            .iter()
            .map(|&c| {
                let outs = self.monte_carlo_sim(c, schedule, sim_opts);
                let steps: Vec<f64> = outs.iter().map(|o| o.steps as f64).collect();
                let conv = outs.iter().filter(|o| o.converged).count() as f64 / outs.len() as f64;
                SweepPoint { param: c as f64, steps: stats(&steps), convergence_rate: conv }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            problem: ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() },
            trials: 8,
            max_iters: 1500,
            cores: vec![1, 2],
            trial_threads: 3,
            ..Default::default()
        }
    }

    #[test]
    fn run_trials_returns_in_order() {
        let out = run_trials(10, 4, 1, |i, _rng| i * 2);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn run_trials_deterministic_across_thread_counts() {
        let a: Vec<u64> = run_trials(12, 1, 99, |_i, rng| rng.next_u64());
        let b: Vec<u64> = run_trials(12, 5, 99, |_i, rng| rng.next_u64());
        assert_eq!(a, b);
    }

    #[test]
    fn run_trials_zero_trials() {
        let out: Vec<u32> = run_trials(0, 4, 1, |_, _| 0u32);
        assert!(out.is_empty());
    }

    #[test]
    fn leader_monte_carlo_stoiht_converges() {
        let leader = Leader::new(small_cfg());
        let results = leader.monte_carlo_stoiht(&leader.greedy_opts());
        assert_eq!(results.len(), 8);
        let conv = results.iter().filter(|r| r.converged).count();
        assert!(conv >= 7, "only {conv}/8 converged");
    }

    #[test]
    fn leader_sweep_has_configured_points() {
        let mut cfg = small_cfg();
        cfg.trials = 5;
        let leader = Leader::new(cfg);
        let pts = leader.sweep_cores(&SpeedSchedule::AllFast, &SimOpts::default());
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].param, 1.0);
        assert_eq!(pts[1].param, 2.0);
        for p in &pts {
            assert!(p.convergence_rate > 0.5);
            assert!(p.steps.mean > 0.0);
        }
    }

    #[test]
    fn leader_dispatches_stogradmp() {
        let mut cfg = small_cfg();
        cfg.alg = Alg::StoGradMp;
        cfg.trials = 4;
        cfg.max_iters = 150;
        let leader = Leader::new(cfg);
        let seq = leader.monte_carlo_seq(&leader.greedy_opts());
        assert!(seq.iter().all(|r| r.converged), "sequential StoGradMP trials");
        // GradMP converges in tens of iterations where StoIHT needs hundreds.
        assert!(seq.iter().all(|r| r.iters < 100));
        let sims = leader.monte_carlo_sim(
            2,
            &SpeedSchedule::AllFast,
            &SimOpts { max_steps: 150, ..Default::default() },
        );
        assert!(sims.iter().filter(|o| o.converged).count() >= 3, "async StoGradMP sim trials");
    }

    #[test]
    fn monte_carlo_seq_matches_stoiht_under_default_alg() {
        let mut cfg = small_cfg();
        cfg.trials = 3;
        let leader = Leader::new(cfg);
        let a = leader.monte_carlo_stoiht(&leader.greedy_opts());
        let b = leader.monte_carlo_seq(&leader.greedy_opts());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.x, rb.x);
            assert_eq!(ra.iters, rb.iters);
        }
    }

    #[test]
    fn pooled_monte_carlo_matches_scoped_run_trials_bitwise() {
        // The Leader rides the persistent pool; its per-trial RNG scheme
        // must remain exactly run_trials', so the rewiring is invisible.
        let mut cfg = small_cfg();
        cfg.trials = 4;
        let leader = Leader::new(cfg.clone());
        let pooled = leader.monte_carlo_stoiht(&leader.greedy_opts());
        let opts = leader.greedy_opts();
        let scoped = run_trials(cfg.trials, cfg.trial_threads, cfg.seed, |_i, rng| {
            let p = cfg.problem.generate(rng);
            let mut solver_rng = rng.split(0xA160);
            algorithms::stoiht(&p, &opts, &mut solver_rng)
        });
        for (a, b) in pooled.iter().zip(&scoped) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.iters, b.iters);
            assert_eq!(a.residual.to_bits(), b.residual.to_bits());
        }
    }

    #[test]
    fn trial_problems_differ_but_are_reproducible() {
        let leader = Leader::new(small_cfg());
        let probs: Vec<Vec<f64>> = run_trials(3, 2, leader.cfg.seed, |_i, rng| {
            leader.problem_for_trial(rng).x_true
        });
        assert_ne!(probs[0], probs[1]);
        let again: Vec<Vec<f64>> = run_trials(3, 1, leader.cfg.seed, |_i, rng| {
            leader.problem_for_trial(rng).x_true
        });
        assert_eq!(probs, again);
    }

    #[test]
    #[should_panic(expected = "invalid experiment config")]
    fn leader_rejects_bad_config() {
        let mut cfg = small_cfg();
        cfg.problem.b = 7;
        let _ = Leader::new(cfg);
    }
}
