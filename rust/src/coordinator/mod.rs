//! Leader/worker orchestration.
//!
//! The experiments are Monte-Carlo sweeps: hundreds of independent trials
//! (fresh problem instance each) per configuration point. The coordinator
//! owns that outer loop:
//!
//! * [`run_trials`] — a deterministic work-stealing trial pool: trial `i`
//!   always receives the same RNG stream regardless of which OS thread
//!   executes it, so results are bit-identical at any `threads` setting.
//! * [`Leader`] — the config-driven facade the CLI and benches use:
//!   generate per-trial problems, dispatch to the sequential solvers, the
//!   discrete-time simulator, or the real-thread runtime, and aggregate
//!   [`crate::metrics::Stats`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::algorithms::{self, Alg, GreedyOpts, RunResult, StoGradMpKernel};
use crate::config::ExperimentConfig;
use crate::metrics::{stats, Stats};
use crate::problem::Problem;
use crate::rng::Rng;
use crate::sim::{simulate, simulate_with, SimOpts, SimOutcome, SpeedSchedule};

/// Run `trials` independent jobs on `threads` OS threads.
///
/// Job `i` gets an RNG derived from `master_seed` and `i` only — results
/// are independent of the thread count and of scheduling order. Outputs
/// are returned in trial order.
pub fn run_trials<T, F>(trials: usize, threads: usize, master_seed: u64, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Rng) -> T + Sync,
{
    assert!(threads >= 1);
    // Pre-derive one RNG per trial from the master stream (serially, so
    // the assignment is scheduling-independent).
    let mut root = Rng::seed_from(master_seed);
    let trial_rngs: Vec<Rng> = (0..trials).map(|i| root.split(i as u64)).collect();

    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..trials).map(|_| None).collect());

    std::thread::scope(|scope| {
        for _ in 0..threads.min(trials.max(1)) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= trials {
                    break;
                }
                let mut rng = trial_rngs[i].clone();
                let out = f(i, &mut rng);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });

    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every trial must produce a result"))
        .collect()
}

/// Aggregated sweep point: a configuration value and the sample statistics
/// of its per-trial outcomes.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// The swept parameter (e.g. number of cores).
    pub param: f64,
    /// Statistics of steps-to-exit across trials.
    pub steps: Stats,
    /// Fraction of trials that converged.
    pub convergence_rate: f64,
}

/// Config-driven experiment facade.
pub struct Leader {
    pub cfg: ExperimentConfig,
}

impl Leader {
    pub fn new(cfg: ExperimentConfig) -> Self {
        cfg.validate().expect("invalid experiment config");
        Leader { cfg }
    }

    /// Fresh problem instance for trial `i` (deterministic in the config
    /// seed; shared by all solvers compared within the trial).
    pub fn problem_for_trial(&self, rng: &mut Rng) -> Problem {
        self.cfg.problem.generate(rng)
    }

    /// Greedy options implied by the config.
    pub fn greedy_opts(&self) -> GreedyOpts {
        GreedyOpts {
            gamma: self.cfg.gamma,
            tolerance: self.cfg.tolerance,
            max_iters: self.cfg.max_iters,
            ..Default::default()
        }
    }

    /// Monte-Carlo over sequential StoIHT (the paper's horizontal line in
    /// Fig. 2): returns per-trial results.
    pub fn monte_carlo_stoiht(&self, opts: &GreedyOpts) -> Vec<RunResult> {
        run_trials(self.cfg.trials, self.cfg.trial_threads, self.cfg.seed, |_i, rng| {
            let p = self.problem_for_trial(rng);
            let mut solver_rng = rng.split(0xA160);
            algorithms::stoiht(&p, opts, &mut solver_rng)
        })
    }

    /// Monte-Carlo over the configured sequential algorithm
    /// ([`ExperimentConfig::alg`]) — the generalized horizontal line. The
    /// StoIHT arm delegates so the trial body (and its RNG derivation)
    /// exists exactly once.
    pub fn monte_carlo_seq(&self, opts: &GreedyOpts) -> Vec<RunResult> {
        match self.cfg.alg {
            Alg::Stoiht => self.monte_carlo_stoiht(opts),
            Alg::StoGradMp => {
                run_trials(self.cfg.trials, self.cfg.trial_threads, self.cfg.seed, |_i, rng| {
                    let p = self.problem_for_trial(rng);
                    let mut solver_rng = rng.split(0xA160);
                    algorithms::stogradmp(&p, opts, &mut solver_rng)
                })
            }
        }
    }

    /// Monte-Carlo over the discrete-time simulator at a fixed core count,
    /// driving the configured algorithm's kernel.
    pub fn monte_carlo_sim(
        &self,
        cores: usize,
        schedule: &SpeedSchedule,
        sim_opts: &SimOpts,
    ) -> Vec<SimOutcome> {
        let alg = self.cfg.alg;
        run_trials(self.cfg.trials, self.cfg.trial_threads, self.cfg.seed, move |_i, rng| {
            let p = self.problem_for_trial(rng);
            let mut sim_rng = rng.split(0x519);
            match alg {
                Alg::Stoiht => simulate(&p, cores, schedule, sim_opts, &mut sim_rng),
                Alg::StoGradMp => {
                    simulate_with(&p, cores, schedule, sim_opts, &mut sim_rng, StoGradMpKernel::new)
                }
            }
        })
    }

    /// Sweep the configured core counts; aggregate steps-to-exit stats.
    pub fn sweep_cores(&self, schedule: &SpeedSchedule, sim_opts: &SimOpts) -> Vec<SweepPoint> {
        self.cfg
            .cores
            .iter()
            .map(|&c| {
                let outs = self.monte_carlo_sim(c, schedule, sim_opts);
                let steps: Vec<f64> = outs.iter().map(|o| o.steps as f64).collect();
                let conv = outs.iter().filter(|o| o.converged).count() as f64 / outs.len() as f64;
                SweepPoint { param: c as f64, steps: stats(&steps), convergence_rate: conv }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            problem: ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() },
            trials: 8,
            max_iters: 1500,
            cores: vec![1, 2],
            trial_threads: 3,
            ..Default::default()
        }
    }

    #[test]
    fn run_trials_returns_in_order() {
        let out = run_trials(10, 4, 1, |i, _rng| i * 2);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn run_trials_deterministic_across_thread_counts() {
        let a: Vec<u64> = run_trials(12, 1, 99, |_i, rng| rng.next_u64());
        let b: Vec<u64> = run_trials(12, 5, 99, |_i, rng| rng.next_u64());
        assert_eq!(a, b);
    }

    #[test]
    fn run_trials_zero_trials() {
        let out: Vec<u32> = run_trials(0, 4, 1, |_, _| 0u32);
        assert!(out.is_empty());
    }

    #[test]
    fn leader_monte_carlo_stoiht_converges() {
        let leader = Leader::new(small_cfg());
        let results = leader.monte_carlo_stoiht(&leader.greedy_opts());
        assert_eq!(results.len(), 8);
        let conv = results.iter().filter(|r| r.converged).count();
        assert!(conv >= 7, "only {conv}/8 converged");
    }

    #[test]
    fn leader_sweep_has_configured_points() {
        let mut cfg = small_cfg();
        cfg.trials = 5;
        let leader = Leader::new(cfg);
        let pts = leader.sweep_cores(&SpeedSchedule::AllFast, &SimOpts::default());
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].param, 1.0);
        assert_eq!(pts[1].param, 2.0);
        for p in &pts {
            assert!(p.convergence_rate > 0.5);
            assert!(p.steps.mean > 0.0);
        }
    }

    #[test]
    fn leader_dispatches_stogradmp() {
        let mut cfg = small_cfg();
        cfg.alg = Alg::StoGradMp;
        cfg.trials = 4;
        cfg.max_iters = 150;
        let leader = Leader::new(cfg);
        let seq = leader.monte_carlo_seq(&leader.greedy_opts());
        assert!(seq.iter().all(|r| r.converged), "sequential StoGradMP trials");
        // GradMP converges in tens of iterations where StoIHT needs hundreds.
        assert!(seq.iter().all(|r| r.iters < 100));
        let sims = leader.monte_carlo_sim(
            2,
            &SpeedSchedule::AllFast,
            &SimOpts { max_steps: 150, ..Default::default() },
        );
        assert!(sims.iter().filter(|o| o.converged).count() >= 3, "async StoGradMP sim trials");
    }

    #[test]
    fn monte_carlo_seq_matches_stoiht_under_default_alg() {
        let mut cfg = small_cfg();
        cfg.trials = 3;
        let leader = Leader::new(cfg);
        let a = leader.monte_carlo_stoiht(&leader.greedy_opts());
        let b = leader.monte_carlo_seq(&leader.greedy_opts());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.x, rb.x);
            assert_eq!(ra.iters, rb.iters);
        }
    }

    #[test]
    fn trial_problems_differ_but_are_reproducible() {
        let leader = Leader::new(small_cfg());
        let probs: Vec<Vec<f64>> = run_trials(3, 2, leader.cfg.seed, |_i, rng| {
            leader.problem_for_trial(rng).x_true
        });
        assert_ne!(probs[0], probs[1]);
        let again: Vec<Vec<f64>> = run_trials(3, 1, leader.cfg.seed, |_i, rng| {
            leader.problem_for_trial(rng).x_true
        });
        assert_eq!(probs, again);
    }

    #[test]
    #[should_panic(expected = "invalid experiment config")]
    fn leader_rejects_bad_config() {
        let mut cfg = small_cfg();
        cfg.problem.b = 7;
        let _ = Leader::new(cfg);
    }
}
