//! Leader/worker orchestration.
//!
//! The experiments are Monte-Carlo sweeps: hundreds of independent trials
//! (fresh problem instance each) per configuration point. The coordinator
//! owns that outer loop:
//!
//! * [`run_trials`] — a deterministic work-stealing trial pool: trial `i`
//!   always receives the same RNG stream regardless of which OS thread
//!   executes it, so results are bit-identical at any `threads` setting.
//! * [`ResultSlots`] — preallocated one-writer-per-slot output storage, so
//!   the trial loop commits results without touching a lock (the seed took
//!   the results `Mutex` once per trial); the persistent
//!   [`crate::service::RecoveryPool`] reuses the same scheme.
//! * [`Leader`] — the config-driven facade the CLI and benches use:
//!   generate per-trial problems, dispatch to the sequential solvers, the
//!   discrete-time simulator, or the real-thread runtime, and aggregate
//!   [`crate::metrics::Stats`]. Its Monte-Carlo sweeps ride a persistent
//!   [`crate::service::RecoveryPool`] (spawned once per leader) with the
//!   identical per-trial RNG derivation, so results are bit-for-bit what
//!   the spawn-per-call [`run_trials`] produces.

use crate::algorithms::{self, Alg, GreedyOpts, RunResult, StoGradMpKernel};
use crate::config::ExperimentConfig;
use crate::metrics::{stats, Stats};
use crate::problem::Problem;
use crate::rng::Rng;
use crate::service::RecoveryPool;
use crate::sim::{simulate, simulate_with, SimOpts, SimOutcome, SpeedSchedule};
use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{thread, OnceLock, RaceCell};

/// Preallocated per-trial output slots written without locks.
///
/// The work-queue protocol (an atomic ticket in [`run_trials`] and in the
/// recovery pool) hands each slot index to exactly one worker, so a slot
/// write needs no synchronization of its own; publication to the reader
/// happens through the queue's existing synchronization (thread join, or
/// the pool's release/acquire completion counter + mutex hand-off).
///
/// This type is the **only** place in the crate allowed to contain
/// `unsafe` (`#![deny(unsafe_code)]` everywhere else). The storage is one
/// [`RaceCell`] per slot, so under `--features model` every access below
/// is race-checked against the happens-before edges the protocol claims
/// to provide, and the Miri CI job checks the raw pointer accesses
/// themselves for undefined behavior.
pub(crate) struct ResultSlots<T> {
    slots: Vec<RaceCell<Option<T>>>,
}

// SAFETY: slots are only written through `put` under the one-writer-per-
// index protocol documented there, and only read after a happens-before
// edge from every writer; `T: Send` is all that crossing threads then
// requires.
#[allow(unsafe_code)]
unsafe impl<T: Send> Sync for ResultSlots<T> {}

impl<T> ResultSlots<T> {
    pub(crate) fn new(len: usize) -> Self {
        ResultSlots { slots: (0..len).map(|_| RaceCell::new(None)).collect() }
    }

    pub(crate) fn len(&self) -> usize {
        self.slots.len()
    }

    /// Write slot `i`.
    ///
    /// Protocol: the caller must have claimed `i` exclusively (e.g. via an
    /// atomic `fetch_add` ticket), so no other `put`/`take` touches slot
    /// `i` concurrently. Violating this is undefined behavior in normal
    /// builds — and a reported data race under the model checker.
    #[allow(unsafe_code)]
    pub(crate) fn put(&self, i: usize, v: T) {
        self.slots[i].with_mut(|p| {
            // SAFETY: `p` points into live storage owned by `self`, and
            // the claim protocol above makes this thread the only user of
            // slot `i` until the publication edge to the reader.
            unsafe { *p = Some(v) }
        });
    }

    /// Take slot `i` back out.
    ///
    /// Protocol: all writers must be finished and synchronized-with
    /// (happens-before) this call, and no other `put`/`take` may target
    /// slot `i` concurrently.
    #[allow(unsafe_code)]
    pub(crate) fn take(&self, i: usize) -> Option<T> {
        self.slots[i].with_mut(|p| {
            // SAFETY: `p` points into live storage owned by `self`, and
            // the protocol above guarantees exclusive access here.
            unsafe { (*p).take() }
        })
    }

    /// Consume into the ordered results, given that every index below
    /// `claimed` was handed to some worker by the ticket. Panics with a
    /// diagnosis that distinguishes a slot the ticket **never reached**
    /// (a queue bug — e.g. a worker loop exiting early) from one that was
    /// **claimed but never produced** (its worker died mid-job).
    pub(crate) fn into_vec(self, claimed: usize) -> Vec<T> {
        self.slots
            .into_iter()
            .enumerate()
            .map(|(i, c)| match c.into_inner() {
                Some(v) => v,
                None if i >= claimed => {
                    panic!("slot {i} was never claimed by any worker (ticket stopped early)")
                }
                None => panic!("slot {i} was claimed but produced no result (worker died)"),
            })
            .collect()
    }
}

/// Run `trials` independent jobs on `threads` OS threads.
///
/// Job `i` gets an RNG derived from `master_seed` and `i` only — results
/// are independent of the thread count and of scheduling order. Outputs
/// are returned in trial order. The loop body is lock-free: trials are
/// claimed by an atomic ticket and committed into [`ResultSlots`]
/// (one exclusive writer per slot), with the scope join supplying the
/// final happens-before edge.
pub fn run_trials<T, F>(trials: usize, threads: usize, master_seed: u64, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Rng) -> T + Sync,
{
    assert!(threads >= 1);
    // Pre-derive one RNG per trial from the master stream (serially, so
    // the assignment is scheduling-independent).
    let trial_rngs = split_rngs(master_seed, trials);

    let next = AtomicUsize::new(0);
    let slots: ResultSlots<T> = ResultSlots::new(trials);

    thread::scope(|scope| {
        for _ in 0..threads.min(trials.max(1)) {
            scope.spawn(|| loop {
                // Relaxed: the ticket only needs uniqueness of `i`, not
                // publication — the scope join below is the visibility edge.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= trials {
                    break;
                }
                let mut rng = trial_rngs[i].clone();
                let out = f(i, &mut rng);
                // Slot protocol: the ticket above hands index i to this
                // thread alone; reads happen after the scope joins workers.
                slots.put(i, out);
            });
        }
    });

    // Relaxed: post-join read — the scope already synchronized everything.
    let claimed = next.load(Ordering::Relaxed).min(trials);
    slots.into_vec(claimed)
}

/// One independent RNG per job, derived from the master seed and the job
/// index only — the scheduling-independent splitting scheme shared by
/// [`run_trials`] and the persistent recovery pool.
pub fn split_rngs(master_seed: u64, jobs: usize) -> Vec<Rng> {
    let mut root = Rng::seed_from(master_seed);
    (0..jobs).map(|i| root.split(i as u64)).collect()
}

/// Aggregated sweep point: a configuration value and the sample statistics
/// of its per-trial outcomes.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// The swept parameter (e.g. number of cores).
    pub param: f64,
    /// Statistics of steps-to-exit across trials.
    pub steps: Stats,
    /// Fraction of trials that converged.
    pub convergence_rate: f64,
}

/// Config-driven experiment facade. Owns a persistent
/// [`RecoveryPool`] (sized by `trial_threads`, spawned lazily on the
/// first sweep so constructing a `Leader` stays free): every Monte-Carlo
/// sweep below is a batch of pool jobs, so repeated sweeps — a core-count
/// sweep, the bench suites, the CLI — reuse the same worker threads
/// instead of re-spawning a scoped team per call.
pub struct Leader {
    pub cfg: ExperimentConfig,
    pool: OnceLock<RecoveryPool>,
}

impl Leader {
    pub fn new(cfg: ExperimentConfig) -> Self {
        cfg.validate().expect("invalid experiment config");
        Leader { cfg, pool: OnceLock::new() }
    }

    /// The leader's persistent worker pool (spawned on first use).
    pub fn pool(&self) -> &RecoveryPool {
        self.pool.get_or_init(|| RecoveryPool::new(self.cfg.trial_threads))
    }

    /// Fresh problem instance for trial `i` (deterministic in the config
    /// seed; shared by all solvers compared within the trial).
    ///
    /// Contract: this is exactly `cfg.problem.generate(rng)` — the pooled
    /// sweeps below inline that same call (their `'static` closures cannot
    /// borrow `self`), so any change to the per-trial draw must happen in
    /// `ProblemSpec::generate`, never here.
    pub fn problem_for_trial(&self, rng: &mut Rng) -> Problem {
        self.cfg.problem.generate(rng)
    }

    /// Greedy options implied by the config.
    pub fn greedy_opts(&self) -> GreedyOpts {
        GreedyOpts {
            gamma: self.cfg.gamma,
            tolerance: self.cfg.tolerance,
            max_iters: self.cfg.max_iters,
            ..Default::default()
        }
    }

    /// Monte-Carlo over sequential StoIHT (the paper's horizontal line in
    /// Fig. 2): returns per-trial results. Rides the persistent pool with
    /// the [`run_trials`] RNG derivation, so the results are bit-for-bit
    /// what the scoped-thread path produced.
    pub fn monte_carlo_stoiht(&self, opts: &GreedyOpts) -> Vec<RunResult> {
        let problem = self.cfg.problem.clone();
        let opts = opts.clone();
        self.pool().run_jobs(self.cfg.trials, self.cfg.seed, move |_i, rng| {
            let p = problem.generate(rng);
            let mut solver_rng = rng.split(0xA160);
            algorithms::stoiht(&p, &opts, &mut solver_rng)
        })
    }

    /// Monte-Carlo over the configured sequential algorithm
    /// ([`ExperimentConfig::alg`]) — the generalized horizontal line. The
    /// StoIHT arm delegates so the trial body (and its RNG derivation)
    /// exists exactly once.
    pub fn monte_carlo_seq(&self, opts: &GreedyOpts) -> Vec<RunResult> {
        match self.cfg.alg {
            Alg::Stoiht => self.monte_carlo_stoiht(opts),
            Alg::StoGradMp => {
                let problem = self.cfg.problem.clone();
                let opts = opts.clone();
                self.pool().run_jobs(self.cfg.trials, self.cfg.seed, move |_i, rng| {
                    let p = problem.generate(rng);
                    let mut solver_rng = rng.split(0xA160);
                    algorithms::stogradmp(&p, &opts, &mut solver_rng)
                })
            }
        }
    }

    /// Monte-Carlo over the discrete-time simulator at a fixed core count,
    /// driving the configured algorithm's kernel.
    pub fn monte_carlo_sim(
        &self,
        cores: usize,
        schedule: &SpeedSchedule,
        sim_opts: &SimOpts,
    ) -> Vec<SimOutcome> {
        let alg = self.cfg.alg;
        let problem = self.cfg.problem.clone();
        let schedule = schedule.clone();
        let sim_opts = sim_opts.clone();
        self.pool().run_jobs(self.cfg.trials, self.cfg.seed, move |_i, rng| {
            let p = problem.generate(rng);
            let mut sim_rng = rng.split(0x519);
            match alg {
                Alg::Stoiht => simulate(&p, cores, &schedule, &sim_opts, &mut sim_rng),
                Alg::StoGradMp => simulate_with(
                    &p,
                    cores,
                    &schedule,
                    &sim_opts,
                    &mut sim_rng,
                    StoGradMpKernel::new,
                ),
            }
        })
    }

    /// Sweep the configured core counts; aggregate steps-to-exit stats.
    pub fn sweep_cores(&self, schedule: &SpeedSchedule, sim_opts: &SimOpts) -> Vec<SweepPoint> {
        self.cfg
            .cores
            .iter()
            .map(|&c| {
                let outs = self.monte_carlo_sim(c, schedule, sim_opts);
                let steps: Vec<f64> = outs.iter().map(|o| o.steps as f64).collect();
                let conv = outs.iter().filter(|o| o.converged).count() as f64 / outs.len() as f64;
                SweepPoint { param: c as f64, steps: stats(&steps), convergence_rate: conv }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            problem: ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() },
            trials: 8,
            max_iters: 1500,
            cores: vec![1, 2],
            trial_threads: 3,
            ..Default::default()
        }
    }

    #[test]
    fn run_trials_returns_in_order() {
        let out = run_trials(10, 4, 1, |i, _rng| i * 2);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn run_trials_deterministic_across_thread_counts() {
        let a: Vec<u64> = run_trials(12, 1, 99, |_i, rng| rng.next_u64());
        let b: Vec<u64> = run_trials(12, 5, 99, |_i, rng| rng.next_u64());
        assert_eq!(a, b);
    }

    #[test]
    fn run_trials_zero_trials() {
        let out: Vec<u32> = run_trials(0, 4, 1, |_, _| 0u32);
        assert!(out.is_empty());
    }

    #[test]
    fn result_slots_zero_length_drains_empty() {
        let slots: ResultSlots<u8> = ResultSlots::new(0);
        assert_eq!(slots.len(), 0);
        assert!(slots.into_vec(0).is_empty());
    }

    #[test]
    fn result_slots_put_take_round_trip() {
        let slots: ResultSlots<u8> = ResultSlots::new(2);
        slots.put(1, 42);
        assert_eq!(slots.take(1), Some(42));
        assert_eq!(slots.take(1), None);
        assert_eq!(slots.take(0), None);
    }

    #[test]
    #[should_panic(expected = "never claimed by any worker")]
    fn result_slots_diagnose_unclaimed_slot() {
        let slots: ResultSlots<u8> = ResultSlots::new(2);
        slots.put(0, 7);
        // The ticket only reached index 1, so slot 1 was never handed out.
        let _ = slots.into_vec(1);
    }

    #[test]
    #[should_panic(expected = "claimed slot 1 produced no result")]
    fn result_slots_diagnose_dead_worker() {
        let slots: ResultSlots<u8> = ResultSlots::new(2);
        slots.put(0, 7);
        // Both slots were claimed, but slot 1's worker never committed.
        let _ = slots.into_vec(2);
    }

    #[test]
    #[cfg_attr(miri, ignore = "full Monte-Carlo sweep is too slow under Miri")]
    fn leader_monte_carlo_stoiht_converges() {
        let leader = Leader::new(small_cfg());
        let results = leader.monte_carlo_stoiht(&leader.greedy_opts());
        assert_eq!(results.len(), 8);
        let conv = results.iter().filter(|r| r.converged).count();
        assert!(conv >= 7, "only {conv}/8 converged");
    }

    #[test]
    #[cfg_attr(miri, ignore = "full Monte-Carlo sweep is too slow under Miri")]
    fn leader_sweep_has_configured_points() {
        let mut cfg = small_cfg();
        cfg.trials = 5;
        let leader = Leader::new(cfg);
        let pts = leader.sweep_cores(&SpeedSchedule::AllFast, &SimOpts::default());
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].param, 1.0);
        assert_eq!(pts[1].param, 2.0);
        for p in &pts {
            assert!(p.convergence_rate > 0.5);
            assert!(p.steps.mean > 0.0);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full Monte-Carlo sweep is too slow under Miri")]
    fn leader_dispatches_stogradmp() {
        let mut cfg = small_cfg();
        cfg.alg = Alg::StoGradMp;
        cfg.trials = 4;
        cfg.max_iters = 150;
        let leader = Leader::new(cfg);
        let seq = leader.monte_carlo_seq(&leader.greedy_opts());
        assert!(seq.iter().all(|r| r.converged), "sequential StoGradMP trials");
        // GradMP converges in tens of iterations where StoIHT needs hundreds.
        assert!(seq.iter().all(|r| r.iters < 100));
        let sims = leader.monte_carlo_sim(
            2,
            &SpeedSchedule::AllFast,
            &SimOpts { max_steps: 150, ..Default::default() },
        );
        assert!(sims.iter().filter(|o| o.converged).count() >= 3, "async StoGradMP sim trials");
    }

    #[test]
    #[cfg_attr(miri, ignore = "full Monte-Carlo sweep is too slow under Miri")]
    fn monte_carlo_seq_matches_stoiht_under_default_alg() {
        let mut cfg = small_cfg();
        cfg.trials = 3;
        let leader = Leader::new(cfg);
        let a = leader.monte_carlo_stoiht(&leader.greedy_opts());
        let b = leader.monte_carlo_seq(&leader.greedy_opts());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.x, rb.x);
            assert_eq!(ra.iters, rb.iters);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full Monte-Carlo sweep is too slow under Miri")]
    fn pooled_monte_carlo_matches_scoped_run_trials_bitwise() {
        // The Leader rides the persistent pool; its per-trial RNG scheme
        // must remain exactly run_trials', so the rewiring is invisible.
        let mut cfg = small_cfg();
        cfg.trials = 4;
        let leader = Leader::new(cfg.clone());
        let pooled = leader.monte_carlo_stoiht(&leader.greedy_opts());
        let opts = leader.greedy_opts();
        let scoped = run_trials(cfg.trials, cfg.trial_threads, cfg.seed, |_i, rng| {
            let p = cfg.problem.generate(rng);
            let mut solver_rng = rng.split(0xA160);
            algorithms::stoiht(&p, &opts, &mut solver_rng)
        });
        for (a, b) in pooled.iter().zip(&scoped) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.iters, b.iters);
            assert_eq!(a.residual.to_bits(), b.residual.to_bits());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full Monte-Carlo sweep is too slow under Miri")]
    fn trial_problems_differ_but_are_reproducible() {
        let leader = Leader::new(small_cfg());
        let probs: Vec<Vec<f64>> = run_trials(3, 2, leader.cfg.seed, |_i, rng| {
            leader.problem_for_trial(rng).x_true
        });
        assert_ne!(probs[0], probs[1]);
        let again: Vec<Vec<f64>> = run_trials(3, 1, leader.cfg.seed, |_i, rng| {
            leader.problem_for_trial(rng).x_true
        });
        assert_eq!(probs, again);
    }

    #[test]
    #[should_panic(expected = "invalid experiment config")]
    fn leader_rejects_bad_config() {
        let mut cfg = small_cfg();
        cfg.problem.b = 7;
        let _ = Leader::new(cfg);
    }
}
