//! Configuration substrate: a dependency-free TOML-subset parser plus the
//! typed experiment configuration the CLI consumes.
//!
//! The subset covers what experiment configs need — top-level and `[table]`
//! sections, `key = value` with integers, floats, booleans, strings
//! (double-quoted, with `\"`, `\\`, `\n`, `\t` escapes), and flat arrays of
//! primitives. Comments (`#`) and blank lines are ignored. Unknown keys are
//! rejected at the typed layer so typos fail loudly.

mod toml;

pub use toml::{parse_toml, TomlDoc, TomlError, Value};

use crate::algorithms::Alg;
use crate::problem::{Ensemble, ProblemSpec, SignalModel};
use crate::sim::ShardOpts;
use crate::tally::ExchangeProtocol;

/// Recovery-service settings (`astir batch`, the persistent
/// [`crate::service::RecoveryPool`]): TOML `[service]` section, CLI
/// `--workers/--jobs/--batch` overrides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Persistent pool size (worker threads spawned once per service).
    pub workers: usize,
    /// Recovery jobs a batch run submits.
    pub jobs: usize,
    /// Signals per job recovered in MMV lockstep (1 = single-signal jobs).
    pub batch: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: default_trial_threads(), jobs: 16, batch: 1 }
    }
}

/// Network front-end settings (`astir serve`, [`crate::service::server`]):
/// TOML `[serve]` section, CLI `--addr/--workers/--batch-window-ms/
/// --max-inflight` overrides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Connection-handler threads.
    pub workers: usize,
    /// Micro-batch window in milliseconds (0 = solo solves, bit-identical
    /// to in-process `solve_job`).
    pub batch_window_ms: u64,
    /// Admission cap on concurrently admitted jobs.
    pub max_inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: default_trial_threads(),
            batch_window_ms: 2,
            max_inflight: 64,
        }
    }
}

/// Sharded-tally settings (`astir async --shards`, driving
/// [`crate::service::ShardedPool`] and the sharded simulator): TOML
/// `[shard]` section, CLI `--shards/--exchange-period/--exchange-protocol`
/// overrides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// In-process shards `S` (1 = the unsharded single-tally path).
    pub shards: usize,
    /// Staleness bound `E`: exchange support votes every `E` local steps.
    pub exchange_period: usize,
    /// Exchange protocol (all-to-all gossip or leader merge).
    pub protocol: ExchangeProtocol,
}

impl Default for ShardConfig {
    fn default() -> Self {
        let d = ShardOpts::default();
        ShardConfig { shards: d.shards, exchange_period: d.exchange_period, protocol: d.protocol }
    }
}

impl ShardConfig {
    /// The runtime sharding axes this config denotes.
    pub fn shard_opts(&self) -> ShardOpts {
        ShardOpts {
            shards: self.shards,
            exchange_period: self.exchange_period,
            protocol: self.protocol,
        }
    }
}

/// Typed experiment configuration (see `configs/*.toml` for examples).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Problem distribution.
    pub problem: ProblemSpec,
    /// Which [`crate::algorithms::SupportKernel`] the solvers and the
    /// asynchronous runtimes drive (paper default: StoIHT).
    pub alg: Alg,
    /// Step size `gamma` (paper: 1.0).
    pub gamma: f64,
    /// Exit tolerance on `||y - A x||_2` (paper: 1e-7).
    pub tolerance: f64,
    /// Maximum iterations / time steps (paper: 1500).
    pub max_iters: usize,
    /// Monte-Carlo trials.
    pub trials: usize,
    /// Master seed.
    pub seed: u64,
    /// Core counts to sweep in async experiments.
    pub cores: Vec<usize>,
    /// Worker threads used to parallelize *trials* (not the simulated cores).
    pub trial_threads: usize,
    /// Recovery-service settings (`astir batch`).
    pub service: ServiceConfig,
    /// Network front-end settings (`astir serve`).
    pub serve: ServeConfig,
    /// Sharded-tally settings (`astir async --shards`).
    pub shard: ShardConfig,
}

impl Default for ExperimentConfig {
    /// The paper's §IV setup.
    fn default() -> Self {
        ExperimentConfig {
            problem: ProblemSpec::paper(),
            alg: Alg::Stoiht,
            gamma: 1.0,
            tolerance: 1e-7,
            max_iters: 1500,
            trials: 500,
            seed: 20170301,
            cores: vec![1, 2, 4, 8, 16],
            trial_threads: default_trial_threads(),
            service: ServiceConfig::default(),
            serve: ServeConfig::default(),
            shard: ShardConfig::default(),
        }
    }
}

/// Default parallelism for Monte-Carlo trials: available cores, capped.
pub fn default_trial_threads() -> usize {
    crate::sync::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

impl ExperimentConfig {
    /// Parse from TOML text. Unknown keys and unknown sections are errors.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = parse_toml(text).map_err(|e| e.to_string())?;
        // A misspelled section ("[services]") must not silently yield
        // defaults; the per-key strictness below only sees known sections.
        for name in doc.section_names() {
            if !matches!(name, "" | "problem" | "service" | "serve" | "shard") {
                return Err(format!("unknown section `[{name}]` (problem|service|serve|shard)"));
            }
        }
        let mut cfg = ExperimentConfig::default();

        for (key, value) in doc.section("") {
            match key.as_str() {
                "alg" => {
                    let s = value.as_str().ok_or("alg must be a string")?;
                    cfg.alg = Alg::parse(s)
                        .ok_or_else(|| format!("unknown alg `{s}` (stoiht|stogradmp)"))?;
                }
                "gamma" => cfg.gamma = value.as_f64().ok_or("gamma must be a number")?,
                "tolerance" => cfg.tolerance = value.as_f64().ok_or("tolerance must be a number")?,
                "max_iters" => {
                    cfg.max_iters =
                        value.as_usize().ok_or("max_iters must be a positive integer")?
                }
                "trials" => {
                    cfg.trials = value.as_usize().ok_or("trials must be a positive integer")?
                }
                "seed" => cfg.seed = value.as_u64().ok_or("seed must be a nonnegative integer")?,
                "trial_threads" => {
                    cfg.trial_threads =
                        value.as_usize().ok_or("trial_threads must be a positive integer")?
                }
                "cores" => {
                    cfg.cores = value
                        .as_array()
                        .ok_or("cores must be an array")?
                        .iter()
                        .map(|v| v.as_usize().ok_or("cores entries must be positive integers"))
                        .collect::<Result<Vec<_>, _>>()?;
                }
                other => return Err(format!("unknown top-level key `{other}`")),
            }
        }

        for (key, value) in doc.section("problem") {
            let p = &mut cfg.problem;
            match key.as_str() {
                "n" => p.n = value.as_usize().ok_or("problem.n must be a positive integer")?,
                "m" => p.m = value.as_usize().ok_or("problem.m must be a positive integer")?,
                "b" => p.b = value.as_usize().ok_or("problem.b must be a positive integer")?,
                "s" => p.s = value.as_usize().ok_or("problem.s must be a positive integer")?,
                "noise_std" => {
                    p.noise_std = value.as_f64().ok_or("problem.noise_std must be a number")?
                }
                "dense_a" => {
                    p.dense_a = value.as_bool().ok_or("problem.dense_a must be a boolean")?
                }
                "ensemble" => {
                    let s = value.as_str().ok_or("problem.ensemble must be a string")?;
                    p.ensemble =
                        Ensemble::parse(s).ok_or_else(|| format!("unknown ensemble `{s}`"))?;
                }
                "signal" => {
                    let s = value.as_str().ok_or("problem.signal must be a string")?;
                    p.signal =
                        SignalModel::parse(s).ok_or_else(|| format!("unknown signal model `{s}`"))?;
                }
                other => return Err(format!("unknown problem key `{other}`")),
            }
        }

        for (key, value) in doc.section("service") {
            let s = &mut cfg.service;
            match key.as_str() {
                "workers" => {
                    s.workers =
                        value.as_usize().ok_or("service.workers must be a positive integer")?
                }
                "jobs" => {
                    s.jobs = value.as_usize().ok_or("service.jobs must be a positive integer")?
                }
                "batch" => {
                    s.batch = value.as_usize().ok_or("service.batch must be a positive integer")?
                }
                other => return Err(format!("unknown service key `{other}`")),
            }
        }

        for (key, value) in doc.section("serve") {
            let s = &mut cfg.serve;
            match key.as_str() {
                "addr" => {
                    s.addr = value.as_str().ok_or("serve.addr must be a string")?.to_string()
                }
                "workers" => {
                    s.workers =
                        value.as_usize().ok_or("serve.workers must be a positive integer")?
                }
                "batch_window_ms" => {
                    s.batch_window_ms = value
                        .as_u64()
                        .ok_or("serve.batch_window_ms must be a nonnegative integer")?
                }
                "max_inflight" => {
                    s.max_inflight =
                        value.as_usize().ok_or("serve.max_inflight must be a positive integer")?
                }
                other => return Err(format!("unknown serve key `{other}`")),
            }
        }

        for (key, value) in doc.section("shard") {
            let s = &mut cfg.shard;
            match key.as_str() {
                "shards" => {
                    s.shards = value.as_usize().ok_or("shard.shards must be a positive integer")?
                }
                "exchange_period" => {
                    s.exchange_period = value
                        .as_usize()
                        .ok_or("shard.exchange_period must be a positive integer")?
                }
                "protocol" => {
                    let p = value.as_str().ok_or("shard.protocol must be a string")?;
                    s.protocol = ExchangeProtocol::parse(p)
                        .ok_or_else(|| format!("unknown shard protocol `{p}` (gossip|leader)"))?;
                }
                other => return Err(format!("unknown shard key `{other}`")),
            }
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<(), String> {
        self.problem.validate()?;
        if self.gamma <= 0.0 {
            return Err("gamma must be positive".into());
        }
        if self.tolerance <= 0.0 {
            return Err("tolerance must be positive".into());
        }
        if self.max_iters == 0 {
            return Err("max_iters must be positive".into());
        }
        if self.trials == 0 {
            return Err("trials must be positive".into());
        }
        if self.cores.is_empty() || self.cores.contains(&0) {
            return Err("cores must be a nonempty list of positive integers".into());
        }
        if self.trial_threads == 0 {
            return Err("trial_threads must be positive".into());
        }
        if self.service.workers == 0 {
            return Err("service.workers must be positive".into());
        }
        if self.service.jobs == 0 {
            return Err("service.jobs must be positive".into());
        }
        if self.service.batch == 0 {
            return Err("service.batch must be positive".into());
        }
        if self.serve.addr.is_empty() {
            return Err("serve.addr must be nonempty".into());
        }
        if self.serve.workers == 0 {
            return Err("serve.workers must be positive".into());
        }
        if self.serve.max_inflight == 0 {
            return Err("serve.max_inflight must be positive".into());
        }
        // Reuse the runtime-side checks ("shards must be >= 1", …) with
        // the section name prefixed, matching the other error strings.
        self.shard.shard_opts().validate().map_err(|e| format!("shard.{e}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.problem.n, 1000);
        assert_eq!(c.problem.m, 300);
        assert_eq!(c.problem.b, 15);
        assert_eq!(c.problem.s, 20);
        assert_eq!(c.gamma, 1.0);
        assert_eq!(c.tolerance, 1e-7);
        assert_eq!(c.max_iters, 1500);
        c.validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
# experiment
gamma = 0.5
tolerance = 1e-6
max_iters = 200
trials = 10
seed = 7
cores = [1, 2, 4]
trial_threads = 2

[problem]
n = 64
m = 32
b = 8
s = 4
ensemble = "bernoulli"
signal = "flat"
noise_std = 0.01
"#;
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.gamma, 0.5);
        assert_eq!(c.tolerance, 1e-6);
        assert_eq!(c.max_iters, 200);
        assert_eq!(c.trials, 10);
        assert_eq!(c.seed, 7);
        assert_eq!(c.cores, vec![1, 2, 4]);
        assert_eq!(c.problem.n, 64);
        assert_eq!(c.problem.ensemble, Ensemble::Bernoulli);
        assert_eq!(c.problem.signal, SignalModel::FlatSpikes);
        assert_eq!(c.problem.noise_std, 0.01);
    }

    #[test]
    fn dense_a_knob_parses_and_validates() {
        let toml = r#"
[problem]
n = 64
m = 32
b = 8
s = 4
ensemble = "partial_dct"
dense_a = false
"#;
        let c = ExperimentConfig::from_toml(toml).unwrap();
        assert!(!c.problem.dense_a);
        assert_eq!(c.problem.ensemble, Ensemble::PartialDct);
        // Default stays dense.
        assert!(ExperimentConfig::default().problem.dense_a);
        // Matrix-free with a non-partial_dct ensemble fails validation.
        assert!(ExperimentConfig::from_toml("[problem]\ndense_a = false").is_err());
        // ... as does a non-power-of-two n.
        let bad = "[problem]\nn = 96\nm = 48\nb = 8\nensemble = \"partial_dct\"\ndense_a = false";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        assert!(ExperimentConfig::from_toml("[problem]\ndense_a = 3").is_err());
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(ExperimentConfig::from_toml("gamam = 1.0").is_err());
        assert!(ExperimentConfig::from_toml("[problem]\nq = 3").is_err());
        assert!(ExperimentConfig::from_toml("[service]\nthreads = 2").is_err());
        // Misspelled sections fail loudly instead of yielding defaults.
        assert!(ExperimentConfig::from_toml("[services]\nworkers = 2").is_err());
        assert!(ExperimentConfig::from_toml("[problems]\nn = 64").is_err());
    }

    #[test]
    fn service_section_parses_and_validates() {
        let c = ExperimentConfig::from_toml("[service]\nworkers = 3\njobs = 40\nbatch = 8")
            .unwrap();
        assert_eq!(c.service, ServiceConfig { workers: 3, jobs: 40, batch: 8 });
        // Defaults: single-signal jobs, auto-sized pool.
        let d = ExperimentConfig::default();
        assert_eq!(d.service.batch, 1);
        assert_eq!(d.service.jobs, 16);
        assert!(d.service.workers >= 1);
        assert!(ExperimentConfig::from_toml("[service]\nworkers = 0").is_err());
        assert!(ExperimentConfig::from_toml("[service]\njobs = 0").is_err());
        assert!(ExperimentConfig::from_toml("[service]\nbatch = 0").is_err());
        assert!(ExperimentConfig::from_toml("[service]\nbatch = true").is_err());
    }

    #[test]
    fn serve_section_parses_and_validates() {
        let text = "[serve]\naddr = \"0.0.0.0:9000\"\nworkers = 2\nbatch_window_ms = 0\n\
                    max_inflight = 4";
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            c.serve,
            ServeConfig {
                addr: "0.0.0.0:9000".to_string(),
                workers: 2,
                batch_window_ms: 0,
                max_inflight: 4,
            }
        );
        // Defaults: loopback, small window, generous admission.
        let d = ExperimentConfig::default();
        assert_eq!(d.serve.addr, "127.0.0.1:7878");
        assert_eq!(d.serve.batch_window_ms, 2);
        assert_eq!(d.serve.max_inflight, 64);
        assert!(d.serve.workers >= 1);
        assert!(ExperimentConfig::from_toml("[serve]\nworkers = 0").is_err());
        assert!(ExperimentConfig::from_toml("[serve]\nmax_inflight = 0").is_err());
        assert!(ExperimentConfig::from_toml("[serve]\naddr = \"\"").is_err());
        assert!(ExperimentConfig::from_toml("[serve]\nbatch_window_ms = \"fast\"").is_err());
        assert!(ExperimentConfig::from_toml("[serve]\nport = 80").is_err());
    }

    #[test]
    fn shard_section_parses_and_validates() {
        let text = "[shard]\nshards = 4\nexchange_period = 8\nprotocol = \"leader\"";
        let c = ExperimentConfig::from_toml(text).unwrap();
        let expect = ShardConfig {
            shards: 4,
            exchange_period: 8,
            protocol: ExchangeProtocol::LeaderMerge,
        };
        assert_eq!(c.shard, expect);
        assert_eq!(c.shard.shard_opts().shards, 4);
        // Defaults: unsharded, moderate staleness, gossip.
        let d = ExperimentConfig::default();
        assert_eq!(d.shard, ShardConfig::default());
        assert_eq!(d.shard.shards, 1);
        assert_eq!(d.shard.exchange_period, 16);
        assert_eq!(d.shard.protocol, ExchangeProtocol::Gossip);
        // "leader_merge" is accepted as a spelling of "leader".
        let alias = ExperimentConfig::from_toml("[shard]\nprotocol = \"leader_merge\"").unwrap();
        assert_eq!(alias.shard.protocol, ExchangeProtocol::LeaderMerge);
        assert!(ExperimentConfig::from_toml("[shard]\nshards = 0").is_err());
        assert!(ExperimentConfig::from_toml("[shard]\nexchange_period = 0").is_err());
        assert!(ExperimentConfig::from_toml("[shard]\nprotocol = \"pigeon\"").is_err());
        assert!(ExperimentConfig::from_toml("[shard]\nperiod = 2").is_err());
    }

    #[test]
    fn alg_selector_parses() {
        assert_eq!(ExperimentConfig::default().alg, Alg::Stoiht);
        let c = ExperimentConfig::from_toml("alg = \"stogradmp\"").unwrap();
        assert_eq!(c.alg, Alg::StoGradMp);
        assert!(ExperimentConfig::from_toml("alg = \"htp\"").is_err());
        assert!(ExperimentConfig::from_toml("alg = 3").is_err());
    }

    #[test]
    fn rejects_invalid_values() {
        assert!(ExperimentConfig::from_toml("gamma = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[problem]\nb = 7").is_err()); // 7 ∤ 300
        assert!(ExperimentConfig::from_toml("cores = []").is_err());
        assert!(ExperimentConfig::from_toml("cores = [0]").is_err());
        assert!(ExperimentConfig::from_toml("[problem]\nensemble = \"martian\"").is_err());
    }

    #[test]
    fn partial_override_keeps_defaults() {
        let c = ExperimentConfig::from_toml("trials = 3").unwrap();
        assert_eq!(c.trials, 3);
        assert_eq!(c.problem.n, 1000); // untouched default
    }
}
