//! A dependency-free parser for the TOML subset used by ASTIR configs.
//!
//! Supported: top-level and `[section]` tables, `key = value` lines where a
//! value is an integer, float, boolean, double-quoted string, or a flat
//! array of those; `#` comments; blank lines. Nested tables, dotted keys,
//! multiline strings, and datetimes are intentionally out of scope.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed primitive or flat-array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(v) => Some(*v as f64),
            Value::Float(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(v) if *v >= 0 => Some(*v as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Clone, Debug, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: map from section name ("" = top level) to key/value
/// pairs in declaration order.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, Vec<(String, Value)>>,
}

impl TomlDoc {
    /// Key/value pairs of a section (empty slice if absent).
    pub fn section(&self, name: &str) -> &[(String, Value)] {
        self.sections.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Look up one key in one section.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.section(section).iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

fn err<T>(line: usize, message: impl Into<String>) -> Result<T, TomlError> {
    Err(TomlError { line, message: message.into() })
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut current = String::new();
    doc.sections.entry(current.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let lineno = lineno + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                return err(lineno, "unterminated section header");
            };
            let name = name.trim();
            if name.is_empty() || !name.chars().all(is_key_char) {
                return err(lineno, format!("invalid section name `{name}`"));
            }
            current = name.to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return err(lineno, "expected `key = value`");
        };
        let key = line[..eq].trim();
        if key.is_empty() || !key.chars().all(is_key_char) {
            return err(lineno, format!("invalid key `{key}`"));
        }
        let value_text = line[eq + 1..].trim();
        if value_text.is_empty() {
            return err(lineno, "missing value");
        }
        let (value, rest) = parse_value(value_text, lineno)?;
        if !rest.trim().is_empty() {
            return err(lineno, format!("trailing characters `{}`", rest.trim()));
        }
        let entries = doc.sections.get_mut(&current).unwrap();
        if entries.iter().any(|(k, _)| k == key) {
            return err(lineno, format!("duplicate key `{key}`"));
        }
        entries.push((key.to_string(), value));
    }
    Ok(doc)
}

fn is_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str && !escaped => {
                escaped = true;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = false;
    }
    line
}

/// Parse one value at the start of `text`; return (value, remaining text).
fn parse_value<'a>(text: &'a str, lineno: usize) -> Result<(Value, &'a str), TomlError> {
    let text = text.trim_start();
    if text.starts_with('"') {
        return parse_string(text, lineno);
    }
    if let Some(rest) = text.strip_prefix('[') {
        return parse_array(rest, lineno);
    }
    if let Some(rest) = text.strip_prefix("true") {
        return Ok((Value::Bool(true), rest));
    }
    if let Some(rest) = text.strip_prefix("false") {
        return Ok((Value::Bool(false), rest));
    }
    // Number: consume chars valid in numbers, then decide int vs float.
    let end = text
        .char_indices()
        .find(|(_, c)| !matches!(c, '0'..='9' | '+' | '-' | '.' | 'e' | 'E' | '_'))
        .map(|(i, _)| i)
        .unwrap_or(text.len());
    let (num, rest) = text.split_at(end);
    let num_clean: String = num.chars().filter(|&c| c != '_').collect();
    if num_clean.is_empty() {
        return err(lineno, format!("cannot parse value starting at `{text}`"));
    }
    let looks_float = num_clean.contains('.') || num_clean.contains('e') || num_clean.contains('E');
    if looks_float {
        match num_clean.parse::<f64>() {
            Ok(v) => Ok((Value::Float(v), rest)),
            Err(_) => err(lineno, format!("invalid float `{num}`")),
        }
    } else {
        match num_clean.parse::<i64>() {
            Ok(v) => Ok((Value::Int(v), rest)),
            Err(_) => err(lineno, format!("invalid integer `{num}`")),
        }
    }
}

fn parse_string<'a>(text: &'a str, lineno: usize) -> Result<(Value, &'a str), TomlError> {
    debug_assert!(text.starts_with('"'));
    let mut out = String::new();
    let mut chars = text[1..].char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((Value::Str(out), &text[1 + i + 1..])),
            '\\' => match chars.next() {
                Some((_, 'n')) => out.push('\n'),
                Some((_, 't')) => out.push('\t'),
                Some((_, '"')) => out.push('"'),
                Some((_, '\\')) => out.push('\\'),
                Some((_, other)) => return err(lineno, format!("unknown escape `\\{other}`")),
                None => return err(lineno, "dangling escape"),
            },
            other => out.push(other),
        }
    }
    err(lineno, "unterminated string")
}

fn parse_array<'a>(mut text: &'a str, lineno: usize) -> Result<(Value, &'a str), TomlError> {
    let mut items = Vec::new();
    loop {
        text = text.trim_start();
        if let Some(rest) = text.strip_prefix(']') {
            return Ok((Value::Array(items), rest));
        }
        if text.is_empty() {
            return err(lineno, "unterminated array");
        }
        let (v, rest) = parse_value(text, lineno)?;
        if matches!(v, Value::Array(_)) {
            return err(lineno, "nested arrays are not supported");
        }
        items.push(v);
        text = rest.trim_start();
        if let Some(rest) = text.strip_prefix(',') {
            text = rest;
        } else if !text.starts_with(']') {
            return err(lineno, "expected `,` or `]` in array");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_primitives() {
        let d = parse_toml("a = 1\nb = -2.5\nc = true\nd = \"hi\"\ne = 1e-7\n").unwrap();
        assert_eq!(d.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(d.get("", "b"), Some(&Value::Float(-2.5)));
        assert_eq!(d.get("", "c"), Some(&Value::Bool(true)));
        assert_eq!(d.get("", "d"), Some(&Value::Str("hi".into())));
        assert_eq!(d.get("", "e"), Some(&Value::Float(1e-7)));
    }

    #[test]
    fn parses_sections_and_comments() {
        let d = parse_toml("# top\nx = 1 # trailing\n[sec]\ny = 2\n").unwrap();
        assert_eq!(d.get("", "x"), Some(&Value::Int(1)));
        assert_eq!(d.get("sec", "y"), Some(&Value::Int(2)));
        assert!(d.section_names().any(|s| s == "sec"));
    }

    #[test]
    fn parses_arrays() {
        let d = parse_toml("a = [1, 2, 3]\nb = [1.5, 2]\nc = [\"x\", \"y\"]\nd = []\n").unwrap();
        assert_eq!(
            d.get("", "a"),
            Some(&Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)]))
        );
        assert_eq!(d.get("", "d"), Some(&Value::Array(vec![])));
        let b = d.get("", "b").unwrap().as_array().unwrap();
        assert_eq!(b[0].as_f64(), Some(1.5));
        assert_eq!(b[1].as_f64(), Some(2.0));
    }

    #[test]
    fn string_escapes_and_hash_inside_string() {
        let d = parse_toml(r#"s = "a#b\n\"q\"\\" "#).unwrap();
        assert_eq!(d.get("", "s").unwrap().as_str(), Some("a#b\n\"q\"\\"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.to_string().contains("line 2"));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse_toml("[unterminated").is_err());
        assert!(parse_toml("[]").is_err());
        assert!(parse_toml("k = ").is_err());
        assert!(parse_toml("k = \"open").is_err());
        assert!(parse_toml("k = [1, 2").is_err());
        assert!(parse_toml("k = [[1]]").is_err());
        assert!(parse_toml("k = 1 2").is_err());
        assert!(parse_toml("k = zzz").is_err());
        assert!(parse_toml("a = 1\na = 2").is_err()); // duplicate
        assert!(parse_toml("bad key = 1").is_err());
    }

    #[test]
    fn numbers_with_underscores_and_signs() {
        let d = parse_toml("a = 1_000\nb = +2\nc = -0.5\n").unwrap();
        assert_eq!(d.get("", "a").unwrap().as_i64(), Some(1000));
        assert_eq!(d.get("", "b").unwrap().as_i64(), Some(2));
        assert_eq!(d.get("", "c").unwrap().as_f64(), Some(-0.5));
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_usize(), Some(3));
        assert_eq!(Value::Int(-3).as_u64(), None);
        assert_eq!(Value::Float(1.0).as_i64(), None);
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
        assert_eq!(Value::Str("x".into()).as_f64(), None);
    }
}
