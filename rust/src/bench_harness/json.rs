//! Hand-rolled JSON for the bench telemetry (offline build — no serde):
//! a schema-stable writer for [`RunReport`] and a small RFC 8259 parser
//! (lenient only in accepting leading zeros in numbers) used by
//! `astir bench --compare` and the round-trip tests.
//!
//! ## The `astir-bench-v1` schema
//!
//! ```json
//! {
//!   "schema": "astir-bench-v1",
//!   "git_rev": "0123abcd4567" | null,
//!   "mode": "smoke" | "full",
//!   "suites": [{
//!     "name": "hot_path",
//!     "skipped": ["jumbo_step_sparse"],
//!     "benches": [{
//!       "name": "proxy_fused_15x1000",
//!       "scale": "standard" | "jumbo",
//!       "seed": 11,
//!       "dims": {"n": 1000, "m": 300, "b": 15, "s": 20} | null,
//!       "iters": 123456,
//!       "samples": 321,
//!       "mean_s": 1.1e-6, "std_s": 2.0e-8, "min_s": 1.0e-6,
//!       "throughput_iters_per_s": 9.1e5
//!     }]
//!   }]
//! }
//! ```
//!
//! Numbers are shortest-round-trip `f64` (or plain integers); non-finite
//! statistics (a dry-run record) serialize as `null` and parse back as
//! NaN. Integer fields (seed, iters, samples) follow the JSON interop
//! convention of at most 2^53 — larger values survive serialization but
//! lose precision through the `f64` parse, like in every JS consumer.
//! Key order is fixed — the snapshot test in
//! `rust/tests/bench_telemetry.rs` pins it.

use std::fmt::Write as _;
use std::path::Path;

use crate::metrics::{json_escape, json_f64, Stats};

use super::{BenchDims, BenchRecord, Mode, RunReport, Scale, SuiteReport, SCHEMA};

/// Serialize a [`RunReport`] as one line of schema-stable JSON.
pub fn report_to_json(report: &RunReport) -> String {
    let mut out = String::new();
    out.push_str("{\"schema\":\"");
    out.push_str(&json_escape(&report.schema));
    out.push_str("\",\"git_rev\":");
    match &report.git_rev {
        Some(rev) => {
            let _ = write!(out, "\"{}\"", json_escape(rev));
        }
        None => out.push_str("null"),
    }
    let _ = write!(out, ",\"mode\":\"{}\",\"suites\":[", report.mode.as_str());
    for (i, suite) in report.suites.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        suite_to_json(&mut out, suite);
    }
    out.push_str("]}");
    out
}

fn suite_to_json(out: &mut String, suite: &SuiteReport) {
    let _ = write!(out, "{{\"name\":\"{}\",\"skipped\":[", json_escape(&suite.name));
    for (i, s) in suite.skipped.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{}\"", json_escape(s));
    }
    out.push_str("],\"benches\":[");
    for (i, b) in suite.benches.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        bench_to_json(out, b);
    }
    out.push_str("]}");
}

fn bench_to_json(out: &mut String, b: &BenchRecord) {
    let _ = write!(
        out,
        "{{\"name\":\"{}\",\"scale\":\"{}\",\"seed\":{},\"dims\":",
        json_escape(&b.name),
        b.scale.as_str(),
        b.seed
    );
    match &b.dims {
        Some(d) => {
            let _ = write!(out, "{{\"n\":{},\"m\":{},\"b\":{},\"s\":{}}}", d.n, d.m, d.b, d.s);
        }
        None => out.push_str("null"),
    }
    let throughput = b.throughput();
    let _ = write!(
        out,
        ",\"iters\":{},\"samples\":{},\"mean_s\":{},\"std_s\":{},\"min_s\":{},\
         \"throughput_iters_per_s\":{}}}",
        b.iters,
        b.time.n,
        json_f64(b.time.mean),
        json_f64(b.time.std),
        json_f64(b.time.min),
        json_f64(throughput)
    );
}

/// Write a report to `path`, creating parent dirs.
pub fn write_report(report: &RunReport, path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, report_to_json(report))
}

/// Parse and validate an `astir-bench-v1` document back into a
/// [`RunReport`] (statistics not carried by the schema — max, median —
/// come back as NaN).
pub fn parse_report(text: &str) -> Result<RunReport, String> {
    let doc = Json::parse(text)?;
    let schema = req_str(&doc, "schema")?;
    if schema != SCHEMA {
        return Err(format!("unsupported bench schema `{schema}` (want `{SCHEMA}`)"));
    }
    let git_rev = match doc.get("git_rev") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(_) => return Err("git_rev must be a string or null".to_string()),
    };
    let mode_s = req_str(&doc, "mode")?;
    let mode = Mode::parse(&mode_s).ok_or_else(|| format!("unknown mode `{mode_s}`"))?;
    let suites = doc
        .get("suites")
        .and_then(Json::as_arr)
        .ok_or("missing `suites` array")?
        .iter()
        .map(parse_suite)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(RunReport { schema, git_rev, mode, suites })
}

fn parse_suite(j: &Json) -> Result<SuiteReport, String> {
    let name = req_str(j, "name")?;
    let skipped = j
        .get("skipped")
        .and_then(Json::as_arr)
        .ok_or("missing `skipped` array")?
        .iter()
        .map(|s| s.as_str().map(str::to_string).ok_or("skipped entries must be strings"))
        .collect::<Result<Vec<_>, _>>()?;
    let benches = j
        .get("benches")
        .and_then(Json::as_arr)
        .ok_or("missing `benches` array")?
        .iter()
        .map(parse_bench)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(SuiteReport { name, benches, skipped })
}

fn parse_bench(j: &Json) -> Result<BenchRecord, String> {
    let name = req_str(j, "name")?;
    let scale_s = req_str(j, "scale")?;
    let scale = Scale::parse(&scale_s).ok_or_else(|| format!("unknown scale `{scale_s}`"))?;
    let seed = req_num(j, "seed")? as u64;
    let dims = match j.get("dims") {
        None | Some(Json::Null) => None,
        Some(d) => Some(BenchDims {
            n: req_num(d, "n")? as usize,
            m: req_num(d, "m")? as usize,
            b: req_num(d, "b")? as usize,
            s: req_num(d, "s")? as usize,
        }),
    };
    let iters = req_num(j, "iters")? as usize;
    let samples = req_num(j, "samples")? as usize;
    let mean = opt_num(j, "mean_s");
    let std = opt_num(j, "std_s");
    let min = opt_num(j, "min_s");
    Ok(BenchRecord {
        name,
        scale,
        dims,
        seed,
        iters,
        time: Stats { n: samples, mean, std, min, max: f64::NAN, median: f64::NAN },
    })
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string field `{key}`"))
}

fn req_num(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing numeric field `{key}`"))
}

/// Numeric-or-null field (non-finite stats serialize as null → NaN).
fn opt_num(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

/// A parsed JSON value. Objects keep insertion order (no dedup — last
/// `get` match wins is not needed; first wins).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.i)
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // high surrogate: require a \uXXXX low pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            match char::from_u32(code) {
                                Some(ch) => out.push(ch),
                                None => return Err(self.err("invalid unicode escape")),
                            }
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                // Raw bytes: the input is a &str, so multibyte UTF-8
                // sequences are valid — copy them through byte-wise.
                _ => {
                    if c < 0x20 {
                        return Err(self.err("control character in string"));
                    }
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.s.len() && self.s[end] >= 0x80 {
                        end += 1;
                    }
                    // SAFETY-free: re-slice the original str boundaries.
                    let chunk = std::str::from_utf8(&self.s[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    out.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let Some(c) = self.peek() else {
                return Err(self.err("truncated \\u escape"));
            };
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("expected digit after decimal point"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).expect("ascii number");
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".to_string()));
    }

    #[test]
    fn parses_structures() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").and_then(Json::as_str), Some("x"));
        let arr = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""a\"b\\c\n\t\u00b5\ud83d\ude00""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\n\tµ😀"));
        // raw multibyte UTF-8 passes through
        assert_eq!(Json::parse("\"µs 😀\"").unwrap().as_str(), Some("µs 😀"));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "tru", "{", "[1,", "[1 2]", "{\"a\" 1}", "{\"a\":}", "\"\\q\"", "\"\\u12g4\"",
            "\"unterminated", "1.5 extra", "\"\\ud800x\"", "nul", "+1", "{1: 2}", "1.", "[1.e3]",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn escape_roundtrip_through_parser() {
        let original = "quote\" slash\\ tab\t newline\n µ";
        let doc = format!("\"{}\"", json_escape(original));
        assert_eq!(Json::parse(&doc).unwrap().as_str(), Some(original));
    }
}
