//! The named bench suite registry.
//!
//! Every benchmark in the repo is defined here, once, as a function that
//! registers [`BenchSpec`]s into a [`Suite`]; the `cargo bench` binaries
//! (`rust/benches/*.rs`) and the `astir bench` CLI both execute suites
//! from this registry, so a perf number means the same thing however it
//! was produced. Twelve suites, one per bench binary:
//!
//! * `hot_path` — kernel microbenches: roofline triad, gemv/proxy
//!   primitives, top-s + tally ops, full Alg.-2 steps, dense-vs-sparse at
//!   paper/stress/jumbo scales, contended tally, PJRT artifact path.
//! * `fig1`, `fig2_upper`, `fig2_lower`, `ablations`, `baselines` —
//!   the Monte-Carlo figure/ablation regenerators, registered as
//!   single-pass experiment benches (their trial counts, not repetition,
//!   supply the averaging) that still emit their `results/` tables.
//! * `stogradmp_async` — the §V extension: sequential StoGradMP vs the
//!   discrete-time sweep vs real-thread async wallclock per core count.
//! * `large_n` — the matrix-free subsampled-DCT operator at
//!   `n = 2^17 … 2^20`: transform-backed apply/adjoint/proxy microbenches
//!   plus an `n = 2^20, m = 3·10^5` asynchronous StoIHT run — shapes whose
//!   dense matrix (up to 2.4 TB) could never be materialized. Smoke-budgeted:
//!   every point runs in CI and is gated by the committed baseline.
//! * `throughput` — the recovery **service** measured as a service at
//!   `n = 2^17`: jobs/sec through the persistent pool vs spawn-per-call,
//!   and batched MMV lockstep recovery vs a sequential per-signal loop.
//! * `loadgen` — `astir serve` end-to-end over loopback TCP: open-loop
//!   Poisson arrivals at two offered rates, recording the window wall
//!   time plus the server's own p50/p99 request latency, with a warm
//!   operator-cache hit-ratio assertion.
//! * `sharded` — the bounded-staleness sharded tally: Monte-Carlo
//!   steps-to-converge over the `S × E` grid (`S ∈ {1,2,4,8}` shards,
//!   exchange every `E ∈ {1,4,16,64}` steps; `S = 1` is the unsharded
//!   reference), emitted as one recovery-vs-staleness table, plus a
//!   real-thread [`crate::service::ShardedPool`] wallclock point.
//! * `distributed` — the staleness grid again, but each `(S, E)` cell is
//!   a **multi-process fleet**: `S` `astir shard-worker` processes
//!   exchanging through an `astir exchange-hub` on loopback (when the
//!   CLI binary is reachable — `ASTIR_BIN` or running under
//!   `astir bench`; otherwise an in-process fleet over real loopback
//!   sockets), plus the in-process [`crate::service::ShardedPool`]
//!   reference at the same axes for the socket tax.
//!
//! Smoke mode shrinks the Monte-Carlo budgets to CI size; full mode keeps
//! the paper-ish defaults (`ASTIR_BENCH_TRIALS` raises them further).
//! Jumbo-tagged points are env-gated, see [`Suite::jumbo_gated`].

use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{thread, Arc};

use crate::algorithms::{Alg, StoGradMpKernel, StoihtKernel};
use crate::async_runtime::{run_async, run_async_with, AsyncOpts};
use crate::backend::{Backend, PjrtBackend};
use crate::config::ExperimentConfig;
use crate::coordinator::{run_trials, Leader};
use crate::experiments::{self, Fig2Variant};
use crate::linalg::{dot, plan_for, simd, DenseOp, Mat, MeasureOp, SparseIterate};
use crate::metrics::{stats, Table};
use crate::problem::{Ensemble, Problem, ProblemSpec};
use crate::report;
use crate::rng::Rng;
use crate::service::api::JobRequest;
use crate::service::server::{ServeOpts, Server};
use crate::service::transport::{run_worker, ExchangeHub, HubOpts, HubReport};
use crate::service::wire::Client;
use crate::service::{recover_batch_stoiht, solve_job, RecoveryPool, ShardedPool};
use crate::sim::{simulate_sharded, ShardOpts, SimOpts, SimOutcome, SpeedSchedule};
use crate::support::{top_s_into, union};
use crate::tally::{AtomicTally, TallyWeighting};

use super::{
    bench_header, git_rev, BenchSpec, Mode, RunOpts, RunReport, Scale, Suite, SuiteReport, SCHEMA,
};

/// A named, registered suite.
#[derive(Clone, Copy)]
pub struct SuiteDef {
    pub name: &'static str,
    pub about: &'static str,
    pub register: fn(&mut Suite),
}

/// The bench registry, in execution order.
pub fn registry() -> Vec<SuiteDef> {
    vec![
        SuiteDef {
            name: "hot_path",
            about: "kernel microbenches (proxy, top-s, tally, dense vs sparse, PJRT)",
            register: hot_path_suite,
        },
        SuiteDef {
            name: "fig1",
            about: "Fig. 1 — StoIHT vs oracle-support StoIHT",
            register: fig1_suite,
        },
        SuiteDef {
            name: "fig2_upper",
            about: "Fig. 2 upper — steps to exit vs cores, all fast",
            register: fig2_upper_suite,
        },
        SuiteDef {
            name: "fig2_lower",
            about: "Fig. 2 lower — steps to exit vs cores, half slow",
            register: fig2_lower_suite,
        },
        SuiteDef {
            name: "ablations",
            about: "A1–A4, A6 design-choice ablations",
            register: ablations_suite,
        },
        SuiteDef {
            name: "baselines",
            about: "A5 — phase-transition sweep over all solvers",
            register: baselines_suite,
        },
        SuiteDef {
            name: "stogradmp_async",
            about: "asynchronous StoGradMP — sequential vs async at the paper scale",
            register: stogradmp_async_suite,
        },
        SuiteDef {
            name: "large_n",
            about: "matrix-free subsampled DCT at n = 10^5…10^6 (no m x n matrix exists)",
            register: large_n_suite,
        },
        SuiteDef {
            name: "throughput",
            about: "recovery service jobs/sec — persistent pool vs spawn, batched vs sequential",
            register: throughput_suite,
        },
        SuiteDef {
            name: "loadgen",
            about: "astir serve over loopback — open-loop Poisson latency + operator cache",
            register: loadgen_suite,
        },
        SuiteDef {
            name: "sharded",
            about: "sharded tally — steps to converge over the S x E staleness grid",
            register: sharded_suite,
        },
        SuiteDef {
            name: "distributed",
            about: "multi-process sharded fleet over loopback — S x E grid through the hub",
            register: distributed_suite,
        },
    ]
}

/// Look up a suite by name.
pub fn find(name: &str) -> Option<SuiteDef> {
    registry().into_iter().find(|s| s.name == name)
}

/// Execute one suite under `opts`.
pub fn run_suite(def: &SuiteDef, opts: &RunOpts) -> SuiteReport {
    if !opts.dry_run {
        bench_header(&format!("suite {} — {}", def.name, def.about));
    }
    let mut suite = Suite::new(def.name, opts);
    (def.register)(&mut suite);
    suite.into_report()
}

/// Execute one suite, wrapped as a full telemetry report
/// (what `BENCH_<suite>.json` holds).
pub fn run_one(def: &SuiteDef, opts: &RunOpts) -> RunReport {
    RunReport {
        schema: SCHEMA.to_string(),
        git_rev: git_rev(),
        mode: opts.mode,
        suites: vec![run_suite(def, opts)],
    }
}

/// Execute every registered suite (the `astir bench` path). Per-bench
/// filtering still applies inside each suite.
pub fn run_all(opts: &RunOpts) -> RunReport {
    RunReport {
        schema: SCHEMA.to_string(),
        git_rev: git_rev(),
        mode: opts.mode,
        suites: registry().iter().map(|d| run_suite(d, opts)).collect(),
    }
}

/// Full-mode trial budget: `$ASTIR_BENCH_TRIALS` (default per suite).
pub fn bench_trials(default_trials: usize) -> usize {
    std::env::var("ASTIR_BENCH_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_trials)
}

/// Mode-scaled experiment config: full mode keeps the per-suite default
/// (raised by `ASTIR_BENCH_TRIALS`); smoke shrinks trials and the core
/// sweep to CI-sized numbers.
fn experiment_cfg(mode: Mode, full_default_trials: usize, smoke_trials: usize) -> ExperimentConfig {
    let mut cfg =
        ExperimentConfig { trials: bench_trials(full_default_trials), ..Default::default() };
    if mode == Mode::Smoke {
        cfg.trials = smoke_trials;
        cfg.cores = vec![1, 4];
    }
    cfg
}

/// Standard banner printed before an experiment suite runs.
pub fn banner(what: &str, cfg: &ExperimentConfig) {
    println!("\n################################################################");
    println!("# {what}");
    println!(
        "# n={} m={} b={} s={} gamma={} tol={:.0e} trials={} threads={}",
        cfg.problem.n,
        cfg.problem.m,
        cfg.problem.b,
        cfg.problem.s,
        cfg.gamma,
        cfg.tolerance,
        cfg.trials,
        cfg.trial_threads
    );
    println!("# (set ASTIR_BENCH_TRIALS=500 for the paper's full budget)");
    println!("################################################################");
}

/// Experiment-bench spec carrying the config's dims and seed.
fn expspec(name: &str, cfg: &ExperimentConfig) -> BenchSpec {
    BenchSpec::experiment(name)
        .dims(cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s)
        .seed(cfg.seed)
}

/// Results-table name for a suite emission. Smoke runs are CI-sized
/// (trials=2), so their tables get a `smoke_` prefix rather than
/// clobbering full-budget figure data under `results/`.
fn results_name(mode: Mode, name: &str) -> String {
    match mode {
        Mode::Full => name.to_string(),
        Mode::Smoke => format!("smoke_{name}"),
    }
}

// ---------------------------------------------------------------- hot_path

/// Dense-vs-sparse comparison at one problem scale: the fused proxy kernel
/// alone, then the full Alg.-2 step (proxy + identify + estimate). The
/// equivalence suite (`rust/tests/sparse_equivalence.rs`) proves the two
/// paths produce bit-identical iterates; this measures what sparsity buys.
fn sparse_vs_dense_at(suite: &mut Suite, label: &str, spec: &ProblemSpec, seed: u64, jumbo: bool) {
    let mk = |name: &str| {
        let s = BenchSpec::micro(&format!("{label}_{name}"))
            .dims(spec.n, spec.m, spec.b, spec.s)
            .seed(seed);
        if jumbo {
            s.jumbo()
        } else {
            s
        }
    };
    let specs = [mk("proxy_dense"), mk("proxy_sparse"), mk("step_dense"), mk("step_sparse")];
    if suite.is_dry_run() {
        // Listing: register every spec (Suite::bench handles gates)
        // without paying problem-generation setup.
        for s in specs {
            suite.bench(s, || {});
        }
        return;
    }
    if !specs.iter().any(|s| suite.wants(s)) {
        // Record the jumbo gate without paying the (~200 MB at n=10^5)
        // setup; filtered-out points stay silent.
        for s in &specs {
            if s.scale == Scale::Jumbo {
                suite.skip(&s.name, "jumbo scale gated (smoke mode / ASTIR_BENCH_SKIP_JUMBO)");
            }
        }
        return;
    }
    bench_header(&format!("sparse fast path — {label} (n={} b={} s={})", spec.n, spec.b, spec.s));
    let mut rng = Rng::seed_from(seed);
    let p: Problem = spec.generate(&mut rng);

    // A representative 2s-support iterate (Γ ∪ T̃) and tally estimate.
    let est: Vec<usize> = {
        let mut e = rng.subset(spec.n, spec.s);
        e.sort_unstable();
        e
    };
    let mut warm = StoihtKernel::new(&p, 1.0);
    let mut x_sparse = SparseIterate::zeros(spec.n);
    for _ in 0..5 {
        let b = warm.sample_block(&mut rng);
        warm.step_sparse(&mut x_sparse, b, Some(&est));
    }
    let x_dense: Vec<f64> = x_sparse.to_dense();

    let [pd_spec, ps_spec, sd_spec, ss_spec] = specs;

    // --- fused proxy kernel alone -----------------------------------
    let (blk, yb) = p.block(0);
    let mut scratch = vec![0.0; spec.b];
    let mut out = vec![0.0; spec.n];
    let dense_proxy = suite.bench(pd_spec, || {
        blk.proxy_step_into(yb, &x_dense, 1.0, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    let supp = x_sparse.support().to_vec();
    let sparse_proxy = suite.bench(ps_spec, || {
        blk.proxy_step_sparse_into(
            p.a_t(),
            0,
            yb,
            x_sparse.values(),
            &supp,
            1.0,
            &mut scratch,
            &mut out,
        );
        std::hint::black_box(&out);
    });
    if let (Some(d), Some(s)) = (&dense_proxy, &sparse_proxy) {
        println!(
            "  => proxy kernel speedup: {:.2}x (|supp| = {})",
            d.time.mean / s.time.mean,
            supp.len()
        );
    }

    // --- full Alg.-2 step (proxy + identify + estimate) -------------
    let mut kd = StoihtKernel::new(&p, 1.0);
    let mut xd = x_dense.clone();
    let mut rng_d = Rng::seed_from(seed ^ 0xBEEF);
    let dense_step = suite.bench(sd_spec, || {
        let b = kd.sample_block(&mut rng_d);
        std::hint::black_box(kd.step(&mut xd, b, Some(&est)));
    });
    let mut ks = StoihtKernel::new(&p, 1.0);
    let mut xs = x_sparse.clone();
    let mut rng_s = Rng::seed_from(seed ^ 0xBEEF);
    let sparse_step = suite.bench(ss_spec, || {
        let b = ks.sample_block(&mut rng_s);
        std::hint::black_box(ks.step_sparse(&mut xs, b, Some(&est)));
    });
    if let (Some(d), Some(s)) = (&dense_step, &sparse_step) {
        println!(
            "  => full-step speedup: {:.2}x ({} vs {} per iter)",
            d.time.mean / s.time.mean,
            super::human_time(d.time.mean),
            super::human_time(s.time.mean)
        );
    }
}

/// The `hot_path` suite: per-iteration cost centers of the whole stack,
/// with a STREAM-like roofline measured in the same process.
///
/// NOTE: the paper-scale setup below (a ~2.5 MB problem, a few ms) runs
/// even for dry/filtered invocations — the bench closures must be
/// constructible so the registered spec list is single-sourced and can
/// never diverge between listing and measuring. Only genuinely heavy
/// setup (the 8 MB triad buffers, stress/jumbo problems, worker threads,
/// PJRT) is gated behind `wants()`/`is_dry_run()`.
fn hot_path_suite(suite: &mut Suite) {
    let spec = ProblemSpec::paper();
    let mut rng = Rng::seed_from(1);
    let p = spec.generate(&mut rng);
    let x: Vec<f64> = (0..spec.n).map(|_| rng.gauss() * 0.1).collect();

    // --- memory roofline (in-process STREAM-like triad) -------------
    // Triad a[i] = b[i] + s*c[i] over an 8 MB working set.
    let mut triad_bw = None;
    let triad_spec = BenchSpec::micro("triad_1m").seed(0);
    if suite.wants(&triad_spec) && !suite.is_dry_run() {
        let nn = 1 << 20;
        let bsrc: Vec<f64> = (0..nn).map(|i| i as f64).collect();
        let csrc: Vec<f64> = (0..nn).map(|i| (i * 7) as f64).collect();
        let mut asink = vec![0.0f64; nn];
        let triad = suite.bench(triad_spec, || {
            for (a, (b, c)) in asink.iter_mut().zip(bsrc.iter().zip(&csrc)) {
                *a = b + 0.5 * c;
            }
            std::hint::black_box(&asink);
        });
        if let Some(t) = triad {
            let bw = 24e6 / t.time.mean / 1e9; // GB/s (3 streams x 8 B x 1M)
            println!("  => sustainable bandwidth ≈ {bw:.1} GB/s");
            triad_bw = Some(bw);
        }
    } else {
        suite.bench(triad_spec, || {});
    }

    // --- linalg primitives (paper shape) ----------------------------
    let blk_rows = spec.b;
    let a_blk =
        Mat::<f64>::from_fn(blk_rows, spec.n, |i, j| ((i * spec.n + j) as f64 * 0.37).sin());
    let yv: Vec<f64> = (0..blk_rows).map(|i| i as f64 * 0.1).collect();
    let mut scratch = vec![0.0; blk_rows];
    let mut out = vec![0.0; spec.n];
    let dims = |s: BenchSpec| s.dims(spec.n, spec.m, spec.b, spec.s).seed(1);
    suite.bench(dims(BenchSpec::micro("dot_n1000")), || {
        std::hint::black_box(dot(&x, &out));
    });
    suite.bench(dims(BenchSpec::micro("gemv_15x1000")), || {
        a_blk.as_block().gemv_into(&x, &mut scratch);
        std::hint::black_box(&scratch);
    });
    let proxy = suite.bench(dims(BenchSpec::micro("proxy_fused_15x1000")), || {
        a_blk.as_block().proxy_step_into(&yv, &x, 1.0, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    if let (Some(pr), Some(bw)) = (&proxy, triad_bw) {
        // Proxy traffic: A streamed twice (2 * 15k * 8 B) + vectors.
        let traffic = (2 * blk_rows * spec.n + 4 * spec.n + 2 * blk_rows) as f64 * 8.0;
        println!(
            "  => proxy streams {:.0} KB/iter at {:.1} GB/s ({:.0}% of triad roofline)",
            traffic / 1e3,
            traffic / pr.time.mean / 1e9,
            100.0 * (traffic / pr.time.mean / 1e9) / bw
        );
    }

    // --- transform core: fused radix-4 FFT vs radix-2 reference -----
    // One plan, one twiddle table, bit-identical output (pinned by
    // rust/tests/simd_parity.rs); the pair measures what pair fusion and
    // the bit-reversal table buy at a cache-resident size.
    let nt = 4096usize;
    let plan = plan_for(nt);
    let mut dct_scratch = plan.scratch();
    let xt: Vec<f64> = (0..nt).map(|i| (i as f64 * 0.41).sin()).collect();
    let mut out_t = vec![0.0; nt];
    let fused = suite.bench(BenchSpec::micro("transform_dct_fused_n4096").seed(1), || {
        plan.dct2_into(&xt, &mut dct_scratch, &mut out_t);
        std::hint::black_box(&out_t);
    });
    let radix2 = suite.bench(BenchSpec::micro("transform_dct_radix2_n4096").seed(1), || {
        plan.dct2_reference_into(&xt, &mut dct_scratch, &mut out_t);
        std::hint::black_box(&out_t);
    });
    if let (Some(f), Some(r)) = (&fused, &radix2) {
        println!("  => fused FFT vs radix-2 reference: {:.2}x", r.time.mean / f.time.mean);
    }

    // --- dispatched vs pinned-scalar kernels on the fused proxy ------
    // Both arms run the identical two-pass proxy over the same block; the
    // only difference is the kernel entry point, so the ratio isolates
    // what the `linalg::simd` doorway buys on the paper shape.
    let proxy_simd = suite.bench(dims(BenchSpec::micro("proxy_simd_15x1000")), || {
        for i in 0..blk_rows {
            scratch[i] = yv[i] - simd::dot(a_blk.row(i), &x);
        }
        out.copy_from_slice(&x);
        for i in 0..blk_rows {
            if scratch[i] != 0.0 {
                simd::axpy(scratch[i], a_blk.row(i), &mut out);
            }
        }
        std::hint::black_box(&out);
    });
    let proxy_scalar = suite.bench(dims(BenchSpec::micro("proxy_scalar_15x1000")), || {
        for i in 0..blk_rows {
            scratch[i] = yv[i] - simd::dot_scalar(a_blk.row(i), &x);
        }
        out.copy_from_slice(&x);
        for i in 0..blk_rows {
            if scratch[i] != 0.0 {
                simd::axpy_scalar(scratch[i], a_blk.row(i), &mut out);
            }
        }
        std::hint::black_box(&out);
    });
    if let (Some(v), Some(s)) = (&proxy_simd, &proxy_scalar) {
        println!(
            "  => SIMD proxy vs pinned scalar: {:.2}x (level {})",
            s.time.mean / v.time.mean,
            simd::level().as_str()
        );
    }

    // --- multi-RHS panel apply: the batch dim rides the SIMD lane ----
    let panel_op = DenseOp::new(a_blk.clone());
    let mut panel_scratch = panel_op.make_scratch();
    for bcols in [1usize, 4, 8] {
        let sp = dims(BenchSpec::micro(&format!("panel_apply_b{bcols}_15x1000")));
        let x_panel: Vec<f64> =
            (0..bcols * spec.n).map(|i| ((i * 13 % 101) as f64) * 0.01).collect();
        let mut out_panel = vec![0.0; bcols * blk_rows];
        suite.bench(sp, || {
            panel_op.apply_multi_into(&x_panel, &mut panel_scratch, &mut out_panel);
            std::hint::black_box(&out_panel);
        });
    }

    // --- support + tally ops ----------------------------------------
    let v: Vec<f64> = (0..spec.n).map(|i| ((i * 31 % 97) as f64) - 48.0).collect();
    let mut idx_scratch = Vec::new();
    let mut sel = vec![0usize; spec.s];
    suite.bench(dims(BenchSpec::micro("top_s_quickselect")), || {
        top_s_into(&v, spec.s, &mut idx_scratch, &mut sel);
        std::hint::black_box(&sel);
    });
    let tally = AtomicTally::new(spec.n, TallyWeighting::Progress);
    let gamma: Vec<usize> = (0..spec.s).map(|k| k * 37 % spec.n).collect();
    let mut sorted_gamma = gamma.clone();
    sorted_gamma.sort_unstable();
    suite.bench(dims(BenchSpec::micro("tally_commit")), || {
        tally.commit(&sorted_gamma, &sorted_gamma, 7);
    });
    let mut tally_scratch = Vec::new();
    suite.bench(dims(BenchSpec::micro("tally_estimate")), || {
        std::hint::black_box(tally.estimate(spec.s, &mut tally_scratch));
    });

    // --- full StoIHT iteration (native) -----------------------------
    let mut kernel = StoihtKernel::new(&p, 1.0);
    let mut xi = vec![0.0f64; spec.n];
    let mut block_rng = Rng::seed_from(3);
    let mut est_sorted: Vec<usize> = (0..spec.s).map(|k| k * 17 % spec.n).collect();
    est_sorted.sort_unstable();
    est_sorted.dedup();
    suite.bench(dims(BenchSpec::micro("full_step_sparse_exit")).seed(3), || {
        let b = kernel.sample_block(&mut block_rng);
        let gamma = kernel.step(&mut xi, b, Some(&est_sorted)).to_vec();
        let supp = union(&gamma, &est_sorted);
        std::hint::black_box(p.residual_norm_sparse(&xi, &supp));
    });
    suite.bench(dims(BenchSpec::micro("residual_dense")).seed(3), || {
        std::hint::black_box(p.residual_norm(&xi));
    });

    // --- dense vs sparse in the s ≪ n regime the paper targets ------
    sparse_vs_dense_at(suite, "paper", &ProblemSpec::paper(), 11, false);
    sparse_vs_dense_at(
        suite,
        "stress",
        &ProblemSpec { n: 10_000, m: 300, b: 15, s: 20, ..ProblemSpec::paper() },
        12,
        false,
    );
    sparse_vs_dense_at(
        suite,
        "jumbo",
        &ProblemSpec { n: 100_000, m: 120, b: 15, s: 50, ..ProblemSpec::paper() },
        13,
        true,
    );

    // --- atomic tally under contention (8 threads) ------------------
    let contended_spec = dims(BenchSpec::micro("tally_commit_contended")).seed(0);
    if suite.wants(&contended_spec) && !suite.is_dry_run() {
        let shared = Arc::new(AtomicTally::new(spec.n, TallyWeighting::Progress));
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for w in 0..7 {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            handles.push(thread::spawn(move || {
                let mut r = Rng::seed_from(w);
                let mut prev: Vec<usize> = Vec::new();
                let mut t = 1u64;
                // Relaxed: a shutdown flag with no payload to publish.
                while !stop.load(Ordering::Relaxed) {
                    let mut g = r.subset(1000, 20);
                    g.sort_unstable();
                    shared.commit(&g, &prev, t);
                    prev = g;
                    t += 1;
                }
            }));
        }
        let res = suite.bench(contended_spec, || {
            shared.commit(&sorted_gamma, &sorted_gamma, 9);
        });
        // Relaxed: same shutdown flag; the join below synchronizes.
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        if let Some(r) = res {
            println!("  => contended commit {}", super::human_time(r.time.mean));
        }
    } else {
        suite.bench(contended_spec, || {});
    }

    // --- PJRT artifact path (needs `make artifacts`) ----------------
    let tiny_spec = BenchSpec::micro("pjrt_stoiht_step_tiny").dims(32, 16, 4, 3).seed(2);
    let paper_spec = BenchSpec::micro("pjrt_stoiht_step_paper").dims(1000, 300, 15, 20).seed(3);
    if suite.is_dry_run() {
        suite.bench(tiny_spec, || {});
        suite.bench(paper_spec, || {});
    } else if suite.wants(&tiny_spec) || suite.wants(&paper_spec) {
        match PjrtBackend::from_default_dir() {
            Ok(mut be) => {
                let tiny = ProblemSpec::tiny().generate(&mut Rng::seed_from(2));
                let xt = vec![0.0f64; tiny.spec.n];
                let mask = vec![0.0f64; tiny.spec.n];
                // warm the executable cache outside the timer
                let _ = be.stoiht_step(&tiny, 0, &xt, 1.0, &mask).unwrap();
                suite.bench(tiny_spec, || {
                    std::hint::black_box(be.stoiht_step(&tiny, 0, &xt, 1.0, &mask).unwrap());
                });
                let paper = spec.generate(&mut Rng::seed_from(3));
                let xp = vec![0.0f64; spec.n];
                let maskp = vec![0.0f64; spec.n];
                let _ = be.stoiht_step(&paper, 0, &xp, 1.0, &maskp).unwrap();
                suite.bench(paper_spec, || {
                    std::hint::black_box(be.stoiht_step(&paper, 0, &xp, 1.0, &maskp).unwrap());
                });
            }
            Err(e) => {
                let why = format!("PJRT unavailable: {e}");
                suite.skip(&tiny_spec.name, &why);
                suite.skip(&paper_spec.name, &why);
            }
        }
    }
}

// ------------------------------------------------------------ experiments

/// Fig. 1 — mean recovery error vs iteration, plus the paper's headline
/// iteration-count claims at the 1e-5 error level.
fn fig1_suite(suite: &mut Suite) {
    let cfg = experiment_cfg(suite.mode(), 25, 2);
    let spec = expspec("mean_error_series", &cfg);
    if !suite.wants(&spec) {
        return;
    }
    if !suite.is_dry_run() {
        banner("Fig. 1 — mean recovery error vs iteration", &cfg);
    }
    let mut result = None;
    suite.bench(spec, || result = Some(experiments::fig1(&cfg)));
    let Some(out) = result else { return };
    let table = out.series;

    // Thin for the terminal; full series + summary to results/.
    let thin = table.thinned(100);
    let mode = suite.mode();
    report::emit(&results_name(mode, "fig1"), "Fig. 1 (every 100th iteration)", &thin);
    report::emit(&results_name(mode, "fig1_full"), "Fig. 1 full series", &table);
    report::emit(
        &results_name(mode, "fig1_summary"),
        "Fig. 1 per-variant convergence (variant 0=stoiht, 1..=alpha 0,.25,.5,.75,1)",
        &out.summary,
    );

    // Quantified paper claims at the 1e-5 error level.
    let thr = 1e-5;
    let std_it = experiments::fig1::iters_to_threshold(&table, 1, thr);
    println!("\niterations to mean error < {thr:.0e}:");
    let labels = ["stoiht", "alpha=0", "alpha=.25", "alpha=.5", "alpha=.75", "alpha=1"];
    for (k, label) in labels.iter().enumerate() {
        match experiments::fig1::iters_to_threshold(&table, k + 1, thr) {
            Some(it) => println!("  {label:>9}: {it}"),
            None => println!("  {label:>9}: (not reached)"),
        }
    }
    if let (Some(s), Some(a1)) = (std_it, experiments::fig1::iters_to_threshold(&table, 6, thr)) {
        println!(
            "\npaper claim `alpha=1 needs ~half the iterations`: ratio = {:.2}",
            a1 as f64 / s as f64
        );
    }
}

/// Shared Fig.-2 driver: one experiment bench per panel.
fn fig2_suite(suite: &mut Suite, variant: Fig2Variant, emit_name: &str, what: &str) {
    let mut cfg = experiment_cfg(suite.mode(), 30, 2);
    if matches!(variant, Fig2Variant::Lower { .. }) && !cfg.cores.contains(&2) {
        // The paper's lower panel headline is "no gain at c = 2".
        cfg.cores.push(2);
        cfg.cores.sort_unstable();
    }
    let spec = expspec("steps_vs_cores", &cfg);
    if !suite.wants(&spec) {
        return;
    }
    if !suite.is_dry_run() {
        banner(what, &cfg);
    }
    let mut result = None;
    suite.bench(spec, || result = Some(experiments::fig2(&cfg, variant)));
    let Some(table) = result else { return };
    report::emit(&results_name(suite.mode(), emit_name), variant.label(), &table);

    let std_mean = table.rows[0][4];
    println!("\nstandard StoIHT line: {std_mean:.0} steps");
    for row in &table.rows {
        println!(
            "  c={:<3} async {:6.0} ± {:4.0}  ({:4.2}x vs standard, conv {:.0}%)",
            row[0],
            row[1],
            row[2],
            std_mean / row[1],
            100.0 * row[3]
        );
    }
}

fn fig2_upper_suite(suite: &mut Suite) {
    fig2_suite(
        suite,
        Fig2Variant::Upper,
        "fig2_upper",
        "Fig. 2 upper — steps to exit vs cores (all fast)",
    );
}

fn fig2_lower_suite(suite: &mut Suite) {
    fig2_suite(
        suite,
        Fig2Variant::Lower { period: 4 },
        "fig2_lower",
        "Fig. 2 lower — half the cores slow (period 4)",
    );
    if !suite.is_dry_run() {
        println!("\npaper claim: c=2 ⇒ no improvement; larger c ⇒ improvement.");
    }
}

/// Ablations A1–A4 + A6, each its own filterable bench.
fn ablations_suite(suite: &mut Suite) {
    let cfg = experiment_cfg(suite.mode(), 15, 2);
    let mode = suite.mode();
    if !suite.is_dry_run() {
        banner("Ablations A1–A4, A6", &cfg);
    }

    let mut t1 = None;
    suite.bench(expspec("tally_vs_shared_x", &cfg), || {
        t1 = Some(experiments::tally_vs_shared_x(&cfg));
    });
    if let Some(t) = t1 {
        report::emit(
            &results_name(mode, "ablation_tally_vs_shared_x"),
            "A1: tally vs HOGWILD!-style shared x (half-slow schedule)",
            &t,
        );
        report::note(
            "paper §I: with dense cost functions, sharing x lets slow cores undo progress;",
        );
        report::note("sharing the passively-read tally is robust. Compare the *_conv columns.");
    }

    let mut t2 = None;
    suite.bench(expspec("inconsistent_reads", &cfg), || {
        t2 = Some(experiments::inconsistent_reads(&cfg));
    });
    if let Some(t) = t2 {
        report::emit(
            &results_name(mode, "ablation_inconsistent_reads"),
            "A2: per-coordinate stale-read probability",
            &t,
        );
    }

    let mut t3 = None;
    suite.bench(expspec("weighting", &cfg), || {
        t3 = Some(experiments::tally_weighting(&cfg));
    });
    if let Some(t) = t3 {
        report::emit(
            &results_name(mode, "ablation_weighting"),
            "A3: tally weighting schemes (half-slow schedule)",
            &t,
        );
        report::note(
            "paper Alg. 2 weights votes by local iteration (+t/−(t−1)) so fast cores dominate.",
        );
    }

    let sizes: &[usize] =
        if suite.mode() == Mode::Smoke { &[15, 50] } else { &[5, 10, 15, 25, 50, 75] };
    let mut t4 = None;
    suite.bench(expspec("block_size", &cfg), || {
        t4 = Some(experiments::block_size_sweep(&cfg, sizes));
    });
    if let Some(t) = t4 {
        report::emit(
            &results_name(mode, "ablation_block_size"),
            "A4: StoIHT iterations vs block size b (m = 300)",
            &t,
        );
    }

    let mut t6 = None;
    suite.bench(expspec("self_exclusion", &cfg), || {
        let leader = Leader::new(cfg.clone());
        let mut table = Table::new(&[
            "cores",
            "literal_mean",
            "literal_conv",
            "selfexcl_mean",
            "selfexcl_conv",
        ]);
        for &c in &cfg.cores {
            let lit = leader.monte_carlo_sim(
                c,
                &SpeedSchedule::AllFast,
                &SimOpts { max_steps: cfg.max_iters, ..Default::default() },
            );
            let sx = leader.monte_carlo_sim(
                c,
                &SpeedSchedule::AllFast,
                &SimOpts { max_steps: cfg.max_iters, self_exclude: true, ..Default::default() },
            );
            let mean = |o: &[SimOutcome]| {
                stats(&o.iter().map(|x| x.steps as f64).collect::<Vec<_>>()).mean
            };
            let conv =
                |o: &[SimOutcome]| o.iter().filter(|x| x.converged).count() as f64 / o.len() as f64;
            table.push_row(vec![c as f64, mean(&lit), conv(&lit), mean(&sx), conv(&sx)]);
        }
        t6 = Some(table);
    });
    if let Some(t) = t6 {
        report::emit(
            &results_name(mode, "ablation_self_exclusion"),
            "A6: literal Alg. 2 vs self-excluding tally reads",
            &t,
        );
        report::note(
            "self-exclusion makes c=1 degenerate exactly to Alg. 1, removing the small-c penalty.",
        );
    }
}

/// A5 — baseline phase-transition sweep over all five solvers.
fn baselines_suite(suite: &mut Suite) {
    let mut cfg = experiment_cfg(suite.mode(), 15, 3);
    // Phase transitions are the expensive sweep (5 solvers x trials x m).
    cfg.trials = cfg.trials.min(50);
    let ms: &[usize] =
        if suite.mode() == Mode::Smoke { &[120, 300] } else { &[60, 90, 120, 150, 180, 240, 300] };
    let spec = expspec("phase_transition", &cfg);
    if !suite.wants(&spec) {
        return;
    }
    if !suite.is_dry_run() {
        banner("A5 — success rate vs m (phase transition)", &cfg);
    }
    let mut result = None;
    suite.bench(spec, || result = Some(experiments::phase_transition(&cfg, ms)));
    let Some(table) = result else { return };
    report::emit(
        &results_name(suite.mode(), "baselines_phase_transition"),
        "A5: success rate vs m",
        &table,
    );
    report::note("success = relative recovery error < 1e-4; n=1000, s=20, Gaussian ensemble");
}

/// The `stogradmp_async` suite — the §V extension measured end-to-end:
/// sequential StoGradMP (Monte-Carlo mean wallclock + iteration count),
/// a discrete-time steps-vs-cores sweep mirroring Fig. 2 for the new
/// kernel, and real-thread async wallclock per core count at the paper's
/// problem scale.
fn stogradmp_async_suite(suite: &mut Suite) {
    let mut cfg = experiment_cfg(suite.mode(), 10, 2);
    cfg.alg = Alg::StoGradMp;
    // GradMP-family converges in tens of iterations; the paper's 1500-step
    // cap would only pad the non-convergent tail.
    cfg.max_iters = 300;
    let mode = suite.mode();
    let wants_any = suite.wants(&expspec("sequential", &cfg))
        || suite.wants(&expspec("steps_vs_cores", &cfg))
        || cfg.cores.iter().any(|&c| suite.wants(&expspec(&format!("wallclock_c{c}"), &cfg)));
    if !suite.is_dry_run() && wants_any {
        banner("asynchronous StoGradMP — sequential vs async", &cfg);
    }

    // Sequential reference: Monte-Carlo mean iterations-to-exit.
    let mut seq = None;
    suite.bench(expspec("sequential", &cfg), || {
        let leader = Leader::new(cfg.clone());
        seq = Some(leader.monte_carlo_seq(&leader.greedy_opts()));
    });
    if let Some(runs) = &seq {
        let iters: Vec<f64> = runs.iter().map(|r| r.iters as f64).collect();
        let conv = runs.iter().filter(|r| r.converged).count();
        let st = stats(&iters);
        println!(
            "  => sequential StoGradMP: {:.0} ± {:.0} iters to exit ({}/{} converged)",
            st.mean,
            st.std,
            conv,
            runs.len()
        );
    }

    // Discrete-time steps-vs-cores (the Fig.-2 semantics for this kernel).
    let mut table = None;
    suite.bench(expspec("steps_vs_cores", &cfg), || {
        table = Some(experiments::fig2(&cfg, Fig2Variant::Upper));
    });
    if let Some(t) = table {
        report::emit(
            &results_name(mode, "stogradmp_async_steps"),
            "asynchronous StoGradMP — time steps to exit vs cores (all fast)",
            &t,
        );
        let seq_mean = t.rows[0][4];
        for row in &t.rows {
            println!(
                "  c={:<3} async {:6.1} steps ({:4.2}x vs sequential, conv {:.0}%)",
                row[0],
                row[1],
                seq_mean / row[1].max(1e-9),
                100.0 * row[3]
            );
        }
    }

    // Real-thread wallclock per core count: the measured version of the
    // paper's "a speedup in total time is expected" for the new kernel.
    // One shared instance, generated OUTSIDE the timed closures — the
    // telemetry the CI gate compares must hold solve time only.
    let wall_specs: Vec<(usize, BenchSpec)> =
        cfg.cores.iter().map(|&c| (c, expspec(&format!("wallclock_c{c}"), &cfg))).collect();
    if suite.is_dry_run() {
        for (_, spec) in wall_specs {
            suite.bench(spec, || {});
        }
        return;
    }
    if !wall_specs.iter().any(|(_, s)| suite.wants(s)) {
        return;
    }
    let mut rng = Rng::seed_from(cfg.seed);
    let p = cfg.problem.generate(&mut rng);
    for (c, spec) in wall_specs {
        let mut outcome = None;
        suite.bench(spec, || {
            let opts = AsyncOpts {
                tolerance: cfg.tolerance,
                max_local_iters: cfg.max_iters,
                ..Default::default()
            };
            let out = run_async_with(&p, c, &opts, cfg.seed ^ c as u64, StoGradMpKernel::new);
            outcome = Some((out.converged, out.wall));
        });
        if let Some((converged, wall)) = outcome {
            println!("  => c={c}: wall {:.1?} (converged={converged})", wall);
        }
    }
}

/// The `large_n` suite — million-dimension recovery through the
/// matrix-free [`crate::linalg::SubsampledDctOp`]. Two shapes:
///
/// * `n = 2^17 (131k), m = 30 000` — apply/adjoint/sparse-proxy
///   microbenches (one fast transform each; the dense pair would need
///   63 GB), plus two A/B pairs for the PR-8 kernel work: the pair-fused
///   cache-blocked FFT vs the retained radix-2 reference, and the
///   dispatched SIMD proxy vs the pinned scalar kernels.
/// * `n = 2^20 (1.05M), m = 300 000` — a full-transform apply microbench
///   plus a 4-worker asynchronous StoIHT recovery run, fixed local
///   iteration budget (StoIHT needs hundreds of iterations to converge at
///   this shape; the bench measures async solve throughput, and the dense
///   pair would need 2.4 TB — this shape *only exists* matrix-free).
///
/// Nothing here is jumbo-gated: the operator stores `O(m + n)` floats, so
/// even the `n = 2^20` point runs inside the CI smoke budget and under the
/// committed `baseline_smoke.json` regression gate.
fn large_n_suite(suite: &mut Suite) {
    let shape = |name: &str, n: usize, m: usize, seed: u64| {
        BenchSpec::micro(name).dims(n, m, 15, 50).seed(seed)
    };
    let (n_s, m_s) = (1usize << 17, 30_000usize);
    let (n_l, m_l) = (1usize << 20, 300_000usize);
    let apply_s = shape("dct_apply_n131k", n_s, m_s, 40);
    let adjoint_s = shape("dct_adjoint_n131k", n_s, m_s, 40);
    let proxy_s = shape("proxy_sparse_n131k", n_s, m_s, 40);
    let fused_s = shape("dct_fused_n131k", n_s, m_s, 40);
    let radix2_s = shape("dct_radix2_n131k", n_s, m_s, 40);
    let simd_s = shape("proxy_simd_15x131k", n_s, m_s, 42);
    let scalar_s = shape("proxy_scalar_15x131k", n_s, m_s, 42);
    let apply_l = shape("dct_apply_n1m", n_l, m_l, 44);
    let async_l = BenchSpec::experiment("stoiht_async_n1m").dims(n_l, m_l, 15, 50).seed(44);
    if suite.is_dry_run() {
        for s in [
            apply_s, adjoint_s, proxy_s, fused_s, radix2_s, simd_s, scalar_s, apply_l, async_l,
        ] {
            suite.bench(s, || {});
        }
        return;
    }
    let mf_spec = |n: usize, m: usize| ProblemSpec {
        n,
        m,
        b: 15,
        s: 50,
        ensemble: Ensemble::PartialDct,
        dense_a: false,
        ..ProblemSpec::paper()
    };

    // --- n = 2^17: operator primitives -------------------------------
    if [&apply_s, &adjoint_s, &proxy_s].iter().any(|s| suite.wants(s)) {
        bench_header(&format!("matrix-free operator — n = {n_s}, m = {m_s}"));
        let p = mf_spec(n_s, m_s).generate(&mut Rng::seed_from(40));
        let mut scratch = p.op.make_scratch();
        let x: Vec<f64> = (0..n_s).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut out_m = vec![0.0; m_s];
        suite.bench(apply_s, || {
            p.op.apply_into(&x, &mut scratch, &mut out_m);
            std::hint::black_box(&out_m);
        });
        let r: Vec<f64> = (0..m_s).map(|i| (i as f64 * 0.73).cos()).collect();
        let mut out_n = vec![0.0; n_s];
        suite.bench(adjoint_s, || {
            p.op.apply_t_into(&r, &mut scratch, &mut out_n);
            std::hint::black_box(&out_n);
        });
        // The async hot path: sparse proxy on one block with a 2s-support
        // iterate (Γ ∪ T̃).
        let mut supp = Rng::seed_from(41).subset(n_s, 100);
        supp.sort_unstable();
        let mut xs = vec![0.0; n_s];
        for (q, &j) in supp.iter().enumerate() {
            xs[j] = 0.1 + q as f64 * 0.01;
        }
        let mut resid = vec![0.0; p.spec.b];
        let yb: Vec<f64> = p.y_block(0).to_vec();
        suite.bench(proxy_s, || {
            p.op.block_proxy_step_sparse(
                0,
                &yb,
                &xs,
                &supp,
                1.0,
                &mut resid,
                &mut scratch,
                &mut out_n,
            );
            std::hint::black_box(&out_n);
        });
    }

    // --- n = 2^17: fused radix-4 FFT vs the radix-2 reference ---------
    // Same plan, bit-identical output; at this length (odd lg n → the
    // 2^13 depth-first block) the cache-blocked schedule is engaged, so
    // this pair is the headline transform-rewrite measurement.
    if [&fused_s, &radix2_s].iter().any(|s| suite.wants(s)) {
        bench_header(&format!("transform core — fused vs radix-2 at n = {n_s}"));
        let plan = plan_for(n_s);
        let mut ds = plan.scratch();
        let x: Vec<f64> = (0..n_s).map(|i| (i as f64 * 0.29).sin()).collect();
        let mut out = vec![0.0; n_s];
        let f = suite.bench(fused_s, || {
            plan.dct2_into(&x, &mut ds, &mut out);
            std::hint::black_box(&out);
        });
        let r = suite.bench(radix2_s, || {
            plan.dct2_reference_into(&x, &mut ds, &mut out);
            std::hint::black_box(&out);
        });
        if let (Some(f), Some(r)) = (&f, &r) {
            println!("  => fused/blocked FFT speedup: {:.2}x", r.time.mean / f.time.mean);
        }
    }

    // --- n = 2^17: dispatched vs pinned-scalar proxy kernels ----------
    // A 15-row dense block at this width streams ~16 MB per pass, so the
    // A/B shows the doorway's effect where memory bandwidth, not issue
    // width, is the roofline.
    if [&simd_s, &scalar_s].iter().any(|s| suite.wants(s)) {
        bench_header(&format!("dispatched vs scalar proxy — 15 x {n_s} dense block"));
        let rows = 15usize;
        let a = Mat::<f64>::from_fn(rows, n_s, |i, j| ((i * n_s + j) as f64 * 0.19).sin());
        let yv: Vec<f64> = (0..rows).map(|i| i as f64 * 0.3).collect();
        let x: Vec<f64> = (0..n_s).map(|i| (i as f64 * 0.53).cos() * 0.1).collect();
        let mut resid = vec![0.0; rows];
        let mut out = vec![0.0; n_s];
        let vec_rec = suite.bench(simd_s, || {
            for i in 0..rows {
                resid[i] = yv[i] - simd::dot(a.row(i), &x);
            }
            out.copy_from_slice(&x);
            for i in 0..rows {
                if resid[i] != 0.0 {
                    simd::axpy(resid[i], a.row(i), &mut out);
                }
            }
            std::hint::black_box(&out);
        });
        let sc_rec = suite.bench(scalar_s, || {
            for i in 0..rows {
                resid[i] = yv[i] - simd::dot_scalar(a.row(i), &x);
            }
            out.copy_from_slice(&x);
            for i in 0..rows {
                if resid[i] != 0.0 {
                    simd::axpy_scalar(resid[i], a.row(i), &mut out);
                }
            }
            std::hint::black_box(&out);
        });
        if let (Some(v), Some(s)) = (&vec_rec, &sc_rec) {
            println!(
                "  => SIMD proxy vs pinned scalar: {:.2}x (level {})",
                s.time.mean / v.time.mean,
                simd::level().as_str()
            );
        }
    }

    // --- n = 2^20: the shape that only exists matrix-free -------------
    if !(suite.wants(&apply_l) || suite.wants(&async_l)) {
        return;
    }
    bench_header(&format!("matrix-free operator — n = {n_l}, m = {m_l} (dense pair: 2.4 TB)"));
    let p = mf_spec(n_l, m_l).generate(&mut Rng::seed_from(44));
    if suite.wants(&apply_l) {
        let mut scratch = p.op.make_scratch();
        let x: Vec<f64> = (0..n_l).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut out_m = vec![0.0; m_l];
        suite.bench(apply_l, || {
            p.op.apply_into(&x, &mut scratch, &mut out_m);
            std::hint::black_box(&out_m);
        });
    }
    if suite.wants(&async_l) {
        let iters = if suite.mode() == Mode::Smoke { 30 } else { 150 };
        let mut outcome = None;
        suite.bench(async_l, || {
            let opts = AsyncOpts {
                max_local_iters: iters,
                check_every: 8,
                ..Default::default()
            };
            outcome = Some(run_async_with(&p, 4, &opts, 77, |prob| StoihtKernel::new(prob, 1.0)));
        });
        if let Some(out) = outcome {
            let done: u64 = out.local_iters.iter().sum();
            println!(
                "  => 4 workers, {done} local iterations total (cap {iters}/worker), \
                 converged={} — no m x n matrix was ever allocated",
                out.converged
            );
        }
    }
}

/// The `throughput` suite — the recovery service measured **as a
/// service** at `n = 2^17`, matrix-free subsampled DCT (one operator
/// drawn once and shared by `Arc` across every job):
///
/// * `pool_jobs_c4` vs `spawn_jobs_c4` — 8 independent single-signal jobs
///   on 4 workers. Both arms run the identical per-job solve with
///   identical seeds (the pool's RNG splitting is `run_trials`'); the
///   pool amortizes thread spawn and queue setup across calls.
/// * `sequential_b8` vs `batched_b8` — 8 MMV signals sharing one operator
///   and one planted support, both arms single-threaded: a per-signal
///   loop of independent solves vs the lockstep batched path (one
///   multi-RHS fused proxy per time step + a tally **shared across the
///   batch**). The shared tally concentrates votes `B`x faster, so
///   per-signal iterations drop the way Fig. 2's steps-to-exit drop with
///   cores — the batched arm's jobs/sec win is structural (fewer
///   iterations), not a constant-factor trick.
///
/// Everything is standard scale and single-pass (experiment budgets), so
/// the whole suite runs in CI smoke under the committed baseline gate.
fn throughput_suite(suite: &mut Suite) {
    // M = m/b = 8 blocks: StoIHT's iteration count scales with the block
    // count (~M·ln(1/tol) — the expected update contracts by (1 − 1/M)),
    // so a small M keeps each job at a few hundred O(n log n) transforms
    // and the whole suite inside the CI smoke budget.
    let (n, m, b, s) = (1usize << 17, 4096usize, 512usize, 16usize);
    let jobs = 8usize;
    let shape = |name: &str, seed: u64| BenchSpec::experiment(name).dims(n, m, b, s).seed(seed);
    let pool_spec = shape("pool_jobs_c4", 60);
    let spawn_spec = shape("spawn_jobs_c4", 60);
    let seq_spec = shape("sequential_b8", 61);
    let bat_spec = shape("batched_b8", 61);
    if suite.is_dry_run() {
        for sp in [pool_spec, spawn_spec, seq_spec, bat_spec] {
            suite.bench(sp, || {});
        }
        return;
    }
    let mf = ProblemSpec {
        n,
        m,
        b,
        s,
        ensemble: Ensemble::PartialDct,
        dense_a: false,
        ..ProblemSpec::paper()
    };
    // Tolerance-based exit with a generous cap: the comparisons below are
    // about how FAST each serving architecture reaches the same tolerance.
    // check_every = 5 amortizes the exit transform (one dct2 per check,
    // comparable to an iteration) identically across all four arms.
    let opts = AsyncOpts { max_local_iters: 2000, check_every: 5, ..Default::default() };

    // --- persistent pool vs spawn-per-call ---------------------------
    if suite.wants(&pool_spec) || suite.wants(&spawn_spec) {
        bench_header(&format!("recovery service — {jobs} jobs at n = {n}, pool vs spawn"));
        let mut rng = Rng::seed_from(60);
        let op = mf.draw_operator(&mut rng);
        let ps: Arc<Vec<Problem>> =
            Arc::new((0..jobs).map(|_| mf.generate_with_op(&op, &mut rng)).collect());
        // Spawned once, OUTSIDE the timed region — that is the point.
        let pool = RecoveryPool::new(4);
        let pool_rec = suite.bench(pool_spec, || {
            let jp = Arc::clone(&ps);
            let jo = opts.clone();
            let outs = pool.run_jobs(jobs, 123, move |i, r| {
                let seed = r.next_u64();
                solve_job(&jp[i], Alg::Stoiht, &jo, seed)
            });
            assert!(outs.iter().all(|o| o.converged), "pool jobs must converge");
            std::hint::black_box(&outs);
        });
        let spawn_rec = suite.bench(spawn_spec, || {
            // Today's architecture: scoped trial threads + one fresh OS
            // thread per job inside run_async (cores = 1). Same seeds,
            // same solves — run_trials and the pool split RNGs alike.
            let outs = run_trials(jobs, 4, 123, |i, r| {
                let seed = r.next_u64();
                run_async(&ps[i], 1, &opts, seed)
            });
            assert!(outs.iter().all(|o| o.converged), "spawned jobs must converge");
            std::hint::black_box(&outs);
        });
        if let (Some(p), Some(sp)) = (&pool_rec, &spawn_rec) {
            println!(
                "  => pool {:.2} jobs/s vs spawn-per-call {:.2} jobs/s ({:.2}x)",
                jobs as f64 / p.time.mean,
                jobs as f64 / sp.time.mean,
                sp.time.mean / p.time.mean
            );
        }
    }

    // --- batched MMV lockstep vs sequential per-signal loop ----------
    if !(suite.wants(&seq_spec) || suite.wants(&bat_spec)) {
        return;
    }
    bench_header(&format!("batched MMV recovery — {jobs} signals, one operator, n = {n}"));
    let mut rng = Rng::seed_from(61);
    let op = mf.draw_operator(&mut rng);
    let mmv = mf.generate_mmv_with_op(&op, &mut rng, jobs);
    let seq_rec = suite.bench(seq_spec, || {
        for (c, p) in mmv.iter().enumerate() {
            let out = solve_job(p, Alg::Stoiht, &opts, 500 + c as u64);
            assert!(out.converged, "sequential signal {c} must converge");
            std::hint::black_box(&out);
        }
    });
    let bat_rec = suite.bench(bat_spec, || {
        let out = recover_batch_stoiht(&mmv, &opts, 500);
        assert!(out.all_converged(), "batched signals must converge");
        std::hint::black_box(&out);
    });
    if let (Some(sq), Some(bt)) = (&seq_rec, &bat_rec) {
        println!(
            "  => batched {:.2} signals/s vs sequential {:.2} signals/s ({:.2}x jobs/sec)",
            jobs as f64 / bt.time.mean,
            jobs as f64 / sq.time.mean,
            sq.time.mean / bt.time.mean
        );
    }
}

/// One offered rate of the `loadgen` suite: bind a fresh in-process
/// [`Server`] on a loopback ephemeral port, fire `reqs` at Poisson
/// arrival times (exponential inter-arrivals precomputed from a seeded
/// [`Rng`], so the offered load never adapts to server backpressure the
/// way closed-loop clients do), then pull the server's own telemetry.
///
/// Three records ride on one window: the timed `window_spec` bench (wall
/// time until every reply landed) and the p50/p99 request latencies via
/// [`Suite::record_metric`]. Filtering out the window spec drops the
/// whole trio — the percentiles only exist once the window has run.
fn loadgen_run_rate(
    suite: &mut Suite,
    reqs: &[JobRequest],
    rate_hz: f64,
    window_spec: BenchSpec,
    p50_spec: BenchSpec,
    p99_spec: BenchSpec,
) {
    if !suite.wants(&window_spec) {
        return;
    }
    let mut arr = Rng::seed_from(window_spec.seed ^ 0xA55A);
    let mut t = 0.0f64;
    let offsets: Vec<f64> = reqs
        .iter()
        .map(|_| {
            t += -(1.0 - arr.next_f64()).ln() / rate_hz;
            t
        })
        .collect();
    let opts = ServeOpts {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        batch_window_ms: 2,
        max_inflight: reqs.len().max(64),
    };
    let server = Server::bind(opts).expect("bind loopback").spawn().expect("spawn serve thread");
    let addr = server.addr().to_string();
    suite.bench(window_spec, || {
        let start = Instant::now();
        let mut handles = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            let req = req.clone();
            let addr = addr.clone();
            let off = Duration::from_secs_f64(offsets[i]);
            let h = thread::Builder::new()
                .name(format!("loadgen-{i}"))
                .spawn(move || {
                    let now = start.elapsed();
                    if off > now {
                        thread::sleep(off - now);
                    }
                    let mut client = Client::connect(&addr).expect("connect loopback");
                    let resp = client.job(&req).expect("transport").expect("typed reply");
                    assert!(resp.converged, "open-loop job must converge");
                })
                .expect("spawn client thread");
            handles.push(h);
        }
        for h in handles {
            h.join().expect("client thread");
        }
    });
    let snap = server.stats();
    server.stop();
    assert_eq!(snap.served, reqs.len() as u64, "every offered job must be served");
    assert_eq!(snap.rejected, 0, "open-loop window must not hit admission control");
    let ratio = snap.cache_hit_ratio();
    assert!(ratio >= 0.5, "operator cache too cold: hit ratio {ratio:.2}");
    println!(
        "  => rate {rate_hz:.0}/s: cache {}/{} hits (ratio {:.2}), p50 {} p99 {}",
        snap.cache_hits,
        snap.cache_hits + snap.cache_misses,
        ratio,
        super::human_time(snap.p50_s),
        super::human_time(snap.p99_s)
    );
    suite.record_metric(p50_spec, snap.p50_s);
    suite.record_metric(p99_spec, snap.p99_s);
}

/// The `loadgen` suite — `astir serve` measured end-to-end over loopback
/// TCP. Jobs cycle over three operator seeds with client-generated `y`
/// measurements (same seed ⇒ warm-cache hit, fresh signal — exactly how
/// an MMV client drives the server), at two offered Poisson rates. Each
/// rate contributes a timed window bench plus the server's own p50/p99
/// request latency through the `astir-bench-v1` schema, so CI's baseline
/// gate covers tail latency, not just throughput.
fn loadgen_suite(suite: &mut Suite) {
    let (n, m, b, s) = (4096usize, 1024usize, 128usize, 16usize);
    let shape = |name: &str, seed: u64| BenchSpec::experiment(name).dims(n, m, b, s).seed(seed);
    let lo = shape("open_loop_lo", 80);
    let lo_p50 = shape("p50_lo", 80);
    let lo_p99 = shape("p99_lo", 80);
    let hi = shape("open_loop_hi", 81);
    let hi_p50 = shape("p50_hi", 81);
    let hi_p99 = shape("p99_hi", 81);
    if suite.is_dry_run() {
        for sp in [lo, lo_p50, lo_p99, hi, hi_p50, hi_p99] {
            suite.bench(sp, || {});
        }
        return;
    }
    if ![&lo, &lo_p50, &lo_p99, &hi, &hi_p50, &hi_p99].iter().any(|sp| suite.wants(sp)) {
        return;
    }
    let jobs = if suite.mode() == Mode::Smoke { 24 } else { 96 };
    bench_header(&format!("astir serve load generator — {jobs} jobs per offered rate, n = {n}"));
    let mf = ProblemSpec {
        n,
        m,
        b,
        s,
        ensemble: Ensemble::PartialDct,
        dense_a: false,
        ..ProblemSpec::paper()
    };
    let op_seeds = [70u64, 71, 72];
    let mut sig_rng = Rng::seed_from(83);
    let reqs: Vec<JobRequest> = (0..jobs)
        .map(|i| {
            let base = JobRequest::from_spec(&mf, op_seeds[i % op_seeds.len()]);
            let op = base.draw_operator();
            let p = mf.generate_with_op(&op, &mut sig_rng);
            JobRequest { y: Some(p.y.clone()), ..base }
        })
        .collect();
    loadgen_run_rate(suite, &reqs, 20.0, lo, lo_p50, lo_p99);
    loadgen_run_rate(suite, &reqs, 80.0, hi, hi_p50, hi_p99);
}

// ----------------------------------------------------------------- sharded

/// The `sharded` suite — recovery vs staleness for the bounded-staleness
/// sharded-tally design. One Monte-Carlo bench per shard count `S`, each
/// sweeping the exchange period `E`; all 16 grid cells land in a single
/// `sharded_staleness` results table through the standard report layer.
/// The `S = 1` row is the unsharded single-tally simulator by construction
/// (pinned bit-identical in `sim::tests`), so the table reads as "what
/// does sharding + staleness cost relative to the paper's shared tally".
/// A final real-thread point runs [`ShardedPool`] at `S = 4, E = 16`.
fn sharded_suite(suite: &mut Suite) {
    let cfg = experiment_cfg(suite.mode(), 20, 2);
    let mode = suite.mode();
    const SHARDS: [usize; 4] = [1, 2, 4, 8];
    const PERIODS: [usize; 4] = [1, 4, 16, 64];
    let grid_specs: Vec<(usize, BenchSpec)> =
        SHARDS.iter().map(|&s| (s, expspec(&format!("staleness_s{s}"), &cfg))).collect();
    let pool_spec = expspec("pool_s4", &cfg);
    if suite.is_dry_run() {
        for (_, spec) in grid_specs {
            suite.bench(spec, || {});
        }
        suite.bench(pool_spec, || {});
        return;
    }
    if grid_specs.iter().any(|(_, sp)| suite.wants(sp)) || suite.wants(&pool_spec) {
        banner("sharded tally — steps to converge vs staleness bound E", &cfg);
    }

    let mut table = Table::new(&["shards", "exchange_period", "mean_steps", "std_steps", "conv"]);
    for (s, spec) in grid_specs {
        let mut rows = None;
        suite.bench(spec, || {
            let mut out_rows = Vec::new();
            for &e in &PERIODS {
                let so = ShardOpts { shards: s, exchange_period: e, ..Default::default() };
                let sim_opts = SimOpts { max_steps: cfg.max_iters, ..Default::default() };
                let outs: Vec<SimOutcome> =
                    run_trials(cfg.trials, cfg.trial_threads, cfg.seed, |_i, rng| {
                        // The Leader's monte_carlo_sim derivation: fresh
                        // problem from the trial stream, solver RNG split.
                        let p = cfg.problem.generate(rng);
                        let mut sim_rng = rng.split(0x519);
                        simulate_sharded(&p, &so, &SpeedSchedule::AllFast, &sim_opts, &mut sim_rng)
                    });
                let steps: Vec<f64> = outs.iter().map(|o| o.steps as f64).collect();
                let st = stats(&steps);
                let conv =
                    outs.iter().filter(|o| o.converged).count() as f64 / outs.len().max(1) as f64;
                out_rows.push(vec![s as f64, e as f64, st.mean, st.std, conv]);
            }
            rows = Some(out_rows);
        });
        if let Some(rows) = rows {
            for r in rows {
                println!(
                    "  S={:.0} E={:<3.0} {:7.1} ± {:6.1} steps (conv {:.0}%)",
                    r[0],
                    r[1],
                    r[2],
                    r[3],
                    100.0 * r[4]
                );
                table.push_row(r);
            }
        }
    }
    if !table.rows.is_empty() {
        report::emit(
            &results_name(mode, "sharded_staleness"),
            "sharded tally — time steps to converge over the S x E grid (all fast)",
            &table,
        );
    }

    // Real-thread wallclock: the ShardedPool at a mid-grid point. The
    // problem is generated OUTSIDE the timed closure — the CI-gated
    // telemetry must hold solve time only.
    if !suite.wants(&pool_spec) {
        return;
    }
    let mut rng = Rng::seed_from(cfg.seed);
    let p = cfg.problem.generate(&mut rng);
    let mut outcome = None;
    suite.bench(pool_spec, || {
        let opts = AsyncOpts {
            tolerance: cfg.tolerance,
            max_local_iters: cfg.max_iters,
            ..Default::default()
        };
        let so = ShardOpts { shards: 4, exchange_period: 16, ..Default::default() };
        let out = ShardedPool::new(so).run(&p, Alg::Stoiht, &opts, cfg.seed ^ 4);
        outcome = Some((out.converged(), out.rounds, out.wall));
    });
    if let Some((converged, rounds, wall)) = outcome {
        println!("  => pool S=4 E=16: wall {wall:.1?}, {rounds} round(s), converged={converged}");
    }
}

/// Resolve the `astir` CLI binary for process-fleet benches: `ASTIR_BIN`
/// wins, else the current executable when it *is* the CLI (i.e. the suite
/// runs under `astir bench`). `None` under `cargo bench` harness binaries
/// — those fall back to an in-process fleet over real loopback sockets.
fn astir_bin() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("ASTIR_BIN") {
        let p = std::path::PathBuf::from(p);
        if p.is_file() {
            return Some(p);
        }
    }
    let exe = std::env::current_exe().ok()?;
    match exe.file_stem().and_then(|s| s.to_str()) {
        Some("astir") => Some(exe),
        _ => None,
    }
}

/// Child processes killed on drop, so a failed fleet cell cannot leak
/// hubs/workers into later benches.
struct FleetGuard(Vec<std::process::Child>);

impl Drop for FleetGuard {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// One `(S, E)` fleet over real processes: spawn `astir exchange-hub` on
/// an ephemeral loopback port, scrape its address, launch `S`
/// `astir shard-worker` processes with the suite's problem flags, and
/// wait the whole fleet out. Returns `(rounds, clean)` scraped from the
/// hub's `hub-report` line.
fn run_process_fleet(
    bin: &std::path::Path,
    cfg: &ExperimentConfig,
    s: usize,
    e: usize,
) -> (u64, bool) {
    use std::io::BufRead;
    use std::process::{Command, Stdio};
    let mut hub = Command::new(bin)
        .args(["exchange-hub", "--addr", "127.0.0.1:0", "--shards", &s.to_string()])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .stdin(Stdio::null())
        .spawn()
        .expect("spawn astir exchange-hub");
    let hub_out = hub.stdout.take().expect("piped hub stdout");
    let mut guard = FleetGuard(vec![hub]);
    let mut lines = std::io::BufReader::new(hub_out).lines();
    let addr = loop {
        match lines.next() {
            Some(Ok(line)) => {
                if let Some(rest) = line.strip_prefix("listening on ") {
                    break rest.trim().to_string();
                }
            }
            _ => panic!("exchange-hub exited before printing its address"),
        }
    };
    let p = &cfg.problem;
    for k in 0..s {
        let worker = Command::new(bin)
            .args(["shard-worker", "--hub", &addr, "--shard", &k.to_string()])
            .args(["--shards", &s.to_string(), "--exchange-period", &e.to_string()])
            .args(["--n", &p.n.to_string(), "--m", &p.m.to_string()])
            .args(["--b", &p.b.to_string(), "--s", &p.s.to_string()])
            .args(["--seed", &cfg.seed.to_string(), "--max-iters", &cfg.max_iters.to_string()])
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .stdin(Stdio::null())
            .spawn()
            .expect("spawn astir shard-worker");
        guard.0.push(worker);
    }
    for w in &mut guard.0[1..] {
        let status = w.wait().expect("wait shard-worker");
        assert!(status.success(), "shard-worker failed: {status}");
    }
    let status = guard.0[0].wait().expect("wait exchange-hub");
    assert!(status.success(), "exchange-hub failed: {status}");
    let mut report = (0u64, false);
    for line in lines.map_while(Result::ok) {
        if let Some(rest) = line.strip_prefix("hub-report rounds=") {
            let mut it = rest.splitn(2, ' ');
            let rounds = it.next().and_then(|t| t.parse().ok()).unwrap_or(0);
            let clean = it.next() == Some("degraded=[]");
            report = (rounds, clean);
        }
    }
    report
}

/// The same fleet with in-process workers: real loopback sockets and the
/// full wire protocol, no process spawn — the fallback when the CLI
/// binary is not reachable from the running bench harness.
fn run_loopback_fleet(
    problem: &Problem,
    opts: &AsyncOpts,
    s: usize,
    e: usize,
    seed: u64,
) -> (u64, bool) {
    let sh = ShardOpts { shards: s, exchange_period: e, ..Default::default() };
    let hub = ExchangeHub::bind(HubOpts::new("127.0.0.1:0", s)).expect("bind exchange hub");
    let addr = hub.addr().expect("hub addr").to_string();
    let hub = hub.spawn();
    thread::scope(|scope| {
        let handles: Vec<_> = (0..s)
            .map(|k| {
                let (addr, sh) = (&addr, &sh);
                scope.spawn(move || {
                    run_worker(problem, addr, k, sh, Alg::Stoiht, opts, seed)
                        .expect("fleet worker")
                })
            })
            .collect();
        for h in handles {
            h.join().expect("join fleet worker");
        }
    });
    let report: HubReport = hub.join().expect("join hub thread").expect("hub run");
    (report.rounds, report.degraded.is_empty())
}

/// `distributed` — the `S × E` staleness grid as multi-process fleets
/// over loopback (see the module doc), plus the in-process pool at the
/// same axes: the per-cell delta is the socket transport tax.
fn distributed_suite(suite: &mut Suite) {
    let cfg = experiment_cfg(suite.mode(), 2, 1);
    let mode = suite.mode();
    const CELLS: [(usize, usize); 4] = [(2, 1), (2, 16), (4, 1), (4, 16)];
    let grid: Vec<((usize, usize), BenchSpec)> = CELLS
        .iter()
        .map(|&(s, e)| ((s, e), expspec(&format!("fleet_s{s}_e{e}"), &cfg)))
        .collect();
    let inproc_spec = expspec("inproc_s4_e16", &cfg);
    if suite.is_dry_run() {
        for (_, spec) in grid {
            suite.bench(spec, || {});
        }
        suite.bench(inproc_spec, || {});
        return;
    }
    if grid.iter().any(|(_, sp)| suite.wants(sp)) || suite.wants(&inproc_spec) {
        banner("distributed sharded recovery — process fleets over loopback", &cfg);
    }
    let opts = AsyncOpts {
        tolerance: cfg.tolerance,
        max_local_iters: cfg.max_iters,
        ..Default::default()
    };
    // The CLI's sharded run-seed derivation, so every cell (process or
    // loopback fallback) computes the identical recovery.
    let seed = cfg.seed ^ 0xA5;
    let bin = astir_bin();
    match &bin {
        Some(p) => println!("  fleet mode: real processes ({})", p.display()),
        None => println!(
            "  fleet mode: in-process loopback sockets (set ASTIR_BIN or run via \
             `astir bench` for real process fleets)"
        ),
    }
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.problem.generate(&mut rng);
    let mut table = Table::new(&["shards", "exchange_period", "rounds", "clean"]);
    for ((s, e), spec) in grid {
        if !suite.wants(&spec) {
            continue;
        }
        let mut fleet = None;
        suite.bench(spec, || {
            fleet = Some(match &bin {
                Some(bin) => run_process_fleet(bin, &cfg, s, e),
                None => run_loopback_fleet(&problem, &opts, s, e, seed),
            });
        });
        if let Some((rounds, clean)) = fleet {
            println!("  => fleet S={s} E={e}: rounds={rounds} clean={clean}");
            table.push_row(vec![s as f64, e as f64, rounds as f64, f64::from(u8::from(clean))]);
        }
    }
    if !table.rows.is_empty() {
        report::emit(
            &results_name(mode, "distributed_fleet"),
            "distributed sharded recovery — exchange rounds per S x E fleet on loopback",
            &table,
        );
    }
    if !suite.wants(&inproc_spec) {
        return;
    }
    let mut outcome = None;
    suite.bench(inproc_spec, || {
        let so = ShardOpts { shards: 4, exchange_period: 16, ..Default::default() };
        let out = ShardedPool::new(so).run(&problem, Alg::Stoiht, &opts, seed);
        outcome = Some((out.converged(), out.rounds));
    });
    if let Some((converged, rounds)) = outcome {
        println!("  => in-process S=4 E=16 reference: rounds={rounds} converged={converged}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_complete() {
        let names: Vec<&str> = registry().iter().map(|d| d.name).collect();
        assert_eq!(
            names,
            [
                "hot_path",
                "fig1",
                "fig2_upper",
                "fig2_lower",
                "ablations",
                "baselines",
                "stogradmp_async",
                "large_n",
                "throughput",
                "loadgen",
                "sharded",
                "distributed"
            ]
        );
        for n in &names {
            assert!(find(n).is_some());
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn large_n_suite_registers_the_acceptance_point() {
        // `astir bench --filter large_n` must reach the n = 2^20 async
        // recovery point (the acceptance-criteria invocation), and every
        // point must be standard scale (never jumbo-gated: the operator is
        // O(m + n) memory, so smoke CI runs all of it).
        let opts = RunOpts {
            mode: Mode::Smoke,
            filter: Some("large_n".to_string()),
            skip_jumbo: true,
            dry_run: true,
        };
        let report = run_all(&opts);
        let ln = report.suites.iter().find(|s| s.name == "large_n").unwrap();
        let names: Vec<&str> = ln.benches.iter().map(|b| b.name.as_str()).collect();
        let expected = [
            "dct_apply_n131k",
            "dct_adjoint_n131k",
            "proxy_sparse_n131k",
            "dct_apply_n1m",
            "stoiht_async_n1m",
        ];
        for e in expected {
            assert!(names.contains(&e), "missing {e} in {names:?}");
        }
        assert!(ln.benches.iter().all(|b| b.scale == Scale::Standard));
        let big = ln.benches.iter().find(|b| b.name == "stoiht_async_n1m").unwrap();
        let dims = big.dims.unwrap();
        assert!(dims.n >= 1_000_000, "n = {} is not million-dimension", dims.n);
        assert_eq!(dims.m, 300_000);
    }

    #[test]
    fn throughput_suite_registers_the_service_comparisons() {
        // `astir bench --filter throughput` must reach both jobs/sec
        // comparisons (the acceptance-criteria invocation), at n = 2^17.
        let opts = RunOpts {
            mode: Mode::Smoke,
            filter: Some("throughput".to_string()),
            skip_jumbo: true,
            dry_run: true,
        };
        let report = run_all(&opts);
        let tp = report.suites.iter().find(|s| s.name == "throughput").unwrap();
        let names: Vec<&str> = tp.benches.iter().map(|b| b.name.as_str()).collect();
        for e in ["pool_jobs_c4", "spawn_jobs_c4", "sequential_b8", "batched_b8"] {
            assert!(names.contains(&e), "missing {e} in {names:?}");
        }
        assert!(tp.benches.iter().all(|b| b.scale == Scale::Standard));
        for bench in &tp.benches {
            assert_eq!(bench.dims.unwrap().n, 1 << 17, "{}: wrong n", bench.name);
        }
        // nothing outside the new suite matches the filter
        let elsewhere: usize = report
            .suites
            .iter()
            .filter(|s| s.name != "throughput")
            .map(|s| s.benches.len())
            .sum();
        assert_eq!(elsewhere, 0);
    }

    #[test]
    fn loadgen_suite_registers_latency_records() {
        // `astir bench --filter loadgen` must reach the two offered-rate
        // windows AND their derived p50/p99 latency records — the CI
        // baseline gate covers tail latency only if the specs register
        // identically under --list, --filter, and smoke runs.
        let opts = RunOpts {
            mode: Mode::Smoke,
            filter: Some("loadgen".to_string()),
            skip_jumbo: true,
            dry_run: true,
        };
        let report = run_all(&opts);
        let lg = report.suites.iter().find(|s| s.name == "loadgen").unwrap();
        let names: Vec<&str> = lg.benches.iter().map(|b| b.name.as_str()).collect();
        for e in ["open_loop_lo", "p50_lo", "p99_lo", "open_loop_hi", "p50_hi", "p99_hi"] {
            assert!(names.contains(&e), "missing {e} in {names:?}");
        }
        assert!(lg.benches.iter().all(|b| b.scale == Scale::Standard));
        for bench in &lg.benches {
            assert_eq!(bench.dims.unwrap().n, 4096, "{}: wrong n", bench.name);
        }
        // nothing outside the new suite matches the filter
        let elsewhere: usize =
            report.suites.iter().filter(|s| s.name != "loadgen").map(|s| s.benches.len()).sum();
        assert_eq!(elsewhere, 0);
    }

    #[test]
    fn dry_run_registers_specs_for_every_suite() {
        let opts = RunOpts { mode: Mode::Smoke, filter: None, skip_jumbo: true, dry_run: true };
        let report = run_all(&opts);
        assert_eq!(report.schema, SCHEMA);
        assert_eq!(report.suites.len(), 11);
        for s in &report.suites {
            assert!(
                !s.benches.is_empty() || !s.skipped.is_empty(),
                "suite {} registered nothing",
                s.name
            );
        }
        // the registry's microbench core is present
        let hot = &report.suites[0];
        let names: Vec<&str> = hot.benches.iter().map(|b| b.name.as_str()).collect();
        for expected in ["triad_1m", "proxy_fused_15x1000", "paper_step_sparse", "tally_commit"] {
            assert!(names.contains(&expected), "missing {expected} in {names:?}");
        }
    }

    #[test]
    fn stogradmp_filter_selects_the_new_suite() {
        // `astir bench --filter stogradmp` must reach the new suite's
        // benches (the acceptance-criteria invocation).
        let opts = RunOpts {
            mode: Mode::Smoke,
            filter: Some("stogradmp".to_string()),
            skip_jumbo: true,
            dry_run: true,
        };
        let report = run_all(&opts);
        let sg = report.suites.iter().find(|s| s.name == "stogradmp_async").unwrap();
        let names: Vec<&str> = sg.benches.iter().map(|b| b.name.as_str()).collect();
        for expected in ["sequential", "steps_vs_cores", "wallclock_c1", "wallclock_c4"] {
            assert!(names.contains(&expected), "missing {expected} in {names:?}");
        }
        // nothing outside the new suite matches the filter
        let elsewhere: usize = report
            .suites
            .iter()
            .filter(|s| s.name != "stogradmp_async")
            .map(|s| s.benches.len())
            .sum();
        assert_eq!(elsewhere, 0);
    }

    #[test]
    fn sharded_suite_registers_the_staleness_grid() {
        // `astir bench --filter sharded` must reach every shard count of
        // the staleness grid plus the real-thread pool point — the CI
        // baseline gate covers them only if the specs register identically
        // under --list, --filter, and smoke runs.
        let opts = RunOpts {
            mode: Mode::Smoke,
            filter: Some("sharded".to_string()),
            skip_jumbo: true,
            dry_run: true,
        };
        let report = run_all(&opts);
        let sh = report.suites.iter().find(|s| s.name == "sharded").unwrap();
        let names: Vec<&str> = sh.benches.iter().map(|b| b.name.as_str()).collect();
        for e in ["staleness_s1", "staleness_s2", "staleness_s4", "staleness_s8", "pool_s4"] {
            assert!(names.contains(&e), "missing {e} in {names:?}");
        }
        assert!(sh.benches.iter().all(|b| b.scale == Scale::Standard));
        // nothing outside the new suite matches the filter
        let elsewhere: usize =
            report.suites.iter().filter(|s| s.name != "sharded").map(|s| s.benches.len()).sum();
        assert_eq!(elsewhere, 0);
    }

    #[test]
    fn filter_narrows_to_one_bench() {
        let opts = RunOpts {
            mode: Mode::Smoke,
            filter: Some("hot_path/tally_commit_contended".to_string()),
            skip_jumbo: true,
            dry_run: true,
        };
        let report = run_all(&opts);
        let total: usize = report.suites.iter().map(|s| s.benches.len()).sum();
        assert_eq!(total, 1);
        assert_eq!(report.suites[0].benches[0].name, "tally_commit_contended");
    }
}
