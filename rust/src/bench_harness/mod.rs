//! Bench suite registry + timing harness + perf telemetry (criterion is
//! unavailable offline, so all of it is in-repo).
//!
//! Three layers:
//!
//! * **Timing core** — [`bench_with`]/[`bench`]: warm up until timings
//!   stabilize (or the warmup budget is spent), then run fixed-size
//!   batches until the measurement budget is spent, reporting mean / σ /
//!   min over batch means.
//! * **Registry** — every benchmark is declared as a [`BenchSpec`] (name,
//!   scale tag, problem dims, seed, smoke/full [`Budget`]s) and registered
//!   into a named [`Suite`]; the ten suites live in [`suites`] and are
//!   shared by the `cargo bench` binaries and the `astir bench` CLI.
//! * **Telemetry** — a finished run serializes to a schema-stable JSON
//!   document ([`json`], hand-rolled — no serde offline) that CI uploads
//!   and [`compare_reports`] diffs against a committed baseline, failing
//!   the run when any benchmark regresses beyond a threshold.

pub mod json;
pub mod suites;

use std::time::{Duration, Instant};

use crate::metrics::{format_sig, stats, Stats};

/// Identifier of the JSON telemetry schema emitted by this crate.
pub const SCHEMA: &str = "astir-bench-v1";

/// Default `--compare` regression threshold: fail when a benchmark's mean
/// time grows by more than this fraction (50% — shared CI runners are
/// noisy; tighten via `astir bench --threshold`).
pub const DEFAULT_REGRESSION_THRESHOLD: f64 = 0.5;

/// One benchmark's timing summary (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Total measured iterations.
    pub iters: usize,
    /// Statistics over per-iteration times (seconds), from batch means.
    pub time: Stats,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{}, min {}, {} iters)",
            self.name,
            human_time(self.time.mean),
            human_time(self.time.std),
            human_time(self.time.min),
            self.iters
        )
    }

    /// Iterations per second at the mean time.
    pub fn throughput(&self) -> f64 {
        1.0 / self.time.mean
    }
}

/// Render seconds with an adaptive unit.
pub fn human_time(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let (v, unit) = if secs >= 1.0 {
        (secs, "s")
    } else if secs >= 1e-3 {
        (secs * 1e3, "ms")
    } else if secs >= 1e-6 {
        (secs * 1e6, "µs")
    } else {
        (secs * 1e9, "ns")
    };
    format!("{} {unit}", format_sig(v, 4))
}

/// Measurement budget for one benchmark run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Budget {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum number of measured batches regardless of elapsed time.
    pub min_samples: usize,
}

impl Budget {
    /// Microbenchmark budget under `--smoke` (CI-sized).
    pub const fn micro_smoke() -> Self {
        Budget {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 3,
        }
    }

    /// Microbenchmark budget for a full run.
    pub const fn micro_full() -> Self {
        Budget {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_samples: 3,
        }
    }

    /// One timed pass, no warmup — for Monte-Carlo experiment drivers
    /// where a single run is already an aggregate over many trials.
    pub const fn once() -> Self {
        Budget { warmup: Duration::ZERO, measure: Duration::ZERO, min_samples: 1 }
    }
}

/// Smoke (CI) vs full measurement mode; selects which [`BenchSpec`]
/// budget applies and how experiment suites size their trial counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Smoke,
    Full,
}

impl Mode {
    pub fn as_str(self) -> &'static str {
        match self {
            Mode::Smoke => "smoke",
            Mode::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<Mode> {
        match s {
            "smoke" => Some(Mode::Smoke),
            "full" => Some(Mode::Full),
            _ => None,
        }
    }
}

/// Scale tag: `Jumbo` points allocate disproportionate memory/time and are
/// env-gated (`ASTIR_BENCH_SKIP_JUMBO=1`, always set in CI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Standard,
    Jumbo,
}

impl Scale {
    pub fn as_str(self) -> &'static str {
        match self {
            Scale::Standard => "standard",
            Scale::Jumbo => "jumbo",
        }
    }

    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "standard" => Some(Scale::Standard),
            "jumbo" => Some(Scale::Jumbo),
            _ => None,
        }
    }
}

/// Problem dimensions attached to a benchmark record (telemetry context:
/// a perf number is meaningless without the shape it was measured on).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BenchDims {
    pub n: usize,
    pub m: usize,
    pub b: usize,
    pub s: usize,
}

/// Declarative description of one benchmark in a suite.
#[derive(Clone, Debug)]
pub struct BenchSpec {
    pub name: String,
    pub scale: Scale,
    pub dims: Option<BenchDims>,
    pub seed: u64,
    pub smoke: Budget,
    pub full: Budget,
}

impl BenchSpec {
    /// Repeated-timing microbenchmark (quick smoke batch, 1 s full batch).
    pub fn micro(name: &str) -> Self {
        BenchSpec {
            name: name.to_string(),
            scale: Scale::Standard,
            dims: None,
            seed: 0,
            smoke: Budget::micro_smoke(),
            full: Budget::micro_full(),
        }
    }

    /// Single-pass experiment driver (one timed run in both modes — the
    /// Monte-Carlo trial count, not repetition, supplies the averaging).
    pub fn experiment(name: &str) -> Self {
        BenchSpec { smoke: Budget::once(), full: Budget::once(), ..BenchSpec::micro(name) }
    }

    /// Attach problem dimensions.
    pub fn dims(mut self, n: usize, m: usize, b: usize, s: usize) -> Self {
        self.dims = Some(BenchDims { n, m, b, s });
        self
    }

    /// Attach the RNG seed the workload was generated from.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Tag as a jumbo-scale point (env-gated).
    pub fn jumbo(mut self) -> Self {
        self.scale = Scale::Jumbo;
        self
    }

    /// The budget selected by `mode`.
    pub fn budget(&self, mode: Mode) -> Budget {
        match mode {
            Mode::Smoke => self.smoke,
            Mode::Full => self.full,
        }
    }
}

/// One executed benchmark with its spec metadata — the unit of the JSON
/// telemetry schema.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    pub scale: Scale,
    pub dims: Option<BenchDims>,
    pub seed: u64,
    pub iters: usize,
    pub time: Stats,
}

impl BenchRecord {
    /// Iterations per second at the mean time (NaN for records without a
    /// positive finite mean — dry-run placeholders).
    pub fn throughput(&self) -> f64 {
        if self.time.mean > 0.0 {
            1.0 / self.time.mean
        } else {
            f64::NAN
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{}, min {}, {} iters)",
            self.name,
            human_time(self.time.mean),
            human_time(self.time.std),
            human_time(self.time.min),
            self.iters
        )
    }
}

/// All records from one named suite.
#[derive(Clone, Debug)]
pub struct SuiteReport {
    pub name: String,
    pub benches: Vec<BenchRecord>,
    /// Bench names skipped at run time (jumbo gate, unavailable backend).
    pub skipped: Vec<String>,
}

/// A full run: what `BENCH_<suite>.json` / `astir bench --json` contain.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub schema: String,
    pub git_rev: Option<String>,
    pub mode: Mode,
    pub suites: Vec<SuiteReport>,
}

/// Options controlling a suite run (CLI flags / environment).
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub mode: Mode,
    /// Substring filter over `suite/bench` names.
    pub filter: Option<String>,
    /// Skip [`Scale::Jumbo`] points (`ASTIR_BENCH_SKIP_JUMBO=1`).
    pub skip_jumbo: bool,
    /// Register specs without timing anything (`astir bench --list` and
    /// the determinism tests).
    pub dry_run: bool,
}

impl RunOpts {
    /// Mode plus environment-derived gates; no filter.
    pub fn from_env(mode: Mode) -> Self {
        RunOpts { mode, filter: None, skip_jumbo: skip_jumbo_env(), dry_run: false }
    }
}

/// The jumbo gate: `ASTIR_BENCH_SKIP_JUMBO` set to anything but `0`/empty.
pub fn skip_jumbo_env() -> bool {
    std::env::var_os("ASTIR_BENCH_SKIP_JUMBO").is_some_and(|v| !v.is_empty() && v != "0")
}

/// An executing (or dry-run) suite: benches register and run in order.
pub struct Suite {
    name: String,
    opts: RunOpts,
    benches: Vec<BenchRecord>,
    skipped: Vec<String>,
}

impl Suite {
    pub fn new(name: &str, opts: &RunOpts) -> Self {
        Suite {
            name: name.to_string(),
            opts: opts.clone(),
            benches: Vec::new(),
            skipped: Vec::new(),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn mode(&self) -> Mode {
        self.opts.mode
    }

    pub fn is_dry_run(&self) -> bool {
        self.opts.dry_run
    }

    fn full_name(&self, bench: &str) -> String {
        format!("{}/{bench}", self.name)
    }

    fn filtered_out(&self, bench: &str) -> bool {
        match &self.opts.filter {
            Some(f) => !self.full_name(bench).contains(f.as_str()),
            None => false,
        }
    }

    /// Jumbo points are skipped by the env gate and in smoke mode
    /// (CI-sized by definition). Dry runs still *list* jumbo specs —
    /// suite definitions must register them without paying setup
    /// (see `suites::sparse_vs_dense_at`).
    pub fn jumbo_gated(&self) -> bool {
        self.opts.skip_jumbo || self.opts.mode == Mode::Smoke
    }

    /// Would [`Suite::bench`] measure this spec? Lets suite definitions
    /// skip expensive setup for filtered-out or jumbo-gated points.
    pub fn wants(&self, spec: &BenchSpec) -> bool {
        !(self.filtered_out(&spec.name) || (spec.scale == Scale::Jumbo && self.jumbo_gated()))
    }

    /// Record a benchmark as skipped (gated scale, unavailable backend).
    pub fn skip(&mut self, name: &str, why: &str) {
        if self.filtered_out(name) {
            return;
        }
        if !self.opts.dry_run {
            println!("{:<44} skipped: {why}", self.full_name(name));
        }
        self.skipped.push(name.to_string());
    }

    /// Run one benchmark under the mode-selected budget and record it.
    /// Returns the record, or `None` when the spec was filtered out,
    /// jumbo-gated, or this is a dry run (so derived-metric printouts
    /// guarded by the return value stay quiet).
    pub fn bench<F: FnMut()>(&mut self, spec: BenchSpec, f: F) -> Option<BenchRecord> {
        if self.filtered_out(&spec.name) {
            return None;
        }
        if spec.scale == Scale::Jumbo && self.jumbo_gated() {
            self.skip(&spec.name, "jumbo scale gated (smoke mode / ASTIR_BENCH_SKIP_JUMBO)");
            return None;
        }
        if self.opts.dry_run {
            // Listing: record the spec (even a jumbo one) without running.
            self.benches.push(BenchRecord {
                name: spec.name.clone(),
                scale: spec.scale,
                dims: spec.dims,
                seed: spec.seed,
                iters: 0,
                time: stats(&[]),
            });
            return None;
        }
        let r = bench_with(&spec.name, spec.budget(self.opts.mode), f);
        let rec = BenchRecord {
            name: spec.name,
            scale: spec.scale,
            dims: spec.dims,
            seed: spec.seed,
            iters: r.iters,
            time: r.time,
        };
        println!("{}", rec.summary());
        self.benches.push(rec.clone());
        Some(rec)
    }

    /// Record a metric measured *outside* the timing harness (a latency
    /// percentile from a server's telemetry, say) as a benchmark record
    /// whose mean is `seconds`. Obeys the same filter / jumbo / dry-run
    /// gates as [`Suite::bench`], so derived metrics stay schema-stable
    /// across `--list`, `--filter`, and smoke runs.
    pub fn record_metric(&mut self, spec: BenchSpec, seconds: f64) -> Option<BenchRecord> {
        if self.filtered_out(&spec.name) {
            return None;
        }
        if spec.scale == Scale::Jumbo && self.jumbo_gated() {
            self.skip(&spec.name, "jumbo scale gated (smoke mode / ASTIR_BENCH_SKIP_JUMBO)");
            return None;
        }
        if self.opts.dry_run {
            self.benches.push(BenchRecord {
                name: spec.name.clone(),
                scale: spec.scale,
                dims: spec.dims,
                seed: spec.seed,
                iters: 0,
                time: stats(&[]),
            });
            return None;
        }
        let rec = BenchRecord {
            name: spec.name,
            scale: spec.scale,
            dims: spec.dims,
            seed: spec.seed,
            iters: 1,
            time: stats(&[seconds]),
        };
        println!("{}", rec.summary());
        self.benches.push(rec.clone());
        Some(rec)
    }

    /// Finish the suite, yielding its report.
    pub fn into_report(self) -> SuiteReport {
        SuiteReport { name: self.name, benches: self.benches, skipped: self.skipped }
    }
}

/// Best-effort git revision for telemetry: `$ASTIR_GIT_REV` override,
/// else `git rev-parse --short=12 HEAD`, else `None`.
pub fn git_rev() -> Option<String> {
    if let Ok(v) = std::env::var("ASTIR_GIT_REV") {
        if !v.is_empty() {
            return Some(v);
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
}

/// Benchmark a closure under an explicit [`Budget`].
pub fn bench_with<F: FnMut()>(name: &str, budget: Budget, mut f: F) -> BenchResult {
    // Warmup + calibration: find a batch size that runs >= ~1 ms. A zero
    // warmup (experiment budgets) skips calibration entirely — the single
    // measured pass must not be preceded by a hidden extra run.
    let mut batch = 1usize;
    if budget.warmup > Duration::ZERO {
        let warm_start = Instant::now();
        loop {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed();
            if dt >= Duration::from_millis(1) || batch >= 1 << 20 {
                if warm_start.elapsed() >= budget.warmup {
                    break;
                }
            } else {
                batch *= 2;
            }
            if warm_start.elapsed() >= budget.warmup.max(Duration::from_millis(10)) {
                break;
            }
        }
    }

    // Measurement: batches of `batch` iterations.
    let mut batch_means: Vec<f64> = Vec::new();
    let mut iters = 0usize;
    let meas_start = Instant::now();
    while meas_start.elapsed() < budget.measure || batch_means.len() < budget.min_samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        batch_means.push(dt / batch as f64);
        iters += batch;
        if batch_means.len() > 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters, time: stats(&batch_means) }
}

/// Benchmark a closure: warm up for `warmup`, then measure for `measure`.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, f: F) -> BenchResult {
    bench_with(name, Budget { warmup, measure, min_samples: 3 }, f)
}

/// Default quick bench (0.2 s warmup, 1 s measurement) with printing.
pub fn quick_bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench_with(name, Budget::micro_full(), f);
    println!("{}", r.summary());
    r
}

/// Standard header printed by every bench binary.
pub fn bench_header(title: &str) {
    println!("\n=== {title} ===");
}

/// One bench's baseline-vs-current delta from [`compare_reports`].
#[derive(Clone, Debug)]
pub struct Delta {
    /// `suite/bench` key.
    pub name: String,
    pub base_mean: f64,
    pub new_mean: f64,
    /// `new_mean / base_mean` (> 1 means slower than baseline).
    pub ratio: f64,
    pub regressed: bool,
}

/// Outcome of diffing a run against a baseline report.
#[derive(Clone, Debug, Default)]
pub struct CompareOutcome {
    pub deltas: Vec<Delta>,
    /// Baseline benches absent from the new run (renamed/removed).
    pub missing_in_new: Vec<String>,
    /// New benches with no baseline (informational).
    pub new_only: Vec<String>,
}

impl CompareOutcome {
    /// The deltas that exceeded the threshold.
    pub fn regressions(&self) -> Vec<&Delta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }
}

/// Compare `new` against `base`: a bench regresses when its mean time
/// grows by more than `threshold` (fractional; 0.5 = +50%). Benches are
/// matched by `suite/bench` name; records without a finite positive mean
/// (dry runs) are ignored.
pub fn compare_reports(base: &RunReport, new: &RunReport, threshold: f64) -> CompareOutcome {
    let index = |r: &RunReport| -> Vec<(String, f64)> {
        r.suites
            .iter()
            .flat_map(|s| {
                s.benches
                    .iter()
                    .map(|b| (format!("{}/{}", s.name, b.name), b.time.mean))
                    .collect::<Vec<_>>()
            })
            .collect()
    };
    let base_idx = index(base);
    let new_idx = index(new);
    let mut out = CompareOutcome::default();
    for (name, base_mean) in &base_idx {
        let Some((_, new_mean)) = new_idx.iter().find(|(n, _)| n == name) else {
            out.missing_in_new.push(name.clone());
            continue;
        };
        if !(base_mean.is_finite() && *base_mean > 0.0 && new_mean.is_finite()) {
            continue;
        }
        let ratio = new_mean / base_mean;
        out.deltas.push(Delta {
            name: name.clone(),
            base_mean: *base_mean,
            new_mean: *new_mean,
            ratio,
            regressed: ratio > 1.0 + threshold,
        });
    }
    for (name, _) in &new_idx {
        if !base_idx.iter().any(|(n, _)| n == name) {
            out.new_only.push(name.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_cheap_closure() {
        let mut acc = 0u64;
        let r = bench(
            "noop-ish",
            Duration::from_millis(10),
            Duration::from_millis(50),
            || {
                acc = acc.wrapping_add(std::hint::black_box(1));
            },
        );
        assert!(r.iters > 100);
        assert!(r.time.mean > 0.0 && r.time.mean < 1e-3);
        assert!(r.throughput() > 1000.0);
    }

    #[test]
    fn measures_a_slow_closure() {
        let r = bench(
            "sleepy",
            Duration::from_millis(1),
            Duration::from_millis(30),
            || crate::sync::thread::sleep(Duration::from_millis(2)),
        );
        assert!(r.time.mean >= 1.5e-3, "{}", r.time.mean);
    }

    #[test]
    fn once_budget_runs_exactly_once() {
        let mut calls = 0usize;
        let r = bench_with("one-shot", Budget::once(), || calls += 1);
        assert_eq!(calls, 1);
        assert_eq!(r.iters, 1);
        assert_eq!(r.time.n, 1);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" µs"));
        assert!(human_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn summary_contains_name() {
        let r = bench("xyz", Duration::from_millis(1), Duration::from_millis(5), || {
            std::hint::black_box(3 + 4);
        });
        assert!(r.summary().contains("xyz"));
    }

    #[test]
    fn spec_builders_and_budget_selection() {
        let spec = BenchSpec::micro("m").dims(10, 4, 2, 1).seed(7);
        assert_eq!(spec.scale, Scale::Standard);
        assert_eq!(spec.dims, Some(BenchDims { n: 10, m: 4, b: 2, s: 1 }));
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.budget(Mode::Smoke), Budget::micro_smoke());
        assert_eq!(spec.budget(Mode::Full), Budget::micro_full());
        let e = BenchSpec::experiment("e").jumbo();
        assert_eq!(e.scale, Scale::Jumbo);
        assert_eq!(e.budget(Mode::Full), Budget::once());
    }

    #[test]
    fn suite_filters_and_gates() {
        let opts = RunOpts {
            mode: Mode::Smoke,
            filter: Some("demo/yes".to_string()),
            skip_jumbo: true,
            dry_run: false,
        };
        let mut suite = Suite::new("demo", &opts);
        assert!(suite.wants(&BenchSpec::micro("yes_please")));
        assert!(!suite.wants(&BenchSpec::micro("nope")));
        assert!(!suite.wants(&BenchSpec::micro("yes_but_jumbo").jumbo()));
        let mut ran = false;
        assert!(suite.bench(BenchSpec::micro("nope"), || ran = true).is_none());
        assert!(!ran);
        let rec = suite.bench(BenchSpec::experiment("yes_once").seed(3), || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(rec.unwrap().seed, 3);
        let report = suite.into_report();
        assert_eq!(report.benches.len(), 1);
        assert_eq!(report.benches[0].name, "yes_once");
    }

    #[test]
    fn suite_dry_run_records_specs_without_running() {
        let opts = RunOpts { mode: Mode::Smoke, filter: None, skip_jumbo: false, dry_run: true };
        let mut suite = Suite::new("demo", &opts);
        let mut ran = false;
        let rec = suite.bench(BenchSpec::micro("a").dims(5, 4, 2, 1), || ran = true);
        assert!(rec.is_none() && !ran);
        let report = suite.into_report();
        assert_eq!(report.benches.len(), 1);
        assert_eq!(report.benches[0].iters, 0);
        assert_eq!(report.benches[0].dims, Some(BenchDims { n: 5, m: 4, b: 2, s: 1 }));
    }

    #[test]
    fn record_metric_obeys_suite_gates() {
        let opts = RunOpts { mode: Mode::Smoke, filter: None, skip_jumbo: false, dry_run: false };
        let mut suite = Suite::new("demo", &opts);
        let rec = suite.record_metric(BenchSpec::experiment("p99").seed(11), 0.25).unwrap();
        assert_eq!(rec.seed, 11);
        assert_eq!(rec.iters, 1);
        assert!((rec.time.mean - 0.25).abs() < 1e-15);

        // Dry runs register the spec as a zero-iteration placeholder.
        let dry = RunOpts { mode: Mode::Smoke, filter: None, skip_jumbo: false, dry_run: true };
        let mut listing = Suite::new("demo", &dry);
        assert!(listing.record_metric(BenchSpec::experiment("p99"), 0.25).is_none());
        let report = listing.into_report();
        assert_eq!(report.benches.len(), 1);
        assert_eq!(report.benches[0].iters, 0);

        // Filtered-out metrics are dropped entirely.
        let filt = RunOpts {
            mode: Mode::Smoke,
            filter: Some("demo/other".to_string()),
            skip_jumbo: false,
            dry_run: false,
        };
        let mut filtered = Suite::new("demo", &filt);
        assert!(filtered.record_metric(BenchSpec::experiment("p99"), 0.25).is_none());
        assert!(filtered.into_report().benches.is_empty());
    }

    #[test]
    fn jumbo_gate_records_skip() {
        let opts = RunOpts { mode: Mode::Smoke, filter: None, skip_jumbo: true, dry_run: false };
        let mut suite = Suite::new("demo", &opts);
        let mut ran = false;
        assert!(suite.bench(BenchSpec::micro("big").jumbo(), || ran = true).is_none());
        assert!(!ran);
        let report = suite.into_report();
        assert!(report.benches.is_empty());
        assert_eq!(report.skipped, ["big"]);
    }

    fn report_with(name: &str, mean: f64) -> RunReport {
        RunReport {
            schema: SCHEMA.to_string(),
            git_rev: None,
            mode: Mode::Smoke,
            suites: vec![SuiteReport {
                name: "s".to_string(),
                benches: vec![BenchRecord {
                    name: name.to_string(),
                    scale: Scale::Standard,
                    dims: None,
                    seed: 0,
                    iters: 10,
                    time: crate::metrics::stats(&[mean]),
                }],
                skipped: Vec::new(),
            }],
        }
    }

    #[test]
    fn compare_flags_regressions_beyond_threshold() {
        let base = report_with("k", 1.0);
        let ok = compare_reports(&base, &report_with("k", 1.2), 0.5);
        assert_eq!(ok.regressions().len(), 0);
        assert!((ok.deltas[0].ratio - 1.2).abs() < 1e-12);
        let bad = compare_reports(&base, &report_with("k", 2.0), 0.5);
        assert_eq!(bad.regressions().len(), 1);
        assert!(bad.regressions()[0].regressed);
    }

    #[test]
    fn compare_tracks_membership_changes() {
        let base = report_with("old", 1.0);
        let new = report_with("new", 1.0);
        let out = compare_reports(&base, &new, 0.5);
        assert!(out.deltas.is_empty());
        assert_eq!(out.missing_in_new, ["s/old"]);
        assert_eq!(out.new_only, ["s/new"]);
    }

    #[test]
    fn mode_and_scale_roundtrip() {
        assert_eq!(Mode::parse(Mode::Smoke.as_str()), Some(Mode::Smoke));
        assert_eq!(Mode::parse(Mode::Full.as_str()), Some(Mode::Full));
        assert_eq!(Mode::parse("nope"), None);
        assert_eq!(Scale::parse(Scale::Jumbo.as_str()), Some(Scale::Jumbo));
        assert_eq!(Scale::parse("nope"), None);
    }
}
