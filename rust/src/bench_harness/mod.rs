//! In-repo micro-benchmark harness (criterion is unavailable offline).
//!
//! The `cargo bench` targets are `harness = false` binaries that use this
//! module for timing and the `experiments` drivers for figure
//! regeneration. The measurement loop is deliberately simple: warm up
//! until timings stabilize (or the warmup budget is spent), then run
//! fixed-size batches until the measurement budget is spent, reporting
//! mean / σ / min over batch means.

use std::time::{Duration, Instant};

use crate::metrics::{format_sig, stats, Stats};

/// One benchmark's timing summary (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Total measured iterations.
    pub iters: usize,
    /// Statistics over per-iteration times (seconds), from batch means.
    pub time: Stats,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{}, min {}, {} iters)",
            self.name,
            human_time(self.time.mean),
            human_time(self.time.std),
            human_time(self.time.min),
            self.iters
        )
    }

    /// Iterations per second at the mean time.
    pub fn throughput(&self) -> f64 {
        1.0 / self.time.mean
    }
}

/// Render seconds with an adaptive unit.
pub fn human_time(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let (v, unit) = if secs >= 1.0 {
        (secs, "s")
    } else if secs >= 1e-3 {
        (secs * 1e3, "ms")
    } else if secs >= 1e-6 {
        (secs * 1e6, "µs")
    } else {
        (secs * 1e9, "ns")
    };
    format!("{} {unit}", format_sig(v, 4))
}

/// Benchmark a closure: warm up for `warmup`, then measure for `measure`.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: find a batch size that runs >= ~1 ms.
    let warm_start = Instant::now();
    let mut batch = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed();
        if dt >= Duration::from_millis(1) || batch >= 1 << 20 {
            if warm_start.elapsed() >= warmup {
                break;
            }
        } else {
            batch *= 2;
        }
        if warm_start.elapsed() >= warmup.max(Duration::from_millis(10)) {
            break;
        }
    }

    // Measurement: batches of `batch` iterations.
    let mut batch_means: Vec<f64> = Vec::new();
    let mut iters = 0usize;
    let meas_start = Instant::now();
    while meas_start.elapsed() < measure || batch_means.len() < 3 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        batch_means.push(dt / batch as f64);
        iters += batch;
        if batch_means.len() > 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters, time: stats(&batch_means) }
}

/// Default quick bench (0.2 s warmup, 1 s measurement) with printing.
pub fn quick_bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_millis(200), Duration::from_secs(1), f);
    println!("{}", r.summary());
    r
}

/// Standard header printed by every bench binary.
pub fn bench_header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_cheap_closure() {
        let mut acc = 0u64;
        let r = bench(
            "noop-ish",
            Duration::from_millis(10),
            Duration::from_millis(50),
            || {
                acc = acc.wrapping_add(std::hint::black_box(1));
            },
        );
        assert!(r.iters > 100);
        assert!(r.time.mean > 0.0 && r.time.mean < 1e-3);
        assert!(r.throughput() > 1000.0);
    }

    #[test]
    fn measures_a_slow_closure() {
        let r = bench(
            "sleepy",
            Duration::from_millis(1),
            Duration::from_millis(30),
            || std::thread::sleep(Duration::from_millis(2)),
        );
        assert!(r.time.mean >= 1.5e-3, "{}", r.time.mean);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" µs"));
        assert!(human_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn summary_contains_name() {
        let r = bench("xyz", Duration::from_millis(1), Duration::from_millis(5), || {
            std::hint::black_box(3 + 4);
        });
        assert!(r.summary().contains("xyz"));
    }
}
