//! In-crate static analysis behind `astir lint` — the concurrency-hygiene
//! hard gate (zero dependencies, same spirit as [`crate::testutil`]).
//!
//! Six rules, each encoding an invariant the rest of the crate's tooling
//! relies on:
//!
//! * **L1 `ordering-justification`** — every atomic call site naming an
//!   `Ordering::` variant (`Relaxed`, `Acquire`, `Release`, `AcqRel`,
//!   `SeqCst`) must carry a comment mentioning that variant on the same
//!   line or within the 4 preceding lines. The model checker can only
//!   falsify a *stated* intent; this rule makes the intent exist.
//!   `src/sync/` is exempt (it *implements* the primitives).
//! * **L2 `sync-doorway`** — `std::sync` / `std::thread` paths may appear
//!   only under `src/sync/`: every other module must import from
//!   [`crate::sync`], otherwise the `--features model` build silently
//!   loses instrumentation for that call site.
//! * **L3 `safety-comment`** — every `unsafe` token (block, fn, or impl)
//!   needs a `SAFETY` comment on the same line or within the 5 preceding
//!   lines (attributes and doc lines in between are fine).
//! * **L4 `hygiene`** — no `dbg!` / `todo!` / `unimplemented!` in code,
//!   and no *code* extending past column 100 (string literals and
//!   comments may overflow — rustfmt cannot break those either).
//! * **L5 `net-doorway`** — `std::net` paths may appear only under
//!   `src/service/` (the serve front-end and its wire codec): tests and
//!   benches exercise the network through [`crate::service::wire`], so
//!   socket setup, timeouts, and shutdown live behind one audited seam.
//! * **L6 `simd-doorway`** — `std::arch` / `core::arch` paths, the
//!   `target_feature` attribute/cfg, the CPU feature-probe macro, and
//!   `_mm*` vector intrinsics may appear only under `src/linalg/simd/`
//!   (see [`crate::linalg::simd`]); inside the doorway, every
//!   intrinsic-bearing line must sit under a `SAFETY` comment naming the
//!   CPU feature (`AVX2` / `NEON`) within the 6 preceding lines.
//!   Everywhere else the crate is plain portable safe Rust.
//!
//! The analysis is source-level and deliberately simple: a byte classifier
//! ([`classify`]) splits each file into code / comment / string regions
//! (handling nested block comments, raw strings, and char literals), and
//! the rules pattern-match on the code region only — so rule names inside
//! string literals (this file!) or docs never trip the gate.
//!
//! Run as `astir lint [--root DIR]`; CI treats any finding as a hard
//! failure, and `tests/lint_gate.rs` enforces the same on `cargo test`.

use std::fmt;
use std::path::{Path, PathBuf};

/// Byte classes produced by [`classify`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Executable source (incl. attributes and whitespace).
    Code,
    /// `//`, `///`, `//!`, or (nested) `/* ... */` contents.
    Comment,
    /// String / raw-string / char-literal contents *and* delimiters.
    Str,
}

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule id (`L1`..`L6`).
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Classify every byte of `src` as code, comment, or string.
///
/// Handles line comments, nested block comments, plain and raw strings
/// (any `#` depth, with `b`/`r`/`br` prefixes), and char literals —
/// including the `'"'` case that would otherwise desynchronize string
/// state. Lifetimes (`'a`) are code.
pub fn classify(src: &str) -> Vec<Kind> {
    let b = src.as_bytes();
    let n = b.len();
    let mut kinds = vec![Kind::Code; n];
    let mut i = 0;
    let is_ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                kinds[i] = Kind::Comment;
                i += 1;
            }
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 0usize;
            while i < n {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    kinds[i] = Kind::Comment;
                    kinds[i + 1] = Kind::Comment;
                    i += 2;
                    depth += 1;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    kinds[i] = Kind::Comment;
                    kinds[i + 1] = Kind::Comment;
                    i += 2;
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else {
                    kinds[i] = Kind::Comment;
                    i += 1;
                }
            }
        } else if c == b'r' || c == b'b' {
            // Possible raw-string / byte-string prefix: r" r#" br" b" ...
            let prev_ident = i > 0 && is_ident(b[i - 1]);
            let mut j = i + 1;
            let mut had_r = c == b'r';
            if c == b'b' && j < n && b[j] == b'r' {
                had_r = true;
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if !prev_ident && j < n && b[j] == b'"' && (had_r || hashes == 0) {
                for k in i..=j {
                    kinds[k] = Kind::Str;
                }
                i = j + 1;
                if !had_r {
                    // b"..." — ordinary escapes apply.
                    i = scan_plain_str(b, &mut kinds, i);
                } else {
                    // Raw: ends at `"` followed by `hashes` `#`s.
                    while i < n {
                        kinds[i] = Kind::Str;
                        if b[i] == b'"' && i + hashes < n {
                            let close = (1..=hashes).all(|h| b[i + h] == b'#');
                            if close {
                                for h in 1..=hashes {
                                    kinds[i + h] = Kind::Str;
                                }
                                i += hashes + 1;
                                break;
                            }
                        }
                        i += 1;
                    }
                }
            } else {
                i += 1;
            }
        } else if c == b'"' {
            kinds[i] = Kind::Str;
            i = scan_plain_str(b, &mut kinds, i + 1);
        } else if c == b'\'' {
            // Char literal or lifetime. Escapes (`'\n'`) are literals;
            // `'x'` is a literal iff a closing quote follows the char.
            if i + 1 < n && b[i + 1] == b'\\' {
                kinds[i] = Kind::Str;
                let mut j = i + 1;
                while j < n && b[j] != b'\'' {
                    kinds[j] = Kind::Str;
                    j += 1;
                }
                if j < n {
                    kinds[j] = Kind::Str;
                }
                i = j + 1;
            } else {
                // Find the char boundary after the single content char.
                let start = i + 1;
                let mut j = start + 1;
                while j < n && (b[j] & 0xC0) == 0x80 {
                    j += 1; // skip UTF-8 continuation bytes
                }
                if start < n && j < n && b[j] == b'\'' {
                    for k in i..=j {
                        kinds[k] = Kind::Str;
                    }
                    i = j + 1;
                } else {
                    i += 1; // lifetime
                }
            }
        } else {
            i += 1;
        }
    }
    kinds
}

/// Continue a plain `"` string at byte `i` (opening quote already
/// classified); returns the index past the closing quote.
fn scan_plain_str(b: &[u8], kinds: &mut [Kind], mut i: usize) -> usize {
    while i < b.len() {
        kinds[i] = Kind::Str;
        match b[i] {
            b'\\' if i + 1 < b.len() => {
                kinds[i + 1] = Kind::Str;
                i += 2;
            }
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// A source line split by byte class: `code` keeps code bytes (comments
/// and strings blanked to spaces, so columns are preserved), `comment`
/// keeps only comment bytes.
struct MaskedLine {
    code: String,
    comment: String,
}

fn masked_lines(src: &str, kinds: &[Kind]) -> Vec<MaskedLine> {
    let mut out = Vec::new();
    let mut offset = 0;
    for line in src.split_inclusive('\n') {
        let mut code = String::with_capacity(line.len());
        let mut comment = String::with_capacity(line.len());
        for (j, ch) in line.char_indices() {
            match kinds[offset + j] {
                Kind::Code => {
                    code.push(ch);
                    comment.push(' ');
                }
                Kind::Comment => {
                    code.push(' ');
                    comment.push(ch);
                }
                Kind::Str => {
                    code.push(' ');
                    comment.push(' ');
                }
            }
        }
        out.push(MaskedLine { code, comment });
        offset += line.len();
    }
    out
}

const ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// How many preceding lines may hold the L1 justification comment.
const L1_WINDOW: usize = 4;
/// How many preceding lines may hold the L3 `SAFETY` comment.
const L3_WINDOW: usize = 5;
/// How many preceding lines may hold L6's feature-naming `SAFETY` comment.
const L6_WINDOW: usize = 6;

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// All positions where `needle` occurs in `hay` as a standalone token
/// (neither neighbor is an identifier character).
fn token_positions(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = hay[from..].find(needle) {
        let at = from + rel;
        let before_ok = at == 0 || !hay[..at].chars().next_back().is_some_and(is_ident_char);
        let after = at + needle.len();
        let after_ok = !hay[after..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + needle.len();
    }
    out
}

/// True if any comment within the `window` lines ending at `idx`
/// (inclusive) contains `needle`.
fn comment_window_contains(lines: &[MaskedLine], idx: usize, window: usize, needle: &str) -> bool {
    let lo = idx.saturating_sub(window);
    lines[lo..=idx].iter().any(|l| l.comment.contains(needle))
}

/// True if `hay` contains a token *starting with* `_mm` (an x86 vector
/// intrinsic such as `_mm256_loadu_pd`): an occurrence of `_mm` whose
/// preceding character is not an identifier character. A prefix scan, not
/// [`token_positions`], because the intrinsic name continues with
/// identifier characters after the prefix.
fn has_mm_intrinsic(hay: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = hay[from..].find("_mm") {
        let at = from + rel;
        if at == 0 || !hay[..at].chars().next_back().is_some_and(is_ident_char) {
            return true;
        }
        from = at + 3;
    }
    false
}

/// Lint one file's source text. `file` is the display path; rule
/// exemptions key off it (`src/sync/` prefix after normalization).
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    let norm = file.replace('\\', "/");
    let in_sync = norm.contains("src/sync/") || norm.ends_with("src/sync");
    let in_service = norm.contains("src/service/") || norm.ends_with("src/service");
    let in_simd = norm.contains("src/linalg/simd/") || norm.ends_with("src/linalg/simd");
    let kinds = classify(src);
    let lines = masked_lines(src, &kinds);
    let mut findings = Vec::new();
    let mut push = |line: usize, rule: &'static str, message: String| {
        findings.push(Finding { file: file.to_string(), line: line + 1, rule, message });
    };

    for (idx, line) in lines.iter().enumerate() {
        let code = line.code.as_str();

        // L1: Ordering:: variants need a nearby justification comment.
        if !in_sync {
            for at in code.match_indices("Ordering::").map(|(a, _)| a) {
                let rest = &code[at + "Ordering::".len()..];
                let variant = ORDERINGS
                    .iter()
                    .find(|v| rest.starts_with(**v) && token_positions(rest, v).contains(&0));
                if let Some(v) = variant {
                    if !comment_window_contains(&lines, idx, L1_WINDOW, v) {
                        push(
                            idx,
                            "L1",
                            format!(
                                "atomic uses Ordering::{v} without a comment mentioning \
                                 `{v}` on this line or the {L1_WINDOW} above"
                            ),
                        );
                    }
                }
            }
        }

        // L2: std::sync / std::thread only inside src/sync/.
        if !in_sync {
            for pat in ["std::sync", "std::thread"] {
                if !token_positions(code, pat).is_empty() {
                    push(
                        idx,
                        "L2",
                        format!("`{pat}` outside src/sync/ — import via crate::sync instead"),
                    );
                }
            }
        }

        // L5: std::net only inside src/service/.
        if !in_service && !token_positions(code, "std::net").is_empty() {
            push(
                idx,
                "L5",
                "`std::net` outside src/service/ — go through crate::service::wire instead"
                    .to_string(),
            );
        }

        // L6: arch intrinsics only inside the SIMD doorway; intrinsic call
        // sites there sit under a SAFETY comment naming the CPU feature.
        if !in_simd {
            for pat in ["std::arch", "core::arch", "target_feature", "is_x86_feature_detected"] {
                if !token_positions(code, pat).is_empty() {
                    push(
                        idx,
                        "L6",
                        format!(
                            "`{pat}` outside src/linalg/simd/ — SIMD dispatch goes through \
                             crate::linalg::simd"
                        ),
                    );
                }
            }
        }
        if has_mm_intrinsic(code) {
            if !in_simd {
                push(
                    idx,
                    "L6",
                    "`_mm*` intrinsic outside src/linalg/simd/ — SIMD dispatch goes through \
                     crate::linalg::simd"
                        .to_string(),
                );
            } else if !comment_window_contains(&lines, idx, L6_WINDOW, "SAFETY")
                || !(comment_window_contains(&lines, idx, L6_WINDOW, "AVX2")
                    || comment_window_contains(&lines, idx, L6_WINDOW, "NEON"))
            {
                push(
                    idx,
                    "L6",
                    format!(
                        "intrinsic without a SAFETY comment naming the CPU feature \
                         (AVX2/NEON) on this line or the {L6_WINDOW} above"
                    ),
                );
            }
        }

        // L3: `unsafe` needs a nearby SAFETY comment.
        if !token_positions(code, "unsafe").is_empty()
            && !comment_window_contains(&lines, idx, L3_WINDOW, "SAFETY")
        {
            push(
                idx,
                "L3",
                format!("`unsafe` without a SAFETY comment on this line or the {L3_WINDOW} above"),
            );
        }

        // L4: banned macros; code past column 100.
        for mac in ["dbg!", "todo!", "unimplemented!"] {
            if !token_positions(code, &mac[..mac.len() - 1]).is_empty() && code.contains(mac) {
                push(idx, "L4", format!("`{mac}` must not be committed"));
            }
        }
        let last_code_col =
            code.chars().enumerate().filter(|(_, c)| !c.is_whitespace()).map(|(i, _)| i + 1);
        if let Some(col) = last_code_col.last() {
            if col > 100 {
                push(idx, "L4", format!("code extends to column {col} (limit 100)"));
            }
        }
    }
    findings
}

/// Recursively collect `.rs` files under `dir` (sorted for stable output).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root`'s `src/`, `tests/`, `benches/`, and
/// `examples/` trees (whichever exist), plus a root `build.rs`.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches", "examples"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let build = root.join("build.rs");
    if build.is_file() {
        files.push(build);
    }
    let mut findings = Vec::new();
    for path in files {
        let src = std::fs::read_to_string(&path)?;
        let rel = path.strip_prefix(root).unwrap_or(&path);
        findings.extend(lint_source(&rel.to_string_lossy(), &src));
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds_at(src: &str) -> Vec<(char, Kind)> {
        src.chars().zip(classify(src)).collect()
    }

    #[test]
    fn classifier_masks_comments_and_strings() {
        let src = "let a = 1; // trailing\nlet s = \"std::sync\"; /* b /* nest */ c */ let t = 2;";
        let k = classify(src);
        let code: String = src
            .char_indices()
            .map(|(i, c)| if k[i] == Kind::Code { c } else { ' ' })
            .collect();
        assert!(code.contains("let a = 1;"));
        assert!(code.contains("let t = 2;"));
        assert!(!code.contains("trailing"));
        assert!(!code.contains("std::sync"));
        assert!(!code.contains("nest"));
    }

    #[test]
    fn classifier_handles_char_literals_and_lifetimes() {
        // The '"' char literal must not open a string.
        let src = "let q = '\"'; let l: &'static str = x; let n = '\\n';";
        let k = kinds_at(src);
        let code: String =
            k.iter().map(|&(c, kind)| if kind == Kind::Code { c } else { ' ' }).collect();
        assert!(code.contains("&'static str"));
        assert!(!code.contains('"'));
    }

    #[test]
    fn classifier_handles_raw_strings() {
        let src = "let r = r#\"std::thread \"inner\" \"#; let after = 1;";
        let k = classify(src);
        let code: String = src
            .char_indices()
            .map(|(i, c)| if k[i] == Kind::Code { c } else { ' ' })
            .collect();
        assert!(!code.contains("std::thread"));
        assert!(code.contains("let after = 1;"));
    }

    #[test]
    fn l1_requires_justification() {
        let bad = "fn f(a: &AtomicUsize) { a.load(Ordering::Relaxed); }";
        let f = lint_source("src/x.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "L1");
        assert_eq!(f[0].line, 1);

        let good = "// Relaxed: test-only counter.\nfn f(a: &AtomicUsize) {\n    \
                    a.load(Ordering::Relaxed);\n}";
        assert!(lint_source("src/x.rs", good).is_empty());

        let trailing = "a.load(Ordering::Acquire); // Acquire: pairs with release store";
        assert!(lint_source("src/x.rs", trailing).is_empty());

        // A comment naming the *wrong* ordering does not justify.
        let wrong = "// Relaxed: wrong note.\na.store(1, Ordering::Release);";
        assert_eq!(lint_source("src/x.rs", wrong).len(), 1);

        // The comment must be within the window.
        let far = format!("// Relaxed: too far.\n{}a.load(Ordering::Relaxed);", "\n".repeat(5));
        assert_eq!(lint_source("src/x.rs", &far).len(), 1);
    }

    #[test]
    fn l1_ignores_cmp_ordering_and_sync_module() {
        let cmp = "match x.cmp(&y) { std::cmp::Ordering::Less => 1, _ => 0 }";
        assert!(lint_source("src/x.rs", cmp).is_empty());
        let sync = "a.load(Ordering::SeqCst);";
        assert!(lint_source("src/sync/model/mod.rs", sync).is_empty());
    }

    #[test]
    fn l2_fences_the_doorway() {
        let bad = "use std::sync::Mutex;\nlet t = std::thread::spawn(f);";
        let f = lint_source("src/coordinator/mod.rs", bad);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "L2"));
        // Allowed inside the doorway, and in strings/comments anywhere.
        assert!(lint_source("src/sync/mod.rs", bad).is_empty());
        let masked = "// std::sync is discussed here\nlet s = \"std::thread\";";
        assert!(lint_source("src/x.rs", masked).is_empty());
    }

    #[test]
    fn l5_fences_the_net_doorway() {
        let bad = "use std::net::TcpStream;\nlet l = std::net::TcpListener::bind(a);";
        let f = lint_source("tests/serve_e2e.rs", bad);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "L5"));
        // Allowed inside the service doorway, and in strings/comments anywhere.
        assert!(lint_source("src/service/wire.rs", bad).is_empty());
        assert!(lint_source("src/service/server.rs", bad).is_empty());
        let masked = "// std::net is discussed here\nlet s = \"std::net\";";
        assert!(lint_source("src/x.rs", masked).is_empty());
    }

    #[test]
    fn l6_fences_the_simd_doorway() {
        let bad = "use core::arch::x86_64::_mm256_add_pd;\n\
                   #[target_feature(enable = \"avx2\")]\nfn f() {}";
        let f = lint_source("src/linalg/dense.rs", bad);
        // Line 1 trips twice (`core::arch` path + `_mm*` token), line 2 once.
        assert_eq!(f.len(), 3, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "L6"));

        // Inside the doorway: fine under a feature-naming SAFETY comment...
        let good = "// SAFETY (AVX2): probe-verified by the caller.\n\
                    let v = _mm256_setzero_pd();";
        assert!(lint_source("src/linalg/simd/avx2.rs", good).is_empty());
        // ...but a naked intrinsic, or SAFETY without the feature name,
        // still trips.
        let naked = "let v = _mm256_setzero_pd();";
        let f = lint_source("src/linalg/simd/avx2.rs", naked);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "L6");
        let vague = "// SAFETY: fine, trust me.\nlet v = _mm256_setzero_pd();";
        assert_eq!(lint_source("src/linalg/simd/avx2.rs", vague).len(), 1);
        // The comment must be within the window.
        let far = format!(
            "// SAFETY (AVX2): too far.\n{}let v = _mm256_setzero_pd();",
            "\n".repeat(6)
        );
        assert_eq!(lint_source("src/linalg/simd/avx2.rs", &far).len(), 1);

        // The probe macro is doorway-only too; strings/comments never trip.
        let probe = "let ok = is_x86_feature_detected!(\"avx2\");";
        assert!(lint_source("src/linalg/simd/mod.rs", probe).is_empty());
        assert_eq!(lint_source("src/backend/mod.rs", probe).len(), 1);
        let masked = "// std::arch is discussed here\nlet s = \"_mm256_add_pd\";";
        assert!(lint_source("src/x.rs", masked).is_empty());
    }

    #[test]
    fn l3_requires_safety_comment() {
        let bad = "fn f(p: *mut u8) { unsafe { *p = 0 } }";
        let f = lint_source("src/x.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L3");
        let good = "fn f(p: *mut u8) {\n    // SAFETY: p is valid per the contract above.\n    \
                    unsafe { *p = 0 }\n}";
        assert!(lint_source("src/x.rs", good).is_empty());
        // `unsafe_code` in attributes is not the `unsafe` token.
        assert!(lint_source("src/x.rs", "#![deny(unsafe_code)]").is_empty());
    }

    #[test]
    fn l4_bans_debug_macros_and_wide_code() {
        assert_eq!(lint_source("src/x.rs", "dbg!(x);").len(), 1);
        assert_eq!(lint_source("src/x.rs", "todo!()").len(), 1);
        let wide_code = format!("let x = {};", "1 + ".repeat(30) + "1");
        assert!(wide_code.len() > 100);
        let f = lint_source("src/x.rs", &wide_code);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "L4");
        // Overflow inside a string or comment is fine (rustfmt can't break
        // those either).
        let wide_str = format!("let s = \"{}\";", "x".repeat(120));
        assert!(lint_source("src/x.rs", &wide_str).is_empty());
        let wide_comment = format!("// {}", "y".repeat(120));
        assert!(lint_source("src/x.rs", &wide_comment).is_empty());
    }

    #[test]
    fn findings_render_with_location() {
        let f = lint_source("src/x.rs", "dbg!(1);");
        assert_eq!(format!("{}", f[0]), "src/x.rs:1: [L4] `dbg!` must not be committed");
    }
}
