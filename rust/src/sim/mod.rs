//! Discrete-time multicore simulator — the semantics of the paper's §IV-B.
//!
//! A **time step** is the time the fastest core needs for one iteration of
//! Algorithm 2. Within step `τ`:
//!
//! 1. *Read phase* — every core **starting** an iteration this step reads
//!    the same tally estimate `T̃ = supp_s(φ)` ("every core utilizes the
//!    same set identified by the tally"), samples its block, and computes
//!    its proxy/identify/estimate arithmetic from its **local** iterate.
//! 2. *Commit phase* — every core **finishing** an iteration this step
//!    (fast cores: the same step; slow cores with period `k`: `k−1` steps
//!    after the read — they compute from information that is `k−1` steps
//!    stale, which is the asynchrony hazard being studied) installs its new
//!    local iterate, casts its tally votes `φ_{Γ^t} += t`,
//!    `φ_{Γ^{t−1}} −= t−1`, and checks the exit criterion
//!    `||y − A x||_2 < tol`.
//!
//! The run terminates the first time **any** core passes the exit check
//! (the paper records that step count), or at `max_steps`.
//!
//! Beyond the paper, the simulator also implements:
//!
//! * [`SharingMode::SharedX`] — ablation A1: HOGWILD!-style sharing of the
//!   *iterate* instead of the tally (cores read the shared `x`, compute,
//!   and write their sparse updates back, zeroing their previously-written
//!   support). This is the strawman §I argues cannot work because dense
//!   cost functions make overwrites frequent.
//! * `stale_read_prob` — ablation A2: inconsistent reads of `φ`; each
//!   coordinate of the read snapshot is, with this probability, taken from
//!   the tally as of the *previous* step (an entry-granularity torn read).
//! * [`crate::tally::TallyWeighting`] — ablation A3.
//! * `self_exclude` — ablation A6 (a reproduction finding, not in the
//!   paper): each core subtracts its **own** standing vote before taking
//!   `supp_s(φ)`, so `T̃` carries only *other* cores' information. With
//!   this on, `c = 1` degenerates *exactly* to Algorithm 1 (empty `T̃`),
//!   which removes the small-`c` penalty of the literal Alg. 2 (see the
//!   reproduction notes in README.md).
//!
//! Tally-mode cores keep their local iterates as [`SparseIterate`]s and
//! step through their kernel's sparse fast path — bit-identical to the
//! dense step, but `O(b (s + |T̃|))` on the residual pass. The SharedX
//! ablation keeps a dense shared vector (overwrites break the sparse
//! invariant by design).
//!
//! The simulator is **generic over the algorithm**: [`simulate_with`]
//! drives any [`SupportKernel`] (StoIHT, StoGradMP, future kernels)
//! through the identical read/commit semantics, and [`simulate`] is the
//! StoIHT specialization the paper's figures use — bit-identical to the
//! pre-trait hardwired loop (pinned by `rust/tests/kernel_parity.rs`).

use crate::algorithms::{ShardedKernel, StoihtKernel, SupportKernel};
use crate::linalg::{MeasureOp, SparseIterate};
use crate::problem::Problem;
use crate::rng::Rng;
use crate::support::{support_of, union};
use crate::tally::{
    add_votes_into, merge_votes_into, positive_top_s, positive_top_s_into, ExchangeProtocol,
    LocalTally, TallyWeighting,
};

/// Per-core speed assignment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpeedSchedule {
    /// Every core completes one iteration per time step (Fig. 2 upper).
    AllFast,
    /// The first `ceil(c/2)` cores are fast; the rest complete one
    /// iteration every `period` steps (Fig. 2 lower uses `period = 4`).
    HalfSlow { period: usize },
    /// Explicit per-core periods (1 = fast).
    Custom(Vec<usize>),
}

impl SpeedSchedule {
    /// Resolve to per-core periods for `cores` cores.
    pub fn periods(&self, cores: usize) -> Vec<usize> {
        match self {
            SpeedSchedule::AllFast => vec![1; cores],
            SpeedSchedule::HalfSlow { period } => {
                assert!(*period >= 1);
                let fast = cores - cores / 2; // ceil(c/2) fast
                (0..cores).map(|i| if i < fast { 1 } else { *period }).collect()
            }
            SpeedSchedule::Custom(p) => {
                assert_eq!(p.len(), cores, "custom schedule length != cores");
                assert!(p.iter().all(|&k| k >= 1), "periods must be >= 1");
                p.clone()
            }
        }
    }
}

/// What the cores share.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SharingMode {
    /// The paper's Algorithm 2: share the tally `φ`, keep iterates local.
    Tally,
    /// Ablation A1: share the iterate `x` HOGWILD!-style (no tally).
    SharedX,
}

/// Simulator options (defaults = paper §IV).
#[derive(Clone, Debug)]
pub struct SimOpts {
    /// Step size `gamma`.
    pub gamma: f64,
    /// Exit tolerance on `||y − A x||_2`.
    pub tolerance: f64,
    /// Hard cap on global time steps.
    pub max_steps: usize,
    /// Tally weighting scheme (paper: `Progress`).
    pub weighting: TallyWeighting,
    /// Sharing mode (paper: `Tally`).
    pub mode: SharingMode,
    /// Probability that each coordinate of a tally read is one step stale.
    pub stale_read_prob: f64,
    /// A6: subtract the reading core's own standing vote from `φ` before
    /// `supp_s` (the paper's Alg. 2 reads the raw tally; default false).
    pub self_exclude: bool,
    /// Record per-step recovery error of the best core (diagnostics).
    pub record_error: bool,
}

impl Default for SimOpts {
    fn default() -> Self {
        SimOpts {
            gamma: 1.0,
            tolerance: 1e-7,
            max_steps: 1500, // the paper's cap applies to time steps too
            weighting: TallyWeighting::Progress,
            mode: SharingMode::Tally,
            stale_read_prob: 0.0,
            self_exclude: false,
            record_error: false,
        }
    }
}

/// Result of one simulated multicore run.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// Global time steps elapsed when the first core exited (or `max_steps`).
    pub steps: usize,
    /// Whether any core met the tolerance.
    pub converged: bool,
    /// Index of the first core to exit.
    pub exit_core: Option<usize>,
    /// Local iterations completed per core.
    pub local_iters: Vec<u64>,
    /// Recovery error of the exiting core's iterate (or best core at cap).
    pub final_error: f64,
    /// Per-step min-over-cores recovery error (empty unless `record_error`).
    pub error_trace: Vec<f64>,
}

/// The iterate produced by an in-flight iteration: sparse in Tally mode,
/// dense in the HOGWILD!-style SharedX ablation.
enum PendingX {
    Sparse(SparseIterate<f64>),
    Dense(Vec<f64>),
}

/// One in-flight iteration (between its read and commit steps).
struct Pending {
    commit_at: usize,
    new_x: PendingX,
    gamma: Vec<usize>,
    /// Support of `new_x` (sorted) for the sparse residual check.
    support: Vec<usize>,
}

/// Simulate asynchronous StoIHT with `cores` cores (paper Alg. 2 + §IV-B).
pub fn simulate(
    problem: &Problem,
    cores: usize,
    schedule: &SpeedSchedule,
    opts: &SimOpts,
    rng: &mut Rng,
) -> SimOutcome {
    simulate_with(problem, cores, schedule, opts, rng, |p| StoihtKernel::new(p, opts.gamma))
}

/// Simulate `cores` asynchronous cores driving any [`SupportKernel`]
/// (paper Alg. 2 + §IV-B semantics, algorithm-generic). `make_kernel`
/// builds one per-core step object; every sharing mode, speed schedule,
/// fault-injection knob, and weighting ablation composes with any kernel.
pub fn simulate_with<'p, K: SupportKernel>(
    problem: &'p Problem,
    cores: usize,
    schedule: &SpeedSchedule,
    opts: &SimOpts,
    rng: &mut Rng,
    make_kernel: impl Fn(&'p Problem) -> K,
) -> SimOutcome {
    assert!(cores >= 1);
    let spec = &problem.spec;
    let periods = schedule.periods(cores);
    let n = spec.n;
    let s = spec.s;

    // Per-core state.
    let mut kernels: Vec<K> = (0..cores).map(|_| make_kernel(problem)).collect();
    let mut rngs: Vec<Rng> = (0..cores).map(|i| rng.split(i as u64 + 1)).collect();
    let mut xs: Vec<SparseIterate<f64>> = (0..cores).map(|_| SparseIterate::zeros(n)).collect();
    let mut t_local: Vec<u64> = vec![1; cores];
    let mut prev_gamma: Vec<Vec<usize>> = vec![Vec::new(); cores];
    let mut pending: Vec<Option<Pending>> = (0..cores).map(|_| None).collect();

    // Shared state.
    let mut tally = LocalTally::new(n, opts.weighting);
    let mut prev_votes: Vec<i64> = vec![0; n]; // tally as of previous step
    let mut shared_x: Vec<f64> = vec![0.0; n]; // SharedX mode only
    let mut commit_order_rng = rng.split(0x5EED);
    let mut fault_rng = rng.split(0xFA17);

    // Exit-check scratch, shared across steps (the matrix-free operator's
    // workspace is ~4n floats — not a per-commit allocation).
    let mut exit_r_scratch: Vec<f64> = Vec::new();
    let mut exit_op_scratch = problem.op.make_scratch();

    let mut error_trace = Vec::new();

    for step in 1..=opts.max_steps {
        // ---- read phase: cores starting an iteration this step ----------
        // All readers in this step see the same tally state (pre-commit),
        // modulo injected stale coordinates (and minus their own vote under
        // A6 self-exclusion).
        let shared_estimate: Vec<usize> = if opts.mode == SharingMode::Tally && !opts.self_exclude {
            read_estimate(&tally, &prev_votes, s, opts.stale_read_prob, &mut fault_rng)
        } else {
            Vec::new()
        };
        for c in 0..cores {
            if pending[c].is_some() {
                continue; // mid-iteration (slow core)
            }
            if (step - 1) % periods[c] != 0 {
                continue; // not scheduled to start this step
            }
            let commit_at = step + periods[c] - 1;
            let block = kernels[c].sample_block(&mut rngs[c]);
            let p = match opts.mode {
                SharingMode::Tally => {
                    let estimate: Vec<usize> = if opts.self_exclude {
                        read_estimate_excluding(
                            &tally,
                            &prev_votes,
                            s,
                            opts.stale_read_prob,
                            &mut fault_rng,
                            &prev_gamma[c],
                            opts.weighting.add_weight(t_local[c].saturating_sub(1)),
                        )
                    } else {
                        shared_estimate.clone()
                    };
                    let mut new_x = xs[c].clone();
                    let mut gamma = Vec::new();
                    kernels[c].tally_step(&mut new_x, block, &estimate, &mut gamma);
                    let support = union(&gamma, &estimate);
                    Pending { commit_at, new_x: PendingX::Sparse(new_x), gamma, support }
                }
                SharingMode::SharedX => {
                    // HOGWILD!-style: read the shared iterate, no-tally step.
                    let mut new_x = shared_x.clone();
                    let mut gamma = Vec::new();
                    kernels[c].dense_step(&mut new_x, block, &mut gamma);
                    let support = gamma.clone();
                    Pending { commit_at, new_x: PendingX::Dense(new_x), gamma, support }
                }
            };
            pending[c] = Some(p);
        }

        // ---- commit phase: cores finishing an iteration this step --------
        prev_votes.copy_from_slice(tally.votes());
        let mut committers: Vec<usize> = (0..cores)
            .filter(|&c| pending[c].as_ref().is_some_and(|p| p.commit_at == step))
            .collect();
        // Randomize commit order (matters for SharedX overwrites).
        shuffle(&mut committers, &mut commit_order_rng);

        let mut exited: Option<(usize, f64)> = None;
        for &c in &committers {
            let p = pending[c].take().unwrap();
            match p.new_x {
                PendingX::Sparse(nx) => {
                    debug_assert_eq!(opts.mode, SharingMode::Tally);
                    xs[c] = nx;
                    tally.commit(&p.gamma, &prev_gamma[c], t_local[c]);
                    prev_gamma[c] = p.gamma;
                    t_local[c] += 1;
                    if exited.is_none() {
                        let r = problem.residual_norm_sparse_with(
                            xs[c].values(),
                            &p.support,
                            &mut exit_r_scratch,
                            &mut exit_op_scratch,
                        );
                        if r < opts.tolerance {
                            exited = Some((c, problem.recovery_error(xs[c].values())));
                        }
                    }
                }
                PendingX::Dense(nx) => {
                    debug_assert_eq!(opts.mode, SharingMode::SharedX);
                    // Zero what this core wrote last time, then write Γ^t.
                    for &i in &prev_gamma[c] {
                        shared_x[i] = 0.0;
                    }
                    for &i in &p.gamma {
                        shared_x[i] = nx[i];
                    }
                    prev_gamma[c] = p.gamma;
                    t_local[c] += 1;
                }
            }
        }
        if opts.mode == SharingMode::SharedX && !committers.is_empty() && exited.is_none() {
            // Exit is judged on the shared iterate after all writes land.
            let supp = support_of(&shared_x);
            let r = problem.residual_norm_sparse_with(
                &shared_x,
                &supp,
                &mut exit_r_scratch,
                &mut exit_op_scratch,
            );
            if r < opts.tolerance {
                exited = Some((usize::MAX, problem.recovery_error(&shared_x)));
            }
        }

        if opts.record_error {
            let err = match opts.mode {
                SharingMode::Tally => xs
                    .iter()
                    .map(|x| problem.recovery_error(x.values()))
                    .fold(f64::INFINITY, f64::min),
                SharingMode::SharedX => problem.recovery_error(&shared_x),
            };
            error_trace.push(err);
        }

        if let Some((core, err)) = exited {
            return SimOutcome {
                steps: step,
                converged: true,
                exit_core: if core == usize::MAX { None } else { Some(core) },
                local_iters: t_local.iter().map(|&t| t - 1).collect(),
                final_error: err,
                error_trace,
            };
        }
    }

    // Cap reached: report the best core (or the shared iterate).
    let final_error = match opts.mode {
        SharingMode::Tally => xs
            .iter()
            .map(|x| problem.recovery_error(x.values()))
            .fold(f64::INFINITY, f64::min),
        SharingMode::SharedX => problem.recovery_error(&shared_x),
    };
    SimOutcome {
        steps: opts.max_steps,
        converged: false,
        exit_core: None,
        local_iters: t_local.iter().map(|&t| t - 1).collect(),
        final_error,
        error_trace,
    }
}

/// Sharding axes for [`simulate_sharded_with`] and
/// [`crate::service::ShardedPool`]: how many shards partition the
/// measurement blocks, how often support estimates are exchanged, and
/// through which protocol.
#[derive(Clone, Debug)]
pub struct ShardOpts {
    /// Number of in-process shards `S` (1 = the unsharded single-tally
    /// path, bit-identical to [`simulate_with`] / `run_async`).
    pub shards: usize,
    /// Staleness bound `E`: exchange every `E` local steps; between
    /// exchanges a shard reads peer supports up to `E` steps stale.
    pub exchange_period: usize,
    /// How the per-shard tallies are merged at each exchange.
    pub protocol: ExchangeProtocol,
}

impl Default for ShardOpts {
    fn default() -> Self {
        ShardOpts { shards: 1, exchange_period: 16, protocol: ExchangeProtocol::Gossip }
    }
}

impl ShardOpts {
    pub fn validate(&self) -> Result<(), String> {
        if self.shards == 0 {
            return Err("shards must be >= 1".into());
        }
        if self.exchange_period == 0 {
            return Err("exchange_period must be >= 1".into());
        }
        Ok(())
    }
}

/// Sharded-tally specialization of [`simulate_sharded_with`] for StoIHT.
pub fn simulate_sharded(
    problem: &Problem,
    shard_opts: &ShardOpts,
    schedule: &SpeedSchedule,
    opts: &SimOpts,
    rng: &mut Rng,
) -> SimOutcome {
    simulate_sharded_with(problem, shard_opts, schedule, opts, rng, |p| {
        StoihtKernel::new(p, opts.gamma)
    })
}

/// Simulate the **sharded tally** design: `S` shards, each owning a
/// contiguous slice of the measurement blocks (via
/// [`crate::algorithms::ShardedKernel`]) and voting into a **local**
/// tally, with support estimates exchanged every `E` steps.
///
/// Semantics per global step `τ` (all shards lockstep; a shard with
/// schedule period `k` iterates every `k`-th step):
///
/// 1. *Exchange phase*, on steps with `(τ − 1) % E == 0`: every shard
///    publishes a snapshot of its local votes, and the stale views are
///    rebuilt with the commutative order-canonicalized merge of
///    [`merge_votes_into`]. Under [`ExchangeProtocol::Gossip`] shard `k`
///    keeps `Σ_{j≠k} snap_j`; under [`ExchangeProtocol::LeaderMerge`]
///    one merged view `Σ_j snap_j` is shared by all shards.
/// 2. *Iterate phase*: each scheduled shard reads its estimate — gossip:
///    `supp_s(own live votes + stale peer sum)`; leader-merge:
///    `supp_s(merged)`, its own contribution equally stale — then
///    samples a block **from its owned range**, steps, votes into its
///    local tally, and checks the exit criterion. Shards never touch
///    each other's tallies, so within-step ordering is immaterial and
///    the run is a pure function of `(problem, S, E, protocol, seed)`.
///
/// At `E = 1` every peer view is one step old for both protocols and
/// they coincide (pinned by a test); growing `E` is the staleness axis
/// the `sharded` bench suite charts. `S = 1` delegates to
/// [`simulate_with`] — one shard owns every block and reads only its own
/// live tally, which *is* the single-tally path, so the delegation keeps
/// it bit-identical by construction (also pinned).
///
/// The fault-injection ablations (`stale_read_prob`, `self_exclude`) are
/// single-tally concepts and are not simulated here; sharded staleness
/// is modeled by `E` alone. `SharedX` mode is rejected: sharding is
/// defined by partitioned tallies.
pub fn simulate_sharded_with<'p, K: SupportKernel>(
    problem: &'p Problem,
    shard_opts: &ShardOpts,
    schedule: &SpeedSchedule,
    opts: &SimOpts,
    rng: &mut Rng,
    make_kernel: impl Fn(&'p Problem) -> K,
) -> SimOutcome {
    let shards = shard_opts.shards;
    let e = shard_opts.exchange_period;
    assert!(shards >= 1 && e >= 1, "shards and exchange_period must be >= 1");
    assert_eq!(
        opts.mode,
        SharingMode::Tally,
        "sharded simulation shares tallies, not iterates (SharedX is a single-box ablation)"
    );
    if shards == 1 {
        return simulate_with(problem, 1, schedule, opts, rng, make_kernel);
    }

    let spec = &problem.spec;
    let n = spec.n;
    let s = spec.s;
    let periods = schedule.periods(shards);

    // Per-shard state (RNG derivation mirrors `simulate_with`).
    let mut kernels: Vec<ShardedKernel<K>> =
        (0..shards).map(|k| ShardedKernel::new(make_kernel(problem), k, shards)).collect();
    let mut rngs: Vec<Rng> = (0..shards).map(|i| rng.split(i as u64 + 1)).collect();
    let mut xs: Vec<SparseIterate<f64>> = (0..shards).map(|_| SparseIterate::zeros(n)).collect();
    let mut t_local: Vec<u64> = vec![1; shards];
    let mut prev_gamma: Vec<Vec<usize>> = vec![Vec::new(); shards];
    let mut tallies: Vec<LocalTally> =
        (0..shards).map(|_| LocalTally::new(n, opts.weighting)).collect();

    // Stale exchange views, rebuilt every `e` steps.
    let mut peer_sums: Vec<Vec<i64>> = vec![vec![0; n]; shards]; // gossip
    let mut merged: Vec<i64> = vec![0; n]; // leader-merge

    // Reused scratch.
    let mut combined: Vec<i64> = Vec::new();
    let mut estimate: Vec<usize> = Vec::new();
    let mut gamma: Vec<usize> = Vec::new();
    let mut exit_r_scratch: Vec<f64> = Vec::new();
    let mut exit_op_scratch = problem.op.make_scratch();
    let mut error_trace = Vec::new();

    for step in 1..=opts.max_steps {
        // ---- exchange phase -------------------------------------------
        if (step - 1) % e == 0 {
            let snapshots: Vec<Vec<i64>> =
                tallies.iter().map(|t| t.votes().to_vec()).collect();
            match shard_opts.protocol {
                ExchangeProtocol::Gossip => {
                    for (k, sum) in peer_sums.iter_mut().enumerate() {
                        merge_votes_into(&snapshots, Some(k), sum);
                    }
                }
                ExchangeProtocol::LeaderMerge => {
                    merge_votes_into(&snapshots, None, &mut merged);
                }
            }
        }

        // ---- iterate phase --------------------------------------------
        let mut exited: Option<(usize, f64)> = None;
        for k in 0..shards {
            if (step - 1) % periods[k] != 0 {
                continue; // not scheduled this step
            }
            match shard_opts.protocol {
                ExchangeProtocol::Gossip => {
                    combined.clear();
                    combined.extend_from_slice(tallies[k].votes());
                    add_votes_into(&mut combined, &peer_sums[k]);
                    positive_top_s_into(&combined, s, &mut estimate);
                }
                ExchangeProtocol::LeaderMerge => {
                    positive_top_s_into(&merged, s, &mut estimate);
                }
            }
            let block = kernels[k].sample_block(&mut rngs[k]);
            kernels[k].tally_step(&mut xs[k], block, &estimate, &mut gamma);
            tallies[k].commit(&gamma, &prev_gamma[k], t_local[k]);
            std::mem::swap(&mut prev_gamma[k], &mut gamma);
            t_local[k] += 1;
            if exited.is_none() {
                let support = union(&prev_gamma[k], &estimate);
                let r = problem.residual_norm_sparse_with(
                    xs[k].values(),
                    &support,
                    &mut exit_r_scratch,
                    &mut exit_op_scratch,
                );
                if r < opts.tolerance {
                    exited = Some((k, problem.recovery_error(xs[k].values())));
                }
            }
        }

        if opts.record_error {
            let err = xs
                .iter()
                .map(|x| problem.recovery_error(x.values()))
                .fold(f64::INFINITY, f64::min);
            error_trace.push(err);
        }

        if let Some((shard, err)) = exited {
            return SimOutcome {
                steps: step,
                converged: true,
                exit_core: Some(shard),
                local_iters: t_local.iter().map(|&t| t - 1).collect(),
                final_error: err,
                error_trace,
            };
        }
    }

    let final_error =
        xs.iter().map(|x| problem.recovery_error(x.values())).fold(f64::INFINITY, f64::min);
    SimOutcome {
        steps: opts.max_steps,
        converged: false,
        exit_core: None,
        local_iters: t_local.iter().map(|&t| t - 1).collect(),
        final_error,
        error_trace,
    }
}

/// Read `T̃` with staleness injection, minus the reading core's own
/// standing vote (`own_weight` on `own_gamma`) — A6 self-exclusion.
fn read_estimate_excluding(
    tally: &LocalTally,
    prev_votes: &[i64],
    s: usize,
    stale_prob: f64,
    fault_rng: &mut Rng,
    own_gamma: &[usize],
    own_weight: i64,
) -> Vec<usize> {
    let cur = tally.votes();
    let mut mixed: Vec<i64> = if stale_prob <= 0.0 {
        cur.to_vec()
    } else {
        (0..cur.len())
            .map(|i| if fault_rng.bernoulli(stale_prob) { prev_votes[i] } else { cur[i] })
            .collect()
    };
    for &i in own_gamma {
        mixed[i] -= own_weight;
    }
    positive_top_s(&mixed, s)
}

/// Read `T̃` with optional per-coordinate staleness injection.
fn read_estimate(
    tally: &LocalTally,
    prev_votes: &[i64],
    s: usize,
    stale_prob: f64,
    fault_rng: &mut Rng,
) -> Vec<usize> {
    if stale_prob <= 0.0 {
        return tally.estimate(s);
    }
    let cur = tally.votes();
    let mixed: Vec<i64> = (0..cur.len())
        .map(|i| if fault_rng.bernoulli(stale_prob) { prev_votes[i] } else { cur[i] })
        .collect();
    positive_top_s(&mixed, s)
}

/// Fisher–Yates shuffle using the crate RNG.
fn shuffle<T>(items: &mut [T], rng: &mut Rng) {
    for i in (1..items.len()).rev() {
        let j = rng.below(i + 1);
        items.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn schedules_resolve_correctly() {
        assert_eq!(SpeedSchedule::AllFast.periods(3), vec![1, 1, 1]);
        assert_eq!(SpeedSchedule::HalfSlow { period: 4 }.periods(4), vec![1, 1, 4, 4]);
        // odd cores: ceil(c/2) fast
        assert_eq!(SpeedSchedule::HalfSlow { period: 4 }.periods(5), vec![1, 1, 1, 4, 4]);
        assert_eq!(SpeedSchedule::Custom(vec![1, 2, 3]).periods(3), vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "length != cores")]
    fn custom_schedule_length_checked() {
        SpeedSchedule::Custom(vec![1]).periods(2);
    }

    #[test]
    fn single_core_converges() {
        let p = easy(1);
        let out =
            simulate(&p, 1, &SpeedSchedule::AllFast, &SimOpts::default(), &mut Rng::seed_from(7));
        assert!(out.converged, "steps {}", out.steps);
        assert!(out.final_error < 1e-5);
        assert_eq!(out.exit_core, Some(0));
        assert_eq!(out.local_iters.len(), 1);
        assert_eq!(out.local_iters[0] as usize, out.steps);
    }

    #[test]
    fn multicore_converges_and_is_deterministic() {
        let p = easy(2);
        let a =
            simulate(&p, 4, &SpeedSchedule::AllFast, &SimOpts::default(), &mut Rng::seed_from(9));
        let b =
            simulate(&p, 4, &SpeedSchedule::AllFast, &SimOpts::default(), &mut Rng::seed_from(9));
        assert!(a.converged);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.exit_core, b.exit_core);
        assert_eq!(a.local_iters, b.local_iters);
    }

    #[test]
    fn more_cores_do_not_hurt_on_average() {
        let mut total1 = 0usize;
        let mut total8 = 0usize;
        let sched = SpeedSchedule::AllFast;
        let opts = SimOpts::default();
        for seed in 0..6u64 {
            let p = easy(40 + seed);
            let o1 = simulate(&p, 1, &sched, &opts, &mut Rng::seed_from(seed));
            let o8 = simulate(&p, 8, &sched, &opts, &mut Rng::seed_from(seed));
            assert!(o1.converged && o8.converged);
            total1 += o1.steps;
            total8 += o8.steps;
        }
        assert!(total8 <= total1, "8 cores {total8} vs 1 core {total1}");
    }

    #[test]
    fn slow_cores_complete_fewer_local_iterations() {
        let p = easy(3);
        let out = simulate(
            &p,
            4,
            &SpeedSchedule::HalfSlow { period: 4 },
            &SimOpts::default(),
            &mut Rng::seed_from(11),
        );
        assert!(out.converged);
        // Cores 0,1 fast; 2,3 slow: slow complete ~steps/4 iterations.
        let fast = out.local_iters[0].max(out.local_iters[1]);
        let slow = out.local_iters[2].max(out.local_iters[3]);
        assert!(slow <= fast / 2 + 1, "fast {fast} slow {slow}");
    }

    #[test]
    fn shared_x_single_core_converges() {
        // c=1 SharedX is plain sequential StoIHT (no tally, no contention).
        let p = easy(4);
        let opts = SimOpts { mode: SharingMode::SharedX, ..Default::default() };
        let out = simulate(&p, 1, &SpeedSchedule::AllFast, &opts, &mut Rng::seed_from(5));
        assert!(out.converged);
        assert!(out.final_error < 1e-5);
    }

    #[test]
    fn stale_reads_do_not_break_convergence() {
        let p = easy(5);
        let opts = SimOpts { stale_read_prob: 0.3, ..Default::default() };
        let out = simulate(&p, 4, &SpeedSchedule::AllFast, &opts, &mut Rng::seed_from(6));
        assert!(out.converged, "steps {}", out.steps);
    }

    #[test]
    fn max_steps_cap_is_respected() {
        let p = easy(6);
        let opts = SimOpts { max_steps: 3, ..Default::default() };
        let out = simulate(&p, 2, &SpeedSchedule::AllFast, &opts, &mut Rng::seed_from(8));
        assert!(!out.converged);
        assert_eq!(out.steps, 3);
        assert!(out.final_error.is_finite());
    }

    #[test]
    fn error_trace_recorded_when_asked() {
        let p = easy(7);
        let opts = SimOpts { record_error: true, max_steps: 20, ..Default::default() };
        let out = simulate(&p, 2, &SpeedSchedule::AllFast, &opts, &mut Rng::seed_from(3));
        assert_eq!(out.error_trace.len(), out.steps);
        // errors are finite and eventually decrease
        assert!(out.error_trace.iter().all(|e| e.is_finite()));
    }

    #[test]
    fn generic_sim_drives_stogradmp_through_every_mode() {
        // The tentpole guarantee: every existing mode composes with the
        // StoGradMP kernel through the same generic loop.
        use crate::algorithms::StoGradMpKernel;
        let p = easy(9);
        let sched = SpeedSchedule::AllFast;
        let variants = [
            SimOpts { max_steps: 200, ..Default::default() },
            SimOpts { max_steps: 200, self_exclude: true, ..Default::default() },
            SimOpts { max_steps: 200, stale_read_prob: 0.3, ..Default::default() },
            SimOpts { max_steps: 200, weighting: TallyWeighting::Unit, ..Default::default() },
            SimOpts { max_steps: 200, mode: SharingMode::SharedX, ..Default::default() },
        ];
        for (k, opts) in variants.iter().enumerate() {
            let mut rng = Rng::seed_from(30 + k as u64);
            let out = simulate_with(&p, 4, &sched, opts, &mut rng, StoGradMpKernel::new);
            assert!(out.converged, "variant {k} did not converge in {} steps", out.steps);
            assert!(out.final_error < 1e-5, "variant {k} error {}", out.final_error);
            // GradMP-family needs far fewer steps than StoIHT.
            assert!(out.steps < 100, "variant {k} steps {}", out.steps);
        }
        // Half-slow schedule composes too.
        let out = simulate_with(
            &p,
            4,
            &SpeedSchedule::HalfSlow { period: 4 },
            &SimOpts { max_steps: 300, ..Default::default() },
            &mut Rng::seed_from(77),
            StoGradMpKernel::new,
        );
        assert!(out.converged);
    }

    #[test]
    fn matrix_free_problems_drive_the_simulator() {
        // The simulator is representation-agnostic: kernels route through
        // the problem's MeasureOp, so a matrix-free subsampled-DCT problem
        // runs every mode without an m x n matrix existing anywhere.
        use crate::algorithms::StoGradMpKernel;
        let p = ProblemSpec::tiny_matrix_free().generate(&mut Rng::seed_from(31));
        let out = simulate(
            &p,
            4,
            &SpeedSchedule::AllFast,
            &SimOpts::default(),
            &mut Rng::seed_from(32),
        );
        assert!(out.converged, "steps {}", out.steps);
        assert!(out.final_error < 1e-5);
        let out = simulate_with(
            &p,
            2,
            &SpeedSchedule::AllFast,
            &SimOpts { max_steps: 200, ..Default::default() },
            &mut Rng::seed_from(33),
            StoGradMpKernel::new,
        );
        assert!(out.converged, "stogradmp steps {}", out.steps);
    }

    #[test]
    fn sharded_s1_is_bit_identical_to_the_single_tally_path() {
        // Acceptance pin: at S = 1 the sharded entry point IS the
        // single-tally simulator, for both kernels, to the bit.
        use crate::algorithms::StoGradMpKernel;
        let p = easy(21);
        let opts = SimOpts { max_steps: 400, ..Default::default() };
        let sched = SpeedSchedule::AllFast;
        for e in [1usize, 16, 64] {
            let sharded = ShardOpts { shards: 1, exchange_period: e, ..Default::default() };
            let a = simulate_sharded(&p, &sharded, &sched, &opts, &mut Rng::seed_from(13));
            let b = simulate(&p, 1, &sched, &opts, &mut Rng::seed_from(13));
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.exit_core, b.exit_core);
            assert_eq!(a.local_iters, b.local_iters);
            assert_eq!(a.final_error.to_bits(), b.final_error.to_bits(), "E={e}");
            let a = simulate_sharded_with(
                &p,
                &sharded,
                &sched,
                &opts,
                &mut Rng::seed_from(14),
                StoGradMpKernel::new,
            );
            let b =
                simulate_with(&p, 1, &sched, &opts, &mut Rng::seed_from(14), StoGradMpKernel::new);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.final_error.to_bits(), b.final_error.to_bits(), "stogradmp E={e}");
        }
    }

    #[test]
    fn sharded_converges_and_is_deterministic_for_both_protocols() {
        let p = easy(22);
        let sched = SpeedSchedule::AllFast;
        for protocol in [ExchangeProtocol::Gossip, ExchangeProtocol::LeaderMerge] {
            for shards in [2usize, 4] {
                let so = ShardOpts { shards, exchange_period: 4, protocol };
                let opts = SimOpts { max_steps: 800, ..Default::default() };
                let a = simulate_sharded(&p, &so, &sched, &opts, &mut Rng::seed_from(17));
                let b = simulate_sharded(&p, &so, &sched, &opts, &mut Rng::seed_from(17));
                assert!(a.converged, "{protocol:?} S={shards} steps {}", a.steps);
                assert!(a.final_error < 1e-5);
                assert_eq!(a.steps, b.steps);
                assert_eq!(a.exit_core, b.exit_core);
                assert_eq!(a.local_iters, b.local_iters);
                assert_eq!(a.final_error.to_bits(), b.final_error.to_bits());
            }
        }
    }

    #[test]
    fn protocols_coincide_at_exchange_period_one() {
        // With E = 1, a gossip shard's "own live" votes equal its own
        // just-published snapshot, so gossip's view (own + peer snaps)
        // equals leader-merge's view (all snaps) — the two protocols are
        // the same algorithm at staleness zero.
        let p = easy(23);
        let opts = SimOpts { max_steps: 800, ..Default::default() };
        for shards in [2usize, 3] {
            let mk = |protocol| ShardOpts { shards, exchange_period: 1, protocol };
            let g = simulate_sharded(
                &p,
                &mk(ExchangeProtocol::Gossip),
                &SpeedSchedule::AllFast,
                &opts,
                &mut Rng::seed_from(19),
            );
            let l = simulate_sharded(
                &p,
                &mk(ExchangeProtocol::LeaderMerge),
                &SpeedSchedule::AllFast,
                &opts,
                &mut Rng::seed_from(19),
            );
            assert_eq!(g.steps, l.steps, "S={shards}");
            assert_eq!(g.exit_core, l.exit_core);
            assert_eq!(g.final_error.to_bits(), l.final_error.to_bits());
        }
    }

    #[test]
    fn bounded_staleness_slows_but_does_not_break_recovery() {
        let p = easy(24);
        let sched = SpeedSchedule::AllFast;
        let opts = SimOpts { max_steps: 1500, ..Default::default() };
        let fresh = ShardOpts { shards: 4, exchange_period: 1, ..Default::default() };
        let stale = ShardOpts { shards: 4, exchange_period: 64, ..Default::default() };
        let a = simulate_sharded(&p, &fresh, &sched, &opts, &mut Rng::seed_from(25));
        let b = simulate_sharded(&p, &stale, &sched, &opts, &mut Rng::seed_from(25));
        assert!(a.converged && b.converged, "E=1: {} steps, E=64: {} steps", a.steps, b.steps);
        assert!(b.final_error < 1e-5);
    }

    #[test]
    fn shard_opts_validate_rejects_zeros() {
        assert!(ShardOpts::default().validate().is_ok());
        assert!(ShardOpts { shards: 0, ..Default::default() }.validate().is_err());
        assert!(ShardOpts { exchange_period: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn tally_weighting_variants_run() {
        let p = easy(8);
        for w in [TallyWeighting::Progress, TallyWeighting::Unit, TallyWeighting::NoDecrement] {
            let opts = SimOpts { weighting: w, ..Default::default() };
            let out = simulate(&p, 4, &SpeedSchedule::AllFast, &opts, &mut Rng::seed_from(2));
            assert!(out.converged, "{w:?} did not converge");
        }
    }
}
