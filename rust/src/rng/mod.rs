//! Deterministic, splittable pseudo-randomness.
//!
//! Everything stochastic in the crate — measurement matrices, sparse
//! signals, block sampling, core interleavings — flows through this module
//! so that every experiment is reproducible from a single `u64` seed and
//! every Monte-Carlo trial / worker core gets an *independent* stream
//! ([`Rng::split`], seeded via SplitMix64 like the reference xoshiro
//! implementation recommends).
//!
//! The generator is **xoshiro256++** (Blackman & Vigna): 4x64-bit state,
//! sub-ns per draw, passes BigCrush; Gaussian variates use the polar
//! Box–Muller method with a cached spare.

/// SplitMix64 — used to expand seeds into xoshiro state and to derive
/// independent child seeds.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator with Gaussian support.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64 (SplitMix64 expansion).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child stream (e.g. one per trial or per core).
    ///
    /// Uses fresh SplitMix64 output keyed by the next raw draw and the
    /// index, so `split(i)` and `split(j)` are uncorrelated for `i != j`
    /// and neither correlates with the parent's continuation.
    pub fn split(&mut self, index: u64) -> Rng {
        let mut sm = self.next_u64() ^ index.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64 random bits (xoshiro256++ step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift with rejection).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: accept unless lo < (2^64 mod n).
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal variate (polar Box–Muller with cached spare).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// `k` distinct indices drawn uniformly from `[0, n)`, in random order
    /// (partial Fisher–Yates over an index table; O(n) memory, O(n) time —
    /// fine at the crate's dimensions).
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "subset: k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from an (unnormalized, nonnegative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0);
            if u < w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1 // numerical slack
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Random sign (+1.0 / -1.0).
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(123);
        let mut b = Rng::seed_from(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut parent1 = Rng::seed_from(5);
        let mut parent2 = Rng::seed_from(5);
        let mut c1 = parent1.split(0);
        let mut c2 = parent2.split(0);
        for _ in 0..50 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        let mut p = Rng::seed_from(5);
        let mut a = p.split(1);
        let mut b = p.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::seed_from(99);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::seed_from(7);
        let mut counts = [0usize; 10];
        for _ in 0..50_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            assert!((4000..6000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::seed_from(31);
        let n = 100_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let g = rng.gauss();
            s1 += g;
            s2 += g * g;
            s4 += g * g * g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        let kurt = s4 / n as f64 / (var * var);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
        assert!((kurt - 3.0).abs() < 0.15, "kurtosis {kurt}");
    }

    #[test]
    fn subset_distinct_and_in_range() {
        let mut rng = Rng::seed_from(3);
        for _ in 0..200 {
            let k = rng.below(20);
            let s = rng.subset(50, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in subset");
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn subset_covers_uniformly() {
        let mut rng = Rng::seed_from(13);
        let mut counts = [0usize; 20];
        for _ in 0..20_000 {
            for i in rng.subset(20, 3) {
                counts[i] += 1;
            }
        }
        // Each index expected 3000 hits.
        for &c in &counts {
            assert!((2500..3500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::seed_from(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "below(0)")]
    fn below_zero_panics() {
        Rng::seed_from(0).below(0);
    }

    #[test]
    fn bernoulli_and_sign() {
        let mut rng = Rng::seed_from(23);
        let heads = (0..10_000).filter(|_| rng.bernoulli(0.25)).count();
        assert!((2000..3000).contains(&heads), "{heads}");
        let pos = (0..10_000).filter(|_| rng.sign() > 0.0).count();
        assert!((4500..5500).contains(&pos), "{pos}");
    }
}
