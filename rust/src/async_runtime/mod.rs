//! Real-thread asynchronous sparse recovery — the deployment the paper
//! *simulates*.
//!
//! `c` OS threads run Algorithm 2 concurrently against a lock-free
//! [`crate::tally::AtomicTally`]; there are no barriers, no locks on the
//! solve path, and reads of `φ` are genuinely inconsistent (relaxed atomic
//! loads racing concurrent `fetch_add`s). The first worker whose local
//! iterate passes `||y − A x||_2 < tol` raises a stop flag; everyone else
//! drains out. This module turns the paper's simulated claim ("a speedup
//! in total time is expected") into a measured wallclock number (see
//! README.md and the `hot_path` / `stogradmp_async` benches).
//!
//! The runtime is **generic over the algorithm**: [`run_async_with`]
//! drives any [`SupportKernel`] — StoIHT ([`run_async`], the default),
//! StoGradMP (`StoGradMpKernel`), or the PJRT-backed [`BackendStep`] —
//! through the identical read/vote/commit/exit protocol. It is also
//! agnostic to the **measurement representation**: the native kernels
//! speak [`crate::linalg::MeasureOp`], so the same threads run against the
//! materialized matrix or the matrix-free subsampled-DCT operator
//! (`dense_a = false`), which is how `n = 10^6` recoveries fit in memory
//! (see the `large_n` bench suite).
//!
//! The worker inner loop is allocation-free after warmup: iterates are
//! [`SparseIterate`]s driven through each kernel's sparse fast path, `Γ^t`
//! is written into reused buffers (no per-iteration `to_vec`), and the
//! tally estimate and the sparse exit check run in caller-owned scratch.
//!
//! Slow cores are emulated by *work*, not sleep: a worker with period `k`
//! burns its kernel's identify-phase compute `k − 1` extra times per
//! iteration, so the time-dilation is made of the same memory traffic the
//! fast cores issue — closer to a genuinely contended machine than
//! `thread::sleep`.

use std::time::{Duration, Instant};

use crate::algorithms::{StoihtKernel, SupportKernel};
use crate::backend::Backend;
use crate::linalg::{MeasureOp, SparseIterate};
use crate::problem::Problem;
use crate::rng::Rng;
use crate::sim::SpeedSchedule;
use crate::support::union_into;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{thread, Mutex};
use crate::tally::{AtomicTally, TallyWeighting};

/// Options for the real-thread runtime.
#[derive(Clone, Debug)]
pub struct AsyncOpts {
    /// Step size used by the default StoIHT factory ([`run_async`]).
    /// Kernels bake their step size at construction, so a custom
    /// [`run_async_with`] factory must thread it itself (e.g.
    /// `BackendStep::new(p, backend).with_gamma(opts.gamma)`) — the
    /// runtime cannot inject it after the fact.
    pub gamma: f64,
    pub tolerance: f64,
    /// Per-worker local iteration cap.
    pub max_local_iters: usize,
    /// Tally weighting (paper: Progress).
    pub weighting: TallyWeighting,
    /// Check the exit residual every `check_every` local iterations.
    pub check_every: usize,
    /// Per-core speed schedule (slow = extra proxy recomputations).
    pub schedule: SpeedSchedule,
}

impl Default for AsyncOpts {
    fn default() -> Self {
        AsyncOpts {
            gamma: 1.0,
            tolerance: 1e-7,
            max_local_iters: 1500,
            weighting: TallyWeighting::Progress,
            check_every: 1,
            schedule: SpeedSchedule::AllFast,
        }
    }
}

/// Result of a real-thread run.
#[derive(Clone, Debug)]
pub struct AsyncOutcome {
    /// Wallclock from launch to the winner's exit signal.
    pub wall: Duration,
    /// Whether any worker met the tolerance.
    pub converged: bool,
    /// Winning worker id.
    pub exit_core: Option<usize>,
    /// Local iterations completed per worker at drain time.
    pub local_iters: Vec<u64>,
    /// Winner's final `||y − A x||`.
    pub residual: f64,
    /// Winner's recovery error.
    pub final_error: f64,
    /// Winner's iterate.
    pub x: Vec<f64>,
}

/// Winner info published through the stop protocol.
struct ExitInfo {
    core: usize,
    residual: f64,
    x: Vec<f64>,
    at: Instant,
}

/// The per-worker Algorithm-2 loop body, shared verbatim by the scoped
/// real-thread runtime ([`run_async_with`]) and the persistent
/// [`crate::service::RecoveryPool`] (which runs it inline on a long-lived
/// worker for single-signal jobs — that sharing is what makes pool results
/// **bit-identical** to a spawn-per-call `cores = 1` run).
///
/// Runs read/vote/commit/exit iterations until the tolerance is met
/// (returns `Some(residual)` — the caller publishes `x` and raises the
/// stop flag), another worker raises `stop`, or the local iteration cap is
/// reached (both `None`). `counter` observes the worker's local iteration
/// count throughout.
#[allow(clippy::too_many_arguments)]
pub(crate) fn drive_worker<K: SupportKernel>(
    step: &mut K,
    x: &mut SparseIterate<f64>,
    s: usize,
    opts: &AsyncOpts,
    period: usize,
    rng: &mut Rng,
    tally: &AtomicTally,
    stop: &AtomicBool,
    counter: &AtomicU64,
) -> Option<f64> {
    let mut driver = WorkerDriver::new();
    let upto = opts.max_local_iters as u64;
    driver.drive(step, x, s, opts, period, rng, tally, tally, stop, counter, upto)
}

/// The resumable form of [`drive_worker`]: per-worker scratch plus the
/// local iteration cursor, so a caller can run the Algorithm-2 loop in
/// segments — which is how [`crate::service::ShardedPool`] interleaves
/// `E`-iteration chunks with exchange rounds without perturbing the
/// single-tally loop (the `drive_worker` wrapper above runs one
/// full-length segment and is bit-identical to the pre-refactor body).
pub(crate) struct WorkerDriver {
    // Reused per-iteration buffers — the loop below does no heap
    // allocation once these reach steady-state capacity.
    gamma: Vec<usize>,
    prev_gamma: Vec<usize>,
    estimate: Vec<usize>,
    tally_scratch: Vec<i64>,
    resid_scratch: Vec<f64>,
    /// Next local iteration to run (`t` starts at 1).
    t: u64,
}

impl WorkerDriver {
    pub(crate) fn new() -> WorkerDriver {
        WorkerDriver {
            gamma: Vec::new(),
            prev_gamma: Vec::new(),
            estimate: Vec::new(),
            tally_scratch: Vec::new(),
            resid_scratch: Vec::new(),
            t: 1,
        }
    }

    /// Local iterations completed so far.
    pub(crate) fn local_iters(&self) -> u64 {
        self.t - 1
    }

    /// Run local iterations up to and including `upto` (the caller also
    /// caps at `opts.max_local_iters`). Estimates are read from `read`
    /// and votes committed to `vote` — the same tally for the
    /// single-tally runtimes; a shard splits them under leader-merge,
    /// where the read side is a frozen merged view between exchanges.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn drive<K: SupportKernel>(
        &mut self,
        step: &mut K,
        x: &mut SparseIterate<f64>,
        s: usize,
        opts: &AsyncOpts,
        period: usize,
        rng: &mut Rng,
        read: &AtomicTally,
        vote: &AtomicTally,
        stop: &AtomicBool,
        counter: &AtomicU64,
        upto: u64,
    ) -> Option<f64> {
        let upto = upto.min(opts.max_local_iters as u64);
        while self.t <= upto {
            let t = self.t;
            // Acquire: pairs with the winner's Release store so the drain
            // observes the published ExitInfo (the mutex would suffice, but
            // the flag is also the cheap fast-path check).
            if stop.load(Ordering::Acquire) {
                break;
            }
            // read: T̃ = supp_s(φ) — racy by design.
            read.estimate_into(s, &mut self.tally_scratch, &mut self.estimate);
            let block = step.sample_block(rng);
            // slow-core emulation: burn (period-1) identify phases.
            for _ in 1..period {
                step.burn(x, block);
            }
            step.tally_step(x, block, &self.estimate, &mut self.gamma);
            // update tally: φ_Γt += t, φ_Γ(t-1) -= t-1 (atomic RMWs).
            vote.commit(&self.gamma, &self.prev_gamma, t);
            std::mem::swap(&mut self.prev_gamma, &mut self.gamma);
            // Relaxed: progress telemetry only; readers join (or quiesce)
            // before trusting the final value.
            counter.store(t, Ordering::Relaxed);
            self.t += 1;
            if t as usize % opts.check_every == 0 {
                // The kernel's sparse exit check over x's support
                // (Γ^t ∪ T̃ for StoIHT, the pruned Γ^t for GradMP).
                let r = step.residual(x, &mut self.resid_scratch);
                if r < opts.tolerance {
                    return Some(r);
                }
            }
        }
        None
    }
}

/// Run asynchronous StoIHT on `cores` OS threads (native compute).
pub fn run_async(problem: &Problem, cores: usize, opts: &AsyncOpts, seed: u64) -> AsyncOutcome {
    run_async_with(problem, cores, opts, seed, |p| StoihtKernel::new(p, opts.gamma))
}

/// As [`run_async`] but generic over the per-worker [`SupportKernel`]
/// factory: asynchronous StoGradMP (`|p| StoGradMpKernel::new(p)`), the
/// PJRT-backed step (`examples/e2e_pjrt.rs`), or any future kernel. The
/// factory crosses the thread boundary (it must be `Sync`), never the
/// kernel — each worker constructs its step inside its own thread.
pub fn run_async_with<'p, K, F>(
    problem: &'p Problem,
    cores: usize,
    opts: &AsyncOpts,
    seed: u64,
    make_step: F,
) -> AsyncOutcome
where
    K: SupportKernel + 'p,
    F: Fn(&'p Problem) -> K + Sync,
{
    assert!(cores >= 1);
    let spec = &problem.spec;
    let periods = opts.schedule.periods(cores);
    let tally = AtomicTally::new(spec.n, opts.weighting);
    let stop = AtomicBool::new(false);
    let exit_info: Mutex<Option<ExitInfo>> = Mutex::new(None);
    let iter_counters: Vec<AtomicU64> = (0..cores).map(|_| AtomicU64::new(0)).collect();
    let mut seed_root = Rng::seed_from(seed);
    let worker_rngs: Vec<Rng> = (0..cores).map(|i| seed_root.split(i as u64)).collect();
    let start = Instant::now();

    thread::scope(|scope| {
        for w in 0..cores {
            let mut rng = worker_rngs[w].clone();
            let tally = &tally;
            let stop = &stop;
            let exit_info = &exit_info;
            let counter = &iter_counters[w];
            let period = periods[w];
            let make_step = &make_step;
            scope.spawn(move || {
                let mut step = make_step(problem);
                let mut x = SparseIterate::zeros(spec.n);
                let won = drive_worker(
                    &mut step, &mut x, spec.s, opts, period, &mut rng, tally, stop, counter,
                );
                if let Some(r) = won {
                    let mut guard = exit_info.lock().unwrap();
                    if guard.is_none() {
                        *guard = Some(ExitInfo {
                            core: w,
                            residual: r,
                            x: x.values().to_vec(),
                            at: Instant::now(),
                        });
                    }
                    drop(guard);
                    // Release: pairs with the workers' Acquire load above,
                    // publishing ExitInfo before the drain begins.
                    stop.store(true, Ordering::Release);
                }
            });
        }
    });

    let info = exit_info.into_inner().unwrap();
    // Relaxed: post-join reads — the scope already synchronized workers.
    let local_iters: Vec<u64> = iter_counters.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    match info {
        Some(info) => AsyncOutcome {
            wall: info.at.duration_since(start),
            converged: true,
            exit_core: Some(info.core),
            local_iters,
            residual: info.residual,
            final_error: problem.recovery_error(&info.x),
            x: info.x,
        },
        None => AsyncOutcome {
            wall: start.elapsed(),
            converged: false,
            exit_core: None,
            local_iters,
            residual: f64::NAN,
            final_error: f64::NAN,
            x: vec![0.0; spec.n],
        },
    }
}

/// Backend-driven worker step (PJRT or any [`Backend`] impl), running the
/// StoIHT arithmetic inside the backend while speaking the same
/// [`SupportKernel`] protocol as the native kernels.
pub struct BackendStep<'p, B: Backend> {
    backend: B,
    problem: &'p Problem,
    /// Step size `gamma` (the native kernels bake it at construction too).
    gamma: f64,
    mask: Vec<f64>,
    /// Per-block selection probabilities `p(i)`.
    probs: Vec<f64>,
    /// `1 / (M p(i))` per block, so `alpha = gamma / (M p(i))` — matching
    /// `StoihtKernel::with_probs` for any (not just uniform) distribution.
    inv_mp: Vec<f64>,
    support_scratch: Vec<usize>,
}

impl<'p, B: Backend> BackendStep<'p, B> {
    /// Uniform block sampling (the paper's experiments), `gamma = 1`.
    pub fn new(problem: &'p Problem, backend: B) -> Self {
        let mb = problem.spec.num_blocks();
        Self::with_probs(problem, backend, vec![1.0 / mb as f64; mb])
    }

    /// Arbitrary block distribution `p(i)` (must sum to 1).
    pub fn with_probs(problem: &'p Problem, backend: B, probs: Vec<f64>) -> Self {
        assert!(
            problem.op.dense().is_some(),
            "BackendStep requires a dense problem: the Backend protocol (PJRT artifacts) \
             consumes the materialized matrix"
        );
        let mb = problem.spec.num_blocks();
        assert_eq!(probs.len(), mb, "probs length != number of blocks");
        let total: f64 = probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "block probabilities must sum to 1");
        let inv_mp = probs
            .iter()
            .map(|&p| {
                assert!(p > 0.0, "every block needs positive probability");
                1.0 / (mb as f64 * p)
            })
            .collect();
        BackendStep {
            backend,
            problem,
            gamma: 1.0,
            mask: vec![0.0; problem.spec.n],
            probs,
            inv_mp,
            support_scratch: Vec::new(),
        }
    }

    /// Override the step size `gamma` (builder style).
    pub fn with_gamma(mut self, gamma: f64) -> Self {
        self.gamma = gamma;
        self
    }
}

impl<'p, B: Backend> SupportKernel for BackendStep<'p, B> {
    fn problem(&self) -> &Problem {
        self.problem
    }

    fn sample_block(&self, rng: &mut Rng) -> usize {
        rng.categorical(&self.probs)
    }

    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    ) {
        self.mask.fill(0.0);
        for &i in estimate {
            self.mask[i] = 1.0;
        }
        let alpha = self.gamma * self.inv_mp[block];
        let (x_next, gamma_set) = self
            .backend
            .stoiht_step(self.problem, block, x.values(), alpha, &self.mask)
            .expect("backend step failed");
        // x_next is zero off Γ^t ∪ estimate by construction (the mask is
        // the estimate's indicator), so that union is its support.
        union_into(&gamma_set, estimate, &mut self.support_scratch);
        x.assign_from(&x_next, &self.support_scratch);
        gamma_out.clear();
        gamma_out.extend_from_slice(&gamma_set);
    }

    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>) {
        self.mask.fill(0.0);
        let alpha = self.gamma * self.inv_mp[block];
        let (x_next, gamma_set) = self
            .backend
            .stoiht_step(self.problem, block, x, alpha, &self.mask)
            .expect("backend step failed");
        x.copy_from_slice(&x_next);
        gamma_out.clear();
        gamma_out.extend_from_slice(&gamma_set);
    }

    fn burn(&mut self, x: &SparseIterate<f64>, block: usize) {
        let _ = self.backend.proxy_step(self.problem, block, x.values(), 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::problem::ProblemSpec;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn single_thread_converges() {
        let p = easy(1);
        let out = run_async(&p, 1, &AsyncOpts::default(), 42);
        assert!(out.converged);
        assert!(out.residual < 1e-7);
        assert!(out.final_error < 1e-5);
        assert_eq!(out.exit_core, Some(0));
    }

    #[test]
    fn winner_residual_is_verified_post_hoc() {
        let p = easy(2);
        let out = run_async(&p, 4, &AsyncOpts::default(), 7);
        assert!(out.converged);
        // Re-verify the published iterate against the full dense residual.
        assert!(p.residual_norm(&out.x) < 1e-6, "{}", p.residual_norm(&out.x));
    }

    #[test]
    fn all_workers_progress() {
        let p = easy(3);
        let out = run_async(&p, 4, &AsyncOpts::default(), 9);
        assert!(out.converged);
        assert_eq!(out.local_iters.len(), 4);
        // The winner must have progressed; losers may have been stopped
        // before completing a single iteration on a fast-converging run.
        let winner = out.exit_core.unwrap();
        assert!(out.local_iters[winner] > 0);
        assert!(out.local_iters.iter().all(|&t| t <= 1500));
    }

    #[test]
    fn cap_without_convergence() {
        let p = easy(4);
        let opts = AsyncOpts { max_local_iters: 2, ..Default::default() };
        let out = run_async(&p, 2, &opts, 11);
        assert!(!out.converged);
        assert!(out.exit_core.is_none());
        assert!(out.local_iters.iter().all(|&t| t <= 2));
    }

    #[test]
    fn slow_schedule_still_converges() {
        let p = easy(5);
        let opts =
            AsyncOpts { schedule: SpeedSchedule::HalfSlow { period: 4 }, ..Default::default() };
        let out = run_async(&p, 4, &opts, 13);
        assert!(out.converged);
    }

    #[test]
    fn stress_many_threads_tiny_problem() {
        // More threads than hardware cores on a tiny problem: exercises the
        // stop/drain protocol under heavy interleaving.
        let p = easy(6);
        let out = run_async(&p, 12, &AsyncOpts::default(), 17);
        assert!(out.converged);
        assert!(p.residual_norm(&out.x) < 1e-6);
    }

    #[test]
    fn backend_step_converges_through_native_backend() {
        // The Backend-driven worker (the PJRT protocol path) over the
        // native backend: exercises the mask/union/assign plumbing. Boxed
        // on purpose — the Box<dyn SupportKernel> forwarding path is the
        // one heterogeneous callers use.
        let p = easy(7);
        let out = run_async_with(&p, 2, &AsyncOpts::default(), 23, |prob| {
            Box::new(BackendStep::new(prob, NativeBackend::new()))
        });
        assert!(out.converged);
        assert!(p.residual_norm(&out.x) < 1e-6);
    }

    #[test]
    fn async_stogradmp_converges_multithreaded() {
        // The tentpole deliverable: asynchronous StoGradMP end-to-end on
        // real threads, sharing the same lock-free tally protocol.
        use crate::algorithms::StoGradMpKernel;
        let p = easy(10);
        for cores in [1usize, 4] {
            let opts = AsyncOpts { max_local_iters: 200, ..Default::default() };
            let out = run_async_with(&p, cores, &opts, 37 + cores as u64, StoGradMpKernel::new);
            assert!(out.converged, "cores {cores}");
            assert!(p.residual_norm(&out.x) < 1e-6, "cores {cores}");
            // GradMP prunes to s: the winner iterate is s-sparse.
            let nnz = out.x.iter().filter(|&&v| v != 0.0).count();
            assert!(nnz <= p.spec.s, "cores {cores}: nnz {nnz}");
            // and converges in far fewer local iterations than StoIHT needs
            let winner = out.exit_core.unwrap();
            assert!(out.local_iters[winner] < 100, "{:?}", out.local_iters);
        }
    }

    #[test]
    fn async_stogradmp_slow_schedule_converges() {
        use crate::algorithms::StoGradMpKernel;
        let p = easy(11);
        let opts = AsyncOpts {
            schedule: SpeedSchedule::HalfSlow { period: 4 },
            max_local_iters: 300,
            ..Default::default()
        };
        let out = run_async_with(&p, 4, &opts, 53, StoGradMpKernel::new);
        assert!(out.converged);
        assert!(p.residual_norm(&out.x) < 1e-6);
    }

    #[test]
    fn backend_step_alpha_honors_nonuniform_probs() {
        // gamma / (M p(i)) must match StoihtKernel::with_probs, not the
        // uniform collapse the seed shipped.
        let p = easy(8);
        let mb = p.spec.num_blocks();
        let mut probs = vec![0.5 / (mb - 1) as f64; mb];
        probs[0] = 0.5;
        let step = BackendStep::with_probs(&p, NativeBackend::new(), probs.clone());
        let gamma = 0.8;
        assert!((gamma * step.inv_mp[0] - gamma / (mb as f64 * 0.5)).abs() < 1e-12);
        assert!((gamma * step.inv_mp[1] - gamma / (mb as f64 * probs[1])).abs() < 1e-12);
        assert!((step.with_gamma(0.8).gamma - 0.8).abs() < 1e-15);
        // sampling respects the distribution
        let step = BackendStep::with_probs(&p, NativeBackend::new(), probs);
        let mut rng = Rng::seed_from(11);
        let hits = (0..4000).filter(|_| step.sample_block(&mut rng) == 0).count();
        assert!((1700..2300).contains(&hits), "{hits}");
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn backend_step_rejects_bad_probs() {
        let p = easy(9);
        let mb = p.spec.num_blocks();
        let _ = BackendStep::with_probs(&p, NativeBackend::new(), vec![0.3 / mb as f64; mb]);
    }

    fn matrix_free_problem(seed: u64) -> Problem {
        ProblemSpec::tiny_matrix_free().generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn matrix_free_async_stoiht_converges() {
        // The tentpole composition: real threads + lock-free tally + the
        // matrix-free subsampled-DCT operator, no m x n matrix anywhere.
        let p = matrix_free_problem(12);
        for cores in [1usize, 4] {
            let out = run_async(&p, cores, &AsyncOpts::default(), 91 + cores as u64);
            assert!(out.converged, "cores {cores}");
            assert!(p.residual_norm(&out.x) < 1e-6, "cores {cores}");
            assert!(p.recovery_error(&out.x) < 1e-5, "cores {cores}");
        }
    }

    #[test]
    fn matrix_free_async_stogradmp_converges() {
        use crate::algorithms::StoGradMpKernel;
        let p = matrix_free_problem(13);
        let opts = AsyncOpts { max_local_iters: 200, ..Default::default() };
        let out = run_async_with(&p, 2, &opts, 17, StoGradMpKernel::new);
        assert!(out.converged);
        assert!(p.residual_norm(&out.x) < 1e-6);
        let nnz = out.x.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= p.spec.s);
    }

    #[test]
    #[should_panic(expected = "dense problem")]
    fn backend_step_rejects_matrix_free_problems() {
        let p = matrix_free_problem(14);
        let _ = BackendStep::new(&p, NativeBackend::new());
    }
}
