//! The shared **tally vector** `φ` — the paper's §III contribution.
//!
//! Instead of sharing the iterate, cores share a vote vector over
//! coordinates: at local iteration `t` a core adds weight `t` on its
//! freshly-identified support `Γ^t` and removes the weight `t-1` it added
//! on `Γ^{t-1}` last iteration, so
//!
//! * only each core's **latest** belief is represented, and
//! * faster cores (larger local `t`) carry **more weight** — they are
//!   further along and likelier to have found the true support.
//!
//! Two implementations share the voting/estimate logic:
//!
//! * [`AtomicTally`] — lock-free `AtomicI64` per coordinate for the real
//!   thread runtime (`fetch_add` with relaxed ordering; the paper leans on
//!   exactly this hardware guarantee, citing HOGWILD!).
//! * [`LocalTally`] — plain `i64`s for the single-threaded discrete-time
//!   simulator (and for snapshot arithmetic in fault injection).
//!
//! The support estimate `T̃ = supp_s(φ)` is restricted to coordinates with
//! **positive** tally: an all-zero tally yields an *empty* estimate rather
//! than an arbitrary tie-broken index set, which makes "no information"
//! degrade exactly to Algorithm 1 (the paper's Alg. 2 is silent on the
//! cold-start tie; see the design notes in README.md).

use crate::sync::atomic::{AtomicI64, Ordering};
use crate::sync::{Condvar, Mutex};

/// Tally weighting schemes (ablation A3; the paper uses [`Progress`]).
///
/// [`Progress`]: TallyWeighting::Progress
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TallyWeighting {
    /// Paper Alg. 2: `+t` on `Γ^t`, `-(t-1)` on `Γ^{t-1}`.
    Progress,
    /// Unweighted: `+1` on `Γ^t`, `-1` on `Γ^{t-1}` (pure frequency of the
    /// latest beliefs, no speed preference).
    Unit,
    /// `+t` on `Γ^t`, never decrement (beliefs accumulate forever —
    /// demonstrates why removing the stale vote matters).
    NoDecrement,
}

impl TallyWeighting {
    /// Weight added on `Γ^t` at local iteration `t`.
    #[inline]
    pub fn add_weight(self, t: u64) -> i64 {
        match self {
            TallyWeighting::Progress | TallyWeighting::NoDecrement => t as i64,
            TallyWeighting::Unit => 1,
        }
    }

    /// Weight removed from `Γ^{t-1}` at local iteration `t` (0 = skip).
    #[inline]
    pub fn remove_weight(self, t: u64) -> i64 {
        match self {
            TallyWeighting::Progress => t as i64 - 1,
            TallyWeighting::Unit => 1,
            TallyWeighting::NoDecrement => 0,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "progress" => Some(TallyWeighting::Progress),
            "unit" => Some(TallyWeighting::Unit),
            "no_decrement" => Some(TallyWeighting::NoDecrement),
            _ => None,
        }
    }
}

/// Select up to `s` indices with the largest **strictly positive** values,
/// written into a caller buffer (cleared first). Sorted ascending.
/// `snapshot` is any integer view of `φ`.
///
/// Uses `select_nth_unstable_by` — `O(candidates)` partial selection
/// instead of a full `O(candidates log candidates)` sort; the runtimes call
/// this once per core per iteration.
pub fn positive_top_s_into(snapshot: &[i64], s: usize, out: &mut Vec<usize>) {
    out.clear();
    out.extend((0..snapshot.len()).filter(|&i| snapshot[i] > 0));
    if out.len() > s {
        if s == 0 {
            out.clear();
        } else {
            // (value desc, index asc) is a total order: the selected set is
            // identical to the full-sort-and-truncate it replaces.
            out.select_nth_unstable_by(s - 1, |&i, &j| {
                snapshot[j].cmp(&snapshot[i]).then(i.cmp(&j))
            });
            out.truncate(s);
        }
    }
    out.sort_unstable();
}

/// Allocating convenience wrapper over [`positive_top_s_into`].
pub fn positive_top_s(snapshot: &[i64], s: usize) -> Vec<usize> {
    let mut out = Vec::new();
    positive_top_s_into(snapshot, s, &mut out);
    out
}

/// Lock-free shared tally for the real-thread runtime.
pub struct AtomicTally {
    votes: Vec<AtomicI64>,
    weighting: TallyWeighting,
}

impl AtomicTally {
    pub fn new(n: usize, weighting: TallyWeighting) -> Self {
        AtomicTally { votes: (0..n).map(|_| AtomicI64::new(0)).collect(), weighting }
    }

    pub fn len(&self) -> usize {
        self.votes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.votes.is_empty()
    }

    /// Commit one iteration's vote transition: `φ_{Γ^t} += w_add(t)`,
    /// `φ_{Γ^{t-1}} -= w_rem(t)`. Each coordinate update is an atomic RMW
    /// (relaxed — the algorithm tolerates any interleaving by design).
    pub fn commit(&self, gamma_t: &[usize], gamma_prev: &[usize], t: u64) {
        let add = self.weighting.add_weight(t);
        for &i in gamma_t {
            // Relaxed: HOGWILD!-style — only the RMW's atomicity matters;
            // readers tolerate any interleaving by design.
            self.votes[i].fetch_add(add, Ordering::Relaxed);
        }
        let rem = self.weighting.remove_weight(t);
        if rem != 0 {
            for &i in gamma_prev {
                // Relaxed: same vote-accounting argument as `fetch_add`.
                self.votes[i].fetch_sub(rem, Ordering::Relaxed);
            }
        }
    }

    /// Relaxed-load snapshot into a caller buffer (no global consistency —
    /// this *is* the inconsistent read the paper discusses; the algorithm
    /// is designed to tolerate it).
    pub fn snapshot_into(&self, out: &mut [i64]) {
        assert_eq!(out.len(), self.votes.len());
        for (o, v) in out.iter_mut().zip(&self.votes) {
            // Relaxed: the snapshot is *defined* to be inconsistent.
            *o = v.load(Ordering::Relaxed);
        }
    }

    /// `T̃ = supp_s(φ)` (positive entries only), via a fresh snapshot, into
    /// a caller buffer — the allocation-free form the worker loops use.
    pub fn estimate_into(&self, s: usize, scratch: &mut Vec<i64>, out: &mut Vec<usize>) {
        scratch.resize(self.votes.len(), 0);
        self.snapshot_into(scratch);
        positive_top_s_into(scratch, s, out);
    }

    /// `T̃ = supp_s(φ)` (positive entries only), via a fresh snapshot.
    pub fn estimate(&self, s: usize, scratch: &mut Vec<i64>) -> Vec<usize> {
        let mut out = Vec::new();
        self.estimate_into(s, scratch, &mut out);
        out
    }

    /// Sum of all votes (diagnostic; equals Σ_cores w(t_core) under
    /// Progress weighting once all commits have landed).
    pub fn total(&self) -> i64 {
        // Relaxed: diagnostic sum; callers quiesce writers (join) first.
        self.votes.iter().map(|v| v.load(Ordering::Relaxed)).sum()
    }

    /// Add a signed per-coordinate delta — how a gossip shard bakes the
    /// freshly merged peer contribution into its live tally at an
    /// exchange point.
    pub fn add_votes(&self, delta: &[i64]) {
        assert_eq!(delta.len(), self.votes.len());
        for (v, &d) in self.votes.iter().zip(delta) {
            if d != 0 {
                // Relaxed: exchange points are barrier-quiesced — only the
                // owning shard touches its tally here, and the exchange
                // board's mutex/condvar handshake publishes the result;
                // the RMW keeps concurrent monitoring reads tearless.
                v.fetch_add(d, Ordering::Relaxed);
            }
        }
    }

    /// Overwrite every coordinate — how a leader-merge shard refreshes
    /// its frozen read-side view of the merged tally at an exchange
    /// point.
    pub fn store_votes(&self, votes: &[i64]) {
        assert_eq!(votes.len(), self.votes.len());
        for (v, &w) in self.votes.iter().zip(votes) {
            // Relaxed: same barrier-quiesced single-writer argument as
            // `add_votes`; the board handshake orders the publication.
            v.store(w, Ordering::Relaxed);
        }
    }
}

/// Plain (single-threaded) tally for the discrete-time simulator.
#[derive(Clone, Debug)]
pub struct LocalTally {
    votes: Vec<i64>,
    weighting: TallyWeighting,
}

impl LocalTally {
    pub fn new(n: usize, weighting: TallyWeighting) -> Self {
        LocalTally { votes: vec![0; n], weighting }
    }

    pub fn commit(&mut self, gamma_t: &[usize], gamma_prev: &[usize], t: u64) {
        let add = self.weighting.add_weight(t);
        for &i in gamma_t {
            self.votes[i] += add;
        }
        let rem = self.weighting.remove_weight(t);
        if rem != 0 {
            for &i in gamma_prev {
                self.votes[i] -= rem;
            }
        }
    }

    pub fn estimate(&self, s: usize) -> Vec<usize> {
        positive_top_s(&self.votes, s)
    }

    pub fn votes(&self) -> &[i64] {
        &self.votes
    }

    pub fn total(&self) -> i64 {
        self.votes.iter().sum()
    }
}

// ------------------------------------------------- sharded exchange layer

/// How sharded tallies exchange support information (see
/// [`crate::sim::simulate_sharded_with`] and
/// [`crate::service::ShardedPool`]).
///
/// Both protocols move the same payload — per-shard vote snapshots — and
/// both merge with the commutative, order-canonicalized sum of
/// [`merge_votes_into`]; they differ in *whose* votes a shard sees fresh:
///
/// * [`Gossip`]: all-to-all. Between exchanges a shard reads its **own
///   live** votes plus peer snapshots up to E steps stale.
/// * [`LeaderMerge`]: parameter-server shape. A single merged view is
///   formed at each exchange and every shard — including the
///   contributor — reads that frozen view until the next exchange.
///
/// [`Gossip`]: ExchangeProtocol::Gossip
/// [`LeaderMerge`]: ExchangeProtocol::LeaderMerge
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeProtocol {
    Gossip,
    LeaderMerge,
}

impl ExchangeProtocol {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gossip" => Some(ExchangeProtocol::Gossip),
            "leader" | "leader_merge" => Some(ExchangeProtocol::LeaderMerge),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ExchangeProtocol::Gossip => "gossip",
            ExchangeProtocol::LeaderMerge => "leader",
        }
    }
}

/// Accumulate one published vote snapshot into a merge buffer.
pub fn add_votes_into(acc: &mut [i64], snap: &[i64]) {
    assert_eq!(acc.len(), snap.len());
    for (a, &v) in acc.iter_mut().zip(snap) {
        *a += v;
    }
}

/// The canonical sharded merge: coordinate-wise sum of the snapshots,
/// optionally excluding one shard (a gossip shard excludes itself — its
/// own votes stay live in its local tally).
///
/// `i64` addition is commutative and associative, so **any** accumulation
/// order produces the identical vector; that order-independence is what
/// makes sharded runs bit-identical at any thread interleaving of the
/// merge (pinned by a proptest).
pub fn merge_votes_into(snapshots: &[Vec<i64>], exclude: Option<usize>, out: &mut Vec<i64>) {
    let n = snapshots.first().map_or(0, Vec::len);
    out.clear();
    out.resize(n, 0);
    for (j, snap) in snapshots.iter().enumerate() {
        if Some(j) != exclude {
            add_votes_into(out, snap);
        }
    }
}

/// Rendezvous point for the real-thread exchange: per-shard snapshot
/// slots plus a generation-counted barrier built on the `crate::sync`
/// doorway (so `--features model` can model-check the protocol).
///
/// One exchange is two barrier crossings:
///
/// 1. every shard calls [`publish_and_wait`] — all snapshots for this
///    round are in once it returns;
/// 2. shards read merged views ([`peer_sum_into`] / [`merged_into`]) and
///    apply them to their tallies;
/// 3. every shard calls [`wait`] — no shard may republish (next round)
///    while a peer is still reading this round's slots.
///
/// [`publish_and_wait`]: ExchangeBoard::publish_and_wait
/// [`peer_sum_into`]: ExchangeBoard::peer_sum_into
/// [`merged_into`]: ExchangeBoard::merged_into
/// [`wait`]: ExchangeBoard::wait
pub struct ExchangeBoard {
    slots: Vec<Mutex<Vec<i64>>>,
    n: usize,
    round: Mutex<RoundState>,
    all_in: Condvar,
}

struct RoundState {
    arrived: usize,
    generation: u64,
    /// Shards that reported `finished = true` at the barrier in progress.
    finished_now: usize,
    /// The `finished_now` count latched when the last barrier released —
    /// every shard of that round reads the same value, which is how the
    /// fleet agrees (deterministically) on when to stop exchanging.
    finished_latch: usize,
}

impl ExchangeBoard {
    pub fn new(shards: usize, n: usize) -> Self {
        assert!(shards >= 1, "an exchange needs at least one shard");
        ExchangeBoard {
            slots: (0..shards).map(|_| Mutex::new(vec![0i64; n])).collect(),
            n,
            round: Mutex::new(RoundState {
                arrived: 0,
                generation: 0,
                finished_now: 0,
                finished_latch: 0,
            }),
            all_in: Condvar::new(),
        }
    }

    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// Tally dimension `n` every published snapshot must match. The
    /// socket transport ([`crate::service::transport`]) validates remote
    /// snapshots against this before they can reach a merge.
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Publish shard `k`'s local vote snapshot and block until every
    /// shard has published for this round. `finished` reports whether
    /// this shard is done iterating (converged or at its cap); the
    /// per-round count is readable via [`finished_count`] until the next
    /// barrier crossing.
    ///
    /// [`finished_count`]: ExchangeBoard::finished_count
    pub fn publish_and_wait(&self, k: usize, votes: &[i64], finished: bool) {
        assert_eq!(votes.len(), self.n);
        self.slots[k].lock().unwrap().copy_from_slice(votes);
        self.barrier(finished);
    }

    /// Plain barrier crossing (phase 3 above).
    pub fn wait(&self) {
        self.barrier(false);
    }

    /// How many shards reported `finished` at the last released barrier.
    pub fn finished_count(&self) -> usize {
        self.round.lock().unwrap().finished_latch
    }

    /// Sum every published snapshot except shard `k`'s (the gossip view).
    pub fn peer_sum_into(&self, k: usize, out: &mut Vec<i64>) {
        self.sum_into(Some(k), out);
    }

    /// Sum every published snapshot (the leader-merge view).
    pub fn merged_into(&self, out: &mut Vec<i64>) {
        self.sum_into(None, out);
    }

    fn sum_into(&self, exclude: Option<usize>, out: &mut Vec<i64>) {
        out.clear();
        out.resize(self.n, 0);
        for (j, slot) in self.slots.iter().enumerate() {
            if Some(j) != exclude {
                add_votes_into(out, &slot.lock().unwrap());
            }
        }
    }

    /// Generation-counted barrier: the last arrival flips the generation
    /// and wakes everyone; earlier arrivals sleep until the flip. The
    /// mutex/condvar pair orders every slot write before every
    /// post-barrier slot read.
    fn barrier(&self, finished: bool) {
        let mut st = self.round.lock().unwrap();
        st.arrived += 1;
        st.finished_now += finished as usize;
        if st.arrived == self.slots.len() {
            st.arrived = 0;
            st.finished_latch = st.finished_now;
            st.finished_now = 0;
            st.generation += 1;
            self.all_in.notify_all();
        } else {
            let gen = st.generation;
            while st.generation == gen {
                st = self.all_in.wait(st).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::{thread, Arc};

    #[test]
    fn weighting_schemes() {
        assert_eq!(TallyWeighting::Progress.add_weight(5), 5);
        assert_eq!(TallyWeighting::Progress.remove_weight(5), 4);
        assert_eq!(TallyWeighting::Unit.add_weight(5), 1);
        assert_eq!(TallyWeighting::Unit.remove_weight(5), 1);
        assert_eq!(TallyWeighting::NoDecrement.add_weight(5), 5);
        assert_eq!(TallyWeighting::NoDecrement.remove_weight(5), 0);
        assert_eq!(TallyWeighting::parse("progress"), Some(TallyWeighting::Progress));
        assert_eq!(TallyWeighting::parse("bogus"), None);
    }

    #[test]
    fn positive_top_s_ignores_nonpositive() {
        let snap = vec![0i64, -3, 5, 2, 0, 7];
        assert_eq!(positive_top_s(&snap, 2), vec![2, 5]);
        assert_eq!(positive_top_s(&snap, 10), vec![2, 3, 5]);
        assert_eq!(positive_top_s(&[0, 0, 0], 2), Vec::<usize>::new());
    }

    #[test]
    fn positive_top_s_tie_break_low_index() {
        let snap = vec![3i64, 5, 3, 5, 3];
        assert_eq!(positive_top_s(&snap, 3), vec![0, 1, 3]);
    }

    #[test]
    fn positive_top_s_partial_selection_matches_full_sort() {
        // Reference: full sort by (value desc, index asc), truncate, re-sort.
        let reference = |snap: &[i64], s: usize| -> Vec<usize> {
            let mut c: Vec<usize> = (0..snap.len()).filter(|&i| snap[i] > 0).collect();
            c.sort_by(|&i, &j| snap[j].cmp(&snap[i]).then(i.cmp(&j)));
            c.truncate(s);
            c.sort_unstable();
            c
        };
        let mut rng = crate::rng::Rng::seed_from(55);
        for _ in 0..300 {
            let n = 1 + rng.below(80);
            let snap: Vec<i64> = (0..n).map(|_| rng.below(9) as i64 - 3).collect();
            let s = rng.below(n + 2);
            assert_eq!(positive_top_s(&snap, s), reference(&snap, s), "n={n} s={s}");
        }
        assert_eq!(positive_top_s(&[5, 5, 5], 0), Vec::<usize>::new());
    }

    #[test]
    fn estimate_into_reuses_buffers() {
        let at = AtomicTally::new(8, TallyWeighting::Progress);
        at.commit(&[1, 6], &[], 3);
        let mut scratch = Vec::new();
        let mut out = vec![42usize; 5];
        at.estimate_into(2, &mut scratch, &mut out);
        assert_eq!(out, vec![1, 6]);
        at.estimate_into(1, &mut scratch, &mut out);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn local_tally_paper_protocol() {
        // Single core: after iterations t = 1..=3 with supports g1, g2, g3,
        // the tally holds exactly +3 on g3 (all earlier votes retracted).
        let mut t = LocalTally::new(8, TallyWeighting::Progress);
        let g1 = vec![0, 1];
        let g2 = vec![1, 2];
        let g3 = vec![2, 3];
        t.commit(&g1, &[], 1);
        assert_eq!(t.votes(), &[1, 1, 0, 0, 0, 0, 0, 0]);
        t.commit(&g2, &g1, 2);
        assert_eq!(t.votes(), &[0, 2, 2, 0, 0, 0, 0, 0]);
        t.commit(&g3, &g2, 3);
        assert_eq!(t.votes(), &[0, 0, 3, 3, 0, 0, 0, 0]);
        assert_eq!(t.estimate(2), vec![2, 3]);
        assert_eq!(t.total(), 6);
    }

    #[test]
    fn faster_core_outvotes_slower() {
        let mut t = LocalTally::new(6, TallyWeighting::Progress);
        // slow core at t=2 votes {0,1}; fast core at t=9 votes {4,5}.
        t.commit(&[0, 1], &[], 2);
        t.commit(&[4, 5], &[], 9);
        assert_eq!(t.estimate(2), vec![4, 5]);
    }

    #[test]
    fn unit_weighting_counts_frequency() {
        let mut t = LocalTally::new(6, TallyWeighting::Unit);
        t.commit(&[0], &[], 50); // late core, weight still 1
        t.commit(&[1], &[], 1);
        t.commit(&[1], &[], 1); // two cores agree on 1
        assert_eq!(t.estimate(1), vec![1]);
    }

    #[test]
    fn no_decrement_accumulates() {
        let mut t = LocalTally::new(4, TallyWeighting::NoDecrement);
        t.commit(&[0], &[], 1);
        t.commit(&[1], &[0], 2); // the remove of {0} is skipped
        assert_eq!(t.votes(), &[1, 2, 0, 0]);
    }

    #[test]
    fn atomic_matches_local_single_thread() {
        let at = AtomicTally::new(8, TallyWeighting::Progress);
        let mut lt = LocalTally::new(8, TallyWeighting::Progress);
        let seqs: Vec<(Vec<usize>, Vec<usize>, u64)> = vec![
            (vec![0, 2], vec![], 1),
            (vec![2, 4], vec![0, 2], 2),
            (vec![4, 6], vec![2, 4], 3),
        ];
        for (g, gp, t) in &seqs {
            at.commit(g, gp, *t);
            lt.commit(g, gp, *t);
        }
        let mut snap = vec![0i64; 8];
        at.snapshot_into(&mut snap);
        assert_eq!(&snap, lt.votes());
        let mut scratch = Vec::new();
        assert_eq!(at.estimate(2, &mut scratch), lt.estimate(2));
        assert_eq!(at.total(), lt.total());
    }

    #[test]
    fn atomic_concurrent_commits_conserve_total() {
        // 8 threads x 100 iterations of the paper protocol each; the final
        // total must equal Σ_threads s * final_t (every intermediate vote
        // retracted) regardless of interleaving — the core lock-free
        // invariant the design relies on.
        // Miri runs the same protocol, shrunk to keep the interpreter fast.
        let n = if cfg!(miri) { 16 } else { 64 };
        let tally = Arc::new(AtomicTally::new(n, TallyWeighting::Progress));
        let threads = if cfg!(miri) { 3 } else { 8 };
        let iters: u64 = if cfg!(miri) { 8 } else { 100 };
        let s = 4;
        let handles: Vec<_> = (0..threads)
            .map(|tid| {
                let tally = Arc::clone(&tally);
                thread::spawn(move || {
                    let mut rng = crate::rng::Rng::seed_from(900 + tid as u64);
                    let mut prev: Vec<usize> = Vec::new();
                    for t in 1..=iters {
                        let mut g = rng.subset(n, s);
                        g.sort_unstable();
                        tally.commit(&g, &prev, t);
                        prev = g;
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Each thread's surviving weight is its final t times s entries.
        let expected = threads as i64 * iters as i64 * s as i64;
        assert_eq!(tally.total(), expected);
    }

    #[test]
    fn exchange_protocol_parses_and_round_trips() {
        assert_eq!(ExchangeProtocol::parse("gossip"), Some(ExchangeProtocol::Gossip));
        assert_eq!(ExchangeProtocol::parse("leader"), Some(ExchangeProtocol::LeaderMerge));
        assert_eq!(ExchangeProtocol::parse("leader_merge"), Some(ExchangeProtocol::LeaderMerge));
        assert_eq!(ExchangeProtocol::parse("bogus"), None);
        for p in [ExchangeProtocol::Gossip, ExchangeProtocol::LeaderMerge] {
            assert_eq!(ExchangeProtocol::parse(p.as_str()), Some(p));
        }
    }

    #[test]
    fn merge_votes_sums_and_excludes() {
        let snaps = vec![vec![1i64, 0, -2], vec![0, 3, 1], vec![5, 5, 5]];
        let mut out = Vec::new();
        merge_votes_into(&snaps, None, &mut out);
        assert_eq!(out, vec![6, 8, 4]);
        merge_votes_into(&snaps, Some(2), &mut out);
        assert_eq!(out, vec![1, 3, -1]);
        merge_votes_into(&[], None, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn tally_vote_overlays_for_both_protocols() {
        // Gossip shard: bake a peer delta into a live tally.
        let at = AtomicTally::new(4, TallyWeighting::Progress);
        at.commit(&[0, 1], &[], 2);
        at.add_votes(&[0, 3, -1, 0]);
        let mut snap = vec![0i64; 4];
        at.snapshot_into(&mut snap);
        assert_eq!(snap, vec![2, 5, -1, 0]);
        // Leader shard: refresh a frozen read-side view wholesale.
        let frozen = AtomicTally::new(4, TallyWeighting::Progress);
        frozen.store_votes(&[7, 0, 1, -2]);
        frozen.snapshot_into(&mut snap);
        assert_eq!(snap, vec![7, 0, 1, -2]);
        let mut scratch = Vec::new();
        assert_eq!(frozen.estimate(2, &mut scratch), vec![0, 2]);
    }

    #[test]
    fn exchange_board_round_trips_snapshots() {
        // Two shards run one full exchange (publish → read → release) on
        // real threads; each must see exactly the other's snapshot in its
        // peer sum, and the merged view is the total.
        let board = Arc::new(ExchangeBoard::new(2, 3));
        let snaps = [vec![1i64, 2, 0], vec![0i64, 5, -1]];
        let handles: Vec<_> = (0..2)
            .map(|k| {
                let board = Arc::clone(&board);
                let mine = snaps[k].clone();
                let other = snaps[1 - k].clone();
                thread::spawn(move || {
                    board.publish_and_wait(k, &mine, k == 1);
                    let mut peers = Vec::new();
                    board.peer_sum_into(k, &mut peers);
                    assert_eq!(peers, other);
                    let mut merged = Vec::new();
                    board.merged_into(&mut merged);
                    assert_eq!(merged, vec![1, 7, -1]);
                    // Exactly one shard declared itself finished.
                    assert_eq!(board.finished_count(), 1);
                    board.wait();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn estimate_is_sorted_and_bounded() {
        let at = AtomicTally::new(16, TallyWeighting::Progress);
        at.commit(&[3, 9, 12], &[], 4);
        let mut scratch = Vec::new();
        let est = at.estimate(2, &mut scratch);
        assert!(est.len() <= 2);
        assert!(est.windows(2).all(|w| w[0] < w[1]));
        assert!(est.iter().all(|&i| [3usize, 9, 12].contains(&i)));
    }
}
