//! `astir serve` — a zero-dependency TCP front-end over the recovery
//! service: warm operator cache, deadline micro-batching, typed admission
//! control, and per-job latency accounting.
//!
//! ## Architecture
//!
//! One blocking accept loop ([`Server::run`]) feeds accepted connections
//! through a mutex/condvar queue to a small set of persistent handler
//! threads (`--workers`). A handler owns its connection for its lifetime:
//! it reads length-prefixed JSON frames ([`super::wire`]), dispatches
//! them, and writes replies in order. All threading goes through the
//! [`crate::sync`] doorway, so the serving layer obeys the same
//! discipline (and model-shim compatibility) as the solver runtime.
//!
//! * **Operator cache** — a bounded LRU keyed by [`OpKey`]. Lookups and
//!   inserts are brief critical sections; the draw itself runs **outside
//!   the lock** (it is the expensive part of a job and must not serialize
//!   unrelated handlers, or poison the cache if it panics). Concurrent
//!   misses on one key may both draw, but publication is
//!   insert-if-absent: the loser adopts the winner's `Arc`, so every
//!   holder of a key shares one operator — the identity that lets their
//!   problems share a lockstep window (`Problem::shares_operator_with`).
//! * **Deadline micro-batcher** — with `--batch-window-ms T > 0`, the
//!   first job of a window becomes *leader*: it holds the window open up
//!   to `T` ms (or [`WINDOW_FILL`] jobs), then solves everything that
//!   joined in one [`super::recover_batch_stoiht`] call. Compatible jobs
//!   arriving meanwhile join as *followers* and sleep on the condvar —
//!   where "compatible" requires holding the **same `Arc`** as the
//!   window's operator (`Arc::ptr_eq`), not merely an equal key: an
//!   evict-and-redraw between two cache lookups yields distinct
//!   operators under one key, and such a job solves solo instead.
//!   Subsampled-DCT operators additionally share their twiddle/phase
//!   tables through the process-wide [`crate::linalg::plan_for`] cache,
//!   so even distinct cache entries at one `n` reuse one table build.
//!   Incompatible jobs likewise fall back to a solo
//!   [`super::solve_job`]. With `T = 0` every job runs solo inline — the
//!   configuration whose responses are **bit-identical** to an
//!   in-process `solve_job` with the same seed (pinned by
//!   `rust/tests/serve_e2e.rs`).
//! * **Admission control** — an atomic in-flight counter reserved by
//!   compare-exchange (a failed admission never transiently inflates the
//!   count); a job frame arriving when `--max-inflight` jobs are already
//!   admitted is rejected with [`ServeError::Busy`] instead of queued.
//!   `stats` frames bypass admission. Accepted connections waiting for a
//!   free handler are likewise bounded ([`CONN_BACKLOG`]): over the
//!   bound the server answers one typed `Busy` frame and closes rather
//!   than queuing the connection invisibly.
//! * **Panic isolation** — the whole admitted section (operator draw,
//!   problem build, solve) runs under `catch_unwind`, so a panicking job
//!   (or micro-batch window) answers [`ServeError::WorkerPanic`] for the
//!   affected jobs only, releases its admission slots, and the server
//!   keeps serving. Server-side locks recover from poisoning
//!   (`lock_recover`) — the guarded state (cache entries, counters,
//!   batcher queue) stays structurally valid across an unwind, so one
//!   hostile frame can never wedge every later handler at
//!   `.lock().unwrap()`.
//!
//! The server solves StoIHT (`Alg::Stoiht`) with [`AsyncOpts::default`]
//! in v1; the algorithm/options become request fields in a future
//! additive revision.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::{thread, Arc, Condvar, Mutex, MutexGuard};

use crate::algorithms::Alg;
use crate::async_runtime::AsyncOpts;
use crate::linalg::Operator;
use crate::metrics::quantile;
use crate::problem::Problem;
use crate::service::api::{
    BatchRequest, JobRequest, JobResponse, OpKey, ServeError, StatsSnapshot,
};
use crate::service::wire::{write_frame, Reply, Request, MAX_FRAME_LEN};
use crate::service::{recover_batch_stoiht, solve_job};

/// A micro-batch window closes early once this many jobs joined.
pub const WINDOW_FILL: usize = 8;

/// Operator-cache capacity (distinct `OpKey`s kept warm).
pub const OP_CACHE_CAP: usize = 32;

/// Latency sample retained for percentile estimation: the last `LAT_CAP`
/// per-job wall latencies in a ring, so a long-running server neither
/// grows without bound nor slows its stats queries over time.
pub const LAT_CAP: usize = 4096;

/// Accepted connections allowed to wait for a free handler. Beyond this
/// the server sends one typed [`ServeError::Busy`] frame and closes the
/// connection instead of parking it in an invisible queue. Sized above
/// the `loadgen` suite's peak concurrency so a healthy open-loop window
/// never sheds load.
pub const CONN_BACKLOG: usize = 256;

/// Lock, recovering from poisoning: a panicking handler must not wedge
/// every other handler at `.lock().unwrap()`. Safe here because every
/// critical section in this module leaves its state structurally valid
/// at any unwind point (plain `Vec`/counter edits; the batcher's
/// open-window flag is only toggled with no panic source in between).
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// [`Condvar::wait`] with the same poison recovery as [`lock_recover`].
pub(crate) fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|p| p.into_inner())
}

/// Leader poll interval while a window is open, and the per-read socket
/// timeout handlers use to stay responsive to shutdown.
const WINDOW_POLL: Duration = Duration::from_micros(200);
const READ_POLL: Duration = Duration::from_millis(25);

/// Front-end configuration (CLI `serve` flags / `[serve]` TOML section).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Handler threads (each owns one connection at a time).
    pub workers: usize,
    /// Micro-batch window in milliseconds; 0 disables batching (every
    /// job solves solo, bit-identical to in-process `solve_job`).
    pub batch_window_ms: u64,
    /// Admission cap on concurrently admitted jobs.
    pub max_inflight: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            addr: "127.0.0.1:7878".to_string(),
            workers: crate::config::default_trial_threads(),
            batch_window_ms: 2,
            max_inflight: 64,
        }
    }
}

// ------------------------------------------------------- operator cache

/// Bounded LRU of drawn operators. The draw runs **outside the lock**
/// (it can be hundreds of milliseconds of dense generation — the lock
/// only ever guards brief list edits); publication is insert-if-absent,
/// so two concurrent misses on one key still come away holding the same
/// `Arc` — without that identity their problems could never share a
/// batch window.
struct OpCache {
    entries: Mutex<Vec<(OpKey, Arc<Operator>)>>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl OpCache {
    fn new(cap: usize) -> OpCache {
        assert!(cap >= 1, "operator cache needs capacity >= 1");
        let entries = Mutex::new(Vec::with_capacity(cap));
        OpCache { entries, cap, hits: AtomicU64::new(0), misses: AtomicU64::new(0) }
    }

    fn get_or_draw(&self, req: &JobRequest) -> Arc<Operator> {
        let key = req.op_key();
        if let Some(op) = self.lookup(&key) {
            return op;
        }
        // Miss: draw with no lock held. The caller has validated the
        // request (size caps included), and even if the draw panics the
        // cache stays unlocked and unpoisoned.
        let op = req.draw_operator();
        self.publish(key, op)
    }

    /// Warm-path lookup; a hit is moved to the LRU front.
    fn lookup(&self, key: &OpKey) -> Option<Arc<Operator>> {
        let mut entries = lock_recover(&self.entries);
        let pos = entries.iter().position(|(k, _)| k == key)?;
        let entry = entries.remove(pos);
        let op = Arc::clone(&entry.1);
        entries.insert(0, entry);
        // Relaxed: independent monotone counters, read only by stats.
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(op)
    }

    /// Publish a freshly drawn operator — unless a concurrent miss on the
    /// same key published first, in which case the canonical cached `Arc`
    /// is returned and `op` is discarded (every holder of a key must
    /// share ONE operator).
    fn publish(&self, key: OpKey, op: Arc<Operator>) -> Arc<Operator> {
        // Relaxed: as in `lookup`. Counted per draw, so a lost race still
        // shows up as the miss (= redundant draw) it was.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut entries = lock_recover(&self.entries);
        if let Some(pos) = entries.iter().position(|(k, _)| *k == key) {
            let entry = entries.remove(pos);
            let canonical = Arc::clone(&entry.1);
            entries.insert(0, entry);
            return canonical;
        }
        entries.insert(0, (key, Arc::clone(&op)));
        entries.truncate(self.cap);
        op
    }
}

// ---------------------------------------------------------------- stats

/// The last [`LAT_CAP`] latencies. Order is irrelevant for percentile
/// estimation, so overwrites simply cycle through the filled buffer.
struct LatencyRing {
    buf: Vec<f64>,
    next: usize,
}

impl LatencyRing {
    fn new() -> LatencyRing {
        LatencyRing { buf: Vec::new(), next: 0 }
    }

    fn push(&mut self, v: f64) {
        if self.buf.len() < LAT_CAP {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % LAT_CAP;
        }
    }
}

struct Stats {
    served: AtomicU64,
    rejected: AtomicU64,
    inflight: AtomicUsize,
    latencies: Mutex<LatencyRing>,
}

impl Stats {
    fn new() -> Stats {
        Stats {
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            inflight: AtomicUsize::new(0),
            latencies: Mutex::new(LatencyRing::new()),
        }
    }

    fn snapshot(&self, cache: &OpCache) -> StatsSnapshot {
        let lat = lock_recover(&self.latencies);
        StatsSnapshot {
            // Relaxed loads: monitoring counters; each is independently
            // coherent and no cross-counter invariant is promised.
            served: self.served.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            cache_hits: cache.hits.load(Ordering::Relaxed),
            // Relaxed: monitoring counters, as above.
            cache_misses: cache.misses.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed) as u64,
            p50_s: quantile(&lat.buf, 0.50),
            p90_s: quantile(&lat.buf, 0.90),
            p99_s: quantile(&lat.buf, 0.99),
        }
    }
}

// --------------------------------------------------------- micro-batcher

struct PendingJob {
    problem: Problem,
    known_truth: bool,
}

struct BatcherState {
    /// Monotone window counter; results are addressed by `(gen, index)`.
    gen: u64,
    /// A window is currently accepting followers.
    open: bool,
    /// The open window's compatibility key (operator key + `b` + `s`).
    key: Option<(OpKey, usize, usize)>,
    /// The open window's operator — the leader's `Arc`. Followers join
    /// only when their own operator is `Arc::ptr_eq` to this one: an
    /// evict-and-redraw between two cache lookups yields distinct `Arc`s
    /// under one key, and `recover_batch_stoiht` requires true pointer
    /// identity across the window.
    op: Option<Arc<Operator>>,
    /// The open window's seed (its leader's request seed).
    seed: u64,
    deadline: Instant,
    jobs: Vec<PendingJob>,
    /// Follower results parked until their owner wakes and claims them.
    results: Vec<(u64, usize, Result<JobResponse, ServeError>)>,
}

struct Batcher {
    state: Mutex<BatcherState>,
    cv: Condvar,
}

impl Batcher {
    fn new() -> Batcher {
        let state = Mutex::new(BatcherState {
            gen: 0,
            open: false,
            key: None,
            op: None,
            seed: 0,
            deadline: Instant::now(),
            jobs: Vec::new(),
            results: Vec::new(),
        });
        Batcher { state, cv: Condvar::new() }
    }
}

// --------------------------------------------------------------- server

struct ServerShared {
    opts: ServeOpts,
    alg_opts: AsyncOpts,
    cache: OpCache,
    stats: Stats,
    batcher: Batcher,
    conns: Mutex<VecDeque<TcpStream>>,
    conn_cv: Condvar,
    stop: AtomicBool,
}

/// A bound-but-not-yet-running server. [`Server::run`] blocks the caller
/// (the CLI path); [`Server::spawn`] runs it on a background thread and
/// returns a [`ServerHandle`] (the in-process path for tests and the
/// `loadgen` bench suite).
pub struct Server {
    listener: TcpListener,
    shared: Arc<ServerShared>,
}

impl Server {
    /// Bind the listen socket (fails fast on a bad/busy address).
    pub fn bind(opts: ServeOpts) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&opts.addr)?;
        let shared = Arc::new(ServerShared {
            alg_opts: AsyncOpts::default(),
            cache: OpCache::new(OP_CACHE_CAP),
            stats: Stats::new(),
            batcher: Batcher::new(),
            conns: Mutex::new(VecDeque::new()),
            conn_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            opts,
        });
        Ok(Server { listener, shared })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve until [`ServerHandle::stop`] (or process exit). Prints one
    /// `listening on <addr>` line so a parent process can scrape the
    /// resolved address.
    pub fn run(self) -> std::io::Result<()> {
        let addr = self.listener.local_addr()?;
        println!("listening on {addr}");
        let workers = self.shared.opts.workers.max(1);
        let handlers: Vec<thread::JoinHandle<()>> = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&self.shared);
                thread::Builder::new()
                    .name(format!("astir-serve-{w}"))
                    .spawn(move || handler_main(&shared))
                    .expect("spawn serve handler")
            })
            .collect();
        for conn in self.listener.incoming() {
            // Acquire: pairs with the Release store in `shutdown`, making
            // the stop request visible across the accept wake-up.
            if self.shared.stop.load(Ordering::Acquire) {
                break;
            }
            if let Ok(stream) = conn {
                let mut q = lock_recover(&self.shared.conns);
                if q.len() >= CONN_BACKLOG {
                    drop(q);
                    // Relaxed: monitoring counter.
                    self.shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    reject_connection(stream);
                } else {
                    q.push_back(stream);
                    self.shared.conn_cv.notify_one();
                }
            }
        }
        self.shared.conn_cv.notify_all();
        for h in handlers {
            let _ = h.join();
        }
        Ok(())
    }

    /// Run on a background thread; the returned handle can query stats
    /// and stop the server (also done on drop).
    pub fn spawn(self) -> std::io::Result<ServerHandle> {
        let addr = self.listener.local_addr()?;
        let shared = Arc::clone(&self.shared);
        let thread = thread::Builder::new()
            .name("astir-serve-accept".to_string())
            .spawn(move || {
                let _ = self.run();
            })
            .expect("spawn serve accept loop");
        Ok(ServerHandle { addr, shared, thread: Some(thread) })
    }
}

/// Owner handle for a spawned server. Dropping it stops the server.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    thread: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counters + latency percentiles, identical to a wire `stats` frame.
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.stats.snapshot(&self.shared.cache)
    }

    /// Stop accepting, drain handler threads, and join the accept loop.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        let Some(thread) = self.thread.take() else { return };
        // Release: pairs with the Acquire loads in the accept loop and
        // the handlers' polled reads.
        self.shared.stop.store(true, Ordering::Release);
        self.shared.conn_cv.notify_all();
        // Unblock the accept call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let _ = thread.join();
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// -------------------------------------------------------------- handlers

fn handler_main(shared: &ServerShared) {
    loop {
        let stream = {
            let mut q = lock_recover(&shared.conns);
            loop {
                if let Some(s) = q.pop_front() {
                    break s;
                }
                // Acquire: see `ServerHandle::shutdown`.
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                q = wait_recover(&shared.conn_cv, q);
            }
        };
        serve_connection(shared, stream);
    }
}

/// Best-effort typed rejection for a connection over [`CONN_BACKLOG`]:
/// one `Busy` error frame, then close (drop).
fn reject_connection(mut stream: TcpStream) {
    let reply = Reply::Job(Err(ServeError::Busy));
    let _ = write_frame(&mut stream, &reply.to_json());
}

fn serve_connection(shared: &ServerShared, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_nodelay(true);
    loop {
        let text = match read_frame_polled(&mut stream, &shared.stop) {
            Ok(Some(text)) => text,
            // Clean hang-up, shutdown, or an unrecoverable socket/frame
            // error: either way this connection is done.
            Ok(None) | Err(_) => return,
        };
        // Last-resort isolation: no panic anywhere in parse/dispatch may
        // kill the handler thread (the inner solve paths release their
        // admission slots themselves before unwinding this far).
        let reply = catch_unwind(AssertUnwindSafe(|| dispatch(shared, &text)))
            .unwrap_or_else(|_| Reply::Job(Err(ServeError::WorkerPanic)));
        if write_frame(&mut stream, &reply.to_json()).is_err() {
            return;
        }
    }
}

fn dispatch(shared: &ServerShared, text: &str) -> Reply {
    match Request::parse(text) {
        Ok(Request::Job(req)) => Reply::Job(handle_job(shared, &req)),
        Ok(Request::Batch(batch)) => match handle_batch(shared, &batch) {
            Ok(results) => Reply::Batch(results),
            Err(e) => Reply::Job(Err(e)),
        },
        Ok(Request::Stats) => Reply::Stats(shared.stats.snapshot(&shared.cache)),
        Err(e) => Reply::Job(Err(e)),
    }
}

/// [`super::wire::read_frame`] adapted to a socket with a short read
/// timeout: timeouts poll the stop flag instead of killing the
/// connection, so handlers stay responsive to shutdown while blocked on
/// an idle peer. `Ok(None)` means hang-up (at a frame boundary) or stop.
fn read_frame_polled(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> std::io::Result<Option<String>> {
    let mut header = [0u8; 4];
    match read_full_polled(stream, stop, &mut header)? {
        // Stop requested, or a clean hang-up before the first header
        // byte: either way this connection is done.
        ReadFull::Stopped | ReadFull::EofAtStart => return Ok(None),
        ReadFull::Filled => {}
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME_LEN"),
        ));
    }
    let mut payload = vec![0u8; len];
    match read_full_polled(stream, stop, &mut payload)? {
        ReadFull::Stopped => return Ok(None),
        ReadFull::EofAtStart => {
            return Err(std::io::Error::new(ErrorKind::UnexpectedEof, "eof inside a frame"));
        }
        ReadFull::Filled => {}
    }
    String::from_utf8(payload)
        .map(Some)
        .map_err(|_| std::io::Error::new(ErrorKind::InvalidData, "frame is not UTF-8"))
}

enum ReadFull {
    Filled,
    /// The stop flag went up mid-read; the buffer is abandoned.
    Stopped,
    /// The peer hung up before the first byte (empty buffers count as
    /// trivially filled instead).
    EofAtStart,
}

/// Fill `buf`, treating read timeouts as polls of the stop flag. EOF
/// after the first byte is an error (a torn frame).
fn read_full_polled(
    stream: &mut TcpStream,
    stop: &AtomicBool,
    buf: &mut [u8],
) -> std::io::Result<ReadFull> {
    let mut have = 0usize;
    while have < buf.len() {
        // Acquire: see `ServerHandle::shutdown`.
        if stop.load(Ordering::Acquire) {
            return Ok(ReadFull::Stopped);
        }
        match stream.read(&mut buf[have..]) {
            Ok(0) if have == 0 => return Ok(ReadFull::EofAtStart),
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "peer hung up mid-frame",
                ));
            }
            Ok(k) => have += k,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadFull::Filled)
}

// ------------------------------------------------------------- dispatch

fn handle_job(shared: &ServerShared, req: &JobRequest) -> Result<JobResponse, ServeError> {
    req.validate()?;
    if !admit(shared, 1) {
        return Err(ServeError::Busy);
    }
    let start = Instant::now();
    // The whole admitted section — operator draw, problem build, solve —
    // runs under catch_unwind, so an unexpected panic cannot leak the
    // admission slot (finish always runs) or unwind past the handler.
    let result = catch_unwind(AssertUnwindSafe(|| solve_admitted(shared, req)))
        .unwrap_or_else(|_| Err(ServeError::WorkerPanic));
    finish(shared, 1, start);
    result
}

fn handle_batch(
    shared: &ServerShared,
    batch: &BatchRequest,
) -> Result<Vec<Result<JobResponse, ServeError>>, ServeError> {
    batch.validate()?;
    let k = batch.jobs.len();
    if !admit(shared, k) {
        return Err(ServeError::Busy);
    }
    let start = Instant::now();
    // Same slot-safety as `handle_job`: a panicking draw/build answers
    // per-job WorkerPanic and still releases all k slots.
    let results = catch_unwind(AssertUnwindSafe(|| solve_batch(shared, batch)))
        .unwrap_or_else(|_| batch.jobs.iter().map(|_| Err(ServeError::WorkerPanic)).collect());
    finish(shared, k, start);
    Ok(results)
}

fn solve_batch(
    shared: &ServerShared,
    batch: &BatchRequest,
) -> Vec<Result<JobResponse, ServeError>> {
    if batch.compatible() {
        let op = shared.cache.get_or_draw(&batch.jobs[0]);
        match batch.jobs.iter().map(|j| j.problem(&op)).collect::<Result<Vec<_>, _>>() {
            Ok(problems) => {
                let known: Vec<bool> = batch.jobs.iter().map(|j| j.y.is_none()).collect();
                solve_window(&problems, &known, &shared.alg_opts, batch.jobs[0].seed)
            }
            Err(e) => batch.jobs.iter().map(|_| Err(e.clone())).collect(),
        }
    } else {
        // Mixed keys: no shared window possible, solve sequentially.
        batch
            .jobs
            .iter()
            .map(|j| {
                let op = shared.cache.get_or_draw(j);
                match j.problem(&op) {
                    Ok(p) => solve_solo(&p, j.y.is_none(), &shared.alg_opts, j.seed),
                    Err(e) => Err(e),
                }
            })
            .collect()
    }
}

/// Admission control: reserve `k` in-flight slots or refuse. The
/// reservation commits by compare-exchange, so a refused admission never
/// transiently inflates the counter (a fetch_add-then-undo could bounce
/// a concurrent request that actually fit under the cap).
fn admit(shared: &ServerShared, k: usize) -> bool {
    let inflight = &shared.stats.inflight;
    // Relaxed initial read: the CAS below revalidates against the cap.
    let mut cur = inflight.load(Ordering::Relaxed);
    loop {
        if cur.saturating_add(k) > shared.opts.max_inflight {
            // Relaxed: monitoring counter.
            shared.stats.rejected.fetch_add(k as u64, Ordering::Relaxed);
            return false;
        }
        // AcqRel on success: the counter is a capacity token passed
        // between handler threads — a committed reservation must be
        // visible to concurrent admits. Relaxed on failure: the loop
        // re-reads the observed value and revalidates against the cap.
        match inflight.compare_exchange(cur, cur + k, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(seen) => cur = seen,
        }
    }
}

/// Release `k` slots and record their shared wall latency.
fn finish(shared: &ServerShared, k: usize, start: Instant) {
    // AcqRel: see `admit`.
    shared.stats.inflight.fetch_sub(k, Ordering::AcqRel);
    // Relaxed: monitoring counter.
    shared.stats.served.fetch_add(k as u64, Ordering::Relaxed);
    let elapsed = start.elapsed().as_secs_f64();
    let mut lat = lock_recover(&shared.stats.latencies);
    for _ in 0..k {
        lat.push(elapsed);
    }
}

fn solve_admitted(shared: &ServerShared, req: &JobRequest) -> Result<JobResponse, ServeError> {
    let op = shared.cache.get_or_draw(req);
    let problem = req.problem(&op)?;
    let known_truth = req.y.is_none();
    if shared.opts.batch_window_ms == 0 {
        solve_solo(&problem, known_truth, &shared.alg_opts, req.seed)
    } else {
        run_batched(shared, req, problem, known_truth, &op)
    }
}

/// One job through the deadline micro-batcher: lead a fresh window, join
/// an open compatible one, or (incompatible / full window) solve solo.
/// Joining requires `op` to be the **same `Arc`** as the window's — equal
/// keys are not enough, since an LRU evict-and-redraw between two cache
/// lookups yields distinct operators under one key.
fn run_batched(
    shared: &ServerShared,
    req: &JobRequest,
    problem: Problem,
    known_truth: bool,
    op: &Arc<Operator>,
) -> Result<JobResponse, ServeError> {
    let window = Duration::from_millis(shared.opts.batch_window_ms);
    let my_key = (req.op_key(), req.b, req.s);
    let mut st = lock_recover(&shared.batcher.state);
    let joinable = st.open
        && st.key == Some(my_key)
        && st.jobs.len() < WINDOW_FILL
        && st.op.as_ref().is_some_and(|w| Arc::ptr_eq(w, op));
    if joinable {
        // Follower: enqueue and sleep until the leader posts our result.
        let gen = st.gen;
        let idx = st.jobs.len();
        st.jobs.push(PendingJob { problem, known_truth });
        loop {
            if let Some(pos) = st.results.iter().position(|(g, i, _)| *g == gen && *i == idx) {
                return st.results.remove(pos).2;
            }
            st = wait_recover(&shared.batcher.cv, st);
        }
    }
    if st.open {
        // A window is open but we cannot join it (foreign key, full, or a
        // stale same-key operator): solve solo rather than stall behind
        // its deadline.
        drop(st);
        return solve_solo(&problem, known_truth, &shared.alg_opts, req.seed);
    }
    // Leader: open a window keyed and seeded by this request, hold it to
    // the deadline (sleep-polling — the sync doorway's model shim has no
    // timed condvar wait), then solve whatever joined in one call.
    st.gen += 1;
    let gen = st.gen;
    st.open = true;
    st.key = Some(my_key);
    st.op = Some(Arc::clone(op));
    st.seed = req.seed;
    st.deadline = Instant::now() + window;
    st.jobs.push(PendingJob { problem, known_truth });
    loop {
        if st.jobs.len() >= WINDOW_FILL || Instant::now() >= st.deadline {
            break;
        }
        drop(st);
        thread::sleep(WINDOW_POLL);
        st = lock_recover(&shared.batcher.state);
    }
    st.open = false;
    st.key = None;
    st.op = None;
    let jobs = std::mem::take(&mut st.jobs);
    let seed = st.seed;
    drop(st);
    let (problems, known): (Vec<Problem>, Vec<bool>) =
        jobs.into_iter().map(|j| (j.problem, j.known_truth)).unzip();
    let mut results = solve_window(&problems, &known, &shared.alg_opts, seed);
    let mine = results.remove(0);
    if !results.is_empty() {
        let mut st = lock_recover(&shared.batcher.state);
        for (offset, r) in results.into_iter().enumerate() {
            st.results.push((gen, offset + 1, r));
        }
        shared.batcher.cv.notify_all();
    }
    mine
}

/// One lockstep window under panic isolation: a panic anywhere in the
/// batch answers `WorkerPanic` for every window member (their solves are
/// interleaved — no per-job blame), and the server survives.
fn solve_window(
    problems: &[Problem],
    known_truth: &[bool],
    opts: &AsyncOpts,
    seed: u64,
) -> Vec<Result<JobResponse, ServeError>> {
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        recover_batch_stoiht(problems, opts, seed)
    }));
    match out {
        Ok(batch) => batch
            .signals
            .into_iter()
            .zip(known_truth)
            .map(|(s, &k)| Ok(JobResponse::from_outcome(s, k)))
            .collect(),
        Err(_) => problems.iter().map(|_| Err(ServeError::WorkerPanic)).collect(),
    }
}

/// One solo solve under panic isolation — the `--batch-window-ms 0` path,
/// bit-identical to in-process [`super::solve_job`] with the same seed.
fn solve_solo(
    problem: &Problem,
    known_truth: bool,
    opts: &AsyncOpts,
    seed: u64,
) -> Result<JobResponse, ServeError> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        solve_job(problem, Alg::Stoiht, opts, seed)
    }))
    .map(|out| JobResponse::from_outcome(out, known_truth))
    .map_err(|_| ServeError::WorkerPanic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::Ensemble;
    use crate::service::wire::Client;

    fn req(seed: u64) -> JobRequest {
        JobRequest { ensemble: Ensemble::Gaussian, n: 128, m: 64, b: 8, s: 4, seed, y: None }
    }

    fn serve(batch_window_ms: u64, max_inflight: usize) -> ServerHandle {
        let opts = ServeOpts {
            addr: "127.0.0.1:0".to_string(),
            workers: 3,
            batch_window_ms,
            max_inflight,
        };
        Server::bind(opts).unwrap().spawn().unwrap()
    }

    #[test]
    fn op_cache_dedups_hits_and_evicts_lru() {
        let cache = OpCache::new(2);
        let a1 = cache.get_or_draw(&req(1));
        let a2 = cache.get_or_draw(&req(1));
        assert!(Arc::ptr_eq(&a1, &a2), "hit must return the cached Arc");
        let _b = cache.get_or_draw(&req(2));
        // Touch 1 (moves it to front), then insert 3: 2 is the LRU victim.
        let a3 = cache.get_or_draw(&req(1));
        assert!(Arc::ptr_eq(&a1, &a3));
        let _c = cache.get_or_draw(&req(3));
        let _b2 = cache.get_or_draw(&req(2)); // miss: was evicted
        // Relaxed: test-only counter reads, no ordering at stake.
        assert_eq!(cache.hits.load(Ordering::Relaxed), 2);
        assert_eq!(cache.misses.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn op_cache_distinguishes_full_keys() {
        let cache = OpCache::new(8);
        let a = cache.get_or_draw(&req(1));
        let b = cache.get_or_draw(&JobRequest { n: 64, m: 32, ..req(1) });
        assert!(!Arc::ptr_eq(&a, &b));
        // Relaxed: test-only counter read.
        assert_eq!(cache.misses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn op_cache_publish_race_adopts_the_first_arc() {
        // Two concurrent misses on one key both draw (outside the lock);
        // insert-if-absent publication makes the loser adopt the winner's
        // Arc so the single-operator-per-key identity survives the race.
        let cache = OpCache::new(2);
        let r = req(1);
        assert!(cache.lookup(&r.op_key()).is_none());
        let first = r.draw_operator();
        let second = r.draw_operator();
        assert!(!Arc::ptr_eq(&first, &second));
        let won = cache.publish(r.op_key(), Arc::clone(&first));
        let lost = cache.publish(r.op_key(), Arc::clone(&second));
        assert!(Arc::ptr_eq(&won, &first));
        assert!(Arc::ptr_eq(&lost, &first), "loser must adopt the published Arc");
        // Both draws count as misses; the adoption is not a lookup hit.
        // Relaxed: test-only counter reads.
        assert_eq!(cache.misses.load(Ordering::Relaxed), 2);
        assert_eq!(cache.hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn latency_ring_is_bounded_and_overwrites_oldest() {
        let mut ring = LatencyRing::new();
        for i in 0..LAT_CAP + 5 {
            ring.push(i as f64);
        }
        assert_eq!(ring.buf.len(), LAT_CAP);
        // The five overwrites cycled from the start of the buffer.
        assert_eq!(ring.buf[0], LAT_CAP as f64);
        assert_eq!(ring.buf[4], (LAT_CAP + 4) as f64);
        assert_eq!(ring.buf[5], 5.0);
    }

    #[test]
    fn stats_snapshot_survives_a_poisoned_latency_sample() {
        let sh = shared_for_test(0, 4);
        {
            let mut lat = lock_recover(&sh.stats.latencies);
            lat.push(0.001);
            lat.push(f64::NAN);
            lat.push(0.003);
        }
        // Regression: the NaN used to panic quantile()'s partial_cmp sort;
        // now the poisoned sample is ignored for percentile estimation.
        let snap = sh.stats.snapshot(&sh.cache);
        assert_eq!(snap.p50_s, 0.002);
        // An all-poisoned ring degrades to NaN percentiles, not a panic,
        // and the stats frame round-trips them as JSON `null`.
        {
            let mut lat = lock_recover(&sh.stats.latencies);
            lat.buf.clear();
            lat.push(f64::NAN);
        }
        let snap = sh.stats.snapshot(&sh.cache);
        assert!(snap.p50_s.is_nan());
        let frame = crate::service::wire::Reply::Stats(snap).to_json();
        assert!(frame.contains("\"p50_s\":null"), "frame: {frame}");
        let crate::service::wire::Reply::Stats(back) =
            crate::service::wire::Reply::parse(&frame).unwrap()
        else {
            panic!("expected a stats reply");
        };
        assert!(back.p50_s.is_nan() && back.p99_s.is_nan());
    }

    fn shared_for_test(batch_window_ms: u64, max_inflight: usize) -> ServerShared {
        ServerShared {
            opts: ServeOpts {
                addr: "127.0.0.1:0".to_string(),
                workers: 1,
                batch_window_ms,
                max_inflight,
            },
            alg_opts: AsyncOpts::default(),
            cache: OpCache::new(OP_CACHE_CAP),
            stats: Stats::new(),
            batcher: Batcher::new(),
            conns: Mutex::new(VecDeque::new()),
            conn_cv: Condvar::new(),
            stop: AtomicBool::new(false),
        }
    }

    #[test]
    fn admit_reserves_exactly_up_to_the_cap() {
        let sh = shared_for_test(0, 2);
        assert!(admit(&sh, 1));
        assert!(admit(&sh, 1));
        // A refused admission must not disturb committed reservations.
        assert!(!admit(&sh, 1));
        // Relaxed: test-only counter reads, no ordering at stake.
        assert_eq!(sh.stats.inflight.load(Ordering::Relaxed), 2);
        assert_eq!(sh.stats.rejected.load(Ordering::Relaxed), 1);
        finish(&sh, 2, Instant::now());
        assert_eq!(sh.stats.inflight.load(Ordering::Relaxed), 0);
        // A batch larger than the whole cap is refused outright.
        assert!(!admit(&sh, 3));
        assert!(admit(&sh, 2));
    }

    #[test]
    #[cfg_attr(miri, ignore = "full solve loop is too slow under Miri")]
    fn stale_same_key_window_falls_back_to_solo() {
        // An open window whose key matches but whose operator Arc differs
        // (evict-and-redraw between the two cache lookups) must NOT be
        // joined — recover_batch_stoiht asserts pointer identity across
        // the window. The late job solves solo and the window is left
        // untouched.
        let sh = shared_for_test(50, 16);
        let request = req(5);
        let cached = sh.cache.get_or_draw(&request);
        let problem = request.problem(&cached).unwrap();
        {
            let mut st = lock_recover(&sh.batcher.state);
            st.gen = 1;
            st.open = true;
            st.key = Some((request.op_key(), request.b, request.s));
            // Same key, different Arc: a redraw of the same request.
            st.op = Some(request.draw_operator());
            st.seed = request.seed;
            st.deadline = Instant::now() + Duration::from_secs(600);
        }
        let resp = run_batched(&sh, &request, problem, true, &cached).unwrap();
        assert!(resp.converged);
        let st = lock_recover(&sh.batcher.state);
        assert!(st.open, "the foreign window must be left open");
        assert!(st.jobs.is_empty(), "the stale-operator job must not have joined");
    }

    #[test]
    #[cfg_attr(miri, ignore = "opens real TCP sockets")]
    fn served_job_is_bit_identical_to_solve_job() {
        let handle = serve(0, 16);
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let request = req(7);
        let resp = client.job(&request).unwrap().unwrap();
        let op = request.draw_operator();
        let problem = request.problem(&op).unwrap();
        let want = solve_job(&problem, Alg::Stoiht, &AsyncOpts::default(), request.seed);
        assert_eq!(resp.converged, want.converged);
        assert_eq!(resp.iters, want.iters);
        assert_eq!(resp.x.len(), want.x.len());
        for (a, b) in resp.x.iter().zip(&want.x) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(resp.residual.to_bits(), want.residual.to_bits());
        let stats = handle.stats();
        assert_eq!(stats.served, 1);
        assert_eq!(stats.cache_misses, 1);
        assert!(stats.p50_s > 0.0);
        // Same key again: a cache hit, same bits.
        let again = client.job(&request).unwrap().unwrap();
        assert_eq!(again.x, resp.x);
        assert_eq!(handle.stats().cache_hits, 1);
        handle.stop();
    }

    #[test]
    #[cfg_attr(miri, ignore = "opens real TCP sockets")]
    fn window_merges_compatible_concurrent_jobs() {
        let handle = serve(40, 16);
        let addr = handle.addr().to_string();
        let clients: Vec<thread::JoinHandle<JobResponse>> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                thread::Builder::new()
                    .name("serve-test-client".to_string())
                    .spawn(move || {
                        let mut c = Client::connect(&addr).unwrap();
                        c.job(&req(5)).unwrap().unwrap()
                    })
                    .expect("spawn test client")
            })
            .collect();
        for c in clients {
            let resp = c.join().unwrap();
            assert!(resp.converged, "windowed solve must still converge");
            assert!(resp.residual < 1e-6);
        }
        let stats = handle.stats();
        assert_eq!(stats.served, 2);
        // Exactly one lookup outcome per request. The split is racy (two
        // concurrent misses may both draw before either publishes — the
        // loser adopts the winner's Arc), but bounded.
        assert_eq!(stats.cache_hits + stats.cache_misses, 2);
        assert!(stats.cache_misses >= 1, "first request for a key must miss");
        handle.stop();
    }

    #[test]
    #[cfg_attr(miri, ignore = "opens real TCP sockets")]
    fn invalid_and_incompatible_frames_get_typed_errors() {
        let handle = serve(0, 16);
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        // Invalid problem: b does not divide m.
        let bad = JobRequest { b: 7, ..req(1) };
        assert!(matches!(client.job(&bad).unwrap(), Err(ServeError::Invalid(_))));
        // Wrong version: speak v2 by hand.
        let mut stream = TcpStream::connect(handle.addr()).unwrap();
        write_frame(&mut stream, r#"{"api_version":2,"stats":true}"#).unwrap();
        let text = crate::service::wire::read_frame(&mut stream).unwrap().unwrap();
        let Reply::Job(Err(e)) = Reply::parse(&text).unwrap() else {
            panic!("expected a typed error reply");
        };
        assert_eq!(e.code(), "unsupported_version");
        // Garbage payload: malformed, connection survives for a retry.
        write_frame(&mut stream, "not json").unwrap();
        let text = crate::service::wire::read_frame(&mut stream).unwrap().unwrap();
        let Reply::Job(Err(e)) = Reply::parse(&text).unwrap() else {
            panic!("expected a typed error reply");
        };
        assert_eq!(e.code(), "malformed");
        write_frame(&mut stream, &Request::Stats.to_json()).unwrap();
        let text = crate::service::wire::read_frame(&mut stream).unwrap().unwrap();
        assert!(matches!(Reply::parse(&text).unwrap(), Reply::Stats(_)));
        handle.stop();
    }

    #[test]
    #[cfg_attr(miri, ignore = "opens real TCP sockets")]
    fn batch_frame_recovers_compatible_jobs_together() {
        let handle = serve(0, 16);
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let batch = BatchRequest { jobs: vec![req(9), req(9)] };
        let results = client.batch(&batch).unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            assert!(r.as_ref().unwrap().converged);
        }
        // One shared window: a single cache lookup for both jobs.
        assert_eq!(handle.stats().cache_misses, 1);
        assert_eq!(handle.stats().served, 2);
        handle.stop();
    }
}
