//! Versioned typed job API (`api_version` **1**) — the one vocabulary the
//! serving stack speaks.
//!
//! Every layer that moves a recovery job around — the `astir batch` CLI,
//! the TCP front-end ([`super::server`] / [`super::wire`]), and the
//! in-process pool entry points — consumes [`JobRequest`] /
//! [`BatchRequest`] and produces [`JobResponse`] / [`ServeError`] instead
//! of ad-hoc per-call argument lists. The types serialize through the
//! in-crate JSON writer/parser ([`crate::bench_harness::json::Json`] — no
//! serde in the offline build), with `f64` payloads written in shortest
//! round-trip form so a served iterate is **bit-identical** after a wire
//! round trip.
//!
//! ## Compatibility rule (v1)
//!
//! Within `api_version: 1`, changes are **additive only**: new optional
//! fields may appear, existing fields never change meaning, type, or
//! disappear. Parsers MUST ignore unknown fields (the `get`-based
//! decoding here does exactly that). Any breaking change bumps
//! [`API_VERSION`], and a peer speaking an unknown version is rejected
//! with [`ServeError::UnsupportedVersion`] instead of being misread.
//!
//! ## Determinism contract
//!
//! A request is resolved in two independently seeded steps so the
//! operator cache cannot perturb results:
//!
//! * [`JobRequest::draw_operator`] draws from `Rng::seed_from(seed)` —
//!   the stream a cache miss consumes;
//! * [`JobRequest::problem`] draws the signal (when `y` is absent) from
//!   `Rng::seed_from(seed).split(1)` — a stream independent of whether
//!   the operator came fresh or from cache.
//!
//! Served results are therefore bit-identical to calling these two
//! helpers plus [`super::solve_job`] in-process with the same seed — the
//! contract `rust/tests/serve_e2e.rs` pins over a real socket.

use std::fmt;
use std::fmt::Write as _;

use crate::sync::Arc;

use crate::bench_harness::json::Json;
use crate::linalg::Operator;
use crate::problem::{Ensemble, Problem, ProblemSpec, SignalModel};
use crate::rng::Rng;
use crate::service::JobOutcome;

/// The wire protocol version every frame carries.
pub const API_VERSION: u64 = 1;

/// Largest `n` or `m` a served request may ask for. Generous against the
/// paper's scales (matrix-free DCT runs at `n = 2^17`+), but finite: a
/// remote frame must never be able to drive the server into a capacity
/// overflow or an allocation-failure abort.
pub const MAX_DIM: usize = 1 << 22;

/// Largest `n * m` for ensembles that materialize the dense operator
/// (512 MiB of `f64`). `partial_dct` is served matrix-free and is bound
/// only by [`MAX_DIM`].
pub const MAX_DENSE_ELEMS: usize = 1 << 26;

/// Typed error half of every response — exhaustive, stable codes.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Admission control: in-flight jobs already at `--max-inflight`;
    /// the server rejects rather than queues. Retry later.
    Busy,
    /// The frame was not a well-formed v1 request (bad JSON, missing or
    /// mistyped field).
    Malformed(String),
    /// The request parsed but describes an invalid problem
    /// (`ProblemSpec::validate` failure, wrong `y` length, …).
    Invalid(String),
    /// A batch's jobs cannot share one lockstep window (mismatched
    /// operator key or dimensions).
    Incompatible(String),
    /// The job (or its micro-batch window) panicked in a worker; only
    /// this job's slot is poisoned, the server and the rest of the
    /// window keep going.
    WorkerPanic,
    /// The peer requested an `api_version` this build does not speak.
    UnsupportedVersion(u64),
}

impl ServeError {
    /// Stable wire code (`snake_case`, never reused across meanings).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Busy => "busy",
            ServeError::Malformed(_) => "malformed",
            ServeError::Invalid(_) => "invalid",
            ServeError::Incompatible(_) => "incompatible",
            ServeError::WorkerPanic => "worker_panic",
            ServeError::UnsupportedVersion(_) => "unsupported_version",
        }
    }

    /// Human-readable detail line.
    pub fn message(&self) -> String {
        match self {
            ServeError::Busy => "server at max in-flight jobs; retry later".to_string(),
            ServeError::Malformed(m) | ServeError::Invalid(m) | ServeError::Incompatible(m) => {
                m.clone()
            }
            ServeError::WorkerPanic => "job panicked in a worker".to_string(),
            ServeError::UnsupportedVersion(v) => {
                format!("unsupported api_version {v} (this build speaks {API_VERSION})")
            }
        }
    }

    /// Serialize as the `{"code":…,"message":…}` error object.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(
            out,
            "{{\"code\":\"{}\",\"message\":\"{}\"}}",
            self.code(),
            crate::metrics::json_escape(&self.message())
        );
    }

    /// Decode an error object (inverse of [`ServeError::to_json`]).
    pub fn from_json(j: &Json) -> Result<ServeError, ServeError> {
        let code = req_str(j, "code")?;
        let msg = req_str(j, "message").unwrap_or_default();
        Ok(match code.as_str() {
            "busy" => ServeError::Busy,
            "malformed" => ServeError::Malformed(msg),
            "invalid" => ServeError::Invalid(msg),
            "incompatible" => ServeError::Incompatible(msg),
            "worker_panic" => ServeError::WorkerPanic,
            "unsupported_version" => {
                // Best effort: the offending version is only in the text.
                ServeError::UnsupportedVersion(0)
            }
            other => return Err(malformed(format!("unknown error code `{other}`"))),
        })
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code(), self.message())
    }
}

/// Shorthand for a malformed-frame error.
pub(crate) fn malformed(msg: impl Into<String>) -> ServeError {
    ServeError::Malformed(msg.into())
}

/// One recovery job: the problem coordinates `(ensemble, n, m, b, s)`,
/// the deterministic `seed`, and optionally the raw measurements `y`
/// (length `m`). When `y` is absent the server plants a signal from the
/// seed (the benchmarking/self-test mode); when present, the planted
/// truth is unknown and [`JobResponse::final_error`] is `null`.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRequest {
    pub ensemble: Ensemble,
    pub n: usize,
    pub m: usize,
    pub b: usize,
    pub s: usize,
    pub seed: u64,
    pub y: Option<Vec<f64>>,
}

/// Operator-cache key: everything that determines the drawn operator.
/// Two requests with equal keys are served from ONE `Arc<Operator>`, so
/// their problems satisfy `Problem::shares_operator_with` — the
/// precondition for joining the same micro-batch window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpKey {
    pub ensemble: Ensemble,
    pub n: usize,
    pub m: usize,
    pub seed: u64,
}

impl JobRequest {
    /// Lift CLI/TOML problem config into a typed request (no raw `y`).
    pub fn from_spec(spec: &ProblemSpec, seed: u64) -> JobRequest {
        JobRequest {
            ensemble: spec.ensemble,
            n: spec.n,
            m: spec.m,
            b: spec.b,
            s: spec.s,
            seed,
            y: None,
        }
    }

    /// The problem distribution this request describes. Served
    /// `partial_dct` is always matrix-free (the dense pair at service
    /// scale could be terabytes), so such requests need a power-of-two
    /// `n`; every other ensemble materializes the matrix.
    pub fn spec(&self) -> ProblemSpec {
        ProblemSpec {
            n: self.n,
            m: self.m,
            b: self.b,
            s: self.s,
            ensemble: self.ensemble,
            signal: SignalModel::GaussianSpikes,
            noise_std: 0.0,
            dense_a: !matches!(self.ensemble, Ensemble::PartialDct),
        }
    }

    /// Reject invalid problems *before* any generation code can panic on
    /// them — the served API must never turn user input into a panic.
    /// That includes **size caps** ([`MAX_DIM`], [`MAX_DENSE_ELEMS`]):
    /// they run first, so no downstream code ever sees dimensions whose
    /// allocation could overflow or abort.
    pub fn validate(&self) -> Result<(), ServeError> {
        if self.n > MAX_DIM || self.m > MAX_DIM {
            return Err(ServeError::Invalid(format!(
                "n = {} / m = {} exceed the serving cap MAX_DIM = {MAX_DIM}",
                self.n, self.m
            )));
        }
        let spec = self.spec();
        if spec.dense_a && self.n.saturating_mul(self.m) > MAX_DENSE_ELEMS {
            return Err(ServeError::Invalid(format!(
                "dense {} operator of {} x {} exceeds MAX_DENSE_ELEMS = {MAX_DENSE_ELEMS}",
                self.ensemble.as_str(), self.m, self.n
            )));
        }
        spec.validate().map_err(ServeError::Invalid)?;
        if let Some(y) = &self.y {
            if y.len() != self.m {
                return Err(ServeError::Invalid(format!(
                    "y has {} entries, expected m = {}",
                    y.len(),
                    self.m
                )));
            }
            if y.iter().any(|v| !v.is_finite()) {
                return Err(ServeError::Invalid("y contains non-finite entries".to_string()));
            }
        }
        Ok(())
    }

    /// The operator-cache key (see [`OpKey`]).
    pub fn op_key(&self) -> OpKey {
        OpKey { ensemble: self.ensemble, n: self.n, m: self.m, seed: self.seed }
    }

    /// Draw this request's measurement operator from its dedicated RNG
    /// stream (`Rng::seed_from(seed)`). The caller must have validated
    /// the request. Cache misses run this; cache hits skip it entirely
    /// without perturbing the signal stream below.
    pub fn draw_operator(&self) -> Arc<Operator> {
        self.spec().draw_operator(&mut Rng::seed_from(self.seed))
    }

    /// Resolve the request against an operator (fresh or cached) into a
    /// concrete [`Problem`]. Signal draws use `Rng::seed_from(seed)
    /// .split(1)` — independent of the operator stream, so a cache hit
    /// yields bit-identical measurements to a cold draw.
    pub fn problem(&self, op: &Arc<Operator>) -> Result<Problem, ServeError> {
        self.validate()?;
        let spec = self.spec();
        match &self.y {
            Some(y) => Problem::from_measurements(spec, op, y.clone())
                .map_err(ServeError::Invalid),
            None => {
                let mut root = Rng::seed_from(self.seed);
                let mut sig_rng = root.split(1);
                Ok(spec.generate_with_op(op, &mut sig_rng))
            }
        }
    }

    /// Serialize (no envelope — [`super::wire`] adds `api_version`).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(
            out,
            "{{\"ensemble\":\"{}\",\"n\":{},\"m\":{},\"b\":{},\"s\":{},\"seed\":{}",
            self.ensemble.as_str(),
            self.n,
            self.m,
            self.b,
            self.s,
            self.seed
        );
        if let Some(y) = &self.y {
            out.push_str(",\"y\":");
            write_f64_array(out, y);
        }
        out.push('}');
    }

    /// Decode one job object. Unknown fields are ignored (v1 rule).
    pub fn from_json(j: &Json) -> Result<JobRequest, ServeError> {
        let ens = req_str(j, "ensemble")?;
        let ensemble = Ensemble::parse(&ens)
            .ok_or_else(|| malformed(format!("unknown ensemble `{ens}`")))?;
        let y = match j.get("y") {
            None | Some(Json::Null) => None,
            Some(v) => Some(f64_array(v, "y")?),
        };
        Ok(JobRequest {
            ensemble,
            n: req_usize(j, "n")?,
            m: req_usize(j, "m")?,
            b: req_usize(j, "b")?,
            s: req_usize(j, "s")?,
            seed: req_u64(j, "seed")?,
            y,
        })
    }
}

/// Several jobs submitted as one unit. Jobs that agree on the window key
/// (operator key + `b` + `s`) can be recovered in one lockstep
/// [`super::recover_batch_stoiht`] window; the server checks with
/// [`BatchRequest::compatible`] and falls back to per-job solves
/// otherwise.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchRequest {
    pub jobs: Vec<JobRequest>,
}

impl BatchRequest {
    /// Every job individually valid, batch non-empty.
    pub fn validate(&self) -> Result<(), ServeError> {
        if self.jobs.is_empty() {
            return Err(ServeError::Invalid("empty batch".to_string()));
        }
        for job in &self.jobs {
            job.validate()?;
        }
        Ok(())
    }

    /// Can all jobs share one lockstep window (one operator `Arc`, equal
    /// dimensions)?
    pub fn compatible(&self) -> bool {
        let Some(first) = self.jobs.first() else { return false };
        let key = (first.op_key(), first.b, first.s);
        self.jobs.iter().all(|job| (job.op_key(), job.b, job.s) == key)
    }

    /// Serialize (no envelope).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    pub(crate) fn write_json(&self, out: &mut String) {
        out.push('[');
        for (i, job) in self.jobs.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            job.write_json(out);
        }
        out.push(']');
    }

    /// Decode the `jobs` array.
    pub fn from_json(j: &Json) -> Result<BatchRequest, ServeError> {
        let arr = j.as_arr().ok_or_else(|| malformed("`jobs` must be an array"))?;
        let jobs = arr.iter().map(JobRequest::from_json).collect::<Result<Vec<_>, _>>()?;
        Ok(BatchRequest { jobs })
    }
}

/// One job's result. `x` round-trips bit-exactly (shortest round-trip
/// `f64` text both ways); `final_error` is `null` when the request
/// supplied raw `y` (no planted truth to compare against).
#[derive(Clone, Debug, PartialEq)]
pub struct JobResponse {
    pub converged: bool,
    pub iters: u64,
    pub residual: f64,
    pub final_error: Option<f64>,
    pub x: Vec<f64>,
    pub wall_s: f64,
}

impl JobResponse {
    /// Lift a pool/batch outcome into the wire type. `known_truth` is
    /// false for raw-`y` requests, whose `final_error` would otherwise
    /// be distance to an arbitrary all-zero placeholder.
    pub fn from_outcome(out: JobOutcome, known_truth: bool) -> JobResponse {
        JobResponse {
            converged: out.converged,
            iters: out.iters,
            residual: out.residual,
            final_error: known_truth.then_some(out.final_error),
            x: out.x,
            wall_s: out.wall.as_secs_f64(),
        }
    }

    /// Serialize (no envelope).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(out, "{{\"converged\":{},\"iters\":{},\"residual\":", self.converged,
            self.iters);
        push_f64(out, self.residual);
        out.push_str(",\"final_error\":");
        match self.final_error {
            Some(e) => push_f64(out, e),
            None => out.push_str("null"),
        }
        out.push_str(",\"x\":");
        write_f64_array(out, &self.x);
        out.push_str(",\"wall_s\":");
        push_f64(out, self.wall_s);
        out.push('}');
    }

    /// Decode one response object.
    pub fn from_json(j: &Json) -> Result<JobResponse, ServeError> {
        let converged = j
            .get("converged")
            .and_then(Json::as_bool)
            .ok_or_else(|| malformed("missing bool field `converged`"))?;
        let final_error = match j.get("final_error") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| malformed("`final_error` must be a number"))?),
        };
        Ok(JobResponse {
            converged,
            iters: req_u64(j, "iters")?,
            residual: req_f64(j, "residual")?,
            final_error,
            x: f64_array(j.get("x").ok_or_else(|| malformed("missing field `x`"))?, "x")?,
            wall_s: req_f64(j, "wall_s")?,
        })
    }
}

/// Server counters + latency percentiles, queryable over the wire (a
/// `stats` frame) and from the in-process handle. Percentiles are NaN
/// (wire `null`) until the first job completes.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    /// Jobs completed (ok or worker-panic), excluding admission rejects.
    pub served: u64,
    /// Jobs rejected by admission control, plus connections turned away
    /// over the accept backlog (both answer [`ServeError::Busy`]).
    pub rejected: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Jobs currently admitted and not yet answered.
    pub inflight: u64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
}

impl StatsSnapshot {
    /// Operator-cache hit ratio in `[0, 1]` (NaN before any lookup).
    pub fn cache_hit_ratio(&self) -> f64 {
        self.cache_hits as f64 / (self.cache_hits + self.cache_misses) as f64
    }

    /// Serialize (no envelope).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(
            out,
            "{{\"served\":{},\"rejected\":{},\"cache_hits\":{},\"cache_misses\":{},\
             \"inflight\":{}",
            self.served, self.rejected, self.cache_hits, self.cache_misses, self.inflight
        );
        for (key, v) in [("p50_s", self.p50_s), ("p90_s", self.p90_s), ("p99_s", self.p99_s)] {
            let _ = write!(out, ",\"{key}\":");
            push_f64(out, v);
        }
        out.push('}');
    }

    /// Decode a stats object (`null` percentiles come back as NaN).
    pub fn from_json(j: &Json) -> Result<StatsSnapshot, ServeError> {
        Ok(StatsSnapshot {
            served: req_u64(j, "served")?,
            rejected: req_u64(j, "rejected")?,
            cache_hits: req_u64(j, "cache_hits")?,
            cache_misses: req_u64(j, "cache_misses")?,
            inflight: req_u64(j, "inflight")?,
            p50_s: opt_f64(j, "p50_s"),
            p90_s: opt_f64(j, "p90_s"),
            p99_s: opt_f64(j, "p99_s"),
        })
    }
}

// --------------------------------------------------- exchange frames (v1)

/// A shard process announcing itself to `astir exchange-hub`. The reply
/// ([`ExchangeJoined`]) is withheld until the whole fleet has joined (or
/// the join window closes), so it doubles as the session start barrier.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExchangeJoin {
    /// This worker's shard id in `0..shards`.
    pub shard: usize,
    /// Fleet size `S` the worker was configured with; every joiner must
    /// agree or the hub rejects with [`ServeError::Incompatible`].
    pub shards: usize,
    /// Tally dimension `n` — the length of every vote snapshot.
    pub n: usize,
    /// Local steps between exchanges (`E`). The hub derives its per-peer
    /// round deadline from the largest `E` in the fleet.
    pub exchange_period: usize,
}

/// Hub → worker: the fleet is assembled, rounds may begin.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExchangeJoined {
    /// Fleet size the hub is running (echoed for sanity).
    pub shards: usize,
    /// The per-peer round deadline the hub will enforce, so the worker
    /// can bound its own reply reads a margin above it.
    pub round_timeout_ms: u64,
}

/// One shard's vote snapshot for one exchange round. `votes` is the
/// shard's **own contribution** (live tally minus previously folded peer
/// votes) — exactly what `ExchangeBoard::publish_and_wait` receives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExchangePublish {
    pub shard: usize,
    /// 1-based round number; must match the round the hub is assembling
    /// (a stale or future round is [`ServeError::Incompatible`]).
    pub round: u64,
    /// Sticky convergence flag, the `finished` bit of the in-process
    /// barrier: once raised the shard keeps republishing until the whole
    /// fleet is done.
    pub finished: bool,
    pub votes: Vec<i64>,
}

/// Hub → worker: the completed round's merged view. `merged` includes the
/// receiving shard's own snapshot (its peer sum is `merged - own`, exact
/// in `i64`), so one payload serves the whole fleet.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExchangeView {
    /// Echo of the completed round number.
    pub round: u64,
    /// How many shards are done — the worker exits when this reaches
    /// `S`. Dead peers count as finished (they can never un-finish).
    pub finished_shards: usize,
    /// How many peers missed this round (dead or never joined) and were
    /// merged from their last snapshot — the `Degraded` signal.
    pub stale_peers: usize,
    pub merged: Vec<i64>,
}

/// Clean goodbye after the worker has seen `finished_shards == S`. Not
/// acknowledged; the hub records the shard as cleanly finished rather
/// than degraded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExchangeLeave {
    pub shard: usize,
}

impl ExchangeJoin {
    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(
            out,
            "{{\"shard\":{},\"shards\":{},\"n\":{},\"exchange_period\":{}}}",
            self.shard, self.shards, self.n, self.exchange_period
        );
    }

    pub fn from_json(j: &Json) -> Result<ExchangeJoin, ServeError> {
        Ok(ExchangeJoin {
            shard: req_usize(j, "shard")?,
            shards: req_usize(j, "shards")?,
            n: req_usize(j, "n")?,
            exchange_period: req_usize(j, "exchange_period")?,
        })
    }
}

impl ExchangeJoined {
    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(
            out,
            "{{\"shards\":{},\"round_timeout_ms\":{}}}",
            self.shards, self.round_timeout_ms
        );
    }

    pub fn from_json(j: &Json) -> Result<ExchangeJoined, ServeError> {
        Ok(ExchangeJoined {
            shards: req_usize(j, "shards")?,
            round_timeout_ms: req_u64(j, "round_timeout_ms")?,
        })
    }
}

impl ExchangePublish {
    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(
            out,
            "{{\"shard\":{},\"round\":{},\"finished\":{},\"votes\":",
            self.shard, self.round, self.finished
        );
        write_i64_array(out, &self.votes);
        out.push('}');
    }

    pub fn from_json(j: &Json) -> Result<ExchangePublish, ServeError> {
        Ok(ExchangePublish {
            shard: req_usize(j, "shard")?,
            round: req_u64(j, "round")?,
            finished: req_bool(j, "finished")?,
            votes: i64_array(
                j.get("votes").ok_or_else(|| malformed("missing array field `votes`"))?,
                "votes",
            )?,
        })
    }
}

impl ExchangeView {
    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(
            out,
            "{{\"round\":{},\"finished_shards\":{},\"stale_peers\":{},\"merged\":",
            self.round, self.finished_shards, self.stale_peers
        );
        write_i64_array(out, &self.merged);
        out.push('}');
    }

    pub fn from_json(j: &Json) -> Result<ExchangeView, ServeError> {
        Ok(ExchangeView {
            round: req_u64(j, "round")?,
            finished_shards: req_usize(j, "finished_shards")?,
            stale_peers: req_usize(j, "stale_peers")?,
            merged: i64_array(
                j.get("merged").ok_or_else(|| malformed("missing array field `merged`"))?,
                "merged",
            )?,
        })
    }
}

impl ExchangeLeave {
    pub(crate) fn write_json(&self, out: &mut String) {
        let _ = write!(out, "{{\"shard\":{}}}", self.shard);
    }

    pub fn from_json(j: &Json) -> Result<ExchangeLeave, ServeError> {
        Ok(ExchangeLeave { shard: req_usize(j, "shard")? })
    }
}

// ------------------------------------------------ shared JSON primitives

/// Shortest-round-trip `f64` (non-finite → `null`, like the bench
/// telemetry). `f64::to_string` output re-parses to the identical bits,
/// which is what makes served iterates bit-identical across the wire.
pub(crate) fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null");
    }
}

pub(crate) fn write_f64_array(out: &mut String, vals: &[f64]) {
    out.push('[');
    for (i, &v) in vals.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_f64(out, v);
    }
    out.push(']');
}

pub(crate) fn f64_array(j: &Json, key: &str) -> Result<Vec<f64>, ServeError> {
    let arr = j.as_arr().ok_or_else(|| malformed(format!("`{key}` must be an array")))?;
    arr.iter()
        .map(|v| match v {
            Json::Num(x) => Ok(*x),
            Json::Null => Ok(f64::NAN),
            _ => Err(malformed(format!("`{key}` entries must be numbers"))),
        })
        .collect()
}

/// Exact `i64` over a JSON layer whose numbers are `f64`-backed: values
/// within the exact-integer window `±2^53` travel as plain numbers;
/// anything beyond travels as a decimal **string** so no bits are lost.
/// [`i64_array`] accepts both forms per entry.
pub(crate) fn push_i64(out: &mut String, v: i64) {
    const EXACT: i64 = 1 << 53;
    if (-EXACT..=EXACT).contains(&v) {
        let _ = write!(out, "{v}");
    } else {
        let _ = write!(out, "\"{v}\"");
    }
}

pub(crate) fn write_i64_array(out: &mut String, vals: &[i64]) {
    out.push('[');
    for (i, &v) in vals.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_i64(out, v);
    }
    out.push(']');
}

/// Decode a vote vector written by [`write_i64_array`]. Numbers outside
/// the exact window are rejected rather than silently rounded.
pub(crate) fn i64_array(j: &Json, key: &str) -> Result<Vec<i64>, ServeError> {
    const EXACT: f64 = 9_007_199_254_740_992.0;
    let arr = j.as_arr().ok_or_else(|| malformed(format!("`{key}` must be an array")))?;
    arr.iter()
        .map(|v| match v {
            Json::Num(x) if x.fract() == 0.0 && x.abs() <= EXACT => Ok(*x as i64),
            Json::Num(x) => {
                Err(malformed(format!("`{key}` entry {x} is not an exact integer")))
            }
            Json::Str(s) => s
                .parse::<i64>()
                .map_err(|_| malformed(format!("`{key}` entry `{s}` is not an i64"))),
            _ => Err(malformed(format!("`{key}` entries must be integers"))),
        })
        .collect()
}

fn req_bool(j: &Json, key: &str) -> Result<bool, ServeError> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| malformed(format!("missing boolean field `{key}`")))
}

fn req_str(j: &Json, key: &str) -> Result<String, ServeError> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| malformed(format!("missing string field `{key}`")))
}

fn req_f64(j: &Json, key: &str) -> Result<f64, ServeError> {
    match j.get(key) {
        Some(Json::Null) => Ok(f64::NAN),
        Some(v) => v.as_f64().ok_or_else(|| malformed(format!("`{key}` must be a number"))),
        None => Err(malformed(format!("missing numeric field `{key}`"))),
    }
}

fn opt_f64(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

/// Nonnegative integer field (the JSON interop 2^53 rule applies, same
/// as the bench telemetry).
pub(crate) fn req_u64(j: &Json, key: &str) -> Result<u64, ServeError> {
    let v = j
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| malformed(format!("missing numeric field `{key}`")))?;
    if v < 0.0 || v.fract() != 0.0 || v > 9.007_199_254_740_992e15 {
        return Err(malformed(format!("`{key}` must be a nonnegative integer, got {v}")));
    }
    Ok(v as u64)
}

fn req_usize(j: &Json, key: &str) -> Result<usize, ServeError> {
    Ok(req_u64(j, key)? as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(seed: u64) -> JobRequest {
        JobRequest { ensemble: Ensemble::Gaussian, n: 128, m: 64, b: 8, s: 4, seed, y: None }
    }

    #[test]
    fn job_request_roundtrips() {
        let req = job(7);
        let parsed = JobRequest::from_json(&Json::parse(&req.to_json()).unwrap()).unwrap();
        assert_eq!(parsed, req);
        let with_y =
            JobRequest { y: Some(vec![0.1 + 0.2, -0.0, 1e-300, 3.5]), m: 4, b: 2, ..job(9) };
        let parsed = JobRequest::from_json(&Json::parse(&with_y.to_json()).unwrap()).unwrap();
        assert_eq!(parsed, with_y);
        // Bit-exact float round trip, including the -0.0 sign bit.
        let y = parsed.y.unwrap();
        for (a, b) in y.iter().zip(with_y.y.as_ref().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn unknown_fields_are_ignored_v1_rule() {
        let mut text = job(3).to_json();
        text.insert_str(1, "\"future_field\":[1,2,3],");
        let parsed = JobRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, job(3));
    }

    #[test]
    fn malformed_requests_are_typed_errors() {
        for bad in [
            r#"{"ensemble":"nope","n":8,"m":4,"b":2,"s":1,"seed":0}"#,
            r#"{"ensemble":"gaussian","n":8,"m":4,"b":2,"seed":0}"#,
            r#"{"ensemble":"gaussian","n":8.5,"m":4,"b":2,"s":1,"seed":0}"#,
            r#"{"ensemble":"gaussian","n":-8,"m":4,"b":2,"s":1,"seed":0}"#,
            r#"{"ensemble":"gaussian","n":8,"m":4,"b":2,"s":1,"seed":0,"y":"zz"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(
                matches!(JobRequest::from_json(&j), Err(ServeError::Malformed(_))),
                "should be malformed: {bad}"
            );
        }
    }

    #[test]
    fn validate_rejects_bad_problems_without_panicking() {
        let bad_blocks = JobRequest { b: 5, ..job(1) }; // 5 does not divide 64
        assert!(matches!(bad_blocks.validate(), Err(ServeError::Invalid(_))));
        let bad_y = JobRequest { y: Some(vec![1.0; 3]), ..job(1) };
        assert!(matches!(bad_y.validate(), Err(ServeError::Invalid(_))));
        let nan_y = JobRequest { y: Some(vec![f64::NAN; 64]), ..job(1) };
        assert!(matches!(nan_y.validate(), Err(ServeError::Invalid(_))));
        // partial_dct is served matrix-free => power-of-two n required.
        let bad_dct = JobRequest { ensemble: Ensemble::PartialDct, n: 100, ..job(1) };
        assert!(matches!(bad_dct.validate(), Err(ServeError::Invalid(_))));
        job(1).validate().unwrap();
    }

    #[test]
    fn validate_caps_hostile_dimensions_before_any_allocation() {
        // Per-axis cap: applies to every ensemble, checked before the
        // spec-level divisibility rules so absurd numbers short-circuit.
        let huge_n = JobRequest { n: MAX_DIM + 1, ..job(1) };
        assert!(matches!(huge_n.validate(), Err(ServeError::Invalid(_))));
        let huge_m = JobRequest { m: MAX_DIM + 1, ..job(1) };
        assert!(matches!(huge_m.validate(), Err(ServeError::Invalid(_))));
        // Dense-element cap: n and m individually legal, product not.
        let dense = JobRequest { n: 1 << 16, m: 1 << 16, b: 1 << 8, s: 4, ..job(1) };
        assert!(matches!(dense.validate(), Err(ServeError::Invalid(_))));
        // The same footprint served matrix-free (partial_dct) is fine.
        let dct = JobRequest {
            ensemble: Ensemble::PartialDct,
            n: 1 << 17,
            m: 1 << 10,
            b: 1 << 7,
            s: 16,
            ..job(1)
        };
        dct.validate().unwrap();
    }

    #[test]
    fn problem_resolution_is_cache_stable() {
        // Same request, one fresh operator vs one shared (cache-hit)
        // operator: bit-identical signals and measurements.
        let req = job(11);
        let op = req.draw_operator();
        let p1 = req.problem(&op).unwrap();
        let p2 = req.problem(&op).unwrap();
        assert_eq!(p1.x_true, p2.x_true);
        assert_eq!(p1.y, p2.y);
        assert!(p1.shares_operator_with(&p2));
        // Provided-y mode: measurements taken verbatim, no planted truth.
        let served = JobRequest { y: Some(p1.y.clone()), ..req.clone() };
        let p3 = served.problem(&op).unwrap();
        assert_eq!(p3.y, p1.y);
        assert!(p3.x_true.iter().all(|&v| v == 0.0));
        assert!(p3.support.is_empty());
    }

    #[test]
    fn op_keys_and_window_compatibility() {
        let a = job(1);
        let b = job(1);
        let c = job(2);
        assert_eq!(a.op_key(), b.op_key());
        assert_ne!(a.op_key(), c.op_key());
        assert!(BatchRequest { jobs: vec![a.clone(), b] }.compatible());
        assert!(!BatchRequest { jobs: vec![a.clone(), c] }.compatible());
        let diff_s = JobRequest { s: 5, ..a.clone() };
        assert!(!BatchRequest { jobs: vec![a, diff_s] }.compatible());
        assert!(!BatchRequest { jobs: vec![] }.compatible());
    }

    #[test]
    fn batch_request_roundtrips_and_validates() {
        let batch = BatchRequest { jobs: vec![job(1), job(2)] };
        let parsed = BatchRequest::from_json(&Json::parse(&batch.to_json()).unwrap()).unwrap();
        assert_eq!(parsed, batch);
        batch.validate().unwrap();
        assert!(matches!(
            BatchRequest { jobs: vec![] }.validate(),
            Err(ServeError::Invalid(_))
        ));
    }

    #[test]
    fn job_response_roundtrips_bit_exactly() {
        let resp = JobResponse {
            converged: true,
            iters: 321,
            residual: 3.000000000000001e-8,
            final_error: Some(1.25e-6),
            x: vec![0.0, -0.0, 0.1 + 0.2, -17.25, 1e-300],
            wall_s: 0.0125,
        };
        let parsed = JobResponse::from_json(&Json::parse(&resp.to_json()).unwrap()).unwrap();
        assert_eq!(parsed, resp);
        for (a, b) in parsed.x.iter().zip(&resp.x) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // No planted truth: final_error serializes as null.
        let blind = JobResponse { final_error: None, ..resp };
        let parsed = JobResponse::from_json(&Json::parse(&blind.to_json()).unwrap()).unwrap();
        assert_eq!(parsed.final_error, None);
    }

    #[test]
    fn serve_error_roundtrips_every_variant() {
        let variants = [
            ServeError::Busy,
            ServeError::Malformed("bad frame".to_string()),
            ServeError::Invalid("b must divide m".to_string()),
            ServeError::Incompatible("mixed operator keys".to_string()),
            ServeError::WorkerPanic,
        ];
        for e in variants {
            let parsed = ServeError::from_json(&Json::parse(&e.to_json()).unwrap()).unwrap();
            assert_eq!(parsed, e, "round trip of {e}");
        }
        let v = ServeError::UnsupportedVersion(9);
        let parsed = ServeError::from_json(&Json::parse(&v.to_json()).unwrap()).unwrap();
        assert_eq!(parsed.code(), "unsupported_version");
    }

    #[test]
    fn stats_snapshot_roundtrips() {
        let s = StatsSnapshot {
            served: 10,
            rejected: 2,
            cache_hits: 8,
            cache_misses: 2,
            inflight: 1,
            p50_s: 0.002,
            p90_s: 0.004,
            p99_s: f64::NAN,
        };
        assert!((s.cache_hit_ratio() - 0.8).abs() < 1e-12);
        let parsed = StatsSnapshot::from_json(&Json::parse(&s.to_json()).unwrap()).unwrap();
        assert_eq!(parsed.served, 10);
        assert_eq!(parsed.cache_hits, 8);
        assert_eq!(parsed.p50_s, 0.002);
        assert!(parsed.p99_s.is_nan());
    }

    #[test]
    fn i64_votes_roundtrip_across_the_exact_window() {
        let votes = vec![
            0,
            -1,
            42,
            i64::MAX,
            i64::MIN,
            (1 << 53),
            -(1 << 53),
            (1 << 53) + 1,
            -(1 << 53) - 1,
        ];
        let mut out = String::new();
        write_i64_array(&mut out, &votes);
        // In-window values are plain numbers; out-of-window are strings.
        assert!(out.contains("9007199254740992"));
        assert!(out.contains("\"9007199254740993\""));
        assert!(out.contains(&format!("\"{}\"", i64::MIN)));
        let parsed = i64_array(&Json::parse(&out).unwrap(), "votes").unwrap();
        assert_eq!(parsed, votes);
        // Non-exact numbers are typed errors, not silent rounding.
        let frac = Json::parse("[1.5]").unwrap();
        assert!(matches!(i64_array(&frac, "v"), Err(ServeError::Malformed(_))));
        let big = Json::parse("[1e300]").unwrap();
        assert!(matches!(i64_array(&big, "v"), Err(ServeError::Malformed(_))));
    }

    #[test]
    fn exchange_frames_roundtrip() {
        let join = ExchangeJoin { shard: 2, shards: 4, n: 16, exchange_period: 8 };
        let j = Json::parse(&{
            let mut s = String::new();
            join.write_json(&mut s);
            s
        })
        .unwrap();
        assert_eq!(ExchangeJoin::from_json(&j).unwrap(), join);

        let publish = ExchangePublish {
            shard: 1,
            round: 3,
            finished: true,
            votes: vec![-5, 0, i64::MAX, i64::MIN],
        };
        let j = Json::parse(&{
            let mut s = String::new();
            publish.write_json(&mut s);
            s
        })
        .unwrap();
        assert_eq!(ExchangePublish::from_json(&j).unwrap(), publish);

        let view = ExchangeView {
            round: 3,
            finished_shards: 2,
            stale_peers: 1,
            merged: vec![7, -9, 1 << 60],
        };
        let j = Json::parse(&{
            let mut s = String::new();
            view.write_json(&mut s);
            s
        })
        .unwrap();
        assert_eq!(ExchangeView::from_json(&j).unwrap(), view);

        let leave = ExchangeLeave { shard: 3 };
        let j = Json::parse(&{
            let mut s = String::new();
            leave.write_json(&mut s);
            s
        })
        .unwrap();
        assert_eq!(ExchangeLeave::from_json(&j).unwrap(), leave);
    }

    #[test]
    fn from_outcome_maps_truth_knowledge() {
        let out = JobOutcome {
            converged: true,
            iters: 5,
            residual: 1e-8,
            final_error: 2e-7,
            x: vec![1.0, 0.0],
            wall: std::time::Duration::from_millis(3),
        };
        let known = JobResponse::from_outcome(out.clone(), true);
        assert_eq!(known.final_error, Some(2e-7));
        assert_eq!(known.iters, 5);
        let blind = JobResponse::from_outcome(out, false);
        assert_eq!(blind.final_error, None);
        assert!((blind.wall_s - 0.003).abs() < 1e-9);
    }
}
