//! Persistent recovery service: a long-lived worker pool plus batched
//! multi-signal (MMV-style) recovery.
//!
//! The paper's asynchronous architecture amortizes one shared-memory
//! support tally across many cheap worker updates; the asynchronous
//! shared-memory literature it builds on (Liu & Wright's async coordinate
//! descent, Duchi et al.'s async stochastic optimization — see PAPERS.md)
//! gets its speedups precisely because **workers persist** and the per-job
//! cost is dominated by arithmetic, not setup. This module applies the
//! same discipline to serving recovery traffic:
//!
//! * [`RecoveryPool`] — OS threads spawned **once** and fed through a
//!   lock-light job queue (one mutex/condvar pair for sleeping and batch
//!   hand-off; job claims are an atomic ticket, results commit into
//!   preallocated [`crate::coordinator`] slots without a lock). Per-job
//!   RNG splitting is deterministic exactly like `coordinator::run_trials`
//!   — job `i` derives from the master seed and `i` only — so pool output
//!   is bit-identical at any worker count.
//! * [`solve_job`] / [`solve_job_with`] — one single-signal recovery run
//!   inline on the calling (pool) thread, through the **same**
//!   `drive_worker` loop body as the real-thread runtime: a pool job's
//!   result is bit-for-bit what `run_async_with(problem, 1, …)` returns,
//!   minus the thread spawn (pinned by `rust/tests/service_pool.rs`).
//! * [`ShardedPool`] — bounded-staleness sharded recovery: `S` scoped
//!   threads, each owning a contiguous slice of the measurement blocks and
//!   a **local** tally, running the same `drive_worker` loop body in
//!   `E`-iteration segments between barrier-synchronized support exchanges
//!   (gossip or leader-merge, see [`crate::tally::ExchangeBoard`]). No
//!   early-stop flag plus commutative canonical-order merges make the
//!   results bit-identical at any thread interleaving; one shard delegates
//!   to [`solve_job_with`], so `S = 1` is the single-tally result exactly.
//! * [`recover_batch_stoiht`] — lockstep batched recovery of `B` signals
//!   sharing one operator (`Problem::shares_operator_with`): each time
//!   step samples **one** block and performs **one** multi-RHS fused
//!   proxy call ([`crate::linalg::MeasureOp::block_proxy_step_sparse_multi`]),
//!   and every signal votes its `Γ` into a **shared** tally whose estimate
//!   feeds back into all of them — the paper's Algorithm 2 with "cores"
//!   played by signals. For MMV batches (shared true support, see
//!   [`crate::problem::ProblemSpec::generate_mmv_with_op`]) the tally
//!   concentrates `B`× faster, so per-signal iterations drop just as
//!   Fig. 2's steps-to-exit drop with cores — which is why the batched
//!   path beats a sequential per-signal loop on jobs/sec (measured by the
//!   `throughput` bench suite).
//!
//! Operator setup is the expensive, shareable part of a job (a
//! materialized matrix, or the subsampled-DCT plan at `n = 2^17+`):
//! problems carry `Arc<Operator>`, so a pool full of jobs and a batch full
//! of signals all run against one allocation.
//!
//! The network face of the service lives in three submodules: [`api`]
//! (the versioned typed job vocabulary), [`wire`] (length-prefixed JSON
//! framing + the blocking client), and [`server`] (the `astir serve`
//! front-end: operator cache, deadline micro-batching, admission
//! control).

pub mod api;
pub mod server;
pub mod transport;
pub mod wire;

use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::{thread, Arc, Condvar, Mutex};

use crate::algorithms::{Alg, StoGradMpKernel, StoihtKernel, SupportKernel};
use crate::async_runtime::{drive_worker, AsyncOpts};
use crate::coordinator::{split_rngs, ResultSlots};
use crate::linalg::{MeasureOp, ProxyCol, SparseIterate};
use crate::problem::Problem;
use crate::rng::Rng;
use crate::sim::ShardOpts;
use crate::support::{top_s_into, union_into};
use crate::tally::{
    positive_top_s_into, AtomicTally, ExchangeBoard, ExchangeProtocol, LocalTally,
};

// ------------------------------------------------------------------- pool

/// A batch of queued jobs, type-erased so the long-lived workers need no
/// knowledge of the result type. Indices are claimed by an atomic ticket;
/// the last completion (release/acquire counter) retires the batch.
trait JobSet: Send + Sync {
    fn len(&self) -> usize;
    /// Claim the next unclaimed job index, if any.
    fn claim(&self) -> Option<usize>;
    /// Execute job `i` (the exclusive owner of slot `i`).
    fn run(&self, i: usize);
    /// Mark one job finished; `true` when it was the last of the batch.
    fn finish_one(&self) -> bool;
}

/// The typed job batch: a shared closure, pre-split per-job RNGs, and
/// lock-free result slots.
struct TypedJobs<T, F> {
    f: F,
    rngs: Vec<Rng>,
    slots: ResultSlots<T>,
    next: AtomicUsize,
    pending: AtomicUsize,
    /// First job panic, kept whole (index + original payload) so the
    /// submitter can re-raise it with the diagnostics the scoped-thread
    /// path used to propagate.
    panic: Mutex<Option<(usize, Box<dyn std::any::Any + Send>)>>,
}

impl<T, F> JobSet for TypedJobs<T, F>
where
    T: Send + 'static,
    F: Fn(usize, &mut Rng) -> T + Send + Sync + 'static,
{
    fn len(&self) -> usize {
        self.slots.len()
    }

    fn claim(&self) -> Option<usize> {
        // Relaxed: the ticket only needs to hand out each index once;
        // publication of the slot each ticket guards rides the AcqRel
        // retire in `finish_one`, not the claim itself.
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        (i < self.len()).then_some(i)
    }

    fn run(&self, i: usize) {
        // A panicking job must not strand the submitter: catch the unwind
        // here, keep the payload, and let run_jobs re-raise it.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = self.rngs[i].clone();
            (self.f)(i, &mut rng)
        }));
        match result {
            // Slot protocol: index i was claimed exclusively by the atomic
            // ticket in `claim`; the submitter reads only after the
            // completion hand-off below (see `ResultSlots`).
            Ok(v) => self.slots.put(i, v),
            Err(payload) => {
                let mut guard = self.panic.lock().unwrap();
                if guard.is_none() {
                    *guard = Some((i, payload));
                }
            }
        }
    }

    fn finish_one(&self) -> bool {
        // AcqRel (via `pending_ordering`): the last decrement acquires
        // every earlier worker's slot writes, so the mutex hand-off to the
        // submitter publishes them.
        self.pending.fetch_sub(1, pending_ordering()) == 1
    }
}

/// Ordering for the batch-retire countdown in `finish_one`: `AcqRel` in
/// production. The model-check tier's mutation witness deliberately
/// weakens it to `Relaxed` (via [`crate::sync::model`]) and asserts the
/// checker reports the resulting slot race — proof the checker would
/// catch this ordering being broken for real.
fn pending_ordering() -> Ordering {
    #[cfg(feature = "model")]
    if crate::sync::model::weaken_pool_pending() {
        // Relaxed: deliberately wrong — reachable only from the
        // mutation-witness model tests.
        return Ordering::Relaxed;
    }
    // AcqRel: the production choice; justification at the call site.
    Ordering::AcqRel
}

/// Queue state guarded by the pool mutex (held only to sleep, install a
/// batch, or retire one — never while running a job).
struct PoolState {
    batch: Option<Arc<dyn JobSet>>,
    /// Monotone count of installed batches.
    epoch: u64,
    /// Highest epoch whose batch has fully completed.
    completed: u64,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers sleep here between batches.
    work_cv: Condvar,
    /// Submitters sleep here until their batch completes (or the queue
    /// frees up for the next batch).
    done_cv: Condvar,
}

/// A persistent recovery worker pool: threads are spawned once at
/// construction and serve every subsequent [`RecoveryPool::run_jobs`]
/// batch, so steady-state job cost is solver arithmetic — no thread
/// spawn, no operator re-materialization (jobs share `Arc`ed problems),
/// no per-trial result lock.
pub struct RecoveryPool {
    shared: Arc<PoolShared>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl RecoveryPool {
    /// Spawn `workers` persistent threads (>= 1).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "RecoveryPool needs at least one worker");
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                batch: None,
                epoch: 0,
                completed: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("astir-pool-{w}"))
                    .spawn(move || worker_main(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        RecoveryPool { shared, handles }
    }

    /// Number of persistent workers.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `jobs` independent jobs on the pool and return their results in
    /// job order. Job `i` receives an RNG derived from `master_seed` and
    /// `i` only (the `run_trials` scheme), so the output is bit-identical
    /// at any worker count. Blocks until the batch completes; concurrent
    /// submitters queue up FIFO-ish behind the pool mutex.
    pub fn run_jobs<T, F>(&self, jobs: usize, master_seed: u64, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize, &mut Rng) -> T + Send + Sync + 'static,
    {
        if jobs == 0 {
            return Vec::new();
        }
        let set = Arc::new(TypedJobs {
            f,
            rngs: split_rngs(master_seed, jobs),
            slots: ResultSlots::new(jobs),
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(jobs),
            panic: Mutex::new(None),
        });
        let my_epoch;
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.batch.is_some() {
                // Another submitter's batch is in flight; wait for retire.
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.epoch += 1;
            my_epoch = st.epoch;
            st.batch = Some(Arc::clone(&set) as Arc<dyn JobSet>);
            self.shared.work_cv.notify_all();
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.completed < my_epoch {
                st = self.shared.done_cv.wait(st).unwrap();
            }
        }
        if let Some((i, payload)) = set.panic.lock().unwrap().take() {
            // Re-raise the original payload so the caller sees the real
            // assertion message, with the job index on stderr for context.
            eprintln!("recovery pool job {i} panicked; re-raising its payload");
            std::panic::resume_unwind(payload);
        }
        // Slot protocol: batch completion was observed under the mutex
        // after the last worker's AcqRel decrement, so every slot write
        // happens-before these takes, and this submitter is the only
        // reader of this batch's slots (see `ResultSlots`).
        (0..jobs)
            .map(|i| set.slots.take(i).expect("pool job produced no result"))
            .collect()
    }

    /// [`RecoveryPool::run_jobs`] with per-job panic isolation: a job that
    /// panics yields `Err(ServeError::WorkerPanic)` in **its own slot**
    /// instead of poisoning the whole window — the rest of the batch
    /// completes and returns normally. This is the entry point the serve
    /// path uses, so one hostile or buggy request cannot take down a
    /// micro-batch (or the submitter) with it.
    pub fn try_run_jobs<T, F>(
        &self,
        jobs: usize,
        master_seed: u64,
        f: F,
    ) -> Vec<Result<T, api::ServeError>>
    where
        T: Send + 'static,
        F: Fn(usize, &mut Rng) -> T + Send + Sync + 'static,
    {
        self.run_jobs(jobs, master_seed, move |i, rng| {
            // AssertUnwindSafe: on Err the result value is dropped whole;
            // no partially-mutated state outlives the catch.
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, rng)))
                .map_err(|_| api::ServeError::WorkerPanic)
        })
    }
}

impl Drop for RecoveryPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The persistent worker loop: sleep until a fresh batch epoch appears,
/// drain claims from it, retire the batch on the last completion.
fn worker_main(shared: &PoolShared) {
    let mut last_epoch = 0u64;
    loop {
        let set: Arc<dyn JobSet> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > last_epoch {
                    // A batch was installed since we last looked; it may
                    // already be gone (retired by faster workers).
                    last_epoch = st.epoch;
                    if let Some(b) = &st.batch {
                        break Arc::clone(b);
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        while let Some(i) = set.claim() {
            set.run(i);
            if set.finish_one() {
                let mut st = shared.state.lock().unwrap();
                st.batch = None;
                st.completed = st.completed.max(last_epoch);
                shared.done_cv.notify_all();
            }
        }
    }
}

// ------------------------------------------------------- single-signal job

/// Outcome of one pool recovery job (or one signal of a batch).
#[derive(Clone, Debug)]
pub struct JobOutcome {
    /// Whether the tolerance was met within the iteration cap.
    pub converged: bool,
    /// Local iterations completed.
    pub iters: u64,
    /// Final `‖y − A x‖₂` (the winning check's value when converged, the
    /// final iterate's residual otherwise).
    pub residual: f64,
    /// `‖x − x_true‖₂`.
    pub final_error: f64,
    /// The recovered iterate.
    pub x: Vec<f64>,
    /// Wallclock for this job's solve loop.
    pub wall: Duration,
}

/// Solve one problem inline on the calling thread through the identical
/// worker loop as `run_async_with(problem, 1, opts, seed, make_step)` —
/// same RNG derivation (`Rng::seed_from(seed).split(0)`), same tally
/// protocol, same exit check — so a converged pool job is bit-for-bit the
/// spawn-per-call single-worker result. (Non-converged runs additionally
/// report the final iterate's actual residual/error where the runtime
/// reports NaN.)
pub fn solve_job_with<'p, K, F>(
    problem: &'p Problem,
    opts: &AsyncOpts,
    seed: u64,
    make_step: F,
) -> JobOutcome
where
    K: SupportKernel + 'p,
    F: FnOnce(&'p Problem) -> K,
{
    let spec = &problem.spec;
    let period = opts.schedule.periods(1)[0];
    let tally = AtomicTally::new(spec.n, opts.weighting);
    let stop = AtomicBool::new(false);
    let counter = AtomicU64::new(0);
    let mut seed_root = Rng::seed_from(seed);
    let mut rng = seed_root.split(0);
    let start = Instant::now();
    let mut step = make_step(problem);
    let mut x = SparseIterate::zeros(spec.n);
    let won = drive_worker(
        &mut step, &mut x, spec.s, opts, period, &mut rng, &tally, &stop, &counter,
    );
    let wall = start.elapsed();
    // Relaxed: the single worker loop above ran on this very thread and
    // has returned — no cross-thread publication is involved.
    let iters = counter.load(Ordering::Relaxed);
    let (converged, residual) = match won {
        Some(r) => (true, r),
        None => (false, problem.residual_norm(x.values())),
    };
    let final_error = problem.recovery_error(x.values());
    JobOutcome { converged, iters, residual, final_error, x: x.into_values(), wall }
}

/// [`solve_job_with`] dispatched over the config-level algorithm selector,
/// matching the CLI's `astir async` kernel factories.
pub fn solve_job(problem: &Problem, alg: Alg, opts: &AsyncOpts, seed: u64) -> JobOutcome {
    match alg {
        Alg::Stoiht => solve_job_with(problem, opts, seed, |p| StoihtKernel::new(p, opts.gamma)),
        Alg::StoGradMp => solve_job_with(problem, opts, seed, StoGradMpKernel::new),
    }
}

// ------------------------------------------------------------ sharded pool

/// Outcome of a [`ShardedPool`] run: one [`JobOutcome`] per shard plus the
/// canonical winner and the number of exchange rounds executed.
#[derive(Clone, Debug)]
pub struct ShardedOutcome {
    /// Per-shard outcomes, indexed by shard id.
    pub shards: Vec<JobOutcome>,
    /// First shard (by local iterations, ties to the lower id) to meet the
    /// tolerance — a schedule-independent choice, unlike the real-thread
    /// runtime's wall-clock race winner.
    pub winner: Option<usize>,
    /// Barrier-synchronized exchange rounds executed (0 for one shard,
    /// which never exchanges).
    pub rounds: u64,
    /// Wallclock for the whole run.
    pub wall: Duration,
}

impl ShardedOutcome {
    /// Did any shard meet the tolerance?
    pub fn converged(&self) -> bool {
        self.winner.is_some()
    }

    /// The winning shard's outcome, when one converged.
    pub fn winning(&self) -> Option<&JobOutcome> {
        self.winner.map(|k| &self.shards[k])
    }
}

/// Real-thread sharded-tally recovery: `S` shards, each a scoped OS thread
/// owning a contiguous slice of the measurement blocks (via
/// [`crate::algorithms::ShardedKernel`]) and a **local** tally, running
/// the identical `WorkerDriver` loop body as the single-tally runtimes in
/// `E`-iteration segments between barrier-synchronized support exchanges
/// on a [`crate::tally::ExchangeBoard`].
///
/// Determinism: shard `k`'s RNG derives from the master seed and `k` only
/// (`Rng::seed_from(seed).split(k)` — the `run_async_with` worker scheme);
/// no shard ever interrupts another (there is no early-stop flag), so each
/// shard's iteration sequence depends only on `(S, E, protocol, seed)`;
/// and every exchange merge is a commutative `i64` sum applied in
/// canonical shard order under the board's barriers. Results are therefore
/// **bit-identical** at any thread interleaving, and the winner is chosen
/// canonically (fewest local iterations, ties to the lower shard id)
/// rather than by wall-clock race. With one shard the run delegates to
/// [`solve_job_with`], so it is bit-for-bit the single-tally result.
pub struct ShardedPool {
    opts: ShardOpts,
}

impl ShardedPool {
    /// Validate and capture the sharding axes.
    pub fn new(opts: ShardOpts) -> Self {
        opts.validate().expect("invalid shard options");
        ShardedPool { opts }
    }

    /// The sharding axes this pool runs with.
    pub fn shard_opts(&self) -> &ShardOpts {
        &self.opts
    }

    /// [`ShardedPool::run_with`] dispatched over the config-level
    /// algorithm selector, matching [`solve_job`].
    pub fn run(&self, problem: &Problem, alg: Alg, opts: &AsyncOpts, seed: u64) -> ShardedOutcome {
        match alg {
            Alg::Stoiht => {
                self.run_with(problem, opts, seed, |p| StoihtKernel::new(p, opts.gamma))
            }
            Alg::StoGradMp => self.run_with(problem, opts, seed, StoGradMpKernel::new),
        }
    }

    /// Run one problem across the configured shards with a caller-built
    /// kernel per shard (`make_step` is invoked once on each shard's own
    /// thread, exactly like `run_async_with`'s per-worker factories).
    pub fn run_with<'p, K, F>(
        &self,
        problem: &'p Problem,
        opts: &AsyncOpts,
        seed: u64,
        make_step: F,
    ) -> ShardedOutcome
    where
        K: SupportKernel + 'p,
        F: Fn(&'p Problem) -> K + Sync,
    {
        let sh = &self.opts;
        let shards = sh.shards;
        if shards == 1 {
            // The unsharded path IS the single-tally job — same RNG
            // derivation, same loop body — so delegate for bit-identity.
            let start = Instant::now();
            let out = solve_job_with(problem, opts, seed, make_step);
            let winner = out.converged.then_some(0);
            return ShardedOutcome { shards: vec![out], winner, rounds: 0, wall: start.elapsed() };
        }
        let e = sh.exchange_period as u64;
        let periods = opts.schedule.periods(shards);
        let board = ExchangeBoard::new(shards, problem.spec.n);
        let slots: ResultSlots<(JobOutcome, u64)> = ResultSlots::new(shards);
        let start = Instant::now();
        thread::scope(|scope| {
            for k in 0..shards {
                let (board, slots) = (&board, &slots);
                let (make_step, periods) = (&make_step, &periods);
                scope.spawn(move || {
                    // The in-process board behind the same transport
                    // doorway the socket hub uses: `run_shard` is the
                    // pre-transport per-shard loop body verbatim, so the
                    // pool stays bit-identical across the refactor (and
                    // to a multi-process fleet at the same axes).
                    let mut transport = transport::BoardTransport::new(board, k);
                    let run = transport::run_shard(
                        problem,
                        &mut transport,
                        k,
                        sh.protocol,
                        e,
                        opts,
                        periods[k],
                        seed,
                        |p| make_step(p),
                    )
                    .expect("the in-process exchange cannot fail");
                    // Slot protocol: shard k is slot k's only writer; the
                    // scope join below is the publication edge.
                    slots.put(k, (run.outcome, run.rounds));
                });
            }
        });
        let mut rounds = 0u64;
        let outs: Vec<JobOutcome> = (0..shards)
            .map(|i| {
                let (o, r) = slots.take(i).expect("shard produced no result");
                rounds = r;
                o
            })
            .collect();
        let winner = outs
            .iter()
            .enumerate()
            .filter(|(_, o)| o.converged)
            .min_by_key(|&(i, o)| (o.iters, i))
            .map(|(i, _)| i);
        ShardedOutcome { shards: outs, winner, rounds, wall: start.elapsed() }
    }
}

// ---------------------------------------------------------- batched (MMV)

/// Outcome of one lockstep batched recovery.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Per-signal outcomes (`iters` = the lockstep time step the signal
    /// exited at; `wall` = the whole batch's wallclock, shared).
    pub signals: Vec<JobOutcome>,
    /// Lockstep time steps executed (max over signals).
    pub steps: u64,
    /// Wallclock for the whole batch.
    pub wall: Duration,
}

impl BatchOutcome {
    /// Did every signal meet the tolerance?
    pub fn all_converged(&self) -> bool {
        self.signals.iter().all(|s| s.converged)
    }
}

/// Lockstep batched StoIHT over `B` signals sharing one operator.
///
/// Each time step samples **one** measurement block and performs **one**
/// multi-RHS fused proxy call for all still-active signals; each signal
/// then identifies its own `Γ` (per-column arithmetic bit-identical to
/// `StoihtKernel::step_sparse`, given the same iterate/estimate/block) and
/// votes it into a tally **shared across the batch**, whose `supp_s`
/// estimate feeds every signal's next estimate phase — Algorithm 2 with
/// signals in the role of cores. Converged signals drop out of the
/// lockstep (their standing votes remain: for MMV batches they are
/// correct information about the shared support).
pub fn recover_batch_stoiht(problems: &[Problem], opts: &AsyncOpts, seed: u64) -> BatchOutcome {
    assert!(!problems.is_empty(), "recover_batch_stoiht: empty batch");
    let base = &problems[0];
    let spec = &base.spec;
    for p in problems {
        assert!(
            p.shares_operator_with(base),
            "recover_batch_stoiht: all signals must share one operator (Arc)"
        );
        assert_eq!(p.spec.n, spec.n, "batch dimension mismatch");
        assert_eq!(p.spec.m, spec.m, "batch measurement-count mismatch");
        assert_eq!(p.spec.b, spec.b, "batch block size mismatch");
        assert_eq!(p.spec.s, spec.s, "batch sparsity mismatch");
    }
    let batch = problems.len();
    let mb = spec.num_blocks();
    // Exactly StoihtKernel::with_probs' uniform alpha, so per-column bits
    // match a solo kernel's step.
    let probs = vec![1.0 / mb as f64; mb];
    let alpha = opts.gamma / (mb as f64 * probs[0]);
    let mut seed_root = Rng::seed_from(seed);
    let mut rng = seed_root.split(0);
    let start = Instant::now();

    // Per-signal state (parallel vectors so the lockstep borrow splits).
    let mut xs: Vec<SparseIterate<f64>> =
        (0..batch).map(|_| SparseIterate::zeros(spec.n)).collect();
    let mut outs: Vec<Vec<f64>> = vec![vec![0.0; spec.n]; batch];
    let mut resids: Vec<Vec<f64>> = vec![vec![0.0; spec.b]; batch];
    let mut prevs: Vec<Vec<usize>> = vec![Vec::new(); batch];
    let mut done: Vec<bool> = vec![false; batch];
    let mut iters: Vec<u64> = vec![0; batch];
    let mut residuals: Vec<f64> = vec![f64::NAN; batch];
    // Shared state + scratch.
    let mut tally = LocalTally::new(spec.n, opts.weighting);
    let mut op_scratch = base.op.make_scratch();
    let mut estimate: Vec<usize> = Vec::new();
    let mut idx_scratch: Vec<usize> = Vec::new();
    let mut gamma_set: Vec<usize> = vec![0; spec.s.min(spec.n)];
    let mut union_scratch: Vec<usize> = Vec::new();
    let mut r_scratch: Vec<f64> = Vec::new();
    let mut active_idx: Vec<usize> = Vec::with_capacity(batch);
    let mut steps = 0u64;

    for t in 1..=opts.max_local_iters as u64 {
        if done.iter().all(|&d| d) {
            break;
        }
        steps = t;
        // read: the shared estimate T̃ = supp_s(φ).
        positive_top_s_into(tally.votes(), spec.s, &mut estimate);
        let block = rng.categorical(&probs);
        let row0 = block * spec.b;
        // One fused multi-RHS proxy over the active columns.
        active_idx.clear();
        {
            let mut cols: Vec<ProxyCol<'_>> = Vec::with_capacity(batch);
            for (((c, out), resid), x) in
                outs.iter_mut().enumerate().zip(resids.iter_mut()).zip(xs.iter())
            {
                if done[c] {
                    continue;
                }
                active_idx.push(c);
                cols.push(ProxyCol {
                    y_b: problems[c].y_block(block),
                    x: x.values(),
                    support: x.support(),
                    resid: &mut resid[..],
                    out: &mut out[..],
                });
            }
            base.op.block_proxy_step_sparse_multi(row0, &mut cols, alpha, &mut op_scratch);
        }
        // Per-signal identify / estimate / vote.
        for &c in &active_idx {
            top_s_into(&outs[c], spec.s, &mut idx_scratch, &mut gamma_set);
            if estimate.is_empty() {
                xs[c].assign_from(&outs[c], &gamma_set);
            } else {
                union_into(&gamma_set, &estimate, &mut union_scratch);
                xs[c].assign_from(&outs[c], &union_scratch);
            }
            tally.commit(&gamma_set, &prevs[c], t);
            prevs[c].clear();
            prevs[c].extend_from_slice(&gamma_set);
            iters[c] = t;
        }
        // Exit checks (per signal, same halting statistic as the solo run).
        if t as usize % opts.check_every == 0 {
            for &c in &active_idx {
                let r = problems[c].residual_norm_sparse_with(
                    xs[c].values(),
                    xs[c].support(),
                    &mut r_scratch,
                    &mut op_scratch,
                );
                if r < opts.tolerance {
                    done[c] = true;
                    residuals[c] = r;
                }
            }
        }
    }
    let wall = start.elapsed();
    let signals = (0..batch)
        .map(|c| {
            let residual = if done[c] {
                residuals[c]
            } else {
                problems[c].residual_norm(xs[c].values())
            };
            JobOutcome {
                converged: done[c],
                iters: iters[c],
                residual,
                final_error: problems[c].recovery_error(xs[c].values()),
                x: xs[c].to_dense(),
                wall,
            }
        })
        .collect();
    BatchOutcome { signals, steps, wall }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::async_runtime::{run_async, run_async_with};
    use crate::problem::ProblemSpec;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn pool_runs_jobs_in_order_and_reuses_threads() {
        let pool = RecoveryPool::new(3);
        assert_eq!(pool.workers(), 3);
        for round in 0..4u64 {
            let out = pool.run_jobs(10, round, move |i, _rng| i * 2 + round as usize);
            assert_eq!(out, (0..10).map(|i| i * 2 + round as usize).collect::<Vec<_>>());
        }
    }

    #[test]
    fn pool_matches_run_trials_bitwise() {
        // The pool's RNG splitting is the run_trials scheme exactly.
        let pool = RecoveryPool::new(4);
        let from_pool: Vec<u64> = pool.run_jobs(12, 99, |_i, rng| rng.next_u64());
        let from_trials: Vec<u64> =
            crate::coordinator::run_trials(12, 5, 99, |_i, rng| rng.next_u64());
        assert_eq!(from_pool, from_trials);
    }

    #[test]
    fn pool_zero_and_one_job_edges() {
        let pool = RecoveryPool::new(2);
        let none: Vec<u32> = pool.run_jobs(0, 1, |_, _| 7);
        assert!(none.is_empty());
        let one: Vec<u32> = pool.run_jobs(1, 1, |i, _| i as u32 + 41);
        assert_eq!(one, vec![41]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "full solve loop is too slow under Miri")]
    fn solve_job_converges_and_is_sparse() {
        let p = easy(1);
        let out = solve_job(&p, Alg::Stoiht, &AsyncOpts::default(), 42);
        assert!(out.converged);
        assert!(out.residual < 1e-7);
        assert!(out.final_error < 1e-5);
        assert!(out.iters > 0);
        assert!(p.residual_norm(&out.x) < 1e-6);
    }

    #[test]
    #[cfg_attr(miri, ignore = "full solve loop is too slow under Miri")]
    fn solve_job_reports_honest_nonconvergence() {
        let p = easy(2);
        let opts = AsyncOpts { max_local_iters: 2, ..Default::default() };
        let out = solve_job(&p, Alg::Stoiht, &opts, 7);
        assert!(!out.converged);
        assert_eq!(out.iters, 2);
        // Unlike the runtime's NaN, the service reports the actual state.
        assert!(out.residual.is_finite() && out.residual > 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "full batched solve is too slow under Miri")]
    fn batch_recovers_mmv_signals() {
        let spec = ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() };
        let mut rng = Rng::seed_from(5);
        let op = spec.draw_operator(&mut rng);
        let batch = spec.generate_mmv_with_op(&op, &mut rng, 4);
        let out = recover_batch_stoiht(&batch, &AsyncOpts::default(), 31);
        let iters: Vec<u64> = out.signals.iter().map(|s| s.iters).collect();
        assert!(out.all_converged(), "iters {iters:?}");
        for (p, s) in batch.iter().zip(&out.signals) {
            assert!(s.residual < 1e-7);
            assert!(p.residual_norm(&s.x) < 1e-6);
            assert!(p.recovery_error(&s.x) < 1e-5);
        }
        assert!(out.steps >= out.signals.iter().map(|s| s.iters).max().unwrap());
    }

    #[test]
    #[cfg_attr(miri, ignore = "full batched solve is too slow under Miri")]
    fn batch_of_one_converges() {
        let spec = ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() };
        let mut rng = Rng::seed_from(6);
        let op = spec.draw_operator(&mut rng);
        let batch = spec.generate_mmv_with_op(&op, &mut rng, 1);
        let out = recover_batch_stoiht(&batch, &AsyncOpts::default(), 32);
        assert!(out.all_converged());
    }

    #[test]
    #[should_panic(expected = "share one operator")]
    fn batch_rejects_foreign_operators() {
        let a = easy(7);
        let b = easy(8);
        let _ = recover_batch_stoiht(
            &[a, b],
            &AsyncOpts { max_local_iters: 1, ..Default::default() },
            1,
        );
    }

    #[test]
    fn pool_survives_a_panicking_job_batch() {
        let pool = RecoveryPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_jobs(4, 1, |i, _rng| {
                if i == 2 {
                    panic!("boom");
                }
                i
            })
        }));
        let err = result.expect_err("submitter must observe the job panic");
        // The ORIGINAL payload is re-raised, not a generic wrapper.
        assert_eq!(err.downcast_ref::<&str>().copied(), Some("boom"));
        // The pool still serves subsequent batches.
        let ok: Vec<usize> = pool.run_jobs(3, 2, |i, _| i + 1);
        assert_eq!(ok, vec![1, 2, 3]);
    }

    #[test]
    fn try_run_jobs_isolates_a_mid_batch_panic() {
        // Satellite contract: a panicking job mid-window poisons ONLY its
        // own slot; every other job's result comes back intact and the
        // submitter never unwinds.
        let pool = RecoveryPool::new(2);
        let results = pool.try_run_jobs(5, 3, |i, _rng| {
            if i == 2 {
                panic!("hostile request");
            }
            i * 10
        });
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            if i == 2 {
                assert_eq!(r.as_ref().unwrap_err(), &api::ServeError::WorkerPanic);
            } else {
                assert_eq!(r.as_ref().unwrap(), &(i * 10));
            }
        }
        // The pool keeps serving — both the panic-isolated and the
        // re-raising entry points — after the poisoned window retires.
        let ok = pool.try_run_jobs(2, 4, |i, _| i + 7);
        assert_eq!(ok.into_iter().map(Result::unwrap).collect::<Vec<_>>(), vec![7, 8]);
        let plain: Vec<usize> = pool.run_jobs(2, 5, |i, _| i);
        assert_eq!(plain, vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "invalid shard options")]
    fn sharded_pool_rejects_zero_shards() {
        let _ = ShardedPool::new(ShardOpts { shards: 0, ..Default::default() });
    }

    #[test]
    #[cfg_attr(miri, ignore = "full solve loop is too slow under Miri")]
    fn sharded_pool_single_shard_matches_the_async_runtime_bitwise() {
        // Acceptance pin: S = 1 sharded output is bit-identical to the
        // single-tally path for BOTH kernels, at any exchange period.
        let p = easy(11);
        let opts = AsyncOpts::default();
        for e in [1usize, 16] {
            let so = ShardOpts { shards: 1, exchange_period: e, ..Default::default() };
            let pool = ShardedPool::new(so);
            for alg in [Alg::Stoiht, Alg::StoGradMp] {
                let sharded = pool.run(&p, alg, &opts, 42);
                let solo = match alg {
                    Alg::Stoiht => run_async(&p, 1, &opts, 42),
                    Alg::StoGradMp => run_async_with(&p, 1, &opts, 42, StoGradMpKernel::new),
                };
                assert!(solo.converged && sharded.converged(), "{alg:?} E={e}");
                assert_eq!(sharded.rounds, 0);
                let w = sharded.winning().unwrap();
                assert_eq!(w.iters, solo.local_iters[0]);
                assert_eq!(w.residual.to_bits(), solo.residual.to_bits());
                assert_eq!(w.final_error.to_bits(), solo.final_error.to_bits());
                assert_eq!(w.x.len(), solo.x.len());
                for (a, b) in w.x.iter().zip(&solo.x) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full solve loop is too slow under Miri")]
    fn sharded_pool_runs_are_deterministic_and_converge() {
        // Same (S, E, protocol, seed) => bitwise-identical results no
        // matter how the OS interleaves the shard threads.
        let p = easy(12);
        let opts = AsyncOpts::default();
        for protocol in [ExchangeProtocol::Gossip, ExchangeProtocol::LeaderMerge] {
            for shards in [2usize, 4] {
                let pool = ShardedPool::new(ShardOpts { shards, exchange_period: 4, protocol });
                let a = pool.run(&p, Alg::Stoiht, &opts, 7);
                let b = pool.run(&p, Alg::Stoiht, &opts, 7);
                assert!(a.converged(), "{protocol:?} S={shards}");
                assert!(a.winning().unwrap().final_error < 1e-5);
                assert!(a.rounds >= 1);
                assert_eq!(a.winner, b.winner);
                assert_eq!(a.rounds, b.rounds);
                for (sa, sb) in a.shards.iter().zip(&b.shards) {
                    assert_eq!(sa.converged, sb.converged);
                    assert_eq!(sa.iters, sb.iters);
                    assert_eq!(sa.residual.to_bits(), sb.residual.to_bits());
                    assert_eq!(sa.final_error.to_bits(), sb.final_error.to_bits());
                    for (u, v) in sa.x.iter().zip(&sb.x) {
                        assert_eq!(u.to_bits(), v.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full solve loop is too slow under Miri")]
    fn sharded_pool_handles_stogradmp() {
        let p = easy(13);
        let opts = AsyncOpts::default();
        let so = ShardOpts { shards: 2, exchange_period: 8, ..Default::default() };
        let out = ShardedPool::new(so).run(&p, Alg::StoGradMp, &opts, 3);
        assert!(out.converged());
        assert!(out.rounds >= 1);
        assert!(out.winning().unwrap().final_error < 1e-5);
    }

    #[test]
    #[cfg_attr(miri, ignore = "full solve loop is too slow under Miri")]
    fn try_run_jobs_matches_run_jobs_bitwise_on_clean_batches() {
        // Panic isolation must not perturb results: same closure, same
        // master seed => identical outputs job for job.
        let pool = RecoveryPool::new(3);
        let p = Arc::new(easy(9));
        let q = Arc::clone(&p);
        let direct: Vec<Vec<f64>> = pool.run_jobs(3, 17, move |i, _| {
            solve_job(&p, Alg::Stoiht, &AsyncOpts::default(), i as u64).x
        });
        let guarded = pool.try_run_jobs(3, 17, move |i, _| {
            solve_job(&q, Alg::Stoiht, &AsyncOpts::default(), i as u64).x
        });
        for (a, b) in direct.iter().zip(guarded) {
            let b = b.unwrap();
            assert_eq!(a.len(), b.len());
            for (u, v) in a.iter().zip(&b) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }
}
