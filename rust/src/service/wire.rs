//! Wire protocol for `astir serve`: length-prefixed JSON frames carrying
//! the versioned [`super::api`] types, plus the blocking client used by
//! the end-to-end tests and the `loadgen` bench suite.
//!
//! ## Framing
//!
//! Each message is one frame: a 4-byte **big-endian** payload length
//! followed by that many bytes of UTF-8 JSON. Frames above
//! [`MAX_FRAME_LEN`] are rejected before allocation (a malformed or
//! hostile length prefix must not OOM the server). A connection carries
//! any number of frames; requests on one connection are answered in
//! order.
//!
//! ## Envelope
//!
//! Every frame is a JSON object with an `api_version` field (see
//! [`super::api`] for the v1 compatibility rule). Requests:
//!
//! ```json
//! {"api_version":1,"job":{"ensemble":"gaussian","n":128,"m":64,"b":8,"s":4,"seed":7}}
//! {"api_version":1,"jobs":[{…},{…}]}
//! {"api_version":1,"stats":true}
//! ```
//!
//! Replies: `{"api_version":1,"ok":{…}}` (one [`JobResponse`]),
//! `{"api_version":1,"error":{"code":…,"message":…}}`, per-job
//! `{"api_version":1,"batch":[{"ok":…}|{"error":…},…]}`, and
//! `{"api_version":1,"stats":{…}}`.
//!
//! The exchange-hub protocol ([`super::transport`]) rides the same
//! framing and envelope, with frame bodies under `join` / `publish` /
//! `leave` (worker → hub) and `joined` / `view` / `error` (hub →
//! worker) — see [`HubRequest`] / [`HubReply`].
//!
//! This module is the crate's **only** home for `std::net` outside
//! [`super::server`] — lint rule `net-doorway` (L5) confines raw socket
//! use to `src/service/`, so tests and benches drive the server through
//! [`Client`] rather than opening sockets themselves.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::bench_harness::json::Json;
use crate::service::api::{
    malformed, BatchRequest, ExchangeJoin, ExchangeJoined, ExchangeLeave, ExchangePublish,
    ExchangeView, JobRequest, JobResponse, ServeError, StatsSnapshot, API_VERSION,
};

/// Hard cap on a frame payload (64 MiB — a million-dimension iterate in
/// shortest-round-trip text fits comfortably).
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Write one `length ++ payload` frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &str) -> std::io::Result<()> {
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME_LEN", bytes.len()),
        ));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

/// Read one frame. `Ok(None)` on clean EOF **at a frame boundary** (the
/// peer hung up between requests); an EOF inside a frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Option<String>> {
    let mut len_buf = [0u8; 4];
    match r.read(&mut len_buf) {
        Ok(0) => return Ok(None),
        Ok(k) => r.read_exact(&mut len_buf[k..])?,
        Err(e) => return Err(e),
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME_LEN"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    String::from_utf8(payload)
        .map(Some)
        .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "frame is not UTF-8"))
}

// ----------------------------------------------------------- envelopes

/// A decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Job(JobRequest),
    Batch(BatchRequest),
    Stats,
}

impl Request {
    /// Serialize with the `api_version` envelope.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{{\"api_version\":{API_VERSION},");
        match self {
            Request::Job(job) => {
                out.push_str("\"job\":");
                job.write_json(&mut out);
            }
            Request::Batch(batch) => {
                out.push_str("\"jobs\":");
                batch.write_json(&mut out);
            }
            Request::Stats => out.push_str("\"stats\":true"),
        }
        out.push('}');
        out
    }

    /// Parse a request frame, enforcing the version handshake.
    pub fn parse(text: &str) -> Result<Request, ServeError> {
        let j = Json::parse(text).map_err(malformed)?;
        check_version(&j)?;
        if let Some(job) = j.get("job") {
            return Ok(Request::Job(JobRequest::from_json(job)?));
        }
        if let Some(jobs) = j.get("jobs") {
            return Ok(Request::Batch(BatchRequest::from_json(jobs)?));
        }
        if j.get("stats").and_then(Json::as_bool) == Some(true) {
            return Ok(Request::Stats);
        }
        Err(malformed("request carries none of `job`, `jobs`, `stats`"))
    }
}

/// A decoded reply frame.
#[derive(Clone, Debug)]
pub enum Reply {
    /// One job's result (or its typed failure).
    Job(Result<JobResponse, ServeError>),
    /// Per-job results of a `jobs` frame, in submission order.
    Batch(Vec<Result<JobResponse, ServeError>>),
    Stats(StatsSnapshot),
}

impl Reply {
    /// Serialize with the `api_version` envelope.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{{\"api_version\":{API_VERSION},");
        match self {
            Reply::Job(result) => write_result(&mut out, result),
            Reply::Batch(results) => {
                out.push_str("\"batch\":[");
                for (i, r) in results.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('{');
                    write_result(&mut out, r);
                    out.push('}');
                }
                out.push(']');
            }
            Reply::Stats(stats) => {
                out.push_str("\"stats\":");
                stats.write_json(&mut out);
            }
        }
        out.push('}');
        out
    }

    /// Parse a reply frame, enforcing the version handshake.
    pub fn parse(text: &str) -> Result<Reply, ServeError> {
        let j = Json::parse(text).map_err(malformed)?;
        check_version(&j)?;
        if let Some(b) = j.get("batch") {
            let arr = b.as_arr().ok_or_else(|| malformed("`batch` must be an array"))?;
            let results = arr.iter().map(parse_result).collect::<Result<Vec<_>, _>>()?;
            return Ok(Reply::Batch(results));
        }
        if let Some(s) = j.get("stats") {
            return Ok(Reply::Stats(StatsSnapshot::from_json(s)?));
        }
        Ok(Reply::Job(parse_result(&j)?))
    }
}

fn write_result(out: &mut String, r: &Result<JobResponse, ServeError>) {
    match r {
        Ok(resp) => {
            out.push_str("\"ok\":");
            resp.write_json(out);
        }
        Err(e) => {
            out.push_str("\"error\":");
            e.write_json(out);
        }
    }
}

fn parse_result(j: &Json) -> Result<Result<JobResponse, ServeError>, ServeError> {
    if let Some(ok) = j.get("ok") {
        return Ok(Ok(JobResponse::from_json(ok)?));
    }
    if let Some(err) = j.get("error") {
        return Ok(Err(ServeError::from_json(err)?));
    }
    Err(malformed("reply carries neither `ok` nor `error`"))
}

// ------------------------------------------------------- hub envelopes

/// A decoded exchange-hub request frame (shard worker → hub). Same
/// framing and `api_version` envelope as the serve protocol, with the
/// frame body under `join` / `publish` / `leave`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HubRequest {
    Join(ExchangeJoin),
    Publish(ExchangePublish),
    Leave(ExchangeLeave),
}

impl HubRequest {
    /// Serialize with the `api_version` envelope.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{{\"api_version\":{API_VERSION},");
        match self {
            HubRequest::Join(f) => {
                out.push_str("\"join\":");
                f.write_json(&mut out);
            }
            HubRequest::Publish(f) => {
                out.push_str("\"publish\":");
                f.write_json(&mut out);
            }
            HubRequest::Leave(f) => {
                out.push_str("\"leave\":");
                f.write_json(&mut out);
            }
        }
        out.push('}');
        out
    }

    /// Parse a hub request frame, enforcing the version handshake.
    pub fn parse(text: &str) -> Result<HubRequest, ServeError> {
        let j = Json::parse(text).map_err(malformed)?;
        check_version(&j)?;
        if let Some(f) = j.get("join") {
            return Ok(HubRequest::Join(ExchangeJoin::from_json(f)?));
        }
        if let Some(f) = j.get("publish") {
            return Ok(HubRequest::Publish(ExchangePublish::from_json(f)?));
        }
        if let Some(f) = j.get("leave") {
            return Ok(HubRequest::Leave(ExchangeLeave::from_json(f)?));
        }
        Err(malformed("request carries none of `join`, `publish`, `leave`"))
    }
}

/// A decoded exchange-hub reply frame (hub → shard worker).
#[derive(Clone, Debug, PartialEq)]
pub enum HubReply {
    /// The fleet assembled; rounds may begin.
    Joined(ExchangeJoined),
    /// A completed round's merged view.
    View(ExchangeView),
    /// Typed rejection (version/shape mismatch, bad shard id, …).
    Error(ServeError),
}

impl HubReply {
    /// Serialize with the `api_version` envelope.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{{\"api_version\":{API_VERSION},");
        match self {
            HubReply::Joined(f) => {
                out.push_str("\"joined\":");
                f.write_json(&mut out);
            }
            HubReply::View(f) => {
                out.push_str("\"view\":");
                f.write_json(&mut out);
            }
            HubReply::Error(e) => {
                out.push_str("\"error\":");
                e.write_json(&mut out);
            }
        }
        out.push('}');
        out
    }

    /// Parse a hub reply frame, enforcing the version handshake.
    pub fn parse(text: &str) -> Result<HubReply, ServeError> {
        let j = Json::parse(text).map_err(malformed)?;
        check_version(&j)?;
        if let Some(f) = j.get("joined") {
            return Ok(HubReply::Joined(ExchangeJoined::from_json(f)?));
        }
        if let Some(f) = j.get("view") {
            return Ok(HubReply::View(ExchangeView::from_json(f)?));
        }
        if let Some(e) = j.get("error") {
            return Ok(HubReply::Error(ServeError::from_json(e)?));
        }
        Err(malformed("reply carries none of `joined`, `view`, `error`"))
    }
}

fn check_version(j: &Json) -> Result<(), ServeError> {
    let v = super::api::req_u64(j, "api_version")?;
    if v != API_VERSION {
        return Err(ServeError::UnsupportedVersion(v));
    }
    Ok(())
}

// -------------------------------------------------------------- client

/// Blocking client for one `astir serve` connection. Sends one frame per
/// call and waits for the matching reply. The transport layer
/// (`crate::Result`) is separate from the service layer (the inner
/// `Result<_, ServeError>`): an `Err` outer means the connection broke,
/// an `Err` inner means the server answered with a typed rejection.
pub struct Client {
    stream: TcpStream,
}

/// Default bound on [`Client::connect`]: generous for a loaded CI loopback,
/// finite for a blackholed address (the unbounded `TcpStream::connect` used
/// to hang the `loadgen` suite and CLI clients forever).
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Default read deadline installed by [`Client::connect`]: a connected
/// server that stalls mid-reply (wedged worker, half-dead peer) used to
/// block the client in `read_frame` forever — the receiving-side hole the
/// connect/write bounds left open. Generous against real solve times
/// (heaviest served jobs finish in seconds); override per call with
/// [`Client::set_read_timeout`].
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Bounded connect shared by [`Client`] and the exchange transport: each
/// resolved candidate address is tried in turn; the last failure is
/// reported if none accepts.
pub(crate) fn connect_stream(addr: &str, timeout: Duration) -> std::io::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let mut last_err = None;
    for candidate in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&candidate, timeout) {
            Ok(stream) => return Ok(stream),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            "address resolved to no socket addresses",
        )
    }))
}

impl Client {
    /// Connect to a server address (`host:port`), bounded by
    /// [`DEFAULT_CONNECT_TIMEOUT`] with [`DEFAULT_READ_TIMEOUT`]
    /// installed. Use [`Client::connect_with_timeout`] to pick the
    /// connect bound.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        Self::connect_with_timeout(addr, DEFAULT_CONNECT_TIMEOUT)
    }

    /// Connect with an explicit bound. The returned client carries the
    /// [`DEFAULT_READ_TIMEOUT`] read deadline so a stalled server
    /// surfaces as an error instead of a hang.
    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> std::io::Result<Client> {
        let stream = connect_stream(addr, timeout)?;
        stream.set_read_timeout(Some(DEFAULT_READ_TIMEOUT))?;
        Ok(Client { stream })
    }

    /// Optional read timeout (tests use this to bound a hang).
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }

    /// Optional write timeout, the sending-side twin of
    /// [`Client::set_read_timeout`] (a peer that stops draining must not
    /// wedge the client in `write_frame`).
    pub fn set_write_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_write_timeout(timeout)
    }

    fn round_trip(&mut self, request: &Request) -> crate::Result<Reply> {
        write_frame(&mut self.stream, &request.to_json())?;
        let text = read_frame(&mut self.stream)?
            .ok_or_else(|| crate::err!("server closed the connection before replying"))?;
        Reply::parse(&text).map_err(|e| crate::err!("bad reply frame: {e}"))
    }

    /// Submit one job.
    pub fn job(&mut self, req: &JobRequest) -> crate::Result<Result<JobResponse, ServeError>> {
        match self.round_trip(&Request::Job(req.clone()))? {
            Reply::Job(result) => Ok(result),
            other => Err(crate::err!("expected a job reply, got {other:?}")),
        }
    }

    /// Submit a batch; per-job results come back in submission order.
    pub fn batch(
        &mut self,
        req: &BatchRequest,
    ) -> crate::Result<Vec<Result<JobResponse, ServeError>>> {
        match self.round_trip(&Request::Batch(req.clone()))? {
            Reply::Batch(results) => Ok(results),
            Reply::Job(Err(e)) => Err(crate::err!("batch rejected: {e}")),
            other => Err(crate::err!("expected a batch reply, got {other:?}")),
        }
    }

    /// Query server counters and latency percentiles.
    pub fn stats(&mut self) -> crate::Result<StatsSnapshot> {
        match self.round_trip(&Request::Stats)? {
            Reply::Stats(stats) => Ok(stats),
            other => Err(crate::err!("expected a stats reply, got {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::Ensemble;

    fn job(seed: u64) -> JobRequest {
        JobRequest { ensemble: Ensemble::Gaussian, n: 64, m: 32, b: 4, s: 3, seed, y: None }
    }

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "hello µ-batch").unwrap();
        write_frame(&mut buf, "").unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap().as_deref(), Some("hello µ-batch"));
        assert_eq!(read_frame(&mut cursor).unwrap().as_deref(), Some(""));
        assert_eq!(read_frame(&mut cursor).unwrap(), None, "clean EOF at boundary");
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "full frame").unwrap();
        buf.truncate(buf.len() - 3);
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).unwrap_err().kind() == std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn hostile_length_prefix_is_rejected_before_allocation() {
        let mut buf = (u32::MAX).to_be_bytes().to_vec();
        buf.extend_from_slice(b"x");
        let mut cursor = std::io::Cursor::new(buf);
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn request_envelopes_roundtrip() {
        for req in [
            Request::Job(job(1)),
            Request::Batch(BatchRequest { jobs: vec![job(1), job(2)] }),
            Request::Stats,
        ] {
            let parsed = Request::parse(&req.to_json()).unwrap();
            assert_eq!(parsed, req);
        }
    }

    #[test]
    fn reply_envelopes_roundtrip() {
        let resp = JobResponse {
            converged: true,
            iters: 9,
            residual: 1e-9,
            final_error: Some(2e-8),
            x: vec![0.5, -0.25],
            wall_s: 0.001,
        };
        let ok = Reply::Job(Ok(resp.clone()));
        let Reply::Job(Ok(parsed)) = Reply::parse(&ok.to_json()).unwrap() else {
            panic!("expected ok job reply");
        };
        assert_eq!(parsed, resp);

        let err = Reply::Job(Err(ServeError::Busy));
        let Reply::Job(Err(parsed)) = Reply::parse(&err.to_json()).unwrap() else {
            panic!("expected error job reply");
        };
        assert_eq!(parsed, ServeError::Busy);

        let batch = Reply::Batch(vec![Ok(resp.clone()), Err(ServeError::WorkerPanic)]);
        let Reply::Batch(results) = Reply::parse(&batch.to_json()).unwrap() else {
            panic!("expected batch reply");
        };
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].as_ref().unwrap(), &resp);
        assert_eq!(results[1].as_ref().unwrap_err(), &ServeError::WorkerPanic);

        let stats = Reply::Stats(StatsSnapshot {
            served: 3,
            rejected: 0,
            cache_hits: 2,
            cache_misses: 1,
            inflight: 0,
            p50_s: 0.001,
            p90_s: 0.002,
            p99_s: 0.003,
        });
        let Reply::Stats(parsed) = Reply::parse(&stats.to_json()).unwrap() else {
            panic!("expected stats reply");
        };
        assert_eq!(parsed.served, 3);
        assert_eq!(parsed.cache_hits, 2);
    }

    #[test]
    fn version_handshake_rejects_unknown_versions() {
        let future = r#"{"api_version":2,"stats":true}"#;
        assert_eq!(Request::parse(future), Err(ServeError::UnsupportedVersion(2)));
        let missing = r#"{"stats":true}"#;
        assert!(matches!(Request::parse(missing), Err(ServeError::Malformed(_))));
        assert!(matches!(
            Reply::parse(r#"{"api_version":3,"ok":{}}"#),
            Err(ServeError::UnsupportedVersion(3))
        ));
    }

    #[test]
    fn connect_timeout_bounds_an_unroutable_address() {
        // 10.255.255.1 is a blackhole on any sane CI network: packets are
        // dropped, so the old unbounded connect would hang until the OS
        // gave up (minutes). With the bound the client must come back
        // quickly — either a timeout or an immediate network error, never
        // a hang. The generous elapsed ceiling keeps slow CI from flaking.
        let bound = Duration::from_millis(250);
        let start = std::time::Instant::now();
        let result = Client::connect_with_timeout("10.255.255.1:9", bound);
        assert!(result.is_err(), "blackholed address must not connect");
        assert!(
            start.elapsed() < Duration::from_secs(30),
            "connect_with_timeout took {:?}, bound was {bound:?}",
            start.elapsed()
        );
    }

    #[test]
    fn hub_envelopes_roundtrip() {
        use crate::service::api::{
            ExchangeJoin, ExchangeJoined, ExchangeLeave, ExchangePublish, ExchangeView,
        };
        for req in [
            HubRequest::Join(ExchangeJoin { shard: 0, shards: 4, n: 8, exchange_period: 16 }),
            HubRequest::Publish(ExchangePublish {
                shard: 3,
                round: 7,
                finished: false,
                votes: vec![1, -2, i64::MIN, i64::MAX, 1 << 53, -(1 << 53) - 1, 0, 9],
            }),
            HubRequest::Leave(ExchangeLeave { shard: 1 }),
        ] {
            assert_eq!(HubRequest::parse(&req.to_json()).unwrap(), req);
        }
        for reply in [
            HubReply::Joined(ExchangeJoined { shards: 4, round_timeout_ms: 2400 }),
            HubReply::View(ExchangeView {
                round: 7,
                finished_shards: 2,
                stale_peers: 1,
                merged: vec![i64::MIN, -1, 0, 1, i64::MAX],
            }),
            HubReply::Error(ServeError::Incompatible("n mismatch".to_string())),
        ] {
            assert_eq!(HubReply::parse(&reply.to_json()).unwrap(), reply);
        }
        // Version handshake applies to hub frames too.
        let future = r#"{"api_version":2,"leave":{"shard":0}}"#;
        assert_eq!(HubRequest::parse(future), Err(ServeError::UnsupportedVersion(2)));
    }

    #[test]
    fn default_read_timeout_bounds_a_stalled_server() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // Accept in the background and hold the connection open silently —
        // the stalled-hub regression the read deadline exists for.
        let hold = crate::sync::thread::spawn(move || listener.accept().map(|(s, _)| s));
        let mut client = Client::connect(&addr).unwrap();
        assert_eq!(
            client.stream.read_timeout().unwrap(),
            Some(DEFAULT_READ_TIMEOUT),
            "connect must install the default read deadline"
        );
        // Shrink the deadline so the check is fast; before the fix this
        // call blocked forever (no read timeout was ever set).
        client.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let start = std::time::Instant::now();
        assert!(client.stats().is_err(), "stalled server must error, not hang");
        assert!(
            start.elapsed() < Duration::from_secs(20),
            "read deadline did not bound the stall: {:?}",
            start.elapsed()
        );
        drop(hold.join());
    }

    #[test]
    fn unknown_request_shape_is_malformed() {
        let bad = r#"{"api_version":1,"frob":true}"#;
        assert!(matches!(Request::parse(bad), Err(ServeError::Malformed(_))));
        assert!(matches!(Request::parse("not json"), Err(ServeError::Malformed(_))));
    }
}
