//! Socket-backed exchange transport: the [`crate::tally::ExchangeBoard`]
//! rendezvous behind an [`ExchangeTransport`] trait, so `S` shard
//! **processes** — not just threads — run the unmodified
//! [`ShardedKernel`] loop and swap `i64` vote snapshots through a
//! lightweight exchange hub (`astir exchange-hub`).
//!
//! ## Architecture
//!
//! * [`ExchangeTransport`] — one exchange round abstracted over where the
//!   peers live: publish a snapshot, receive the round's **merged view**
//!   (which includes the caller's own snapshot — the gossip peer sum is
//!   `merged − own`, exact in `i64`), then release the round.
//! * [`BoardTransport`] — the in-process board as a transport.
//!   [`super::ShardedPool`] runs on this, and `run_shard` is a verbatim
//!   port of its PR 9 loop body, so the refactor is pinned bit-identical
//!   by the existing sharded test/bench tiers.
//! * [`ExchangeHub`] — a TCP rendezvous speaking the [`super::wire`]
//!   length-prefixed JSON framing with the versioned frame types of
//!   [`super::api`] (`join` / `publish` / `leave` requests, `joined` /
//!   `view` / `error` replies). One fleet per hub run: `S` workers join
//!   (the `joined` reply is the fleet-assembly barrier), publish once per
//!   round, and each receives the round's merged view.
//! * [`HubTransport`] — the worker-side client; [`run_worker`] wires it
//!   under `run_shard` for the `astir shard-worker` CLI.
//!
//! ## Determinism
//!
//! The merged view is a commutative exact `i64` sum of every shard's
//! latest snapshot, and the worker derives its gossip peer sum as
//! `merged − own` — bit-identical to the board's `peer_sum_into`. With
//! every peer healthy, a hub fleet at `(S, E, protocol, seed)` therefore
//! reproduces the in-process [`super::ShardedPool`] result **bit for
//! bit** (pinned in-crate below and end-to-end over real processes by
//! `rust/tests/distributed_e2e.rs`).
//!
//! ## Failure semantics (the `Degraded` path)
//!
//! The bounded-staleness math is exactly the slack a lossy fleet needs:
//! a shard that misses a round is not waited for forever. Per-peer
//! deadlines derive from the staleness bound `E` (base grace + time
//! proportional to the largest `E` in the fleet, unless pinned by
//! `--round-timeout-ms`): a worker that does not publish within the
//! deadline of its previous reply — or whose connection breaks — is
//! **retired**. Its last snapshot keeps being merged (stale), it counts
//! as finished so the fleet can still drain, and every subsequent
//! [`ExchangeView`] reports it in `stale_peers` so the survivors know
//! they are running degraded. Nothing ever blocks unboundedly: every hub
//! read and write carries a deadline, the worker bounds its reply reads
//! a margin above the hub's round deadline, and a round closes either by
//! its last publish or by the deadline of the straggler holding it open.
//!
//! Version or shape mismatches (wrong `api_version`, `S` or `n`
//! disagreement, duplicate shard ids, stale round numbers) are rejected
//! with typed [`ServeError`]s surfaced as [`TransportError::Rejected`] —
//! a misconfigured worker fails loudly instead of corrupting a merge.

use std::io;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU64};
use crate::sync::{thread, Arc, Condvar, Mutex};

use crate::algorithms::{Alg, ShardedKernel, StoGradMpKernel, StoihtKernel, SupportKernel};
use crate::async_runtime::{AsyncOpts, WorkerDriver};
use crate::linalg::SparseIterate;
use crate::problem::Problem;
use crate::rng::Rng;
use crate::service::api::{
    ExchangeJoin, ExchangeJoined, ExchangeLeave, ExchangePublish, ExchangeView, ServeError,
};
use crate::service::server::{lock_recover, wait_recover};
use crate::service::wire::{
    connect_stream, read_frame, write_frame, HubReply, HubRequest, DEFAULT_CONNECT_TIMEOUT,
};
use crate::service::JobOutcome;
use crate::sim::ShardOpts;
use crate::tally::{AtomicTally, ExchangeBoard, ExchangeProtocol};

/// Accept/session-start poll interval for the hub's main loop.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Socket write deadline on both ends (a peer that stops draining must
/// not wedge a round).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Worker-side bound on the `joined` reply — it doubles as the
/// fleet-assembly barrier, so it is bounded by the hub's join window
/// (default 30 s) rather than a round deadline.
const JOIN_REPLY_TIMEOUT: Duration = Duration::from_secs(60);

/// Worker-side slack above the hub's per-peer round deadline: the hub,
/// not the worker's own read, is the round's timekeeper.
const READ_MARGIN: Duration = Duration::from_secs(10);

/// Per-peer round deadline derived from the staleness bound: a base
/// grace plus an allowance proportional to the largest `E` in the fleet
/// (a shard computes `E` local steps between publishes).
fn derived_round_timeout(max_period: usize) -> Duration {
    Duration::from_millis(2_000 + 25 * max_period as u64)
}

// ------------------------------------------------------------ the trait

/// What a shard learns from one completed exchange round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundInfo {
    /// Shards done iterating (converged or at their cap) as latched at
    /// this round — identical in every shard, hence a deterministic
    /// fleet exit at `finished_shards == S`. Dead peers count as
    /// finished (they can never un-finish).
    pub finished_shards: usize,
    /// Peers that missed this round (dead or never joined) and were
    /// merged from their last snapshot — `> 0` means the fleet is
    /// degraded. Always `0` in-process.
    pub stale_peers: usize,
}

/// Errors a socket-backed exchange can surface. The in-process board
/// never fails.
#[derive(Debug)]
pub enum TransportError {
    /// Socket-level failure: connect, read, write, or a missed deadline.
    Io(io::Error),
    /// The peer spoke, but not the protocol we expect (undecodable
    /// frame, wrong round echo, wrong view dimensions).
    Protocol(String),
    /// The hub rejected this worker with a typed error (version/shape
    /// mismatch, duplicate shard id, closed join window).
    Rejected(ServeError),
    /// The hub hung up where a reply was expected.
    HubClosed,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "transport i/o: {e}"),
            TransportError::Protocol(m) => write!(f, "transport protocol: {m}"),
            TransportError::Rejected(e) => write!(f, "rejected by hub: {e}"),
            TransportError::HubClosed => write!(f, "hub closed the connection"),
        }
    }
}

impl From<io::Error> for TransportError {
    fn from(e: io::Error) -> TransportError {
        TransportError::Io(e)
    }
}

/// One round of the two-crossing exchange rendezvous, abstracted over
/// where the peers live (in-process [`ExchangeBoard`] or a TCP
/// [`ExchangeHub`]).
pub trait ExchangeTransport {
    /// Fleet size `S`.
    fn shards(&self) -> usize;

    /// First crossing: publish this shard's snapshot (plus its sticky
    /// `finished` flag), block until every live peer has published, and
    /// fill `merged_out` with the round's merged view. The view includes
    /// the caller's own snapshot: its peer sum is `merged − own`, exact
    /// in `i64`.
    fn exchange(
        &mut self,
        own: &[i64],
        finished: bool,
        merged_out: &mut Vec<i64>,
    ) -> Result<RoundInfo, TransportError>;

    /// Second crossing: release the round, so no shard can overwrite
    /// state a peer is still reading. A no-op over sockets — the hub
    /// snapshots each round's view into an immutable payload, so there
    /// is nothing a later publish could race with.
    fn complete_round(&mut self) -> Result<(), TransportError>;
}

// ---------------------------------------------------- in-process board

/// The in-process [`ExchangeBoard`] as a transport — PR 9's rendezvous
/// semantics verbatim, which is what pins [`super::ShardedPool`] (and
/// through it this refactor) bit-identical to the pre-transport loop.
pub struct BoardTransport<'a> {
    board: &'a ExchangeBoard,
    shard: usize,
}

impl<'a> BoardTransport<'a> {
    /// Wrap one shard's view of a shared board.
    pub fn new(board: &'a ExchangeBoard, shard: usize) -> BoardTransport<'a> {
        assert!(shard < board.shards(), "shard id out of range");
        BoardTransport { board, shard }
    }
}

impl ExchangeTransport for BoardTransport<'_> {
    fn shards(&self) -> usize {
        self.board.shards()
    }

    fn exchange(
        &mut self,
        own: &[i64],
        finished: bool,
        merged_out: &mut Vec<i64>,
    ) -> Result<RoundInfo, TransportError> {
        self.board.publish_and_wait(self.shard, own, finished);
        // Latched at the barrier above: identical in every shard this
        // round, hence a deterministic exit.
        let finished_shards = self.board.finished_count();
        self.board.merged_into(merged_out);
        Ok(RoundInfo { finished_shards, stale_peers: 0 })
    }

    fn complete_round(&mut self) -> Result<(), TransportError> {
        self.board.wait();
        Ok(())
    }
}

// ------------------------------------------------------ the shard loop

/// Result of one shard's run against a transport.
#[derive(Clone, Debug)]
pub struct ShardRun {
    /// The shard's recovery outcome (same shape as a pool job's).
    pub outcome: JobOutcome,
    /// Exchange rounds completed before the fleet drained (matches
    /// [`super::ShardedOutcome::rounds`]).
    pub rounds: u64,
    /// Rounds this shard saw `stale_peers > 0` — how long it ran
    /// degraded. Always `0` in-process.
    pub stale_rounds: u64,
}

/// The sharded-recovery loop body, generic over the transport: PR 9's
/// [`super::ShardedPool`] per-shard thread, lifted verbatim with the
/// board calls routed through [`ExchangeTransport`]. Both the in-process
/// pool and the `shard-worker` CLI run **this** function, which is what
/// makes a multi-process fleet bit-identical to the threaded pool.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_shard<'p, K, T, F>(
    problem: &'p Problem,
    transport: &mut T,
    shard: usize,
    protocol: ExchangeProtocol,
    exchange_period: u64,
    opts: &AsyncOpts,
    period: usize,
    seed: u64,
    make_step: F,
) -> Result<ShardRun, TransportError>
where
    K: SupportKernel + 'p,
    T: ExchangeTransport,
    F: FnOnce(&'p Problem) -> K,
{
    let spec = &problem.spec;
    let shards = transport.shards();
    let e = exchange_period;
    let mut rng = Rng::seed_from(seed).split(shard as u64);
    let mut step = ShardedKernel::new(make_step(problem), shard, shards);
    // Gossip reads and votes one live tally (peer sums baked in);
    // leader-merge votes `tally` but reads a `frozen` merged view
    // refreshed at each exchange.
    let tally = AtomicTally::new(spec.n, opts.weighting);
    let frozen = AtomicTally::new(spec.n, opts.weighting);
    let counter = AtomicU64::new(0);
    // Never raised: every shard runs to its own completion so that the
    // outcome is independent of scheduling (thread or process).
    let stop = AtomicBool::new(false);
    let mut driver = WorkerDriver::new();
    let mut x = SparseIterate::zeros(spec.n);
    let mut own_snap = vec![0i64; spec.n];
    // Peer votes currently baked into `tally` (gossip only; stays zero
    // under leader-merge).
    let mut peer = vec![0i64; spec.n];
    let mut new_peer: Vec<i64> = Vec::new();
    let mut merged: Vec<i64> = Vec::new();
    let mut delta = vec![0i64; spec.n];
    let mut finished = false;
    let mut won: Option<f64> = None;
    let mut wall = Duration::ZERO;
    let shard_start = Instant::now();
    let mut rounds = 0u64;
    let mut stale_rounds = 0u64;
    loop {
        rounds += 1;
        // Own contribution = live tally minus the baked-in peer base (a
        // finished shard republishes the same snapshot, keeping the
        // merge deterministic).
        tally.snapshot_into(&mut own_snap);
        for (o, p) in own_snap.iter_mut().zip(&peer) {
            *o -= *p;
        }
        let info = transport.exchange(&own_snap, finished, &mut merged)?;
        let done = info.finished_shards;
        if info.stale_peers > 0 {
            stale_rounds += 1;
        }
        if !finished {
            match protocol {
                ExchangeProtocol::Gossip => {
                    // Peer sum = merged view minus our own snapshot —
                    // exact i64 arithmetic, bit-identical to the board's
                    // `peer_sum_into`.
                    new_peer.clear();
                    new_peer.extend(merged.iter().zip(&own_snap).map(|(m, o)| m - o));
                    for ((d, np), pb) in delta.iter_mut().zip(&new_peer).zip(&peer) {
                        *d = *np - *pb;
                    }
                    tally.add_votes(&delta);
                    std::mem::swap(&mut peer, &mut new_peer);
                }
                ExchangeProtocol::LeaderMerge => {
                    frozen.store_votes(&merged);
                }
            }
        }
        transport.complete_round()?;
        if done == shards {
            break;
        }
        if finished {
            continue;
        }
        let (read, vote) = match protocol {
            ExchangeProtocol::Gossip => (&tally, &tally),
            ExchangeProtocol::LeaderMerge => (&frozen, &tally),
        };
        won = driver.drive(
            &mut step,
            &mut x,
            spec.s,
            opts,
            period,
            &mut rng,
            read,
            vote,
            &stop,
            &counter,
            rounds * e,
        );
        if won.is_some() || driver.local_iters() >= opts.max_local_iters as u64 {
            finished = true;
            wall = shard_start.elapsed();
        }
    }
    let iters = driver.local_iters();
    let (converged, residual) = match won {
        Some(r) => (true, r),
        None => (false, problem.residual_norm(x.values())),
    };
    let final_error = problem.recovery_error(x.values());
    let outcome =
        JobOutcome { converged, iters, residual, final_error, x: x.into_values(), wall };
    Ok(ShardRun { outcome, rounds: rounds.saturating_sub(1), stale_rounds })
}

/// One distributed shard worker, end to end: [`join_fleet`], then
/// [`run_joined`]. This is the library body of `astir shard-worker`
/// (which calls the two halves itself, to report fleet assembly in
/// between).
pub fn run_worker(
    problem: &Problem,
    hub: &str,
    shard: usize,
    sh: &ShardOpts,
    alg: Alg,
    opts: &AsyncOpts,
    seed: u64,
) -> Result<ShardRun, TransportError> {
    let transport = join_fleet(problem, hub, shard, sh)?;
    run_joined(problem, transport, shard, sh, alg, opts, seed)
}

/// Validate the shard axes and join the fleet at `hub`. Returns once the
/// whole fleet has assembled (or the hub's join window lapsed).
pub fn join_fleet(
    problem: &Problem,
    hub: &str,
    shard: usize,
    sh: &ShardOpts,
) -> Result<HubTransport, TransportError> {
    sh.validate().map_err(TransportError::Protocol)?;
    if shard >= sh.shards {
        return Err(TransportError::Protocol(format!(
            "shard id {shard} out of range for S={}",
            sh.shards
        )));
    }
    let join = ExchangeJoin {
        shard,
        shards: sh.shards,
        n: problem.spec.n,
        exchange_period: sh.exchange_period,
    };
    HubTransport::connect(hub, join)
}

/// Run an already-joined worker to completion and leave cleanly.
pub fn run_joined(
    problem: &Problem,
    mut transport: HubTransport,
    shard: usize,
    sh: &ShardOpts,
    alg: Alg,
    opts: &AsyncOpts,
    seed: u64,
) -> Result<ShardRun, TransportError> {
    let period = opts.schedule.periods(sh.shards)[shard];
    let e = sh.exchange_period as u64;
    let run = match alg {
        Alg::Stoiht => {
            run_shard(problem, &mut transport, shard, sh.protocol, e, opts, period, seed, |p| {
                StoihtKernel::new(p, opts.gamma)
            })
        }
        Alg::StoGradMp => run_shard(
            problem,
            &mut transport,
            shard,
            sh.protocol,
            e,
            opts,
            period,
            seed,
            StoGradMpKernel::new,
        ),
    }?;
    transport.leave();
    Ok(run)
}

/// FNV-1a over the IEEE-754 bit patterns of `xs` — a cheap cross-process
/// bit-identity digest. `astir shard-worker` prints it per shard and the
/// distributed end-to-end test compares it against the in-process pool's
/// iterate, without shipping whole vectors through stdout.
pub fn x_digest(xs: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in xs {
        for byte in v.to_bits().to_le_bytes() {
            h ^= u64::from(byte);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

// --------------------------------------------------- worker-side client

/// Worker-side socket transport: one connection to an [`ExchangeHub`],
/// one request/reply round trip per exchange.
pub struct HubTransport {
    stream: TcpStream,
    shard: usize,
    shards: usize,
    n: usize,
    round: u64,
}

impl HubTransport {
    /// Connect and join a fleet. Blocks until the whole fleet has joined
    /// (the hub withholds the `joined` reply until the session starts),
    /// bounded by a 60 s join-reply deadline.
    pub fn connect(addr: &str, join: ExchangeJoin) -> Result<HubTransport, TransportError> {
        let mut stream = connect_stream(addr, DEFAULT_CONNECT_TIMEOUT)?;
        // Round frames are small and strictly request/reply: waiting out
        // Nagle/delayed-ACK would tax every exchange round.
        let _ = stream.set_nodelay(true);
        stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
        stream.set_read_timeout(Some(JOIN_REPLY_TIMEOUT))?;
        write_frame(&mut stream, &HubRequest::Join(join.clone()).to_json())?;
        let joined = match read_reply(&mut stream)? {
            HubReply::Joined(j) => j,
            HubReply::Error(e) => return Err(TransportError::Rejected(e)),
            HubReply::View(_) => {
                return Err(TransportError::Protocol("expected a joined reply".to_string()))
            }
        };
        if joined.shards != join.shards {
            return Err(TransportError::Protocol(format!(
                "hub runs S={}, worker configured for S={}",
                joined.shards, join.shards
            )));
        }
        // A view reply arrives within one hub round deadline of our
        // publish (stragglers are degraded at that deadline); pad it so
        // the hub, not this read, is the round's timekeeper.
        let read = Duration::from_millis(joined.round_timeout_ms).saturating_add(READ_MARGIN);
        stream.set_read_timeout(Some(read))?;
        Ok(HubTransport {
            stream,
            shard: join.shard,
            shards: join.shards,
            n: join.n,
            round: 0,
        })
    }

    /// Exchange rounds completed so far.
    pub fn rounds(&self) -> u64 {
        self.round
    }

    /// Best-effort clean goodbye: after a leave the hub records this
    /// shard as cleanly finished instead of degraded.
    pub fn leave(mut self) {
        let leave = HubRequest::Leave(ExchangeLeave { shard: self.shard });
        let _ = write_frame(&mut self.stream, &leave.to_json());
    }
}

impl ExchangeTransport for HubTransport {
    fn shards(&self) -> usize {
        self.shards
    }

    fn exchange(
        &mut self,
        own: &[i64],
        finished: bool,
        merged_out: &mut Vec<i64>,
    ) -> Result<RoundInfo, TransportError> {
        let publish = ExchangePublish {
            shard: self.shard,
            round: self.round + 1,
            finished,
            votes: own.to_vec(),
        };
        write_frame(&mut self.stream, &HubRequest::Publish(publish).to_json())?;
        match read_reply(&mut self.stream)? {
            HubReply::View(view) => {
                if view.round != self.round + 1 {
                    return Err(TransportError::Protocol(format!(
                        "view for round {} while publishing round {}",
                        view.round,
                        self.round + 1
                    )));
                }
                if view.merged.len() != self.n {
                    return Err(TransportError::Protocol(format!(
                        "merged view has {} entries, fleet runs n={}",
                        view.merged.len(),
                        self.n
                    )));
                }
                self.round += 1;
                merged_out.clear();
                merged_out.extend_from_slice(&view.merged);
                Ok(RoundInfo {
                    finished_shards: view.finished_shards,
                    stale_peers: view.stale_peers,
                })
            }
            HubReply::Error(e) => Err(TransportError::Rejected(e)),
            HubReply::Joined(_) => {
                Err(TransportError::Protocol("unexpected joined reply mid-session".to_string()))
            }
        }
    }

    fn complete_round(&mut self) -> Result<(), TransportError> {
        // The board needs a second crossing so no shard republishes into
        // a slot a peer is still reading; the hub snapshots each round's
        // view into an immutable payload at completion, so the crossing
        // is subsumed by the publish round trip.
        Ok(())
    }
}

fn read_reply(stream: &mut TcpStream) -> Result<HubReply, TransportError> {
    match read_frame(stream) {
        Ok(Some(text)) => {
            HubReply::parse(&text).map_err(|e| TransportError::Protocol(format!("bad reply: {e}")))
        }
        Ok(None) => Err(TransportError::HubClosed),
        Err(e) => Err(TransportError::Io(e)),
    }
}

// --------------------------------------------------------------- the hub

/// Hub configuration (CLI `exchange-hub` flags).
#[derive(Clone, Debug)]
pub struct HubOpts {
    /// Bind address; port 0 picks an ephemeral port (tests, benches).
    pub addr: String,
    /// Fleet size `S` — the hub serves exactly one fleet, then returns.
    pub shards: usize,
    /// How long to wait for the fleet to assemble before starting
    /// without the missing shards (they are degraded from round 1).
    pub join_timeout: Duration,
    /// Per-peer round deadline; `None` derives it from the largest
    /// staleness bound `E` in the fleet (see [`ExchangeHub`]).
    pub round_timeout: Option<Duration>,
}

impl HubOpts {
    /// Defaults: 30 s join window, round deadline derived from `E`.
    pub fn new(addr: impl Into<String>, shards: usize) -> HubOpts {
        HubOpts {
            addr: addr.into(),
            shards,
            join_timeout: Duration::from_secs(30),
            round_timeout: None,
        }
    }
}

/// What a hub run observed — enough for a driver to decide whether the
/// fleet ran clean or degraded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HubReport {
    /// Exchange rounds completed.
    pub rounds: u64,
    /// Shards that never joined, missed a round deadline, or broke their
    /// connection before finishing — their last snapshots were merged as
    /// stale. Sorted, empty for a clean run.
    pub degraded: Vec<usize>,
}

/// The exchange rendezvous as a one-fleet TCP server. Bind, read the
/// address (ephemeral ports supported), then [`run`] (or [`spawn`]) to
/// serve: accept up to `S` connections, hold the `joined` replies until
/// the fleet is assembled, then relay publish/view rounds until every
/// shard has finished and left.
///
/// [`run`]: ExchangeHub::run
/// [`spawn`]: ExchangeHub::spawn
pub struct ExchangeHub {
    listener: TcpListener,
    opts: HubOpts,
}

impl ExchangeHub {
    /// Bind the rendezvous socket (the fleet can connect from the moment
    /// this returns; frames are only consumed once [`ExchangeHub::run`]
    /// starts).
    pub fn bind(opts: HubOpts) -> io::Result<ExchangeHub> {
        assert!(opts.shards >= 1, "a fleet needs at least one shard");
        let listener = TcpListener::bind(&opts.addr)?;
        Ok(ExchangeHub { listener, opts })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve one fleet to completion on the calling thread.
    pub fn run(self) -> io::Result<HubReport> {
        let shards = self.opts.shards;
        let shared = Arc::new(HubShared::new(&self.opts));
        let join_deadline = Instant::now() + self.opts.join_timeout;
        self.listener.set_nonblocking(true)?;
        let mut handlers = Vec::new();
        let mut accepted = 0usize;
        // Accept until the fleet is full, polling the session-start
        // condition either way: this loop — not the handlers — is the
        // join window's timekeeper, so no condvar timeout is needed.
        loop {
            if accepted < shards {
                match self.listener.accept() {
                    Ok((stream, _)) => {
                        accepted += 1;
                        let shared = Arc::clone(&shared);
                        handlers.push(thread::spawn(move || {
                            serve_shard(stream, &shared, join_deadline)
                        }));
                        continue;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            }
            let started = {
                let mut st = lock_recover(&shared.st);
                if !st.started && (st.joined == shards || Instant::now() >= join_deadline) {
                    st.start();
                    shared.cv.notify_all();
                }
                st.started
            };
            if started {
                break;
            }
            thread::sleep(ACCEPT_POLL);
        }
        // Late connects get refused fast instead of joining a dead queue.
        drop(self.listener);
        for h in handlers {
            let _ = h.join();
        }
        let st = lock_recover(&shared.st);
        let mut degraded = st.degraded.clone();
        degraded.sort_unstable();
        degraded.dedup();
        Ok(HubReport { rounds: st.round, degraded })
    }

    /// [`ExchangeHub::run`] on a background thread (tests, benches).
    pub fn spawn(self) -> thread::JoinHandle<io::Result<HubReport>> {
        thread::spawn(move || self.run())
    }
}

struct HubShared {
    st: Mutex<HubState>,
    cv: Condvar,
}

struct HubState {
    shards: usize,
    /// Tally dimension, fixed by the first join; later joiners must
    /// match.
    n: Option<usize>,
    /// Pinned round deadline from the CLI, if any.
    pinned_timeout: Option<Duration>,
    /// The deadline in force once the session starts.
    timeout: Duration,
    started: bool,
    joined: usize,
    /// Ever joined.
    present: Vec<bool>,
    /// Joined and not retired.
    alive: Vec<bool>,
    /// Sticky per-shard finished flags (meaningful while alive).
    finished: Vec<bool>,
    /// Published in the round currently assembling.
    published: Vec<bool>,
    /// Last snapshot per shard (empty = never published = zeros).
    last: Vec<Vec<i64>>,
    /// Completed rounds.
    round: u64,
    /// The latest completed round's `view` reply, shared by every
    /// handler of that round (the view is shard-independent because it
    /// includes each shard's own snapshot).
    view: Arc<String>,
    degraded: Vec<usize>,
    /// Largest staleness bound `E` seen at join time.
    max_period: usize,
}

impl HubShared {
    fn new(opts: &HubOpts) -> HubShared {
        let s = opts.shards;
        HubShared {
            st: Mutex::new(HubState {
                shards: s,
                n: None,
                pinned_timeout: opts.round_timeout,
                timeout: Duration::ZERO,
                started: false,
                joined: 0,
                present: vec![false; s],
                alive: vec![false; s],
                finished: vec![false; s],
                published: vec![false; s],
                last: vec![Vec::new(); s],
                round: 0,
                view: Arc::new(String::new()),
                degraded: Vec::new(),
                max_period: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Record one shard's publish for the assembling round, close the
    /// round if it is now complete, and block until it closes (either by
    /// the last peer's publish or by a straggler's read deadline retiring
    /// it — every non-published peer's handler sits in a bounded read,
    /// so this wait always terminates). Returns the round's view payload.
    fn publish(&self, shard: usize, p: ExchangePublish) -> Result<Arc<String>, ServeError> {
        let mut st = lock_recover(&self.st);
        if !st.alive[shard] {
            return Err(ServeError::Invalid(format!("shard {shard} already retired")));
        }
        let assembling = st.round + 1;
        if p.round != assembling {
            return Err(ServeError::Incompatible(format!(
                "publish for round {} but the hub is assembling round {assembling}",
                p.round
            )));
        }
        let n = st.n.unwrap_or(0);
        if p.votes.len() != n {
            return Err(ServeError::Incompatible(format!(
                "vote snapshot has {} entries, fleet runs n={n}",
                p.votes.len()
            )));
        }
        st.last[shard] = p.votes;
        if p.finished {
            st.finished[shard] = true;
        }
        st.published[shard] = true;
        if st.round_complete() {
            st.complete_round();
            self.cv.notify_all();
        }
        while st.round < assembling {
            st = wait_recover(&self.cv, st);
        }
        Ok(Arc::clone(&st.view))
    }

    /// Remove a shard from the fleet: cleanly (post-finish leave) or
    /// degraded (deadline, EOF, protocol violation). Its last snapshot
    /// keeps being merged; if it was the last straggler of the
    /// assembling round, the round closes so waiting peers proceed.
    fn retire(&self, shard: usize, clean: bool) {
        let mut st = lock_recover(&self.st);
        if !st.alive[shard] {
            return;
        }
        st.alive[shard] = false;
        st.published[shard] = false;
        if !(clean && st.finished[shard]) {
            st.degraded.push(shard);
        }
        if st.started && st.round_complete() {
            st.complete_round();
        }
        self.cv.notify_all();
    }
}

impl HubState {
    fn register(&mut self, join: &ExchangeJoin) -> Result<(), ServeError> {
        if self.started {
            return Err(ServeError::Invalid("join window closed".to_string()));
        }
        if join.shards != self.shards {
            return Err(ServeError::Incompatible(format!(
                "worker configured for S={} but hub runs S={}",
                join.shards, self.shards
            )));
        }
        if join.shard >= self.shards {
            return Err(ServeError::Invalid(format!(
                "shard id {} out of range for S={}",
                join.shard, self.shards
            )));
        }
        if self.present[join.shard] {
            return Err(ServeError::Invalid(format!("shard {} already joined", join.shard)));
        }
        match self.n {
            None => self.n = Some(join.n),
            Some(n) if n != join.n => {
                return Err(ServeError::Incompatible(format!(
                    "tally dimension mismatch: fleet runs n={n}, joiner has n={}",
                    join.n
                )));
            }
            Some(_) => {}
        }
        self.present[join.shard] = true;
        self.alive[join.shard] = true;
        self.joined += 1;
        self.max_period = self.max_period.max(join.exchange_period);
        Ok(())
    }

    /// Start the session: shards that never joined are degraded from
    /// round 1, and the round deadline is resolved.
    fn start(&mut self) {
        self.started = true;
        for k in 0..self.shards {
            if !self.present[k] {
                self.degraded.push(k);
            }
        }
        self.timeout =
            self.pinned_timeout.unwrap_or_else(|| derived_round_timeout(self.max_period));
    }

    /// Every live shard has published the assembling round (and there is
    /// at least one live shard — an empty fleet has no round to close).
    fn round_complete(&self) -> bool {
        let mut any = false;
        for k in 0..self.shards {
            if self.alive[k] {
                if !self.published[k] {
                    return false;
                }
                any = true;
            }
        }
        any
    }

    /// Close the assembling round: merge every shard's latest snapshot
    /// (dead and absent peers contribute their stale last — zeros if
    /// they never published), latch the finished count, and freeze the
    /// view payload every handler of this round replies with.
    fn complete_round(&mut self) {
        self.round += 1;
        let n = self.n.unwrap_or(0);
        let mut merged = vec![0i64; n];
        for last in &self.last {
            for (m, v) in merged.iter_mut().zip(last) {
                *m += *v;
            }
        }
        let alive_count = self.alive.iter().filter(|a| **a).count();
        let finished_shards = (0..self.shards)
            .filter(|&k| if self.alive[k] { self.finished[k] } else { true })
            .count();
        let view = HubReply::View(ExchangeView {
            round: self.round,
            finished_shards,
            stale_peers: self.shards - alive_count,
            merged,
        });
        self.view = Arc::new(view.to_json());
        for p in &mut self.published {
            *p = false;
        }
    }
}

/// One connection's handler: join, fleet barrier, then publish/view
/// rounds until the worker leaves or fails.
fn serve_shard(mut stream: TcpStream, shared: &HubShared, join_deadline: Instant) {
    let _ = stream.set_nodelay(true);
    // Bound the join read by the remaining join window plus slack; a
    // connection that never sends a join cannot hold the hub open.
    let join_window = join_deadline
        .saturating_duration_since(Instant::now())
        .saturating_add(Duration::from_secs(5));
    if stream.set_read_timeout(Some(join_window)).is_err()
        || stream.set_write_timeout(Some(WRITE_TIMEOUT)).is_err()
    {
        return;
    }
    let text = match read_frame(&mut stream) {
        Ok(Some(t)) => t,
        _ => return,
    };
    let join = match HubRequest::parse(&text) {
        Ok(HubRequest::Join(j)) => j,
        Ok(_) => {
            reject(&mut stream, ServeError::Malformed("expected a join frame".to_string()));
            return;
        }
        Err(e) => {
            reject(&mut stream, e);
            return;
        }
    };
    let shard = join.shard;
    let timeout = {
        let mut st = lock_recover(&shared.st);
        if let Err(e) = st.register(&join) {
            drop(st);
            reject(&mut stream, e);
            return;
        }
        // Fleet-assembly barrier: the joined reply is withheld until the
        // session starts (all S present, or the join window closes — the
        // hub's accept loop is the timekeeper that forces a start).
        while !st.started {
            st = wait_recover(&shared.cv, st);
        }
        st.timeout
    };
    let joined = HubReply::Joined(ExchangeJoined {
        shards: join.shards,
        round_timeout_ms: timeout.as_millis() as u64,
    });
    if write_frame(&mut stream, &joined.to_json()).is_err() {
        shared.retire(shard, false);
        return;
    }
    // The per-peer deadline: a worker that does not publish within the
    // round deadline of its previous reply is retired and the fleet
    // proceeds on its stale snapshot.
    if stream.set_read_timeout(Some(timeout)).is_err() {
        shared.retire(shard, false);
        return;
    }
    loop {
        let text = match read_frame(&mut stream) {
            Ok(Some(t)) => t,
            // Clean EOF, timeout, or reset: the worker is gone mid-round.
            _ => {
                shared.retire(shard, false);
                return;
            }
        };
        match HubRequest::parse(&text) {
            Ok(HubRequest::Publish(p)) if p.shard == shard => {
                let view = match shared.publish(shard, p) {
                    Ok(v) => v,
                    Err(e) => {
                        reject(&mut stream, e);
                        shared.retire(shard, false);
                        return;
                    }
                };
                if write_frame(&mut stream, &view).is_err() {
                    shared.retire(shard, false);
                    return;
                }
            }
            Ok(HubRequest::Leave(l)) if l.shard == shard => {
                shared.retire(shard, true);
                return;
            }
            Ok(_) => {
                reject(&mut stream, ServeError::Invalid("unexpected frame".to_string()));
                shared.retire(shard, false);
                return;
            }
            Err(e) => {
                reject(&mut stream, e);
                shared.retire(shard, false);
                return;
            }
        }
    }
}

/// Best-effort typed rejection before dropping a connection.
fn reject(stream: &mut TcpStream, e: ServeError) {
    let _ = write_frame(stream, &HubReply::Error(e).to_json());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::Ensemble;
    use crate::service::api::JobRequest;
    use crate::service::ShardedPool;

    fn make_problem(seed: u64) -> Problem {
        let req = JobRequest {
            ensemble: Ensemble::Gaussian,
            n: 128,
            m: 64,
            b: 8,
            s: 4,
            seed,
            y: None,
        };
        let op = req.draw_operator();
        req.problem(&op).unwrap()
    }

    fn assert_outcomes_bit_identical(a: &JobOutcome, b: &JobOutcome) {
        assert_eq!(a.converged, b.converged);
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.residual.to_bits(), b.residual.to_bits());
        assert_eq!(a.final_error.to_bits(), b.final_error.to_bits());
        assert_eq!(a.x.len(), b.x.len());
        for (u, v) in a.x.iter().zip(&b.x) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn hub_fleet_matches_the_in_process_pool_bit_for_bit() {
        let problem = make_problem(11);
        let opts = AsyncOpts::default();
        for protocol in [ExchangeProtocol::Gossip, ExchangeProtocol::LeaderMerge] {
            let sh = ShardOpts { shards: 3, exchange_period: 8, protocol };
            let pool = ShardedPool::new(sh.clone()).run(&problem, Alg::Stoiht, &opts, 7);
            let hub = ExchangeHub::bind(HubOpts::new("127.0.0.1:0", 3)).unwrap();
            let addr = hub.addr().unwrap().to_string();
            let hub = hub.spawn();
            let mut runs: Vec<Option<ShardRun>> = vec![None, None, None];
            thread::scope(|scope| {
                let mut handles = Vec::new();
                for k in 0..3 {
                    let (addr, sh, problem, opts) = (&addr, &sh, &problem, &opts);
                    handles.push(scope.spawn(move || {
                        run_worker(problem, addr, k, sh, Alg::Stoiht, opts, 7).unwrap()
                    }));
                }
                for (k, h) in handles.into_iter().enumerate() {
                    runs[k] = Some(h.join().unwrap());
                }
            });
            let report = hub.join().unwrap().unwrap();
            assert!(report.degraded.is_empty(), "clean fleet must not degrade");
            assert_eq!(report.rounds, pool.rounds + 1, "hub counts the final drain round");
            for (k, run) in runs.iter().enumerate() {
                let run = run.as_ref().unwrap();
                assert_eq!(run.stale_rounds, 0);
                assert_eq!(run.rounds, pool.rounds, "protocol {protocol:?} shard {k}");
                assert_outcomes_bit_identical(&run.outcome, &pool.shards[k]);
            }
        }
    }

    #[test]
    fn dead_peer_degrades_the_fleet_instead_of_deadlocking() {
        let problem = make_problem(5);
        let opts = AsyncOpts::default();
        let sh = ShardOpts { shards: 3, exchange_period: 4, protocol: ExchangeProtocol::Gossip };
        let mut hub_opts = HubOpts::new("127.0.0.1:0", 3);
        // Tight deadline so a vanished peer is detected quickly even if
        // the EOF is swallowed by the platform.
        hub_opts.round_timeout = Some(Duration::from_millis(500));
        let hub = ExchangeHub::bind(hub_opts).unwrap();
        let addr = hub.addr().unwrap().to_string();
        let hub = hub.spawn();
        thread::scope(|scope| {
            // Shard 2 joins the fleet, then its process "dies": the
            // dropped connection is the kill. Connect concurrently with
            // the workers — the join reply is the fleet barrier.
            let doomed = scope.spawn(|| {
                HubTransport::connect(
                    &addr,
                    ExchangeJoin { shard: 2, shards: 3, n: 128, exchange_period: 4 },
                )
            });
            let mut handles = Vec::new();
            for k in 0..2 {
                let (addr, sh, problem, opts) = (&addr, &sh, &problem, &opts);
                handles.push(scope.spawn(move || {
                    run_worker(problem, addr, k, sh, Alg::Stoiht, opts, 7)
                }));
            }
            // The fleet is assembled once connect returns; now kill the
            // peer mid-round.
            drop(doomed.join().unwrap().unwrap());
            for h in handles {
                let run = h.join().unwrap().expect("survivors must finish, not deadlock");
                assert!(run.rounds > 0);
                assert!(run.stale_rounds > 0, "survivors must observe the degraded rounds");
            }
        });
        let report = hub.join().unwrap().unwrap();
        assert_eq!(report.degraded, vec![2]);
    }

    #[test]
    fn hub_rejects_mismatched_joins_with_typed_errors() {
        // Fleet-size mismatch.
        let mut opts = HubOpts::new("127.0.0.1:0", 1);
        opts.join_timeout = Duration::from_millis(300);
        let hub = ExchangeHub::bind(opts).unwrap();
        let addr = hub.addr().unwrap().to_string();
        let hub = hub.spawn();
        let err = HubTransport::connect(
            &addr,
            ExchangeJoin { shard: 0, shards: 2, n: 16, exchange_period: 1 },
        )
        .unwrap_err();
        assert!(
            matches!(err, TransportError::Rejected(ServeError::Incompatible(_))),
            "got {err}"
        );
        let report = hub.join().unwrap().unwrap();
        assert_eq!(report.rounds, 0);
        assert_eq!(report.degraded, vec![0], "the slot never joined");

        // Duplicate shard id: the second join is rejected, the first
        // keeps the slot (and is degraded when we drop it).
        let mut opts = HubOpts::new("127.0.0.1:0", 2);
        opts.join_timeout = Duration::from_millis(600);
        let hub = ExchangeHub::bind(opts).unwrap();
        let addr = hub.addr().unwrap().to_string();
        let hub = hub.spawn();
        let join = ExchangeJoin { shard: 0, shards: 2, n: 16, exchange_period: 1 };
        // Neither join reply arrives before the window closes (the fleet
        // never completes), so connect on a thread and harvest after.
        let (a, b) = thread::scope(|scope| {
            let first = scope.spawn(|| HubTransport::connect(&addr, join.clone()));
            thread::sleep(Duration::from_millis(150));
            let second = scope.spawn(|| HubTransport::connect(&addr, join.clone()));
            (first.join().unwrap(), second.join().unwrap())
        });
        assert!(a.is_ok(), "first join holds the slot");
        let err = b.unwrap_err();
        assert!(
            matches!(err, TransportError::Rejected(ServeError::Invalid(_))),
            "duplicate join must be Invalid, got {err}"
        );
        drop(a);
        let report = hub.join().unwrap().unwrap();
        assert!(report.degraded.contains(&1), "slot 1 never joined");
    }

    #[test]
    fn single_shard_fleet_completes() {
        let problem = make_problem(3);
        let opts = AsyncOpts::default();
        let sh = ShardOpts { shards: 1, exchange_period: 16, ..ShardOpts::default() };
        let hub = ExchangeHub::bind(HubOpts::new("127.0.0.1:0", 1)).unwrap();
        let addr = hub.addr().unwrap().to_string();
        let hub = hub.spawn();
        let run = run_worker(&problem, &addr, 0, &sh, Alg::Stoiht, &opts, 9).unwrap();
        assert!(run.rounds >= 1);
        assert_eq!(run.stale_rounds, 0);
        let report = hub.join().unwrap().unwrap();
        assert!(report.degraded.is_empty());
    }
}
