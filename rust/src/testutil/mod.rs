//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Usage (`no_run`: doctest binaries don't inherit the rpath to the XLA
//! runtime libs this crate links against; the same code runs as a unit
//! test below):
//!
//! ```no_run
//! use astir::testutil::{property, Gen, OrFail};
//! property("dot is symmetric", 100, |g: &mut Gen| {
//!     let n = g.usize_in(1, 32);
//!     let a = g.vec_f64(n, -10.0, 10.0);
//!     let b = g.vec_f64(n, -10.0, 10.0);
//!     let d1 = astir::linalg::dot(&a, &b);
//!     let d2 = astir::linalg::dot(&b, &a);
//!     ((d1 - d2).abs() < 1e-9).or_fail(format!("{d1} != {d2}"))
//! });
//! ```
//!
//! On failure the harness re-runs the failing case and panics with the
//! case's seed so `ASTIR_PROP_SEED=<seed>` reproduces it exactly; there is
//! no structural shrinking, but every generator is seed-deterministic, so a
//! failing seed is a complete repro.

use crate::rng::Rng;

/// Random-input generator handed to property bodies.
pub struct Gen {
    rng: Rng,
    /// The seed that reproduces this case.
    pub seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Gen { rng: Rng::seed_from(seed), seed }
    }

    /// Access the underlying RNG for domain-specific sampling.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Standard normal.
    pub fn gauss(&mut self) -> f64 {
        self.rng.gauss()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Vector of uniform values.
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Vector of standard normals.
    pub fn vec_gauss(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.gauss()).collect()
    }

    /// `k` distinct sorted indices below `n`.
    pub fn sorted_subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut v = self.rng.subset(n, k);
        v.sort_unstable();
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }
}

/// Outcome of one property case: `Ok(())` or a failure message.
pub type CaseResult = Result<(), String>;

/// Tiny helper: turn a boolean into a [`CaseResult`] with a message.
/// (Named `or_fail` to avoid colliding with the unstable `bool::ok_or`.)
pub trait OrFail {
    fn or_fail(self, msg: impl Into<String>) -> CaseResult;
}

impl OrFail for bool {
    fn or_fail(self, msg: impl Into<String>) -> CaseResult {
        if self {
            Ok(())
        } else {
            Err(msg.into())
        }
    }
}

/// Run `cases` random cases of `body`. Panics (with the reproducing seed)
/// on the first failure. Honors `ASTIR_PROP_SEED` to re-run a single case.
pub fn property(name: &str, cases: usize, mut body: impl FnMut(&mut Gen) -> CaseResult) {
    if let Ok(seed_str) = std::env::var("ASTIR_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("ASTIR_PROP_SEED must be a u64");
        let mut g = Gen::from_seed(seed);
        if let Err(msg) = body(&mut g) {
            panic!("property `{name}` failed under ASTIR_PROP_SEED={seed}: {msg}");
        }
        return;
    }
    // Derive per-case seeds from the property name so distinct properties
    // explore distinct inputs but remain fully deterministic run-to-run.
    let mut h: u64 = 0xcbf29ce484222325;
    for byte in name.bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for case in 0..cases {
        let seed = h ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::from_seed(seed);
        if let Err(msg) = body(&mut g) {
            panic!(
                "property `{name}` failed on case {case}/{cases}: {msg}\n  reproduce with ASTIR_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::from_seed(9);
        let mut b = Gen::from_seed(9);
        assert_eq!(a.vec_f64(8, 0.0, 1.0), b.vec_f64(8, 0.0, 1.0));
        assert_eq!(a.usize_in(3, 9), b.usize_in(3, 9));
    }

    #[test]
    fn ranges_are_respected() {
        let mut g = Gen::from_seed(1);
        for _ in 0..1000 {
            let v = g.usize_in(2, 5);
            assert!((2..=5).contains(&v));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let s = g.sorted_subset(10, 4);
        assert_eq!(s.len(), 4);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property("counting", 25, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "reproduce with ASTIR_PROP_SEED=")]
    fn property_reports_seed_on_failure() {
        property("always fails", 3, |_g| Err("boom".into()));
    }

    #[test]
    fn or_fail_helper() {
        assert!(true.or_fail("x").is_ok());
        assert_eq!(false.or_fail("x"), Err("x".to_string()));
    }

    #[test]
    fn choose_picks_members() {
        let mut g = Gen::from_seed(2);
        let items = [10, 20, 30];
        for _ in 0..50 {
            assert!(items.contains(g.choose(&items)));
        }
    }
}
