//! The crate's **single doorway to concurrency primitives**.
//!
//! Every module that synchronizes — [`crate::tally`]'s atomic vote
//! counters, [`crate::async_runtime`]'s stop flag and scoped workers,
//! [`crate::coordinator`]'s one-writer-per-slot result storage,
//! [`crate::service`]'s persistent pool queue — imports its primitives
//! from here, never from `std::sync`/`std::thread` directly (`astir lint`
//! enforces this tree-wide). The doorway has two personalities:
//!
//! * **Normal builds** (no `model` feature): zero-cost re-exports of the
//!   `std` primitives. [`RaceCell`] is a `#[repr(transparent)]` wrapper
//!   over [`std::cell::UnsafeCell`]; everything else is literally the
//!   `std` type.
//! * **`--features model` builds**: the same names resolve to
//!   *instrumented* implementations in [`model`], driven by an in-crate
//!   deterministic model checker (a zero-dependency "loom-lite"). Inside
//!   a [`model::check`] run, every lock, condvar wait, atomic access, and
//!   [`RaceCell`] access becomes a scheduling point of a
//!   bounded-preemption DFS over thread interleavings, with vector-clock
//!   happens-before tracking that detects data races, deadlocks (which is
//!   how lost condvar wakeups surface — the model injects no spurious
//!   wakeups), and double-takes. Outside a `check` run the instrumented
//!   types fall back to plain `std` behavior, so the crate still works
//!   end-to-end when compiled with the feature on.
//!
//! What the model checker **proves**: for the explored schedules of a
//! small closed program, no `RaceCell` access races under the C++11-style
//! happens-before induced by mutexes, thread spawn/join/scope, and
//! release/acquire atomics; no reachable all-threads-blocked state; no
//! assertion failure in any interleaving. What it **cannot** prove:
//! anything about schedules beyond the preemption bound, weak-memory
//! *value* visibility (execution is sequentially consistent; only the
//! happens-before bookkeeping honors the chosen `Ordering`s), or
//! undefined behavior inside unsafe code — that is what the Miri CI job
//! is for, and TSan re-checks the real compiled protocol under load (see
//! README, "Concurrency correctness").
//!
//! [`RaceCell`] is the doorway's one non-`std` name: unsynchronized
//! interior-mutable storage whose *caller* guarantees exclusion (the
//! atomic-ticket protocol of [`crate::coordinator::run_trials`] and the
//! recovery pool). The real implementation hands out raw pointers with no
//! overhead; the model implementation race-checks every access, which is
//! exactly the machine-checked version of the `SAFETY:` contracts written
//! on its call sites.

#[cfg(feature = "model")]
pub mod model;

// `Arc` and `OnceLock` carry no schedule-relevant semantics the checker
// needs to interpose on (no blocking, no unsynchronized data), so both
// personalities share the `std` types. `mpsc` and friends are
// deliberately absent: if a module needs a new primitive, it gets added
// here, instrumented, or not at all.
pub use std::sync::{Arc, OnceLock};

#[cfg(not(feature = "model"))]
pub use real::{atomic, thread, Condvar, Mutex, MutexGuard, RaceCell};

#[cfg(feature = "model")]
pub use model::shim::{atomic, thread, Condvar, Mutex, MutexGuard, RaceCell};

/// The zero-cost personality: `std` re-exports plus the transparent
/// [`RaceCell`]. (Private — consumers name `crate::sync::…` only.)
#[cfg(not(feature = "model"))]
mod real {
    pub use std::sync::{Condvar, Mutex, MutexGuard};

    /// Atomic integer/bool types and the `Ordering` enum.
    pub mod atomic {
        pub use std::sync::atomic::{
            AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering,
        };
    }

    /// Thread spawning, scoped threads, and runtime introspection.
    pub mod thread {
        pub use std::thread::{
            available_parallelism, scope, sleep, spawn, Builder, JoinHandle, Scope,
            ScopedJoinHandle,
        };
    }

    /// Unsynchronized interior-mutable storage with caller-guaranteed
    /// exclusion — the crate-visible face of [`std::cell::UnsafeCell`].
    ///
    /// `with` passes a read pointer, `with_mut` a write pointer; the model
    /// personality uses that read/write distinction for race detection, so
    /// call the one that matches the access. Dereferencing the pointer is
    /// the caller's `unsafe`, under the protocol documented at the call
    /// site (see [`crate::coordinator::ResultSlots`]). Closures must not
    /// touch other `sync` primitives: accesses are modeled as atomic
    /// scheduling steps.
    ///
    /// `RaceCell` is deliberately `!Sync` (it contains an `UnsafeCell`);
    /// a container proving a cross-thread exclusion protocol opts in with
    /// its own `unsafe impl Sync`, keeping the obligation visible where
    /// the protocol lives.
    #[derive(Debug)]
    #[repr(transparent)]
    pub struct RaceCell<T>(std::cell::UnsafeCell<T>);

    impl<T> RaceCell<T> {
        pub const fn new(v: T) -> Self {
            RaceCell(std::cell::UnsafeCell::new(v))
        }

        /// Run `f` with a read pointer to the contents.
        #[inline]
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Run `f` with a write pointer to the contents.
        #[inline]
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }

        /// Exclusive access through a unique borrow (safe: `&mut self`
        /// proves no other accessor exists).
        #[inline]
        pub fn get_mut(&mut self) -> &mut T {
            self.0.get_mut()
        }

        /// Consume the cell (exclusive by ownership; never racy).
        #[inline]
        pub fn into_inner(self) -> T {
            self.0.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn race_cell_round_trips() {
        let mut c = RaceCell::new(7usize);
        *c.get_mut() = 9;
        // The two access paths hand out the same storage (no unsafe needed
        // to check identity: raw pointers compare safely).
        let (pr, pw) = (c.with(|p| p as usize), c.with_mut(|p| p as usize));
        assert_eq!(pr, pw);
        assert_eq!(c.into_inner(), 9);
    }

    #[test]
    fn doorway_types_behave_like_std() {
        let m = Mutex::new(3);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 4);
        let a = atomic::AtomicUsize::new(1);
        // Relaxed: single-threaded test, no cross-thread publication.
        assert_eq!(a.fetch_add(2, atomic::Ordering::Relaxed), 1);
        let h = thread::spawn(|| 5usize);
        assert_eq!(h.join().unwrap(), 5);
        let out = thread::scope(|s| s.spawn(|| 6usize).join().unwrap());
        assert_eq!(out, 6);
    }
}
