//! Instrumented drop-in replacements for the doorway's primitives.
//!
//! Same names and signatures as the `std` re-exports in
//! [`crate::sync`]'s normal personality, so the rest of the crate
//! compiles unchanged under `--features model`. Each operation asks
//! [`super::cur`] whether the calling thread belongs to an active
//! [`super::check`] run: if yes, the op goes through the scheduler (park,
//! grant, vector-clock bookkeeping) before touching the real primitive;
//! if no — outside any model run, or while unwinding during tear-down —
//! it falls straight through to `std`, so `model`-feature builds still
//! behave normally end-to-end.
//!
//! Two deliberate asymmetries with `std`:
//!
//! * Poisoning is mirrored structurally ([`PoisonError::into_inner`]
//!   exists so `.unwrap_or_else(|e| e.into_inner())` call sites compile
//!   against both personalities) but model-held mutexes never poison —
//!   a panic inside a model run aborts the whole schedule instead.
//! * [`thread::scope`]'s closure takes `&Scope<'scope, 'env>` with a free
//!   outer lifetime rather than `std`'s `&'scope Scope<'scope, 'env>`;
//!   every call site that works with `std`'s signature also works with
//!   this one, and it lets the wrapper stay safe code.

use std::cell::UnsafeCell;
use std::sync::atomic::AtomicU64 as StdAtomicU64;
use std::sync::atomic::Ordering as StdOrdering;
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex};

use super::{cur, Class, Ctx};

fn is_acquire(ord: StdOrdering) -> bool {
    matches!(ord, StdOrdering::Acquire | StdOrdering::AcqRel | StdOrdering::SeqCst)
}

fn is_release(ord: StdOrdering) -> bool {
    matches!(ord, StdOrdering::Release | StdOrdering::AcqRel | StdOrdering::SeqCst)
}

/// Resolve the calling thread's model context and the object's
/// per-execution id in one step (`None` = passthrough).
fn registered(reg: &StdAtomicU64, class: Class) -> Option<(Ctx, usize)> {
    cur().map(|ctx| {
        let id = ctx.register(reg, class);
        (ctx, id)
    })
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

/// Instrumented atomics; `Ordering` itself is the `std` enum (the model
/// interprets it rather than redefining it).
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    use super::{is_acquire, is_release, registered, Class, StdAtomicU64};

    macro_rules! model_atomic {
        ($name:ident, $std:ty, $val:ty) => {
            /// Instrumented stand-in for the `std` atomic of the same name.
            #[derive(Debug)]
            pub struct $name {
                inner: $std,
                reg: StdAtomicU64,
            }

            impl $name {
                pub const fn new(v: $val) -> Self {
                    Self { inner: <$std>::new(v), reg: StdAtomicU64::new(0) }
                }

                /// Run one value operation, routed through the scheduler
                /// when a model run is active. `acq`/`rel` describe the
                /// happens-before effect of the chosen ordering; `store`
                /// marks a plain store (replaces the release sequence).
                fn op<R>(
                    &self,
                    name: &'static str,
                    acq: bool,
                    rel: bool,
                    store: bool,
                    f: impl FnOnce() -> R,
                ) -> R {
                    match registered(&self.reg, Class::Atomic) {
                        Some((ctx, id)) => ctx.atomic_op(id, name, acq, rel, store, f),
                        None => f(),
                    }
                }

                pub fn load(&self, ord: Ordering) -> $val {
                    self.op("load", is_acquire(ord), false, false, || self.inner.load(ord))
                }

                pub fn store(&self, v: $val, ord: Ordering) {
                    self.op("store", false, is_release(ord), true, || self.inner.store(v, ord))
                }

                pub fn swap(&self, v: $val, ord: Ordering) -> $val {
                    let (acq, rel) = (is_acquire(ord), is_release(ord));
                    self.op("swap", acq, rel, false, || self.inner.swap(v, ord))
                }
            }
        };
    }

    macro_rules! model_atomic_int {
        ($name:ident, $val:ty) => {
            impl $name {
                pub fn fetch_add(&self, v: $val, ord: Ordering) -> $val {
                    let (acq, rel) = (is_acquire(ord), is_release(ord));
                    self.op("fetch_add", acq, rel, false, || self.inner.fetch_add(v, ord))
                }

                pub fn fetch_sub(&self, v: $val, ord: Ordering) -> $val {
                    let (acq, rel) = (is_acquire(ord), is_release(ord));
                    self.op("fetch_sub", acq, rel, false, || self.inner.fetch_sub(v, ord))
                }

                pub fn compare_exchange(
                    &self,
                    current: $val,
                    new: $val,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$val, $val> {
                    // Happens-before bookkeeping is conservative: acquire
                    // if either ordering acquires (a failed CAS is still a
                    // load), release only per the success ordering.
                    let acq = is_acquire(success) || is_acquire(failure);
                    let rel = is_release(success);
                    self.op("compare_exchange", acq, rel, false, || {
                        self.inner.compare_exchange(current, new, success, failure)
                    })
                }
            }
        };
    }

    model_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    model_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    model_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    model_atomic!(AtomicI64, std::sync::atomic::AtomicI64, i64);
    model_atomic_int!(AtomicUsize, usize);
    model_atomic_int!(AtomicU64, u64);
    model_atomic_int!(AtomicI64, i64);
}

// ---------------------------------------------------------------------------
// Mutex + Condvar
// ---------------------------------------------------------------------------

/// Structural stand-in for [`std::sync::PoisonError`], so call sites can
/// `.unwrap_or_else(|e| e.into_inner())` against either personality.
pub struct PoisonError<G>(G);

impl<G> PoisonError<G> {
    pub fn into_inner(self) -> G {
        self.0
    }
}

impl<G> std::fmt::Debug for PoisonError<G> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("PoisonError { .. }")
    }
}

pub type LockResult<G> = Result<G, PoisonError<G>>;

/// Instrumented mutex: logical ownership lives in the scheduler, the data
/// still sits in a real `std` mutex (whose `try_lock` must succeed by the
/// time the scheduler grants the acquisition).
#[derive(Debug)]
pub struct Mutex<T> {
    reg: StdAtomicU64,
    data: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Self {
        Mutex { reg: StdAtomicU64::new(0), data: StdMutex::new(t) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match registered(&self.reg, Class::Mutex) {
            Some((ctx, id)) => {
                ctx.mutex_lock(id);
                let real = self
                    .data
                    .try_lock()
                    .unwrap_or_else(|_| panic!("model mutex m{id}: real lock held at grant"));
                Ok(MutexGuard { model: Some((self, id)), real: Some(real) })
            }
            None => match self.data.lock() {
                Ok(g) => Ok(MutexGuard { model: None, real: Some(g) }),
                Err(p) => Err(PoisonError(MutexGuard { model: None, real: Some(p.into_inner()) })),
            },
        }
    }
}

/// Guard for [`Mutex`]; dropping it performs the model's release edge
/// (after releasing the real lock — no other model thread can attempt the
/// real lock until the scheduler sees the release anyway).
pub struct MutexGuard<'a, T> {
    model: Option<(&'a Mutex<T>, usize)>,
    real: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.real.as_ref().expect("guard holds the real lock")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.real.as_mut().expect("guard holds the real lock")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.real.take());
        if let Some((_, id)) = self.model {
            if let Some(ctx) = cur() {
                ctx.mutex_unlock(id);
            }
        }
    }
}

/// Instrumented condvar. Inside a model run, waiting and notifying go
/// through the scheduler (no real blocking, no spurious wakeups — which
/// is what makes lost-wakeup bugs reproducible); outside one, it is the
/// real primitive.
#[derive(Debug, Default)]
pub struct Condvar {
    reg: StdAtomicU64,
    inner: StdCondvar,
}

impl Condvar {
    pub const fn new() -> Self {
        Condvar { reg: StdAtomicU64::new(0), inner: StdCondvar::new() }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let model = guard.model;
        match (cur(), model) {
            (Some(ctx), Some((mx, mid))) => {
                let cv = ctx.register(&self.reg, Class::Condvar);
                // Dismantle the guard by hand: the model wait performs the
                // release edge itself, so the guard's Drop must not.
                drop(guard.real.take());
                guard.model = None;
                drop(guard);
                ctx.condvar_wait(cv, mid);
                let real = mx.data.try_lock().unwrap_or_else(|_| {
                    panic!("model mutex m{mid}: real lock held at cv re-acquire")
                });
                Ok(MutexGuard { model: Some((mx, mid)), real: Some(real) })
            }
            _ => {
                let real = guard.real.take().expect("guard holds the real lock");
                guard.model = None;
                drop(guard);
                match self.inner.wait(real) {
                    Ok(g) => Ok(MutexGuard { model, real: Some(g) }),
                    Err(p) => Err(PoisonError(MutexGuard { model, real: Some(p.into_inner()) })),
                }
            }
        }
    }

    pub fn notify_one(&self) {
        match registered(&self.reg, Class::Condvar) {
            Some((ctx, cv)) => ctx.condvar_notify(cv, false),
            None => self.inner.notify_one(),
        }
    }

    pub fn notify_all(&self) {
        match registered(&self.reg, Class::Condvar) {
            Some((ctx, cv)) => ctx.condvar_notify(cv, true),
            None => self.inner.notify_all(),
        }
    }
}

// ---------------------------------------------------------------------------
// RaceCell
// ---------------------------------------------------------------------------

/// The instrumented face of [`crate::sync`]'s `RaceCell`: every access is
/// race-checked against the model's happens-before relation before the
/// pointer is handed to the closure. The scheduler's grants exclude real
/// overlap, so checked accesses are well-defined even when they *would*
/// race — the violation is reported instead of executed.
#[derive(Debug)]
pub struct RaceCell<T> {
    reg: StdAtomicU64,
    cell: UnsafeCell<T>,
}

impl<T> RaceCell<T> {
    pub const fn new(v: T) -> Self {
        RaceCell { reg: StdAtomicU64::new(0), cell: UnsafeCell::new(v) }
    }

    /// Run `f` with a read pointer to the contents (modeled as a read).
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        match registered(&self.reg, Class::Cell) {
            Some((ctx, id)) => ctx.cell_op(id, false, || f(self.cell.get())),
            None => f(self.cell.get()),
        }
    }

    /// Run `f` with a write pointer to the contents (modeled as a write).
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        match registered(&self.reg, Class::Cell) {
            Some((ctx, id)) => ctx.cell_op(id, true, || f(self.cell.get())),
            None => f(self.cell.get()),
        }
    }

    /// Exclusive access through a unique borrow (never racy).
    pub fn get_mut(&mut self) -> &mut T {
        self.cell.get_mut()
    }

    /// Consume the cell (exclusive by ownership; never racy).
    pub fn into_inner(self) -> T {
        self.cell.into_inner()
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Instrumented thread spawning. Inside a model run, spawn/join become
/// scheduler edges; outside one, everything delegates to `std`.
pub mod thread {
    pub use std::thread::{available_parallelism, sleep};

    use super::super::{abort_execution, cur, enter_thread, ExecShared, Tid};
    use super::Arc;

    /// Mirror of [`std::thread::Builder`] (only `name` + `spawn`, which is
    /// all the crate uses).
    #[derive(Debug)]
    pub struct Builder {
        inner: std::thread::Builder,
        name: Option<String>,
    }

    impl Builder {
        #[allow(clippy::new_without_default)]
        pub fn new() -> Builder {
            Builder { inner: std::thread::Builder::new(), name: None }
        }

        pub fn name(self, name: String) -> Builder {
            Builder { inner: self.inner.name(name.clone()), name: Some(name) }
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            match cur() {
                Some(ctx) => {
                    let name = self.name.unwrap_or_else(|| "child".to_string());
                    let tid = ctx.spawn_register(name);
                    let exec = Arc::clone(&ctx.exec);
                    let body_exec = Arc::clone(&exec);
                    let real = self.inner.spawn(move || enter_thread(body_exec, tid, f))?;
                    Ok(JoinHandle { real, model: Some((exec, tid)) })
                }
                None => Ok(JoinHandle { real: self.inner.spawn(f)?, model: None }),
            }
        }
    }

    /// Join a model child. During a normal run this is the scheduler's
    /// join edge. During a *panic unwind* (destructors joining worker
    /// threads while the stack burns down) the owning execution is
    /// aborted first, so parked children wake and the subsequent real
    /// join cannot hang the scheduler.
    fn model_join(model: &Option<(Arc<ExecShared>, Tid)>) {
        let Some((exec, tid)) = model else { return };
        if std::thread::panicking() {
            abort_execution(exec, "panic unwound into a join of a live model thread");
            return;
        }
        if let Some(ctx) = cur() {
            if Arc::ptr_eq(&ctx.exec, exec) {
                ctx.join_thread(*tid);
            }
        }
    }

    /// Mirror of [`std::thread::JoinHandle`].
    pub struct JoinHandle<T> {
        real: std::thread::JoinHandle<T>,
        model: Option<(Arc<ExecShared>, Tid)>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            model_join(&self.model);
            self.real.join()
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("failed to spawn thread")
    }

    /// Mirror of [`std::thread::Scope`]. Children spawned here are
    /// model-joined before the underlying real scope joins them, so the
    /// implicit join at scope exit can never block the scheduler.
    pub struct Scope<'scope, 'env: 'scope> {
        inner: &'scope std::thread::Scope<'scope, 'env>,
        model: Option<ScopeModel>,
    }

    struct ScopeModel {
        exec: Arc<ExecShared>,
        children: std::sync::Mutex<Vec<Tid>>,
    }

    impl<'scope, 'env> Scope<'scope, 'env> {
        pub fn spawn<F, T>(&self, f: F) -> ScopedJoinHandle<'scope, T>
        where
            F: FnOnce() -> T + Send + 'scope,
            T: Send + 'scope,
        {
            if let (Some(m), Some(ctx)) = (&self.model, cur()) {
                if Arc::ptr_eq(&ctx.exec, &m.exec) {
                    let tid = ctx.spawn_register("scoped".to_string());
                    m.children.lock().unwrap_or_else(|p| p.into_inner()).push(tid);
                    let exec = Arc::clone(&m.exec);
                    let real = self.inner.spawn(move || enter_thread(exec, tid, f));
                    let model = Some((Arc::clone(&m.exec), tid));
                    return ScopedJoinHandle { real, model };
                }
            }
            ScopedJoinHandle { real: self.inner.spawn(f), model: None }
        }
    }

    /// Mirror of [`std::thread::ScopedJoinHandle`].
    pub struct ScopedJoinHandle<'scope, T> {
        real: std::thread::ScopedJoinHandle<'scope, T>,
        model: Option<(Arc<ExecShared>, Tid)>,
    }

    impl<T> ScopedJoinHandle<'_, T> {
        pub fn join(self) -> std::thread::Result<T> {
            model_join(&self.model);
            self.real.join()
        }
    }

    /// Mirror of [`std::thread::scope`]. The closure's argument type is
    /// `&Scope<'scope, 'env>` with a free outer lifetime (slightly looser
    /// than `std`'s `&'scope Scope<'scope, 'env>`); call sites written
    /// against `std`'s signature work unchanged.
    ///
    /// A panic inside the closure aborts the owning model execution
    /// *before* the real scope's implicit join runs, so parked children
    /// unwind instead of deadlocking the scheduler.
    pub fn scope<'env, F, T>(f: F) -> T
    where
        F: for<'scope> FnOnce(&Scope<'scope, 'env>) -> T,
    {
        let model = cur().map(|ctx| ScopeModel {
            exec: Arc::clone(&ctx.exec),
            children: std::sync::Mutex::new(Vec::new()),
        });
        std::thread::scope(|s| {
            let wrap = Scope { inner: s, model };
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&wrap)));
            if let Some(m) = &wrap.model {
                match &out {
                    Ok(_) => {
                        if let Some(ctx) = cur() {
                            if Arc::ptr_eq(&ctx.exec, &m.exec) {
                                let kids = m
                                    .children
                                    .lock()
                                    .unwrap_or_else(|p| p.into_inner())
                                    .clone();
                                for tid in kids {
                                    ctx.join_thread(tid);
                                }
                            }
                        }
                    }
                    Err(_) => {
                        abort_execution(&m.exec, "panic inside a scoped model region");
                    }
                }
            }
            match out {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            }
        })
    }
}
