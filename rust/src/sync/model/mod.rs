//! The in-crate deterministic model checker behind the `model` feature —
//! a zero-dependency "loom-lite".
//!
//! [`check`] takes a closed concurrent program (a closure that creates its
//! own threads and shared state through [`crate::sync`]) and re-executes
//! it under **every thread schedule up to a preemption bound**. Real OS
//! threads run the program, but a controller grants exactly one thread the
//! right to run at a time; each grant covers one synchronization operation
//! (lock, unlock, condvar wait/notify, atomic access, [`shim::RaceCell`]
//! access, spawn, join) plus the pure computation after it. The controller
//! records every choice point and drives a depth-first search over the
//! alternatives: schedules are replayed decision-for-decision, so the
//! explored program must be deterministic apart from thread timing.
//!
//! Along every schedule the checker maintains **vector clocks**:
//!
//! * thread spawn/join and scoped-thread exit edges,
//! * mutex release → next acquire edges,
//! * atomic release-store/RMW → acquire-load/RMW edges (a `Relaxed` RMW
//!   continues an existing release sequence but never *synchronizes*;
//!   a `Relaxed` store breaks the sequence — matching the C++11 rules
//!   the crate's `Ordering` choices rely on).
//!
//! Every [`shim::RaceCell`] access is checked FastTrack-style against the
//! last write epoch and read clock; two accesses not ordered by
//! happens-before (at least one a write) abort the search with a
//! [`ViolationKind::DataRace`]. An all-threads-blocked state is a
//! [`ViolationKind::Deadlock`] — which is also how a *lost condvar
//! wakeup* surfaces, because the model injects no spurious wakeups: a
//! `wait` that nobody will ever notify blocks forever in some schedule.
//!
//! Limits, so nobody over-trusts a green run: values are read/written
//! sequentially consistently (only the happens-before bookkeeping honors
//! the weaker `Ordering`s), schedules beyond the preemption bound are not
//! explored, and unsafe-code UB is out of scope (Miri's job). See the
//! README's "Concurrency correctness" section for the division of labor.

pub mod shim;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdGuard};

/// Thread index inside one model execution (0 = the program's root).
pub(crate) type Tid = usize;

/// Trace marker for ops that touch no registered object.
const NO_OBJ: usize = usize::MAX;

/// Trace entries retained for violation reports.
const TRACE_KEEP: usize = 48;

// ---------------------------------------------------------------------------
// Vector clocks
// ---------------------------------------------------------------------------

/// A vector clock: component `t` counts the synchronization steps of
/// thread `t` that happen-before the clock's owner.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub(crate) struct VClock(Vec<u32>);

impl VClock {
    fn get(&self, t: Tid) -> u32 {
        self.0.get(t).copied().unwrap_or(0)
    }

    fn set(&mut self, t: Tid, v: u32) {
        if self.0.len() <= t {
            self.0.resize(t + 1, 0);
        }
        self.0[t] = v;
    }

    fn tick(&mut self, t: Tid) {
        let v = self.get(t);
        self.set(t, v + 1);
    }

    fn join(&mut self, other: &VClock) {
        for (t, &v) in other.0.iter().enumerate() {
            if v > self.get(t) {
                self.set(t, v);
            }
        }
    }

    /// `true` iff `other` ≤ `self` pointwise (everything `other` saw
    /// happens-before the owner of `self`).
    fn dominates(&self, other: &VClock) -> bool {
        other.0.iter().enumerate().all(|(t, &v)| v <= self.get(t))
    }
}

// ---------------------------------------------------------------------------
// Per-execution state
// ---------------------------------------------------------------------------

/// What a parked thread wants to do next (the controller grants it only
/// when the op can complete).
#[derive(Clone, Debug, PartialEq, Eq)]
enum Intent {
    /// First grant after spawn, before any user code runs.
    Start,
    /// Non-blocking op (atomic, cell, unlock, notify, spawn, …).
    Plain,
    /// Blocking `Mutex::lock`: runnable only while the mutex is free.
    Lock(usize),
    /// Re-acquire after a condvar notification: same enabling rule.
    Reacquire(usize),
}

/// Why a thread cannot currently be scheduled at all.
#[derive(Clone, Debug, PartialEq, Eq)]
enum WaitOn {
    /// Parked in `Condvar::wait`; only a notify makes it runnable.
    Cv { cv: usize, mutex: usize },
    /// Waiting in `join` for the target thread to finish.
    Join(Tid),
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum TState {
    Ready(Intent),
    Waiting(WaitOn),
    Running,
    Finished,
}

struct ThreadSt {
    state: TState,
    clock: VClock,
    name: String,
}

#[derive(Default)]
struct MutexSt {
    owner: Option<Tid>,
    /// Clock released by the last unlocker; joined by the next acquirer.
    clock: VClock,
}

#[derive(Default)]
struct AtomicSt {
    /// Release-sequence clock: what an acquire access synchronizes with.
    release: VClock,
}

#[derive(Default)]
struct CellSt {
    /// Epoch of the last write (`w_time == 0` means "never written": the
    /// initializing construction happens-before the sharing that follows).
    w_tid: Tid,
    w_time: u32,
    /// Join of all read epochs since the last write.
    reads: VClock,
}

struct ExecState {
    threads: Vec<ThreadSt>,
    mutexes: Vec<MutexSt>,
    atomics: Vec<AtomicSt>,
    cells: Vec<CellSt>,
    condvars: usize,
    /// The one thread currently allowed to run; `None` = controller's turn.
    active: Option<Tid>,
    violation: Option<Violation>,
    /// Tear-down flag: every parked thread unwinds with [`ModelAbort`].
    abort: bool,
    /// Recent granted ops, `(tid, op, object-id)`, for violation reports.
    trace: Vec<(Tid, &'static str, usize)>,
}

struct ExecShared {
    st: StdMutex<ExecState>,
    cv: StdCondvar,
    /// Generation tag: shim objects registered under an older generation
    /// re-register, so state never leaks across executions.
    gen: u64,
}

/// Lock that shrugs off poisoning: the model tears down via panics by
/// design, and its own bookkeeping must stay reachable while doing so.
fn lock_st(e: &ExecShared) -> StdGuard<'_, ExecState> {
    e.st.lock().unwrap_or_else(|p| p.into_inner())
}

/// Panic payload used to unwind controlled threads during tear-down.
pub(crate) struct ModelAbort;

fn abort_unwind() -> ! {
    std::panic::panic_any(ModelAbort)
}

// ---------------------------------------------------------------------------
// Thread-local context: "am I a controlled thread, and of which execution?"
// ---------------------------------------------------------------------------

#[derive(Clone)]
pub(crate) struct Ctx {
    exec: Arc<ExecShared>,
    tid: Tid,
}

thread_local! {
    static CTX: std::cell::RefCell<Option<Ctx>> = const { std::cell::RefCell::new(None) };
}

/// The calling thread's model context, or `None` when it should fall
/// through to plain `std` behavior (outside any `check` run, or while
/// unwinding — tear-down must not re-enter the scheduler).
pub(crate) fn cur() -> Option<Ctx> {
    if std::thread::panicking() {
        return None;
    }
    CTX.with(|c| c.borrow().clone())
}

/// Object classes a shim can register.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Class {
    Mutex,
    Condvar,
    Atomic,
    Cell,
}

impl Ctx {
    /// Resolve a shim object's per-execution id, registering it on first
    /// contact. The packed tag is `(gen << 24) | (id + 1)`; a stale
    /// generation simply re-registers, giving the object fresh state.
    pub(crate) fn register(&self, reg: &AtomicU64, class: Class) -> usize {
        // Relaxed: the tag is only ever written by the single active model
        // thread and re-validated against `gen` on every read.
        let packed = reg.load(Ordering::Relaxed);
        if packed != 0 && packed >> 24 == self.exec.gen {
            return (packed & 0x00FF_FFFF) as usize - 1;
        }
        let mut st = lock_st(&self.exec);
        let id = match class {
            Class::Mutex => {
                st.mutexes.push(MutexSt::default());
                st.mutexes.len() - 1
            }
            Class::Condvar => {
                st.condvars += 1;
                st.condvars - 1
            }
            Class::Atomic => {
                st.atomics.push(AtomicSt::default());
                st.atomics.len() - 1
            }
            Class::Cell => {
                st.cells.push(CellSt::default());
                st.cells.len() - 1
            }
        };
        assert!(id < 0x00FF_FFFF, "model: too many objects of one class");
        // Relaxed: same single-writer argument as the load above.
        reg.store((self.exec.gen << 24) | (id as u64 + 1), Ordering::Relaxed);
        id
    }

    /// Announce the next op and park until the controller grants it.
    /// Returns with the state lock held and this thread marked `Running`;
    /// the caller performs the op's state transition, then drops the guard
    /// and runs free until its next op.
    fn park(&self, intent: Intent, op: &'static str, obj: usize) -> StdGuard<'_, ExecState> {
        let mut st = lock_st(&self.exec);
        st.threads[self.tid].state = TState::Ready(intent);
        st.active = None;
        self.exec.cv.notify_all();
        loop {
            if st.abort {
                drop(st);
                abort_unwind();
            }
            if st.active == Some(self.tid) {
                st.threads[self.tid].state = TState::Running;
                push_trace(&mut st, self.tid, op, obj);
                return st;
            }
            st = self.exec.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Re-park mid-op (state already set by the caller) and wait for the
    /// next grant. Used by blocking loops: cv wait, join, lock retry.
    fn repark<'a>(&'a self, mut st: StdGuard<'a, ExecState>) -> StdGuard<'a, ExecState> {
        st.active = None;
        self.exec.cv.notify_all();
        loop {
            if st.abort {
                drop(st);
                abort_unwind();
            }
            if st.active == Some(self.tid) {
                st.threads[self.tid].state = TState::Running;
                return st;
            }
            st = self.exec.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Raise a violation from a running thread and unwind it.
    fn violate(&self, mut st: StdGuard<'_, ExecState>, kind: ViolationKind, detail: String) -> ! {
        if st.violation.is_none() {
            let v = build_violation(&st, kind, detail);
            st.violation = Some(v);
        }
        st.abort = true;
        self.exec.cv.notify_all();
        drop(st);
        abort_unwind()
    }

    // -- the op vocabulary (called by shims) --------------------------------

    /// An atomic access: `acq`/`rel` describe the happens-before effect of
    /// the chosen `Ordering`, `store` distinguishes a plain store (which
    /// replaces the release sequence) from an RMW (which continues it).
    /// `f` performs the real value operation while the grant is held.
    pub(crate) fn atomic_op<R>(
        &self,
        id: usize,
        op: &'static str,
        acq: bool,
        rel: bool,
        store: bool,
        f: impl FnOnce() -> R,
    ) -> R {
        let mut st = self.park(Intent::Plain, op, id);
        if acq {
            let c = std::mem::take(&mut st.atomics[id].release);
            st.threads[self.tid].clock.join(&c);
            st.atomics[id].release = c;
        }
        let tid = self.tid;
        st.threads[tid].clock.tick(tid);
        if store {
            // A store replaces the release-sequence head: acquirers of the
            // new value synchronize with this store only.
            st.atomics[id].release =
                if rel { st.threads[tid].clock.clone() } else { VClock::default() };
        } else if rel {
            // A release RMW joins into the existing release sequence.
            let c = st.threads[tid].clock.clone();
            st.atomics[id].release.join(&c);
        }
        // A relaxed RMW continues the release sequence untouched.
        f()
    }

    /// A `RaceCell` access: FastTrack-style race check, then run `f` (the
    /// actual data access) while the grant is held, so the model itself
    /// never lets checked accesses overlap in real time.
    pub(crate) fn cell_op<R>(&self, id: usize, write: bool, f: impl FnOnce() -> R) -> R {
        let op = if write { "cell-write" } else { "cell-read" };
        let mut st = self.park(Intent::Plain, op, id);
        let tid = self.tid;
        let (w_tid, w_time) = (st.cells[id].w_tid, st.cells[id].w_time);
        if w_time > 0 && st.threads[tid].clock.get(w_tid) < w_time {
            let detail = format!(
                "{} of cell c{id} by {} races with the write by {}",
                if write { "write" } else { "read" },
                tname(&st, tid),
                tname(&st, w_tid),
            );
            self.violate(st, ViolationKind::DataRace, detail);
        }
        if write {
            let reads = std::mem::take(&mut st.cells[id].reads);
            if !st.threads[tid].clock.dominates(&reads) {
                let detail =
                    format!("write of cell c{id} by {} races with a prior read", tname(&st, tid));
                self.violate(st, ViolationKind::DataRace, detail);
            }
        }
        st.threads[tid].clock.tick(tid);
        let now = st.threads[tid].clock.get(tid);
        if write {
            st.cells[id].w_tid = tid;
            st.cells[id].w_time = now;
        } else {
            st.cells[id].reads.set(tid, now);
        }
        f()
    }

    /// Blocking `Mutex::lock`.
    pub(crate) fn mutex_lock(&self, id: usize) {
        let mut st = self.park(Intent::Lock(id), "lock", id);
        loop {
            if st.mutexes[id].owner.is_none() {
                st.mutexes[id].owner = Some(self.tid);
                let c = st.mutexes[id].clock.clone();
                st.threads[self.tid].clock.join(&c);
                return;
            }
            // The controller only grants `Lock` while the mutex is free,
            // so this retry is defensive; keep it for robustness.
            st.threads[self.tid].state = TState::Ready(Intent::Lock(id));
            st = self.repark(st);
        }
    }

    /// `Mutex` release (runs from the guard's `Drop`).
    pub(crate) fn mutex_unlock(&self, id: usize) {
        let mut st = self.park(Intent::Plain, "unlock", id);
        let tid = self.tid;
        st.threads[tid].clock.tick(tid);
        st.mutexes[id].clock = st.threads[tid].clock.clone();
        st.mutexes[id].owner = None;
    }

    /// `Condvar::wait`: atomically release the mutex and park until some
    /// notify re-readies this thread, then re-acquire.
    pub(crate) fn condvar_wait(&self, cv: usize, mutex: usize) {
        let mut st = self.park(Intent::Plain, "cv-wait", cv);
        let tid = self.tid;
        st.threads[tid].clock.tick(tid);
        st.mutexes[mutex].clock = st.threads[tid].clock.clone();
        st.mutexes[mutex].owner = None;
        st.threads[tid].state = TState::Waiting(WaitOn::Cv { cv, mutex });
        st = self.repark(st);
        loop {
            if st.mutexes[mutex].owner.is_none() {
                st.mutexes[mutex].owner = Some(tid);
                let c = st.mutexes[mutex].clock.clone();
                st.threads[tid].clock.join(&c);
                return;
            }
            st.threads[tid].state = TState::Ready(Intent::Reacquire(mutex));
            st = self.repark(st);
        }
    }

    /// `Condvar::notify_one` / `notify_all`. Waiters move to "re-acquire
    /// the mutex"; a notify with no waiters is lost, exactly like the real
    /// primitive — which is what makes lost-wakeup bugs findable.
    pub(crate) fn condvar_notify(&self, cv: usize, all: bool) {
        let op = if all { "notify-all" } else { "notify-one" };
        let mut st = self.park(Intent::Plain, op, cv);
        for th in st.threads.iter_mut() {
            if let TState::Waiting(WaitOn::Cv { cv: c, mutex }) = th.state {
                if c == cv {
                    th.state = TState::Ready(Intent::Reacquire(mutex));
                    if !all {
                        break;
                    }
                }
            }
        }
    }

    /// Register a child thread (the spawn edge). Returns its tid; the
    /// caller then really spawns it with [`enter_thread`] at its top.
    pub(crate) fn spawn_register(&self, name: String) -> Tid {
        let mut st = self.park(Intent::Plain, "spawn", NO_OBJ);
        let tid = self.tid;
        st.threads[tid].clock.tick(tid);
        let clock = st.threads[tid].clock.clone();
        st.threads.push(ThreadSt { state: TState::Ready(Intent::Start), clock, name });
        st.threads.len() - 1
    }

    /// Block until `target` finishes, then absorb its clock (join edge).
    pub(crate) fn join_thread(&self, target: Tid) {
        let mut st = self.park(Intent::Plain, "join", target);
        loop {
            if st.threads[target].state == TState::Finished {
                let c = st.threads[target].clock.clone();
                st.threads[self.tid].clock.join(&c);
                return;
            }
            st.threads[self.tid].state = TState::Waiting(WaitOn::Join(target));
            st = self.repark(st);
        }
    }
}

/// Abort an execution from *outside* the normal op protocol — used when a
/// panic unwind is about to perform a real join on threads that are still
/// parked in the scheduler. Records the panic as a violation (if nothing
/// was recorded yet), raises the abort flag, and wakes everyone so parked
/// threads unwind and real joins complete.
pub(crate) fn abort_execution(exec: &Arc<ExecShared>, why: &str) {
    let mut st = lock_st(exec);
    if st.violation.is_none() {
        let v = build_violation(&st, ViolationKind::Panic, why.to_string());
        st.violation = Some(v);
    }
    st.abort = true;
    exec.cv.notify_all();
}

/// Body wrapper for every controlled thread: installs the context, waits
/// for its start grant, runs `f`, and performs finish bookkeeping (wake
/// joiners, record panics as violations, re-raise the payload).
pub(crate) fn enter_thread<T>(exec: Arc<ExecShared>, tid: Tid, f: impl FnOnce() -> T) -> T {
    let ctx = Ctx { exec, tid };
    CTX.with(|c| *c.borrow_mut() = Some(ctx.clone()));
    // Start grant: the spawn edge already seeded our clock.
    {
        let mut st = lock_st(&ctx.exec);
        loop {
            if st.abort {
                drop(st);
                CTX.with(|c| *c.borrow_mut() = None);
                abort_unwind();
            }
            if st.active == Some(tid) {
                st.threads[tid].state = TState::Running;
                push_trace(&mut st, tid, "start", NO_OBJ);
                break;
            }
            st = ctx.exec.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    let mut st = lock_st(&ctx.exec);
    if let Err(p) = &out {
        if !p.is::<ModelAbort>() && st.violation.is_none() {
            let detail = format!("{} panicked: {}", tname(&st, tid), payload_msg(p));
            let v = build_violation(&st, ViolationKind::Panic, detail);
            st.violation = Some(v);
            st.abort = true;
        }
    }
    st.threads[tid].clock.tick(tid);
    st.threads[tid].state = TState::Finished;
    for th in st.threads.iter_mut() {
        if th.state == TState::Waiting(WaitOn::Join(tid)) {
            th.state = TState::Ready(Intent::Plain);
        }
    }
    st.active = None;
    ctx.exec.cv.notify_all();
    drop(st);
    CTX.with(|c| *c.borrow_mut() = None);
    match out {
        Ok(v) => v,
        Err(p) => std::panic::resume_unwind(p),
    }
}

fn payload_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

fn tname(st: &ExecState, tid: Tid) -> String {
    format!("t{tid}({})", st.threads[tid].name)
}

fn push_trace(st: &mut ExecState, tid: Tid, op: &'static str, obj: usize) {
    if st.trace.len() >= 2 * TRACE_KEEP {
        st.trace.drain(..TRACE_KEEP);
    }
    st.trace.push((tid, op, obj));
}

// ---------------------------------------------------------------------------
// Violations and reports
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ViolationKind {
    /// Two `RaceCell` accesses, at least one a write, with no
    /// happens-before edge between them.
    DataRace,
    /// Every live thread blocked (includes lost condvar wakeups).
    Deadlock,
    /// User code panicked (an assertion failed in some schedule).
    Panic,
    /// A single schedule exceeded the step cap (livelock guard).
    Livelock,
    /// The schedule cap was hit before the search completed.
    SchedulesExhausted,
}

/// A counterexample: what went wrong, and the schedule's recent op trace.
#[derive(Clone, Debug)]
pub struct Violation {
    pub kind: ViolationKind,
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}: {}", self.kind, self.message)
    }
}

fn build_violation(st: &ExecState, kind: ViolationKind, detail: String) -> Violation {
    let mut msg = detail;
    msg.push_str("\n  threads:");
    for (t, th) in st.threads.iter().enumerate() {
        let state = match &th.state {
            TState::Ready(i) => format!("ready {i:?}"),
            TState::Waiting(w) => format!("waiting {w:?}"),
            TState::Running => "running".to_string(),
            TState::Finished => "finished".to_string(),
        };
        msg.push_str(&format!("\n    t{t}({}): {state}", th.name));
    }
    msg.push_str("\n  recent ops (oldest first):");
    let tail = st.trace.len().saturating_sub(TRACE_KEEP);
    for &(t, op, obj) in &st.trace[tail..] {
        if obj == NO_OBJ {
            msg.push_str(&format!("\n    t{t} {op}"));
        } else {
            msg.push_str(&format!("\n    t{t} {op} #{obj}"));
        }
    }
    Violation { kind, message: msg }
}

/// Statistics from a completed (violation-free) search.
#[derive(Clone, Copy, Debug)]
pub struct Report {
    /// Schedules fully executed.
    pub schedules: u64,
    /// Longest schedule, in scheduler grants.
    pub max_steps: u64,
}

/// Search configuration. `Default` reads the `ASTIR_MODEL_*` env knobs so
/// CI can bound runtime without touching test code.
#[derive(Clone, Copy, Debug)]
pub struct ModelOpts {
    /// Maximum involuntary context switches per schedule
    /// (`ASTIR_MODEL_PREEMPTIONS`, default 2).
    pub preemption_bound: usize,
    /// Abort the search after this many schedules
    /// (`ASTIR_MODEL_MAX_SCHEDULES`, default 2,000,000).
    pub max_schedules: u64,
    /// Per-schedule grant cap — a livelock guard
    /// (`ASTIR_MODEL_MAX_STEPS`, default 100,000).
    pub max_steps: u64,
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl Default for ModelOpts {
    fn default() -> Self {
        ModelOpts {
            preemption_bound: env_u64("ASTIR_MODEL_PREEMPTIONS", 2) as usize,
            max_schedules: env_u64("ASTIR_MODEL_MAX_SCHEDULES", 2_000_000),
            max_steps: env_u64("ASTIR_MODEL_MAX_STEPS", 100_000),
        }
    }
}

// ---------------------------------------------------------------------------
// Mutation hooks (the "does the checker have teeth" witness)
// ---------------------------------------------------------------------------

// Process-global because the weakened ordering must be visible on pool
// worker threads, not just the test thread. Tests that flip it serialize
// themselves (see tests/model_check.rs).
static WEAKEN_POOL_PENDING: AtomicBool = AtomicBool::new(false);

/// Make [`crate::service`]'s `pending` countdown use `Relaxed` instead of
/// `AcqRel` inside the model — the mutation-witness tests prove the
/// checker reports the resulting race. No effect outside `model` builds.
pub fn set_weaken_pool_pending(on: bool) {
    // SeqCst: a test knob flipped around whole model runs; cost is nil and
    // the strongest ordering keeps the flip unambiguous.
    WEAKEN_POOL_PENDING.store(on, Ordering::SeqCst);
}

/// Read the mutation knob (see [`set_weaken_pool_pending`]).
pub fn weaken_pool_pending() -> bool {
    // SeqCst: see `set_weaken_pool_pending`.
    WEAKEN_POOL_PENDING.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// The controller: DFS over schedules
// ---------------------------------------------------------------------------

/// One choice point: the candidate threads (first = "continue the current
/// thread" when possible) and which one this schedule took.
struct Decision {
    cands: Vec<Tid>,
    idx: usize,
}

static GEN: AtomicU64 = AtomicU64::new(1);

/// Explore `f` under all schedules up to the preemption bound; panic with
/// the counterexample on any violation. See [`check_with`].
pub fn check(f: impl Fn() + Send + Sync + 'static) -> Report {
    match check_with(&ModelOpts::default(), f) {
        Ok(r) => r,
        Err(v) => panic!("model check failed\n{v}"),
    }
}

/// Explore `f` under all thread schedules with at most
/// `opts.preemption_bound` involuntary switches, re-running it once per
/// schedule. Returns search statistics, or the first violation found.
///
/// `f` must be a *closed, deterministic* program: it creates its own
/// threads and shared state (through [`crate::sync`]) and leaves nothing
/// running. State created outside `f` and mutated inside it breaks replay
/// determinism and is reported as such.
pub fn check_with(
    opts: &ModelOpts,
    f: impl Fn() + Send + Sync + 'static,
) -> Result<Report, Violation> {
    assert!(cur().is_none(), "model::check may not be nested inside a model run");
    let f = Arc::new(f);
    let mut trail: Vec<Decision> = Vec::new();
    let mut report = Report { schedules: 0, max_steps: 0 };
    loop {
        if report.schedules >= opts.max_schedules {
            return Err(Violation {
                kind: ViolationKind::SchedulesExhausted,
                message: format!(
                    "search stopped after {} schedules (ASTIR_MODEL_MAX_SCHEDULES); \
                     shrink the program or raise the cap",
                    report.schedules
                ),
            });
        }
        report.schedules += 1;
        let (violation, steps) = run_one_schedule(opts, &f, &mut trail);
        report.max_steps = report.max_steps.max(steps);
        if let Some(mut v) = violation {
            v.message.push_str(&format!("\n  (schedule #{})", report.schedules));
            return Err(v);
        }
        // Backtrack: advance the deepest decision with an untried
        // candidate; drop everything below it.
        loop {
            match trail.last_mut() {
                None => return Ok(report),
                Some(d) if d.idx + 1 < d.cands.len() => {
                    d.idx += 1;
                    break;
                }
                Some(_) => {
                    trail.pop();
                }
            }
        }
    }
}

/// Execute one schedule: replay the decisions already in `trail`, then
/// extend it greedily (always preferring to continue the running thread).
fn run_one_schedule(
    opts: &ModelOpts,
    f: &Arc<impl Fn() + Send + Sync + 'static>,
    trail: &mut Vec<Decision>,
) -> (Option<Violation>, u64) {
    let exec = Arc::new(ExecShared {
        st: StdMutex::new(ExecState {
            threads: vec![ThreadSt {
                state: TState::Ready(Intent::Start),
                clock: VClock::default(),
                name: "root".to_string(),
            }],
            mutexes: Vec::new(),
            atomics: Vec::new(),
            cells: Vec::new(),
            condvars: 0,
            active: None,
            violation: None,
            abort: false,
            trace: Vec::new(),
        }),
        cv: StdCondvar::new(),
        // SeqCst: one increment per schedule; uniqueness is all that matters.
        gen: GEN.fetch_add(1, Ordering::SeqCst),
    });
    let root = {
        let exec = Arc::clone(&exec);
        let f = Arc::clone(f);
        std::thread::Builder::new()
            .name("astir-model-root".into())
            .spawn(move || enter_thread(exec, 0, move || f()))
            .expect("spawn model root thread")
    };
    let mut steps: u64 = 0;
    let mut depth = 0usize; // next index into `trail`
    let mut prev: Option<Tid> = None;
    let mut preemptions = 0usize;
    loop {
        let mut st = lock_st(&exec);
        while st.active.is_some() && !st.abort {
            st = exec.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        if st.violation.is_some() || st.abort {
            drain(&exec, st);
            break;
        }
        if st.threads.iter().all(|t| t.state == TState::Finished) {
            drop(st);
            break;
        }
        let enabled: Vec<Tid> = st
            .threads
            .iter()
            .enumerate()
            .filter_map(|(t, th)| match &th.state {
                TState::Ready(Intent::Start) | TState::Ready(Intent::Plain) => Some(t),
                TState::Ready(Intent::Lock(m)) | TState::Ready(Intent::Reacquire(m)) => {
                    st.mutexes[*m].owner.is_none().then_some(t)
                }
                TState::Waiting(_) | TState::Finished => None,
                TState::Running => unreachable!("a thread is running while the controller decides"),
            })
            .collect();
        if enabled.is_empty() {
            let v = build_violation(
                &st,
                ViolationKind::Deadlock,
                "all live threads are blocked (deadlock or lost wakeup)".to_string(),
            );
            st.violation = Some(v);
            drain(&exec, st);
            break;
        }
        steps += 1;
        if steps > opts.max_steps {
            let v = build_violation(
                &st,
                ViolationKind::Livelock,
                format!("schedule exceeded {} grants (ASTIR_MODEL_MAX_STEPS)", opts.max_steps),
            );
            st.violation = Some(v);
            drain(&exec, st);
            break;
        }
        // Candidate order: continue `prev` first; alternatives only while
        // the preemption budget lasts. A blocked/finished `prev` makes the
        // switch involuntary, which costs nothing.
        let mut cands: Vec<Tid>;
        let has_prev = prev.is_some_and(|p| enabled.contains(&p));
        if has_prev {
            let p = prev.expect("has_prev");
            cands = vec![p];
            if preemptions < opts.preemption_bound {
                cands.extend(enabled.iter().copied().filter(|&t| t != p));
            }
        } else {
            cands = enabled;
        }
        let choice = if depth < trail.len() {
            let d = &trail[depth];
            assert!(
                d.cands == cands,
                "model program is nondeterministic: replay diverged at step {steps} \
                 (expected candidates {:?}, recomputed {:?})",
                d.cands,
                cands
            );
            d.cands[d.idx]
        } else {
            trail.push(Decision { cands: cands.clone(), idx: 0 });
            cands[0]
        };
        if has_prev && Some(choice) != prev {
            preemptions += 1;
        }
        depth += 1;
        prev = Some(choice);
        st.active = Some(choice);
        exec.cv.notify_all();
        drop(st);
    }
    // All controlled threads have finished or are unwinding; the root
    // OS thread (which transitively owns the others) is ready to join.
    let joined = root.join();
    let violation = {
        let mut st = lock_st(&exec);
        if st.violation.is_none() {
            if let Err(p) = &joined {
                if !p.is::<ModelAbort>() {
                    st.violation = Some(Violation {
                        kind: ViolationKind::Panic,
                        message: format!("root thread panicked: {}", payload_msg(p.as_ref())),
                    });
                }
            }
        }
        st.violation.take()
    };
    (violation, steps)
}

/// Tear down a schedule: set the abort flag and wake every parked thread
/// so it unwinds with [`ModelAbort`].
fn drain(exec: &ExecShared, mut st: StdGuard<'_, ExecState>) {
    st.abort = true;
    exec.cv.notify_all();
    drop(st);
}
