//! Experiment drivers — one per figure/ablation (see README.md for the map).
//!
//! Each driver is a pure function from an [`ExperimentConfig`] to a
//! [`Table`], shared by the CLI (`astir fig1`, …) and the `cargo bench`
//! targets, so the regenerated series are identical however they are
//! invoked.

pub mod ablations;
pub mod baselines;
pub mod fig1;
pub mod fig2;

pub use ablations::{block_size_sweep, inconsistent_reads, tally_vs_shared_x, tally_weighting};
pub use baselines::phase_transition;
pub use fig1::fig1;
pub use fig2::{fig2, Fig2Variant};
