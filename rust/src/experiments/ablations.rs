//! Ablations A1–A4 — design choices the paper argues for in
//! prose, each turned into a measured comparison.

use crate::config::ExperimentConfig;
use crate::coordinator::Leader;
use crate::metrics::{stats, Table};
use crate::sim::{SharingMode, SimOpts, SpeedSchedule};
use crate::tally::TallyWeighting;

/// A1 — tally sharing (Alg. 2) vs HOGWILD!-style shared iterate.
///
/// The paper's §I argument: with a dense cost function, sharing `x` makes
/// overwrites frequent and lets slow cores undo fast cores' progress;
/// sharing the passively-used tally is robust. Output columns:
/// `cores, tally_mean, tally_conv, sharedx_mean, sharedx_conv`.
pub fn tally_vs_shared_x(cfg: &ExperimentConfig) -> Table {
    let leader = Leader::new(cfg.clone());
    let mk_opts = |mode: SharingMode| SimOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_steps: cfg.max_iters,
        mode,
        ..Default::default()
    };
    // Slow cores make the overwrite hazard visible (paper's motivation).
    let schedule = SpeedSchedule::HalfSlow { period: 4 };

    let mut table =
        Table::new(&["cores", "tally_mean", "tally_conv", "sharedx_mean", "sharedx_conv"]);
    for &c in &cfg.cores {
        let tally = leader.monte_carlo_sim(c, &schedule, &mk_opts(SharingMode::Tally));
        let shared = leader.monte_carlo_sim(c, &schedule, &mk_opts(SharingMode::SharedX));
        let mean = |outs: &[crate::sim::SimOutcome]| {
            stats(&outs.iter().map(|o| o.steps as f64).collect::<Vec<_>>()).mean
        };
        let conv = |outs: &[crate::sim::SimOutcome]| {
            outs.iter().filter(|o| o.converged).count() as f64 / outs.len() as f64
        };
        table.push_row(vec![c as f64, mean(&tally), conv(&tally), mean(&shared), conv(&shared)]);
    }
    table
}

/// A2 — inconsistent reads of the tally.
///
/// Sweeps the per-coordinate staleness probability of each `φ` read at a
/// fixed core count (the largest configured). The paper's §III hope is
/// that the algorithm is robust because `φ` is used passively; this
/// measures the cost. Output: `stale_prob, steps_mean, steps_std, conv`.
pub fn inconsistent_reads(cfg: &ExperimentConfig) -> Table {
    let leader = Leader::new(cfg.clone());
    let cores = *cfg.cores.iter().max().expect("validated nonempty");
    let probs = [0.0, 0.1, 0.25, 0.5, 0.75, 1.0];

    let mut table = Table::new(&["stale_prob", "steps_mean", "steps_std", "conv"]);
    for &p in &probs {
        let opts = SimOpts {
            gamma: cfg.gamma,
            tolerance: cfg.tolerance,
            max_steps: cfg.max_iters,
            stale_read_prob: p,
            ..Default::default()
        };
        let outs = leader.monte_carlo_sim(cores, &SpeedSchedule::AllFast, &opts);
        let st = stats(&outs.iter().map(|o| o.steps as f64).collect::<Vec<_>>());
        let conv = outs.iter().filter(|o| o.converged).count() as f64 / outs.len() as f64;
        table.push_row(vec![p, st.mean, st.std, conv]);
    }
    table
}

/// A3 — tally weighting schemes (paper `+t/−(t−1)` vs unweighted vs
/// no-decrement), under the slow-core schedule where weighting matters.
/// Output: `cores, progress_mean, unit_mean, nodecr_mean` (+ conv columns).
pub fn tally_weighting(cfg: &ExperimentConfig) -> Table {
    let leader = Leader::new(cfg.clone());
    let schedule = SpeedSchedule::HalfSlow { period: 4 };
    let weightings = [
        ("progress", TallyWeighting::Progress),
        ("unit", TallyWeighting::Unit),
        ("nodecr", TallyWeighting::NoDecrement),
    ];

    let mut table = Table::new(&[
        "cores",
        "progress_mean",
        "progress_conv",
        "unit_mean",
        "unit_conv",
        "nodecr_mean",
        "nodecr_conv",
    ]);
    for &c in &cfg.cores {
        let mut row = vec![c as f64];
        for (_, w) in weightings {
            let opts = SimOpts {
                gamma: cfg.gamma,
                tolerance: cfg.tolerance,
                max_steps: cfg.max_iters,
                weighting: w,
                ..Default::default()
            };
            let outs = leader.monte_carlo_sim(c, &schedule, &opts);
            let st = stats(&outs.iter().map(|o| o.steps as f64).collect::<Vec<_>>());
            let conv = outs.iter().filter(|o| o.converged).count() as f64 / outs.len() as f64;
            row.push(st.mean);
            row.push(conv);
        }
        table.push_row(row);
    }
    table
}

/// A4 — block size sweep for sequential StoIHT (the paper notes the
/// recovery error depends on `b`, deferring to [22]). Sweeps divisors of
/// `m`; output: `b, iters_mean, iters_std, conv`.
pub fn block_size_sweep(cfg: &ExperimentConfig, block_sizes: &[usize]) -> Table {
    let mut table = Table::new(&["b", "iters_mean", "iters_std", "conv"]);
    for &b in block_sizes {
        assert_eq!(cfg.problem.m % b, 0, "b={b} must divide m={}", cfg.problem.m);
        let mut cfg_b = cfg.clone();
        cfg_b.problem.b = b;
        let leader = Leader::new(cfg_b.clone());
        let runs = leader.monte_carlo_stoiht(&leader.greedy_opts());
        let st = stats(&runs.iter().map(|r| r.iters as f64).collect::<Vec<_>>());
        let conv = runs.iter().filter(|r| r.converged).count() as f64 / runs.len() as f64;
        table.push_row(vec![b as f64, st.mean, st.std, conv]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            problem: ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() },
            trials: 6,
            max_iters: 1500,
            cores: vec![2, 6],
            trial_threads: 4,
            ..Default::default()
        }
    }

    #[test]
    fn a1_tally_beats_shared_x_with_slow_cores() {
        let table = tally_vs_shared_x(&small_cfg());
        assert_eq!(table.rows.len(), 2);
        // At the larger core count the tally variant must converge at
        // least as reliably as the shared-x strawman.
        let last = table.rows.last().unwrap();
        let (tally_conv, sharedx_conv) = (last[2], last[4]);
        assert!(tally_conv >= sharedx_conv, "tally {tally_conv} vs sharedx {sharedx_conv}");
        assert!(tally_conv > 0.8);
    }

    #[test]
    fn a2_staleness_grid() {
        let mut cfg = small_cfg();
        cfg.trials = 4;
        let table = inconsistent_reads(&cfg);
        assert_eq!(table.rows.len(), 6);
        // Zero staleness must converge.
        assert!(table.rows[0][3] > 0.7);
    }

    #[test]
    fn a3_weightings_all_converge_on_easy_problem() {
        let mut cfg = small_cfg();
        cfg.trials = 4;
        cfg.cores = vec![4];
        let table = tally_weighting(&cfg);
        assert_eq!(table.rows.len(), 1);
        let row = &table.rows[0];
        for conv_col in [2, 4, 6] {
            assert!(row[conv_col] > 0.5, "col {conv_col}: {}", row[conv_col]);
        }
    }

    #[test]
    fn a4_block_sizes_run() {
        let mut cfg = small_cfg();
        cfg.trials = 4;
        let table = block_size_sweep(&cfg, &[4, 8, 16]);
        assert_eq!(table.rows.len(), 3);
        for row in &table.rows {
            assert!(row[3] > 0.5, "b={} conv={}", row[0], row[3]);
        }
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn a4_rejects_non_divisor() {
        block_size_sweep(&small_cfg(), &[7]);
    }
}
