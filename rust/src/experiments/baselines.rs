//! A5 — baseline field: phase-transition sweep over the measurement count
//! for every recovery algorithm in the crate (IHT, StoIHT, OMP, CoSaMP,
//! StoGradMP).
//!
//! For each `m` in the sweep, run `cfg.trials` fresh instances and record
//! each solver's success rate (relative recovery error < 1e-4) — the
//! classic compressed-sensing phase-transition curves the paper's §II
//! situates itself in.

use crate::algorithms::{cosamp, iht, omp, stogradmp, stoiht, GreedyOpts};
use crate::config::ExperimentConfig;
use crate::coordinator::run_trials;
use crate::metrics::Table;
use crate::problem::ProblemSpec;

/// Success threshold on relative recovery error.
pub const SUCCESS_REL_ERR: f64 = 1e-4;

/// Sweep `m` over `ms`; returns columns
/// `m, iht, stoiht, omp, cosamp, stogradmp` (success rates in [0, 1]).
pub fn phase_transition(cfg: &ExperimentConfig, ms: &[usize]) -> Table {
    let mut table = Table::new(&["m", "iht", "stoiht", "omp", "cosamp", "stogradmp"]);
    for &m in ms {
        let spec = ProblemSpec { m, b: pick_block(m, cfg.problem.b), ..cfg.problem.clone() };
        spec.validate().expect("swept spec invalid");
        let opts = GreedyOpts {
            gamma: cfg.gamma,
            tolerance: cfg.tolerance,
            max_iters: cfg.max_iters,
            ..Default::default()
        };
        let cosamp_opts = GreedyOpts { max_iters: 100, ..opts.clone() };

        // success counts per algorithm
        let results = run_trials(cfg.trials, cfg.trial_threads, cfg.seed ^ m as u64, |_i, rng| {
            let p = spec.generate(rng);
            let mut r1 = rng.split(1);
            let mut r2 = rng.split(2);
            let ok = |x: &[f64]| (p.relative_error(x) < SUCCESS_REL_ERR) as u32;
            [
                ok(&iht(&p, &opts).x),
                ok(&stoiht(&p, &opts, &mut r1).x),
                ok(&omp(&p, &opts).x),
                ok(&cosamp(&p, &cosamp_opts).x),
                ok(&stogradmp(&p, &cosamp_opts, &mut r2).x),
            ]
        });
        let mut row = vec![m as f64];
        for alg in 0..5 {
            let succ: u32 = results.iter().map(|r| r[alg]).sum();
            row.push(succ as f64 / cfg.trials as f64);
        }
        table.push_row(row);
    }
    table
}

/// Largest divisor of `m` that is `<= preferred` (keeps the block count
/// integral as `m` sweeps).
fn pick_block(m: usize, preferred: usize) -> usize {
    (1..=preferred.min(m)).rev().find(|b| m % b == 0).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            problem: ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() },
            trials: 4,
            max_iters: 1500,
            trial_threads: 4,
            ..Default::default()
        }
    }

    #[test]
    fn pick_block_prefers_divisors() {
        assert_eq!(pick_block(48, 8), 8);
        assert_eq!(pick_block(50, 8), 5);
        assert_eq!(pick_block(7, 8), 7);
        assert_eq!(pick_block(13, 4), 1);
    }

    #[test]
    fn phase_transition_monotone_ends() {
        // Success should be ~0 with far too few measurements and ~1 with
        // plenty, for every algorithm.
        let cfg = small_cfg();
        let table = phase_transition(&cfg, &[8, 72]);
        assert_eq!(table.rows.len(), 2);
        let low = &table.rows[0];
        let high = &table.rows[1];
        for alg in 1..6 {
            assert!(low[alg] <= 0.5, "alg {alg} at m=8: {}", low[alg]);
            assert!(high[alg] >= 0.75, "alg {alg} at m=72: {}", high[alg]);
        }
    }
}
