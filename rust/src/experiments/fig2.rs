//! Figure 2 — time steps to convergence vs number of cores.
//!
//! Upper plot: all cores fast (one Alg.-2 iteration per time step each).
//! Lower plot: half the cores slow (one iteration per four time steps).
//! Both plots show mean ± 1σ over `cfg.trials` (paper: 500) of the number
//! of time steps until the **first** core exits, against a horizontal
//! line for standard StoIHT (whose iterations are time steps by
//! definition).
//!
//! Expected shape (paper): upper — async strictly below standard for every
//! core count; lower — no gain at c = 2, gains for larger c.

use crate::config::ExperimentConfig;
use crate::coordinator::Leader;
use crate::metrics::{stats, Table};
use crate::sim::{SimOpts, SpeedSchedule};

/// Which panel of Fig. 2 to regenerate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig2Variant {
    /// Upper panel: all cores fast.
    Upper,
    /// Lower panel: half the cores complete one iteration per `period`
    /// time steps (paper: 4).
    Lower { period: usize },
}

impl Fig2Variant {
    pub fn schedule(&self) -> SpeedSchedule {
        match self {
            Fig2Variant::Upper => SpeedSchedule::AllFast,
            Fig2Variant::Lower { period } => SpeedSchedule::HalfSlow { period: *period },
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Fig2Variant::Upper => "all cores fast (Fig. 2 upper)",
            Fig2Variant::Lower { .. } => "half cores slow (Fig. 2 lower)",
        }
    }
}

/// Run the Fig.-2 experiment. Returns a table with columns
/// `cores, async_mean, async_std, async_conv, stoiht_mean, stoiht_std`.
///
/// The sequential columns repeat the same (core-count independent)
/// statistics on every row — they are the horizontal line of the figure.
/// Both the line and the sweep honor `cfg.alg`, so the same driver
/// regenerates the paper's StoIHT panels *and* the asynchronous-StoGradMP
/// analogue (`astir fig2 --alg stogradmp`); the column names keep the
/// paper's `stoiht_*` labels for results-schema stability.
pub fn fig2(cfg: &ExperimentConfig, variant: Fig2Variant) -> Table {
    let leader = Leader::new(cfg.clone());
    let sim_opts = SimOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_steps: cfg.max_iters, // the paper's 1500-iteration cap, in steps
        ..Default::default()
    };

    // Horizontal line: sequential iterations-to-exit for the configured
    // algorithm.
    let std_runs = leader.monte_carlo_seq(&leader.greedy_opts());
    let std_steps: Vec<f64> = std_runs.iter().map(|r| r.iters as f64).collect();
    let std_stats = stats(&std_steps);

    let schedule = variant.schedule();
    let points = leader.sweep_cores(&schedule, &sim_opts);

    let mut table = Table::new(&[
        "cores", "async_mean", "async_std", "async_conv", "stoiht_mean", "stoiht_std",
    ]);
    for p in points {
        table.push_row(vec![
            p.param,
            p.steps.mean,
            p.steps.std,
            p.convergence_rate,
            std_stats.mean,
            std_stats.std,
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            problem: ProblemSpec { n: 96, m: 48, b: 8, s: 4, ..ProblemSpec::tiny() },
            trials: 10,
            max_iters: 1500,
            cores: vec![1, 4, 8],
            trial_threads: 4,
            ..Default::default()
        }
    }

    #[test]
    fn upper_panel_shape() {
        let table = fig2(&small_cfg(), Fig2Variant::Upper);
        assert_eq!(table.rows.len(), 3);
        // Reproduced shape (see the reproduction notes in README.md): async improves with
        // core count and sits at or below standard for the larger counts;
        // small-c means may exceed standard by the union overhead.
        let std_mean = table.rows[0][4];
        for row in &table.rows {
            assert!(row[3] > 0.7, "convergence rate too low: {}", row[3]);
            assert!(row[1] < 1.5 * std_mean, "async {} vs std {std_mean}", row[1]);
        }
        // more cores helps: c=8 mean < c=1 mean
        assert!(table.rows[2][1] < table.rows[0][1]);
        // and the largest core count is competitive with standard
        assert!(table.rows[2][1] <= 1.15 * std_mean, "{} vs {std_mean}", table.rows[2][1]);
    }

    #[test]
    fn lower_panel_runs() {
        let mut cfg = small_cfg();
        cfg.cores = vec![2, 8];
        cfg.trials = 6;
        let table = fig2(&cfg, Fig2Variant::Lower { period: 4 });
        assert_eq!(table.rows.len(), 2);
        for row in &table.rows {
            assert!(row[3] > 0.5, "convergence {}", row[3]);
        }
    }

    #[test]
    fn stogradmp_alg_selector_runs_end_to_end() {
        let mut cfg = small_cfg();
        cfg.alg = crate::algorithms::Alg::StoGradMp;
        cfg.trials = 4;
        cfg.cores = vec![1, 4];
        cfg.max_iters = 150;
        let table = fig2(&cfg, Fig2Variant::Upper);
        assert_eq!(table.rows.len(), 2);
        for row in &table.rows {
            assert!(row[3] > 0.7, "convergence {}", row[3]);
        }
        // the horizontal line is sequential StoGradMP: tens of iterations
        assert!(table.rows[0][4] < 100.0, "seq mean {}", table.rows[0][4]);
    }

    #[test]
    fn variant_labels_and_schedules() {
        assert_eq!(Fig2Variant::Upper.schedule(), SpeedSchedule::AllFast);
        assert_eq!(
            Fig2Variant::Lower { period: 4 }.schedule(),
            SpeedSchedule::HalfSlow { period: 4 }
        );
        assert!(Fig2Variant::Upper.label().contains("upper"));
    }
}
