//! Figure 1 — StoIHT with an accurate support estimate.
//!
//! Reproduces the paper's first experiment: standard StoIHT (Alg. 1)
//! against the modified StoIHT whose estimate step projects onto
//! `Γ^t ∪ T̃` for a *fixed* oracle estimate `T̃` of accuracy
//! `α = |T̃ ∩ T| / s ∈ {0, 0.25, 0.5, 0.75, 1}`. Output: mean recovery
//! error `‖x^t − x‖₂` per iteration over `cfg.trials` trials (paper: 50).
//!
//! Expected shape (paper): curves with α > 0.5 converge in fewer
//! iterations; α = 1 needs roughly **half** the iterations of standard
//! StoIHT; α = 0 is slower than standard.

use crate::algorithms::{make_oracle, stoiht, stoiht_with_oracle};
use crate::config::ExperimentConfig;
use crate::coordinator::run_trials;
use crate::metrics::{mean_trace, Table, Trace};

/// The α grid of the paper's Fig. 1.
pub const ALPHAS: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];

/// Fig.-1 outputs: the mean-error series (the figure itself) plus a
/// per-variant convergence summary (mean traces plateau when a minority of
/// trials stall, so the summary separates rate from speed).
pub struct Fig1Output {
    /// Columns `iteration, stoiht, alpha_0, …, alpha_100` — mean error.
    pub series: Table,
    /// Columns `variant(0=stoiht,1..=alphas), conv_rate, iters_mean_conv,
    /// iters_median_conv` over trials that reached the tolerance.
    pub summary: Table,
}

/// Run the Fig.-1 experiment (see [`Fig1Output`]).
pub fn fig1(cfg: &ExperimentConfig) -> Fig1Output {
    let opts = crate::algorithms::GreedyOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_iters: cfg.max_iters,
        record_error: true,
        ..Default::default()
    };

    // Each trial returns per-variant (trace, converged, iters); paired
    // common-random-numbers design: same problem instance per trial for
    // every variant, independent solver streams. Variant 0 = standard.
    let per_trial = run_trials(cfg.trials, cfg.trial_threads, cfg.seed, |_i, rng| {
        let problem = cfg.problem.generate(rng);
        let mut solver_rng = rng.split(1);
        let std_run = stoiht(&problem, &opts, &mut solver_rng);
        let mut outs: Vec<(Trace, bool, usize)> =
            vec![(std_run.error_trace, std_run.converged, std_run.iters)];
        for (k, &alpha) in ALPHAS.iter().enumerate() {
            let mut oracle_rng = rng.split(100 + k as u64);
            let oracle = make_oracle(&problem, alpha, &mut oracle_rng);
            let mut srng = rng.split(200 + k as u64);
            let run = stoiht_with_oracle(&problem, &opts, &mut srng, &oracle);
            outs.push((run.error_trace, run.converged, run.iters));
        }
        outs
    });

    let n_variants = ALPHAS.len() + 1;
    let mut summary = Table::new(&["variant", "conv_rate", "iters_mean_conv", "iters_median_conv"]);
    for v in 0..n_variants {
        let conv: Vec<f64> = per_trial
            .iter()
            .filter(|t| t[v].1)
            .map(|t| t[v].2 as f64)
            .collect();
        let rate = conv.len() as f64 / per_trial.len() as f64;
        let st = crate::metrics::stats(&conv);
        summary.push_row(vec![v as f64, rate, st.mean, st.median]);
    }

    let std_mean = mean_trace(&per_trial.iter().map(|t| t[0].0.clone()).collect::<Vec<_>>());
    let alpha_means: Vec<Trace> = (0..ALPHAS.len())
        .map(|k| mean_trace(&per_trial.iter().map(|t| t[k + 1].0.clone()).collect::<Vec<_>>()))
        .collect();

    let len = std_mean
        .len()
        .max(alpha_means.iter().map(|t| t.len()).max().unwrap_or(0));
    let std_mean = std_mean.resampled(len);
    let alpha_means: Vec<Trace> = alpha_means.iter().map(|t| t.resampled(len)).collect();

    let mut table = Table::new(&[
        "iteration", "stoiht", "alpha_0", "alpha_25", "alpha_50", "alpha_75", "alpha_100",
    ]);
    for t in 0..len {
        let mut row = Vec::with_capacity(7);
        row.push((t + 1) as f64);
        row.push(std_mean.values[t]);
        for am in &alpha_means {
            row.push(am.values[t]);
        }
        table.push_row(row);
    }
    Fig1Output { series: table, summary }
}

/// Iterations-to-reach-threshold summary of a Fig.-1 table (used by tests
/// and the bench to assert the paper's qualitative claims).
pub fn iters_to_threshold(table: &Table, col: usize, threshold: f64) -> Option<usize> {
    table
        .rows
        .iter()
        .position(|row| row[col] < threshold)
        .map(|idx| idx + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            problem: ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() },
            trials: 6,
            max_iters: 800,
            trial_threads: 4,
            ..Default::default()
        }
    }

    #[test]
    fn fig1_shape_and_headline_claim() {
        let out = fig1(&small_cfg());
        let table = &out.series;
        assert_eq!(table.columns.len(), 7);
        assert!(!table.rows.is_empty());
        // Columns: 1 = stoiht, 6 = alpha_100.
        let thr = 1e-4;
        let std_iters = iters_to_threshold(table, 1, thr).expect("stoiht should converge");
        let a100_iters = iters_to_threshold(table, 6, thr).expect("alpha=1 should converge");
        // Paper: alpha = 1 needs roughly half the iterations.
        assert!(
            (a100_iters as f64) < 0.8 * std_iters as f64,
            "alpha=1: {a100_iters}, standard: {std_iters}"
        );
        // alpha = 0 must not be faster than alpha = 1.
        let a0 = iters_to_threshold(table, 2, thr).unwrap_or(usize::MAX);
        assert!(a0 >= a100_iters);
        // summary: 6 variants; standard + alpha=1 converge on easy problems
        assert_eq!(out.summary.rows.len(), 6);
        assert!(out.summary.rows[0][1] > 0.8, "standard conv rate");
        assert!(out.summary.rows[5][1] > 0.8, "alpha=1 conv rate");
        // alpha=1 mean iterations (converged) beat standard's
        assert!(out.summary.rows[5][2] < out.summary.rows[0][2]);
    }

    #[test]
    fn fig1_is_deterministic() {
        let cfg = small_cfg();
        let t1 = fig1(&cfg);
        let t2 = fig1(&cfg);
        assert_eq!(t1.series.rows[10], t2.series.rows[10]);
        assert_eq!(t1.summary.rows, t2.summary.rows);
    }

    #[test]
    fn iters_to_threshold_basics() {
        let mut t = Table::new(&["it", "v"]);
        t.push_row(vec![1.0, 0.5]);
        t.push_row(vec![2.0, 0.05]);
        assert_eq!(iters_to_threshold(&t, 1, 0.1), Some(2));
        assert_eq!(iters_to_threshold(&t, 1, 0.01), None);
    }
}
