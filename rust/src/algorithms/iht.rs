//! Classical Iterative Hard Thresholding (paper eq. (2); Blumensath &
//! Davies 2009): `x^{t+1} = H_s(x^t + gamma A^T (y - A x^t))`.
//!
//! IHT is the deterministic ancestor of StoIHT — one full-gradient step per
//! iteration instead of a sampled block — and serves as a baseline in the
//! A5 benchmark sweep.

use super::{GreedyOpts, RunResult};
use crate::linalg::nrm2;
use crate::metrics::Trace;
use crate::problem::Problem;
use crate::support::{hard_threshold_in_place, top_s_into};

/// Run IHT. `opts.gamma` is the full-gradient step size; block structure is
/// ignored.
pub fn iht(problem: &Problem, opts: &GreedyOpts) -> RunResult {
    let spec = &problem.spec;
    let blk = problem.a().as_block();
    let mut x = vec![0.0f64; spec.n];
    let mut proxy = vec![0.0f64; spec.n];
    let mut resid = vec![0.0f64; spec.m];
    let mut idx_scratch: Vec<usize> = Vec::with_capacity(spec.n);
    let mut sel = vec![0usize; spec.s];
    let mut error_trace = Trace::new();
    let mut resid_trace = Trace::new();
    let mut converged = false;
    let mut iters = 0;
    let mut residual = nrm2(&problem.y);

    for t in 1..=opts.max_iters {
        // proxy = x + gamma * A^T (y - A x); resid doubles as scratch.
        blk.proxy_step_into(&problem.y, &x, opts.gamma, &mut resid, &mut proxy);
        // x = H_s(proxy)
        top_s_into(&proxy, spec.s, &mut idx_scratch, &mut sel);
        x.fill(0.0);
        for &i in sel.iter() {
            x[i] = proxy[i];
        }
        iters = t;
        if opts.record_error {
            error_trace.push(problem.recovery_error(&x));
        }
        if t % opts.check_every == 0 {
            residual = problem.residual_norm(&x);
            if opts.record_resid {
                resid_trace.push(residual);
            }
            if residual < opts.tolerance {
                converged = true;
                break;
            }
        }
    }
    if !converged {
        residual = problem.residual_norm(&x);
    }
    RunResult { x, iters, converged, residual, error_trace, resid_trace }
}

/// One IHT step in isolation (used by tests and the PJRT cross-check).
pub fn iht_step(problem: &Problem, x: &[f64], gamma: f64) -> Vec<f64> {
    let spec = &problem.spec;
    let blk = problem.a().as_block();
    let mut proxy = vec![0.0f64; spec.n];
    let mut resid = vec![0.0f64; spec.m];
    blk.proxy_step_into(&problem.y, x, gamma, &mut resid, &mut proxy);
    let mut idx_scratch = Vec::new();
    let mut sel = vec![0usize; spec.s.min(spec.n)];
    hard_threshold_in_place(&mut proxy, spec.s, &mut idx_scratch, &mut sel);
    proxy
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;
    use crate::rng::Rng;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn converges_and_recovers() {
        let p = easy(1);
        let r = iht(&p, &GreedyOpts::default());
        assert!(r.converged, "residual {}", r.residual);
        assert!(p.recovery_error(&r.x) < 1e-6);
    }

    #[test]
    fn iterates_are_s_sparse() {
        let p = easy(2);
        let opts = GreedyOpts { max_iters: 5, ..Default::default() };
        let r = iht(&p, &opts);
        assert!(r.x.iter().filter(|&&v| v != 0.0).count() <= p.spec.s);
    }

    #[test]
    fn step_matches_run_first_iteration() {
        let p = easy(3);
        let one = iht_step(&p, &vec![0.0; p.spec.n], 1.0);
        let opts = GreedyOpts { max_iters: 1, ..Default::default() };
        let r = iht(&p, &opts);
        assert_eq!(one, r.x);
    }

    #[test]
    fn error_trace_decreases_overall() {
        let p = easy(4);
        let r = iht(&p, &GreedyOpts::recording());
        let tr = &r.error_trace.values;
        assert!(tr.first().unwrap() > tr.last().unwrap());
    }

    #[test]
    fn tiny_gamma_fails_to_converge_quickly() {
        let p = easy(5);
        let opts = GreedyOpts { gamma: 1e-4, max_iters: 50, ..Default::default() };
        let r = iht(&p, &opts);
        assert!(!r.converged);
    }
}
