//! Orthogonal Matching Pursuit (Tropp & Gilbert 2007) — the classical
//! greedy baseline: grow the support one index at a time by correlation,
//! re-fit by least squares, repeat `s` times.

use super::{GreedyOpts, RunResult};
use crate::linalg::{lstsq, nrm2};
use crate::metrics::Trace;
use crate::problem::Problem;

/// Run OMP for exactly `s` selection rounds (or until the residual drops
/// below `opts.tolerance`). `opts.gamma` / `max_iters` are unused; the
/// iteration count in the result equals the number of selected atoms.
pub fn omp(problem: &Problem, opts: &GreedyOpts) -> RunResult {
    let spec = &problem.spec;
    let a = problem.a();
    let mut support: Vec<usize> = Vec::with_capacity(spec.s);
    let mut r = problem.y.clone();
    let mut error_trace = Trace::new();
    let mut resid_trace = Trace::new();
    let mut x = vec![0.0f64; spec.n];
    let mut converged = nrm2(&r) < opts.tolerance;
    let mut iters = 0;

    while !converged && support.len() < spec.s {
        // correlate: pick argmax_j |A^T r| over j not yet selected.
        let corr = a.gemv_t(&r);
        let mut best: Option<usize> = None;
        for j in 0..spec.n {
            if support.contains(&j) {
                continue;
            }
            match best {
                None => best = Some(j),
                Some(b) => {
                    let (cj, cb) = (corr[j].abs(), corr[b].abs());
                    if cj > cb || (cj == cb && j < b) {
                        best = Some(j);
                    }
                }
            }
        }
        let j = best.expect("n > s guarantees a candidate");
        support.push(j);
        // least-squares re-fit on the selected columns.
        let sub = a.select_cols(&support);
        let z = lstsq(&sub, &problem.y);
        x.fill(0.0);
        for (k, &col) in support.iter().enumerate() {
            x[col] = z[k];
        }
        // residual r = y - A_T z
        let az = sub.gemv(&z);
        for i in 0..spec.m {
            r[i] = problem.y[i] - az[i];
        }
        iters += 1;
        if opts.record_error {
            error_trace.push(problem.recovery_error(&x));
        }
        let rn = nrm2(&r);
        if opts.record_resid {
            resid_trace.push(rn);
        }
        converged = rn < opts.tolerance;
    }

    let residual = problem.residual_norm(&x);
    RunResult { x, iters, converged, residual, error_trace, resid_trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;
    use crate::rng::Rng;
    use crate::support::support_of;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn exact_recovery_noiseless() {
        for seed in 1..6u64 {
            let p = easy(seed);
            let r = omp(&p, &GreedyOpts::default());
            assert!(r.converged, "seed {seed}: residual {}", r.residual);
            assert!(p.recovery_error(&r.x) < 1e-8, "seed {seed}");
            assert_eq!(support_of(&r.x), p.support, "seed {seed}");
        }
    }

    #[test]
    fn stops_early_when_tolerance_met() {
        // Signal with 1 spike but s = 4: OMP should exit after ~1 atom.
        let mut rng = Rng::seed_from(10);
        let mut sp = ProblemSpec { n: 64, m: 32, b: 4, s: 1, ..ProblemSpec::tiny() };
        let p = sp.generate(&mut rng);
        sp.s = 4; // solver believes s = 4
        let mut p4 = p;
        p4.spec = sp;
        let r = omp(&p4, &GreedyOpts::default());
        assert!(r.converged);
        assert!(r.iters <= 2, "iters {}", r.iters);
    }

    #[test]
    fn selects_at_most_s_atoms() {
        let p = easy(7);
        let r = omp(&p, &GreedyOpts::default());
        assert!(support_of(&r.x).len() <= p.spec.s);
        assert!(r.iters <= p.spec.s);
    }

    #[test]
    fn noisy_case_still_close() {
        let mut rng = Rng::seed_from(8);
        let sp = ProblemSpec { n: 128, m: 64, b: 8, s: 4, noise_std: 1e-3, ..ProblemSpec::tiny() };
        let p = sp.generate(&mut rng);
        let r = omp(&p, &GreedyOpts::default());
        assert!(p.relative_error(&r.x) < 0.05, "rel err {}", p.relative_error(&r.x));
    }

    #[test]
    fn traces_align_with_iterations() {
        let p = easy(9);
        let opts = GreedyOpts { record_error: true, record_resid: true, ..Default::default() };
        let r = omp(&p, &opts);
        assert_eq!(r.error_trace.len(), r.iters);
        assert_eq!(r.resid_trace.len(), r.iters);
    }
}
