//! StoIHT (paper Algorithm 1) and its Fig.-1 oracle-support variant.
//!
//! The per-iteration arithmetic lives in [`StoihtKernel`] — a reusable,
//! allocation-free step object — so the discrete-time simulator and the
//! real-thread runtime execute *exactly* the arithmetic validated here
//! (and, via the test-vector suite, against the JAX oracle). The heavy
//! flops inherit the crate's fast paths transparently: dense dot/axpy
//! streams dispatch through the [`crate::linalg::simd`] doorway and the
//! matrix-free operator rides the cached pair-fused FFT plan — both
//! bit-identical to the scalar references, so nothing here changes.

use super::{GreedyOpts, RunResult, SupportKernel};
use crate::linalg::{nrm2, MeasureOp, OpScratch, SparseIterate};
use crate::metrics::Trace;
use crate::problem::Problem;
use crate::rng::Rng;
use crate::support::{self, top_s_into, union_into};

/// Reusable StoIHT step state: scratch buffers plus the sampling
/// distribution. One kernel per (simulated or real) core. All measurement
/// arithmetic routes through the problem's [`MeasureOp`], so the kernel
/// runs unchanged on the materialized matrix or the matrix-free
/// subsampled-DCT operator.
pub struct StoihtKernel<'p> {
    problem: &'p Problem,
    /// Per-block selection probabilities `p(i)` (uniform by default).
    probs: Vec<f64>,
    /// `gamma / (M p(i))` precomputed per block.
    alphas: Vec<f64>,
    // scratch
    proxy: Vec<f64>,
    resid: Vec<f64>,
    idx_scratch: Vec<usize>,
    gamma_set: Vec<usize>,
    union_scratch: Vec<usize>,
    op_scratch: OpScratch,
    /// `A x` buffer for the dense halting statistic (sequential solver);
    /// sized lazily — the async runtimes use the sparse check instead.
    ax_scratch: Vec<f64>,
}

impl<'p> StoihtKernel<'p> {
    /// Uniform block sampling (the paper's experiments).
    pub fn new(problem: &'p Problem, gamma: f64) -> Self {
        let m_blocks = problem.spec.num_blocks();
        let probs = vec![1.0 / m_blocks as f64; m_blocks];
        Self::with_probs(problem, gamma, probs)
    }

    /// Arbitrary block distribution `p(i)` (must sum to 1).
    pub fn with_probs(problem: &'p Problem, gamma: f64, probs: Vec<f64>) -> Self {
        let m_blocks = problem.spec.num_blocks();
        assert_eq!(probs.len(), m_blocks, "probs length != number of blocks");
        let total: f64 = probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "block probabilities must sum to 1");
        let alphas = probs
            .iter()
            .map(|&p| {
                assert!(p > 0.0, "every block needs positive probability");
                gamma / (m_blocks as f64 * p)
            })
            .collect();
        StoihtKernel {
            problem,
            probs,
            alphas,
            proxy: vec![0.0; problem.spec.n],
            resid: vec![0.0; problem.spec.b],
            idx_scratch: Vec::with_capacity(problem.spec.n),
            gamma_set: vec![0; problem.spec.s.min(problem.spec.n)],
            union_scratch: Vec::with_capacity(2 * problem.spec.s),
            op_scratch: problem.op.make_scratch(),
            ax_scratch: Vec::new(),
        }
    }

    /// Sample a block index from `p(·)`.
    pub fn sample_block(&self, rng: &mut Rng) -> usize {
        rng.categorical(&self.probs)
    }

    /// One full Algorithm-1/2 iteration body.
    ///
    /// * randomize — caller supplies `block` (so schedulers control sampling)
    /// * proxy     — `b = x + gamma/(M p) A_b^T (y_b - A_b x)`
    /// * identify  — `Γ = supp_s(b)`
    /// * estimate  — `x <- b|_{Γ ∪ extra}` where `extra` is the oracle `T̃`
    ///   (Fig. 1) or the tally's `T̃^t` (Alg. 2); `None` gives Algorithm 1.
    ///
    /// Returns the sorted `Γ^t` (borrow of internal scratch — copy it out if
    /// it must outlive the next call).
    pub fn step(
        &mut self,
        x: &mut [f64],
        block: usize,
        extra_support: Option<&[usize]>,
    ) -> &[usize] {
        let problem = self.problem;
        let spec = &problem.spec;
        let yb = problem.y_block(block);
        let alpha = self.alphas[block];
        problem.op.block_proxy_step(
            block * spec.b,
            yb,
            x,
            alpha,
            &mut self.resid,
            &mut self.op_scratch,
            &mut self.proxy,
        );
        top_s_into(&self.proxy, spec.s, &mut self.idx_scratch, &mut self.gamma_set);
        // estimate: copy proxy restricted to the union onto x.
        match extra_support {
            None => {
                x.fill(0.0);
                for &i in &self.gamma_set {
                    x[i] = self.proxy[i];
                }
            }
            Some(extra) => {
                x.fill(0.0);
                for &i in &self.gamma_set {
                    x[i] = self.proxy[i];
                }
                for &i in extra {
                    x[i] = self.proxy[i];
                }
            }
        }
        &self.gamma_set
    }

    /// Sparse fast path of [`StoihtKernel::step`]: identical arithmetic —
    /// bit-for-bit identical iterates, see the `sparse_equivalence`
    /// integration suite — but the proxy's residual pass gathers only the
    /// iterate's supported columns (`O(b (s + |T̃|))` instead of `O(b n)`),
    /// and the estimate update touches `O(s)` coordinates instead of
    /// clearing all `n`. This is the kernel the simulator and the
    /// real-thread runtime drive.
    pub fn step_sparse(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        extra_support: Option<&[usize]>,
    ) -> &[usize] {
        let problem = self.problem;
        let spec = &problem.spec;
        debug_assert_eq!(x.n(), spec.n, "iterate dimension");
        let yb = problem.y_block(block);
        let row0 = block * spec.b;
        let alpha = self.alphas[block];
        problem.op.block_proxy_step_sparse(
            row0,
            yb,
            x.values(),
            x.support(),
            alpha,
            &mut self.resid,
            &mut self.op_scratch,
            &mut self.proxy,
        );
        top_s_into(&self.proxy, spec.s, &mut self.idx_scratch, &mut self.gamma_set);
        match extra_support {
            None => x.assign_from(&self.proxy, &self.gamma_set),
            Some(extra) => {
                union_into(&self.gamma_set, extra, &mut self.union_scratch);
                x.assign_from(&self.proxy, &self.union_scratch);
            }
        }
        &self.gamma_set
    }

    /// The halting statistic `||y - A x||_2`.
    pub fn residual_norm(&self, x: &[f64]) -> f64 {
        self.problem.residual_norm(x)
    }

    /// As [`StoihtKernel::residual_norm`] but through the kernel's own
    /// scratch (no per-check allocation — the matrix-free transform
    /// workspace is ~4n floats). Same arithmetic, same result bits.
    pub fn residual_norm_reusing_scratch(&mut self, x: &[f64]) -> f64 {
        let problem = self.problem;
        problem.residual_norm_with(x, &mut self.ax_scratch, &mut self.op_scratch)
    }

    /// Problem dimension.
    pub fn n(&self) -> usize {
        self.problem.spec.n
    }
}

/// The tally protocol over StoIHT: [`SupportKernel::tally_step`] is the
/// sparse fast path [`StoihtKernel::step_sparse`] verbatim (bit-identical
/// iterates — see `rust/tests/kernel_parity.rs`), with the empty estimate
/// degrading to Algorithm 1.
impl<'p> SupportKernel for StoihtKernel<'p> {
    fn problem(&self) -> &Problem {
        self.problem
    }

    fn sample_block(&self, rng: &mut Rng) -> usize {
        rng.categorical(&self.probs)
    }

    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    ) {
        let extra = if estimate.is_empty() { None } else { Some(estimate) };
        let gamma = self.step_sparse(x, block, extra);
        gamma_out.clear();
        gamma_out.extend_from_slice(gamma);
    }

    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>) {
        let gamma = self.step(x, block, None);
        gamma_out.clear();
        gamma_out.extend_from_slice(gamma);
    }

    fn burn(&mut self, x: &SparseIterate<f64>, block: usize) {
        let problem = self.problem;
        let yb = problem.y_block(block);
        let row0 = block * problem.spec.b;
        let alpha = self.alphas[block];
        problem.op.block_proxy_step_sparse(
            row0,
            yb,
            x.values(),
            x.support(),
            alpha,
            &mut self.resid,
            &mut self.op_scratch,
            &mut self.proxy,
        );
        std::hint::black_box(&self.proxy);
    }

    fn residual(&mut self, x: &SparseIterate<f64>, r_scratch: &mut Vec<f64>) -> f64 {
        // Through the kernel's own operator scratch — allocation-free for
        // the matrix-free operator too.
        self.problem.residual_norm_sparse_with(
            x.values(),
            x.support(),
            r_scratch,
            &mut self.op_scratch,
        )
    }
}

/// StoIHT — paper Algorithm 1 (sequential).
pub fn stoiht(problem: &Problem, opts: &GreedyOpts, rng: &mut Rng) -> RunResult {
    stoiht_impl(problem, opts, rng, None)
}

/// Fig.-1 modified StoIHT: estimate onto `Γ^t ∪ T̃` for a *fixed* support
/// estimate `T̃` (sorted). `oracle` with accuracy α is built via
/// [`support::oracle_estimate`].
pub fn stoiht_with_oracle(
    problem: &Problem,
    opts: &GreedyOpts,
    rng: &mut Rng,
    oracle: &[usize],
) -> RunResult {
    debug_assert!(oracle.windows(2).all(|w| w[0] < w[1]), "oracle must be sorted");
    stoiht_impl(problem, opts, rng, Some(oracle))
}

fn stoiht_impl(
    problem: &Problem,
    opts: &GreedyOpts,
    rng: &mut Rng,
    oracle: Option<&[usize]>,
) -> RunResult {
    assert!(opts.check_every >= 1);
    let mut kernel = StoihtKernel::new(problem, opts.gamma);
    // The sequential solver rides the sparse fast path too; `step_sparse`
    // is bit-identical to the dense step, so nothing observable changes.
    let mut x = SparseIterate::zeros(problem.spec.n);
    let mut error_trace = Trace::new();
    let mut resid_trace = Trace::new();
    let mut converged = false;
    let mut iters = 0;
    let mut residual = nrm2(&problem.y);

    for t in 1..=opts.max_iters {
        let block = kernel.sample_block(rng);
        kernel.step_sparse(&mut x, block, oracle);
        iters = t;
        if opts.record_error {
            error_trace.push(problem.recovery_error(x.values()));
        }
        if t % opts.check_every == 0 {
            residual = kernel.residual_norm_reusing_scratch(x.values());
            if opts.record_resid {
                resid_trace.push(residual);
            }
            if residual < opts.tolerance {
                converged = true;
                break;
            }
        }
    }
    if !converged {
        residual = kernel.residual_norm_reusing_scratch(x.values());
    }
    RunResult { x: x.into_values(), iters, converged, residual, error_trace, resid_trace }
}

/// Convenience used by Fig. 1: oracle estimate with exact accuracy
/// `alpha = hits / s` against the planted support.
pub fn make_oracle(problem: &Problem, alpha: f64, rng: &mut Rng) -> Vec<usize> {
    let s = problem.spec.s;
    let hits = (alpha * s as f64).round() as usize;
    support::oracle_estimate(&problem.support, problem.spec.n, s, hits.min(s), rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn easy_problem(seed: u64) -> Problem {
        // Comfortable oversampling: n=128, m=64, s=4.
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn converges_on_easy_problem() {
        let p = easy_problem(1);
        let mut rng = Rng::seed_from(100);
        let r = stoiht(&p, &GreedyOpts::default(), &mut rng);
        assert!(r.converged, "residual {}", r.residual);
        assert!(p.recovery_error(&r.x) < 1e-6, "err {}", p.recovery_error(&r.x));
        assert!(r.residual < 1e-7);
    }

    #[test]
    fn iterate_is_always_sparse_enough() {
        let p = easy_problem(2);
        let mut rng = Rng::seed_from(3);
        let mut kernel = StoihtKernel::new(&p, 1.0);
        let mut x = vec![0.0; p.spec.n];
        for _ in 0..50 {
            let blk = kernel.sample_block(&mut rng);
            kernel.step(&mut x, blk, None);
            let nnz = x.iter().filter(|&&v| v != 0.0).count();
            assert!(nnz <= p.spec.s);
        }
    }

    #[test]
    fn oracle_union_allows_up_to_2s_nonzeros() {
        let p = easy_problem(3);
        let mut rng = Rng::seed_from(4);
        let oracle = make_oracle(&p, 1.0, &mut rng);
        let mut kernel = StoihtKernel::new(&p, 1.0);
        let mut x = vec![0.0; p.spec.n];
        for _ in 0..20 {
            let blk = kernel.sample_block(&mut rng);
            kernel.step(&mut x, blk, Some(&oracle));
            let nnz = x.iter().filter(|&&v| v != 0.0).count();
            assert!(nnz <= 2 * p.spec.s);
        }
    }

    #[test]
    fn perfect_oracle_speeds_convergence() {
        // Mean iterations over a few trials: alpha=1 should beat standard.
        let mut iters_std = 0usize;
        let mut iters_orc = 0usize;
        for seed in 0..8u64 {
            let p = easy_problem(50 + seed);
            let mut rng1 = Rng::seed_from(1000 + seed);
            let mut rng2 = Rng::seed_from(1000 + seed);
            let r1 = stoiht(&p, &GreedyOpts::default(), &mut rng1);
            let oracle = p.support.clone();
            let r2 = stoiht_with_oracle(&p, &GreedyOpts::default(), &mut rng2, &oracle);
            assert!(r1.converged && r2.converged);
            iters_std += r1.iters;
            iters_orc += r2.iters;
        }
        assert!(
            iters_orc < iters_std,
            "oracle {iters_orc} !< standard {iters_std}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let p = easy_problem(5);
        let r1 = stoiht(&p, &GreedyOpts::default(), &mut Rng::seed_from(9));
        let r2 = stoiht(&p, &GreedyOpts::default(), &mut Rng::seed_from(9));
        assert_eq!(r1.x, r2.x);
        assert_eq!(r1.iters, r2.iters);
    }

    #[test]
    fn respects_max_iters() {
        let p = easy_problem(6);
        let opts = GreedyOpts { max_iters: 3, ..Default::default() };
        let r = stoiht(&p, &opts, &mut Rng::seed_from(1));
        assert_eq!(r.iters, 3);
        assert!(!r.converged);
    }

    #[test]
    fn traces_recorded_when_asked() {
        let p = easy_problem(7);
        let opts = GreedyOpts {
            record_error: true,
            record_resid: true,
            max_iters: 10,
            ..Default::default()
        };
        let r = stoiht(&p, &opts, &mut Rng::seed_from(2));
        assert_eq!(r.error_trace.len(), r.iters);
        assert_eq!(r.resid_trace.len(), r.iters);
        let opts = GreedyOpts { max_iters: 10, ..Default::default() };
        let r = stoiht(&p, &opts, &mut Rng::seed_from(2));
        assert!(r.error_trace.is_empty());
    }

    #[test]
    fn check_every_amortizes_but_still_converges() {
        let p = easy_problem(8);
        let opts = GreedyOpts { check_every: 10, ..Default::default() };
        let r = stoiht(&p, &opts, &mut Rng::seed_from(3));
        assert!(r.converged);
        assert_eq!(r.iters % 10, 0);
    }

    #[test]
    fn nonuniform_probabilities_scale_alpha() {
        let p = easy_problem(9);
        let mb = p.spec.num_blocks();
        let mut probs = vec![0.5 / (mb - 1) as f64; mb];
        probs[0] = 0.5;
        let kernel = StoihtKernel::with_probs(&p, 1.0, probs.clone());
        // alpha_0 = gamma / (M * 0.5)
        assert!((kernel.alphas[0] - 1.0 / (mb as f64 * 0.5)).abs() < 1e-12);
        // sampling respects the distribution
        let mut rng = Rng::seed_from(11);
        let hits = (0..4000).filter(|_| kernel.sample_block(&mut rng) == 0).count();
        assert!((1700..2300).contains(&hits), "{hits}");
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn bad_probs_rejected() {
        let p = easy_problem(10);
        let mb = p.spec.num_blocks();
        let _ = StoihtKernel::with_probs(&p, 1.0, vec![0.3 / mb as f64; mb]);
    }

    #[test]
    fn union_includes_oracle_values_from_proxy() {
        let p = easy_problem(11);
        let mut kernel = StoihtKernel::new(&p, 1.0);
        let mut x = vec![0.0; p.spec.n];
        let oracle: Vec<usize> = vec![0, 1]; // arbitrary indices
        kernel.step(&mut x, 0, Some(&oracle));
        // x at oracle indices equals the proxy there (possibly ~0 but set).
        assert_eq!(x[0], kernel.proxy[0]);
        assert_eq!(x[1], kernel.proxy[1]);
    }

    #[test]
    fn sparse_step_matches_dense_step_bitwise() {
        let p = easy_problem(20);
        let mut rng = Rng::seed_from(21);
        let oracle = make_oracle(&p, 0.5, &mut rng);
        let mut kd = StoihtKernel::new(&p, 1.0);
        let mut ks = StoihtKernel::new(&p, 1.0);
        let mut xd = vec![0.0f64; p.spec.n];
        let mut xs = SparseIterate::zeros(p.spec.n);
        for it in 0..60 {
            let blk = kd.sample_block(&mut rng);
            // Alternate Alg.-1 and Alg.-2-style steps to exercise both arms.
            let extra = if it % 2 == 0 { None } else { Some(oracle.as_slice()) };
            let gd = kd.step(&mut xd, blk, extra).to_vec();
            let gs = ks.step_sparse(&mut xs, blk, extra).to_vec();
            assert_eq!(gd, gs, "iteration {it}: gamma sets differ");
            for i in 0..p.spec.n {
                assert_eq!(
                    xd[i].to_bits(),
                    xs.values()[i].to_bits(),
                    "iteration {it} coordinate {i}: {} vs {}",
                    xd[i],
                    xs.values()[i]
                );
            }
            assert!(xs.nnz() <= 2 * p.spec.s);
        }
    }

    #[test]
    fn sparse_sequential_solver_converges() {
        // stoiht() now rides step_sparse internally; same guarantees hold.
        let p = easy_problem(21);
        let r = stoiht(&p, &GreedyOpts::default(), &mut Rng::seed_from(7));
        assert!(r.converged);
        assert!(p.recovery_error(&r.x) < 1e-6);
        let nnz = r.x.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= p.spec.s);
    }

    #[test]
    fn matrix_free_sequential_solver_converges() {
        // The kernel runs unchanged on the matrix-free subsampled-DCT
        // operator — no m x n matrix is ever materialized.
        let p = ProblemSpec::tiny_matrix_free().generate(&mut Rng::seed_from(30));
        let r = stoiht(&p, &GreedyOpts::default(), &mut Rng::seed_from(31));
        assert!(r.converged, "residual {}", r.residual);
        assert!(p.recovery_error(&r.x) < 1e-6);
    }

    #[test]
    fn make_oracle_accuracy() {
        let p = easy_problem(12);
        let mut rng = Rng::seed_from(13);
        for alpha in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let est = make_oracle(&p, alpha, &mut rng);
            let acc = support::accuracy(&est, &p.support);
            assert!((acc - alpha).abs() < 0.26, "alpha {alpha} acc {acc}");
            assert_eq!(est.len(), p.spec.s);
        }
    }
}
