//! StoGradMP (Nguyen, Needell & Woolf 2014) — the stochastic GradMP /
//! CoSaMP relative of StoIHT and the paper's §V extension target: per
//! iteration, take the *block* gradient as the proxy, merge its top-`2s`
//! set with the current support (and optionally an external support
//! estimate — that is the asynchronous tally hook), least-squares re-fit,
//! prune to `s`.

use super::{GreedyOpts, RunResult};
use crate::linalg::{lstsq, nrm2};
use crate::metrics::Trace;
use crate::problem::Problem;
use crate::rng::Rng;
use crate::support::{support_of, top_s, union};

/// One StoGradMP iteration body, reusable by the asynchronous runtimes.
///
/// * `x` — current iterate (overwritten with the new estimate)
/// * `block` — sampled measurement block
/// * `extra_support` — `T̃^t` from the shared tally (Alg.-2-style union),
///   or `None` for the sequential algorithm.
///
/// Returns the sorted merged support used for the re-fit (the tally votes
/// on its top-`s` prune, matching the StoIHT tally protocol).
pub fn stogradmp_step(
    problem: &Problem,
    x: &mut [f64],
    block: usize,
    extra_support: Option<&[usize]>,
) -> Vec<usize> {
    let spec = &problem.spec;
    let (blk, yb) = problem.block(block);
    // block gradient g = A_b^T (y_b - A_b x)
    let ax = blk.gemv(x);
    let r: Vec<f64> = yb.iter().zip(&ax).map(|(&a, &b)| a - b).collect();
    let g = blk.gemv_t(&r);
    // identify top-2s of the block gradient, merge with current support.
    let omega = top_s(&g, 2 * spec.s);
    let mut merged = union(&omega, &support_of(x));
    if let Some(extra) = extra_support {
        merged = union(&merged, extra);
    }
    // estimate: least squares over the merged support on the FULL system
    // (GradMP's estimation uses the global objective).
    let sub = problem.a.select_cols(&merged);
    let z = lstsq(&sub, &problem.y);
    // prune to top-s.
    let keep = top_s(&z, spec.s);
    x.fill(0.0);
    let mut pruned: Vec<usize> = keep.iter().map(|&k| merged[k]).collect();
    for (&k, &col) in keep.iter().zip(&pruned) {
        x[col] = z[k];
    }
    pruned.sort_unstable();
    pruned
}

/// Sequential StoGradMP.
pub fn stogradmp(problem: &Problem, opts: &GreedyOpts, rng: &mut Rng) -> RunResult {
    let spec = &problem.spec;
    let m_blocks = spec.num_blocks();
    let mut x = vec![0.0f64; spec.n];
    let mut error_trace = Trace::new();
    let mut resid_trace = Trace::new();
    let mut converged = false;
    let mut iters = 0;
    let mut residual = nrm2(&problem.y);

    for t in 1..=opts.max_iters {
        let block = rng.below(m_blocks);
        stogradmp_step(problem, &mut x, block, None);
        iters = t;
        if opts.record_error {
            error_trace.push(problem.recovery_error(&x));
        }
        if t % opts.check_every == 0 {
            residual = problem.residual_norm(&x);
            if opts.record_resid {
                resid_trace.push(residual);
            }
            if residual < opts.tolerance {
                converged = true;
                break;
            }
        }
    }
    if !converged {
        residual = problem.residual_norm(&x);
    }
    RunResult { x, iters, converged, residual, error_trace, resid_trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn recovers_quickly_noiseless() {
        for seed in 1..5u64 {
            let p = easy(seed);
            let opts = GreedyOpts { max_iters: 100, ..Default::default() };
            let r = stogradmp(&p, &opts, &mut Rng::seed_from(seed));
            assert!(r.converged, "seed {seed} residual {}", r.residual);
            assert!(p.recovery_error(&r.x) < 1e-7, "seed {seed}");
            // GradMP-family converges much faster than StoIHT.
            assert!(r.iters < 60, "iters {}", r.iters);
        }
    }

    #[test]
    fn step_keeps_s_sparsity() {
        let p = easy(6);
        let mut x = vec![0.0; p.spec.n];
        for blk in 0..4 {
            let pruned = stogradmp_step(&p, &mut x, blk, None);
            assert!(pruned.len() <= p.spec.s);
            assert_eq!(support_of(&x), pruned);
        }
    }

    #[test]
    fn extra_support_is_respected() {
        let p = easy(7);
        let mut x = vec![0.0; p.spec.n];
        // With the planted support as the extra set, one step should nail
        // the least-squares fit on (a superset of) the truth.
        let pruned = stogradmp_step(&p, &mut x, 0, Some(&p.support));
        assert!(pruned.len() <= p.spec.s);
        assert!(p.recovery_error(&x) < 1e-7, "err {}", p.recovery_error(&x));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = easy(8);
        let r1 = stogradmp(&p, &GreedyOpts::default(), &mut Rng::seed_from(3));
        let r2 = stogradmp(&p, &GreedyOpts::default(), &mut Rng::seed_from(3));
        assert_eq!(r1.x, r2.x);
        assert_eq!(r1.iters, r2.iters);
    }
}
