//! StoGradMP (Nguyen, Needell & Woolf 2014) — the stochastic GradMP /
//! CoSaMP relative of StoIHT and the paper's §V extension target: per
//! iteration, take the *block* gradient as the proxy, merge its top-`2s`
//! set with the current support (and optionally an external support
//! estimate — that is the asynchronous tally hook), least-squares re-fit
//! over the merged support, prune to `s`.
//!
//! Two forms live here:
//!
//! * [`stogradmp_step`] — the allocating reference implementation, kept as
//!   the oracle the kernel is tested against.
//! * [`StoGradMpKernel`] — the reusable, allocation-free step object
//!   implementing [`SupportKernel`], which the sequential solver
//!   ([`stogradmp`]), the discrete-time simulator, and the real-thread
//!   runtime all drive. Its inner loop reuses residual/gradient/merge
//!   scratch and cycles one matrix buffer through the Householder QR
//!   re-fit ([`Qr::solve_into`] / [`Qr::into_matrix`]), so steady-state
//!   iterations perform no heap allocation on the overdetermined path.

use super::{GreedyOpts, RunResult, SupportKernel};
use crate::linalg::{lstsq, nrm2, Mat, MeasureOp, OpScratch, Qr, SparseIterate};
use crate::metrics::Trace;
use crate::problem::Problem;
use crate::rng::Rng;
use crate::support::{support_of, top_s, top_s_into, union, union_into};

/// One StoGradMP iteration body — the allocating reference implementation
/// (see [`StoGradMpKernel`] for the hot-path form). Works on raw matrices,
/// so it requires a dense problem — it is the oracle the operator-driven
/// kernel is pinned against, deliberately *not* routed through
/// [`MeasureOp`].
///
/// * `x` — current iterate (overwritten with the new estimate)
/// * `block` — sampled measurement block
/// * `extra_support` — `T̃^t` from the shared tally (Alg.-2-style union),
///   or `None` for the sequential algorithm.
///
/// Returns the sorted pruned support `Γ^t` (the tally votes on the top-`s`
/// prune, matching the StoIHT tally protocol).
pub fn stogradmp_step(
    problem: &Problem,
    x: &mut [f64],
    block: usize,
    extra_support: Option<&[usize]>,
) -> Vec<usize> {
    let spec = &problem.spec;
    let (blk, yb) = problem.block(block);
    // block gradient g = A_b^T (y_b - A_b x)
    let ax = blk.gemv(x);
    let r: Vec<f64> = yb.iter().zip(&ax).map(|(&a, &b)| a - b).collect();
    let g = blk.gemv_t(&r);
    // identify top-2s of the block gradient, merge with current support.
    let omega = top_s(&g, 2 * spec.s);
    let mut merged = union(&omega, &support_of(x));
    if let Some(extra) = extra_support {
        merged = union(&merged, extra);
    }
    // estimate: least squares over the merged support on the FULL system
    // (GradMP's estimation uses the global objective).
    let sub = problem.a().select_cols(&merged);
    let z = lstsq(&sub, &problem.y);
    // prune to top-s.
    let keep = top_s(&z, spec.s);
    x.fill(0.0);
    let mut pruned: Vec<usize> = keep.iter().map(|&k| merged[k]).collect();
    for (&k, &col) in keep.iter().zip(&pruned) {
        x[col] = z[k];
    }
    pruned.sort_unstable();
    pruned
}

/// Reusable StoGradMP step state: the sampling distribution plus every
/// scratch buffer the identify/merge/re-fit/prune pipeline needs. One
/// kernel per (simulated or real) core.
pub struct StoGradMpKernel<'p> {
    problem: &'p Problem,
    /// Per-block selection probabilities `p(i)` (uniform by default).
    probs: Vec<f64>,
    // scratch — reused across iterations, no steady-state allocation
    resid: Vec<f64>,
    grad: Vec<f64>,
    idx_scratch: Vec<usize>,
    omega: Vec<usize>,
    merge_tmp: Vec<usize>,
    merged: Vec<usize>,
    supp_scratch: Vec<usize>,
    /// Row-major `m x k` gather buffer, cycled through [`Qr::factor`] /
    /// [`Qr::into_matrix`] so the re-fit never allocates the submatrix.
    sub_data: Vec<f64>,
    rhs: Vec<f64>,
    z: Vec<f64>,
    keep: Vec<usize>,
    pruned: Vec<usize>,
    pruned_vals: Vec<f64>,
    nz_supp: Vec<usize>,
    nz_vals: Vec<f64>,
    op_scratch: OpScratch,
}

impl<'p> StoGradMpKernel<'p> {
    /// Uniform block sampling (the paper's experiments).
    pub fn new(problem: &'p Problem) -> Self {
        let m_blocks = problem.spec.num_blocks();
        Self::with_probs(problem, vec![1.0 / m_blocks as f64; m_blocks])
    }

    /// Arbitrary block distribution `p(i)` (must sum to 1). GradMP's
    /// estimation phase re-fits on the full system, so unlike StoIHT no
    /// per-block step-size correction is needed.
    pub fn with_probs(problem: &'p Problem, probs: Vec<f64>) -> Self {
        let spec = &problem.spec;
        assert_eq!(probs.len(), spec.num_blocks(), "probs length != number of blocks");
        let total: f64 = probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "block probabilities must sum to 1");
        assert!(probs.iter().all(|&p| p > 0.0), "every block needs positive probability");
        StoGradMpKernel {
            problem,
            probs,
            resid: vec![0.0; spec.b],
            grad: vec![0.0; spec.n],
            idx_scratch: Vec::with_capacity(spec.n),
            omega: vec![0; (2 * spec.s).min(spec.n)],
            merge_tmp: Vec::with_capacity(4 * spec.s),
            merged: Vec::with_capacity(4 * spec.s),
            supp_scratch: Vec::with_capacity(spec.s),
            sub_data: Vec::new(),
            rhs: Vec::with_capacity(spec.m),
            z: Vec::with_capacity(4 * spec.s),
            keep: Vec::with_capacity(spec.s),
            pruned: Vec::with_capacity(spec.s),
            pruned_vals: Vec::with_capacity(spec.s),
            nz_supp: Vec::with_capacity(spec.s),
            nz_vals: Vec::with_capacity(spec.s),
            op_scratch: problem.op.make_scratch(),
        }
    }

    /// Least-squares re-fit over `self.merged` on the full system, then
    /// prune to `s`: fills `self.pruned` (sorted `Γ^t`) and
    /// `self.pruned_vals` (the surviving coefficients). Identical
    /// arithmetic to the reference ([`lstsq`] + [`top_s`]); the
    /// overdetermined path cycles `self.sub_data` through the QR instead
    /// of allocating.
    fn refit_and_prune(&mut self) {
        let spec = &self.problem.spec;
        let m = spec.m;
        let k = self.merged.len();
        if k <= m {
            self.problem.op.select_cols_into(&self.merged, &mut self.sub_data);
            let sub = Mat::from_vec(m, k, std::mem::take(&mut self.sub_data));
            let qr = Qr::factor(sub);
            qr.solve_into(&self.problem.y, &mut self.rhs, &mut self.z);
            self.sub_data = qr.into_matrix().into_data();
        } else {
            // Underdetermined merged support (only reachable at very low
            // sampling rates): cold CGLS fallback, allocating. The column
            // panel is gathered through the operator, so the path works
            // matrix-free too.
            let mut panel = Vec::new();
            self.problem.op.select_cols_into(&self.merged, &mut panel);
            let sub = Mat::from_vec(m, k, panel);
            let z = lstsq(&sub, &self.problem.y);
            self.z.clear();
            self.z.extend_from_slice(&z);
        }
        self.keep.resize(spec.s.min(k), 0);
        top_s_into(&self.z, spec.s, &mut self.idx_scratch, &mut self.keep);
        // `keep` is ascending and `merged` is sorted, so the image is
        // already the sorted pruned support.
        self.pruned.clear();
        self.pruned_vals.clear();
        for &kk in &self.keep {
            self.pruned.push(self.merged[kk]);
            self.pruned_vals.push(self.z[kk]);
        }
    }
}

/// The tally protocol over StoGradMP. `tally_step` is bit-identical to
/// [`stogradmp_step`] on the same iterate (see the equivalence tests
/// below): the identify phase rides the sparse residual gather
/// ([`crate::linalg::RowBlock::residual_sparse_into`], bit-equal to the
/// dense `y − A_b x` under the `SparseIterate` invariant) and the same
/// row-ordered `A_b^T r` accumulation, so switching the runtimes to the
/// kernel changes no experiment by even an ulp.
impl<'p> SupportKernel for StoGradMpKernel<'p> {
    fn problem(&self) -> &Problem {
        self.problem
    }

    fn sample_block(&self, rng: &mut Rng) -> usize {
        rng.categorical(&self.probs)
    }

    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    ) {
        let problem = self.problem;
        let spec = &problem.spec;
        debug_assert_eq!(x.n(), spec.n, "iterate dimension");
        let yb = problem.y_block(block);
        let row0 = block * spec.b;
        // identify: r = y_b - A_b x (sparse gather), g = A_b^T r.
        problem.op.block_residual_sparse(row0, yb, x.values(), x.support(), &mut self.resid);
        problem.op.block_apply_t_acc(row0, &self.resid, 0.0, &mut self.op_scratch, &mut self.grad);
        top_s_into(&self.grad, 2 * spec.s, &mut self.idx_scratch, &mut self.omega);
        // merge: Ω ∪ supp(x^t) ∪ T̃ (the support carried by the iterate is
        // the previous prune — GradMP's "current support").
        union_into(&self.omega, x.support(), &mut self.merge_tmp);
        if estimate.is_empty() {
            std::mem::swap(&mut self.merged, &mut self.merge_tmp);
        } else {
            union_into(&self.merge_tmp, estimate, &mut self.merged);
        }
        self.refit_and_prune();
        // The carried support is the *nonzero* prune, matching the dense
        // reference's `support_of`: an exactly-zero LS coefficient (a
        // rank-deficient merge clamped by the QR tolerance) must not
        // survive into the next iteration's merge, or the kernel's
        // trajectory would diverge from `stogradmp_step`'s. The vote Γ^t
        // stays the full pruned set, as the reference returns it.
        self.nz_supp.clear();
        self.nz_vals.clear();
        for (&col, &v) in self.pruned.iter().zip(&self.pruned_vals) {
            if v != 0.0 {
                self.nz_supp.push(col);
                self.nz_vals.push(v);
            }
        }
        x.assign_pairs(&self.nz_supp, &self.nz_vals);
        gamma_out.clear();
        gamma_out.extend_from_slice(&self.pruned);
    }

    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>) {
        let problem = self.problem;
        let spec = &problem.spec;
        let yb = problem.y_block(block);
        let row0 = block * spec.b;
        // identify on the dense iterate (the SharedX ablation is O(n) by
        // design — concurrent overwrites break the sparse invariant).
        problem.op.block_apply_into(row0, x, &mut self.op_scratch, &mut self.resid);
        for (r, &y) in self.resid.iter_mut().zip(yb) {
            *r = y - *r;
        }
        problem.op.block_apply_t_acc(row0, &self.resid, 0.0, &mut self.op_scratch, &mut self.grad);
        top_s_into(&self.grad, 2 * spec.s, &mut self.idx_scratch, &mut self.omega);
        self.supp_scratch.clear();
        self.supp_scratch.extend((0..spec.n).filter(|&i| x[i] != 0.0));
        union_into(&self.omega, &self.supp_scratch, &mut self.merged);
        self.refit_and_prune();
        x.fill(0.0);
        for (&col, &v) in self.pruned.iter().zip(&self.pruned_vals) {
            x[col] = v;
        }
        gamma_out.clear();
        gamma_out.extend_from_slice(&self.pruned);
    }

    fn burn(&mut self, x: &SparseIterate<f64>, block: usize) {
        // Throwaway identify phase: the gradient pass is the stream-heavy
        // part of a GradMP iteration (the LS re-fit is compute over a
        // k ≤ 3s column panel).
        let problem = self.problem;
        let yb = problem.y_block(block);
        let row0 = block * problem.spec.b;
        problem.op.block_residual_sparse(row0, yb, x.values(), x.support(), &mut self.resid);
        problem.op.block_apply_t_acc(row0, &self.resid, 0.0, &mut self.op_scratch, &mut self.grad);
        std::hint::black_box(&self.grad);
    }

    fn residual(&mut self, x: &SparseIterate<f64>, r_scratch: &mut Vec<f64>) -> f64 {
        // Through the kernel's own operator scratch (see StoihtKernel).
        self.problem.residual_norm_sparse_with(
            x.values(),
            x.support(),
            r_scratch,
            &mut self.op_scratch,
        )
    }
}

/// Sequential StoGradMP, riding [`StoGradMpKernel`]'s allocation-free step
/// — so the asynchronous runtimes execute *exactly* the arithmetic the
/// sequential solver is tested with (the same factoring StoIHT has had
/// since the seed), and the `c = 1` cross-check in
/// `rust/tests/kernel_parity.rs` can replay it stream-for-stream.
pub fn stogradmp(problem: &Problem, opts: &GreedyOpts, rng: &mut Rng) -> RunResult {
    assert!(opts.check_every >= 1);
    let mut kernel = StoGradMpKernel::new(problem);
    let mut x = SparseIterate::zeros(problem.spec.n);
    let mut gamma = Vec::new();
    let mut r_scratch = Vec::new();
    let mut error_trace = Trace::new();
    let mut resid_trace = Trace::new();
    let mut converged = false;
    let mut iters = 0;
    let mut residual = nrm2(&problem.y);

    for t in 1..=opts.max_iters {
        let block = kernel.sample_block(rng);
        kernel.tally_step(&mut x, block, &[], &mut gamma);
        iters = t;
        if opts.record_error {
            error_trace.push(problem.recovery_error(x.values()));
        }
        if t % opts.check_every == 0 {
            residual = kernel.residual(&x, &mut r_scratch);
            if opts.record_resid {
                resid_trace.push(residual);
            }
            if residual < opts.tolerance {
                converged = true;
                break;
            }
        }
    }
    if !converged {
        residual = problem.residual_norm(x.values());
    }
    RunResult { x: x.into_values(), iters, converged, residual, error_trace, resid_trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn recovers_quickly_noiseless() {
        for seed in 1..5u64 {
            let p = easy(seed);
            let opts = GreedyOpts { max_iters: 100, ..Default::default() };
            let r = stogradmp(&p, &opts, &mut Rng::seed_from(seed));
            assert!(r.converged, "seed {seed} residual {}", r.residual);
            assert!(p.recovery_error(&r.x) < 1e-7, "seed {seed}");
            // GradMP-family converges much faster than StoIHT.
            assert!(r.iters < 60, "iters {}", r.iters);
        }
    }

    #[test]
    fn step_keeps_s_sparsity() {
        let p = easy(6);
        let mut x = vec![0.0; p.spec.n];
        for blk in 0..4 {
            let pruned = stogradmp_step(&p, &mut x, blk, None);
            assert!(pruned.len() <= p.spec.s);
            assert_eq!(support_of(&x), pruned);
        }
    }

    #[test]
    fn extra_support_is_respected() {
        let p = easy(7);
        let mut x = vec![0.0; p.spec.n];
        // With the planted support as the extra set, one step should nail
        // the least-squares fit on (a superset of) the truth.
        let pruned = stogradmp_step(&p, &mut x, 0, Some(&p.support));
        assert!(pruned.len() <= p.spec.s);
        assert!(p.recovery_error(&x) < 1e-7, "err {}", p.recovery_error(&x));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = easy(8);
        let r1 = stogradmp(&p, &GreedyOpts::default(), &mut Rng::seed_from(3));
        let r2 = stogradmp(&p, &GreedyOpts::default(), &mut Rng::seed_from(3));
        assert_eq!(r1.x, r2.x);
        assert_eq!(r1.iters, r2.iters);
    }

    #[test]
    fn kernel_matches_reference_step_bitwise() {
        // Whole trajectories: the allocation-free kernel step vs the
        // allocating reference, with and without a tally-style extra
        // support, must agree on every bit of every iterate.
        for seed in 0..4u64 {
            let p = easy(40 + seed);
            let mut rng = Rng::seed_from(600 + seed);
            let mut extra = rng.subset(p.spec.n, p.spec.s);
            extra.sort_unstable();
            let mut kernel = StoGradMpKernel::new(&p);
            let mut xs = SparseIterate::zeros(p.spec.n);
            let mut xd = vec![0.0f64; p.spec.n];
            let mut gamma = Vec::new();
            for it in 0..25 {
                let block = rng.below(p.spec.num_blocks());
                let use_extra = it % 3 == 1;
                let e: &[usize] = if use_extra { &extra } else { &[] };
                kernel.tally_step(&mut xs, block, e, &mut gamma);
                let pruned =
                    stogradmp_step(&p, &mut xd, block, if use_extra { Some(&extra) } else { None });
                assert_eq!(gamma, pruned, "seed {seed} iter {it}: pruned support");
                for i in 0..p.spec.n {
                    assert_eq!(
                        xd[i].to_bits(),
                        xs.values()[i].to_bits(),
                        "seed {seed} iter {it} coord {i}: {} vs {}",
                        xd[i],
                        xs.values()[i]
                    );
                }
                assert!(xs.nnz() <= p.spec.s);
            }
        }
    }

    #[test]
    fn dense_step_matches_reference() {
        let p = easy(50);
        let mut kernel = StoGradMpKernel::new(&p);
        let mut xk = vec![0.0f64; p.spec.n];
        let mut xr = vec![0.0f64; p.spec.n];
        let mut gamma = Vec::new();
        for it in 0..15 {
            let block = it % p.spec.num_blocks();
            kernel.dense_step(&mut xk, block, &mut gamma);
            let pruned = stogradmp_step(&p, &mut xr, block, None);
            assert_eq!(gamma, pruned, "iter {it}");
            for i in 0..p.spec.n {
                assert_eq!(xk[i].to_bits(), xr[i].to_bits(), "iter {it} coord {i}");
            }
        }
    }

    #[test]
    fn kernel_sequential_solver_converges_sparse() {
        let p = easy(51);
        let opts = GreedyOpts { max_iters: 100, ..Default::default() };
        let r = stogradmp(&p, &opts, &mut Rng::seed_from(9));
        assert!(r.converged);
        assert!(p.recovery_error(&r.x) < 1e-7);
        let nnz = r.x.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= p.spec.s);
    }

    #[test]
    fn tally_estimate_accelerates_first_step() {
        // One kernel step seeded with the planted support as T̃ must land
        // the LS fit exactly, mirroring `extra_support_is_respected`.
        let p = easy(52);
        let mut kernel = StoGradMpKernel::new(&p);
        let mut x = SparseIterate::zeros(p.spec.n);
        let mut gamma = Vec::new();
        kernel.tally_step(&mut x, 0, &p.support, &mut gamma);
        assert!(gamma.len() <= p.spec.s);
        assert!(p.recovery_error(x.values()) < 1e-7);
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn bad_probs_rejected() {
        let p = easy(53);
        let mb = p.spec.num_blocks();
        let _ = StoGradMpKernel::with_probs(&p, vec![0.3 / mb as f64; mb]);
    }

    #[test]
    fn matrix_free_sequential_solver_converges() {
        // Identify (sparse gather + transform adjoint) and the QR re-fit's
        // column panel all route through the matrix-free operator.
        let p = ProblemSpec::tiny_matrix_free().generate(&mut Rng::seed_from(60));
        let opts = GreedyOpts { max_iters: 100, ..Default::default() };
        let r = stogradmp(&p, &opts, &mut Rng::seed_from(61));
        assert!(r.converged, "residual {}", r.residual);
        assert!(p.recovery_error(&r.x) < 1e-6);
        let nnz = r.x.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= p.spec.s);
    }
}
