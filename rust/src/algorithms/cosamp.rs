//! CoSaMP (Needell & Tropp 2009): per iteration, merge the top-`2s` proxy
//! coordinates with the current support, least-squares re-fit on the merged
//! set, prune to the top `s`.

use super::{GreedyOpts, RunResult};
use crate::linalg::{lstsq, nrm2};
use crate::metrics::Trace;
use crate::problem::Problem;
use crate::support::{support_of, top_s, union};

/// Run CoSaMP. Uses `opts.tolerance` / `max_iters` for halting (CoSaMP
/// iteration counts are small — tens, not the paper's 1500).
pub fn cosamp(problem: &Problem, opts: &GreedyOpts) -> RunResult {
    let spec = &problem.spec;
    let a = problem.a();
    let mut x = vec![0.0f64; spec.n];
    let mut r = problem.y.clone();
    let mut error_trace = Trace::new();
    let mut resid_trace = Trace::new();
    let mut converged = nrm2(&r) < opts.tolerance;
    let mut iters = 0;

    while !converged && iters < opts.max_iters {
        // proxy = A^T r; identify top 2s.
        let proxy = a.gemv_t(&r);
        let omega = top_s(&proxy, 2 * spec.s);
        // merge with the current support.
        let merged = union(&omega, &support_of(&x));
        // least squares on the merged support.
        let sub = a.select_cols(&merged);
        let z = lstsq(&sub, &problem.y);
        // prune: keep the top-s of the merged-coefficient vector.
        let keep = top_s(&z, spec.s);
        x.fill(0.0);
        for &k in &keep {
            x[merged[k]] = z[k];
        }
        // residual update.
        let ax = a.gemv(&x);
        for i in 0..spec.m {
            r[i] = problem.y[i] - ax[i];
        }
        iters += 1;
        if opts.record_error {
            error_trace.push(problem.recovery_error(&x));
        }
        let rn = nrm2(&r);
        if opts.record_resid {
            resid_trace.push(rn);
        }
        converged = rn < opts.tolerance;
    }

    let residual = problem.residual_norm(&x);
    RunResult { x, iters, converged, residual, error_trace, resid_trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;
    use crate::rng::Rng;
    use crate::support::support_of;

    fn easy(seed: u64) -> Problem {
        ProblemSpec { n: 128, m: 64, b: 8, s: 4, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(seed))
    }

    #[test]
    fn exact_recovery_noiseless() {
        for seed in 1..6u64 {
            let p = easy(seed);
            let r = cosamp(&p, &GreedyOpts { max_iters: 50, ..Default::default() });
            assert!(r.converged, "seed {seed}: residual {}", r.residual);
            assert!(p.recovery_error(&r.x) < 1e-7, "seed {seed}");
            assert_eq!(support_of(&r.x), p.support);
        }
    }

    #[test]
    fn converges_in_few_iterations() {
        let p = easy(6);
        let r = cosamp(&p, &GreedyOpts { max_iters: 50, ..Default::default() });
        assert!(r.iters < 20, "iters {}", r.iters);
    }

    #[test]
    fn iterate_stays_s_sparse() {
        let p = easy(7);
        let r = cosamp(&p, &GreedyOpts { max_iters: 3, ..Default::default() });
        assert!(support_of(&r.x).len() <= p.spec.s);
    }

    #[test]
    fn paper_scale_recovery() {
        // CoSaMP at the paper's shape (n=1000, m=300, s=20).
        let p = ProblemSpec::paper().generate(&mut Rng::seed_from(42));
        let r = cosamp(&p, &GreedyOpts { max_iters: 60, ..Default::default() });
        assert!(r.converged, "residual {}", r.residual);
        assert!(p.relative_error(&r.x) < 1e-6);
    }

    #[test]
    fn zero_measurement_edge_case() {
        // y = 0 -> immediate convergence to x = 0.
        let mut p = easy(8);
        p.y.iter_mut().for_each(|v| *v = 0.0);
        let r = cosamp(&p, &GreedyOpts::default());
        assert!(r.converged);
        assert_eq!(r.iters, 0);
        assert!(r.x.iter().all(|&v| v == 0.0));
    }
}
