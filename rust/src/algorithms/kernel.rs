//! The `SupportKernel` trait — the per-iteration contract every algorithm
//! must satisfy to ride the asynchronous tally architecture.
//!
//! The paper's Algorithm 2 is agnostic to *which* greedy step each core
//! runs: a core (1) samples a measurement block, (2) steps its local
//! iterate given the shared support estimate `T̃ = supp_s(φ)`, (3) casts
//! its vote `Γ^t` into the tally, and (4) checks the halting residual
//! `‖y − A x‖₂`. This module captures exactly that identify/estimate/vote
//! protocol as a trait — in the spirit of the generic asynchronous
//! block-update frameworks of Liu & Wright (async stochastic coordinate
//! descent) and Xu (async primal-dual block updates) — so the discrete-time
//! simulator ([`crate::sim`]) and the real-thread runtime
//! ([`crate::async_runtime`]) are written once and drive *any* kernel:
//! StoIHT ([`super::StoihtKernel`]), StoGradMP
//! ([`super::StoGradMpKernel`]), the PJRT-backed step
//! ([`crate::async_runtime::BackendStep`]), and every future kernel
//! (CoSaMP, HTP, weighted variants) without touching the runtimes again.
//!
//! Implementations are expected to be **allocation-free after warmup**:
//! `tally_step` writes into caller-owned buffers and reuses internal
//! scratch, because the runtimes call it once per core per iteration.

use crate::linalg::{MeasureOp, SparseIterate};
use crate::problem::Problem;
use crate::rng::Rng;

/// Per-iteration step object of one (simulated or real) core.
///
/// One kernel instance per core: implementations carry per-core scratch and
/// are deliberately **not** required to be `Send` — the runtimes construct
/// each kernel inside its own thread via a `Sync` factory (the PJRT client,
/// for one, is not thread-safe in the 0.1.6 crate).
pub trait SupportKernel {
    /// The problem instance this kernel solves.
    fn problem(&self) -> &Problem;

    /// Sample a measurement block from the kernel's block distribution.
    fn sample_block(&self, rng: &mut Rng) -> usize;

    /// One full asynchronous iteration body (the tally protocol's step):
    /// update the sparse iterate `x` in place given the tally estimate
    /// `estimate = T̃^t` (empty slice = no shared information, degrading to
    /// the sequential algorithm), and write the sorted voted support `Γ^t`
    /// into `gamma_out` (cleared first) — a caller scratch buffer, so no
    /// per-iteration vector is allocated.
    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    );

    /// Dense twin of [`SupportKernel::tally_step`] with no tally estimate,
    /// used by the HOGWILD!-style SharedX ablation (A1), where cores share
    /// the *iterate* — dense by design, since concurrent overwrites break
    /// the sparse-support invariant.
    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>);

    /// Throwaway recompute of the identify-phase arithmetic (slow-core
    /// *work* emulation: a worker with period `k` burns `k − 1` of these
    /// per iteration, so its time dilation is made of the same memory
    /// traffic the fast cores issue).
    fn burn(&mut self, x: &SparseIterate<f64>, block: usize);

    /// The halting statistic `‖y − A x‖₂`, evaluated sparsely over `x`'s
    /// support in caller-owned scratch. Takes `&mut self` so kernels can
    /// route through their own [`crate::linalg::OpScratch`] (the matrix-free
    /// operator's check is one fast transform in reused workspace); the
    /// default allocates a fresh operator scratch per call.
    fn residual(&mut self, x: &SparseIterate<f64>, r_scratch: &mut Vec<f64>) -> f64 {
        let mut op_scratch = self.problem().op.make_scratch();
        self.problem().residual_norm_sparse_with(
            x.values(),
            x.support(),
            r_scratch,
            &mut op_scratch,
        )
    }

    /// Ambient problem dimension `n`.
    fn n(&self) -> usize {
        self.problem().spec.n
    }
}

/// Boxed kernels forward, so factories may return `Box<dyn SupportKernel>`
/// when heterogeneous dispatch is wanted (the runtimes themselves are
/// generic and need no box).
impl<K: SupportKernel + ?Sized> SupportKernel for Box<K> {
    fn problem(&self) -> &Problem {
        (**self).problem()
    }

    fn sample_block(&self, rng: &mut Rng) -> usize {
        (**self).sample_block(rng)
    }

    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    ) {
        (**self).tally_step(x, block, estimate, gamma_out)
    }

    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>) {
        (**self).dense_step(x, block, gamma_out)
    }

    fn burn(&mut self, x: &SparseIterate<f64>, block: usize) {
        (**self).burn(x, block)
    }

    fn residual(&mut self, x: &SparseIterate<f64>, r_scratch: &mut Vec<f64>) -> f64 {
        (**self).residual(x, r_scratch)
    }

    fn n(&self) -> usize {
        (**self).n()
    }
}

/// Contiguous measurement-block range owned by one shard of a sharded
/// run: shard `shard` of `shards` over `num_blocks` blocks, balanced so
/// range sizes differ by at most one and the ranges tile `[0,
/// num_blocks)` exactly (pinned by a test).
pub fn shard_block_range(shard: usize, shards: usize, num_blocks: usize) -> (usize, usize) {
    assert!(shards >= 1 && shard < shards, "shard {shard} out of {shards}");
    assert!(
        shards <= num_blocks,
        "cannot split {num_blocks} measurement blocks across {shards} shards"
    );
    let base = num_blocks / shards;
    let extra = num_blocks % shards;
    // The first `extra` shards take one extra block each.
    let lo = shard * base + shard.min(extra);
    let len = base + usize::from(shard < extra);
    (lo, len)
}

/// Restrict any kernel to one shard's contiguous block range — the
/// measurement-partitioning half of the sharded tally design (the other
/// half, support exchange, lives in [`crate::tally::ExchangeBoard`]).
/// Only [`sample_block`] changes: blocks are drawn uniformly from the
/// owned range; stepping, voting, and the halting residual still see the
/// full problem, so a shard's iterate can converge on the global signal
/// from its slice of the measurements plus the exchanged support.
///
/// [`sample_block`]: SupportKernel::sample_block
pub struct ShardedKernel<K> {
    inner: K,
    lo: usize,
    len: usize,
}

impl<K: SupportKernel> ShardedKernel<K> {
    pub fn new(inner: K, shard: usize, shards: usize) -> Self {
        let nb = inner.problem().spec.num_blocks();
        let (lo, len) = shard_block_range(shard, shards, nb);
        ShardedKernel { inner, lo, len }
    }

    /// The owned `(first_block, block_count)` range.
    pub fn block_range(&self) -> (usize, usize) {
        (self.lo, self.len)
    }
}

impl<K: SupportKernel> SupportKernel for ShardedKernel<K> {
    fn problem(&self) -> &Problem {
        self.inner.problem()
    }

    fn sample_block(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below(self.len)
    }

    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    ) {
        self.inner.tally_step(x, block, estimate, gamma_out)
    }

    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>) {
        self.inner.dense_step(x, block, gamma_out)
    }

    fn burn(&mut self, x: &SparseIterate<f64>, block: usize) {
        self.inner.burn(x, block)
    }

    fn residual(&mut self, x: &SparseIterate<f64>, r_scratch: &mut Vec<f64>) -> f64 {
        self.inner.residual(x, r_scratch)
    }

    fn n(&self) -> usize {
        self.inner.n()
    }
}

/// Which [`SupportKernel`] the config-driven layers (CLI, `Leader`,
/// bench registry) drive — the algorithms with an asynchronous story.
/// The purely sequential baselines (IHT, OMP, CoSaMP) are not listed:
/// they have no per-block stochastic step to vote with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Alg {
    /// StoIHT (paper Algorithms 1/2) — the reproduction's default.
    Stoiht,
    /// StoGradMP (the paper's §V extension target).
    StoGradMp,
}

impl Alg {
    pub fn parse(s: &str) -> Option<Alg> {
        match s {
            "stoiht" => Some(Alg::Stoiht),
            "stogradmp" => Some(Alg::StoGradMp),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Alg::Stoiht => "stoiht",
            Alg::StoGradMp => "stogradmp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{StoGradMpKernel, StoihtKernel};
    use crate::problem::ProblemSpec;

    #[test]
    fn alg_parses_and_round_trips() {
        assert_eq!(Alg::parse("stoiht"), Some(Alg::Stoiht));
        assert_eq!(Alg::parse("stogradmp"), Some(Alg::StoGradMp));
        assert_eq!(Alg::parse("omp"), None);
        for a in [Alg::Stoiht, Alg::StoGradMp] {
            assert_eq!(Alg::parse(a.as_str()), Some(a));
        }
    }

    #[test]
    fn boxed_kernel_forwards() {
        let p = ProblemSpec { n: 64, m: 32, b: 8, s: 3, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(4));
        let mut boxed: Box<dyn SupportKernel + '_> = Box::new(StoihtKernel::new(&p, 1.0));
        let mut x = SparseIterate::zeros(p.spec.n);
        let mut gamma = Vec::new();
        boxed.tally_step(&mut x, 0, &[], &mut gamma);
        assert_eq!(gamma.len(), p.spec.s);
        assert_eq!(boxed.n(), p.spec.n);
        let mut scratch = Vec::new();
        assert!(boxed.residual(&x, &mut scratch).is_finite());
    }

    #[test]
    fn shard_ranges_tile_the_blocks_exactly() {
        for num_blocks in 1..=24 {
            for shards in 1..=num_blocks {
                let mut next = 0;
                for k in 0..shards {
                    let (lo, len) = shard_block_range(k, shards, num_blocks);
                    assert_eq!(lo, next, "ranges must be contiguous");
                    assert!(len >= 1, "every shard owns at least one block");
                    next = lo + len;
                }
                assert_eq!(next, num_blocks, "ranges must cover every block");
                let (lo0, len0) = shard_block_range(0, shards, num_blocks);
                let (lol, lenl) = shard_block_range(shards - 1, shards, num_blocks);
                assert!(len0 >= lenl && len0 - lenl <= 1, "balanced within one");
                assert_eq!(lo0, 0);
                assert_eq!(lol + lenl, num_blocks);
            }
        }
    }

    #[test]
    fn sharded_kernel_samples_only_its_range_and_steps_like_inner() {
        let p = ProblemSpec { n: 64, m: 32, b: 4, s: 3, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(9));
        assert_eq!(p.spec.num_blocks(), 8);
        let mut sharded = ShardedKernel::new(StoihtKernel::new(&p, 1.0), 1, 2);
        assert_eq!(sharded.block_range(), (4, 4));
        let mut rng = Rng::seed_from(11);
        for _ in 0..64 {
            let b = sharded.sample_block(&mut rng);
            assert!((4..8).contains(&b), "sampled block {b} outside the owned range");
        }
        // Stepping is untouched: same block + estimate → same iterate as
        // the unwrapped kernel, bit for bit.
        let mut inner = StoihtKernel::new(&p, 1.0);
        let (mut xa, mut xb) = (SparseIterate::zeros(p.spec.n), SparseIterate::zeros(p.spec.n));
        let (mut ga, mut gb) = (Vec::new(), Vec::new());
        sharded.tally_step(&mut xa, 5, &[], &mut ga);
        inner.tally_step(&mut xb, 5, &[], &mut gb);
        assert_eq!(ga, gb);
        let bits = |x: &SparseIterate<f64>| {
            x.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(&xa), bits(&xb));
    }

    fn check_residual_contract<K: SupportKernel>(p: &Problem, kernel: &mut K, name: &str) {
        let mut rng = Rng::seed_from(6);
        let mut x = SparseIterate::zeros(p.spec.n);
        let mut gamma = Vec::new();
        for _ in 0..5 {
            let b = kernel.sample_block(&mut rng);
            kernel.tally_step(&mut x, b, &[], &mut gamma);
        }
        let mut scratch = Vec::new();
        let sparse = kernel.residual(&x, &mut scratch);
        let dense = p.residual_norm(x.values());
        assert!((sparse - dense).abs() <= 1e-12 * (1.0 + dense), "{name}: {sparse} vs {dense}");
    }

    #[test]
    fn default_residual_matches_dense_residual() {
        let p = ProblemSpec { n: 64, m: 32, b: 8, s: 3, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(5));
        check_residual_contract(&p, &mut StoihtKernel::new(&p, 1.0), "stoiht");
        check_residual_contract(&p, &mut StoGradMpKernel::new(&p), "stogradmp");
    }
}
