//! The `SupportKernel` trait — the per-iteration contract every algorithm
//! must satisfy to ride the asynchronous tally architecture.
//!
//! The paper's Algorithm 2 is agnostic to *which* greedy step each core
//! runs: a core (1) samples a measurement block, (2) steps its local
//! iterate given the shared support estimate `T̃ = supp_s(φ)`, (3) casts
//! its vote `Γ^t` into the tally, and (4) checks the halting residual
//! `‖y − A x‖₂`. This module captures exactly that identify/estimate/vote
//! protocol as a trait — in the spirit of the generic asynchronous
//! block-update frameworks of Liu & Wright (async stochastic coordinate
//! descent) and Xu (async primal-dual block updates) — so the discrete-time
//! simulator ([`crate::sim`]) and the real-thread runtime
//! ([`crate::async_runtime`]) are written once and drive *any* kernel:
//! StoIHT ([`super::StoihtKernel`]), StoGradMP
//! ([`super::StoGradMpKernel`]), the PJRT-backed step
//! ([`crate::async_runtime::BackendStep`]), and every future kernel
//! (CoSaMP, HTP, weighted variants) without touching the runtimes again.
//!
//! Implementations are expected to be **allocation-free after warmup**:
//! `tally_step` writes into caller-owned buffers and reuses internal
//! scratch, because the runtimes call it once per core per iteration.

use crate::linalg::{MeasureOp, SparseIterate};
use crate::problem::Problem;
use crate::rng::Rng;

/// Per-iteration step object of one (simulated or real) core.
///
/// One kernel instance per core: implementations carry per-core scratch and
/// are deliberately **not** required to be `Send` — the runtimes construct
/// each kernel inside its own thread via a `Sync` factory (the PJRT client,
/// for one, is not thread-safe in the 0.1.6 crate).
pub trait SupportKernel {
    /// The problem instance this kernel solves.
    fn problem(&self) -> &Problem;

    /// Sample a measurement block from the kernel's block distribution.
    fn sample_block(&self, rng: &mut Rng) -> usize;

    /// One full asynchronous iteration body (the tally protocol's step):
    /// update the sparse iterate `x` in place given the tally estimate
    /// `estimate = T̃^t` (empty slice = no shared information, degrading to
    /// the sequential algorithm), and write the sorted voted support `Γ^t`
    /// into `gamma_out` (cleared first) — a caller scratch buffer, so no
    /// per-iteration vector is allocated.
    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    );

    /// Dense twin of [`SupportKernel::tally_step`] with no tally estimate,
    /// used by the HOGWILD!-style SharedX ablation (A1), where cores share
    /// the *iterate* — dense by design, since concurrent overwrites break
    /// the sparse-support invariant.
    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>);

    /// Throwaway recompute of the identify-phase arithmetic (slow-core
    /// *work* emulation: a worker with period `k` burns `k − 1` of these
    /// per iteration, so its time dilation is made of the same memory
    /// traffic the fast cores issue).
    fn burn(&mut self, x: &SparseIterate<f64>, block: usize);

    /// The halting statistic `‖y − A x‖₂`, evaluated sparsely over `x`'s
    /// support in caller-owned scratch. Takes `&mut self` so kernels can
    /// route through their own [`crate::linalg::OpScratch`] (the matrix-free
    /// operator's check is one fast transform in reused workspace); the
    /// default allocates a fresh operator scratch per call.
    fn residual(&mut self, x: &SparseIterate<f64>, r_scratch: &mut Vec<f64>) -> f64 {
        let mut op_scratch = self.problem().op.make_scratch();
        self.problem().residual_norm_sparse_with(
            x.values(),
            x.support(),
            r_scratch,
            &mut op_scratch,
        )
    }

    /// Ambient problem dimension `n`.
    fn n(&self) -> usize {
        self.problem().spec.n
    }
}

/// Boxed kernels forward, so factories may return `Box<dyn SupportKernel>`
/// when heterogeneous dispatch is wanted (the runtimes themselves are
/// generic and need no box).
impl<K: SupportKernel + ?Sized> SupportKernel for Box<K> {
    fn problem(&self) -> &Problem {
        (**self).problem()
    }

    fn sample_block(&self, rng: &mut Rng) -> usize {
        (**self).sample_block(rng)
    }

    fn tally_step(
        &mut self,
        x: &mut SparseIterate<f64>,
        block: usize,
        estimate: &[usize],
        gamma_out: &mut Vec<usize>,
    ) {
        (**self).tally_step(x, block, estimate, gamma_out)
    }

    fn dense_step(&mut self, x: &mut [f64], block: usize, gamma_out: &mut Vec<usize>) {
        (**self).dense_step(x, block, gamma_out)
    }

    fn burn(&mut self, x: &SparseIterate<f64>, block: usize) {
        (**self).burn(x, block)
    }

    fn residual(&mut self, x: &SparseIterate<f64>, r_scratch: &mut Vec<f64>) -> f64 {
        (**self).residual(x, r_scratch)
    }

    fn n(&self) -> usize {
        (**self).n()
    }
}

/// Which [`SupportKernel`] the config-driven layers (CLI, `Leader`,
/// bench registry) drive — the algorithms with an asynchronous story.
/// The purely sequential baselines (IHT, OMP, CoSaMP) are not listed:
/// they have no per-block stochastic step to vote with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Alg {
    /// StoIHT (paper Algorithms 1/2) — the reproduction's default.
    Stoiht,
    /// StoGradMP (the paper's §V extension target).
    StoGradMp,
}

impl Alg {
    pub fn parse(s: &str) -> Option<Alg> {
        match s {
            "stoiht" => Some(Alg::Stoiht),
            "stogradmp" => Some(Alg::StoGradMp),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Alg::Stoiht => "stoiht",
            Alg::StoGradMp => "stogradmp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{StoGradMpKernel, StoihtKernel};
    use crate::problem::ProblemSpec;

    #[test]
    fn alg_parses_and_round_trips() {
        assert_eq!(Alg::parse("stoiht"), Some(Alg::Stoiht));
        assert_eq!(Alg::parse("stogradmp"), Some(Alg::StoGradMp));
        assert_eq!(Alg::parse("omp"), None);
        for a in [Alg::Stoiht, Alg::StoGradMp] {
            assert_eq!(Alg::parse(a.as_str()), Some(a));
        }
    }

    #[test]
    fn boxed_kernel_forwards() {
        let p = ProblemSpec { n: 64, m: 32, b: 8, s: 3, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(4));
        let mut boxed: Box<dyn SupportKernel + '_> = Box::new(StoihtKernel::new(&p, 1.0));
        let mut x = SparseIterate::zeros(p.spec.n);
        let mut gamma = Vec::new();
        boxed.tally_step(&mut x, 0, &[], &mut gamma);
        assert_eq!(gamma.len(), p.spec.s);
        assert_eq!(boxed.n(), p.spec.n);
        let mut scratch = Vec::new();
        assert!(boxed.residual(&x, &mut scratch).is_finite());
    }

    fn check_residual_contract<K: SupportKernel>(p: &Problem, kernel: &mut K, name: &str) {
        let mut rng = Rng::seed_from(6);
        let mut x = SparseIterate::zeros(p.spec.n);
        let mut gamma = Vec::new();
        for _ in 0..5 {
            let b = kernel.sample_block(&mut rng);
            kernel.tally_step(&mut x, b, &[], &mut gamma);
        }
        let mut scratch = Vec::new();
        let sparse = kernel.residual(&x, &mut scratch);
        let dense = p.residual_norm(x.values());
        assert!((sparse - dense).abs() <= 1e-12 * (1.0 + dense), "{name}: {sparse} vs {dense}");
    }

    #[test]
    fn default_residual_matches_dense_residual() {
        let p = ProblemSpec { n: 64, m: 32, b: 8, s: 3, ..ProblemSpec::tiny() }
            .generate(&mut Rng::seed_from(5));
        check_residual_contract(&p, &mut StoihtKernel::new(&p, 1.0), "stoiht");
        check_residual_contract(&p, &mut StoGradMpKernel::new(&p), "stogradmp");
    }
}
