//! Sparse-recovery algorithms: the paper's Algorithm 1 (StoIHT), the
//! Fig.-1 oracle-support variant, StoGradMP (its §V extension target), and
//! the greedy baselines the paper cites (IHT, OMP, CoSaMP).
//!
//! All solvers consume a [`crate::problem::Problem`] and a [`GreedyOpts`]
//! and produce a [`RunResult`]. The per-iteration *step* of each
//! asynchronous-capable algorithm is factored into a step object —
//! [`StoihtKernel`], [`StoGradMpKernel`] — implementing the
//! [`SupportKernel`] trait (the tally protocol: sample a block, step the
//! local iterate given `T̃`, return the voted support `Γ^t`, report the
//! halting residual), so the asynchronous runtimes (`sim`,
//! `async_runtime`) are generic over the algorithm and reuse exactly the
//! arithmetic the sequential solvers are tested with.

pub mod cosamp;
pub mod iht;
pub mod kernel;
pub mod omp;
pub mod stogradmp;
pub mod stoiht;

pub use cosamp::cosamp;
pub use iht::iht;
pub use kernel::{shard_block_range, Alg, ShardedKernel, SupportKernel};
pub use omp::omp;
pub use stogradmp::{stogradmp, stogradmp_step, StoGradMpKernel};
pub use stoiht::{make_oracle, stoiht, stoiht_with_oracle, StoihtKernel};

use crate::metrics::Trace;

/// Options shared by the iterative greedy solvers (paper §IV defaults).
#[derive(Clone, Debug)]
pub struct GreedyOpts {
    /// Step size `gamma` (paper: 1).
    pub gamma: f64,
    /// Exit when `||y - A x||_2 <` this (paper: 1e-7).
    pub tolerance: f64,
    /// Iteration cap (paper: 1500).
    pub max_iters: usize,
    /// Evaluate the halting residual every `check_every` iterations
    /// (1 = paper-faithful; larger amortizes the `m x n` halting gemv).
    pub check_every: usize,
    /// Record `||x^t - x_true||_2` each iteration into [`RunResult::error_trace`].
    pub record_error: bool,
    /// Record `||y - A x^t||_2` at each check into [`RunResult::resid_trace`].
    pub record_resid: bool,
}

impl Default for GreedyOpts {
    fn default() -> Self {
        GreedyOpts {
            gamma: 1.0,
            tolerance: 1e-7,
            max_iters: 1500,
            check_every: 1,
            record_error: false,
            record_resid: false,
        }
    }
}

impl GreedyOpts {
    /// Paper defaults with error-trace recording on (Fig. 1).
    pub fn recording() -> Self {
        GreedyOpts { record_error: true, ..Default::default() }
    }
}

/// Outcome of a solver run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Final iterate.
    pub x: Vec<f64>,
    /// Iterations executed (= time steps for the sequential algorithms).
    pub iters: usize,
    /// Whether the residual tolerance was met within `max_iters`.
    pub converged: bool,
    /// Final `||y - A x||_2`.
    pub residual: f64,
    /// Per-iteration `||x^t - x_true||_2` (empty unless `record_error`).
    pub error_trace: Trace,
    /// Residual value at each halting check (empty unless `record_resid`).
    pub resid_trace: Trace,
}

impl RunResult {
    /// Recovery error against the planted signal.
    pub fn recovery_error(&self, problem: &crate::problem::Problem) -> f64 {
        problem.recovery_error(&self.x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts_match_paper() {
        let o = GreedyOpts::default();
        assert_eq!(o.gamma, 1.0);
        assert_eq!(o.tolerance, 1e-7);
        assert_eq!(o.max_iters, 1500);
        assert_eq!(o.check_every, 1);
        assert!(!o.record_error);
    }

    #[test]
    fn recording_enables_error_trace() {
        assert!(GreedyOpts::recording().record_error);
    }
}
