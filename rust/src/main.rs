//! `astir` — CLI for the ASTIR asynchronous sparse-recovery stack.
//!
//! Subcommands map 1:1 onto the paper's figures and this repo's ablations
//! (see README.md for the experiment map):
//!
//! ```text
//! astir fig1                         # Fig. 1: oracle-support StoIHT
//! astir fig2 --schedule all-fast     # Fig. 2 upper
//! astir fig2 --schedule half-slow    # Fig. 2 lower
//! astir ablation tally-vs-shared-x | inconsistent-reads | weighting | block-size
//! astir baselines                    # A5 phase-transition sweep
//! astir run --alg stoiht             # one solve, native backend
//! astir run --alg stoiht --backend pjrt
//! astir async --cores 8              # real-thread asynchronous StoIHT
//! astir info                         # artifact + config introspection
//! ```
//!
//! Common flags: `--config <file.toml>`, `--trials N`, `--seed N`,
//! `--cores-list a,b,c`. Argument parsing is hand-rolled (offline build —
//! no clap); unknown flags are hard errors.

use std::process::ExitCode;

use astir::algorithms::{self, GreedyOpts};
use astir::async_runtime::{run_async, AsyncOpts};
use astir::backend::{Backend, NativeBackend, PjrtBackend};
use astir::config::ExperimentConfig;
use astir::experiments::{self, Fig2Variant};
use astir::report;
use astir::rng::Rng;
use astir::runtime::ArtifactStore;
use astir::sim::SpeedSchedule;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: Vec<String>) -> Result<(), String> {
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    let mut flags = Flags::parse(rest)?;
    let cfg = load_config(&mut flags)?;

    match cmd.as_str() {
        "fig1" => {
            flags.finish()?;
            println!("Fig. 1 — StoIHT with an accurate support estimate");
            println!(
                "n={} m={} b={} s={} gamma={} tol={} trials={}",
                cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s,
                cfg.gamma, cfg.tolerance, cfg.trials
            );
            let out = experiments::fig1(&cfg);
            report::emit("fig1", "mean recovery error vs iteration (thinned)", &summarize_fig1(&out.series));
            report::emit("fig1_full", "full per-iteration series", &out.series);
            report::emit("fig1_summary", "per-variant convergence summary", &out.summary);
        }
        "fig2" => {
            let schedule = flags.take("schedule")?.unwrap_or_else(|| "all-fast".into());
            flags.finish()?;
            let variant = match schedule.as_str() {
                "all-fast" => Fig2Variant::Upper,
                "half-slow" => Fig2Variant::Lower { period: 4 },
                other => return Err(format!("unknown --schedule `{other}` (all-fast|half-slow)")),
            };
            println!("Fig. 2 — time steps to exit vs cores ({})", variant.label());
            let table = experiments::fig2(&cfg, variant);
            let name = if matches!(variant, Fig2Variant::Upper) { "fig2_upper" } else { "fig2_lower" };
            report::emit(name, variant.label(), &table);
        }
        "ablation" => {
            let mut which = flags.take("name")?;
            if which.is_none() {
                which = flags.positional.pop();
            }
            flags.finish()?;
            match which.as_deref() {
                Some("tally-vs-shared-x") => {
                    let t = experiments::tally_vs_shared_x(&cfg);
                    report::emit("ablation_tally_vs_shared_x", "A1: tally vs shared-x sharing", &t);
                }
                Some("inconsistent-reads") => {
                    let t = experiments::inconsistent_reads(&cfg);
                    report::emit("ablation_inconsistent_reads", "A2: stale tally reads", &t);
                }
                Some("weighting") => {
                    let t = experiments::tally_weighting(&cfg);
                    report::emit("ablation_weighting", "A3: tally weighting schemes", &t);
                }
                Some("block-size") => {
                    let bs = divisors_near(cfg.problem.m);
                    let t = experiments::block_size_sweep(&cfg, &bs);
                    report::emit("ablation_block_size", "A4: block size sweep", &t);
                }
                other => {
                    return Err(format!(
                        "unknown ablation {other:?} (tally-vs-shared-x|inconsistent-reads|weighting|block-size)"
                    ))
                }
            }
        }
        "baselines" => {
            flags.finish()?;
            let ms = baseline_ms(&cfg);
            println!("A5 — phase transition over m = {ms:?}");
            let t = experiments::phase_transition(&cfg, &ms);
            report::emit("baselines_phase_transition", "A5: success rate vs m", &t);
        }
        "run" => {
            let alg = flags.take("alg")?.unwrap_or_else(|| "stoiht".into());
            let backend = flags.take("backend")?.unwrap_or_else(|| "native".into());
            flags.finish()?;
            run_single(&cfg, &alg, &backend)?;
        }
        "async" => {
            let cores: usize = flags
                .take("cores")?
                .unwrap_or_else(|| "4".into())
                .parse()
                .map_err(|e| format!("--cores: {e}"))?;
            let schedule = flags.take("schedule")?.unwrap_or_else(|| "all-fast".into());
            flags.finish()?;
            run_async_cmd(&cfg, cores, &schedule)?;
        }
        "info" => {
            flags.finish()?;
            print_info(&cfg);
        }
        "help" | "--help" | "-h" => {
            print_usage();
        }
        other => {
            print_usage();
            return Err(format!("unknown command `{other}`"));
        }
    }
    Ok(())
}

/// Flag parser: `--key value` pairs plus positionals.
struct Flags {
    pairs: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Self, String> {
        let mut pairs = Vec::new();
        let mut positional = Vec::new();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let value = it
                    .next()
                    .ok_or_else(|| format!("flag --{key} needs a value"))?
                    .clone();
                pairs.push((key.to_string(), value));
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Flags { pairs, positional })
    }

    /// Remove and return a flag's value.
    fn take(&mut self, key: &str) -> Result<Option<String>, String> {
        let idx = self.pairs.iter().position(|(k, _)| k == key);
        Ok(idx.map(|i| self.pairs.remove(i).1))
    }

    /// Error on any unconsumed flag/positional.
    fn finish(&mut self) -> Result<(), String> {
        if let Some((k, _)) = self.pairs.first() {
            return Err(format!("unknown flag --{k}"));
        }
        if let Some(p) = self.positional.first() {
            return Err(format!("unexpected argument `{p}`"));
        }
        Ok(())
    }
}

/// Load the config file (if any) and apply common overrides.
fn load_config(flags: &mut Flags) -> Result<ExperimentConfig, String> {
    let mut cfg = match flags.take("config")? {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(&path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = flags.take("trials")? {
        cfg.trials = v.parse().map_err(|e| format!("--trials: {e}"))?;
    }
    if let Some(v) = flags.take("seed")? {
        cfg.seed = v.parse().map_err(|e| format!("--seed: {e}"))?;
    }
    if let Some(v) = flags.take("threads")? {
        cfg.trial_threads = v.parse().map_err(|e| format!("--threads: {e}"))?;
    }
    if let Some(v) = flags.take("cores-list")? {
        cfg.cores = v
            .split(',')
            .map(|s| s.trim().parse::<usize>().map_err(|e| format!("--cores-list: {e}")))
            .collect::<Result<Vec<_>, _>>()?;
    }
    if let Some(v) = flags.take("max-iters")? {
        cfg.max_iters = v.parse().map_err(|e| format!("--max-iters: {e}"))?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Thin the Fig.-1 table for terminal display (every 50th iteration).
fn summarize_fig1(full: &astir::metrics::Table) -> astir::metrics::Table {
    let mut t = astir::metrics::Table::new(
        &full.columns.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (i, row) in full.rows.iter().enumerate() {
        if i % 50 == 0 || i + 1 == full.rows.len() {
            t.push_row(row.clone());
        }
    }
    t
}

fn divisors_near(m: usize) -> Vec<usize> {
    // A small spread of block sizes dividing m, around the paper's 15.
    let candidates = [5usize, 10, 15, 20, 25, 30, 50, 60, 75];
    let mut out: Vec<usize> = candidates.iter().copied().filter(|&b| b <= m && m % b == 0).collect();
    if out.is_empty() {
        out.push(1);
    }
    out
}

fn baseline_ms(cfg: &ExperimentConfig) -> Vec<usize> {
    // Sweep m from deeply undersampled to the configured m.
    let m = cfg.problem.m;
    let mut ms: Vec<usize> = (1..=6).map(|k| k * m / 6).filter(|&v| v >= cfg.problem.s).collect();
    ms.dedup();
    ms
}

fn run_single(cfg: &ExperimentConfig, alg: &str, backend_name: &str) -> Result<(), String> {
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.problem.generate(&mut rng);
    let opts = GreedyOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_iters: cfg.max_iters,
        ..Default::default()
    };
    println!(
        "single solve: alg={alg} backend={backend_name} n={} m={} b={} s={}",
        cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s
    );
    let t0 = std::time::Instant::now();
    let result = match (alg, backend_name) {
        ("stoiht", "native") => algorithms::stoiht(&problem, &opts, &mut rng),
        ("iht", "native") => algorithms::iht(&problem, &opts),
        ("omp", "native") => algorithms::omp(&problem, &opts),
        ("cosamp", "native") => {
            algorithms::cosamp(&problem, &GreedyOpts { max_iters: 100, ..opts })
        }
        ("stogradmp", "native") => {
            algorithms::stogradmp(&problem, &GreedyOpts { max_iters: 200, ..opts }, &mut rng)
        }
        ("stoiht", "pjrt") => {
            let mut be = PjrtBackend::from_default_dir().map_err(|e| e.to_string())?;
            println!("PJRT platform: {}", be.runtime().platform());
            run_stoiht_on_backend(&problem, &opts, &mut be, &mut rng).map_err(|e| e.to_string())?
        }
        (a, b) => return Err(format!("unsupported combination alg={a} backend={b}")),
    };
    let dt = t0.elapsed();
    println!(
        "converged={} iters={} residual={:.3e} recovery_error={:.3e} wall={:.1?}",
        result.converged,
        result.iters,
        result.residual,
        problem.recovery_error(&result.x),
        dt
    );
    Ok(())
}

/// Sequential StoIHT driven through a [`Backend`] (exercises PJRT).
fn run_stoiht_on_backend<B: Backend>(
    problem: &astir::problem::Problem,
    opts: &GreedyOpts,
    backend: &mut B,
    rng: &mut Rng,
) -> anyhow::Result<algorithms::RunResult> {
    let spec = &problem.spec;
    let mb = spec.num_blocks();
    let mut x = vec![0.0f64; spec.n];
    let zero_mask = vec![0.0f64; spec.n];
    let mut iters = 0;
    let mut converged = false;
    let mut residual = f64::INFINITY;
    for t in 1..=opts.max_iters {
        let block = rng.below(mb);
        let (x_next, _gamma) = backend.stoiht_step(problem, block, &x, opts.gamma, &zero_mask)?;
        x = x_next;
        iters = t;
        residual = backend.residual_norm(problem, &x)?;
        if residual < opts.tolerance {
            converged = true;
            break;
        }
    }
    Ok(algorithms::RunResult {
        x,
        iters,
        converged,
        residual,
        error_trace: Default::default(),
        resid_trace: Default::default(),
    })
}

fn run_async_cmd(cfg: &ExperimentConfig, cores: usize, schedule: &str) -> Result<(), String> {
    let sched = match schedule {
        "all-fast" => SpeedSchedule::AllFast,
        "half-slow" => SpeedSchedule::HalfSlow { period: 4 },
        other => return Err(format!("unknown --schedule `{other}`")),
    };
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.problem.generate(&mut rng);
    let opts = AsyncOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_local_iters: cfg.max_iters,
        schedule: sched,
        ..Default::default()
    };
    println!("real-thread asynchronous StoIHT: cores={cores} schedule={schedule}");
    let out = run_async(&problem, cores, &opts, cfg.seed ^ 0xA5);
    println!(
        "converged={} exit_core={:?} wall={:.1?} residual={:.3e} error={:.3e}",
        out.converged, out.exit_core, out.wall, out.residual, out.final_error
    );
    println!("local iterations per core: {:?}", out.local_iters);
    Ok(())
}

fn print_info(cfg: &ExperimentConfig) {
    println!("astir {} — asynchronous sparse recovery (Needell & Woolf 2017)", astir::VERSION);
    println!("\n[config]");
    println!(
        "problem: n={} m={} b={} s={} ensemble={:?} signal={:?} noise={}",
        cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s,
        cfg.problem.ensemble, cfg.problem.signal, cfg.problem.noise_std
    );
    println!(
        "gamma={} tol={} max_iters={} trials={} seed={} cores={:?} trial_threads={}",
        cfg.gamma, cfg.tolerance, cfg.max_iters, cfg.trials, cfg.seed, cfg.cores, cfg.trial_threads
    );
    println!("\n[artifacts] ({})", ArtifactStore::default_dir().display());
    match ArtifactStore::discover(&ArtifactStore::default_dir()) {
        Ok(store) => {
            for meta in store.iter() {
                println!(
                    "  {:?} n={} m={} rows={} s={} -> {}",
                    meta.kind, meta.n, meta.m, meta.b, meta.s, meta.hlo_path.display()
                );
            }
        }
        Err(e) => println!("  (unavailable: {e})"),
    }
    println!("\n[backends] native: {} | pjrt: executes the artifacts above", NativeBackend::new().name());
}

fn print_usage() {
    println!(
        "astir — asynchronous parallel sparse recovery (Needell & Woolf 2017)

USAGE: astir <command> [flags]

COMMANDS
  fig1                         regenerate Fig. 1 (oracle-support StoIHT)
  fig2 --schedule all-fast     regenerate Fig. 2 upper panel
  fig2 --schedule half-slow    regenerate Fig. 2 lower panel
  ablation <name>              A1..A4 (tally-vs-shared-x, inconsistent-reads,
                               weighting, block-size)
  baselines                    A5 phase-transition sweep (IHT/StoIHT/OMP/...)
  run --alg X --backend Y      one solve (alg: stoiht|iht|omp|cosamp|stogradmp;
                               backend: native|pjrt)
  async --cores N              real-thread asynchronous StoIHT
  info                         show config + discovered AOT artifacts

COMMON FLAGS
  --config file.toml   load an experiment config (see configs/)
  --trials N           Monte-Carlo trials (default 500)
  --seed N             master seed
  --threads N          worker threads for trial batching
  --cores-list a,b,c   core counts to sweep
  --max-iters N        iteration / time-step cap"
    );
}
