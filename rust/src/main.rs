//! `astir` — CLI for the ASTIR asynchronous sparse-recovery stack.
//!
//! Subcommands map 1:1 onto the paper's figures and this repo's ablations
//! (see README.md for the experiment map):
//!
//! ```text
//! astir fig1                         # Fig. 1: oracle-support StoIHT
//! astir fig2 --schedule all-fast     # Fig. 2 upper
//! astir fig2 --schedule half-slow    # Fig. 2 lower
//! astir ablation tally-vs-shared-x | inconsistent-reads | weighting | block-size
//! astir baselines                    # A5 phase-transition sweep
//! astir bench --smoke --json out.json  # bench registry + JSON telemetry
//! astir bench --compare baseline.json  # fail on perf regressions
//! astir run --alg stoiht             # one solve, native backend
//! astir run --alg stoiht --backend pjrt
//! astir async --cores 8              # real-thread asynchronous StoIHT
//! astir async --alg stogradmp        # ... or any other SupportKernel
//! astir async --shards 4 --exchange-period 16   # sharded tally, bounded staleness
//! astir batch --jobs 32 --workers 8  # persistent recovery pool, shared operator
//! astir batch --batch 8              # MMV lockstep: 8 signals/job, shared tally
//! astir serve --addr 127.0.0.1:7878  # zero-dep TCP front-end (typed v1 job API)
//! astir exchange-hub --shards 4      # rendezvous for a multi-process fleet
//! astir shard-worker --hub H --shard K --shards 4   # one shard process

//! astir run --alg stoiht --ensemble partial_dct --no-dense-a --n 1048576 --m 327680 --b 16
//! astir fig2 --alg stogradmp --schedule half-slow --period 6
//! astir info                         # artifact + config introspection
//! ```
//!
//! Common flags: `--config <file.toml>`, `--trials N`, `--seed N`,
//! `--cores-list a,b,c`. Argument parsing is hand-rolled (offline build —
//! no clap); unknown flags are hard errors.

use std::process::ExitCode;

use astir::algorithms::{self, Alg, GreedyOpts, StoGradMpKernel};
use astir::async_runtime::{run_async, run_async_with, AsyncOpts};
use astir::backend::{Backend, NativeBackend, PjrtBackend};
use astir::bench_harness::{
    compare_reports, human_time, json as bench_json, suites, Mode, RunOpts,
    DEFAULT_REGRESSION_THRESHOLD,
};
use astir::config::ExperimentConfig;
use astir::experiments::{self, Fig2Variant};
use astir::report;
use astir::rng::Rng;
use astir::runtime::ArtifactStore;
use astir::service::api::{JobRequest, JobResponse};
use astir::service::server::{ServeOpts, Server};
use astir::service::transport::{join_fleet, run_joined, x_digest, ExchangeHub, HubOpts};
use astir::service::{recover_batch_stoiht, solve_job, RecoveryPool, ShardedPool};
use astir::sim::SpeedSchedule;
use astir::tally::ExchangeProtocol;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: Vec<String>) -> Result<(), String> {
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    let mut flags = Flags::parse(rest);
    if cmd == "bench" {
        // The bench registry builds its own mode-scaled configs; the
        // common experiment flags below do not apply.
        return bench_cmd(&mut flags);
    }
    if cmd == "lint" {
        // Source-level analysis: no experiment config involved.
        return lint_cmd(&mut flags);
    }
    let cfg = load_config(&mut flags)?;

    match cmd.as_str() {
        "fig1" => {
            flags.finish()?;
            println!("Fig. 1 — StoIHT with an accurate support estimate");
            println!(
                "n={} m={} b={} s={} gamma={} tol={} trials={}",
                cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s,
                cfg.gamma, cfg.tolerance, cfg.trials
            );
            let out = experiments::fig1(&cfg);
            let thinned = summarize_fig1(&out.series);
            report::emit("fig1", "mean recovery error vs iteration (thinned)", &thinned);
            report::emit("fig1_full", "full per-iteration series", &out.series);
            report::emit("fig1_summary", "per-variant convergence summary", &out.summary);
        }
        "fig2" => {
            let mut cfg = cfg;
            apply_alg_flag(&mut cfg, &mut flags)?;
            let schedule = take_schedule(&mut flags)?;
            flags.finish()?;
            let variant = match schedule {
                SpeedSchedule::AllFast => Fig2Variant::Upper,
                SpeedSchedule::HalfSlow { period } => Fig2Variant::Lower { period },
                SpeedSchedule::Custom(_) => unreachable!("take_schedule never builds Custom"),
            };
            println!(
                "Fig. 2 — time steps to exit vs cores ({}, alg {})",
                variant.label(),
                cfg.alg.as_str()
            );
            let table = experiments::fig2(&cfg, variant);
            // Non-default alg/period runs get their own results names so
            // they never clobber the paper's StoIHT figure data.
            let mut name = if matches!(variant, Fig2Variant::Upper) {
                "fig2_upper".to_string()
            } else {
                "fig2_lower".to_string()
            };
            if cfg.alg != Alg::Stoiht {
                name.push('_');
                name.push_str(cfg.alg.as_str());
            }
            if let Fig2Variant::Lower { period } = variant {
                if period != 4 {
                    name.push_str(&format!("_p{period}"));
                }
            }
            report::emit(&name, variant.label(), &table);
        }
        "ablation" => {
            let mut which = flags.take("name")?;
            if which.is_none() {
                which = flags.take_positional();
            }
            flags.finish()?;
            match which.as_deref() {
                Some("tally-vs-shared-x") => {
                    let t = experiments::tally_vs_shared_x(&cfg);
                    report::emit("ablation_tally_vs_shared_x", "A1: tally vs shared-x sharing", &t);
                }
                Some("inconsistent-reads") => {
                    let t = experiments::inconsistent_reads(&cfg);
                    report::emit("ablation_inconsistent_reads", "A2: stale tally reads", &t);
                }
                Some("weighting") => {
                    let t = experiments::tally_weighting(&cfg);
                    report::emit("ablation_weighting", "A3: tally weighting schemes", &t);
                }
                Some("block-size") => {
                    let bs = divisors_near(cfg.problem.m);
                    let t = experiments::block_size_sweep(&cfg, &bs);
                    report::emit("ablation_block_size", "A4: block size sweep", &t);
                }
                other => {
                    return Err(format!(
                        "unknown ablation {other:?} (tally-vs-shared-x|inconsistent-reads|weighting|block-size)"
                    ))
                }
            }
        }
        "baselines" => {
            flags.finish()?;
            if !cfg.problem.dense_a {
                // The A5 sweep runs the classical full-gradient solvers,
                // which consume the materialized matrix; fail up front
                // instead of panicking mid-sweep.
                return Err(
                    "baselines needs dense matrices (IHT/OMP/CoSaMP); drop --no-dense-a".into()
                );
            }
            let ms = baseline_ms(&cfg);
            println!("A5 — phase transition over m = {ms:?}");
            let t = experiments::phase_transition(&cfg, &ms);
            report::emit("baselines_phase_transition", "A5: success rate vs m", &t);
        }
        "run" => {
            // `--alg` is a superset of the config selector here: the
            // sequential baselines (iht|omp|cosamp) have no async story
            // but remain runnable.
            let alg = flags.take("alg")?.unwrap_or_else(|| cfg.alg.as_str().into());
            let backend = flags.take("backend")?.unwrap_or_else(|| "native".into());
            flags.finish()?;
            run_single(&cfg, &alg, &backend)?;
        }
        "async" => {
            let mut cfg = cfg;
            apply_alg_flag(&mut cfg, &mut flags)?;
            let cores: usize = flags
                .take("cores")?
                .unwrap_or_else(|| "4".into())
                .parse()
                .map_err(|e| format!("--cores: {e}"))?;
            if let Some(v) = flags.take("shards")? {
                cfg.shard.shards = v.parse().map_err(|e| format!("--shards: {e}"))?;
            }
            if let Some(v) = flags.take("exchange-period")? {
                cfg.shard.exchange_period =
                    v.parse().map_err(|e| format!("--exchange-period: {e}"))?;
            }
            if let Some(v) = flags.take("exchange-protocol")? {
                cfg.shard.protocol = ExchangeProtocol::parse(&v)
                    .ok_or_else(|| format!("unknown --exchange-protocol `{v}` (gossip|leader)"))?;
            }
            cfg.validate()?;
            let schedule = take_schedule(&mut flags)?;
            flags.finish()?;
            run_async_cmd(&cfg, cores, &schedule)?;
        }
        "batch" => {
            let mut cfg = cfg;
            apply_alg_flag(&mut cfg, &mut flags)?;
            if let Some(v) = flags.take("jobs")? {
                cfg.service.jobs = v.parse().map_err(|e| format!("--jobs: {e}"))?;
            }
            if let Some(v) = flags.take("workers")? {
                cfg.service.workers = v.parse().map_err(|e| format!("--workers: {e}"))?;
            }
            if let Some(v) = flags.take("batch")? {
                cfg.service.batch = v.parse().map_err(|e| format!("--batch: {e}"))?;
            }
            cfg.validate()?;
            flags.finish()?;
            run_batch_cmd(&cfg)?;
        }
        "serve" => {
            let mut cfg = cfg;
            if let Some(v) = flags.take("addr")? {
                cfg.serve.addr = v;
            }
            if let Some(v) = flags.take("workers")? {
                cfg.serve.workers = v.parse().map_err(|e| format!("--workers: {e}"))?;
            }
            if let Some(v) = flags.take("batch-window-ms")? {
                cfg.serve.batch_window_ms =
                    v.parse().map_err(|e| format!("--batch-window-ms: {e}"))?;
            }
            if let Some(v) = flags.take("max-inflight")? {
                cfg.serve.max_inflight =
                    v.parse().map_err(|e| format!("--max-inflight: {e}"))?;
            }
            cfg.validate()?;
            flags.finish()?;
            run_serve_cmd(&cfg)?;
        }
        "exchange-hub" => {
            let mut cfg = cfg;
            if let Some(v) = flags.take("shards")? {
                cfg.shard.shards = v.parse().map_err(|e| format!("--shards: {e}"))?;
            }
            let addr = flags.take("addr")?.unwrap_or_else(|| "127.0.0.1:7879".into());
            let join_ms = match flags.take("join-timeout-ms")? {
                Some(v) => Some(v.parse().map_err(|e| format!("--join-timeout-ms: {e}"))?),
                None => None,
            };
            let round_ms = match flags.take("round-timeout-ms")? {
                Some(v) => Some(v.parse().map_err(|e| format!("--round-timeout-ms: {e}"))?),
                None => None,
            };
            cfg.validate()?;
            flags.finish()?;
            run_exchange_hub_cmd(&addr, cfg.shard.shards, join_ms, round_ms)?;
        }
        "shard-worker" => {
            let mut cfg = cfg;
            apply_alg_flag(&mut cfg, &mut flags)?;
            let hub = flags.take("hub")?.unwrap_or_else(|| "127.0.0.1:7879".into());
            let shard: usize = flags
                .take("shard")?
                .ok_or_else(|| "shard-worker requires --shard <k>".to_string())?
                .parse()
                .map_err(|e| format!("--shard: {e}"))?;
            if let Some(v) = flags.take("shards")? {
                cfg.shard.shards = v.parse().map_err(|e| format!("--shards: {e}"))?;
            }
            if let Some(v) = flags.take("exchange-period")? {
                cfg.shard.exchange_period =
                    v.parse().map_err(|e| format!("--exchange-period: {e}"))?;
            }
            if let Some(v) = flags.take("exchange-protocol")? {
                cfg.shard.protocol = ExchangeProtocol::parse(&v)
                    .ok_or_else(|| format!("unknown --exchange-protocol `{v}` (gossip|leader)"))?;
            }
            cfg.validate()?;
            let schedule = take_schedule(&mut flags)?;
            flags.finish()?;
            run_shard_worker_cmd(&cfg, &hub, shard, &schedule)?;
        }
        "info" => {
            flags.finish()?;
            print_info(&cfg);
        }
        "help" | "--help" | "-h" => {
            print_usage();
        }
        other => {
            print_usage();
            return Err(format!("unknown command `{other}`"));
        }
    }
    Ok(())
}

/// Flag parser: `--key value` pairs, boolean `--key` switches, and
/// positionals, consumed by the subcommand and then checked empty.
struct Flags {
    args: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Self {
        Flags { args: args.to_vec() }
    }

    /// Remove `--key <value>` and return the value.
    fn take(&mut self, key: &str) -> Result<Option<String>, String> {
        let Some(i) = self.args.iter().position(|a| a == &format!("--{key}")) else {
            return Ok(None);
        };
        if i + 1 >= self.args.len() || self.args[i + 1].starts_with("--") {
            return Err(format!("flag --{key} needs a value"));
        }
        self.args.remove(i);
        Ok(Some(self.args.remove(i)))
    }

    /// Remove a boolean `--key` switch, returning whether it was present.
    fn take_bool(&mut self, key: &str) -> bool {
        match self.args.iter().position(|a| a == &format!("--{key}")) {
            Some(i) => {
                self.args.remove(i);
                true
            }
            None => false,
        }
    }

    /// Remove and return the first positional (non-`--`) argument.
    fn take_positional(&mut self) -> Option<String> {
        let i = self.args.iter().position(|a| !a.starts_with("--"))?;
        Some(self.args.remove(i))
    }

    /// Error on any unconsumed flag/positional.
    fn finish(&mut self) -> Result<(), String> {
        match self.args.first() {
            Some(a) if a.starts_with("--") => Err(format!("unknown flag {a}")),
            Some(a) => Err(format!("unexpected argument `{a}`")),
            None => Ok(()),
        }
    }
}

/// `astir bench`: run the suite registry with filtering, mode selection,
/// JSON telemetry, and baseline regression comparison.
fn bench_cmd(flags: &mut Flags) -> Result<(), String> {
    let filter = flags.take("filter")?;
    let smoke = flags.take_bool("smoke");
    let list = flags.take_bool("list");
    let json = flags.take("json")?;
    let compare = flags.take("compare")?;
    let threshold = match flags.take("threshold")? {
        Some(v) => v.parse::<f64>().map_err(|e| format!("--threshold: {e}"))?,
        None => DEFAULT_REGRESSION_THRESHOLD,
    };
    if !(threshold.is_finite() && threshold >= 0.0) {
        return Err(format!("--threshold must be a nonnegative fraction, got {threshold}"));
    }
    flags.finish()?;

    let mode = if smoke { Mode::Smoke } else { Mode::Full };
    if list && (json.is_some() || compare.is_some()) {
        return Err("--list cannot be combined with --json or --compare".to_string());
    }

    // Fail fast: a missing/malformed/mode-mismatched baseline must error
    // before the (potentially minutes-long) suite run, not after.
    let baseline = match &compare {
        Some(base_path) => {
            let text = std::fs::read_to_string(base_path)
                .map_err(|e| format!("reading baseline {base_path}: {e}"))?;
            let base = bench_json::parse_report(&text)
                .map_err(|e| format!("parsing baseline {base_path}: {e}"))?;
            if base.mode != mode {
                // Experiment benches are mode-scaled (smoke shrinks trials
                // and core sweeps ~10x), so cross-mode ratios are
                // meaningless.
                return Err(format!(
                    "baseline {base_path} was recorded in {} mode but this run is {} mode; \
                     rerun with {} (or record a matching baseline)",
                    base.mode.as_str(),
                    mode.as_str(),
                    if base.mode == Mode::Smoke { "--smoke" } else { "full budgets" }
                ));
            }
            Some(base)
        }
        None => None,
    };

    let mut opts = RunOpts::from_env(mode);
    opts.filter = filter;
    opts.dry_run = list;

    let run_report = suites::run_all(&opts);

    if list {
        println!("registered benchmarks ({} mode):", mode.as_str());
        for s in &run_report.suites {
            for b in &s.benches {
                println!("  {}/{}", s.name, b.name);
            }
            for name in &s.skipped {
                println!("  {}/{name} (gated)", s.name);
            }
        }
        return Ok(());
    }

    println!(
        "\n=== bench summary ({} mode, rev {}) ===",
        mode.as_str(),
        run_report.git_rev.as_deref().unwrap_or("unknown")
    );
    for s in &run_report.suites {
        for b in &s.benches {
            let key = format!("{}/{}", s.name, b.name);
            println!("  {key:<52} {:>12}/iter", human_time(b.time.mean));
        }
        for name in &s.skipped {
            let key = format!("{}/{name}", s.name);
            println!("  {key:<52} {:>12}", "skipped");
        }
    }

    if let Some(path) = json {
        let path = std::path::PathBuf::from(path);
        bench_json::write_report(&run_report, &path)
            .map_err(|e| format!("writing {}: {e}", path.display()))?;
        println!("[bench telemetry written {}]", path.display());
    }

    if let (Some(base), Some(base_path)) = (baseline, compare.as_deref()) {
        let outcome = compare_reports(&base, &run_report, threshold);
        println!(
            "\n=== regression check vs {base_path} (threshold +{:.0}%) ===",
            threshold * 100.0
        );
        for d in &outcome.deltas {
            println!(
                "  {:<52} {:>8.2}x {}",
                d.name,
                d.ratio,
                if d.regressed { "REGRESSED" } else { "ok" }
            );
        }
        for name in &outcome.missing_in_new {
            println!("  {name:<52} (in baseline, missing from this run)");
        }
        for name in &outcome.new_only {
            println!("  {name:<52} (new, no baseline)");
        }
        if outcome.deltas.is_empty() {
            // A filter typo must not let the gate pass vacuously.
            return Err(format!(
                "no benchmarks overlap between this run and the baseline {base_path} \
                 (check --filter and the baseline's contents)"
            ));
        }
        let regressions = outcome.regressions();
        if !regressions.is_empty() {
            return Err(format!(
                "{} benchmark(s) regressed beyond +{:.0}%: {}",
                regressions.len(),
                threshold * 100.0,
                regressions.iter().map(|d| d.name.as_str()).collect::<Vec<_>>().join(", ")
            ));
        }
        println!("no regressions beyond +{:.0}%", threshold * 100.0);
    }
    Ok(())
}

/// The shared `--schedule`/`--period` flag pair (fig2 and async use the
/// identical vocabulary — previously two hand-rolled copies with a
/// hard-coded period).
fn take_schedule(flags: &mut Flags) -> Result<SpeedSchedule, String> {
    let name = flags.take("schedule")?.unwrap_or_else(|| "all-fast".into());
    let period_flag = flags.take("period")?;
    let period = match &period_flag {
        Some(v) => {
            let p: usize = v.parse().map_err(|e| format!("--period: {e}"))?;
            if p < 1 {
                return Err("--period must be >= 1".into());
            }
            p
        }
        None => 4, // the paper's Fig.-2 lower panel
    };
    match name.as_str() {
        "all-fast" => {
            if period_flag.is_some() {
                // Swallowing the flag would run the wrong experiment.
                return Err("--period only applies with --schedule half-slow".into());
            }
            Ok(SpeedSchedule::AllFast)
        }
        "half-slow" => Ok(SpeedSchedule::HalfSlow { period }),
        other => Err(format!("unknown --schedule `{other}` (all-fast|half-slow)")),
    }
}

/// Optional `--alg` override of the config's algorithm selector.
fn apply_alg_flag(cfg: &mut ExperimentConfig, flags: &mut Flags) -> Result<(), String> {
    if let Some(v) = flags.take("alg")? {
        cfg.alg =
            Alg::parse(&v).ok_or_else(|| format!("unknown --alg `{v}` (stoiht|stogradmp)"))?;
    }
    Ok(())
}

/// Load the config file (if any) and apply common overrides.
fn load_config(flags: &mut Flags) -> Result<ExperimentConfig, String> {
    let mut cfg = match flags.take("config")? {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(&path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = flags.take("trials")? {
        cfg.trials = v.parse().map_err(|e| format!("--trials: {e}"))?;
    }
    if let Some(v) = flags.take("seed")? {
        cfg.seed = v.parse().map_err(|e| format!("--seed: {e}"))?;
    }
    if let Some(v) = flags.take("threads")? {
        cfg.trial_threads = v.parse().map_err(|e| format!("--threads: {e}"))?;
    }
    if let Some(v) = flags.take("cores-list")? {
        cfg.cores = v
            .split(',')
            .map(|s| s.trim().parse::<usize>().map_err(|e| format!("--cores-list: {e}")))
            .collect::<Result<Vec<_>, _>>()?;
    }
    if let Some(v) = flags.take("max-iters")? {
        cfg.max_iters = v.parse().map_err(|e| format!("--max-iters: {e}"))?;
    }
    // Problem-shape overrides — the large-n quickstart path (see README,
    // "Matrix-free operators") sizes problems straight from the CLI.
    if let Some(v) = flags.take("n")? {
        cfg.problem.n = v.parse().map_err(|e| format!("--n: {e}"))?;
    }
    if let Some(v) = flags.take("m")? {
        cfg.problem.m = v.parse().map_err(|e| format!("--m: {e}"))?;
    }
    if let Some(v) = flags.take("b")? {
        cfg.problem.b = v.parse().map_err(|e| format!("--b: {e}"))?;
    }
    if let Some(v) = flags.take("s")? {
        cfg.problem.s = v.parse().map_err(|e| format!("--s: {e}"))?;
    }
    if let Some(v) = flags.take("ensemble")? {
        let known = "gaussian|gaussian_unnormalized|bernoulli|partial_dct";
        cfg.problem.ensemble = astir::problem::Ensemble::parse(&v)
            .ok_or_else(|| format!("unknown --ensemble `{v}` ({known})"))?;
    }
    if flags.take_bool("no-dense-a") {
        cfg.problem.dense_a = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Thin the Fig.-1 table for terminal display (every 50th iteration).
fn summarize_fig1(full: &astir::metrics::Table) -> astir::metrics::Table {
    full.thinned(50)
}

fn divisors_near(m: usize) -> Vec<usize> {
    // A small spread of block sizes dividing m, around the paper's 15.
    let candidates = [5usize, 10, 15, 20, 25, 30, 50, 60, 75];
    let mut out: Vec<usize> =
        candidates.iter().copied().filter(|&b| b <= m && m % b == 0).collect();
    if out.is_empty() {
        out.push(1);
    }
    out
}

fn baseline_ms(cfg: &ExperimentConfig) -> Vec<usize> {
    // Sweep m from deeply undersampled to the configured m.
    let m = cfg.problem.m;
    let mut ms: Vec<usize> = (1..=6).map(|k| k * m / 6).filter(|&v| v >= cfg.problem.s).collect();
    ms.dedup();
    ms
}

fn run_single(cfg: &ExperimentConfig, alg: &str, backend_name: &str) -> Result<(), String> {
    if !cfg.problem.dense_a {
        // Fail with guidance instead of a deep panic: only the operator-
        // driven kernels run matrix-free.
        if !matches!(alg, "stoiht" | "stogradmp") {
            return Err(format!(
                "alg `{alg}` needs the materialized matrix; with --no-dense-a use \
                 --alg stoiht or --alg stogradmp"
            ));
        }
        if backend_name != "native" {
            return Err("--no-dense-a requires --backend native (PJRT consumes the matrix)".into());
        }
    }
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.problem.generate(&mut rng);
    let opts = GreedyOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_iters: cfg.max_iters,
        ..Default::default()
    };
    println!(
        "single solve: alg={alg} backend={backend_name} n={} m={} b={} s={}",
        cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s
    );
    let t0 = std::time::Instant::now();
    let result = match (alg, backend_name) {
        ("stoiht", "native") => algorithms::stoiht(&problem, &opts, &mut rng),
        ("iht", "native") => algorithms::iht(&problem, &opts),
        ("omp", "native") => algorithms::omp(&problem, &opts),
        ("cosamp", "native") => {
            algorithms::cosamp(&problem, &GreedyOpts { max_iters: 100, ..opts })
        }
        ("stogradmp", "native") => {
            algorithms::stogradmp(&problem, &GreedyOpts { max_iters: 200, ..opts }, &mut rng)
        }
        ("stoiht", "pjrt") => {
            let mut be = PjrtBackend::from_default_dir().map_err(|e| e.to_string())?;
            println!("PJRT platform: {}", be.runtime().platform());
            run_stoiht_on_backend(&problem, &opts, &mut be, &mut rng).map_err(|e| e.to_string())?
        }
        (a, b) => return Err(format!("unsupported combination alg={a} backend={b}")),
    };
    let dt = t0.elapsed();
    println!(
        "converged={} iters={} residual={:.3e} recovery_error={:.3e} wall={:.1?}",
        result.converged,
        result.iters,
        result.residual,
        problem.recovery_error(&result.x),
        dt
    );
    Ok(())
}

/// Sequential StoIHT driven through a [`Backend`] (exercises PJRT).
fn run_stoiht_on_backend<B: Backend>(
    problem: &astir::problem::Problem,
    opts: &GreedyOpts,
    backend: &mut B,
    rng: &mut Rng,
) -> astir::error::Result<algorithms::RunResult> {
    let spec = &problem.spec;
    let mb = spec.num_blocks();
    let mut x = vec![0.0f64; spec.n];
    let zero_mask = vec![0.0f64; spec.n];
    let mut iters = 0;
    let mut converged = false;
    let mut residual = f64::INFINITY;
    for t in 1..=opts.max_iters {
        let block = rng.below(mb);
        let (x_next, _gamma) = backend.stoiht_step(problem, block, &x, opts.gamma, &zero_mask)?;
        x = x_next;
        iters = t;
        residual = backend.residual_norm(problem, &x)?;
        if residual < opts.tolerance {
            converged = true;
            break;
        }
    }
    Ok(algorithms::RunResult {
        x,
        iters,
        converged,
        residual,
        error_trace: Default::default(),
        resid_trace: Default::default(),
    })
}

fn run_async_cmd(
    cfg: &ExperimentConfig,
    cores: usize,
    schedule: &SpeedSchedule,
) -> Result<(), String> {
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.problem.generate(&mut rng);
    let opts = AsyncOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_local_iters: cfg.max_iters,
        schedule: schedule.clone(),
        ..Default::default()
    };
    let seed = cfg.seed ^ 0xA5;
    if cfg.shard.shards > 1 {
        // Sharded-tally path: shards are the threads; --cores does not
        // apply (each shard is one worker against its local tally).
        let sh = cfg.shard.shard_opts();
        let nb = problem.spec.num_blocks();
        if sh.shards > nb {
            return Err(format!(
                "--shards {} exceeds the {} measurement blocks (m/b); lower --shards or --b",
                sh.shards, nb
            ));
        }
        println!(
            "sharded asynchronous {}: shards={} exchange_period={} protocol={} schedule={:?}",
            cfg.alg.as_str(),
            sh.shards,
            sh.exchange_period,
            sh.protocol.as_str(),
            schedule
        );
        let out = ShardedPool::new(sh).run(&problem, cfg.alg, &opts, seed);
        println!(
            "converged={} winner={:?} rounds={} wall={:.1?}",
            out.converged(),
            out.winner,
            out.rounds,
            out.wall
        );
        for (k, s) in out.shards.iter().enumerate() {
            println!(
                "  shard {k}: converged={} iters={} residual={:.3e} error={:.3e}",
                s.converged, s.iters, s.residual, s.final_error
            );
        }
        return Ok(());
    }
    println!(
        "real-thread asynchronous {}: cores={cores} schedule={schedule:?}",
        cfg.alg.as_str()
    );
    let out = match cfg.alg {
        Alg::Stoiht => run_async(&problem, cores, &opts, seed),
        Alg::StoGradMp => run_async_with(&problem, cores, &opts, seed, StoGradMpKernel::new),
    };
    println!(
        "converged={} exit_core={:?} wall={:.1?} residual={:.3e} error={:.3e}",
        out.converged, out.exit_core, out.wall, out.residual, out.final_error
    );
    println!("local iterations per core: {:?}", out.local_iters);
    Ok(())
}

/// `astir batch` — the recovery service: one shared operator, a persistent
/// worker pool, many single-signal or MMV-batched recovery jobs.
fn run_batch_cmd(cfg: &ExperimentConfig) -> Result<(), String> {
    let svc = &cfg.service;
    if svc.batch > 1 && cfg.alg != Alg::Stoiht {
        return Err(
            "--batch > 1 drives the lockstep batched StoIHT path; use --alg stoiht \
             (or --batch 1 for per-signal stogradmp jobs)"
                .into(),
        );
    }
    let (jobs, batch) = (svc.jobs, svc.batch);
    println!(
        "recovery service: {jobs} job(s) x {batch} signal(s), {} pool worker(s), alg {}",
        svc.workers,
        cfg.alg.as_str()
    );
    println!(
        "problem: n={} m={} b={} s={} ensemble={:?} dense_a={}",
        cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s,
        cfg.problem.ensemble, cfg.problem.dense_a
    );

    // One operator for the whole run — the expensive, shareable part of
    // every job's setup (matrix materialization / transform planning).
    let mut rng = Rng::seed_from(cfg.seed);
    let t_setup = std::time::Instant::now();
    let op = cfg.problem.draw_operator(&mut rng);
    let problems: Vec<Vec<astir::problem::Problem>> = (0..jobs)
        .map(|_| {
            if batch == 1 {
                vec![cfg.problem.generate_with_op(&op, &mut rng)]
            } else {
                cfg.problem.generate_mmv_with_op(&op, &mut rng, batch)
            }
        })
        .collect();
    println!(
        "setup: operator drawn once + {} signal(s) generated in {:.1?} (operator shared by Arc)",
        jobs * batch,
        t_setup.elapsed()
    );

    let pool = RecoveryPool::new(svc.workers);
    let opts = AsyncOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_local_iters: cfg.max_iters,
        ..Default::default()
    };
    let alg = cfg.alg;
    let problems = astir::sync::Arc::new(problems);
    let t0 = std::time::Instant::now();
    // Per-job typed responses — the same v1 vocabulary `astir serve`
    // speaks on the wire (service::api).
    let per_job: Vec<Vec<JobResponse>> = if batch == 1 {
        // Single-signal jobs travel as JobRequests carrying their raw
        // measurements; a panicking job poisons only its own slot.
        let job_problems = astir::sync::Arc::clone(&problems);
        let job_opts = opts.clone();
        let job_op = astir::sync::Arc::clone(&op);
        let spec = cfg.problem.clone();
        let results = pool.try_run_jobs(jobs, cfg.seed ^ 0xBA7C4, move |i, rng| {
            let seed = rng.next_u64();
            let req = JobRequest {
                y: Some(job_problems[i][0].y.clone()),
                ..JobRequest::from_spec(&spec, seed)
            };
            // Resolve through the typed request (raw-y path). The one
            // config corner the v1 spec cannot express — dense partial_dct
            // with a non-power-of-two n — solves the generated problem
            // directly.
            let p = match req.problem(&job_op) {
                Ok(p) => p,
                Err(_) => job_problems[i][0].clone(),
            };
            JobResponse::from_outcome(solve_job(&p, alg, &job_opts, seed), false)
        });
        let mut out = Vec::with_capacity(results.len());
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Ok(resp) => out.push(vec![resp]),
                Err(e) => return Err(format!("job {i} failed: {e}")),
            }
        }
        out
    } else {
        let job_problems = astir::sync::Arc::clone(&problems);
        let job_opts = opts.clone();
        pool.run_jobs(jobs, cfg.seed ^ 0xBA7C4, move |i, rng| {
            let seed = rng.next_u64();
            let out = recover_batch_stoiht(&job_problems[i], &job_opts, seed);
            out.signals.into_iter().map(|s| JobResponse::from_outcome(s, true)).collect()
        })
    };
    let wall = t0.elapsed();
    let signals = jobs * batch;
    let converged: usize =
        per_job.iter().flatten().filter(|r| r.converged).count();
    let mean_steps = per_job
        .iter()
        .map(|job| job.iter().map(|r| r.iters).max().unwrap_or(0) as f64)
        .sum::<f64>()
        / per_job.len().max(1) as f64;
    let worst =
        per_job.iter().flatten().map(|r| r.residual).fold(f64::NEG_INFINITY, f64::max);
    println!(
        "served {signals} signal(s) in {:.1?}: {converged}/{signals} converged, \
         {:.1} signals/s, mean {:.0} steps/job, worst residual {:.3e}",
        wall,
        signals as f64 / wall.as_secs_f64().max(1e-9),
        mean_steps,
        worst
    );
    if converged < signals {
        return Err(format!(
            "{} signal(s) did not reach tolerance {:.0e} within {} iterations",
            signals - converged,
            cfg.tolerance,
            cfg.max_iters
        ));
    }
    Ok(())
}

/// `astir serve` — the zero-dependency TCP front-end: typed v1 job API
/// over length-prefixed JSON frames, warm operator cache, deadline
/// micro-batching, admission control. Blocks until killed.
fn run_serve_cmd(cfg: &ExperimentConfig) -> Result<(), String> {
    let sc = &cfg.serve;
    let opts = ServeOpts {
        addr: sc.addr.clone(),
        workers: sc.workers,
        batch_window_ms: sc.batch_window_ms,
        max_inflight: sc.max_inflight,
    };
    println!(
        "astir serve (api v{}): {} handler(s), batch window {} ms, max inflight {}",
        astir::service::api::API_VERSION,
        opts.workers,
        opts.batch_window_ms,
        opts.max_inflight
    );
    let server = Server::bind(opts).map_err(|e| format!("bind {}: {e}", sc.addr))?;
    server.run().map_err(|e| format!("serve: {e}"))
}

/// `astir exchange-hub`: the socket rendezvous one multi-process sharded
/// fleet runs its support exchanges through (workers: `astir
/// shard-worker`). Serves exactly one fleet session, then exits.
fn run_exchange_hub_cmd(
    addr: &str,
    shards: usize,
    join_timeout_ms: Option<u64>,
    round_timeout_ms: Option<u64>,
) -> Result<(), String> {
    let mut opts = HubOpts::new(addr, shards);
    if let Some(ms) = join_timeout_ms {
        opts.join_timeout = std::time::Duration::from_millis(ms);
    }
    opts.round_timeout = round_timeout_ms.map(std::time::Duration::from_millis);
    let hub = ExchangeHub::bind(opts).map_err(|e| format!("bind {addr}: {e}"))?;
    let bound = hub.addr().map_err(|e| format!("hub addr: {e}"))?;
    // Same scrape contract as `astir serve`: a parent process reads the
    // resolved address (port 0 = ephemeral) from this stdout line.
    println!("listening on {bound}");
    println!("exchange hub: one S={shards} fleet session");
    let report = hub.run().map_err(|e| format!("exchange hub: {e}"))?;
    println!("hub-report rounds={} degraded={:?}", report.rounds, report.degraded);
    Ok(())
}

/// `astir shard-worker`: one shard of a multi-process sharded recovery,
/// exchanging support votes through an `astir exchange-hub`. Every
/// worker of a fleet must be launched with the same problem flags,
/// `--seed`, and shard axes; the run is then bit-identical to
/// `astir async --shards S` in one process.
fn run_shard_worker_cmd(
    cfg: &ExperimentConfig,
    hub: &str,
    shard: usize,
    schedule: &SpeedSchedule,
) -> Result<(), String> {
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.problem.generate(&mut rng);
    let opts = AsyncOpts {
        gamma: cfg.gamma,
        tolerance: cfg.tolerance,
        max_local_iters: cfg.max_iters,
        schedule: schedule.clone(),
        ..Default::default()
    };
    // Same run-seed derivation as `run_async_cmd`'s sharded path — that
    // is what makes the fleet bit-identical to the in-process pool.
    let seed = cfg.seed ^ 0xA5;
    let sh = cfg.shard.shard_opts();
    let nb = problem.spec.num_blocks();
    if sh.shards > nb {
        return Err(format!(
            "--shards {} exceeds the {} measurement blocks (m/b); lower --shards or --b",
            sh.shards, nb
        ));
    }
    println!(
        "joining {hub} as shard {shard}/{}: alg={} E={} protocol={}",
        sh.shards,
        cfg.alg.as_str(),
        sh.exchange_period,
        sh.protocol.as_str()
    );
    let transport =
        join_fleet(&problem, hub, shard, &sh).map_err(|e| format!("shard {shard}: {e}"))?;
    // Scrape line for drivers/tests: the fleet is assembled and the
    // session has started once this prints.
    println!("joined hub as shard {shard}");
    let run = run_joined(&problem, transport, shard, &sh, cfg.alg, &opts, seed)
        .map_err(|e| format!("shard {shard}: {e}"))?;
    let o = &run.outcome;
    println!(
        "shard-result shard={shard} converged={} iters={} rounds={} stale_rounds={} \
         residual_bits={:016x} error_bits={:016x} x_fnv={:016x}",
        o.converged,
        o.iters,
        run.rounds,
        run.stale_rounds,
        o.residual.to_bits(),
        o.final_error.to_bits(),
        x_digest(&o.x)
    );
    Ok(())
}

fn print_info(cfg: &ExperimentConfig) {
    println!("astir {} — asynchronous sparse recovery (Needell & Woolf 2017)", astir::VERSION);
    println!("\n[config]");
    println!(
        "problem: n={} m={} b={} s={} ensemble={:?} signal={:?} noise={} dense_a={}",
        cfg.problem.n, cfg.problem.m, cfg.problem.b, cfg.problem.s,
        cfg.problem.ensemble, cfg.problem.signal, cfg.problem.noise_std, cfg.problem.dense_a
    );
    println!(
        "gamma={} tol={} max_iters={} trials={} seed={} cores={:?} trial_threads={}",
        cfg.gamma, cfg.tolerance, cfg.max_iters, cfg.trials, cfg.seed, cfg.cores, cfg.trial_threads
    );
    println!(
        "service: workers={} jobs={} batch={}",
        cfg.service.workers, cfg.service.jobs, cfg.service.batch
    );
    println!("\n[artifacts] ({})", ArtifactStore::default_dir().display());
    match ArtifactStore::discover(&ArtifactStore::default_dir()) {
        Ok(store) => {
            for meta in store.iter() {
                println!(
                    "  {:?} n={} m={} rows={} s={} -> {}",
                    meta.kind, meta.n, meta.m, meta.b, meta.s, meta.hlo_path.display()
                );
            }
        }
        Err(e) => println!("  (unavailable: {e})"),
    }
    println!(
        "\n[backends] native: {} | pjrt: executes the artifacts above",
        NativeBackend::new().name()
    );
}

/// `astir lint`: run the in-crate static analysis over the source tree
/// and fail (nonzero exit) on any finding — the CI hard gate.
fn lint_cmd(flags: &mut Flags) -> Result<(), String> {
    let root = match flags.take("root")? {
        Some(dir) => std::path::PathBuf::from(dir),
        None => {
            // Work from a checkout root (`rust/src`) or the crate dir.
            let cwd = std::path::PathBuf::from(".");
            if cwd.join("src").is_dir() {
                cwd
            } else {
                std::path::PathBuf::from("rust")
            }
        }
    };
    flags.finish()?;
    if !root.join("src").is_dir() {
        return Err(format!("lint: no src/ under {} (use --root)", root.display()));
    }
    let findings = astir::lint::lint_tree(&root).map_err(|e| format!("lint: {e}"))?;
    if findings.is_empty() {
        println!("lint: clean ({} rules over {})", 6, root.display());
        return Ok(());
    }
    for f in &findings {
        eprintln!("{f}");
    }
    Err(format!("lint: {} finding(s)", findings.len()))
}

fn print_usage() {
    println!(
        "astir — asynchronous parallel sparse recovery (Needell & Woolf 2017)

USAGE: astir <command> [flags]

COMMANDS
  fig1                         regenerate Fig. 1 (oracle-support StoIHT)
  fig2 --schedule all-fast     regenerate Fig. 2 upper panel
  fig2 --schedule half-slow    regenerate Fig. 2 lower panel
  ablation <name>              A1..A4 (tally-vs-shared-x, inconsistent-reads,
                               weighting, block-size)
  baselines                    A5 phase-transition sweep (IHT/StoIHT/OMP/...)
  bench                        run the bench suite registry (perf telemetry)
  run --alg X --backend Y      one solve (alg: stoiht|iht|omp|cosamp|stogradmp;
                               backend: native|pjrt)
  async --cores N              real-thread asynchronous solve (StoIHT default)
  batch                        recovery service: persistent worker pool serving
                               many jobs against ONE shared operator
  serve                        TCP front-end for the recovery service: typed v1
                               job API, operator cache, deadline micro-batching
  exchange-hub                 rendezvous for a multi-process sharded fleet: S
                               shard processes swap vote snapshots through it
                               (one fleet session per run; wire v1 framing)
  shard-worker                 one shard of a distributed sharded recovery;
                               bit-identical to `async --shards S` in-process
                               when every worker shares flags and --seed
  lint                         concurrency-hygiene static analysis (hard CI
                               gate: atomic-ordering justifications, the
                               crate::sync doorway, SAFETY comments, hygiene,
                               std::net confined to src/service/, SIMD
                               intrinsics confined to src/linalg/simd/)
  info                         show config + discovered AOT artifacts

COMMON FLAGS
  --config file.toml   load an experiment config (see configs/)
  --trials N           Monte-Carlo trials (default 500)
  --seed N             master seed
  --threads N          worker threads for trial batching
  --cores-list a,b,c   core counts to sweep
  --max-iters N        iteration / time-step cap
  --n/--m/--b/--s N    override the problem shape
  --ensemble NAME      gaussian | gaussian_unnormalized | bernoulli | partial_dct
  --no-dense-a         matrix-free operator (partial_dct, power-of-two n):
                       never materializes the m x n matrix — the large-n path.
                       e.g.  astir run --alg stoiht --ensemble partial_dct \
                             --no-dense-a --n 1048576 --m 327680 --b 16 --s 50
                       (stogradmp runs matrix-free too, but its per-iteration
                       m x 3s panel re-fit wants m in the 10^4-10^5 range)

ASYNC / FIG2 FLAGS
  --alg stoiht|stogradmp  which SupportKernel the async layers drive
  --schedule NAME         all-fast | half-slow
  --period K              slow-core period for half-slow (default 4)

SHARD FLAGS (astir async; TOML [shard] section: shards/exchange_period/protocol)
  --shards S              partition the measurement blocks over S shard threads,
                          each voting into its own LOCAL tally (1 = unsharded,
                          bit-identical to the single-tally path; default 1)
  --exchange-period E     staleness bound: shards exchange support votes every E
                          local steps through a barrier (default 16)
  --exchange-protocol P   gossip (live local votes + stale peer sums) | leader
                          (all shards read one frozen merged view; default gossip)

BATCH FLAGS (astir batch; TOML [service] section: workers/jobs/batch)
  --jobs N             recovery jobs to serve (default 16)
  --workers N          persistent pool threads, spawned once (default: cores)
  --batch B            signals per job, recovered in MMV lockstep through one
                       multi-RHS proxy + a tally SHARED across the batch
                       (B > 1 is StoIHT-only; signals share the operator and,
                       per job, the planted support)
                       e.g.  astir batch --jobs 16 --workers 8 --batch 8 \
                             --ensemble partial_dct --no-dense-a --n 131072 \
                             --m 4096 --b 512 --s 16

SERVE FLAGS (astir serve; TOML [serve] section: addr/workers/batch_window_ms/
             max_inflight)
  --addr host:port     bind address (default 127.0.0.1:7878; port 0 = ephemeral)
  --workers N          connection-handler threads (default: cores)
  --batch-window-ms T  hold compatible jobs up to T ms and recover them in one
                       lockstep window (0 = solo solves, bit-identical to an
                       in-process solve_job with the same seed; default 2)
  --max-inflight N     admission cap; excess jobs get a typed `busy` rejection
                       instead of queueing (default 64)

DISTRIBUTED FLAGS (astir exchange-hub / shard-worker)
  --addr host:port     hub bind address (default 127.0.0.1:7879; port 0 =
                       ephemeral, scraped from the `listening on` line)
  --hub host:port      hub address a worker joins (default 127.0.0.1:7879)
  --shard K            this worker's shard id in 0..S
  --shards S           fleet size (hub and every worker must agree)
  --join-timeout-ms T  hub: fleet-assembly window before starting degraded
                       (default 30000)
  --round-timeout-ms T hub: per-peer round deadline; a worker that misses it
                       is retired and its last snapshot merged stale
                       (default: derived from the staleness bound E)
  plus, for workers, the SHARD FLAGS above and the same problem flags /
  --seed as `astir async` — identical flags across the fleet give a run
  bit-identical to the in-process `astir async --shards S`

LINT FLAGS (astir lint)
  --root DIR           crate root to lint (default: ./ or ./rust, whichever
                       has a src/ tree)

BENCH FLAGS (astir bench)
  --filter substr      run only benches whose suite/name contains substr
  --smoke              CI-sized budgets (also skips jumbo scales)
  --list               list registered benches without running them
  --json path          write the run's JSON telemetry (astir-bench-v1)
  --compare base.json  diff against a baseline; exit nonzero on regression
  --threshold frac     regression threshold as a fraction (default 0.5)"
    );
}
