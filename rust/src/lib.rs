//! # ASTIR — Asynchronous Stochastic Iterative Recovery
//!
//! A production-quality reproduction of Needell & Woolf,
//! *"An Asynchronous Parallel Approach to Sparse Recovery"* (2017), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: a
//!   multi-core asynchronous runtime in which worker cores run StoIHT
//!   iterations and share a *tally vector* `φ` (not the iterate itself) in
//!   shared memory via atomic updates.
//! * **Layer 2 (`python/compile/model.py`)** — the StoIHT proxy/identify
//!   compute graph in JAX, AOT-lowered to HLO text artifacts.
//! * **Layer 1 (`python/compile/kernels/`)** — the block-gradient hot-spot as
//!   a Pallas kernel (interpret mode on CPU), validated against a pure-jnp
//!   oracle.
//!
//! Python never runs on the solve path: `make artifacts` lowers the compute
//! graph once, and the Rust binary loads the HLO via the PJRT C API
//! (`runtime` module) or runs the hand-optimized native kernels (`backend`).
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`linalg`] | BLAS-like substrate (gemv, QR, CGLS) + the `MeasureOp` operator layer (dense / matrix-free subsampled DCT, in-crate cache-blocked FFT with a shared plan cache) |
//! | [`linalg::simd`] | explicit-width kernel doorway: runtime AVX2/NEON/scalar dispatch for dot/axpy/nrm2 + the 4-column panel dot, bit-identical across levels |
//! | [`rng`] | deterministic xoshiro256++ RNG, Gaussian sampling |
//! | [`problem`] | compressed-sensing problem generation (matrix ensembles, sparse signals, block partitions) |
//! | [`support`] | top-`s` support identification, unions, accuracy metrics |
//! | [`algorithms`] | IHT, StoIHT, OMP, CoSaMP, StoGradMP baselines |
//! | [`tally`] | the shared atomic tally vector `φ` (the paper's §III) + sharded exchange: canonical vote merges, `ExchangeBoard` rendezvous |
//! | [`sim`] | discrete-time multicore simulator (paper §IV-B semantics), incl. sharded-tally axes (shards × exchange period) |
//! | [`async_runtime`] | real-thread asynchronous execution with shared tally; resumable `WorkerDriver` loop |
//! | [`coordinator`] | leader/worker orchestration, trial batching, halting |
//! | [`service`] | persistent recovery pool + batched MMV recovery + bounded-staleness `ShardedPool` (the serving layer) |
//! | [`service::api`] | versioned typed job API (`JobRequest`/`JobResponse`/`ServeError`, `api_version: 1`) |
//! | [`service::wire`] | length-prefixed JSON framing + the blocking TCP [`service::wire::Client`] |
//! | [`service::server`] | `astir serve` — TCP front-end with operator cache, deadline micro-batching, admission control |
//! | [`service::transport`] | socket-backed exchange rendezvous: `astir exchange-hub` + `shard-worker` fleets, bit-identical to the in-process board |
//! | [`runtime`] | PJRT client wrapper: load + execute AOT HLO artifacts |
//! | [`backend`] | compute-backend abstraction (native vs PJRT) |
//! | [`config`] | TOML-subset config parser + experiment configs |
//! | [`metrics`] | convergence traces, trial statistics, CSV/JSON output |
//! | [`experiments`] | drivers regenerating every figure in the paper |
//! | [`report`] | text/CSV/JSON rendering of experiment outputs |
//! | [`bench_harness`] | bench suite registry, timing harness, JSON perf telemetry |
//! | [`sync`] | the crate's single doorway to concurrency primitives (std re-exports, or a model-checked shim under `--features model`) |
//! | [`lint`] | in-crate static analysis behind `astir lint` (atomic-ordering justifications, `sync` + `std::net` doorway enforcement, SAFETY comments) |
//! | [`error`] | zero-dependency error type (`anyhow` stand-in) |
//! | [`testutil`] | mini property-testing framework used by unit tests |

// Unsafe code is confined to two audited places: every other module must
// stay safe. The `#[allow(unsafe_code)]` exceptions are
// `coordinator::ResultSlots` (whose protocol the model checker and Miri
// both exercise) and `linalg::simd::avx2` (probe-gated AVX2 intrinsics,
// every block SAFETY-commented and pinned bit-identical to the scalar
// kernels by `rust/tests/simd_parity.rs`); see README "Concurrency
// correctness" and "SIMD & transform core".
#![deny(unsafe_code)]

pub mod algorithms;
pub mod async_runtime;
pub mod backend;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod lint;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod problem;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod support;
pub mod sync;
pub mod tally;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = error::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
