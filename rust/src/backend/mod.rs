//! Compute-backend abstraction: the same StoIHT arithmetic served by either
//! the hand-optimized native kernels or the AOT-compiled HLO artifacts.
//!
//! The asynchronous protocols (sim, threads) are backend-agnostic; the
//! Monte-Carlo sweeps use [`NativeBackend`] for throughput while
//! [`PjrtBackend`] proves the three-layer composition (Pallas kernel → JAX
//! graph → HLO text → PJRT execution) on the same problems — the
//! integration tests in `rust/tests/pjrt_integration.rs` pin the two
//! backends against each other to f32 tolerance.

use crate::error::Result;
use crate::linalg::RowBlock;
use crate::problem::Problem;
use crate::runtime::PjrtRuntime;
use crate::support::top_s;

/// One iteration's worth of StoIHT compute.
pub trait Backend {
    /// Human-readable backend name (diagnostics / bench labels).
    fn name(&self) -> &'static str;

    /// Proxy step on one measurement block:
    /// `b = x + alpha * A_b^T (y_b - A_b x)`.
    fn proxy_step(
        &mut self,
        problem: &Problem,
        block: usize,
        x: &[f64],
        alpha: f64,
    ) -> Result<Vec<f64>>;

    /// Full Alg.-2 step: proxy + identify + union(tally mask) + estimate.
    /// `tally_mask` is a 0/1 vector of length `n`.
    /// Returns `(x_next, sorted Γ^t)`.
    fn stoiht_step(
        &mut self,
        problem: &Problem,
        block: usize,
        x: &[f64],
        alpha: f64,
        tally_mask: &[f64],
    ) -> Result<(Vec<f64>, Vec<usize>)>;

    /// Halting statistic `||y - A x||_2`.
    fn residual_norm(&mut self, problem: &Problem, x: &[f64]) -> Result<f64>;
}

/// Pure-Rust backend (f64, allocation-free inner kernels).
#[derive(Default)]
pub struct NativeBackend {
    resid_scratch: Vec<f64>,
    proxy_scratch: Vec<f64>,
    idx_scratch: Vec<usize>,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    fn proxy_into(&mut self, blk: RowBlock<'_, f64>, yb: &[f64], x: &[f64], alpha: f64) {
        self.resid_scratch.resize(blk.rows(), 0.0);
        self.proxy_scratch.resize(blk.cols(), 0.0);
        blk.proxy_step_into(yb, x, alpha, &mut self.resid_scratch, &mut self.proxy_scratch);
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn proxy_step(
        &mut self,
        problem: &Problem,
        block: usize,
        x: &[f64],
        alpha: f64,
    ) -> Result<Vec<f64>> {
        let (blk, yb) = problem.block(block);
        self.proxy_into(blk, yb, x, alpha);
        Ok(self.proxy_scratch.clone())
    }

    fn stoiht_step(
        &mut self,
        problem: &Problem,
        block: usize,
        x: &[f64],
        alpha: f64,
        tally_mask: &[f64],
    ) -> Result<(Vec<f64>, Vec<usize>)> {
        let s = problem.spec.s;
        let (blk, yb) = problem.block(block);
        self.proxy_into(blk, yb, x, alpha);
        let gamma = {
            let mut sel = vec![0usize; s.min(self.proxy_scratch.len())];
            crate::support::top_s_into(&self.proxy_scratch, s, &mut self.idx_scratch, &mut sel);
            sel
        };
        let mut x_next = vec![0.0; problem.spec.n];
        for &i in &gamma {
            x_next[i] = self.proxy_scratch[i];
        }
        for (i, &m) in tally_mask.iter().enumerate() {
            if m != 0.0 {
                x_next[i] = self.proxy_scratch[i];
            }
        }
        Ok((x_next, gamma))
    }

    fn residual_norm(&mut self, problem: &Problem, x: &[f64]) -> Result<f64> {
        Ok(problem.residual_norm(x))
    }
}

/// Backend executing the AOT HLO artifacts through PJRT.
///
/// Not `Send`: construct one per thread (see [`PjrtRuntime`]).
pub struct PjrtBackend {
    runtime: PjrtRuntime,
}

impl PjrtBackend {
    pub fn new(runtime: PjrtRuntime) -> Self {
        PjrtBackend { runtime }
    }

    /// Runtime from the default artifact directory.
    pub fn from_default_dir() -> Result<Self> {
        Ok(PjrtBackend { runtime: PjrtRuntime::from_default_dir()? })
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn proxy_step(
        &mut self,
        problem: &Problem,
        block: usize,
        x: &[f64],
        alpha: f64,
    ) -> Result<Vec<f64>> {
        // The artifact set has no bare-proxy entry point; run the full step
        // with an all-ones tally mask, which returns b restricted to
        // Γ ∪ everything = b itself.
        let spec = &problem.spec;
        let ones = vec![1.0f64; spec.n];
        let (x_next, _) = self.stoiht_step(problem, block, x, alpha, &ones)?;
        Ok(x_next)
    }

    fn stoiht_step(
        &mut self,
        problem: &Problem,
        block: usize,
        x: &[f64],
        alpha: f64,
        tally_mask: &[f64],
    ) -> Result<(Vec<f64>, Vec<usize>)> {
        let spec = &problem.spec;
        let b = spec.b;
        let a_blk = &problem.a().data()[block * b * spec.n..(block + 1) * b * spec.n];
        let y_blk = &problem.y[block * b..(block + 1) * b];
        self.runtime
            .stoiht_step(spec.n, b, spec.s, a_blk, y_blk, x, alpha, tally_mask)
    }

    fn residual_norm(&mut self, problem: &Problem, x: &[f64]) -> Result<f64> {
        let spec = &problem.spec;
        self.runtime
            .residual_norm(spec.n, spec.m, problem.a().data(), &problem.y, x)
    }
}

/// Reference helper shared by backend tests: the full Alg.-2 step computed
/// naively (dense top-s via sort) — a third, independent implementation to
/// triangulate native vs PJRT.
pub fn reference_step(
    problem: &Problem,
    block: usize,
    x: &[f64],
    alpha: f64,
    tally_mask: &[f64],
) -> (Vec<f64>, Vec<usize>) {
    let spec = &problem.spec;
    let (blk, yb) = problem.block(block);
    let ax = blk.gemv(x);
    let r: Vec<f64> = yb.iter().zip(&ax).map(|(&a, &b)| a - b).collect();
    let atr = blk.gemv_t(&r);
    let proxy: Vec<f64> = x.iter().zip(&atr).map(|(&xi, &gi)| xi + alpha * gi).collect();
    let gamma = top_s(&proxy, spec.s);
    let mut x_next = vec![0.0; spec.n];
    for &i in &gamma {
        x_next[i] = proxy[i];
    }
    for (i, &m) in tally_mask.iter().enumerate() {
        if m != 0.0 {
            x_next[i] = proxy[i];
        }
    }
    (x_next, gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemSpec;
    use crate::rng::Rng;

    fn tiny() -> Problem {
        ProblemSpec::tiny().generate(&mut Rng::seed_from(5))
    }

    #[test]
    fn native_step_matches_reference() {
        let p = tiny();
        let mut be = NativeBackend::new();
        let mut rng = Rng::seed_from(1);
        for block in 0..p.spec.num_blocks() {
            let x: Vec<f64> = (0..p.spec.n).map(|_| rng.gauss() * 0.1).collect();
            let mut mask = vec![0.0; p.spec.n];
            for i in rng.subset(p.spec.n, 5) {
                mask[i] = 1.0;
            }
            let (want_x, want_g) = reference_step(&p, block, &x, 1.0, &mask);
            let (got_x, got_g) = be.stoiht_step(&p, block, &x, 1.0, &mask).unwrap();
            assert_eq!(got_g, want_g);
            for i in 0..p.spec.n {
                assert!((got_x[i] - want_x[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn native_proxy_matches_composition() {
        let p = tiny();
        let mut be = NativeBackend::new();
        let x: Vec<f64> = (0..p.spec.n).map(|i| (i as f64 * 0.3).sin()).collect();
        let proxy = be.proxy_step(&p, 1, &x, 0.7).unwrap();
        let (blk, yb) = p.block(1);
        let ax = blk.gemv(&x);
        let r: Vec<f64> = yb.iter().zip(&ax).map(|(&a, &b)| a - b).collect();
        let atr = blk.gemv_t(&r);
        for i in 0..p.spec.n {
            assert!((proxy[i] - (x[i] + 0.7 * atr[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn native_residual_matches_problem() {
        let p = tiny();
        let mut be = NativeBackend::new();
        let x = vec![0.0; p.spec.n];
        let r = be.residual_norm(&p, &x).unwrap();
        assert!((r - p.residual_norm(&x)).abs() < 1e-12);
    }

    #[test]
    fn backend_names() {
        assert_eq!(NativeBackend::new().name(), "native");
    }
}
